//! Property test: the fused plan's output must be byte-identical to the
//! stage-by-stage reference path (ingest → drop_nulls → distinct →
//! PipelineModel::transform → collect → empty sweep) on seeded corpora —
//! same schema, same rows in the same order, same drop accounting.
//!
//! The same contract extends to every plan-layer feature: positional
//! `Sample`, `Limit`, multiple `Distinct` ops, and the two-pass `IDF`
//! lowering, each checked staged-vs-fused-vs-streaming-vs-multi-process
//! (including `queue_cap = 1` and fewer-shards-than-workers) and — for
//! the estimator pipeline — against a cache round trip. The process
//! arms spawn real worker processes (the built `repro` binary's hidden
//! `plan-worker` mode); the remote arms drive in-process loopback TCP
//! workers ([`p3sapp::plan::remote::serve_listener`]) over the same
//! `P3PJ`/`P3PW` frames, covering both inline and fetch-by-digest
//! shard shipping.

use p3sapp::cache::CacheManager;
use p3sapp::corpus::{generate_corpus, CorpusSpec};
use p3sapp::driver::{run_p3sapp, DriverOptions};
use p3sapp::frame::{distinct, drop_nulls, Frame, LocalFrame};
use p3sapp::ingest::list_shards;
use p3sapp::ingest::spark::{ingest_files, IngestOptions};
use p3sapp::pipeline::presets::{
    abstract_stages, case_study_features_pipeline, case_study_pipeline, case_study_plan,
    case_study_plan_with, CaseStudyOptions,
};
use p3sapp::plan::{sample_keeps, LogicalPlan, ProcessOptions, RemoteOptions, StreamOptions};
use std::path::PathBuf;
use std::sync::Arc;

const COLS: [&str; 2] = ["title", "abstract"];

/// Multi-process executor options for these tests: the harness
/// executable has no `plan-worker` mode, so the workers are the built
/// `repro` binary.
fn process_opts(processes: usize) -> ProcessOptions {
    ProcessOptions {
        processes,
        worker_cmd: Some(PathBuf::from(env!("CARGO_BIN_EXE_repro"))),
        ..Default::default()
    }
}

/// Remote executor options backed by `n` fresh in-process loopback
/// workers: each endpoint is a real `TcpListener` on `127.0.0.1:0`
/// served by [`p3sapp::plan::remote::serve_listener`] on its own
/// thread (the threads outlive the test harmlessly — an idle accept
/// loop). `inline_max_bytes` is passed through so tests can force the
/// fetch-by-digest shard path.
fn loopback_remote(n: usize, inline_max_bytes: u64) -> RemoteOptions {
    let endpoints = (0..n)
        .map(|_| {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let ep = listener.local_addr().unwrap().to_string();
            std::thread::spawn(move || p3sapp::plan::remote::serve_listener(listener));
            ep
        })
        .collect();
    RemoteOptions { endpoints, inline_max_bytes, ..Default::default() }
}

fn corpus(name: &str, spec: &CorpusSpec) -> (PathBuf, Vec<PathBuf>) {
    let dir = std::env::temp_dir().join(format!("p3sapp-planeq-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    generate_corpus(spec, &dir).unwrap();
    let files = list_shards(&dir).unwrap();
    (dir, files)
}

/// Reference drop accounting alongside the reference frame.
struct Reference {
    frame: LocalFrame,
    nulls_dropped: usize,
    dups_dropped: usize,
    empties_dropped: usize,
}

/// The pre-plan driver path, stage by stage with full barriers.
fn staged_reference(files: &[PathBuf], workers: usize) -> Reference {
    let frame = ingest_files(files, &COLS, &IngestOptions::with_workers(workers)).unwrap();
    let (frame, nulls_dropped) = drop_nulls(frame, &COLS).unwrap();
    let (frame, dups_dropped) = distinct(frame, &COLS).unwrap();
    let model = case_study_pipeline("title", "abstract").fit(&frame).unwrap();
    let frame = model.transform(frame, workers).unwrap();
    let mut local = frame.collect();
    for ci in 0..local.num_columns() {
        local.column_mut(ci).nullify_empty_strs();
    }
    let empties_dropped = local.drop_nulls(&COLS).unwrap();
    Reference { frame: local, nulls_dropped, dups_dropped, empties_dropped }
}

#[test]
fn fused_plan_is_byte_identical_to_staged_reference() {
    for seed in [2, 41, 77, 123] {
        let mut spec = CorpusSpec::tiny(seed);
        // Stress every physical op: plenty of dups, nulls and noise.
        spec.dup_rate = 0.15;
        spec.null_title_rate = 0.1;
        spec.null_abstract_rate = 0.1;
        let (dir, files) = corpus(&format!("seed{seed}"), &spec);

        let reference = staged_reference(&files, 3);
        let out = case_study_plan(&files, "title", "abstract")
            .optimize()
            .execute(3)
            .unwrap();

        assert_eq!(out.frame, reference.frame, "seed {seed}: frames diverge");
        // The multi-process executor runs the same program in worker OS
        // processes and must land on the same bytes and accounting.
        let processed = case_study_plan(&files, "title", "abstract")
            .optimize()
            .execute_process(&process_opts(2))
            .unwrap();
        assert_eq!(processed.frame, reference.frame, "seed {seed}: process frames diverge");
        assert_eq!(processed.nulls_dropped, out.nulls_dropped, "seed {seed}: process nulls");
        assert_eq!(processed.dups_dropped, out.dups_dropped, "seed {seed}: process dups");
        assert_eq!(
            processed.empties_dropped, out.empties_dropped,
            "seed {seed}: process empties"
        );
        // The remote executor ships the same program over loopback TCP
        // (inline_max_bytes = 1 forces every shard through the
        // fetch-by-digest round trip) and streams chunk frames back —
        // same bytes, same accounting.
        let remoted = case_study_plan(&files, "title", "abstract")
            .optimize()
            .execute_remote(&loopback_remote(2, 1))
            .unwrap();
        assert_eq!(remoted.frame, reference.frame, "seed {seed}: remote frames diverge");
        assert_eq!(remoted.nulls_dropped, out.nulls_dropped, "seed {seed}: remote nulls");
        assert_eq!(remoted.dups_dropped, out.dups_dropped, "seed {seed}: remote dups");
        assert_eq!(
            remoted.empties_dropped, out.empties_dropped,
            "seed {seed}: remote empties"
        );
        assert_eq!(out.nulls_dropped, reference.nulls_dropped, "seed {seed}: null drops");
        // A duplicated row that cleans to empty is attributed to the
        // dedup counter by the staged path (dedup runs before cleaning)
        // but to the empty counter by the fused pass (the per-partition
        // empty sweep runs before the driver's dedup merge), so only
        // the sum is attribution-independent.
        assert_eq!(
            out.dups_dropped + out.empties_dropped,
            reference.dups_dropped + reference.empties_dropped,
            "seed {seed}: dup+empty drops"
        );
        assert_eq!(out.rows_out, reference.frame.num_rows(), "seed {seed}: row count");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn streaming_plan_is_byte_identical_to_staged_and_fused_paths() {
    // The streaming executor re-schedules the same per-shard program
    // (parse overlaps clean), so its output must match both the staged
    // reference and the fused single pass bit for bit — including with
    // a fully serialized queue (queue_cap = 1) and across seeds.
    for seed in [2, 77, 123] {
        let mut spec = CorpusSpec::tiny(seed);
        spec.dup_rate = 0.15;
        spec.null_title_rate = 0.1;
        spec.null_abstract_rate = 0.1;
        let (dir, files) = corpus(&format!("stream{seed}"), &spec);

        let reference = staged_reference(&files, 3);
        let plan = case_study_plan(&files, "title", "abstract").optimize();
        let fused = plan.execute(3).unwrap();

        for opts in [
            StreamOptions::default(),
            StreamOptions { readers: 2, workers: 3, queue_cap: 1 },
            StreamOptions { readers: 1, workers: 1, queue_cap: 2 },
        ] {
            let out = plan.execute_stream(&opts).unwrap();
            assert_eq!(out.frame, reference.frame, "seed {seed} {opts:?}: vs staged");
            assert_eq!(out.frame, fused.frame, "seed {seed} {opts:?}: vs fused");
            assert_eq!(out.rows_out, fused.rows_out, "seed {seed} {opts:?}: rows");
            assert_eq!(
                out.rows_ingested, fused.rows_ingested,
                "seed {seed} {opts:?}: ingested"
            );
            assert_eq!(
                out.nulls_dropped, fused.nulls_dropped,
                "seed {seed} {opts:?}: null drops"
            );
            assert_eq!(
                out.dups_dropped, fused.dups_dropped,
                "seed {seed} {opts:?}: dup drops"
            );
            assert_eq!(
                out.empties_dropped, fused.empties_dropped,
                "seed {seed} {opts:?}: empty drops"
            );
        }

        // The unoptimized plan streams to the same bytes too.
        let unfused_streamed = case_study_plan(&files, "title", "abstract")
            .execute_stream(&StreamOptions { readers: 2, workers: 2, queue_cap: 1 })
            .unwrap();
        assert_eq!(unfused_streamed.frame, reference.frame, "seed {seed}: unfused stream");

        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn fused_plan_equivalence_survives_worker_skew() {
    let (dir, files) = corpus("skew", &CorpusSpec::tiny(55));
    let reference = staged_reference(&files, 1);
    for workers in [1, 2, 8] {
        let out = case_study_plan(&files, "title", "abstract")
            .optimize()
            .execute(workers)
            .unwrap();
        assert_eq!(out.frame, reference.frame, "workers {workers}");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Finish a staged run: collect, empty-sweep the string columns, drop
/// the swept rows (the DropEmpty analog shared by every reference here).
fn collect_and_sweep(frame: Frame) -> LocalFrame {
    let mut local = frame.collect();
    for ci in 0..local.num_columns() {
        local.column_mut(ci).nullify_empty_strs();
    }
    local.drop_nulls(&COLS).unwrap();
    local
}

#[test]
fn sampled_plan_matches_the_positionally_sampled_staged_reference() {
    let (fraction, seed) = (0.5, 42u64);
    for corpus_seed in [2, 77] {
        let mut spec = CorpusSpec::tiny(corpus_seed);
        spec.dup_rate = 0.15;
        spec.null_title_rate = 0.1;
        let (dir, files) = corpus(&format!("sample{corpus_seed}"), &spec);

        // Staged reference: ingest (one partition per shard, in shard
        // order), apply the same positional mask the plan's Sample op
        // uses, then the usual staged path.
        let mut frame =
            ingest_files(&files, &COLS, &IngestOptions::with_workers(3)).unwrap();
        assert_eq!(frame.num_partitions(), files.len(), "one partition per shard");
        let mut sampled_out = 0usize;
        for (shard, part) in frame.partitions_mut().iter_mut().enumerate() {
            let mask: Vec<bool> = (0..part.num_rows())
                .map(|i| sample_keeps(seed, shard, i, fraction))
                .collect();
            sampled_out += mask.iter().filter(|&&k| !k).count();
            *part = part.filter_by_mask(&mask);
        }
        let (frame, nulls_dropped) = drop_nulls(frame, &COLS).unwrap();
        let (frame, _) = distinct(frame, &COLS).unwrap();
        let model = case_study_pipeline("title", "abstract").fit(&frame).unwrap();
        let reference = collect_and_sweep(model.transform(frame, 3).unwrap());
        assert!(sampled_out > 0, "a 50% sample must skip rows");

        let opts = CaseStudyOptions { sample: Some((fraction, seed)), ..Default::default() };
        let plan = case_study_plan_with(&files, "title", "abstract", &opts).optimize();
        let fused = plan.execute(3).unwrap();
        assert_eq!(fused.frame, reference, "seed {corpus_seed}: fused vs staged");
        assert_eq!(fused.sampled_out, sampled_out, "seed {corpus_seed}: sample count");
        assert_eq!(fused.nulls_dropped, nulls_dropped, "seed {corpus_seed}: null drops");
        for stream in [
            StreamOptions { readers: 2, workers: 3, queue_cap: 1 },
            // More workers than shards: the scarce-shard delegation
            // must keep positional sampling intact too.
            StreamOptions { readers: 2, workers: 64, queue_cap: 4 },
        ] {
            let streamed = plan.execute_stream(&stream).unwrap();
            assert_eq!(streamed.frame, reference, "seed {corpus_seed} {stream:?}");
            assert_eq!(streamed.sampled_out, sampled_out, "seed {corpus_seed} {stream:?}");
        }
        // Worker processes receive shard indices with their paths, so
        // positional sampling survives the process boundary too.
        let processed = plan.execute_process(&process_opts(2)).unwrap();
        assert_eq!(processed.frame, reference, "seed {corpus_seed}: process");
        assert_eq!(processed.sampled_out, sampled_out, "seed {corpus_seed}: process sample");
        // Remote workers also receive shard indices with their shards,
        // so positional sampling survives the TCP boundary.
        let remoted = plan.execute_remote(&loopback_remote(2, 4 * 1024 * 1024)).unwrap();
        assert_eq!(remoted.frame, reference, "seed {corpus_seed}: remote");
        assert_eq!(remoted.sampled_out, sampled_out, "seed {corpus_seed}: remote sample");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn limited_plan_is_the_staged_reference_prefix_everywhere() {
    let mut spec = CorpusSpec::tiny(41);
    spec.dup_rate = 0.15;
    let (dir, files) = corpus("limit", &spec);
    let reference = staged_reference(&files, 3);
    let n = reference.frame.num_rows() / 2;
    assert!(n > 0, "corpus too small to exercise Limit");

    let opts = CaseStudyOptions { limit: Some(n), ..Default::default() };
    let plan = case_study_plan_with(&files, "title", "abstract", &opts).optimize();
    let mut outputs = vec![plan.execute(1).unwrap(), plan.execute(3).unwrap()];
    for stream in [
        StreamOptions { readers: 2, workers: 3, queue_cap: 1 },
        StreamOptions { readers: 2, workers: 64, queue_cap: 4 },
    ] {
        outputs.push(plan.execute_stream(&stream).unwrap());
    }
    // The global Limit budget is enforced at the driver merge, so the
    // process and remote executors cut the exact same prefix — for
    // remote, the shard-ordered fold of streamed chunk frames is what
    // keeps the budget deterministic.
    outputs.push(plan.execute_process(&process_opts(2)).unwrap());
    outputs.push(plan.execute_remote(&loopback_remote(2, 1)).unwrap());
    for out in &outputs {
        assert_eq!(out.rows_out, n);
        assert_eq!(out.limited_out, reference.frame.num_rows() - n);
        assert_eq!(out.frame, outputs[0].frame, "executors disagree under Limit");
        for ci in 0..out.frame.num_columns() {
            for ri in 0..n {
                assert_eq!(
                    out.frame.column(ci).get_str(ri),
                    reference.frame.column(ci).get_str(ri),
                    "row {ri} col {ci} is not the staged prefix"
                );
            }
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn multi_distinct_plan_matches_the_double_distinct_staged_reference() {
    for seed in [2, 123] {
        let mut spec = CorpusSpec::tiny(seed);
        spec.dup_rate = 0.2;
        spec.null_title_rate = 0.1;
        let (dir, files) = corpus(&format!("multidistinct{seed}"), &spec);

        // Staged: drop nulls, distinct on title, then distinct on
        // abstract, then the cleaning pipeline and the empty sweep.
        let frame = ingest_files(&files, &COLS, &IngestOptions::with_workers(3)).unwrap();
        let (frame, _) = drop_nulls(frame, &COLS).unwrap();
        let (frame, dups_title) = distinct(frame, &["title"]).unwrap();
        let (frame, dups_abstract) = distinct(frame, &["abstract"]).unwrap();
        let rows_after_dedup = frame.num_rows();
        let model = case_study_pipeline("title", "abstract").fit(&frame).unwrap();
        let reference = collect_and_sweep(model.transform(frame, 3).unwrap());
        let staged_empties = rows_after_dedup - reference.num_rows();

        let plan = LogicalPlan::scan(files.clone(), &COLS)
            .drop_nulls(&COLS)
            .distinct(&["title"])
            .distinct(&["abstract"])
            .transforms(p3sapp::pipeline::presets::case_study_stages("title", "abstract"))
            .drop_empty(&COLS)
            .collect();
        for optimized in [plan.clone(), plan.clone().optimize()] {
            let fused = optimized.execute(3).unwrap();
            assert_eq!(fused.frame, reference, "seed {seed}: fused vs staged");
            // A duplicate that itself cleans to empty is attributed to
            // the dup counter by the staged path (dedup runs first) but
            // to the empty counter by the fused pass (the worker-side
            // sweep removes it before the merge), so only the sum is
            // attribution-independent — same contract as the
            // single-distinct property test above.
            assert_eq!(
                fused.dups_dropped + fused.empties_dropped,
                dups_title + dups_abstract + staged_empties,
                "seed {seed}: dup+empty accounting"
            );
            let seq = optimized.execute(1).unwrap();
            assert_eq!(seq.frame, fused.frame, "seed {seed}: seq vs par");
            for stream in [
                StreamOptions { readers: 2, workers: 3, queue_cap: 1 },
                StreamOptions { readers: 2, workers: 64, queue_cap: 4 },
            ] {
                let streamed = optimized.execute_stream(&stream).unwrap();
                assert_eq!(streamed.frame, reference, "seed {seed} {stream:?}");
            }
            // Multi-`Distinct` provenance (per-slot KeySlots) crosses
            // the process boundary in the result frames; the driver's
            // merge must land on the staged bytes from there too.
            let processed = optimized.execute_process(&process_opts(2)).unwrap();
            assert_eq!(processed.frame, reference, "seed {seed}: process multi-distinct");
            // Same provenance contract across TCP: per-slot KeySlots
            // ride the streamed chunk frames and the driver's ordered
            // fold must land on the staged bytes.
            let remoted = optimized.execute_remote(&loopback_remote(2, 1)).unwrap();
            assert_eq!(remoted.frame, reference, "seed {seed}: remote multi-distinct");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn distinct_registers_first_occurrences_that_later_filters_remove() {
    // Shard a's row claims title "dup title" but its abstract sweeps to
    // empty; shard b's row shares the title with a different abstract.
    // The staged order (dedup globally, then clean, then sweep) drops
    // BOTH: b as a duplicate, a as empty. The fused merge must reproduce
    // that even though a's row is filtered inside its worker before the
    // driver ever sees it — its dedup key still has to register.
    let dir = std::env::temp_dir()
        .join(format!("p3sapp-planeq-dupreg-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("a.json"),
        "{\"title\": \"dup title\", \"abstract\": \"\"}\n",
    )
    .unwrap();
    std::fs::write(
        dir.join("b.json"),
        "{\"title\": \"dup title\", \"abstract\": \"perfectly good words\"}\n\
         {\"title\": \"other title\", \"abstract\": \"more good words\"}\n",
    )
    .unwrap();
    let files = list_shards(&dir).unwrap();

    // Staged reference.
    let frame = ingest_files(&files, &COLS, &IngestOptions::with_workers(2)).unwrap();
    let (frame, dups) = distinct(frame, &["title"]).unwrap();
    assert_eq!(dups, 1, "staged path drops b's first row as a title dup");
    let reference = collect_and_sweep(frame);
    assert_eq!(reference.num_rows(), 1, "only 'other title' survives");

    let plan = LogicalPlan::scan(files, &COLS)
        .distinct(&["title"])
        .transforms(abstract_stages("abstract"))
        .drop_empty(&["abstract"])
        .collect();
    for optimized in [plan.clone(), plan.clone().optimize()] {
        let fused = optimized.execute(2).unwrap();
        assert_eq!(fused.rows_out, 1, "a filtered first occurrence must still claim its key");
        assert_eq!(fused.frame.column(0).get_str(0), Some("other title"));
        let streamed = optimized
            .execute_stream(&StreamOptions { readers: 1, workers: 2, queue_cap: 1 })
            .unwrap();
        assert_eq!(streamed.frame, fused.frame);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn lowered_idf_matches_pipeline_fit_transform_across_all_executors() {
    for seed in [2, 77] {
        let mut spec = CorpusSpec::tiny(seed);
        spec.dup_rate = 0.15;
        spec.null_title_rate = 0.1;
        spec.null_abstract_rate = 0.1;
        let (dir, files) = corpus(&format!("idf{seed}"), &spec);

        // Staged reference: the full Table-2 pipeline (cleaning +
        // Tokenizer → HashingTF → IDF) through Pipeline::fit +
        // transform, then the empty sweep — exactly what the two-pass
        // lowering must reproduce byte for byte.
        let frame = ingest_files(&files, &COLS, &IngestOptions::with_workers(3)).unwrap();
        let (frame, _) = drop_nulls(frame, &COLS).unwrap();
        let (frame, _) = distinct(frame, &COLS).unwrap();
        let model = case_study_features_pipeline("title", "abstract").fit(&frame).unwrap();
        let reference = collect_and_sweep(model.transform(frame, 3).unwrap());
        assert!(reference.num_rows() > 0);

        let opts = DriverOptions { workers: 3, features: true, ..Default::default() };
        let plan = opts.build_plan(&files).optimize();

        // Fused two-pass, sequential and parallel.
        let fused = plan.execute(3).unwrap();
        assert_eq!(fused.frame, reference, "seed {seed}: fused two-pass vs Pipeline::fit");
        assert_eq!(plan.execute(1).unwrap().frame, reference, "seed {seed}: sequential");
        assert_eq!(
            fused.rows_out,
            fused.rows_ingested
                - fused.nulls_dropped
                - fused.dups_dropped
                - fused.empties_dropped,
            "seed {seed}: accounting"
        );

        // Streaming two-pass, including a fully serialized queue and
        // the fewer-shards-than-workers delegation.
        for stream in [
            StreamOptions { readers: 2, workers: 3, queue_cap: 1 },
            StreamOptions { readers: 2, workers: 64, queue_cap: 4 },
        ] {
            let streamed = plan.execute_stream(&stream).unwrap();
            assert_eq!(streamed.frame, reference, "seed {seed} {stream:?}: streaming");
        }

        // Multi-process two-pass: pass 1 ships admitted partitions (the
        // plan dedups before the estimator), pass 2 broadcasts the
        // fitted model inside the job — same bytes as Pipeline::fit.
        let processed = plan.execute_process(&process_opts(2)).unwrap();
        assert_eq!(processed.frame, reference, "seed {seed}: process two-pass");

        // Remote two-pass over loopback workers: pass 1 ships admitted
        // partitions back as chunk frames, pass 2 broadcasts the fitted
        // model inside the job — same bytes as Pipeline::fit.
        let remoted = plan.execute_remote(&loopback_remote(2, 1)).unwrap();
        assert_eq!(remoted.frame, reference, "seed {seed}: remote two-pass");

        // Cached: cold run stores (vectors and all), warm run restores
        // the identical frame.
        let cache = Arc::new(CacheManager::open(dir.join("plan-cache")).unwrap());
        let cached_opts = DriverOptions {
            workers: 3,
            features: true,
            cache: Some(Arc::clone(&cache)),
            ..Default::default()
        };
        let cold = run_p3sapp(&files, &cached_opts).unwrap();
        assert!(!cold.from_cache());
        assert_eq!(cold.frame, reference, "seed {seed}: cached cold");
        let warm = run_p3sapp(&files, &cached_opts).unwrap();
        assert!(warm.from_cache());
        assert_eq!(warm.frame, reference, "seed {seed}: cached warm restore");

        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn optimized_plan_fuses_at_least_four_abstract_stages() {
    let plan = case_study_plan(&[], "title", "abstract").optimize();
    let explained = p3sapp::plan::explain(&plan, 2).unwrap();
    // The abstract column's five cleaning stages must have collapsed
    // into a single fused sweep.
    assert!(
        explained.contains("FusedStringStage(abstract <- lower|html|chars|stopwords|short-words(<=1))"),
        "{explained}"
    );
    assert!(
        explained.contains("FusedStringStage(title <- lower|html|chars)"),
        "{explained}"
    );
}
