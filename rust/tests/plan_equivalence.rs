//! Property test: the fused plan's output must be byte-identical to the
//! stage-by-stage reference path (ingest → drop_nulls → distinct →
//! PipelineModel::transform → collect → empty sweep) on seeded corpora —
//! same schema, same rows in the same order, same drop accounting.
//!
//! The same contract extends to every plan-layer feature: positional
//! `Sample`, `Limit`, multiple `Distinct` ops, and the two-pass `IDF`
//! lowering, each checked staged-vs-fused-vs-streaming-vs-multi-process
//! (including `queue_cap = 1` and fewer-shards-than-workers) and — for
//! the estimator pipeline — against a cache round trip. The process
//! arms spawn real worker processes (the built `repro` binary's hidden
//! `plan-worker` mode); the remote arms drive in-process loopback TCP
//! workers ([`p3sapp::plan::remote::serve_listener`]) over the same
//! `P3PJ`/`P3PW` frames, covering both inline and fetch-by-digest
//! shard shipping.

use p3sapp::cache::{fingerprint, CacheManager};
use p3sapp::corpus::{generate_corpus, CorpusSpec};
use p3sapp::driver::{run_p3sapp, DriverOptions, CACHE_RESTORE};
use p3sapp::frame::{distinct, drop_nulls, Frame, LocalFrame};
use p3sapp::ingest::list_shards;
use p3sapp::ingest::spark::{ingest_files, IngestOptions};
use p3sapp::pipeline::features::{HashingTF, Idf};
use p3sapp::pipeline::presets::{
    abstract_stages, case_study_features_pipeline, case_study_pipeline, case_study_plan,
    case_study_plan_with, case_study_stages, CaseStudyOptions,
};
use p3sapp::pipeline::stages::Tokenizer;
use p3sapp::plan::{
    execute_incremental, sample_keeps, ExecutorKind, LogicalPlan, ProcessOptions, RemoteOptions,
    StreamOptions,
};
use std::path::PathBuf;
use std::sync::Arc;

const COLS: [&str; 2] = ["title", "abstract"];

/// Multi-process executor options for these tests: the harness
/// executable has no `plan-worker` mode, so the workers are the built
/// `repro` binary.
fn process_opts(processes: usize) -> ProcessOptions {
    ProcessOptions {
        processes,
        worker_cmd: Some(PathBuf::from(env!("CARGO_BIN_EXE_repro"))),
        ..Default::default()
    }
}

/// Remote executor options backed by `n` fresh in-process loopback
/// workers: each endpoint is a real `TcpListener` on `127.0.0.1:0`
/// served by [`p3sapp::plan::remote::serve_listener`] on its own
/// thread (the threads outlive the test harmlessly — an idle accept
/// loop). `inline_max_bytes` is passed through so tests can force the
/// fetch-by-digest shard path.
fn loopback_remote(n: usize, inline_max_bytes: u64) -> RemoteOptions {
    let endpoints = (0..n)
        .map(|_| {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let ep = listener.local_addr().unwrap().to_string();
            std::thread::spawn(move || p3sapp::plan::remote::serve_listener(listener));
            ep
        })
        .collect();
    RemoteOptions { endpoints, inline_max_bytes, ..Default::default() }
}

fn corpus(name: &str, spec: &CorpusSpec) -> (PathBuf, Vec<PathBuf>) {
    let dir = std::env::temp_dir().join(format!("p3sapp-planeq-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    generate_corpus(spec, &dir).unwrap();
    let files = list_shards(&dir).unwrap();
    (dir, files)
}

/// Reference drop accounting alongside the reference frame.
struct Reference {
    frame: LocalFrame,
    nulls_dropped: usize,
    dups_dropped: usize,
    empties_dropped: usize,
}

/// The pre-plan driver path, stage by stage with full barriers.
fn staged_reference(files: &[PathBuf], workers: usize) -> Reference {
    let frame = ingest_files(files, &COLS, &IngestOptions::with_workers(workers)).unwrap();
    let (frame, nulls_dropped) = drop_nulls(frame, &COLS).unwrap();
    let (frame, dups_dropped) = distinct(frame, &COLS).unwrap();
    let model = case_study_pipeline("title", "abstract").fit(&frame).unwrap();
    let frame = model.transform(frame, workers).unwrap();
    let mut local = frame.collect();
    for ci in 0..local.num_columns() {
        local.column_mut(ci).nullify_empty_strs();
    }
    let empties_dropped = local.drop_nulls(&COLS).unwrap();
    Reference { frame: local, nulls_dropped, dups_dropped, empties_dropped }
}

#[test]
fn fused_plan_is_byte_identical_to_staged_reference() {
    for seed in [2, 41, 77, 123] {
        let mut spec = CorpusSpec::tiny(seed);
        // Stress every physical op: plenty of dups, nulls and noise.
        spec.dup_rate = 0.15;
        spec.null_title_rate = 0.1;
        spec.null_abstract_rate = 0.1;
        let (dir, files) = corpus(&format!("seed{seed}"), &spec);

        let reference = staged_reference(&files, 3);
        let out = case_study_plan(&files, "title", "abstract")
            .optimize()
            .execute(3)
            .unwrap();

        assert_eq!(out.frame, reference.frame, "seed {seed}: frames diverge");
        // The multi-process executor runs the same program in worker OS
        // processes and must land on the same bytes and accounting.
        let processed = case_study_plan(&files, "title", "abstract")
            .optimize()
            .execute_process(&process_opts(2))
            .unwrap();
        assert_eq!(processed.frame, reference.frame, "seed {seed}: process frames diverge");
        assert_eq!(processed.nulls_dropped, out.nulls_dropped, "seed {seed}: process nulls");
        assert_eq!(processed.dups_dropped, out.dups_dropped, "seed {seed}: process dups");
        assert_eq!(
            processed.empties_dropped, out.empties_dropped,
            "seed {seed}: process empties"
        );
        // The remote executor ships the same program over loopback TCP
        // (inline_max_bytes = 1 forces every shard through the
        // fetch-by-digest round trip) and streams chunk frames back —
        // same bytes, same accounting.
        let remoted = case_study_plan(&files, "title", "abstract")
            .optimize()
            .execute_remote(&loopback_remote(2, 1))
            .unwrap();
        assert_eq!(remoted.frame, reference.frame, "seed {seed}: remote frames diverge");
        assert_eq!(remoted.nulls_dropped, out.nulls_dropped, "seed {seed}: remote nulls");
        assert_eq!(remoted.dups_dropped, out.dups_dropped, "seed {seed}: remote dups");
        assert_eq!(
            remoted.empties_dropped, out.empties_dropped,
            "seed {seed}: remote empties"
        );
        assert_eq!(out.nulls_dropped, reference.nulls_dropped, "seed {seed}: null drops");
        // A duplicated row that cleans to empty is attributed to the
        // dedup counter by the staged path (dedup runs before cleaning)
        // but to the empty counter by the fused pass (the per-partition
        // empty sweep runs before the driver's dedup merge), so only
        // the sum is attribution-independent.
        assert_eq!(
            out.dups_dropped + out.empties_dropped,
            reference.dups_dropped + reference.empties_dropped,
            "seed {seed}: dup+empty drops"
        );
        assert_eq!(out.rows_out, reference.frame.num_rows(), "seed {seed}: row count");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn streaming_plan_is_byte_identical_to_staged_and_fused_paths() {
    // The streaming executor re-schedules the same per-shard program
    // (parse overlaps clean), so its output must match both the staged
    // reference and the fused single pass bit for bit — including with
    // a fully serialized queue (queue_cap = 1) and across seeds.
    for seed in [2, 77, 123] {
        let mut spec = CorpusSpec::tiny(seed);
        spec.dup_rate = 0.15;
        spec.null_title_rate = 0.1;
        spec.null_abstract_rate = 0.1;
        let (dir, files) = corpus(&format!("stream{seed}"), &spec);

        let reference = staged_reference(&files, 3);
        let plan = case_study_plan(&files, "title", "abstract").optimize();
        let fused = plan.execute(3).unwrap();

        for opts in [
            StreamOptions::default(),
            StreamOptions { readers: 2, workers: 3, queue_cap: 1 },
            StreamOptions { readers: 1, workers: 1, queue_cap: 2 },
        ] {
            let out = plan.execute_stream(&opts).unwrap();
            assert_eq!(out.frame, reference.frame, "seed {seed} {opts:?}: vs staged");
            assert_eq!(out.frame, fused.frame, "seed {seed} {opts:?}: vs fused");
            assert_eq!(out.rows_out, fused.rows_out, "seed {seed} {opts:?}: rows");
            assert_eq!(
                out.rows_ingested, fused.rows_ingested,
                "seed {seed} {opts:?}: ingested"
            );
            assert_eq!(
                out.nulls_dropped, fused.nulls_dropped,
                "seed {seed} {opts:?}: null drops"
            );
            assert_eq!(
                out.dups_dropped, fused.dups_dropped,
                "seed {seed} {opts:?}: dup drops"
            );
            assert_eq!(
                out.empties_dropped, fused.empties_dropped,
                "seed {seed} {opts:?}: empty drops"
            );
        }

        // The unoptimized plan streams to the same bytes too.
        let unfused_streamed = case_study_plan(&files, "title", "abstract")
            .execute_stream(&StreamOptions { readers: 2, workers: 2, queue_cap: 1 })
            .unwrap();
        assert_eq!(unfused_streamed.frame, reference.frame, "seed {seed}: unfused stream");

        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn fused_plan_equivalence_survives_worker_skew() {
    let (dir, files) = corpus("skew", &CorpusSpec::tiny(55));
    let reference = staged_reference(&files, 1);
    for workers in [1, 2, 8] {
        let out = case_study_plan(&files, "title", "abstract")
            .optimize()
            .execute(workers)
            .unwrap();
        assert_eq!(out.frame, reference.frame, "workers {workers}");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Finish a staged run: collect, empty-sweep the string columns, drop
/// the swept rows (the DropEmpty analog shared by every reference here).
fn collect_and_sweep(frame: Frame) -> LocalFrame {
    let mut local = frame.collect();
    for ci in 0..local.num_columns() {
        local.column_mut(ci).nullify_empty_strs();
    }
    local.drop_nulls(&COLS).unwrap();
    local
}

#[test]
fn sampled_plan_matches_the_positionally_sampled_staged_reference() {
    let (fraction, seed) = (0.5, 42u64);
    for corpus_seed in [2, 77] {
        let mut spec = CorpusSpec::tiny(corpus_seed);
        spec.dup_rate = 0.15;
        spec.null_title_rate = 0.1;
        let (dir, files) = corpus(&format!("sample{corpus_seed}"), &spec);

        // Staged reference: ingest (one partition per shard, in shard
        // order), apply the same positional mask the plan's Sample op
        // uses, then the usual staged path.
        let mut frame =
            ingest_files(&files, &COLS, &IngestOptions::with_workers(3)).unwrap();
        assert_eq!(frame.num_partitions(), files.len(), "one partition per shard");
        let mut sampled_out = 0usize;
        for (shard, part) in frame.partitions_mut().iter_mut().enumerate() {
            let mask: Vec<bool> = (0..part.num_rows())
                .map(|i| sample_keeps(seed, shard, i, fraction))
                .collect();
            sampled_out += mask.iter().filter(|&&k| !k).count();
            *part = part.filter_by_mask(&mask);
        }
        let (frame, nulls_dropped) = drop_nulls(frame, &COLS).unwrap();
        let (frame, _) = distinct(frame, &COLS).unwrap();
        let model = case_study_pipeline("title", "abstract").fit(&frame).unwrap();
        let reference = collect_and_sweep(model.transform(frame, 3).unwrap());
        assert!(sampled_out > 0, "a 50% sample must skip rows");

        let opts = CaseStudyOptions { sample: Some((fraction, seed)), ..Default::default() };
        let plan = case_study_plan_with(&files, "title", "abstract", &opts).optimize();
        let fused = plan.execute(3).unwrap();
        assert_eq!(fused.frame, reference, "seed {corpus_seed}: fused vs staged");
        assert_eq!(fused.sampled_out, sampled_out, "seed {corpus_seed}: sample count");
        assert_eq!(fused.nulls_dropped, nulls_dropped, "seed {corpus_seed}: null drops");
        for stream in [
            StreamOptions { readers: 2, workers: 3, queue_cap: 1 },
            // More workers than shards: the scarce-shard delegation
            // must keep positional sampling intact too.
            StreamOptions { readers: 2, workers: 64, queue_cap: 4 },
        ] {
            let streamed = plan.execute_stream(&stream).unwrap();
            assert_eq!(streamed.frame, reference, "seed {corpus_seed} {stream:?}");
            assert_eq!(streamed.sampled_out, sampled_out, "seed {corpus_seed} {stream:?}");
        }
        // Worker processes receive shard indices with their paths, so
        // positional sampling survives the process boundary too.
        let processed = plan.execute_process(&process_opts(2)).unwrap();
        assert_eq!(processed.frame, reference, "seed {corpus_seed}: process");
        assert_eq!(processed.sampled_out, sampled_out, "seed {corpus_seed}: process sample");
        // Remote workers also receive shard indices with their shards,
        // so positional sampling survives the TCP boundary.
        let remoted = plan.execute_remote(&loopback_remote(2, 4 * 1024 * 1024)).unwrap();
        assert_eq!(remoted.frame, reference, "seed {corpus_seed}: remote");
        assert_eq!(remoted.sampled_out, sampled_out, "seed {corpus_seed}: remote sample");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn limited_plan_is_the_staged_reference_prefix_everywhere() {
    let mut spec = CorpusSpec::tiny(41);
    spec.dup_rate = 0.15;
    let (dir, files) = corpus("limit", &spec);
    let reference = staged_reference(&files, 3);
    let n = reference.frame.num_rows() / 2;
    assert!(n > 0, "corpus too small to exercise Limit");

    let opts = CaseStudyOptions { limit: Some(n), ..Default::default() };
    let plan = case_study_plan_with(&files, "title", "abstract", &opts).optimize();
    let mut outputs = vec![plan.execute(1).unwrap(), plan.execute(3).unwrap()];
    for stream in [
        StreamOptions { readers: 2, workers: 3, queue_cap: 1 },
        StreamOptions { readers: 2, workers: 64, queue_cap: 4 },
    ] {
        outputs.push(plan.execute_stream(&stream).unwrap());
    }
    // The global Limit budget is enforced at the driver merge, so the
    // process and remote executors cut the exact same prefix — for
    // remote, the shard-ordered fold of streamed chunk frames is what
    // keeps the budget deterministic.
    outputs.push(plan.execute_process(&process_opts(2)).unwrap());
    outputs.push(plan.execute_remote(&loopback_remote(2, 1)).unwrap());
    for out in &outputs {
        assert_eq!(out.rows_out, n);
        assert_eq!(out.limited_out, reference.frame.num_rows() - n);
        assert_eq!(out.frame, outputs[0].frame, "executors disagree under Limit");
        for ci in 0..out.frame.num_columns() {
            for ri in 0..n {
                assert_eq!(
                    out.frame.column(ci).get_str(ri),
                    reference.frame.column(ci).get_str(ri),
                    "row {ri} col {ci} is not the staged prefix"
                );
            }
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn multi_distinct_plan_matches_the_double_distinct_staged_reference() {
    for seed in [2, 123] {
        let mut spec = CorpusSpec::tiny(seed);
        spec.dup_rate = 0.2;
        spec.null_title_rate = 0.1;
        let (dir, files) = corpus(&format!("multidistinct{seed}"), &spec);

        // Staged: drop nulls, distinct on title, then distinct on
        // abstract, then the cleaning pipeline and the empty sweep.
        let frame = ingest_files(&files, &COLS, &IngestOptions::with_workers(3)).unwrap();
        let (frame, _) = drop_nulls(frame, &COLS).unwrap();
        let (frame, dups_title) = distinct(frame, &["title"]).unwrap();
        let (frame, dups_abstract) = distinct(frame, &["abstract"]).unwrap();
        let rows_after_dedup = frame.num_rows();
        let model = case_study_pipeline("title", "abstract").fit(&frame).unwrap();
        let reference = collect_and_sweep(model.transform(frame, 3).unwrap());
        let staged_empties = rows_after_dedup - reference.num_rows();

        let plan = LogicalPlan::scan(files.clone(), &COLS)
            .drop_nulls(&COLS)
            .distinct(&["title"])
            .distinct(&["abstract"])
            .transforms(p3sapp::pipeline::presets::case_study_stages("title", "abstract"))
            .drop_empty(&COLS)
            .collect();
        for optimized in [plan.clone(), plan.clone().optimize()] {
            let fused = optimized.execute(3).unwrap();
            assert_eq!(fused.frame, reference, "seed {seed}: fused vs staged");
            // A duplicate that itself cleans to empty is attributed to
            // the dup counter by the staged path (dedup runs first) but
            // to the empty counter by the fused pass (the worker-side
            // sweep removes it before the merge), so only the sum is
            // attribution-independent — same contract as the
            // single-distinct property test above.
            assert_eq!(
                fused.dups_dropped + fused.empties_dropped,
                dups_title + dups_abstract + staged_empties,
                "seed {seed}: dup+empty accounting"
            );
            let seq = optimized.execute(1).unwrap();
            assert_eq!(seq.frame, fused.frame, "seed {seed}: seq vs par");
            for stream in [
                StreamOptions { readers: 2, workers: 3, queue_cap: 1 },
                StreamOptions { readers: 2, workers: 64, queue_cap: 4 },
            ] {
                let streamed = optimized.execute_stream(&stream).unwrap();
                assert_eq!(streamed.frame, reference, "seed {seed} {stream:?}");
            }
            // Multi-`Distinct` provenance (per-slot KeySlots) crosses
            // the process boundary in the result frames; the driver's
            // merge must land on the staged bytes from there too.
            let processed = optimized.execute_process(&process_opts(2)).unwrap();
            assert_eq!(processed.frame, reference, "seed {seed}: process multi-distinct");
            // Same provenance contract across TCP: per-slot KeySlots
            // ride the streamed chunk frames and the driver's ordered
            // fold must land on the staged bytes.
            let remoted = optimized.execute_remote(&loopback_remote(2, 1)).unwrap();
            assert_eq!(remoted.frame, reference, "seed {seed}: remote multi-distinct");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn distinct_registers_first_occurrences_that_later_filters_remove() {
    // Shard a's row claims title "dup title" but its abstract sweeps to
    // empty; shard b's row shares the title with a different abstract.
    // The staged order (dedup globally, then clean, then sweep) drops
    // BOTH: b as a duplicate, a as empty. The fused merge must reproduce
    // that even though a's row is filtered inside its worker before the
    // driver ever sees it — its dedup key still has to register.
    let dir = std::env::temp_dir()
        .join(format!("p3sapp-planeq-dupreg-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("a.json"),
        "{\"title\": \"dup title\", \"abstract\": \"\"}\n",
    )
    .unwrap();
    std::fs::write(
        dir.join("b.json"),
        "{\"title\": \"dup title\", \"abstract\": \"perfectly good words\"}\n\
         {\"title\": \"other title\", \"abstract\": \"more good words\"}\n",
    )
    .unwrap();
    let files = list_shards(&dir).unwrap();

    // Staged reference.
    let frame = ingest_files(&files, &COLS, &IngestOptions::with_workers(2)).unwrap();
    let (frame, dups) = distinct(frame, &["title"]).unwrap();
    assert_eq!(dups, 1, "staged path drops b's first row as a title dup");
    let reference = collect_and_sweep(frame);
    assert_eq!(reference.num_rows(), 1, "only 'other title' survives");

    let plan = LogicalPlan::scan(files, &COLS)
        .distinct(&["title"])
        .transforms(abstract_stages("abstract"))
        .drop_empty(&["abstract"])
        .collect();
    for optimized in [plan.clone(), plan.clone().optimize()] {
        let fused = optimized.execute(2).unwrap();
        assert_eq!(fused.rows_out, 1, "a filtered first occurrence must still claim its key");
        assert_eq!(fused.frame.column(0).get_str(0), Some("other title"));
        let streamed = optimized
            .execute_stream(&StreamOptions { readers: 1, workers: 2, queue_cap: 1 })
            .unwrap();
        assert_eq!(streamed.frame, fused.frame);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn lowered_idf_matches_pipeline_fit_transform_across_all_executors() {
    for seed in [2, 77] {
        let mut spec = CorpusSpec::tiny(seed);
        spec.dup_rate = 0.15;
        spec.null_title_rate = 0.1;
        spec.null_abstract_rate = 0.1;
        let (dir, files) = corpus(&format!("idf{seed}"), &spec);

        // Staged reference: the full Table-2 pipeline (cleaning +
        // Tokenizer → HashingTF → IDF) through Pipeline::fit +
        // transform, then the empty sweep — exactly what the two-pass
        // lowering must reproduce byte for byte.
        let frame = ingest_files(&files, &COLS, &IngestOptions::with_workers(3)).unwrap();
        let (frame, _) = drop_nulls(frame, &COLS).unwrap();
        let (frame, _) = distinct(frame, &COLS).unwrap();
        let model = case_study_features_pipeline("title", "abstract").fit(&frame).unwrap();
        let reference = collect_and_sweep(model.transform(frame, 3).unwrap());
        assert!(reference.num_rows() > 0);

        let opts = DriverOptions { workers: 3, features: true, ..Default::default() };
        let plan = opts.build_plan(&files).optimize();

        // Fused two-pass, sequential and parallel.
        let fused = plan.execute(3).unwrap();
        assert_eq!(fused.frame, reference, "seed {seed}: fused two-pass vs Pipeline::fit");
        assert_eq!(plan.execute(1).unwrap().frame, reference, "seed {seed}: sequential");
        assert_eq!(
            fused.rows_out,
            fused.rows_ingested
                - fused.nulls_dropped
                - fused.dups_dropped
                - fused.empties_dropped,
            "seed {seed}: accounting"
        );

        // Streaming two-pass, including a fully serialized queue and
        // the fewer-shards-than-workers delegation.
        for stream in [
            StreamOptions { readers: 2, workers: 3, queue_cap: 1 },
            StreamOptions { readers: 2, workers: 64, queue_cap: 4 },
        ] {
            let streamed = plan.execute_stream(&stream).unwrap();
            assert_eq!(streamed.frame, reference, "seed {seed} {stream:?}: streaming");
        }

        // Multi-process two-pass: pass 1 ships admitted partitions (the
        // plan dedups before the estimator), pass 2 broadcasts the
        // fitted model inside the job — same bytes as Pipeline::fit.
        let processed = plan.execute_process(&process_opts(2)).unwrap();
        assert_eq!(processed.frame, reference, "seed {seed}: process two-pass");

        // Remote two-pass over loopback workers: pass 1 ships admitted
        // partitions back as chunk frames, pass 2 broadcasts the fitted
        // model inside the job — same bytes as Pipeline::fit.
        let remoted = plan.execute_remote(&loopback_remote(2, 1)).unwrap();
        assert_eq!(remoted.frame, reference, "seed {seed}: remote two-pass");

        // Cached: cold run stores (vectors and all), warm run restores
        // the identical frame.
        let cache = Arc::new(CacheManager::open(dir.join("plan-cache")).unwrap());
        let cached_opts = DriverOptions {
            workers: 3,
            features: true,
            cache: Some(Arc::clone(&cache)),
            ..Default::default()
        };
        let cold = run_p3sapp(&files, &cached_opts).unwrap();
        assert!(!cold.from_cache());
        assert_eq!(cold.frame, reference, "seed {seed}: cached cold");
        let warm = run_p3sapp(&files, &cached_opts).unwrap();
        assert!(warm.from_cache());
        assert_eq!(warm.frame, reference, "seed {seed}: cached warm restore");

        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn warm_append_is_byte_identical_to_cold_across_executors() {
    // The incremental tier's core contract: after a corpus grows by one
    // shard, a warm driver run restores the untouched shards from the
    // per-shard cache, executes only the appended one, and still lands
    // on the exact bytes of a cold full run — for every executor whose
    // schedule keeps the shard file as the unit of work.
    let mut spec = CorpusSpec::tiny(67);
    spec.dup_rate = 0.15;
    spec.null_title_rate = 0.1;
    let (dir, files) = corpus("warmappend", &spec);
    let initial = files[..files.len() - 1].to_vec();
    let cold_full =
        run_p3sapp(&files, &DriverOptions { workers: 3, ..Default::default() }).unwrap();

    for (name, executor) in [
        ("fused", ExecutorKind::Fused),
        ("stream", ExecutorKind::Stream(StreamOptions { readers: 2, workers: 3, queue_cap: 2 })),
        ("process", ExecutorKind::Process(process_opts(2))),
    ] {
        let cache = Arc::new(CacheManager::open(dir.join(format!("cache-{name}"))).unwrap());
        let opts = DriverOptions {
            workers: 3,
            executor: executor.clone(),
            cache: Some(Arc::clone(&cache)),
            ..Default::default()
        };
        let cold = run_p3sapp(&initial, &opts).unwrap();
        assert!(!cold.from_cache(), "{name}: cold run executes");
        assert_eq!(cache.stats().shard_misses, initial.len() as u64, "{name}: cold misses");

        let warm = run_p3sapp(&files, &opts).unwrap();
        assert!(!warm.from_cache(), "{name}: an incremental run did real work");
        let s = cache.stats();
        assert_eq!(s.shard_hits, initial.len() as u64, "{name}: every old shard restored");
        assert_eq!(s.shard_misses, initial.len() as u64 + 1, "{name}: one shard executed");
        let restore = format!("{CACHE_RESTORE}({} of {} shards)", initial.len(), files.len());
        assert!(
            warm.times.stages().any(|(st, _)| st == restore),
            "{name}: missing '{restore}' in {:?}",
            warm.times.stages().map(|(st, _)| st.to_string()).collect::<Vec<_>>()
        );
        assert_eq!(warm.frame, cold_full.frame, "{name}: warm append diverges from cold");
        assert_eq!(warm.rows_out, cold_full.rows_out, "{name}: rows_out");
        assert_eq!(warm.rows_ingested, cold_full.rows_ingested, "{name}: rows_ingested");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn warm_append_drops_duplicates_spanning_the_restore_boundary() {
    // Dedup provenance must cross serialization: a duplicate whose first
    // occurrence lives in a *restored* shard has to be dropped from the
    // *fresh* one (append case), and — after the growth re-indexes the
    // shards — a first occurrence in a fresh shard that sorts ahead has
    // to evict the copy inside a restored shard (prepend case).
    let dir = std::env::temp_dir().join(format!("p3sapp-planeq-incrdup-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let dup = "{\"title\": \"dup title\", \"abstract\": \"shared words here\"}\n";
    std::fs::write(dir.join("a.json"), format!("{dup}{}",
        "{\"title\": \"first\", \"abstract\": \"alpha words\"}\n")).unwrap();
    std::fs::write(
        dir.join("b.json"),
        "{\"title\": \"second\", \"abstract\": \"beta words\"}\n",
    )
    .unwrap();
    let initial = list_shards(&dir).unwrap();
    assert_eq!(initial.len(), 2);

    let cache = CacheManager::open(dir.join("cache")).unwrap();
    let run = |files: &[PathBuf], cache: &CacheManager| {
        let plan = case_study_plan(files, "title", "abstract").optimize();
        let fp = fingerprint(&plan.render(), files).unwrap();
        let warm = execute_incremental(&plan, 2, &ExecutorKind::Fused, cache, &fp)
            .unwrap()
            .expect("eligible plan");
        let cold = plan.execute(2).unwrap();
        assert_eq!(warm.frame, cold.frame, "incremental diverges from cold");
        assert_eq!(warm.dups_dropped, cold.dups_dropped);
        warm
    };
    run(&initial, &cache);

    // Append: the duplicate's first occurrence sits in restored a.json.
    std::fs::write(dir.join("c.json"), format!("{dup}{}",
        "{\"title\": \"third\", \"abstract\": \"gamma words\"}\n")).unwrap();
    let grown = list_shards(&dir).unwrap();
    assert_eq!(grown.len(), 3);
    let warm = run(&grown, &cache);
    assert_eq!(warm.dups_dropped, 1, "the cross-boundary duplicate must drop");
    assert_eq!(cache.stats().shard_hits, 2, "a.json and b.json restored");

    // Prepend: a fresh shard that sorts first registers the key, so the
    // copy inside restored a.json (now at a shifted shard index) drops.
    std::fs::write(dir.join("0early.json"), dup).unwrap();
    let grown2 = list_shards(&dir).unwrap();
    assert_eq!(grown2.len(), 4);
    assert!(grown2[0].ends_with("0early.json"), "{grown2:?}");
    let hits_before = cache.stats().shard_hits;
    let warm2 = run(&grown2, &cache);
    assert_eq!(warm2.dups_dropped, 2, "both copies after the fresh first occurrence drop");
    assert_eq!(
        cache.stats().shard_hits,
        hits_before + 3,
        "content-addressed keys survive the index shift"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn warm_append_two_pass_idf_reuses_persisted_fit_partials() {
    let mut spec = CorpusSpec::tiny(91);
    spec.null_abstract_rate = 0.1;
    let (dir, files) = corpus("incridf", &spec);
    let initial = files[..files.len() - 1].to_vec();

    // A dedup-free estimator plan: per-shard document-frequency partials
    // persist next to the prefix artifacts, so the warm re-fit merges
    // partials (restored + fresh) instead of re-admitting every row.
    let plan_for = |files: &[PathBuf]| {
        LogicalPlan::scan(files.to_vec(), &COLS)
            .drop_nulls(&COLS)
            .transforms(case_study_stages("title", "abstract"))
            .transform(Tokenizer::new("abstract", "tokens"))
            .transform(HashingTF::new("tokens", "tf", 512))
            .fit(Idf::new("tf", "tfidf"))
            .drop_empty(&COLS)
            .collect()
            .optimize()
    };
    let cache = CacheManager::open(dir.join("cache")).unwrap();
    let plan1 = plan_for(&initial);
    let fp1 = fingerprint(&plan1.render(), &initial).unwrap();
    execute_incremental(&plan1, 3, &ExecutorKind::Fused, &cache, &fp1)
        .unwrap()
        .expect("eligible plan");

    let plan2 = plan_for(&files);
    let fp2 = fingerprint(&plan2.render(), &files).unwrap();
    let warm = execute_incremental(&plan2, 3, &ExecutorKind::Fused, &cache, &fp2)
        .unwrap()
        .expect("eligible plan");
    let s = cache.stats();
    assert_eq!(s.shard_hits, initial.len() as u64);
    assert_eq!(s.shard_misses, initial.len() as u64 + 1);
    // The fitted model saw every shard: TF-IDF weights (which depend on
    // global document frequencies) must match a cold full run exactly.
    let cold = plan2.execute(3).unwrap();
    assert_eq!(warm.frame, cold.frame, "merged-partial fit diverges from cold fit");
    assert_eq!(warm.rows_out, cold.rows_out);

    // The dedup-bearing features preset takes the fit-sink fold instead
    // (per-shard partials cannot see global dedup) — same byte contract,
    // via the driver path the CLI exercises.
    let cache2 = Arc::new(CacheManager::open(dir.join("cache-features")).unwrap());
    let opts = DriverOptions {
        workers: 3,
        features: true,
        cache: Some(Arc::clone(&cache2)),
        ..Default::default()
    };
    let plain = run_p3sapp(
        &files,
        &DriverOptions { workers: 3, features: true, ..Default::default() },
    )
    .unwrap();
    run_p3sapp(&initial, &opts).unwrap();
    let warm2 = run_p3sapp(&files, &opts).unwrap();
    assert_eq!(cache2.stats().shard_hits, initial.len() as u64);
    assert_eq!(warm2.frame, plain.frame, "features warm append diverges from cold");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn optimized_plan_fuses_at_least_four_abstract_stages() {
    let plan = case_study_plan(&[], "title", "abstract").optimize();
    let explained = p3sapp::plan::explain(&plan, 2).unwrap();
    // The abstract column's five cleaning stages must have collapsed
    // into a single fused sweep.
    assert!(
        explained.contains("FusedStringStage(abstract <- lower|html|chars|stopwords|short-words(<=1))"),
        "{explained}"
    );
    assert!(
        explained.contains("FusedStringStage(title <- lower|html|chars)"),
        "{explained}"
    );
}
