//! Property test: the fused plan's output must be byte-identical to the
//! stage-by-stage reference path (ingest → drop_nulls → distinct →
//! PipelineModel::transform → collect → empty sweep) on seeded corpora —
//! same schema, same rows in the same order, same drop accounting.

use p3sapp::corpus::{generate_corpus, CorpusSpec};
use p3sapp::frame::{distinct, drop_nulls, LocalFrame};
use p3sapp::ingest::list_shards;
use p3sapp::ingest::spark::{ingest_files, IngestOptions};
use p3sapp::pipeline::presets::{case_study_pipeline, case_study_plan};
use p3sapp::plan::StreamOptions;
use std::path::PathBuf;

const COLS: [&str; 2] = ["title", "abstract"];

fn corpus(name: &str, spec: &CorpusSpec) -> (PathBuf, Vec<PathBuf>) {
    let dir = std::env::temp_dir().join(format!("p3sapp-planeq-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    generate_corpus(spec, &dir).unwrap();
    let files = list_shards(&dir).unwrap();
    (dir, files)
}

/// Reference drop accounting alongside the reference frame.
struct Reference {
    frame: LocalFrame,
    nulls_dropped: usize,
    dups_dropped: usize,
    empties_dropped: usize,
}

/// The pre-plan driver path, stage by stage with full barriers.
fn staged_reference(files: &[PathBuf], workers: usize) -> Reference {
    let frame = ingest_files(files, &COLS, &IngestOptions::with_workers(workers)).unwrap();
    let (frame, nulls_dropped) = drop_nulls(frame, &COLS).unwrap();
    let (frame, dups_dropped) = distinct(frame, &COLS).unwrap();
    let model = case_study_pipeline("title", "abstract").fit(&frame).unwrap();
    let frame = model.transform(frame, workers).unwrap();
    let mut local = frame.collect();
    for ci in 0..local.num_columns() {
        local.column_mut(ci).nullify_empty_strs();
    }
    let empties_dropped = local.drop_nulls(&COLS).unwrap();
    Reference { frame: local, nulls_dropped, dups_dropped, empties_dropped }
}

#[test]
fn fused_plan_is_byte_identical_to_staged_reference() {
    for seed in [2, 41, 77, 123] {
        let mut spec = CorpusSpec::tiny(seed);
        // Stress every physical op: plenty of dups, nulls and noise.
        spec.dup_rate = 0.15;
        spec.null_title_rate = 0.1;
        spec.null_abstract_rate = 0.1;
        let (dir, files) = corpus(&format!("seed{seed}"), &spec);

        let reference = staged_reference(&files, 3);
        let out = case_study_plan(&files, "title", "abstract")
            .optimize()
            .execute(3)
            .unwrap();

        assert_eq!(out.frame, reference.frame, "seed {seed}: frames diverge");
        assert_eq!(out.nulls_dropped, reference.nulls_dropped, "seed {seed}: null drops");
        // A duplicated row that cleans to empty is attributed to the
        // dedup counter by the staged path (dedup runs before cleaning)
        // but to the empty counter by the fused pass (the per-partition
        // empty sweep runs before the driver's dedup merge), so only
        // the sum is attribution-independent.
        assert_eq!(
            out.dups_dropped + out.empties_dropped,
            reference.dups_dropped + reference.empties_dropped,
            "seed {seed}: dup+empty drops"
        );
        assert_eq!(out.rows_out, reference.frame.num_rows(), "seed {seed}: row count");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn streaming_plan_is_byte_identical_to_staged_and_fused_paths() {
    // The streaming executor re-schedules the same per-shard program
    // (parse overlaps clean), so its output must match both the staged
    // reference and the fused single pass bit for bit — including with
    // a fully serialized queue (queue_cap = 1) and across seeds.
    for seed in [2, 77, 123] {
        let mut spec = CorpusSpec::tiny(seed);
        spec.dup_rate = 0.15;
        spec.null_title_rate = 0.1;
        spec.null_abstract_rate = 0.1;
        let (dir, files) = corpus(&format!("stream{seed}"), &spec);

        let reference = staged_reference(&files, 3);
        let plan = case_study_plan(&files, "title", "abstract").optimize();
        let fused = plan.execute(3).unwrap();

        for opts in [
            StreamOptions::default(),
            StreamOptions { readers: 2, workers: 3, queue_cap: 1 },
            StreamOptions { readers: 1, workers: 1, queue_cap: 2 },
        ] {
            let out = plan.execute_stream(&opts).unwrap();
            assert_eq!(out.frame, reference.frame, "seed {seed} {opts:?}: vs staged");
            assert_eq!(out.frame, fused.frame, "seed {seed} {opts:?}: vs fused");
            assert_eq!(out.rows_out, fused.rows_out, "seed {seed} {opts:?}: rows");
            assert_eq!(
                out.rows_ingested, fused.rows_ingested,
                "seed {seed} {opts:?}: ingested"
            );
            assert_eq!(
                out.nulls_dropped, fused.nulls_dropped,
                "seed {seed} {opts:?}: null drops"
            );
            assert_eq!(
                out.dups_dropped, fused.dups_dropped,
                "seed {seed} {opts:?}: dup drops"
            );
            assert_eq!(
                out.empties_dropped, fused.empties_dropped,
                "seed {seed} {opts:?}: empty drops"
            );
        }

        // The unoptimized plan streams to the same bytes too.
        let unfused_streamed = case_study_plan(&files, "title", "abstract")
            .execute_stream(&StreamOptions { readers: 2, workers: 2, queue_cap: 1 })
            .unwrap();
        assert_eq!(unfused_streamed.frame, reference.frame, "seed {seed}: unfused stream");

        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn fused_plan_equivalence_survives_worker_skew() {
    let (dir, files) = corpus("skew", &CorpusSpec::tiny(55));
    let reference = staged_reference(&files, 1);
    for workers in [1, 2, 8] {
        let out = case_study_plan(&files, "title", "abstract")
            .optimize()
            .execute(workers)
            .unwrap();
        assert_eq!(out.frame, reference.frame, "workers {workers}");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn optimized_plan_fuses_at_least_four_abstract_stages() {
    let plan = case_study_plan(&[], "title", "abstract").optimize();
    let explained = p3sapp::plan::explain(&plan, 2).unwrap();
    // The abstract column's five cleaning stages must have collapsed
    // into a single fused sweep.
    assert!(
        explained.contains("FusedStringStage(abstract <- lower|html|chars|stopwords|short-words(<=1))"),
        "{explained}"
    );
    assert!(
        explained.contains("FusedStringStage(title <- lower|html|chars)"),
        "{explained}"
    );
}
