//! Property-based tests over the text-cleaning substrate and the
//! pipeline, driven by the crate's own deterministic PRNG (no proptest
//! in the vendored closure — these are seeded random-input invariant
//! sweeps with explicit failure seeds printed on assert).

use p3sapp::baseline::{clean_abstract_row, clean_title_row};
use p3sapp::corpus::{record, Rng};
use p3sapp::frame::{Column, Frame, Partition, Schema};
use p3sapp::pipeline::presets::{abstract_pipeline, title_pipeline};
use p3sapp::textutil;

/// Random "dirty" scholarly text: generated sentences + random noise
/// injections (HTML, unicode, control chars, brackets).
fn dirty_text(rng: &mut Rng) -> String {
    let n = 1 + rng.gen_range(3);
    let mut t = record::abstract_text(rng, n);
    t = record::add_html_noise(rng, t, 0.6);
    // Sprinkle adversarial fragments.
    const NASTY: &[&str] = &[
        "p < 0.05", "x>y", "<", ">", "&", "&amp;", "(unclosed", "closed)",
        "(()())", "na\u{ef}ve", "\u{3b1}-helix", "it's", "don't", "A1-B2_3",
        "<b>", "</i>", "<!--", "-->", "\"quote\"", "tab\there", "", "   ",
    ];
    for _ in 0..rng.gen_range(4) {
        let frag = *rng.choice(NASTY);
        let pos = if t.is_empty() { 0 } else { rng.gen_range(t.len()) };
        // Insert at a char boundary.
        let mut at = pos;
        while !t.is_char_boundary(at) {
            at -= 1;
        }
        t.insert_str(at, frag);
        t.insert(at, ' ');
    }
    t
}

const TRIALS: usize = 400;

#[test]
fn cleaned_abstract_is_model_ready_for_any_input() {
    let mut rng = Rng::new(0xABCD);
    for trial in 0..TRIALS {
        let input = dirty_text(&mut rng);
        let out = clean_abstract_row(&input);
        // Invariant: only lowercase ASCII letters and single spaces.
        assert!(
            out.chars().all(|c| c.is_ascii_lowercase() || c == ' '),
            "trial {trial}: bad char in {out:?} (input {input:?})"
        );
        assert!(!out.contains("  "), "trial {trial}: double space in {out:?}");
        assert!(!out.starts_with(' ') && !out.ends_with(' '), "trial {trial}");
        // Invariant: no stopwords, no 1-char words.
        for w in out.split_whitespace() {
            assert!(!textutil::is_stopword(w), "trial {trial}: stopword {w}");
            assert!(w.len() > 1, "trial {trial}: short word {w}");
        }
    }
}

#[test]
fn cleaning_is_idempotent() {
    let mut rng = Rng::new(0x1DE0);
    for trial in 0..TRIALS {
        let input = dirty_text(&mut rng);
        let once = clean_abstract_row(&input);
        assert_eq!(clean_abstract_row(&once), once, "abstract trial {trial}: {input:?}");
        let once_t = clean_title_row(&input);
        assert_eq!(clean_title_row(&once_t), once_t, "title trial {trial}");
    }
}

#[test]
fn html_stripper_never_leaves_real_tags() {
    // Entity-encoded markup (`&lt;i&gt;`) correctly decodes to *text*
    // `<i>` on the first pass (BeautifulSoup semantics), so the
    // invariant is on the double-strip: after two passes no real-tag
    // opener may remain (our noise nests entities at most one level).
    let mut rng = Rng::new(0x11AA);
    let (mut pass1, mut out) = (String::new(), String::new());
    for trial in 0..TRIALS {
        let input = dirty_text(&mut rng);
        textutil::strip_html(&input, &mut pass1);
        textutil::strip_html(&pass1, &mut out);
        let bytes = out.as_bytes();
        for (i, w) in out.char_indices() {
            if w == '<' {
                let next = bytes.get(i + 1).copied().unwrap_or(b' ');
                assert!(
                    !(next.is_ascii_alphabetic() || next == b'/' || next == b'!'),
                    "trial {trial}: tag survived in {out:?} (input {input:?})"
                );
            }
        }
    }
}

#[test]
fn pipeline_equals_row_cleaner_on_random_inputs() {
    // The two cleaning architectures (column pipeline vs row loop) must
    // be semantically identical — this is what makes the accuracy
    // experiment meaningful.
    let mut rng = Rng::new(0xC0FE);
    let inputs: Vec<Option<String>> = (0..TRIALS)
        .map(|i| if i % 17 == 0 { None } else { Some(dirty_text(&mut rng)) })
        .collect();

    let schema = Schema::strings(&["title", "abstract"]);
    let frame = Frame::from_partitions(
        schema,
        // Odd partition sizes to exercise boundaries.
        inputs
            .chunks(23)
            .map(|c| {
                Partition::new(vec![
                    Column::from_strs(c.to_vec()),
                    Column::from_strs(c.to_vec()),
                ])
            })
            .collect(),
    )
    .unwrap();

    let title_m = title_pipeline("title").fit(&frame).unwrap();
    let abs_m = abstract_pipeline("abstract").fit(&frame).unwrap();
    let out = abs_m
        .transform(title_m.transform(frame, 2).unwrap(), 2)
        .unwrap()
        .collect();

    for (i, input) in inputs.iter().enumerate() {
        match input {
            None => {
                assert!(out.column(0).is_null(i));
                assert!(out.column(1).is_null(i));
            }
            Some(s) => {
                assert_eq!(
                    out.column(0).get_str(i).unwrap(),
                    clean_title_row(s),
                    "title row {i}: {s:?}"
                );
                assert_eq!(
                    out.column(1).get_str(i).unwrap(),
                    clean_abstract_row(s),
                    "abstract row {i}: {s:?}"
                );
            }
        }
    }
}

#[test]
fn owned_and_borrowed_stage_paths_agree() {
    use p3sapp::pipeline::stages::*;
    use p3sapp::pipeline::Transformer;
    let mut rng = Rng::new(0x0DD);
    let vals: Vec<Option<String>> = (0..TRIALS)
        .map(|i| if i % 11 == 0 { None } else { Some(dirty_text(&mut rng)) })
        .collect();
    let col = Column::from_strs(vals);
    let stages: Vec<Box<dyn Transformer>> = vec![
        Box::new(ConvertToLower::new("c")),
        Box::new(RemoveHtmlTags::new("c")),
        Box::new(RemoveUnwantedCharacters::new("c")),
        Box::new(StopWordsRemoverStr::new("c")),
        Box::new(RemoveShortWords::new("c", 1)),
    ];
    for st in stages {
        let borrowed = st.transform_column(&col);
        let owned = st.transform_column_owned(col.clone());
        assert_eq!(borrowed, owned, "stage {} diverged", st.name());
    }
}

#[test]
fn projected_parser_agrees_with_full_parser_on_generated_corpora() {
    use p3sapp::json::{parse_document, parse_document_projected};
    let mut rng = Rng::new(0xFEED);
    for trial in 0..40 {
        // Build a small record batch, serialize, parse both ways.
        let records: Vec<_> = (0..20)
            .map(|i| {
                record::CoreRecord::generate(&mut rng, i, 0.5, i % 7 == 0, i % 5 == 0)
            })
            .collect();
        let mut doc = String::from("[");
        for (i, r) in records.iter().enumerate() {
            if i > 0 {
                doc.push(',');
            }
            doc.push_str(&r.to_json().to_string());
        }
        doc.push(']');

        let full = parse_document(&doc).unwrap();
        let proj = parse_document_projected(&doc, &["title", "abstract"]).unwrap();
        assert_eq!(full.len(), proj.len());
        for (rec, row) in full.iter().zip(&proj) {
            assert_eq!(rec.get_str("title").map(String::from), row[0], "trial {trial}");
            assert_eq!(rec.get_str("abstract").map(String::from), row[1], "trial {trial}");
        }
    }
}

#[test]
fn tokenizer_roundtrip_property() {
    // join(tokenize(clean)) == clean for already-cleaned text (single
    // spaces, lowercase) — tokenization must be lossless there.
    let mut rng = Rng::new(0x70C0);
    for _ in 0..TRIALS {
        let cleaned = clean_abstract_row(&dirty_text(&mut rng));
        let tokens = textutil::tokenize(&cleaned);
        assert_eq!(tokens.join(" "), cleaned);
    }
}
