//! Plan-cache correctness contract: a warm restore must be
//! byte-identical to the live execution; anything that changes the work
//! (shard content, plan shape) must miss; a damaged artifact must be a
//! miss that re-executes, never an error.

use p3sapp::cache::{fingerprint, shard_key, CacheConfig, CacheManager, ARTIFACT_EXT};
use p3sapp::corpus::{generate_corpus, CorpusSpec};
use p3sapp::driver::{run_p3sapp, DriverOptions};
use p3sapp::ingest::list_shards;
use p3sapp::pipeline::presets::case_study_plan;
use std::path::PathBuf;
use std::sync::Arc;

fn corpus(name: &str, seed: u64) -> (PathBuf, Vec<PathBuf>) {
    let dir =
        std::env::temp_dir().join(format!("p3sapp-cachert-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut spec = CorpusSpec::tiny(seed);
    spec.dup_rate = 0.15;
    spec.null_title_rate = 0.1;
    generate_corpus(&spec, &dir).unwrap();
    let files = list_shards(&dir).unwrap();
    (dir, files)
}

/// A disk-only manager over `dir` — a fresh one per call models a new
/// process (empty memo), which is the tier the cross-run guarantees
/// live in.
fn disk_manager(dir: &std::path::Path) -> CacheManager {
    CacheManager::with_config(CacheConfig {
        dir: dir.to_path_buf(),
        max_bytes: 0,
        memory: false,
        memory_max_bytes: 0,
    })
    .unwrap()
}

#[test]
fn round_trip_restores_the_live_frame_byte_for_byte() {
    let (dir, files) = corpus("rt", 11);
    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let live = plan.execute(2).unwrap();

    let cache_dir = dir.join("cache");
    let fp = fingerprint(&plan.render(), &files).unwrap();
    disk_manager(&cache_dir).put(&fp, &live).unwrap();

    // A different manager instance (fresh process, no memo) restores.
    let restored = disk_manager(&cache_dir).get(&fp).expect("warm hit");
    assert_eq!(restored.frame, live.frame, "restored frame must be byte-identical");
    assert_eq!(restored.rows_ingested, live.rows_ingested);
    assert_eq!(restored.rows_out, live.rows_out);
    assert_eq!(restored.nulls_dropped, live.nulls_dropped);
    assert_eq!(restored.dups_dropped, live.dups_dropped);
    assert_eq!(restored.empties_dropped, live.empties_dropped);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn touched_but_identical_shard_still_hits() {
    let (dir, files) = corpus("touch", 19);
    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let cache_dir = dir.join("cache");
    let fp = fingerprint(&plan.render(), &files).unwrap();
    disk_manager(&cache_dir).put(&fp, &plan.execute(2).unwrap()).unwrap();

    // Rewrite a shard with its own bytes: mtime moves, content doesn't.
    let bytes = std::fs::read(&files[0]).unwrap();
    std::fs::write(&files[0], &bytes).unwrap();

    let fp2 = fingerprint(&plan.render(), &files).unwrap();
    assert_eq!(fp.key(), fp2.key(), "the digest names the bytes, not the mtime");
    assert!(disk_manager(&cache_dir).get(&fp2).is_some());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn content_edit_with_forced_equal_mtime_misses() {
    let (dir, files) = corpus("edit", 29);
    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let cache_dir = dir.join("cache");
    let fp = fingerprint(&plan.render(), &files).unwrap();
    disk_manager(&cache_dir).put(&fp, &plan.execute(2).unwrap()).unwrap();

    // Same-length edit, then force the original mtime back — the
    // stat-visible identity is unchanged; only the bytes differ.
    let shard = &files[0];
    let old_mtime = std::fs::metadata(shard).unwrap().modified().unwrap();
    let mut bytes = std::fs::read(shard).unwrap();
    let i = bytes.iter().position(|&b| b.is_ascii_lowercase()).unwrap();
    bytes[i] = if bytes[i] == b'z' { b'y' } else { b'z' };
    std::fs::write(shard, &bytes).unwrap();
    std::fs::File::options()
        .write(true)
        .open(shard)
        .unwrap()
        .set_modified(old_mtime)
        .unwrap();
    assert_eq!(
        std::fs::metadata(shard).unwrap().modified().unwrap(),
        old_mtime,
        "mtime restoration must hold for this test to mean anything"
    );

    let fp2 = fingerprint(&plan.render(), &files).unwrap();
    assert_ne!(fp.key(), fp2.key(), "content digest must see through the mtime");
    assert!(disk_manager(&cache_dir).get(&fp2).is_none());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn plan_shape_change_misses() {
    let (dir, files) = corpus("shape", 37);
    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let cache_dir = dir.join("cache");
    let fp = fingerprint(&plan.render(), &files).unwrap();
    disk_manager(&cache_dir).put(&fp, &plan.execute(2).unwrap()).unwrap();

    // The same corpus under a different plan (unoptimized: more ops in
    // the render) must derive a different key and miss.
    let other = case_study_plan(&files, "title", "abstract");
    let fp2 = fingerprint(&other.render(), &files).unwrap();
    assert_ne!(fp.key(), fp2.key());
    assert!(disk_manager(&cache_dir).get(&fp2).is_none());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn truncated_artifact_is_a_miss_and_the_driver_reexecutes() {
    let (dir, files) = corpus("trunc", 43);
    let cache_dir = dir.join("cache");
    let cache = Arc::new(CacheManager::open(&cache_dir).unwrap());
    let opts = DriverOptions { workers: 2, cache: Some(Arc::clone(&cache)), ..Default::default() };

    let cold = run_p3sapp(&files, &opts).unwrap();
    assert!(!cold.from_cache());

    // Truncate the stored artifact mid-payload.
    let entries = cache.entries().unwrap();
    assert_eq!(entries.len(), 1);
    let artifact = entries[0].path.clone();
    let bytes = std::fs::read(&artifact).unwrap();
    std::fs::write(&artifact, &bytes[..bytes.len() / 3]).unwrap();

    // Fresh manager (no memo): the damaged artifact must be treated as
    // a miss and the run must re-execute to the same bytes — no error.
    let cache2 = Arc::new(disk_manager(&cache_dir));
    let opts2 =
        DriverOptions { workers: 2, cache: Some(Arc::clone(&cache2)), ..Default::default() };
    let rerun = run_p3sapp(&files, &opts2).unwrap();
    assert!(!rerun.from_cache(), "corrupt artifact must not restore");
    assert_eq!(rerun.frame, cold.frame);
    assert_eq!(cache2.stats().corrupt, 1);
    assert_eq!(cache2.stats().stores, 1, "re-executed result re-stored");

    // And the re-stored artifact is valid again.
    let warm = run_p3sapp(&files, &opts2).unwrap();
    assert!(warm.from_cache());
    assert_eq!(warm.frame, cold.frame);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn stale_version_artifacts_on_disk_are_clean_misses_never_errors() {
    // A cache directory written by an older build (version-2 whole-plan
    // envelopes, before the per-shard kind byte) must behave as if
    // empty: the driver re-executes to the same bytes and re-stores
    // current-version artifacts — no error, no partial restore.
    let (dir, files) = corpus("stale", 61);
    let plain =
        run_p3sapp(&files, &DriverOptions { workers: 2, ..Default::default() }).unwrap();

    let cache_dir = dir.join("cache");
    let cache = Arc::new(CacheManager::open(&cache_dir).unwrap());
    let opts = DriverOptions { workers: 2, cache: Some(Arc::clone(&cache)), ..Default::default() };
    // Plant stale (version-2) artifacts at both the whole-plan key and
    // the first shard's per-shard key.
    let plan = opts.build_plan(&files).optimize();
    let fp = fingerprint(&plan.render(), &files).unwrap();
    let skey = shard_key(&plan.render(), &fp.shards()[0]);
    let mut v2 = Vec::new();
    v2.extend_from_slice(b"P3PC");
    v2.extend_from_slice(&2u32.to_le_bytes());
    v2.extend_from_slice(&[0u8; 64]);
    for key in [fp.key(), skey.as_str()] {
        std::fs::write(cache_dir.join(format!("{key}.{ARTIFACT_EXT}")), &v2).unwrap();
    }

    let out = run_p3sapp(&files, &opts).unwrap();
    assert!(!out.from_cache(), "stale artifacts must not restore");
    assert_eq!(out.frame, plain.frame);
    let s = cache.stats();
    assert!(s.corrupt >= 2, "both stale artifacts dropped, got {}", s.corrupt);
    assert_eq!(s.shard_hits, 0, "no shard may restore from a stale artifact");
    assert_eq!(s.shard_misses, files.len() as u64);

    // The rewrite healed the cache: a fresh-process warm run restores.
    let cache2 = Arc::new(disk_manager(&cache_dir));
    let opts2 =
        DriverOptions { workers: 2, cache: Some(Arc::clone(&cache2)), ..Default::default() };
    let warm = run_p3sapp(&files, &opts2).unwrap();
    assert!(warm.from_cache());
    assert_eq!(warm.frame, plain.frame);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn no_cache_matches_cached_outputs_exactly() {
    let (dir, files) = corpus("nocache", 53);
    let cache = Arc::new(CacheManager::open(dir.join("cache")).unwrap());
    let without = run_p3sapp(&files, &DriverOptions { workers: 2, ..Default::default() })
        .unwrap();
    let with_cold = run_p3sapp(
        &files,
        &DriverOptions { workers: 2, cache: Some(Arc::clone(&cache)), ..Default::default() },
    )
    .unwrap();
    let with_warm = run_p3sapp(
        &files,
        &DriverOptions { workers: 2, cache: Some(Arc::clone(&cache)), ..Default::default() },
    )
    .unwrap();
    assert_eq!(without.frame, with_cold.frame);
    assert_eq!(without.frame, with_warm.frame);
    assert_eq!(without.rows_out, with_warm.rows_out);
    assert!(!without.from_cache() && !with_cold.from_cache() && with_warm.from_cache());
    std::fs::remove_dir_all(&dir).unwrap();
}
