//! Cursor-vs-owned parser agreement — the contract that lets the
//! zero-copy byte cursor (`json::cursor`) replace the owned projected
//! parser on the ingestion hot path: for every shard the two parsers
//! either produce identical projected cells or both reject the shard.
//! Inputs here are deliberately nasty — every escape form, surrogate
//! pairs, embedded NUL, truncated records, invalid UTF-8 inside and
//! outside escaped spans, blank/whitespace-only lines — plus a seeded
//! randomized sweep over a grammar both parsers must agree on.
//!
//! (One known, deliberate divergence is excluded from the grammar: the
//! owned parser's `u32::from_str_radix` accepts a sign in `\u` escapes,
//! e.g. `\u+fff`; the cursor rejects it per RFC 8259. No real corpus
//! contains signed `\u` escapes.)

use p3sapp::ingest::spark::{ingest_files, ingest_files_owned, IngestOptions};
use p3sapp::json::{parse_document_projected, parse_shard_projected};

type Rows = Vec<Vec<Option<String>>>;

fn cursor_rows(buf: &[u8], fields: &[&str]) -> Result<Rows, String> {
    parse_shard_projected(buf, fields)
        .map(|out| {
            (0..out.rows)
                .map(|r| out.cols.iter().map(|c| c[r].as_deref().map(String::from)).collect())
                .collect()
        })
        .map_err(|e| e.to_string())
}

fn owned_rows(input: &str, fields: &[&str]) -> Result<Rows, String> {
    parse_document_projected(input, fields).map_err(|e| e.to_string())
}

/// Both parsers must agree: same rows, or both errors. Error *messages*
/// are not pinned — only accept/reject and the accepted cells are.
fn assert_agree(input: &str, fields: &[&str]) {
    let c = cursor_rows(input.as_bytes(), fields);
    let o = owned_rows(input, fields);
    match (&c, &o) {
        (Ok(cr), Ok(or)) => assert_eq!(cr, or, "projected rows diverge for {input:?}"),
        (Err(_), Err(_)) => {}
        _ => panic!("parsers disagree on accept/reject for {input:?}:\n cursor={c:?}\n owned={o:?}"),
    }
}

#[test]
fn every_escape_form_agrees() {
    for payload in [
        r#"quote \" here"#,
        r#"back \\ slash"#,
        r#"solidus \/ ok"#,
        r#"bell \b feed \f"#,
        r#"line \n ret \r tab \t"#,
        r#"mixed \"\\\/\b\f\n\r\t end"#,
        r#"hex Aé中"#,
        r#"nul \u0000 embedded"#,
        r#"pair 😀 smile"#,
        r#"high edge 𐀀 low edge 􏿿"#,
        r#"adjacent words"#,
    ] {
        assert_agree(&format!("{{\"title\": \"{payload}\", \"abstract\": \"x\"}}"), &[
            "title", "abstract",
        ]);
        // Same payload in a *skipped* (unprojected) field.
        assert_agree(&format!("{{\"junk\": \"{payload}\", \"title\": \"kept\"}}"), &["title"]);
    }
}

#[test]
fn bad_escapes_and_surrogates_reject_on_both() {
    for bad in [
        r#"{"t": "\x41"}"#,    // unknown escape
        r#"{"t": "\u12"}"#,    // short \u
        r#"{"t": "\u12g4"}"#,  // non-hex digit
        r#"{"t": "\ud800"}"#,  // unpaired high surrogate
        r#"{"t": "\ud800A"}"#, // high followed by non-low
        r#"{"t": "\ude00"}"#,  // lone low surrogate
        r#"{"t": "\"#,         // escape at EOF
    ] {
        assert_agree(bad, &["t"]);
        assert!(cursor_rows(bad.as_bytes(), &["t"]).is_err(), "{bad:?}");
    }
}

#[test]
fn truncated_records_reject_on_both() {
    for bad in [
        "{", "{\"t\"", "{\"t\":", "{\"t\": \"a", "{\"t\": \"a\"", "{\"t\": \"a\",",
        "[", "[{\"t\": \"a\"}", "[{\"t\": \"a\"},", "{\"t\": tru}", "{\"t\": nul}",
        "{\"t\": 1e}", "{\"t\": -}", "{\"t\": [1, 2}",
    ] {
        assert_agree(bad, &["t"]);
        assert!(cursor_rows(bad.as_bytes(), &["t"]).is_err(), "{bad:?}");
    }
}

#[test]
fn whitespace_layouts_and_blank_lines_agree() {
    for input in [
        "",
        "   \n \t \n",
        "{\"t\": \"solo\"}",
        "  {\"t\": \"padded\"}  ",
        "{\"t\": \"a\"}\n\n   \n{\"t\": \"b\"}\n",
        "\n\n{\"t\": \"late start\"}",
        "[]",
        "  [ ]  ",
        "[{\"t\": \"a\"}, {\"t\": \"b\"}]",
        "[ {\"t\": \"a\"} ,\n {\"t\": \"b\"} ]",
        // Unicode whitespace around records (owned path trims it).
        "\u{00A0}{\"t\": \"nbsp lead\"}",
        "{\"t\": \"nbsp trail\"}\u{00A0}",
    ] {
        assert_agree(input, &["t"]);
    }
}

#[test]
fn projection_and_duplicate_key_rules_agree() {
    for input in [
        // Non-string / null projected values leave the cell None.
        "{\"t\": 42}",
        "{\"t\": null}",
        "{\"t\": true}",
        "{\"t\": [1, \"not me\"]}",
        "{\"t\": {\"nested\": \"not me\"}}",
        // Duplicate keys: later *string* wins, later non-string ignored.
        "{\"t\": \"first\", \"t\": \"second\"}",
        "{\"t\": \"kept\", \"t\": 7}",
        "{\"t\": 7, \"t\": \"kept\"}",
        // Deeply skipped junk with brace-lookalike payloads.
        "{\"x\": [1, {\"y\": \"n}]\"}, [null, true]], \"t\": \"kept\", \"w\": 1e-3}",
        // Missing projected field entirely.
        "{\"other\": \"x\"}",
        "{}",
    ] {
        assert_agree(input, &["t"]);
    }
}

#[test]
fn number_forms_agree() {
    for (num, ok) in [
        ("0", true),
        ("-0", true),
        ("42", true),
        ("-17", true),
        ("3.25", true),
        ("-0.5", true),
        ("1e10", true),
        ("2E-3", true),
        ("6.02e+23", true),
        ("1e", false),
        ("-", false),
        (".5", false),
        ("+1", false),
    ] {
        let input = format!("{{\"n\": {num}, \"t\": \"x\"}}");
        assert_agree(&input, &["t"]);
        assert_eq!(cursor_rows(input.as_bytes(), &["t"]).is_ok(), ok, "{num}");
    }
}

#[test]
fn invalid_utf8_always_rejects_never_mojibakes() {
    // The owned path cannot even receive invalid UTF-8 (`read_to_string`
    // rejects the file), so the cursor must reject it wherever the bytes
    // hide — value span, escaped-string run, skipped string, key,
    // structural area — and never pass replacement characters through.
    let cases: &[&[u8]] = &[
        b"{\"t\": \"a\xffb\"}",                   // raw value span
        b"{\"t\": \"pre\\n mid \xff post\"}",     // run inside an escaped string
        b"{\"junk\": \"a\xffb\", \"t\": \"ok\"}", // skipped string
        b"{\"k\xff\": 1, \"t\": \"ok\"}",         // key
        b"{\"t\": \"ok\"}\xff",                   // structural area (JSONL tail)
        b"\xff{\"t\": \"ok\"}",                   // before the document
        b"{\"t\": \"trunc \xe2\x82\"}",           // truncated multi-byte seq
        b"{\"t\": \"overlong \xc0\xaf\"}",        // overlong encoding
        b"{\"t\": \"cesu \xed\xa0\xbd\"}",        // surrogate bytes in UTF-8
    ];
    for case in cases {
        let r = parse_shard_projected(case, &["t"]);
        assert!(r.is_err(), "must reject {case:?}");
        assert!(std::str::from_utf8(case).is_err(), "case should be invalid UTF-8");
    }
}

/// Deterministic xorshift generator — no external crates, fixed seeds.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[(self.next() % items.len() as u64) as usize]
    }
}

/// JSON *source text* fragments for string contents — already escaped,
/// safe for both parsers (no signed `\u`, no lone surrogates).
const STR_PARTS: &[&str] = &[
    "plain",
    "two words",
    r#"q\" "#,
    r#"b\\ "#,
    r#"s\/ "#,
    r#"\b\f\n\r\t"#,
    r#"Aé"#,
    r#"中文"#,
    r#"😀"#,
    r#"\u0000"#,
    "naïve Σ café",
    "😀 emoji raw",
    "",
];

const NUMBERS: &[&str] = &["0", "-1", "42", "3.25", "-0.5", "1e10", "2E-3", "6.02e+23"];
const KEYS: &[&str] = &["title", "abstract", "junk", "n", "flags", "meta", "title"];

fn gen_string(rng: &mut Rng) -> String {
    let n = rng.next() % 3 + 1;
    let mut s = String::from("\"");
    for _ in 0..n {
        s.push_str(rng.pick(STR_PARTS));
    }
    s.push('"');
    s
}

fn gen_value(rng: &mut Rng, depth: usize) -> String {
    match rng.next() % if depth == 0 { 4 } else { 6 } {
        0 => gen_string(rng),
        1 => (*rng.pick(NUMBERS)).to_string(),
        2 => (*rng.pick(&["true", "false"])).to_string(),
        3 => "null".to_string(),
        4 => {
            let n = rng.next() % 3;
            let items: Vec<String> = (0..n).map(|_| gen_value(rng, depth - 1)).collect();
            format!("[{}]", items.join(", "))
        }
        _ => gen_record(rng, depth - 1),
    }
}

fn gen_record(rng: &mut Rng, depth: usize) -> String {
    let n = rng.next() % 4;
    let fields: Vec<String> = (0..n)
        .map(|_| format!("\"{}\": {}", rng.pick(KEYS), gen_value(rng, depth)))
        .collect();
    format!("{{{}}}", fields.join(", "))
}

#[test]
fn randomized_documents_agree() {
    let fields = ["title", "abstract"];
    for seed in 1..=40u64 {
        let mut rng = Rng(seed * 0x9E37_79B9_7F4A_7C15);
        let n_records = rng.next() % 6 + 1;
        let records: Vec<String> = (0..n_records).map(|_| gen_record(&mut rng, 2)).collect();
        // Same records in both layouts.
        let array = format!("[{}]", records.join(",\n"));
        let jsonl = records.join("\n");
        assert_agree(&array, &fields);
        assert_agree(&jsonl, &fields);
        // Sanity: the generated documents are well-formed, so agreement
        // is on Ok results, not on mutual rejection.
        assert!(cursor_rows(array.as_bytes(), &fields).is_ok(), "seed {seed}: {array}");
        assert!(cursor_rows(jsonl.as_bytes(), &fields).is_ok(), "seed {seed}: {jsonl}");
    }
}

#[test]
fn file_level_cursor_and_owned_ingest_agree_on_nasty_shard() {
    let dir = std::env::temp_dir().join(format!("p3sapp-cursor-parity-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("nasty.json"),
        concat!(
            "{\"title\": \"esc \\\"q\\\" \\u00e9 \\ud83d\\ude00\", \"abstract\": \"naïve Σ\"}\n",
            "\n",
            "   \n",
            "{\"title\": 42, \"abstract\": null, \"junk\": [1, {\"x\": \"}]\"}]}\n",
            "{\"abstract\": \"only abstract \\u0000 nul\"}\n",
        ),
    )
    .unwrap();
    let files = vec![dir.join("nasty.json")];
    let opts = IngestOptions { workers: 2, queue_cap: 4 };
    let fields = ["title", "abstract"];
    let via_cursor = ingest_files(&files, &fields, &opts).unwrap().collect();
    let via_owned = ingest_files_owned(&files, &fields, &opts).unwrap().collect();
    assert_eq!(via_cursor, via_owned);
    assert_eq!(via_cursor.num_rows(), 3);

    // An invalid-UTF-8 shard is rejected by both paths.
    std::fs::write(dir.join("bad.json"), b"{\"title\": \"a\xffb\", \"abstract\": \"x\"}\n")
        .unwrap();
    let bad = vec![dir.join("bad.json")];
    assert!(ingest_files(&bad, &fields, &opts).is_err());
    assert!(ingest_files_owned(&bad, &fields, &opts).is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}
