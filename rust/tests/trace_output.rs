//! Golden-schema tests for `--trace` output and `explain --analyze`.
//!
//! Each test runs the *built binary* (`CARGO_BIN_EXE_repro`) over a
//! small generated corpus and validates the Chrome-trace-event JSON it
//! writes with the crate's own parser — the same document Perfetto or
//! `chrome://tracing` would load. Pinned contracts:
//!
//! - the document is valid JSON with a non-empty `traceEvents` array of
//!   `"M"` metadata and `"X"` complete events with sane timestamps;
//! - a `--stream` run records distinct driver / reader / worker-thread
//!   lanes (trace pid 0, tids 0 / 100+ / 200+), with the per-op spans
//!   nested inside the driver's `execute` span;
//! - a `--processes` run records worker-*process* lanes (trace pid
//!   `1 + w`), whose shipped spans are clock-aligned into the driver
//!   timeline: every remote span nests inside that worker's driver-side
//!   `rpc` span;
//! - `explain --analyze` renders the analyzed topology with per-op
//!   actuals for every op.

use p3sapp::corpus::{generate_corpus, CorpusSpec};
use p3sapp::json::{parse, Json};
use p3sapp::obs::trace::{READER_TID_BASE, WORKER_TID_BASE};
use std::collections::BTreeSet;
use std::path::PathBuf;
use std::process::Command;

/// Per-test scratch root holding the corpus shards and the trace file.
fn scratch(name: &str) -> (PathBuf, PathBuf) {
    let root = std::env::temp_dir().join(format!("p3sapp-trace-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let corpus = root.join("corpus");
    generate_corpus(&CorpusSpec::tiny(23), &corpus).unwrap();
    (root, corpus)
}

fn run_repro(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_repro")).args(args).output().unwrap();
    assert!(
        out.status.success(),
        "repro {args:?} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).unwrap()
}

/// Parse a written trace and return its document after the generic
/// schema checks every trace must pass.
fn load_trace(path: &PathBuf) -> Json {
    let text = std::fs::read_to_string(path).expect("trace file written");
    let doc = parse(&text).expect("trace must be valid JSON");
    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert!(!events.is_empty(), "trace must record events");
    for e in events {
        match e.get_str("ph") {
            Some("M") => {
                assert!(
                    e.get("args").and_then(|a| a.get_str("name")).is_some(),
                    "metadata event must name its lane: {e:?}"
                );
            }
            Some("X") => {
                let ts = e.get("ts").and_then(Json::as_f64).expect("ts");
                let dur = e.get("dur").and_then(Json::as_f64).expect("dur");
                assert!(ts >= 0.0 && dur >= 0.0, "timestamps must be sane: {e:?}");
            }
            other => panic!("unexpected event phase {other:?}: {e:?}"),
        }
    }
    doc
}

/// The `"X"` (span) events of a parsed trace as
/// `(name, pid, tid, ts, end)` tuples.
fn span_events(doc: &Json) -> Vec<(String, i64, i64, f64, f64)> {
    doc.get("traceEvents")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter(|e| e.get_str("ph") == Some("X"))
        .map(|e| {
            let ts = e.get("ts").and_then(Json::as_f64).unwrap();
            let dur = e.get("dur").and_then(Json::as_f64).unwrap();
            (
                e.get_str("name").unwrap().to_string(),
                e.get("pid").and_then(Json::as_i64).unwrap(),
                e.get("tid").and_then(Json::as_i64).unwrap(),
                ts,
                ts + dur,
            )
        })
        .collect()
}

fn lane_names(doc: &Json) -> Vec<String> {
    doc.get("traceEvents")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter(|e| e.get_str("ph") == Some("M"))
        .filter_map(|e| e.get("args").and_then(|a| a.get_str("name")).map(str::to_string))
        .collect()
}

#[test]
fn streamed_run_traces_driver_reader_and_worker_thread_lanes() {
    let (root, corpus) = scratch("stream");
    let trace = root.join("stream.trace.json");
    run_repro(&[
        "preprocess",
        "--dir",
        corpus.to_str().unwrap(),
        "--approach",
        "p3sapp",
        "--stream",
        "--readers",
        "2",
        "--workers",
        "2",
        "--trace",
        trace.to_str().unwrap(),
    ]);
    let doc = load_trace(&trace);
    let spans = span_events(&doc);

    // All three in-process lane families, distinct by tid.
    assert!(spans.iter().any(|(_, pid, tid, ..)| (*pid, *tid) == (0, 0)), "driver lane");
    assert!(
        spans.iter().any(|(_, pid, tid, ..)| *pid == 0
            && (READER_TID_BASE as i64..WORKER_TID_BASE as i64).contains(tid)),
        "reader lane missing: {spans:?}"
    );
    assert!(
        spans.iter().any(|(_, pid, tid, ..)| *pid == 0 && *tid >= WORKER_TID_BASE as i64),
        "worker-thread lane missing: {spans:?}"
    );
    let names = lane_names(&doc);
    assert!(names.iter().any(|n| n == "driver"), "{names:?}");
    assert!(names.iter().any(|n| n.starts_with("reader ")), "{names:?}");
    assert!(names.iter().any(|n| n.starts_with("worker ")), "{names:?}");

    // Nesting: the driver's execute span brackets the whole pipeline,
    // so every other pid-0 span recorded under it stays inside its
    // interval (one shared monotonic clock).
    let (_, _, _, exec_ts, exec_end) = spans
        .iter()
        .find(|(name, pid, tid, ..)| name == "execute" && (*pid, *tid) == (0, 0))
        .expect("driver execute span")
        .clone();
    let nested: Vec<_> =
        spans.iter().filter(|(name, pid, ..)| *pid == 0 && name != "execute").collect();
    assert!(!nested.is_empty());
    for (name, _, _, ts, end) in nested {
        if *ts >= exec_ts {
            assert!(
                *end <= exec_end,
                "span '{name}' [{ts}, {end}] escapes execute [{exec_ts}, {exec_end}]"
            );
        }
    }
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn multiprocess_run_aligns_worker_spans_inside_driver_rpc_spans() {
    let (root, corpus) = scratch("procs");
    let trace = root.join("procs.trace.json");
    run_repro(&[
        "preprocess",
        "--dir",
        corpus.to_str().unwrap(),
        "--approach",
        "p3sapp",
        "--processes",
        "2",
        "--trace",
        trace.to_str().unwrap(),
    ]);
    let doc = load_trace(&trace);
    let spans = span_events(&doc);

    // Worker-process lanes exist and carry real (shipped) spans beyond
    // the driver-side rpc bracket.
    let worker_pids: BTreeSet<i64> =
        spans.iter().filter(|(_, pid, ..)| *pid >= 1).map(|(_, pid, ..)| *pid).collect();
    assert!(!worker_pids.is_empty(), "no worker-process lanes: {spans:?}");
    assert!(
        spans.iter().any(|(name, pid, ..)| *pid >= 1 && name != "rpc"),
        "no spans shipped back from the workers: {spans:?}"
    );
    let names = lane_names(&doc);
    assert!(names.iter().any(|n| n.starts_with("plan-worker ")), "{names:?}");

    // Clock alignment: each worker's spans were re-anchored to the
    // driver-side RPC start, so they nest inside that worker's rpc span
    // in the one shared timeline.
    for pid in worker_pids {
        let (_, _, _, rpc_ts, rpc_end) = spans
            .iter()
            .find(|(name, p, ..)| name == "rpc" && *p == pid)
            .unwrap_or_else(|| panic!("no rpc span for worker pid {pid}"))
            .clone();
        for (name, p, _, ts, end) in &spans {
            if *p == pid && name != "rpc" {
                assert!(
                    *ts >= rpc_ts && *end <= rpc_end,
                    "worker span '{name}' [{ts}, {end}] escapes rpc [{rpc_ts}, {rpc_end}]"
                );
            }
        }
    }
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn explain_analyze_renders_per_op_actuals() {
    let (root, corpus) = scratch("analyze");
    let stdout = run_repro(&[
        "explain",
        "--dir",
        corpus.to_str().unwrap(),
        "--analyze",
        "--workers",
        "2",
    ]);
    assert!(stdout.contains("== Analyzed Physical Plan =="), "{stdout}");
    assert!(stdout.contains("[actual: "), "{stdout}");
    assert!(
        !stdout.contains("[actual: not executed]"),
        "every op of the cleaning plan runs: {stdout}"
    );
    assert!(stdout.contains("Driver: executed in"), "{stdout}");
    std::fs::remove_dir_all(&root).unwrap();
}
