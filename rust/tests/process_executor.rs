//! Integration tests for the multi-process sharded executor
//! (`plan::process`): real worker processes (the built `repro` binary's
//! hidden `plan-worker` mode), byte-identity against the in-process
//! executors, the scarce-shard fallback, and — most importantly — the
//! worker-failure paths: a worker that exits nonzero, one killed by a
//! signal mid-run, and one that emits a garbled result frame must all
//! surface as clean driver errors naming the worker, with no hang and
//! no orphan processes.
//!
//! The test harness executable has no `plan-worker` mode, so every
//! spawning test points `ProcessOptions::worker_cmd` (or the
//! `P3SAPP_WORKER_CMD` environment override used by the driver-level
//! test) at the built binary via `CARGO_BIN_EXE_repro`.

use p3sapp::corpus::{generate_corpus, CorpusSpec};
use p3sapp::driver::{run_p3sapp, DriverOptions};
use p3sapp::ingest::list_shards;
use p3sapp::pipeline::features::{HashingTF, Idf};
use p3sapp::pipeline::presets::{case_study_features_plan, case_study_plan};
use p3sapp::pipeline::stages::Tokenizer;
use p3sapp::plan::{LogicalPlan, ProcessOptions, RemoteOptions};
use std::path::PathBuf;
use std::time::Duration;

fn repro_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_repro"))
}

fn process_opts(processes: usize) -> ProcessOptions {
    ProcessOptions { processes, worker_cmd: Some(repro_bin()), ..Default::default() }
}

fn corpus(name: &str, seed: u64) -> (PathBuf, Vec<PathBuf>) {
    let dir =
        std::env::temp_dir().join(format!("p3sapp-procexec-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    generate_corpus(&CorpusSpec::tiny(seed), &dir).unwrap();
    let files = list_shards(&dir).unwrap();
    (dir, files)
}

#[test]
fn process_execution_is_byte_identical_to_the_fused_single_pass() {
    let (dir, files) = corpus("ident", 23);
    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let fused = plan.execute(2).unwrap();
    for processes in [2, 3] {
        let out = plan.execute_process(&process_opts(processes)).unwrap();
        assert_eq!(out.frame, fused.frame, "{processes} processes");
        assert_eq!(out.rows_ingested, fused.rows_ingested, "{processes} processes");
        assert_eq!(out.rows_out, fused.rows_out, "{processes} processes");
        assert_eq!(out.nulls_dropped, fused.nulls_dropped, "{processes} processes");
        assert_eq!(out.dups_dropped, fused.dups_dropped, "{processes} processes");
        assert_eq!(out.empties_dropped, fused.empties_dropped, "{processes} processes");
        assert_eq!(out.sampled_out, fused.sampled_out, "{processes} processes");
        assert_eq!(out.limited_out, fused.limited_out, "{processes} processes");
        assert!(out.times.total().as_secs_f64() > 0.0, "stage times must be attributed");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn featured_process_run_matches_in_process_two_pass() {
    // The case-study feature plan has Distinct before the estimator, so
    // pass 1 ships admitted partitions (driver-side Admitter fold).
    let (dir, files) = corpus("feat", 31);
    let plan = case_study_features_plan(&files, "title", "abstract").optimize();
    let fused = plan.execute(2).unwrap();
    let out = plan.execute_process(&process_opts(2)).unwrap();
    assert_eq!(out.frame, fused.frame);
    assert_eq!(out.rows_out, fused.rows_out);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn dedup_free_fit_uses_partials_and_still_matches() {
    // No Distinct/Limit before the estimator: pass 1 runs in partial
    // mode (workers fold their own accumulators, the driver merges
    // document-frequency partials). Output must still match the
    // in-process two-pass bit for bit.
    let (dir, files) = corpus("fitpartial", 47);
    let plan = LogicalPlan::scan(files.clone(), &["title", "abstract"])
        .drop_nulls(&["title", "abstract"])
        .transform(Tokenizer::new("abstract", "tokens"))
        .transform(HashingTF::new("tokens", "tf", 64))
        .fit(Idf::new("tf", "tfidf"))
        .collect();
    let fused = plan.execute(2).unwrap();
    assert!(fused.rows_out > 0);
    let out = plan.execute_process(&process_opts(2)).unwrap();
    assert_eq!(out.frame, fused.frame);
    assert_eq!(out.rows_out, fused.rows_out);

    // The remote executor's partial-fit pass ships one MODE_FIT frame
    // per endpoint (document-frequency partials, not partitions) over
    // loopback TCP and must land on the same bytes.
    let listeners: Vec<String> = (0..2)
        .map(|_| {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let ep = listener.local_addr().unwrap().to_string();
            std::thread::spawn(move || p3sapp::plan::remote::serve_listener(listener));
            ep
        })
        .collect();
    let ropts = RemoteOptions {
        endpoints: listeners,
        // Force the fetch-by-digest path in the fit pass too.
        inline_max_bytes: 1,
        ..Default::default()
    };
    let remoted = plan.execute_remote(&ropts).unwrap();
    assert_eq!(remoted.frame, fused.frame);
    assert_eq!(remoted.rows_out, fused.rows_out);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn fewer_shards_than_workers_delegates_to_the_single_pass() {
    let dir = std::env::temp_dir()
        .join(format!("p3sapp-procexec-scarce-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("only.json"),
        "{\"title\": \"solo title\", \"abstract\": \"plenty of words here\"}\n",
    )
    .unwrap();
    let files = list_shards(&dir).unwrap();
    assert_eq!(files.len(), 1);
    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let fused = plan.execute(2).unwrap();
    // 8 requested workers resolve to 1 (one shard) -> in-process
    // fallback; a bogus worker_cmd proves no process is ever spawned.
    let opts = ProcessOptions {
        processes: 8,
        worker_cmd: Some(PathBuf::from("/nonexistent/worker/binary")),
        ..Default::default()
    };
    let out = plan.execute_process(&opts).unwrap();
    assert_eq!(out.frame, fused.frame);
    let render = plan.lower().unwrap().render_process(&opts);
    assert!(render.contains("fallback"), "{render}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn driver_level_processes_option_works_via_env_worker_cmd() {
    // DriverOptions::processes resolves the worker binary from
    // P3SAPP_WORKER_CMD when no explicit worker_cmd is given — the hook
    // that makes `--processes` testable from a harness executable.
    std::env::set_var("P3SAPP_WORKER_CMD", repro_bin());
    let (dir, files) = corpus("driver", 13);
    let plain = run_p3sapp(&files, &DriverOptions { workers: 2, ..Default::default() }).unwrap();
    let processed = run_p3sapp(
        &files,
        &DriverOptions { workers: 2, processes: Some(2), ..Default::default() },
    )
    .unwrap();
    assert_eq!(processed.frame, plain.frame);
    assert_eq!(processed.rows_ingested, plain.rows_ingested);
    assert_eq!(processed.rows_out, plain.rows_out);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[cfg(unix)]
#[test]
fn worker_nonzero_exit_is_a_driver_error_naming_the_worker() {
    let (dir, files) = corpus("exit", 5);
    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let opts = ProcessOptions {
        processes: 2,
        worker_cmd: Some(PathBuf::from("/bin/false")),
        ..Default::default()
    };
    let err = plan.execute_process(&opts).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("plan worker"), "{msg}");
    assert!(msg.contains("failed"), "{msg}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[cfg(unix)]
#[test]
fn worker_emitting_a_garbled_frame_is_a_driver_error() {
    // /bin/echo ignores the job and prints its argument — a short,
    // digest-less frame the driver must reject cleanly.
    let (dir, files) = corpus("garble", 7);
    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let opts = ProcessOptions {
        processes: 2,
        worker_cmd: Some(PathBuf::from("/bin/echo")),
        ..Default::default()
    };
    let err = plan.execute_process(&opts).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("plan worker"), "{msg}");
    assert!(
        msg.contains("frame") || msg.contains("short") || msg.contains("magic"),
        "{msg}"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[cfg(unix)]
#[test]
fn worker_killed_mid_run_is_a_driver_error_not_a_hang() {
    use std::os::unix::fs::PermissionsExt;
    let (dir, files) = corpus("killed", 11);
    // A "worker" that drains its job, emits a partial frame, then kills
    // itself — simulating a crash mid-stream.
    let script = dir.join("dying-worker.sh");
    std::fs::write(
        &script,
        "#!/bin/sh\ncat > /dev/null\nprintf 'P3PW'\nkill -9 $$\n",
    )
    .unwrap();
    let mut perms = std::fs::metadata(&script).unwrap().permissions();
    perms.set_mode(0o755);
    std::fs::set_permissions(&script, perms).unwrap();

    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let opts = ProcessOptions { processes: 2, worker_cmd: Some(script), ..Default::default() };
    let err = plan.execute_process(&opts).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("plan worker"), "{msg}");
    assert!(msg.contains("signal") || msg.contains("failed"), "{msg}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn pooled_workers_persist_across_runs_and_match_the_single_pass() {
    // The serve daemon's warm pool: the same persistent `plan-worker
    // --persist` processes serve repeated jobs. Output must stay
    // byte-identical to the fused single pass, and the second run must
    // reuse the first run's workers (same pids), not respawn.
    let (dir, files) = corpus("pooled", 17);
    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let fused = plan.execute(2).unwrap();
    let pool = std::sync::Arc::new(p3sapp::plan::WorkerPool::new(repro_bin(), 2));
    let opts =
        ProcessOptions { processes: 2, pool: Some(pool.clone()), ..Default::default() };
    let first = plan.execute_process(&opts).unwrap();
    assert_eq!(first.frame, fused.frame);
    assert_eq!(first.rows_out, fused.rows_out);
    let pids = pool.pids();
    assert_eq!(pids.len(), 2, "both pool slots spawned lazily on first use");
    let second = plan.execute_process(&opts).unwrap();
    assert_eq!(second.frame, fused.frame);
    assert_eq!(pool.pids(), pids, "warm repeat reuses the same workers");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[cfg(unix)]
#[test]
fn pooled_worker_failure_names_the_pooled_worker_and_does_not_hang() {
    // A pool whose command dies immediately: the exchange must fail with
    // an error naming the pooled worker and its command — same contract
    // as the spawn-per-run failure paths above, no hang, no orphan.
    let (dir, files) = corpus("pooldead", 19);
    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let pool = std::sync::Arc::new(p3sapp::plan::WorkerPool::new("/bin/false", 2));
    let opts = ProcessOptions { processes: 2, pool: Some(pool), ..Default::default() };
    let err = plan.execute_process(&opts).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("pooled plan worker"), "{msg}");
    assert!(msg.contains("/bin/false"), "{msg}");
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Remote executor failure paths: every network failure mode must surface
// as a typed driver error naming the endpoint — never a hang. The fake
// "workers" here are plain TCP listeners misbehaving in controlled ways;
// the happy loopback paths live in plan_equivalence.rs.
// ---------------------------------------------------------------------------

fn remote_opts(eps: &[&str]) -> RemoteOptions {
    RemoteOptions {
        endpoints: eps.iter().map(|s| s.to_string()).collect(),
        connect_timeout: Duration::from_secs(2),
        io_timeout: Duration::from_secs(5),
        connect_retries: 1,
        retry_backoff: Duration::from_millis(10),
        ..Default::default()
    }
}

/// Read one length-prefixed frame off `s` raw — the fake workers
/// swallow the driver's job so the socket is drained before they
/// misbehave (a close with unread data would RST instead of FIN).
fn drain_frame(s: &mut std::net::TcpStream) {
    use std::io::Read;
    let mut len = [0u8; 8];
    s.read_exact(&mut len).unwrap();
    let mut body = vec![0u8; u64::from_le_bytes(len) as usize];
    s.read_exact(&mut body).unwrap();
}

#[test]
fn remote_connect_refused_is_a_typed_driver_error_after_retries() {
    // Bind then drop to find a port that refuses connections.
    let port = {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        listener.local_addr().unwrap().port()
    };
    let ep = format!("127.0.0.1:{port}");
    let (dir, files) = corpus("remote-refused", 37);
    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let err = plan.execute_remote(&remote_opts(&[&ep])).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains(&format!("remote worker {ep}")), "{msg}");
    assert!(msg.contains("connect failed after 2 attempts"), "{msg}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn remote_worker_dying_mid_stream_is_a_typed_driver_error() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let ep = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        // Swallow the job, then hang up without a single result frame.
        drain_frame(&mut s);
    });
    let (dir, files) = corpus("remote-midstream", 29);
    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let err = plan.execute_remote(&remote_opts(&[&ep])).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains(&format!("remote worker {ep}")), "{msg}");
    assert!(msg.contains("mid-stream"), "{msg}");
    assert!(msg.contains("0 of"), "{msg}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn remote_garbled_result_frame_is_a_driver_error_naming_the_endpoint() {
    use std::io::Write;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let ep = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        drain_frame(&mut s);
        // A well-framed reply whose body is garbage: wrong magic, no
        // digest. The driver must reject it, not misparse it.
        let garbage = [0x55u8; 32];
        s.write_all(&(garbage.len() as u64).to_le_bytes()).unwrap();
        s.write_all(&garbage).unwrap();
        s.flush().unwrap();
    });
    let (dir, files) = corpus("remote-garbled", 41);
    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let err = plan.execute_remote(&remote_opts(&[&ep])).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains(&format!("remote worker {ep}")), "{msg}");
    assert!(msg.contains("magic") || msg.contains("frame"), "{msg}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn remote_read_timeout_is_a_typed_driver_error_not_a_hang() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let ep = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        // Accept and then stall: never read the job, never reply.
        let (_s, _) = listener.accept().unwrap();
        std::thread::sleep(Duration::from_secs(20));
    });
    let (dir, files) = corpus("remote-stall", 43);
    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let mut opts = remote_opts(&[&ep]);
    opts.io_timeout = Duration::from_millis(400);
    let t0 = std::time::Instant::now();
    let err = plan.execute_remote(&opts).unwrap_err();
    assert!(t0.elapsed() < Duration::from_secs(10), "timed out far too late");
    let msg = format!("{err:#}");
    assert!(msg.contains(&format!("remote worker {ep}")), "{msg}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn remote_without_endpoints_is_a_typed_error_naming_the_flag() {
    let (dir, files) = corpus("remote-noeps", 53);
    let plan = case_study_plan(&files, "title", "abstract").optimize();
    let err = plan.execute_remote(&RemoteOptions::default()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("no endpoints"), "{msg}");
    assert!(msg.contains("--remote"), "{msg}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn explain_process_renders_the_real_topology() {
    let (dir, files) = corpus("explain", 3);
    let plan = case_study_plan(&files, "title", "abstract");
    let opts = ProcessOptions { processes: 2, ..Default::default() };
    let text = p3sapp::plan::explain_process(&plan, &opts).unwrap();
    assert!(text.contains("== Physical Plan (multi-process) =="), "{text}");
    assert!(text.contains("worker processes"), "{text}");
    assert!(text.contains("plan-worker"), "{text}");
    // Two-pass plans render the fit-fold mode in the schedule line.
    let featured = case_study_features_plan(&files, "title", "abstract");
    let text = p3sapp::plan::explain_process(&featured, &opts).unwrap();
    assert!(text.contains("TwoPass"), "{text}");
    assert!(text.contains("admitted partitions"), "{text}");
    std::fs::remove_dir_all(&dir).unwrap();
}
