//! Runtime integration tests: the Rust↔PJRT↔HLO-artifact path.
//! These require `make artifacts` (skipped with a clear message if the
//! artifacts directory is absent, e.g. in a docs-only checkout).

use p3sapp::corpus::{generate_corpus, CorpusSpec};
use p3sapp::driver::{run_p3sapp, DriverOptions};
use p3sapp::ingest::list_shards;
use p3sapp::runtime::{Generator, ModelManifest, Session, Trainer};
use p3sapp::vocab::{Batcher, Vocabulary};
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping runtime test: artifacts/ missing (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_matches_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let m = ModelManifest::load(dir).unwrap();
    assert!(m.config.vocab >= 4);
    assert_eq!(m.param_order.len(), m.n_tensors());
    // 3-layer stacked encoder per the paper.
    assert_eq!(m.config.enc_layers, 3);
    assert!(m.param_order.iter().any(|(n, _)| n == "enc_w_2"));
    for name in ["init", "train_step", "encode", "decode_step"] {
        assert!(dir.join(format!("{name}.hlo.txt")).exists(), "{name} artifact");
    }
}

#[test]
fn session_loads_and_inits_state() {
    let Some(dir) = artifacts_dir() else { return };
    let session = Session::cpu(dir).unwrap();
    assert_eq!(session.platform(), "cpu");
    let trainer = Trainer::new(session).unwrap();
    assert_eq!(trainer.params().len(), trainer.manifest.n_tensors());
    assert_eq!(trainer.step_count(), 0);
}

/// The headline runtime test: loss must fall over a real training run
/// driven entirely from Rust through PJRT, then inference must produce
/// tokens within the vocabulary.
#[test]
fn training_reduces_loss_and_inference_decodes() {
    let Some(dir) = artifacts_dir() else { return };

    // Small corpus through the real pipeline.
    let cdir = std::env::temp_dir().join(format!("p3sapp-rt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cdir);
    let mut spec = CorpusSpec::tiny(77);
    spec.n_records = 400;
    generate_corpus(&spec, &cdir).unwrap();
    let pre = run_p3sapp(&list_shards(&cdir).unwrap(), &DriverOptions::default()).unwrap();

    let session = Session::cpu(dir).unwrap();
    let mut trainer = Trainer::new(session).unwrap();
    let cfg = trainer.manifest.config.clone();
    let frame = pre.frame;
    let texts: Vec<&str> = (0..frame.num_rows())
        .flat_map(|i| {
            [
                frame.column(0).get_str(i).unwrap_or(""),
                frame.column(1).get_str(i).unwrap_or(""),
            ]
        })
        .collect();
    let vocab = Vocabulary::build(texts.into_iter(), cfg.vocab);
    let mut batcher = Batcher::new(
        &frame, &vocab, "title", "abstract", cfg.batch, cfg.src_len, cfg.tgt_len, 1,
    )
    .unwrap();

    let stats = trainer.train_loop(12, || batcher.next_batch()).unwrap();
    let first = stats.first().unwrap().loss;
    let last = stats.last().unwrap().loss;
    assert!(last < first, "loss {first} -> {last} must fall");
    assert!(first.is_finite() && last > 0.0);
    assert_eq!(trainer.step_count(), 12);

    // Inference on the trained params.
    let generator = Generator::from_trainer(trainer).unwrap();
    let abs = frame.column(1).get_str(0).unwrap();
    let (src, mask) = vocab.encode_src(abs, cfg.src_len);
    let gen = generator.generate_ids(&src, &mask).unwrap();
    assert!(gen.token_ids.len() <= cfg.tgt_len);
    for id in &gen.token_ids {
        assert!((*id as usize) < vocab.len(), "generated id {id} out of vocab");
    }
    assert!(gen.wall_secs < 5.0, "t_mi {} too slow", gen.wall_secs);
    std::fs::remove_dir_all(&cdir).unwrap();
}

#[test]
fn generator_rejects_bad_geometry() {
    let Some(dir) = artifacts_dir() else { return };
    let session = Session::cpu(dir).unwrap();
    let trainer = Trainer::new(session).unwrap();
    let generator = Generator::from_trainer(trainer).unwrap();
    let err = generator.generate_ids(&[1, 2, 3], &[1.0, 1.0, 1.0]).unwrap_err();
    assert!(err.to_string().contains("src_len"), "{err}");
}

#[test]
fn trainer_rejects_bad_batch_geometry() {
    let Some(dir) = artifacts_dir() else { return };
    let session = Session::cpu(dir).unwrap();
    let mut trainer = Trainer::new(session).unwrap();
    let bad = p3sapp::vocab::EncodedBatch {
        src: vec![0; 4],
        src_mask: vec![1.0; 4],
        tgt_in: vec![0; 2],
        tgt_out: vec![0; 2],
        tgt_mask: vec![1.0; 2],
        batch: 2,
        src_len: 2,
        tgt_len: 1,
    };
    let err = trainer.train_step(&bad).unwrap_err();
    assert!(err.to_string().contains("geometry"), "{err}");
}

#[test]
fn beam_search_matches_greedy_at_width_one() {
    let Some(dir) = artifacts_dir() else { return };
    let session = Session::cpu(dir).unwrap();
    let trainer = Trainer::new(session).unwrap();
    let cfg = trainer.manifest.config.clone();
    let generator = Generator::from_trainer(trainer).unwrap();
    let src = vec![7i32; cfg.src_len];
    let mask = vec![1.0f32; cfg.src_len];
    let greedy = generator.generate_ids(&src, &mask).unwrap();
    let beam1 = generator.generate_ids_beam(&src, &mask, 1).unwrap();
    assert_eq!(greedy.token_ids, beam1.token_ids);
    // Wider beam returns a valid (possibly different) sequence.
    let beam3 = generator.generate_ids_beam(&src, &mask, 3).unwrap();
    assert!(beam3.token_ids.len() <= cfg.tgt_len);
    assert!(generator.generate_ids_beam(&src, &mask, 0).is_err());
}
