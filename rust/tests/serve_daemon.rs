//! Black-box tests for the preprocessing daemon (`repro serve`).
//!
//! Every test spawns the *built binary* (`CARGO_BIN_EXE_repro serve
//! start`) as a real OS process and talks to it over its Unix socket
//! with real clients — nothing here reaches into daemon internals. The
//! pinned contracts:
//!
//! - a warm repeat of an identical job restores from the daemon's live
//!   cache (the reply reports a `cache_restore` stage) and its frame is
//!   byte-identical to a one-shot in-process run;
//! - N concurrent clients all complete, each byte-identical to the
//!   one-shot result;
//! - shutdown is clean: the pool's persistent workers are reaped (no
//!   orphans) and the socket file is removed;
//! - failure semantics mirror `process_executor.rs`: a garbled or
//!   truncated frame, a queue-full or over-budget submission, and a
//!   client that disconnects mid-job each produce a typed reply naming
//!   the cause (or a log line) — never a daemon crash or hang;
//! - observability is part of the wire contract: stats replies carry
//!   *typed* cache counters (no string parsing), and the metrics
//!   request returns a Prometheus-style exposition with per-job
//!   latency histograms.

#![cfg(unix)]

use p3sapp::corpus::{generate_corpus, CorpusSpec};
use p3sapp::driver::{run_p3sapp, DriverOptions};
use p3sapp::ingest::list_shards;
use p3sapp::serve::proto::{encode_request, read_frame, write_frame};
use p3sapp::serve::{request, ErrKind, JobSpec, Reply, Request};
use std::io::Write;
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

fn repro_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_repro"))
}

/// Per-test scratch root: corpus shards, socket, cache dir.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("p3sapp-serve-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn corpus(root: &PathBuf, seed: u64) -> (PathBuf, Vec<PathBuf>) {
    let dir = root.join("corpus");
    generate_corpus(&CorpusSpec::tiny(seed), &dir).unwrap();
    let files = list_shards(&dir).unwrap();
    (dir, files)
}

/// A running daemon process; Drop shuts it down (politely, then by
/// force) so a failing test cannot leak daemons.
struct DaemonGuard {
    child: Child,
    socket: PathBuf,
}

impl DaemonGuard {
    /// Spawn `repro serve start --socket <root>/serve.sock <extra...>`
    /// and wait for the socket to accept connections.
    fn start(root: &PathBuf, extra: &[&str]) -> DaemonGuard {
        let socket = root.join("serve.sock");
        let child = Command::new(repro_bin())
            .arg("serve")
            .arg("start")
            .arg("--socket")
            .arg(&socket)
            .args(extra)
            .stdin(Stdio::null())
            .spawn()
            .expect("spawn serve daemon");
        let mut guard = DaemonGuard { child, socket };
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            if guard.socket.exists() && UnixStream::connect(&guard.socket).is_ok() {
                break;
            }
            if let Some(status) = guard.child.try_wait().unwrap() {
                panic!("daemon exited before listening: {status}");
            }
            assert!(Instant::now() < deadline, "daemon never started listening");
            std::thread::sleep(Duration::from_millis(20));
        }
        guard
    }

    /// Ask the daemon to stop and wait for the process to exit.
    fn shutdown(mut self) {
        let reply = request(&self.socket, &Request::Shutdown).expect("shutdown request");
        assert!(matches!(reply, Reply::Ok), "shutdown must ack: {reply:?}");
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            if self.child.try_wait().unwrap().is_some() {
                // Forget the child so Drop does not kill a reaped pid.
                self.child.stdin = None;
                std::mem::forget(self);
                return;
            }
            assert!(Instant::now() < deadline, "daemon did not exit after shutdown");
            std::thread::sleep(Duration::from_millis(20));
        }
    }
}

impl Drop for DaemonGuard {
    fn drop(&mut self) {
        let _ = request(&self.socket, &Request::Shutdown);
        let deadline = Instant::now() + Duration::from_secs(5);
        while Instant::now() < deadline {
            if self.child.try_wait().unwrap().is_some() {
                return;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn job(dir: &PathBuf) -> JobSpec {
    JobSpec { dir: dir.clone(), workers: 2, ..Default::default() }
}

/// The one-shot reference run the served replies must match bit for
/// bit: same driver, same options, no daemon.
fn oneshot(files: &[PathBuf]) -> p3sapp::driver::PreprocessResult {
    run_p3sapp(files, &DriverOptions { workers: 2, ..Default::default() }).unwrap()
}

#[test]
fn warm_repeat_restores_from_cache_and_matches_the_oneshot_run() {
    let root = scratch("warm");
    let (dir, files) = corpus(&root, 29);
    let daemon = DaemonGuard::start(&root, &[]);
    let expected = oneshot(&files);

    let cold = match request(&daemon.socket, &Request::Preprocess(job(&dir))).unwrap() {
        Reply::Preprocess(p) => p,
        other => panic!("expected a preprocess reply, got {other:?}"),
    };
    assert!(!cold.from_cache(), "first job must execute, not restore");
    assert_eq!(cold.frame().unwrap(), expected.frame, "cold serve != one-shot");
    assert_eq!(cold.rows_out as usize, expected.rows_out);

    let warm = match request(&daemon.socket, &Request::Preprocess(job(&dir))).unwrap() {
        Reply::Preprocess(p) => p,
        other => panic!("expected a preprocess reply, got {other:?}"),
    };
    assert!(
        warm.stages.iter().any(|(s, _)| s == "cache_restore"),
        "warm repeat must report its cache_restore stage: {:?}",
        warm.stages
    );
    assert_eq!(warm.frame().unwrap(), expected.frame, "warm serve != one-shot");

    daemon.shutdown();
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn concurrent_clients_all_complete_byte_identical() {
    let root = scratch("concurrent");
    let (dir, files) = corpus(&root, 37);
    let daemon = DaemonGuard::start(&root, &["--max-active", "2", "--max-queue", "8"]);
    let expected = oneshot(&files);

    let replies: Vec<Reply> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let socket = daemon.socket.clone();
                let spec = job(&dir);
                scope.spawn(move || request(&socket, &Request::Preprocess(spec)).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(replies.len(), 4);
    for reply in replies {
        match reply {
            Reply::Preprocess(p) => {
                assert_eq!(p.frame().unwrap(), expected.frame, "served frame != one-shot")
            }
            other => panic!("expected a preprocess reply, got {other:?}"),
        }
    }

    daemon.shutdown();
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn shutdown_reaps_pool_workers_and_removes_the_socket() {
    let root = scratch("shutdown");
    let (dir, _files) = corpus(&root, 41);
    let daemon = DaemonGuard::start(&root, &["--processes", "2"]);

    // Run one job so the lazy pool actually spawns its workers.
    match request(&daemon.socket, &Request::Preprocess(job(&dir))).unwrap() {
        Reply::Preprocess(_) => {}
        other => panic!("expected a preprocess reply, got {other:?}"),
    }
    let pids = match request(&daemon.socket, &Request::Stats).unwrap() {
        Reply::Stats(s) => s.worker_pids,
        other => panic!("expected stats, got {other:?}"),
    };
    assert_eq!(pids.len(), 2, "both pool workers should be live");

    let socket = daemon.socket.clone();
    daemon.shutdown();
    assert!(!socket.exists(), "socket file must be removed on clean shutdown");
    // The daemon reaps its pool before exiting, so by now every worker
    // pid must be gone (poll briefly for kernel bookkeeping).
    #[cfg(target_os = "linux")]
    for pid in pids {
        let proc_dir = PathBuf::from(format!("/proc/{pid}"));
        let deadline = Instant::now() + Duration::from_secs(5);
        while proc_dir.exists() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(!proc_dir.exists(), "worker {pid} was orphaned by shutdown");
    }
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn garbled_frame_gets_a_typed_bad_request_and_the_daemon_survives() {
    let root = scratch("garbled");
    let (dir, _files) = corpus(&root, 43);
    let daemon = DaemonGuard::start(&root, &[]);

    // A well-framed envelope of garbage: long enough to pass the length
    // check, wrong magic, wrong digest.
    let mut stream = UnixStream::connect(&daemon.socket).unwrap();
    write_frame(&mut stream, &[0xAB; 64]).unwrap();
    match read_frame(&mut stream).unwrap() {
        Some(frame) => match p3sapp::serve::proto::decode_reply(&frame).unwrap() {
            Reply::Err(e) => {
                assert_eq!(e.kind, ErrKind::BadRequest, "{e:?}");
            }
            other => panic!("expected a typed error, got {other:?}"),
        },
        None => panic!("daemon hung up instead of replying bad_request"),
    }
    drop(stream);

    // The daemon is still serving real work.
    match request(&daemon.socket, &Request::Preprocess(job(&dir))).unwrap() {
        Reply::Preprocess(_) => {}
        other => panic!("daemon should still serve after a garbled frame: {other:?}"),
    }
    daemon.shutdown();
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn truncated_frame_is_rejected_not_hung() {
    let root = scratch("truncated");
    let (dir, _files) = corpus(&root, 47);
    let daemon = DaemonGuard::start(&root, &[]);

    // Announce a 64-byte frame, deliver 5 bytes, half-close: the daemon
    // must see the truncation and reply bad_request, not wait forever.
    let mut stream = UnixStream::connect(&daemon.socket).unwrap();
    stream.write_all(&64u64.to_le_bytes()).unwrap();
    stream.write_all(b"short").unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    match read_frame(&mut stream).unwrap() {
        Some(frame) => match p3sapp::serve::proto::decode_reply(&frame).unwrap() {
            Reply::Err(e) => assert_eq!(e.kind, ErrKind::BadRequest, "{e:?}"),
            other => panic!("expected a typed error, got {other:?}"),
        },
        None => panic!("daemon hung up instead of replying bad_request"),
    }
    drop(stream);

    match request(&daemon.socket, &Request::Preprocess(job(&dir))).unwrap() {
        Reply::Preprocess(_) => {}
        other => panic!("daemon should still serve after a truncated frame: {other:?}"),
    }
    daemon.shutdown();
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn client_disconnect_mid_job_leaves_the_daemon_serving() {
    let root = scratch("disconnect");
    let (dir, _files) = corpus(&root, 53);
    let daemon = DaemonGuard::start(&root, &[]);

    // Submit a deliberately slow job and hang up before the reply.
    let mut spec = job(&dir);
    spec.linger_millis = 300;
    let mut stream = UnixStream::connect(&daemon.socket).unwrap();
    write_frame(&mut stream, &encode_request(&Request::Preprocess(spec))).unwrap();
    drop(stream);

    // The abandoned job must cost the daemon nothing but a log line:
    // its permit is released when it finishes, nothing stays queued.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match request(&daemon.socket, &Request::Stats).unwrap() {
            Reply::Stats(s) if (s.active, s.queued) == (0, 0) => break,
            Reply::Stats(_) => {}
            other => panic!("expected stats, got {other:?}"),
        }
        assert!(Instant::now() < deadline, "abandoned job leaked its permit");
        std::thread::sleep(Duration::from_millis(20));
    }
    match request(&daemon.socket, &Request::Preprocess(job(&dir))).unwrap() {
        Reply::Preprocess(_) => {}
        other => panic!("daemon should still serve after a disconnect: {other:?}"),
    }
    daemon.shutdown();
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn queue_full_submission_gets_a_typed_rejection() {
    let root = scratch("queuefull");
    let (dir, _files) = corpus(&root, 59);
    // One permit, zero queue slots: the second concurrent job must be
    // turned away, typed, immediately.
    let daemon = DaemonGuard::start(&root, &["--max-active", "1", "--max-queue", "0"]);

    let socket = daemon.socket.clone();
    let mut slow = job(&dir);
    slow.linger_millis = 2000;
    let holder =
        std::thread::spawn(move || request(&socket, &Request::Preprocess(slow)).unwrap());
    // Stats is not admission-gated, so it is the synchronization channel:
    // wait until the slow job visibly holds the permit.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        match request(&daemon.socket, &Request::Stats).unwrap() {
            Reply::Stats(s) if s.active == 1 => break,
            Reply::Stats(_) => {}
            other => panic!("expected stats, got {other:?}"),
        }
        assert!(Instant::now() < deadline, "slow job never took the permit");
        std::thread::sleep(Duration::from_millis(10));
    }

    match request(&daemon.socket, &Request::Preprocess(job(&dir))).unwrap() {
        Reply::Err(e) => {
            assert_eq!(e.kind, ErrKind::QueueFull, "{e:?}");
            assert_eq!(e.kind.name(), "queue_full");
            assert!(e.message.contains("queue"), "{}", e.message);
        }
        other => panic!("expected a queue_full rejection, got {other:?}"),
    }
    // The admitted job still completes normally.
    match holder.join().unwrap() {
        Reply::Preprocess(_) => {}
        other => panic!("the admitted job should finish: {other:?}"),
    }
    daemon.shutdown();
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn over_budget_submission_gets_a_typed_rejection() {
    let root = scratch("budget");
    let (dir, files) = corpus(&root, 61);
    let shard_bytes: u64 =
        files.iter().map(|f| std::fs::metadata(f).unwrap().len()).sum();
    assert!(shard_bytes > 1, "corpus must exceed the 1-byte budget");
    let daemon = DaemonGuard::start(&root, &["--job-budget-bytes", "1"]);

    match request(&daemon.socket, &Request::Preprocess(job(&dir))).unwrap() {
        Reply::Err(e) => {
            assert_eq!(e.kind, ErrKind::OverBudget, "{e:?}");
            assert_eq!(e.kind.name(), "over_budget");
            assert!(e.message.contains("budget"), "{}", e.message);
        }
        other => panic!("expected an over_budget rejection, got {other:?}"),
    }
    daemon.shutdown();
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn stats_counters_are_typed_and_metrics_exposition_scrapes() {
    let root = scratch("metrics");
    let (dir, _files) = corpus(&root, 71);
    let daemon = DaemonGuard::start(&root, &[]);

    // Cold then warm: exactly one miss+store, then at least one hit.
    for _ in 0..2 {
        match request(&daemon.socket, &Request::Preprocess(job(&dir))).unwrap() {
            Reply::Preprocess(_) => {}
            other => panic!("expected a preprocess reply, got {other:?}"),
        }
    }
    let stats = match request(&daemon.socket, &Request::Stats).unwrap() {
        Reply::Stats(s) => s,
        other => panic!("expected stats, got {other:?}"),
    };
    // The counters arrive as numbers, not a pre-formatted string.
    let c = stats.cache.expect("the daemon runs with a cache by default");
    assert_eq!(c.stores, 1, "cold job stores exactly once: {c:?}");
    assert!(c.misses >= 1, "cold job must miss first: {c:?}");
    assert!(c.mem_hits + c.disk_hits >= 1, "warm job must hit: {c:?}");

    let text = match request(&daemon.socket, &Request::Metrics).unwrap() {
        Reply::Text(t) => t,
        other => panic!("expected a metrics exposition, got {other:?}"),
    };
    // Counters: the job count and the mirrored live cache stats.
    assert!(text.contains("# TYPE p3sapp_serve_jobs_total counter\n"), "{text}");
    assert!(text.contains("p3sapp_serve_jobs_total 2\n"), "{text}");
    assert!(text.contains("p3sapp_cache_stores_total 1\n"), "{text}");
    assert!(text.contains("p3sapp_plan_rows_out_total"), "{text}");
    // Gauges: admission depth is idle at scrape time.
    assert!(text.contains("# TYPE p3sapp_admission_active gauge\n"), "{text}");
    assert!(text.contains("p3sapp_admission_active 0\n"), "{text}");
    // Histograms: one series per latency leg, cumulative buckets with
    // the +Inf bucket equal to the observation count.
    for series in ["p3sapp_serve_job_queue_wait_us", "p3sapp_serve_job_execute_us"] {
        assert!(text.contains(&format!("# TYPE {series} histogram\n")), "{text}");
        assert!(text.contains(&format!("{series}_count 2\n")), "{text}");
        assert!(text.contains(&format!("{series}_bucket{{le=\"+Inf\"}} 2\n")), "{text}");
    }
    // Only the warm job restored from cache.
    assert!(text.contains("p3sapp_serve_job_cache_restore_us_count 1\n"), "{text}");

    daemon.shutdown();
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn explain_over_the_socket_renders_the_warm_restore_path() {
    let root = scratch("explain");
    let (dir, _files) = corpus(&root, 67);
    let daemon = DaemonGuard::start(&root, &[]);

    let cold = match request(&daemon.socket, &Request::Explain(job(&dir))).unwrap() {
        Reply::Text(t) => t,
        other => panic!("expected an explain render, got {other:?}"),
    };
    assert!(cold.contains("== Physical Plan"), "{cold}");
    assert!(!cold.contains("cache hit"), "cold explain must not claim a hit: {cold}");

    match request(&daemon.socket, &Request::Preprocess(job(&dir))).unwrap() {
        Reply::Preprocess(_) => {}
        other => panic!("expected a preprocess reply, got {other:?}"),
    }
    let warm = match request(&daemon.socket, &Request::Explain(job(&dir))).unwrap() {
        Reply::Text(t) => t,
        other => panic!("expected an explain render, got {other:?}"),
    };
    assert!(warm.contains("cache hit"), "warm explain must render the restore: {warm}");
    assert!(warm.contains("CacheRestore"), "{warm}");

    daemon.shutdown();
    std::fs::remove_dir_all(&root).unwrap();
}
