//! Cross-module integration tests: corpus → ingestion → pipeline →
//! analysis, exercised through the public API only (no internals).

use p3sapp::analysis::accuracy::match_column;
use p3sapp::analysis::cost::{evaluate, CostInputs};
use p3sapp::analysis::trend::fit;
use p3sapp::corpus::{generate_corpus, CorpusSpec};
use p3sapp::driver::{run_ca, run_p3sapp, DriverOptions};
use p3sapp::frame::DType;
use p3sapp::ingest::{ingest_dir, ingest_dir_append, list_shards};
use p3sapp::pipeline::presets::{abstract_pipeline, title_pipeline};
use p3sapp::vocab::{Batcher, Vocabulary};
use std::path::PathBuf;

fn corpus(name: &str, spec: &CorpusSpec) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("p3sapp-it-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    generate_corpus(spec, &dir).unwrap();
    dir
}

#[test]
fn full_p3sapp_path_produces_model_ready_frame() {
    let dir = corpus("full", &CorpusSpec::tiny(1));
    let files = list_shards(&dir).unwrap();
    let res = run_p3sapp(&files, &DriverOptions::default()).unwrap();

    // Model-ready: both columns non-null, non-empty, lowercase, no HTML.
    let f = &res.frame;
    assert!(f.num_rows() > 100);
    for i in 0..f.num_rows() {
        for c in 0..2 {
            let v = f.column(c).get_str(i).expect("no nulls after post-clean");
            assert!(!v.is_empty());
            assert!(!v.contains('<') && !v.contains('>'), "HTML survived: {v}");
            assert_eq!(v, v.to_lowercase(), "casing survived: {v}");
            assert!(!v.chars().any(|ch| ch.is_ascii_digit()), "digits survived: {v}");
        }
    }
    // And batchable end-to-end.
    let texts: Vec<&str> = (0..f.num_rows())
        .flat_map(|i| [f.column(0).get_str(i).unwrap(), f.column(1).get_str(i).unwrap()])
        .collect();
    let vocab = Vocabulary::build(texts.into_iter(), 512);
    let mut batcher = Batcher::new(f, &vocab, "title", "abstract", 8, 16, 6, 3).unwrap();
    let b = batcher.next_batch();
    assert_eq!(b.src.len(), 8 * 16);
    assert!(b.src.iter().all(|&id| id >= 0 && (id as usize) < vocab.len()));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn ca_and_p3sapp_equivalence_over_seeds() {
    // The paper's accuracy experiment across several corpus seeds: our
    // unified substrates must agree exactly (see EXPERIMENTS.md E4 for
    // why the paper's 93-98% becomes 100% here).
    for seed in [3, 17, 92] {
        let dir = corpus(&format!("eq{seed}"), &CorpusSpec::tiny(seed));
        let files = list_shards(&dir).unwrap();
        let ca = run_ca(&files, &DriverOptions::default()).unwrap();
        let pa = run_p3sapp(&files, &DriverOptions::default()).unwrap();
        for col in ["title", "abstract"] {
            let m = match_column(&ca.frame, &pa.frame, col).unwrap();
            assert_eq!(m.percentage, 100.0, "seed {seed} col {col}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn duplicate_and_null_removal_visible_end_to_end() {
    let mut spec = CorpusSpec::tiny(5);
    spec.dup_rate = 0.2;
    spec.null_title_rate = 0.2;
    let dir = corpus("dedup", &spec);
    let files = list_shards(&dir).unwrap();
    let res = run_p3sapp(&files, &DriverOptions::default()).unwrap();
    // At least the injected dup/null fraction disappears.
    assert!(
        (res.rows_out as f64) < res.rows_ingested as f64 * 0.9,
        "{} -> {}",
        res.rows_ingested,
        res.rows_out
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn worker_count_does_not_change_output() {
    let dir = corpus("workers", &CorpusSpec::tiny(9));
    let files = list_shards(&dir).unwrap();
    let r1 = run_p3sapp(&files, &DriverOptions { workers: 1, ..Default::default() }).unwrap();
    let r4 = run_p3sapp(&files, &DriverOptions { workers: 4, ..Default::default() }).unwrap();
    assert_eq!(r1.frame, r4.frame);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn ingestion_paths_agree_and_project_nulls() {
    let dir = corpus("ingest", &CorpusSpec::tiny(11));
    let seq = ingest_dir_append(&dir, &["title", "abstract"]).unwrap();
    let par = ingest_dir(&dir, &["title", "abstract"], 3).unwrap();
    assert_eq!(par.schema().dtype_of("title"), Some(DType::Str));
    assert_eq!(seq, par.collect());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn pipelines_compose_with_tokenizer_downstream() {
    // abstract pipeline then Tokenizer on its output: schema evolves
    // string -> array<string> and survives a parallel transform.
    use p3sapp::pipeline::stages::{StopWordsRemover, Tokenizer};
    use p3sapp::pipeline::Pipeline;

    let dir = corpus("compose", &CorpusSpec::tiny(13));
    let frame = ingest_dir(&dir, &["title", "abstract"], 2).unwrap();
    let (frame, _) = p3sapp::frame::drop_nulls_par(frame, &["title", "abstract"], 2).unwrap();

    let cleaned = abstract_pipeline("abstract")
        .fit(&frame)
        .unwrap()
        .transform(frame, 2)
        .unwrap();
    let tok = Pipeline::new()
        .stage(Tokenizer::new("abstract", "words"))
        .stage(StopWordsRemover::new("words", "words"));
    let out = tok.fit(&cleaned).unwrap().transform(cleaned, 2).unwrap();
    assert_eq!(out.schema().dtype_of("words"), Some(DType::Tokens));
    let local = out.collect();
    let widx = local.column_index("words").unwrap();
    let mut saw_tokens = false;
    for i in 0..local.num_rows() {
        if let Some(toks) = local.column(widx).get_tokens(i) {
            saw_tokens |= !toks.is_empty();
            for t in toks {
                assert!(!p3sapp::textutil::is_stopword(t), "stopword survived: {t}");
            }
        }
    }
    assert!(saw_tokens);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn title_pipeline_preserves_stopwords_abstract_removes_them() {
    let dir = corpus("presets", &CorpusSpec::tiny(21));
    let frame = ingest_dir(&dir, &["title", "abstract"], 2).unwrap();
    let (frame, _) = p3sapp::frame::drop_nulls_par(frame, &["title", "abstract"], 2).unwrap();
    let t = title_pipeline("title").fit(&frame).unwrap().transform(frame, 2).unwrap();
    let local = t.collect();
    // Generated titles contain connectives like "of"/"the" — the title
    // recipe must keep them (they're the model target).
    let mut kept_stopword = false;
    for i in 0..local.num_rows() {
        if let Some(v) = local.column(0).get_str(i) {
            kept_stopword |= v.split_whitespace().any(p3sapp::textutil::is_stopword);
        }
    }
    assert!(kept_stopword, "title pipeline must not remove stopwords");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn timing_feeds_cost_model_coherently() {
    let dir = corpus("cost", &CorpusSpec::tiny(33));
    let files = list_shards(&dir).unwrap();
    let ca = run_ca(&files, &DriverOptions::default()).unwrap();
    let pa = run_p3sapp(&files, &DriverOptions::default()).unwrap();
    let inputs = CostInputs {
        tc_ca_secs: ca.cumulative_secs(),
        tc_p3sapp_secs: pa.cumulative_secs(),
        mtt_per_epoch_secs: 10.0,
    };
    let r = evaluate(&inputs, 10);
    assert!(r.total_ca_hours > 0.0 && r.total_p3sapp_hours > 0.0);
    assert!(r.cost_benefit_pct.abs() <= 100.0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn trend_fit_on_measured_series_is_sane() {
    // 3 growing corpora; P3SAPP preprocessing time should fit a line
    // with non-negative slope and decent R².
    let mut pts = Vec::new();
    for (i, records) in [200usize, 500, 900].into_iter().enumerate() {
        let mut spec = CorpusSpec::tiny(40 + i as u64);
        spec.n_records = records;
        let dir = corpus(&format!("trend{i}"), &spec);
        let files = list_shards(&dir).unwrap();
        let pa = run_p3sapp(&files, &DriverOptions::default()).unwrap();
        pts.push((records as f64, pa.preprocessing_secs()));
        std::fs::remove_dir_all(&dir).unwrap();
    }
    let line = fit(&pts).unwrap();
    assert!(line.slope >= 0.0, "{line:?}");
}
