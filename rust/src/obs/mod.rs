//! Unified observability: span tracing, a metrics registry, and the
//! EXPLAIN ANALYZE aggregation — dependency-free, threaded through
//! every executor.
//!
//! The paper's whole evaluation is stage-level wall time, but after the
//! executor tiers grew (fused single pass, streaming reader/worker
//! split, multi-process workers, the serve daemon) the timing story was
//! fragmented: `metrics::StageTimes` on the driver, raw phase nanos in
//! `P3PW` frames, cache counters on `CacheManager`, a pre-formatted
//! string in the serve stats reply. This module is the one place that
//! can answer *"where did this job's time go, across threads, worker
//! processes, and the daemon"*:
//!
//! - [`trace`] — a process-global [`trace::TraceSink`] records spans
//!   (name, category, lane, monotonic start/dur nanos relative to the
//!   sink's epoch). Spans are recorded from the driver, the streaming
//!   executor's reader and worker threads, the fused executor's pool
//!   threads, and — via a span section in the `P3PW` reply frame —
//!   from inside `plan-worker` processes, clock-aligned to the
//!   driver-side RPC anchor. When no sink is installed every tracing
//!   call is a single relaxed atomic load returning an inert guard, so
//!   executor outputs stay byte-identical and the overhead gate
//!   (`BENCH_obs.json`, ≤5%) holds.
//! - [`chrome`] — renders recorded spans as one Chrome-trace-event
//!   JSON document (`--trace <path>`), loadable in Perfetto or
//!   `chrome://tracing`, with driver / reader / worker-thread /
//!   worker-process lanes in a single timeline.
//! - [`metrics`] — a process-global registry of counters, gauges and
//!   log₂-bucketed histograms with Prometheus-style text exposition;
//!   the serve daemon's `metrics` request scrapes it (admission depth,
//!   pool health, cache counters, per-job queue-wait / execute /
//!   cache-restore latency histograms).
//! - [`analyze`] — folds the per-op spans (category `"op"`, keyed by
//!   op index with `rows_in`/`rows_out` args) into the per-op actuals
//!   that `explain --analyze` renders next to the plan topology.

pub mod analyze;
pub mod chrome;
pub mod metrics;
pub mod trace;

pub use analyze::{aggregate_ops, OpStats};
pub use chrome::chrome_trace_json;
pub use metrics::{registry, Registry};
pub use trace::{
    enabled, install, install_new, lane_reader, lane_scope, lane_worker_process,
    lane_worker_thread, now_ns, pool_lane, record_remote, set_lane, span, uninstall, Lane, Span,
    SpanGuard, TraceSink, LANE_DRIVER,
};
