//! Span tracing: a process-global [`TraceSink`] plus the lane model
//! that places every span on one row of the cross-process timeline.
//!
//! **Lanes.** A [`Lane`] is a `(pid, tid)` pair in *trace* coordinates,
//! not OS ids: the driver process is pid 0 (tid 0 = the driver thread,
//! tids 100+ = streaming reader threads, tids 200+ = cleaning pool
//! threads) and worker OS process `w` is pid `1 + w`. Worker-side spans
//! are recorded against the worker's own epoch and shipped back in the
//! `P3PW` reply; [`record_remote`] re-anchors them onto the driver
//! timeline (adding the driver-side RPC start) and rewrites their pid —
//! so a worker span always nests inside the driver's `rpc worker w`
//! span on the same lane.
//!
//! **Cost when off.** [`span`] is the only call sites pay: one relaxed
//! atomic load, then an inert guard whose `arg`/`Drop` do nothing. Hot
//! paths guard any argument *computation* behind
//! [`SpanGuard::active`]. Executor outputs are byte-identical with
//! tracing on or off — spans observe, never steer.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// One row of the timeline, in trace coordinates (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Lane {
    pub pid: u32,
    pub tid: u32,
}

/// The driver thread of the driver process.
pub const LANE_DRIVER: Lane = Lane { pid: 0, tid: 0 };

/// First tid of the streaming executor's reader threads.
pub const READER_TID_BASE: u32 = 100;

/// First tid of in-process cleaning/worker threads (streaming consumer
/// pool and the fused executor's thread pool).
pub const WORKER_TID_BASE: u32 = 200;

/// Lane of streaming reader thread `k`.
pub fn lane_reader(k: usize) -> Lane {
    Lane { pid: 0, tid: READER_TID_BASE + k as u32 }
}

/// Lane of in-process worker thread `k`.
pub fn lane_worker_thread(k: usize) -> Lane {
    Lane { pid: 0, tid: WORKER_TID_BASE + k as u32 }
}

/// Lane of worker OS process `w` (its main thread).
pub fn lane_worker_process(w: usize) -> Lane {
    Lane { pid: 1 + w as u32, tid: 0 }
}

/// One recorded span. `start_ns`/`dur_ns` are nanoseconds relative to
/// the recording sink's epoch (monotonic, never wall-clock); args are
/// small numeric annotations (`op` index, `shard`, `rows_in`, ...)
/// that survive the wire round-trip from worker processes.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub name: String,
    pub cat: String,
    pub lane: Lane,
    pub start_ns: u64,
    pub dur_ns: u64,
    pub args: Vec<(String, u64)>,
}

/// Collects spans against a fixed monotonic epoch. Install one globally
/// with [`install`]/[`install_new`]; worker processes install a fresh
/// sink per traced job and drain it into the reply frame.
#[derive(Debug)]
pub struct TraceSink {
    epoch: Instant,
    spans: Mutex<Vec<Span>>,
}

impl TraceSink {
    pub fn new() -> TraceSink {
        TraceSink { epoch: Instant::now(), spans: Mutex::new(Vec::new()) }
    }

    /// Nanoseconds since this sink's epoch.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn push(&self, span: Span) {
        self.spans.lock().unwrap().push(span);
    }

    /// Take every recorded span, leaving the sink empty.
    pub fn drain(&self) -> Vec<Span> {
        std::mem::take(&mut *self.spans.lock().unwrap())
    }

    /// Copy of the recorded spans (the sink keeps them) — used by
    /// `explain --analyze` when a `--trace` sink is already installed
    /// and must stay installed for the file write.
    pub fn snapshot(&self) -> Vec<Span> {
        self.spans.lock().unwrap().clone()
    }
}

impl Default for TraceSink {
    fn default() -> Self {
        TraceSink::new()
    }
}

// The fast-path flag (relaxed load in `enabled`) and the sink slot it
// guards. ACTIVE is only ever flipped together with the slot, so a true
// load may race a concurrent uninstall — `sink()` re-checks the slot.
static ACTIVE: AtomicBool = AtomicBool::new(false);

fn slot() -> &'static Mutex<Option<Arc<TraceSink>>> {
    static SLOT: OnceLock<Mutex<Option<Arc<TraceSink>>>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(None))
}

/// Install `sink` as the process-global trace sink.
pub fn install(sink: Arc<TraceSink>) {
    *slot().lock().unwrap() = Some(sink);
    ACTIVE.store(true, Ordering::Release);
}

/// Create, install and return a fresh sink (epoch = now).
pub fn install_new() -> Arc<TraceSink> {
    let sink = Arc::new(TraceSink::new());
    install(sink.clone());
    sink
}

/// Remove the global sink, returning it (with its recorded spans).
pub fn uninstall() -> Option<Arc<TraceSink>> {
    ACTIVE.store(false, Ordering::Release);
    slot().lock().unwrap().take()
}

/// Is a sink installed? One relaxed atomic load — the tracing-off fast
/// path every instrumented call site takes.
pub fn enabled() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

fn sink() -> Option<Arc<TraceSink>> {
    if !enabled() {
        return None;
    }
    slot().lock().unwrap().clone()
}

/// Nanoseconds since the installed sink's epoch (0 when tracing is
/// off). The process executor captures this as the per-worker RPC
/// anchor that [`record_remote`] aligns shipped spans to.
pub fn now_ns() -> u64 {
    sink().map(|s| s.now_ns()).unwrap_or(0)
}

thread_local! {
    static CURRENT_LANE: Cell<Lane> = const { Cell::new(LANE_DRIVER) };
    static POOL_LANE: Cell<Option<Lane>> = const { Cell::new(None) };
}

static NEXT_POOL_LANE: AtomicU32 = AtomicU32::new(0);

/// Set the current thread's lane (dedicated threads: streaming readers
/// and consumers set theirs once at spawn).
pub fn set_lane(lane: Lane) {
    CURRENT_LANE.with(|c| c.set(lane));
}

/// The lane new spans on this thread record against.
pub fn current_lane() -> Lane {
    CURRENT_LANE.with(|c| c.get())
}

/// RAII lane override: restores the previous lane on drop. Used where a
/// closure may run on a borrowed thread (the fused executor's pool, the
/// process executor's per-worker driver threads) so the driver lane is
/// never left reassigned.
pub struct LaneScope {
    prev: Lane,
}

pub fn lane_scope(lane: Lane) -> LaneScope {
    let prev = current_lane();
    set_lane(lane);
    LaneScope { prev }
}

impl Drop for LaneScope {
    fn drop(&mut self) {
        set_lane(self.prev);
    }
}

/// A stable worker-thread lane for the calling thread, assigned on
/// first use from a process-wide counter. The fused executor's pool
/// threads have no external index, so each thread claims the next
/// `WORKER_TID_BASE + k` lane the first time it runs a shard.
pub fn pool_lane() -> Lane {
    POOL_LANE.with(|c| match c.get() {
        Some(lane) => lane,
        None => {
            let k = NEXT_POOL_LANE.fetch_add(1, Ordering::Relaxed) as usize;
            let lane = lane_worker_thread(k);
            c.set(Some(lane));
            lane
        }
    })
}

struct LiveSpan {
    sink: Arc<TraceSink>,
    name: String,
    cat: &'static str,
    lane: Lane,
    start_ns: u64,
    args: Vec<(String, u64)>,
}

/// Records a span over its lifetime; inert (all methods no-ops) when no
/// sink is installed. Dropping records the span on the thread's current
/// lane at construction time.
pub struct SpanGuard {
    live: Option<LiveSpan>,
}

/// Open a span named `name` in category `cat` on the current thread's
/// lane. Hot paths should pass a `&'static str` name (no allocation on
/// the tracing-off path) and guard arg computation with
/// [`SpanGuard::active`].
pub fn span(name: impl Into<String>, cat: &'static str) -> SpanGuard {
    match sink() {
        None => SpanGuard { live: None },
        Some(sink) => {
            let start_ns = sink.now_ns();
            SpanGuard {
                live: Some(LiveSpan {
                    sink,
                    name: name.into(),
                    cat,
                    lane: current_lane(),
                    start_ns,
                    args: Vec::new(),
                }),
            }
        }
    }
}

impl SpanGuard {
    /// True when this guard will record (a sink is installed).
    pub fn active(&self) -> bool {
        self.live.is_some()
    }

    /// Attach a numeric annotation (no-op when inert).
    pub fn arg(&mut self, key: &str, value: u64) {
        if let Some(live) = &mut self.live {
            live.args.push((key.to_string(), value));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(live) = self.live.take() {
            let dur_ns = live.sink.now_ns().saturating_sub(live.start_ns);
            live.sink.push(Span {
                name: live.name,
                cat: live.cat.to_string(),
                lane: live.lane,
                start_ns: live.start_ns,
                dur_ns,
                args: live.args,
            });
        }
    }
}

/// Fold spans shipped back from worker process `w` into the installed
/// sink: their start is re-anchored by `anchor_ns` (the driver-side
/// instant the RPC to that worker began, in driver-epoch nanos) and
/// their pid is rewritten to the worker-process lane. The worker's own
/// epoch starts at job decode — at or after the anchor — and every
/// worker span ends before the reply is sent, so re-anchored spans
/// always nest inside the driver's `rpc worker w` span. No-op when
/// tracing is off.
pub fn record_remote(spans: Vec<Span>, worker: usize, anchor_ns: u64) {
    let Some(sink) = sink() else { return };
    for mut s in spans {
        s.lane.pid = 1 + worker as u32;
        s.start_ns = s.start_ns.saturating_add(anchor_ns);
        sink.push(s);
    }
}

/// The sink is process-global and `cargo test` runs lib tests on
/// parallel threads: every test (in any module) that installs one
/// serializes through this lock.
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_without_a_sink() {
        let _l = test_lock();
        uninstall();
        assert!(!enabled());
        let mut g = span("nothing", "test");
        assert!(!g.active());
        g.arg("ignored", 1); // must not panic or record anywhere
        drop(g);
        assert_eq!(now_ns(), 0);
    }

    #[test]
    fn spans_record_with_lane_args_and_monotonic_times() {
        let _l = test_lock();
        let _sink = install_new();
        {
            let mut outer = span("outer", "test");
            outer.arg("k", 7);
            std::thread::sleep(std::time::Duration::from_millis(2));
            let _inner = span("inner", "test");
        }
        let spans = uninstall().unwrap().drain();
        assert_eq!(spans.len(), 2);
        // Drop order: inner records first.
        let inner = &spans[0];
        let outer = &spans[1];
        assert_eq!((outer.name.as_str(), outer.cat.as_str()), ("outer", "test"));
        assert_eq!(outer.lane, LANE_DRIVER);
        assert_eq!(outer.args, vec![("k".to_string(), 7)]);
        assert!(outer.dur_ns >= 2_000_000, "{}", outer.dur_ns);
        // Proper nesting: inner starts at/after outer and ends at/before.
        assert!(inner.start_ns >= outer.start_ns);
        assert!(inner.start_ns + inner.dur_ns <= outer.start_ns + outer.dur_ns);
    }

    #[test]
    fn lane_scope_restores_and_pool_lanes_are_stable() {
        let _l = test_lock();
        assert_eq!(current_lane(), LANE_DRIVER);
        {
            let _s = lane_scope(lane_worker_process(3));
            assert_eq!(current_lane(), Lane { pid: 4, tid: 0 });
            {
                let _s2 = lane_scope(lane_reader(1));
                assert_eq!(current_lane(), Lane { pid: 0, tid: 101 });
            }
            assert_eq!(current_lane(), Lane { pid: 4, tid: 0 });
        }
        assert_eq!(current_lane(), LANE_DRIVER);
        // A thread's pool lane is assigned once and reused.
        let a = pool_lane();
        assert_eq!(a, pool_lane());
        assert!(a.tid >= WORKER_TID_BASE);
        // A different thread gets a different lane.
        let b = std::thread::spawn(pool_lane).join().unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn record_remote_reanchors_and_renames_the_pid() {
        let _l = test_lock();
        let _sink = install_new();
        let shipped = vec![Span {
            name: "shard".into(),
            cat: "shard".into(),
            lane: LANE_DRIVER, // worker-local coordinates
            start_ns: 10,
            dur_ns: 5,
            args: vec![("shard".into(), 2)],
        }];
        record_remote(shipped, 1, 1_000);
        let spans = uninstall().unwrap().drain();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].lane, Lane { pid: 2, tid: 0 });
        assert_eq!(spans[0].start_ns, 1_010);
        assert_eq!(spans[0].dur_ns, 5);
        assert_eq!(spans[0].args[0].1, 2);
        // With no sink installed, shipped spans are silently dropped.
        record_remote(vec![], 0, 0);
    }
}
