//! Chrome-trace-event rendering: serialize recorded spans as one JSON
//! document loadable in Perfetto (or `chrome://tracing`).
//!
//! We emit the stable subset of the trace-event format: `"M"` metadata
//! events naming each process/thread lane, then one `"X"` (complete)
//! event per span with `ts`/`dur` in fractional microseconds. Pids and
//! tids are *trace* coordinates from [`crate::obs::trace::Lane`] —
//! pid 0 is the driver process, pid `1 + w` is worker process `w` —
//! so a multi-process run renders as one timeline with the worker
//! spans (already re-anchored by `record_remote`) nested inside the
//! driver's per-worker RPC spans.

use std::collections::BTreeSet;
use std::fmt::Write as _;

use crate::json::escape_into;
use crate::obs::trace::{Span, READER_TID_BASE, WORKER_TID_BASE};

fn process_name(pid: u32) -> String {
    if pid == 0 {
        "driver".to_string()
    } else {
        format!("plan-worker {}", pid - 1)
    }
}

fn thread_name(pid: u32, tid: u32) -> String {
    if pid > 0 {
        return "main".to_string();
    }
    if tid == 0 {
        "driver".to_string()
    } else if (READER_TID_BASE..WORKER_TID_BASE).contains(&tid) {
        format!("reader {}", tid - READER_TID_BASE)
    } else {
        format!("worker {}", tid - WORKER_TID_BASE)
    }
}

/// Render `spans` as a `{"traceEvents": [...]}` document.
pub fn chrome_trace_json(spans: &[Span]) -> String {
    let mut out = String::with_capacity(128 + spans.len() * 128);
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    let mut push_event = |out: &mut String, first: &mut bool| {
        if !*first {
            out.push(',');
        }
        *first = false;
        out.push('\n');
    };

    let pids: BTreeSet<u32> = spans.iter().map(|s| s.lane.pid).collect();
    for pid in &pids {
        push_event(&mut out, &mut first);
        out.push_str(&format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\"args\":{{\"name\":"
        ));
        escape_into(&process_name(*pid), &mut out);
        out.push_str("}}");
    }
    let lanes: BTreeSet<(u32, u32)> = spans.iter().map(|s| (s.lane.pid, s.lane.tid)).collect();
    for (pid, tid) in &lanes {
        push_event(&mut out, &mut first);
        out.push_str(&format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"args\":{{\"name\":"
        ));
        escape_into(&thread_name(*pid, *tid), &mut out);
        out.push_str("}}");
    }

    for s in spans {
        push_event(&mut out, &mut first);
        out.push_str("{\"name\":");
        escape_into(&s.name, &mut out);
        out.push_str(",\"cat\":");
        escape_into(&s.cat, &mut out);
        let _ = write!(
            out,
            ",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\"pid\":{},\"tid\":{}",
            s.start_ns as f64 / 1000.0,
            s.dur_ns as f64 / 1000.0,
            s.lane.pid,
            s.lane.tid,
        );
        if !s.args.is_empty() {
            out.push_str(",\"args\":{");
            for (i, (k, v)) in s.args.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, &mut out);
                let _ = write!(out, ":{v}");
            }
            out.push('}');
        }
        out.push('}');
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::{parse, Json};
    use crate::obs::trace::{lane_reader, lane_worker_process, Lane, LANE_DRIVER};

    fn span(name: &str, lane: Lane, start_ns: u64, dur_ns: u64) -> Span {
        Span {
            name: name.to_string(),
            cat: "test".to_string(),
            lane,
            start_ns,
            dur_ns,
            args: vec![("shard".to_string(), 3)],
        }
    }

    #[test]
    fn output_parses_and_carries_lanes_and_metadata() {
        let spans = vec![
            span("drive", LANE_DRIVER, 0, 5_000),
            span("read", lane_reader(0), 1_000, 2_000),
            span("shard \"x\"", lane_worker_process(1), 1_500, 1_000),
        ];
        let doc = parse(&chrome_trace_json(&spans)).expect("valid JSON");
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        // 2 process_name + 3 thread_name metadata events + 3 spans.
        assert_eq!(events.len(), 8);
        let xs: Vec<&Json> = events
            .iter()
            .filter(|e| e.get_str("ph") == Some("X"))
            .collect();
        assert_eq!(xs.len(), 3);
        let read = xs.iter().find(|e| e.get_str("name") == Some("read")).unwrap();
        assert_eq!(read.get("pid").and_then(Json::as_i64), Some(0));
        assert_eq!(read.get("tid").and_then(Json::as_i64), Some(100));
        assert_eq!(read.get("ts").and_then(Json::as_f64), Some(1.0));
        assert_eq!(read.get("dur").and_then(Json::as_f64), Some(2.0));
        assert_eq!(
            read.get("args").and_then(|a| a.get("shard")).and_then(Json::as_i64),
            Some(3)
        );
        // Escaped span name survives the round trip.
        assert!(xs.iter().any(|e| e.get_str("name") == Some("shard \"x\"")));
        let metas: Vec<&Json> = events
            .iter()
            .filter(|e| e.get_str("ph") == Some("M"))
            .collect();
        let names: Vec<&str> = metas
            .iter()
            .filter_map(|m| m.get("args").and_then(|a| a.get_str("name")))
            .collect();
        assert!(names.contains(&"driver"));
        assert!(names.contains(&"plan-worker 1"));
        assert!(names.contains(&"reader 0"));
        assert!(names.contains(&"main"));
    }

    #[test]
    fn empty_span_list_still_renders_valid_json() {
        let doc = parse(&chrome_trace_json(&[])).expect("valid JSON");
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert!(events.is_empty());
    }
}
