//! EXPLAIN ANALYZE aggregation: fold the per-op spans an executed plan
//! recorded into per-op actuals the CLI renders next to the topology.
//!
//! Every executor tier wraps each physical op in a span with category
//! `"op"` and an `"op"` arg carrying the op's index in the lowered op
//! list, plus `rows_in`/`rows_out` args. Ops run once per shard (and
//! worker spans are folded in by `record_remote` before aggregation),
//! so summing across spans with the same index yields total rows and
//! total op time; `shards` counts how many shard-level executions were
//! observed.

use std::collections::BTreeMap;

use crate::obs::trace::Span;

/// Actuals for one physical op, summed across shards (and workers).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct OpStats {
    /// Total time inside the op across all shard executions.
    pub time_ns: u64,
    pub rows_in: u64,
    pub rows_out: u64,
    /// Number of shard-level executions observed.
    pub shards: u64,
}

/// Fold category-`"op"` spans into per-op-index actuals.
pub fn aggregate_ops(spans: &[Span]) -> BTreeMap<u64, OpStats> {
    let mut out: BTreeMap<u64, OpStats> = BTreeMap::new();
    for s in spans {
        if s.cat != "op" {
            continue;
        }
        let Some(&(_, idx)) = s.args.iter().find(|(k, _)| k == "op") else {
            continue;
        };
        let stats = out.entry(idx).or_default();
        stats.time_ns += s.dur_ns;
        stats.shards += 1;
        for (k, v) in &s.args {
            match k.as_str() {
                "rows_in" => stats.rows_in += v,
                "rows_out" => stats.rows_out += v,
                _ => {}
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{Lane, LANE_DRIVER};

    fn op_span(idx: u64, lane: Lane, dur_ns: u64, rows_in: u64, rows_out: u64) -> Span {
        Span {
            name: "op".to_string(),
            cat: "op".to_string(),
            lane,
            start_ns: 0,
            dur_ns,
            args: vec![
                ("op".to_string(), idx),
                ("rows_in".to_string(), rows_in),
                ("rows_out".to_string(), rows_out),
            ],
        }
    }

    #[test]
    fn sums_across_shards_and_skips_non_op_spans() {
        let spans = vec![
            op_span(0, LANE_DRIVER, 100, 10, 8),
            op_span(0, Lane { pid: 2, tid: 0 }, 300, 20, 15),
            op_span(1, LANE_DRIVER, 50, 8, 8),
            Span {
                name: "read shard".to_string(),
                cat: "io".to_string(),
                lane: LANE_DRIVER,
                start_ns: 0,
                dur_ns: 999,
                args: vec![("shard".to_string(), 0)],
            },
            // An op span missing the index arg is ignored, not misfiled.
            Span {
                name: "op".to_string(),
                cat: "op".to_string(),
                lane: LANE_DRIVER,
                start_ns: 0,
                dur_ns: 1,
                args: vec![],
            },
        ];
        let agg = aggregate_ops(&spans);
        assert_eq!(agg.len(), 2);
        assert_eq!(
            agg[&0],
            OpStats { time_ns: 400, rows_in: 30, rows_out: 23, shards: 2 }
        );
        assert_eq!(
            agg[&1],
            OpStats { time_ns: 50, rows_in: 8, rows_out: 8, shards: 1 }
        );
    }

    #[test]
    fn empty_input_yields_empty_map() {
        assert!(aggregate_ops(&[]).is_empty());
    }
}
