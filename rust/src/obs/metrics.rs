//! Process-global metrics registry: counters, gauges, and log₂-bucketed
//! histograms with Prometheus-style text exposition.
//!
//! Dependency-free and deliberately small: every instrument lives in a
//! name-keyed `BTreeMap` behind a mutex, so exposition order is stable
//! and new series need no registration step. The serve daemon is the
//! main producer/consumer — `run_admitted` observes per-job queue-wait
//! / execute / cache-restore latencies, the `metrics` request mirrors
//! gauge-like state (admission depth, pool health, cache counters) at
//! scrape time and renders [`Registry::exposition`].
//!
//! Histograms bucket by powers of two: an observation `v` lands in the
//! first bucket with `le = 2^i >= v` (`v = 0` and `v = 1` share
//! `le = 1`). 32 buckets cover `1 .. 2^31` — microsecond observations
//! up to ~35 minutes — and anything larger still counts toward
//! `_count`/`_sum` under `+Inf`, matching Prometheus cumulative-bucket
//! semantics (`_bucket{le="+Inf"}` always equals `_count`).

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

const HIST_BUCKETS: usize = 32;

#[derive(Debug, Clone)]
struct Hist {
    buckets: [u64; HIST_BUCKETS],
    sum: u64,
    count: u64,
}

impl Hist {
    fn new() -> Hist {
        Hist { buckets: [0; HIST_BUCKETS], sum: 0, count: 0 }
    }

    fn observe(&mut self, v: u64) {
        let idx = bucket_index(v);
        if idx < HIST_BUCKETS {
            self.buckets[idx] += 1;
        }
        self.sum = self.sum.saturating_add(v);
        self.count += 1;
    }
}

/// Index of the first power-of-two bucket holding `v`: the smallest `i`
/// with `v <= 2^i` (0 and 1 both land in bucket 0, `le = 1`).
fn bucket_index(v: u64) -> usize {
    if v <= 1 {
        0
    } else {
        (64 - (v - 1).leading_zeros()) as usize
    }
}

/// Name-keyed counters, gauges and histograms; see module docs.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, u64>>,
    hists: Mutex<BTreeMap<String, Hist>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Add `delta` to counter `name` (created at 0).
    pub fn counter_add(&self, name: &str, delta: u64) {
        let mut c = self.counters.lock().unwrap();
        *c.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Set counter `name` to an absolute value. Used to mirror counters
    /// owned elsewhere (e.g. `CacheStats`) into the exposition at
    /// scrape time without double-counting.
    pub fn counter_store(&self, name: &str, value: u64) {
        self.counters.lock().unwrap().insert(name.to_string(), value);
    }

    /// Set gauge `name`.
    pub fn gauge_set(&self, name: &str, value: u64) {
        self.gauges.lock().unwrap().insert(name.to_string(), value);
    }

    /// Record one observation (conventionally microseconds; name the
    /// series `*_us`) into histogram `name`.
    pub fn observe_us(&self, name: &str, v: u64) {
        let mut h = self.hists.lock().unwrap();
        h.entry(name.to_string()).or_insert_with(Hist::new).observe(v);
    }

    /// Clear every instrument — test isolation only.
    pub fn reset(&self) {
        self.counters.lock().unwrap().clear();
        self.gauges.lock().unwrap().clear();
        self.hists.lock().unwrap().clear();
    }

    /// Prometheus-style text exposition of every instrument, in stable
    /// (BTreeMap) name order: `# TYPE` line, then the samples;
    /// histograms render cumulative `_bucket{le="..."}` lines up to the
    /// highest non-empty bucket, then `+Inf`, `_sum`, `_count`.
    pub fn exposition(&self) -> String {
        let mut out = String::new();
        for (name, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        }
        for (name, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
        }
        for (name, h) in self.hists.lock().unwrap().iter() {
            out.push_str(&format!("# TYPE {name} histogram\n"));
            let max_used = h
                .buckets
                .iter()
                .rposition(|&b| b > 0)
                .map(|i| i + 1)
                .unwrap_or(0);
            let mut cum = 0u64;
            for i in 0..max_used {
                cum += h.buckets[i];
                out.push_str(&format!(
                    "{name}_bucket{{le=\"{}\"}} {cum}\n",
                    1u64 << i
                ));
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
            out.push_str(&format!("{name}_sum {}\n", h.sum));
            out.push_str(&format!("{name}_count {}\n", h.count));
        }
        out
    }
}

/// The process-global registry.
pub fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_first_power_of_two_at_or_above() {
        assert_eq!(bucket_index(0), 0); // le=1
        assert_eq!(bucket_index(1), 0); // le=1
        assert_eq!(bucket_index(2), 1); // le=2
        assert_eq!(bucket_index(3), 2); // le=4
        assert_eq!(bucket_index(4), 2); // le=4
        assert_eq!(bucket_index(5), 3); // le=8
        assert_eq!(bucket_index(1024), 10); // le=1024
        assert_eq!(bucket_index(1025), 11); // le=2048
        assert!(bucket_index(u64::MAX) >= HIST_BUCKETS); // +Inf only
    }

    #[test]
    fn counters_and_gauges_expose() {
        let r = Registry::new();
        r.counter_add("jobs_total", 2);
        r.counter_add("jobs_total", 1);
        r.counter_store("cache_mem_hits_total", 7);
        r.gauge_set("active", 3);
        r.gauge_set("active", 1);
        let text = r.exposition();
        assert!(text.contains("# TYPE jobs_total counter\njobs_total 3\n"));
        assert!(text.contains("cache_mem_hits_total 7\n"));
        assert!(text.contains("# TYPE active gauge\nactive 1\n"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_inf_matches_count() {
        let r = Registry::new();
        for v in [1, 2, 3, 3, 100] {
            r.observe_us("lat_us", v);
        }
        r.observe_us("lat_us", u64::MAX); // +Inf-only observation
        let text = r.exposition();
        assert!(text.contains("# TYPE lat_us histogram\n"));
        assert!(text.contains("lat_us_bucket{le=\"1\"} 1\n"));
        assert!(text.contains("lat_us_bucket{le=\"2\"} 2\n"));
        assert!(text.contains("lat_us_bucket{le=\"4\"} 4\n"));
        assert!(text.contains("lat_us_bucket{le=\"128\"} 5\n"));
        assert!(text.contains("lat_us_bucket{le=\"+Inf\"} 6\n"));
        assert!(text.contains("lat_us_count 6\n"));
        // +Inf bucket equals _count even with an over-range observation.
        let inf: u64 = text
            .lines()
            .find(|l| l.starts_with("lat_us_bucket{le=\"+Inf\"}"))
            .and_then(|l| l.rsplit(' ').next())
            .unwrap()
            .parse()
            .unwrap();
        let count: u64 = text
            .lines()
            .find(|l| l.starts_with("lat_us_count"))
            .and_then(|l| l.rsplit(' ').next())
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(inf, count);
    }

    #[test]
    fn reset_clears_and_global_registry_is_stable() {
        let r = Registry::new();
        r.counter_add("x", 1);
        r.observe_us("y_us", 5);
        r.reset();
        assert_eq!(r.exposition(), "");
        assert!(std::ptr::eq(registry(), registry()));
    }
}
