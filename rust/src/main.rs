//! `repro` — the P3SAPP launcher.
//!
//! Subcommands:
//!   gen-corpus   generate a synthetic CORE-schema corpus tier
//!   preprocess   run one approach (ca | p3sapp) over a corpus dir
//!   explain      print the P3SAPP logical/optimized/physical plan
//!   compare      run both approaches + accuracy matching
//!   train        preprocess then train the seq2seq model (AOT/PJRT)
//!   infer        generate titles with a freshly trained model
//!   report       regenerate the paper's tables/figures (e1..e9, all)
//!   cache        inspect (stats [--json]) or empty (clear) the plan cache
//!   serve        run the preprocessing daemon, or talk to one
//!              (start | preprocess | explain | train | stats | metrics |
//!               shutdown)
//!
//! Every command that executes a plan accepts `--trace FILE` (Chrome
//! trace-event JSON of the run, Perfetto-loadable), and `explain
//! --analyze` executes the plan to annotate the topology with per-op
//! actuals. Run `repro help` for options.

use p3sapp::analysis::accuracy::match_column;
use p3sapp::cache::CacheManager;
use p3sapp::cli::Args;
use p3sapp::config::AppConfig;
use p3sapp::corpus::{generate_corpus, CorpusSpec};
use p3sapp::driver::{run_ca, run_p3sapp, DriverOptions};
use p3sapp::ingest::list_shards;
use p3sapp::report as rpt;
use p3sapp::runtime::{Generator, Session, Trainer};
use p3sapp::vocab::{Batcher, Vocabulary};
use p3sapp::Result;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn main() {
    // Hidden worker mode (`repro plan-worker`): the multi-process plan
    // executor self-execs this binary, ships a P3PJ job on stdin, and
    // reads a P3PW result frame from stdout. Checked before normal CLI
    // parsing so the worker protocol can never collide with user flags;
    // deliberately absent from `usage()` — it is an implementation
    // detail of `--processes`, not a user-facing command.
    if std::env::args().nth(1).as_deref() == Some("plan-worker") {
        // `--persist` is the serve daemon's pool mode: loop over framed
        // jobs on stdin instead of exiting after one. `--listen ADDR`
        // is the remote tier: serve framed jobs over TCP instead of
        // stdin/stdout, one driver connection at a time.
        let code = match std::env::args().nth(2).as_deref() {
            Some("--persist") => p3sapp::plan::process::worker_main_persist(),
            Some("--listen") => {
                p3sapp::plan::remote::listen_main(std::env::args().nth(3).as_deref())
            }
            _ => p3sapp::plan::process::worker_main(),
        };
        std::process::exit(code);
    }
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            usage();
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "usage: repro <command> [options]\n\
         \n\
         commands:\n\
         \x20 gen-corpus  --dir D [--tier 1..5 | --records N] [--seed S] [--scale F]\n\
         \x20 preprocess  --dir D --approach ca|p3sapp [--workers N] [--explain]\n\
         \x20 explain     --dir D [--workers N] [--analyze]\n\
         \x20 compare     --dir D [--workers N]\n\
         \x20 train       --dir D [--steps N] [--artifacts A] [--workers N]\n\
         \x20             [--save-params FILE]\n\
         \x20 infer       --dir D [--steps N] [--titles K] [--artifacts A]\n\
         \x20 report      [--exp all|e1|...|e9] [--base-dir B] [--scale F]\n\
         \x20             [--tiers 1,2,3] [--workers N] [--artifacts A] [--csv]\n\
         \x20             [--explain] [--skip-ca]\n\
         \x20 cache       stats|clear --cache-dir D [--json]\n\
         \x20 serve       start --socket S [--cache-dir D | --no-cache]\n\
         \x20             [--workers N] [--processes N] [--max-active N]\n\
         \x20             [--max-queue N] [--job-budget-bytes B]\n\
         \x20             [--trace FILE]\n\
         \x20             -- run the preprocessing daemon (warm plan cache,\n\
         \x20             persistent worker pool, admission control)\n\
         \x20 serve       preprocess|explain|train --socket S --dir D\n\
         \x20             [--workers N] [--sample F] [--limit N] [--features]\n\
         \x20             [--steps N] [--artifacts A] [--linger-millis M]\n\
         \x20             -- submit one job to a running daemon\n\
         \x20 serve       stats|metrics|shutdown --socket S\n\
         \x20             -- metrics prints the daemon's Prometheus-style\n\
         \x20             exposition (admission depth, pool health, cache\n\
         \x20             counters, per-job latency histograms)\n\
         \x20 plan-worker --listen HOST:PORT\n\
         \x20             -- run a remote plan worker: serves framed plan\n\
         \x20             jobs over TCP for drivers started with --remote;\n\
         \x20             prints the bound address on startup (use port 0\n\
         \x20             to let the OS pick)\n\
         \x20 help\n\
         \n\
         common options:\n\
         \x20 --config FILE   load a TOML config (defaults otherwise)\n\
         \x20 --stream        run P3SAPP through the streaming executor\n\
         \x20                 (parse shard i+1 while cleaning shard i);\n\
         \x20                 applies to preprocess/explain/compare/train/report\n\
         \x20 --queue-cap N   streaming backpressure window in partitions\n\
         \x20                 (implies --stream; default 16)\n\
         \x20 --readers N     streaming parse threads (implies --stream;\n\
         \x20                 default: a quarter of the cores)\n\
         \x20 --processes N   run P3SAPP across N worker OS processes\n\
         \x20                 (0 = one per core): the op program + shard\n\
         \x20                 assignments ship over a versioned wire\n\
         \x20                 format, the driver folds the result frames;\n\
         \x20                 byte-identical output; excludes --stream;\n\
         \x20                 applies to preprocess/explain/compare/train/\n\
         \x20                 infer/report\n\
         \x20 --remote EP[,EP...]\n\
         \x20                 run P3SAPP across remote plan workers (each\n\
         \x20                 EP a HOST:PORT running plan-worker --listen):\n\
         \x20                 shard bytes ship inline or are fetched back\n\
         \x20                 by content digest, workers stream result\n\
         \x20                 chunks; byte-identical output; excludes\n\
         \x20                 --stream and --processes; same commands as\n\
         \x20                 --processes. Knobs: --remote-connect-timeout-\n\
         \x20                 millis, --remote-io-timeout-millis,\n\
         \x20                 --remote-retries, --remote-inline-max-bytes\n\
         \x20 --cache-dir D   persistent plan cache: P3SAPP runs restore a\n\
         \x20                 fingerprint-identical preprocessed frame instead\n\
         \x20                 of re-executing (report repeats, train/infer)\n\
         \x20 --no-cache      ignore --cache-dir (always execute)\n\
         \x20 --no-incremental\n\
         \x20                 disable the per-shard incremental tier: on a\n\
         \x20                 whole-plan miss, execute the full corpus instead\n\
         \x20                 of restoring unchanged shards from --cache-dir\n\
         \x20 --sample F      keep each input record with probability F —\n\
         \x20                 a deterministic positional sample; applies to\n\
         \x20                 every P3SAPP run (preprocess/explain/train/\n\
         \x20                 infer, and report with --skip-ca); the CA\n\
         \x20                 control never samples (compare rejects it)\n\
         \x20 --sample-seed S sample seed (default 42)\n\
         \x20 --limit N       keep only the first N clean rows (same scope\n\
         \x20                 as --sample)\n\
         \x20 --features      run the full Table-2 pipeline: cleaning plus\n\
         \x20                 Tokenizer -> HashingTF -> IDF; the IDF estimator\n\
         \x20                 lowers to a two-pass plan (preprocess/explain/\n\
         \x20                 train/infer; not compare/report)\n\
         \x20 --trace FILE    record every span of the run (driver, reader\n\
         \x20                 and worker threads, worker processes) and write\n\
         \x20                 one Chrome-trace-event JSON timeline on exit —\n\
         \x20                 load it in Perfetto or chrome://tracing; on\n\
         \x20                 serve start the trace covers the daemon's whole\n\
         \x20                 lifetime and is written at shutdown\n"
    );
}

fn load_config(args: &Args) -> Result<AppConfig> {
    match args.get("config") {
        Some(path) => AppConfig::load(Path::new(path)),
        None => Ok(AppConfig::default()),
    }
}

fn run(args: &Args) -> Result<()> {
    if let Some(sub) = &args.subcommand {
        // Only `cache` and `serve` take an action word; elsewhere a
        // stray positional is the error it always was.
        anyhow::ensure!(
            args.command == "cache" || args.command == "serve",
            "unexpected argument '{sub}'"
        );
    }
    match args.command.as_str() {
        // The daemon threads `--trace` through `ServeOptions` instead:
        // its sink must span the daemon lifetime, not this client call.
        "serve" => cmd_serve(args),
        "help" | "" => {
            usage();
            Ok(())
        }
        other => with_trace(args, || match other {
            "gen-corpus" => cmd_gen_corpus(args),
            "preprocess" => cmd_preprocess(args),
            "explain" => cmd_explain(args),
            "compare" => cmd_compare(args),
            "train" => cmd_train(args),
            "infer" => cmd_infer(args),
            "report" => cmd_report(args),
            "cache" => cmd_cache(args),
            other => {
                usage();
                anyhow::bail!("unknown command '{other}'")
            }
        }),
    }
}

/// `--trace FILE`: run `f` under a fresh global trace sink and write
/// the recorded spans as one Chrome-trace-event JSON document when it
/// returns — even on error, so a failing run still leaves its partial
/// timeline. Without the flag, `f` runs with tracing off (every span
/// call is a single relaxed atomic load).
fn with_trace(args: &Args, f: impl FnOnce() -> Result<()>) -> Result<()> {
    let Some(path) = args.get("trace").map(PathBuf::from) else {
        return f();
    };
    let sink = p3sapp::obs::install_new();
    let result = f();
    p3sapp::obs::uninstall();
    let spans = sink.drain();
    match std::fs::write(&path, p3sapp::obs::chrome_trace_json(&spans)) {
        Ok(()) => eprintln!("trace: {} spans written to {}", spans.len(), path.display()),
        Err(e) => eprintln!("trace: writing {}: {e}", path.display()),
    }
    result
}

fn cmd_gen_corpus(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let dir = PathBuf::from(
        args.get("dir").ok_or_else(|| anyhow::anyhow!("--dir is required"))?,
    );
    let seed = args.get_u64("seed", cfg.corpus.seed)?;
    let scale = args.get_f64("scale", cfg.corpus.scale)?;
    let mut spec = match args.get("tier") {
        Some(t) => CorpusSpec::tier(t.parse()?, seed),
        None => {
            let mut s = CorpusSpec::tiny(seed);
            s.n_records = args.get_usize("records", s.n_records)?;
            s
        }
    }
    .scaled(scale);
    spec.html_noise_rate = cfg.corpus.html_noise_rate;
    spec.dup_rate = cfg.corpus.dup_rate;
    let m = generate_corpus(&spec, &dir)?;
    println!(
        "generated {} records ({} duplicates) in {} files, {:.2} MB at {}",
        m.n_records,
        m.n_duplicates,
        m.n_files,
        m.total_bytes as f64 / 1048576.0,
        dir.display()
    );
    Ok(())
}

/// Execution options shared by every command that runs the P3SAPP
/// driver (`preprocess` / `explain` / `compare` / `train` / `infer` /
/// `report`), parsed in exactly one place: the worker count, the
/// executor selection ([`exec_opts`]), the plan-cache flags, and the
/// plan-variant knobs (`--sample`, `--limit`).
struct CommonOpts {
    workers: usize,
    executor: p3sapp::plan::ExecutorKind,
    cache: Option<Arc<CacheManager>>,
    incremental: bool,
    sample: Option<(f64, u64)>,
    limit: Option<usize>,
}

fn common_opts(args: &Args, cfg: &AppConfig) -> Result<CommonOpts> {
    let workers = args.get_usize("workers", cfg.engine.workers)?;
    Ok(CommonOpts {
        workers,
        executor: exec_opts(args, workers)?,
        cache: cache_opt(args)?,
        incremental: !args.flag("no-incremental"),
        sample: sample_opt(args)?,
        limit: match args.get("limit") {
            Some(_) => Some(args.get_usize("limit", 0)?),
            None => None,
        },
    })
}

/// The one place executor-selecting flags are parsed — every command
/// that runs or describes a plan (`preprocess` / `explain` / `compare` /
/// `train` / `infer` / `report` / `serve start`) resolves its
/// [`p3sapp::plan::ExecutorKind`] here, so conflicting flags are
/// rejected identically everywhere, with a message naming both.
fn exec_opts(args: &Args, workers: usize) -> Result<p3sapp::plan::ExecutorKind> {
    use p3sapp::plan::ExecutorKind;
    let stream = stream_opts(args, workers)?;
    let processes = match args.get("processes") {
        Some(_) => Some(args.get_usize("processes", 0)?),
        None => None,
    };
    let remote = remote_opts(args)?;
    // One executor per run: the schedules are alternatives, and
    // silently preferring one would make the others' flags dead knobs.
    anyhow::ensure!(
        processes.is_none() || stream.is_none(),
        "--processes and --stream/--queue-cap/--readers select different executors; \
         pick one"
    );
    anyhow::ensure!(
        remote.is_none() || processes.is_none(),
        "--remote and --processes select different executors; pick one"
    );
    anyhow::ensure!(
        remote.is_none() || stream.is_none(),
        "--remote and --stream/--queue-cap/--readers select different executors; \
         pick one"
    );
    Ok(match (remote, processes, stream) {
        (Some(remote), _, _) => ExecutorKind::Remote(remote),
        (None, Some(n), _) => ExecutorKind::Process(p3sapp::plan::ProcessOptions {
            processes: n,
            ..Default::default()
        }),
        (None, None, Some(stream)) => ExecutorKind::Stream(stream),
        (None, None, None) => ExecutorKind::Fused,
    })
}

/// `--remote EP[,EP...]` (+ optional timeout/retry knobs) → the remote
/// executor options. Each endpoint is a `HOST:PORT` running
/// `repro plan-worker --listen`.
fn remote_opts(args: &Args) -> Result<Option<p3sapp::plan::RemoteOptions>> {
    let Some(list) = args.get("remote") else {
        for knob in [
            "remote-connect-timeout-millis",
            "remote-io-timeout-millis",
            "remote-retries",
            "remote-inline-max-bytes",
        ] {
            anyhow::ensure!(args.get(knob).is_none(), "--{knob} requires --remote");
        }
        return Ok(None);
    };
    let endpoints: Vec<String> =
        list.split(',').map(|e| e.trim().to_string()).filter(|e| !e.is_empty()).collect();
    anyhow::ensure!(
        !endpoints.is_empty(),
        "--remote expects a comma-separated HOST:PORT list, got '{list}'"
    );
    let defaults = p3sapp::plan::RemoteOptions::default();
    Ok(Some(p3sapp::plan::RemoteOptions {
        endpoints,
        connect_timeout: std::time::Duration::from_millis(args.get_u64(
            "remote-connect-timeout-millis",
            defaults.connect_timeout.as_millis() as u64,
        )?),
        io_timeout: std::time::Duration::from_millis(
            args.get_u64("remote-io-timeout-millis", defaults.io_timeout.as_millis() as u64)?,
        ),
        connect_retries: args.get_u64("remote-retries", defaults.connect_retries as u64)? as u32,
        inline_max_bytes: args.get_u64("remote-inline-max-bytes", defaults.inline_max_bytes)?,
        ..defaults
    }))
}

/// `--sample F` (+ optional `--sample-seed S`, default 42) → a
/// deterministic positional input sample for cheap accuracy-table
/// repeats. Applies to the P3SAPP plan only; the CA control never
/// samples.
fn sample_opt(args: &Args) -> Result<Option<(f64, u64)>> {
    if args.get("sample").is_none() {
        anyhow::ensure!(
            args.get("sample-seed").is_none(),
            "--sample-seed requires --sample"
        );
        return Ok(None);
    }
    let fraction = args.get_f64("sample", 1.0)?;
    anyhow::ensure!(
        (0.0..=1.0).contains(&fraction),
        "--sample expects a fraction in [0, 1], got {fraction}"
    );
    Ok(Some((fraction, args.get_u64("sample-seed", 42)?)))
}

/// `--stream` / `--queue-cap N` / `--readers N` → streaming executor
/// options (the latter two imply `--stream`). `workers` is the resolved
/// `--workers` value, reused as the streaming cleaning-pool size.
fn stream_opts(args: &Args, workers: usize) -> Result<Option<p3sapp::plan::StreamOptions>> {
    if !args.flag("stream") && args.get("queue-cap").is_none() && args.get("readers").is_none()
    {
        return Ok(None);
    }
    let defaults = p3sapp::plan::StreamOptions::default();
    Ok(Some(p3sapp::plan::StreamOptions {
        readers: args.get_usize("readers", defaults.readers)?,
        workers,
        queue_cap: args.get_usize("queue-cap", defaults.queue_cap)?,
    }))
}

/// `--cache-dir D` opens the persistent plan cache; `--no-cache`
/// disables it even when a dir is given (today's always-execute
/// behavior, exactly).
fn cache_opt(args: &Args) -> Result<Option<Arc<CacheManager>>> {
    match args.get("cache-dir") {
        Some(dir) if !args.flag("no-cache") => {
            Ok(Some(Arc::new(CacheManager::open(PathBuf::from(dir))?)))
        }
        _ => Ok(None),
    }
}

fn driver_opts(args: &Args, cfg: &AppConfig) -> Result<DriverOptions> {
    let common = common_opts(args, cfg)?;
    Ok(DriverOptions {
        workers: common.workers,
        executor: common.executor,
        cache: common.cache,
        incremental: common.incremental,
        sample: common.sample,
        limit: common.limit,
        features: args.flag("features"),
        ..Default::default()
    })
}

/// EXPLAIN rendering matching the execution `opts` select: the
/// cache-restore path on a warm cache, else the streaming topology when
/// `--stream` is on, else the single-pass (or two-pass, with
/// `--features`) program — built by `DriverOptions::build_plan`, the
/// same derivation `run_p3sapp` executes.
fn render_explain(files: &[PathBuf], opts: &DriverOptions) -> Result<String> {
    p3sapp::cache::explain_with_cache(
        &opts.build_plan(files),
        opts.workers,
        &opts.executor,
        opts.cache.as_deref(),
    )
}

fn cmd_explain(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let dir = PathBuf::from(
        args.get("dir").ok_or_else(|| anyhow::anyhow!("--dir is required"))?,
    );
    let files = list_shards(&dir)?;
    let mut opts = driver_opts(args, &cfg)?;
    if !args.flag("analyze") {
        print!("{}", render_explain(&files, &opts)?);
        return Ok(());
    }
    // EXPLAIN ANALYZE: execute the plan and re-render the topology
    // annotated with per-op actuals folded from the recorded spans.
    // The cache is disabled for the measured run — a restore executes
    // no operators, so there would be nothing to annotate.
    opts.cache = None;
    // Reuse the sink `--trace` installed (the analyze run then lands in
    // that timeline too); otherwise install a private one.
    let (sink, shared) = match p3sapp::obs::uninstall() {
        Some(s) => {
            p3sapp::obs::install(Arc::clone(&s));
            (s, true)
        }
        None => (p3sapp::obs::install_new(), false),
    };
    let run = run_p3sapp(&files, &opts);
    let spans = if shared {
        sink.snapshot()
    } else {
        p3sapp::obs::uninstall();
        sink.drain()
    };
    let res = run?;
    let stats = p3sapp::obs::aggregate_ops(&spans);
    print!("{}", render_explain(&files, &opts)?);
    println!("== Analyzed Physical Plan ==");
    print!("{}", opts.build_plan(&files).optimize().lower()?.render_analyze(&stats));
    let execute_ns: u64 = spans
        .iter()
        .filter(|s| s.cat == "driver" && s.name == "execute")
        .map(|s| s.dur_ns)
        .sum();
    println!(
        "Driver: executed in {:.3} ms; {} rows ingested -> {} rows out",
        execute_ns as f64 / 1e6,
        res.rows_ingested,
        res.rows_out
    );
    Ok(())
}

fn cmd_preprocess(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let dir = PathBuf::from(
        args.get("dir").ok_or_else(|| anyhow::anyhow!("--dir is required"))?,
    );
    let files = list_shards(&dir)?;
    let opts = driver_opts(args, &cfg)?;
    let approach = args.get_or("approach", "p3sapp");
    if args.flag("explain") && approach == "p3sapp" {
        print!("{}", render_explain(&files, &opts)?);
        println!();
    }
    let res = match approach {
        "ca" => run_ca(&files, &opts)?,
        "p3sapp" => run_p3sapp(&files, &opts)?,
        other => anyhow::bail!("--approach must be ca or p3sapp, got '{other}'"),
    };
    println!("approach           {approach}");
    println!("rows ingested      {}", res.rows_ingested);
    println!("rows out           {}", res.rows_out);
    for (stage, d) in res.times.stages() {
        println!("{stage:18} {:.3} s", d.as_secs_f64());
    }
    println!("preprocessing      {:.3} s", res.preprocessing_secs());
    println!("cumulative (t_c)   {:.3} s", res.cumulative_secs());
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let dir = PathBuf::from(
        args.get("dir").ok_or_else(|| anyhow::anyhow!("--dir is required"))?,
    );
    let files = list_shards(&dir)?;
    let opts = driver_opts(args, &cfg)?;
    // The comparison's whole point is identical work on both sides; the
    // CA control has no sample/limit/feature path, so a lopsided run
    // would report meaningless reductions and accuracy.
    anyhow::ensure!(
        opts.sample.is_none() && opts.limit.is_none() && !opts.features,
        "--sample/--limit/--features do not apply to compare (the CA control \
         always runs the full cleaning workload)"
    );
    println!("running P3SAPP ...");
    let pa = run_p3sapp(&files, &opts)?;
    println!("running conventional approach ...");
    let ca = run_ca(&files, &opts)?;

    let mut t = rpt::TextTable::new(
        "CA vs P3SAPP",
        &["metric", "CA", "P3SAPP", "reduction %"],
    );
    let red = |a: f64, b: f64| {
        if a > 0.0 { format!("{:.3}", (a - b) / a * 100.0) } else { "-".into() }
    };
    t.row(vec![
        "ingestion (s)".into(),
        format!("{:.3}", ca.ingestion_secs()),
        format!("{:.3}", pa.ingestion_secs()),
        red(ca.ingestion_secs(), pa.ingestion_secs()),
    ]);
    t.row(vec![
        "preprocessing (s)".into(),
        format!("{:.3}", ca.preprocessing_secs()),
        format!("{:.3}", pa.preprocessing_secs()),
        red(ca.preprocessing_secs(), pa.preprocessing_secs()),
    ]);
    t.row(vec![
        "cumulative (s)".into(),
        format!("{:.3}", ca.cumulative_secs()),
        format!("{:.3}", pa.cumulative_secs()),
        red(ca.cumulative_secs(), pa.cumulative_secs()),
    ]);
    print!("{}", t.render());

    for col in ["title", "abstract"] {
        let m = match_column(&ca.frame, &pa.frame, col)?;
        println!(
            "accuracy[{col}]: {}/{} matching = {:.3}%",
            m.matching,
            m.rows_ca.max(m.rows_p3sapp),
            m.percentage
        );
    }
    Ok(())
}

/// Preprocess a corpus and train for `steps`; returns what infer needs.
fn train_pipeline(
    args: &Args,
    cfg: &AppConfig,
) -> Result<(Trainer, Vocabulary, p3sapp::frame::LocalFrame, Vec<f32>)> {
    let dir = PathBuf::from(
        args.get("dir").ok_or_else(|| anyhow::anyhow!("--dir is required"))?,
    );
    let artifacts = args.get_or("artifacts", &cfg.model.artifacts_dir).to_string();
    let steps = args.get_usize("steps", cfg.model.train_steps)?;
    let files = list_shards(&dir)?;
    let opts = driver_opts(args, cfg)?;

    println!("preprocessing (P3SAPP) ...");
    let pre = run_p3sapp(&files, &opts)?;
    println!("  {} clean rows in {:.3} s", pre.rows_out, pre.cumulative_secs());

    let session = Session::cpu(&artifacts)?;
    println!("PJRT platform: {}", session.platform());
    let mut trainer = Trainer::new(session)?;
    let mcfg = trainer.manifest.config.clone();
    println!(
        "model: vocab={} hidden={} enc_layers={} B={} S={} T={} ({} tensors)",
        mcfg.vocab, mcfg.hidden, mcfg.enc_layers, mcfg.batch, mcfg.src_len, mcfg.tgt_len,
        trainer.manifest.n_tensors()
    );

    let frame = pre.frame;
    let texts: Vec<&str> = (0..frame.num_rows())
        .flat_map(|i| {
            [
                frame.column(0).get_str(i).unwrap_or(""),
                frame.column(1).get_str(i).unwrap_or(""),
            ]
        })
        .collect();
    let vocab = Vocabulary::build(texts.into_iter(), mcfg.vocab);
    println!("vocabulary: {} entries", vocab.len());

    let mut batcher = Batcher::new(
        &frame,
        &vocab,
        "title",
        "abstract",
        mcfg.batch,
        mcfg.src_len,
        mcfg.tgt_len,
        cfg.model.batch_seed,
    )?;
    println!(
        "training {} steps ({} pairs, {} batches/epoch) ...",
        steps,
        batcher.num_pairs(),
        batcher.batches_per_epoch()
    );
    let stats = trainer.train_loop(steps, || batcher.next_batch())?;
    let losses: Vec<f32> = stats.iter().map(|s| s.loss).collect();
    let avg_step = stats.iter().map(|s| s.wall_secs).sum::<f64>() / stats.len().max(1) as f64;
    for chunk in stats.chunks(steps.div_ceil(10).max(1)) {
        let s = chunk.last().unwrap();
        println!("  step {:4}  loss {:.4}  ({:.3} s/step)", s.step, s.loss, s.wall_secs);
    }
    println!("avg step time: {avg_step:.3} s");
    Ok((trainer, vocab, frame, losses))
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let (trainer, _, _, losses) = train_pipeline(args, &cfg)?;
    let first = *losses.first().unwrap_or(&f32::NAN);
    let last = *losses.last().unwrap_or(&f32::NAN);
    println!(
        "loss: {:.4} -> {:.4} over {} steps",
        first,
        last,
        trainer.step_count()
    );
    if let Some(path) = args.get("save-params") {
        trainer.save_checkpoint(Path::new(path))?;
        println!("checkpoint saved to {path}");
    }
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let titles = args.get_usize("titles", 5)?;
    let (trainer, vocab, frame, _) = train_pipeline(args, &cfg)?;
    let generator = Generator::from_trainer(trainer)?;
    println!("\ngenerating {titles} titles:");
    let mut total = 0.0;
    for i in 0..titles.min(frame.num_rows()) {
        let abstract_text = frame.column(1).get_str(i).unwrap_or("");
        let true_title = frame.column(0).get_str(i).unwrap_or("");
        let (gen, secs) = generator.generate_title(&vocab, abstract_text)?;
        total += secs;
        println!("  [{i}] t_mi={secs:.3}s");
        println!("      true: {true_title}");
        println!("      gen:  {gen}");
    }
    println!("mean t_mi: {:.3} s", total / titles.max(1) as f64);
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let exp = args.get_or("exp", "all");
    let base = PathBuf::from(args.get_or("base-dir", "/tmp/p3sapp-experiments"));
    let common = common_opts(args, &cfg)?;
    let mut opts = rpt::SuiteOptions::new(&base);
    opts.seed = args.get_u64("seed", cfg.corpus.seed)?;
    opts.scale = args.get_f64("scale", cfg.corpus.scale)?;
    opts.workers = common.workers;
    opts.tiers = args.get_usize_list("tiers", &[1, 2, 3, 4, 5])?;
    opts.explain = args.flag("explain");
    opts.executor = common.executor;
    opts.cache = common.cache;
    opts.sample = common.sample;
    opts.limit = common.limit;
    opts.skip_ca = args.flag("skip-ca");
    // A sampled/limited suite only preprocesses a subset on the P3SAPP
    // side; the CA control has no sample path, so running it would
    // produce inflated reductions and collapsed accuracy tables.
    // Require the explicit opt-out rather than silently skewing Tables
    // 2–6.
    anyhow::ensure!(
        (common.sample.is_none() && common.limit.is_none()) || opts.skip_ca,
        "--sample/--limit make the CA control incomparable; add --skip-ca \
         (and drop --exp e4, which needs the CA frames)"
    );
    // The suite has no feature-tail path (its tables are about the
    // cleaning workload); reject rather than silently ignore the flag.
    anyhow::ensure!(!args.flag("features"), "report does not support --features");
    let csv = args.flag("csv");

    let needs_mtt = matches!(exp, "all" | "e5" | "e6");
    let suite = rpt::run_suite(&opts)?;

    // Training-time model: measure sec/step with a short real run when
    // the cost tables are requested (paper Tables 7-8).
    let model = if needs_mtt {
        let artifacts = args.get_or("artifacts", &cfg.model.artifacts_dir);
        Some(measure_train_model(&suite, artifacts, cfg.model.batch_seed)?)
    } else {
        None
    };

    let emit = |t: rpt::TextTable| {
        if csv {
            print!("{}", t.to_csv());
        } else {
            println!("{}", t.render());
        }
    };
    let want = |e: &str| exp == "all" || exp == e;
    if want("e1") {
        emit(rpt::table2(&suite));
    }
    if want("e2") {
        emit(rpt::table3(&suite));
    }
    if want("e3") {
        emit(rpt::table4(&suite));
    }
    if want("e4") {
        emit(rpt::table5_6(&suite, "title")?);
        emit(rpt::table5_6(&suite, "abstract")?);
    }
    if want("e5") {
        emit(rpt::table7(&suite, model.as_ref().unwrap())?);
    }
    if want("e6") {
        emit(rpt::table8(&suite, model.as_ref().unwrap())?);
    }
    if want("e7") {
        emit(rpt::fig10(&suite)?);
    }
    if want("e8") {
        emit(rpt::fig12(&suite));
    }
    if want("e9") {
        report_inference_time(args, &cfg)?;
    }
    Ok(())
}

/// `repro cache stats|clear --cache-dir D [--json]` — inspect or empty
/// the persistent plan cache without running any preprocessing. `stats`
/// reports the per-artifact disk tier plus the directory's lifetime
/// eviction/corruption counts and incremental-tier shard split
/// (the `counters.v1` sidecar); `--json` emits the same data
/// machine-readably — the CI incremental smoke asserts the
/// `shard_hits`/`shard_misses` fields from it.
fn cmd_cache(args: &Args) -> Result<()> {
    let dir = args
        .get("cache-dir")
        .ok_or_else(|| anyhow::anyhow!("--cache-dir is required"))?;
    let sub = args.subcommand.as_deref().unwrap_or("stats");
    anyhow::ensure!(
        sub == "stats" || sub == "clear",
        "cache takes 'stats' or 'clear', got '{sub}'"
    );
    // Inspection must not create directories: a typo'd --cache-dir
    // should be reported, not silently materialized as an empty cache.
    if !Path::new(dir).is_dir() {
        anyhow::bail!("no cache directory at {dir}");
    }
    let mgr = CacheManager::open(PathBuf::from(dir))?;
    match sub {
        "stats" => {
            let entries = mgr.entries()?;
            let lifetime = mgr.lifetime_counters();
            let now = std::time::SystemTime::now();
            let total: u64 = entries.iter().map(|e| e.bytes).sum();
            let age_secs = |e: &p3sapp::cache::CacheEntry| {
                e.modified.and_then(|m| now.duration_since(m).ok()).map(|d| d.as_secs())
            };
            if args.flag("json") {
                let items: Vec<String> = entries
                    .iter()
                    .map(|e| {
                        let age = age_secs(e)
                            .map(|a| a.to_string())
                            .unwrap_or_else(|| "null".into());
                        format!(
                            "{{\"key\":\"{}\",\"bytes\":{},\"age_secs\":{age}}}",
                            json_escape(&e.key),
                            e.bytes
                        )
                    })
                    .collect();
                println!(
                    "{{\"dir\":\"{}\",\"artifacts\":{},\"total_bytes\":{total},\
                     \"evictions\":{},\"corrupt\":{},\"shard_hits\":{},\
                     \"shard_misses\":{},\"shard_stores\":{},\"entries\":[{}]}}",
                    json_escape(dir),
                    entries.len(),
                    lifetime.evictions,
                    lifetime.corrupt,
                    lifetime.shard_hits,
                    lifetime.shard_misses,
                    lifetime.shard_stores,
                    items.join(",")
                );
                return Ok(());
            }
            let mut t = rpt::TextTable::new(
                format!("Plan cache at {dir}"),
                &["key", "size (KB)", "age (s)"],
            );
            for e in &entries {
                let age = age_secs(e).map(|a| a.to_string()).unwrap_or_else(|| "-".into());
                t.row(vec![e.key.clone(), format!("{:.1}", e.bytes as f64 / 1024.0), age]);
            }
            print!("{}", t.render());
            println!(
                "{} artifacts, {:.2} MB total",
                entries.len(),
                total as f64 / (1024.0 * 1024.0)
            );
            println!(
                "lifetime: {} evicted, {} corrupt dropped",
                lifetime.evictions, lifetime.corrupt
            );
            println!(
                "incremental: {} shards restored, {} executed, {} stored",
                lifetime.shard_hits, lifetime.shard_misses, lifetime.shard_stores
            );
        }
        "clear" => {
            let n = mgr.clear()?;
            println!("removed {n} cached artifacts from {dir}");
        }
        _ => unreachable!("validated above"),
    }
    Ok(())
}

/// Minimal JSON string escaping for `cache stats --json` (keys are hex
/// and the dir is a user path — quotes, backslashes and control chars
/// are all that can occur).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// `repro serve <action> --socket S` — run the preprocessing daemon
/// (`start`) or submit to one (`preprocess`/`explain`/`train`/`stats`/
/// `metrics`/`shutdown`). Client replies print in the same shape as the
/// one-shot commands so scripts (and the CI smoke job) can diff them
/// directly; `metrics` prints the daemon's Prometheus-style exposition
/// verbatim.
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let sub = args.subcommand.as_deref().ok_or_else(|| {
        anyhow::anyhow!(
            "serve takes an action: start|preprocess|explain|train|stats|metrics|shutdown"
        )
    })?;
    let socket = PathBuf::from(
        args.get("socket").ok_or_else(|| anyhow::anyhow!("--socket is required"))?,
    );
    match sub {
        "start" => {
            let defaults = p3sapp::serve::ServeOptions::default();
            // The daemon's whole point is warmth, so the cache defaults
            // to *on* (next to the socket); `--no-cache` opts out.
            let cache_dir = if args.flag("no-cache") {
                None
            } else {
                Some(match args.get("cache-dir") {
                    Some(dir) => PathBuf::from(dir),
                    None => socket.with_extension("cache"),
                })
            };
            let workers = args.get_usize("workers", cfg.engine.workers)?;
            // The daemon's executor is its warm worker pool; routing
            // through the shared helper keeps conflicting executor
            // flags rejected identically here.
            let processes = match exec_opts(args, workers)? {
                p3sapp::plan::ExecutorKind::Fused => 0,
                p3sapp::plan::ExecutorKind::Process(p) => p.processes,
                other => anyhow::bail!(
                    "serve start runs jobs through its own warm worker pool \
                     (--processes N); --{} does not apply",
                    other.name()
                ),
            };
            p3sapp::serve::run_serve(p3sapp::serve::ServeOptions {
                socket,
                cache_dir,
                worker_cmd: None,
                workers,
                processes,
                max_active: args.get_usize("max-active", defaults.max_active)?,
                max_queue: args.get_usize("max-queue", defaults.max_queue)?,
                job_budget_bytes: args
                    .get_u64("job-budget-bytes", defaults.job_budget_bytes)?,
                trace: args.get("trace").map(PathBuf::from),
            })
        }
        "stats" => {
            print_serve_reply(p3sapp::serve::request(&socket, &p3sapp::serve::Request::Stats)?)
        }
        "metrics" => print_serve_reply(p3sapp::serve::request(
            &socket,
            &p3sapp::serve::Request::Metrics,
        )?),
        "shutdown" => print_serve_reply(p3sapp::serve::request(
            &socket,
            &p3sapp::serve::Request::Shutdown,
        )?),
        "preprocess" | "explain" | "train" => {
            let spec = serve_job_spec(args)?;
            let req = match sub {
                "preprocess" => p3sapp::serve::Request::Preprocess(spec),
                "explain" => p3sapp::serve::Request::Explain(spec),
                _ => p3sapp::serve::Request::Train {
                    spec,
                    artifacts: args.get_or("artifacts", &cfg.model.artifacts_dir).to_string(),
                    steps: args.get_usize("steps", cfg.model.train_steps)?,
                },
            };
            print_serve_reply(p3sapp::serve::request(&socket, &req)?)
        }
        other => anyhow::bail!(
            "serve takes start|preprocess|explain|train|stats|metrics|shutdown, got '{other}'"
        ),
    }
}

/// The job half of a `serve` client invocation: which corpus, and the
/// plan-variant knobs the daemon folds into its own warm options.
fn serve_job_spec(args: &Args) -> Result<p3sapp::serve::JobSpec> {
    let dir = PathBuf::from(
        args.get("dir").ok_or_else(|| anyhow::anyhow!("--dir is required"))?,
    );
    Ok(p3sapp::serve::JobSpec {
        dir,
        workers: args.get_usize("workers", 0)?,
        sample: sample_opt(args)?,
        limit: match args.get("limit") {
            Some(_) => Some(args.get_usize("limit", 0)?),
            None => None,
        },
        features: args.flag("features"),
        linger_millis: args.get_u64("linger-millis", 0)?,
    })
}

/// Render a daemon reply. Preprocess replies reuse the `cmd_preprocess`
/// stage layout (so a warm job visibly reports its `cache_restore`
/// stage); typed daemon errors become the process exit error, naming
/// their cause.
fn print_serve_reply(reply: p3sapp::serve::Reply) -> Result<()> {
    use p3sapp::serve::Reply;
    match reply {
        Reply::Ok => println!("ok"),
        Reply::Text(text) => {
            print!("{text}");
            if !text.ends_with('\n') {
                println!();
            }
        }
        Reply::Stats(s) => {
            println!("active             {}", s.active);
            println!("queued             {}", s.queued);
            let pids = if s.worker_pids.is_empty() {
                "-".to_string()
            } else {
                s.worker_pids.iter().map(u32::to_string).collect::<Vec<_>>().join(" ")
            };
            println!("worker pids        {pids}");
            // Typed counters render only here, at the CLI edge.
            match &s.cache {
                Some(c) => println!(
                    "cache              mem_hits={} disk_hits={} misses={} stores={} \
                     fp_digest_shards={} fp_stat_revalidations={} \
                     shard_hits={} shard_misses={} shard_stores={}",
                    c.mem_hits,
                    c.disk_hits,
                    c.misses,
                    c.stores,
                    c.fp_digest_shards,
                    c.fp_stat_revalidations,
                    c.shard_hits,
                    c.shard_misses,
                    c.shard_stores
                ),
                None => println!("cache              disabled"),
            }
        }
        Reply::Preprocess(p) => {
            println!("rows ingested      {}", p.rows_ingested);
            println!("rows out           {}", p.rows_out);
            let mut total = 0.0;
            for (stage, nanos) in &p.stages {
                let secs = *nanos as f64 / 1e9;
                total += secs;
                println!("{stage:18} {secs:.3} s");
            }
            println!("cumulative (t_c)   {total:.3} s");
        }
        Reply::Err(e) => anyhow::bail!("serve error [{}]: {}", e.kind.name(), e.message),
    }
    Ok(())
}

/// Measure per-step training time on the first tier's cleaned frame.
fn measure_train_model(
    suite: &rpt::SuiteResult,
    artifacts: &str,
    batch_seed: u64,
) -> Result<rpt::TrainTimeModel> {
    let frame = &suite.tiers[0].p3sapp.frame;
    let session = Session::cpu(artifacts)?;
    let mut trainer = Trainer::new(session)?;
    let mcfg = trainer.manifest.config.clone();
    let texts: Vec<&str> = (0..frame.num_rows())
        .flat_map(|i| {
            [
                frame.column(0).get_str(i).unwrap_or(""),
                frame.column(1).get_str(i).unwrap_or(""),
            ]
        })
        .collect();
    let vocab = Vocabulary::build(texts.into_iter(), mcfg.vocab);
    let mut batcher = Batcher::new(
        frame, &vocab, "title", "abstract", mcfg.batch, mcfg.src_len, mcfg.tgt_len, batch_seed,
    )?;
    // Warm-up step (compile caches), then measure a few.
    trainer.train_step(&batcher.next_batch())?;
    let stats = trainer.train_loop(5, || batcher.next_batch())?;
    let sec_per_step =
        stats.iter().map(|s| s.wall_secs).sum::<f64>() / stats.len() as f64;
    eprintln!("[report] measured {sec_per_step:.3} s/step (batch {})", mcfg.batch);
    Ok(rpt::TrainTimeModel { sec_per_step, batch_size: mcfg.batch, train_frac: 0.9 })
}

/// E9: mean single-title inference time (paper: t_mi ≈ 2 s on a K80).
fn report_inference_time(args: &Args, cfg: &AppConfig) -> Result<()> {
    let artifacts = args.get_or("artifacts", &cfg.model.artifacts_dir);
    let session = Session::cpu(artifacts)?;
    let trainer = Trainer::new(session)?;
    let mcfg = trainer.manifest.config.clone();
    let generator = Generator::from_trainer(trainer)?;
    let src = vec![5i32; mcfg.src_len];
    let mask = vec![1.0f32; mcfg.src_len];
    // Warm-up, then measure.
    generator.generate_ids(&src, &mask)?;
    let mut total = 0.0;
    let n = 5;
    for _ in 0..n {
        total += generator.generate_ids(&src, &mask)?.wall_secs;
    }
    println!(
        "== E9: inference time ==\nmean t_mi over {n} runs: {:.4} s (paper: ~2 s on K80)",
        total / n as f64
    );
    Ok(())
}
