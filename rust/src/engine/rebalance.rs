//! Partition rebalancing: ingestion inherits the corpus's file-size skew
//! (one partition per shard, KB→MB). Before the transform stages run,
//! heavily skewed frames are re-split so the slowest partition doesn't
//! serialize the whole stage (straggler elimination).

use crate::frame::Frame;

/// Heuristic: rebalance when the largest partition holds more than
/// `max_share` of total bytes, or when there are fewer partitions than
/// workers (idle cores).
pub fn needs_rebalance(frame: &Frame, workers: usize, max_share: f64) -> bool {
    let nparts = frame.num_partitions();
    if nparts == 0 {
        return false;
    }
    if nparts < workers {
        return true;
    }
    let sizes: Vec<usize> = frame.partitions().iter().map(|p| p.approx_bytes()).collect();
    let total: usize = sizes.iter().sum();
    if total == 0 {
        return false;
    }
    let max = *sizes.iter().max().unwrap();
    (max as f64) / (total as f64) > max_share
}

/// Re-split into `workers * per_worker` equal-row partitions when the
/// skew heuristic fires; otherwise pass through unchanged.
pub fn rebalance(frame: Frame, workers: usize) -> Frame {
    if needs_rebalance(&frame, workers, 0.25) {
        frame.repartition(workers.max(1) * 4)
    } else {
        frame
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frame::{Column, Frame, Partition, Schema};

    fn skewed_frame() -> Frame {
        let schema = Schema::strings(&["c"]);
        let big: Vec<Option<String>> = (0..1000).map(|i| Some(format!("row {i} xxxxxxxx"))).collect();
        let small: Vec<Option<String>> = vec![Some("tiny".into())];
        Frame::from_partitions(
            schema,
            vec![
                Partition::new(vec![Column::from_strs(big)]),
                Partition::new(vec![Column::from_strs(small.clone())]),
                Partition::new(vec![Column::from_strs(small)]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn detects_byte_skew() {
        let f = skewed_frame();
        assert!(needs_rebalance(&f, 2, 0.25));
    }

    #[test]
    fn detects_underpartitioning() {
        let f = skewed_frame();
        assert!(needs_rebalance(&f, 8, 1.1), "3 partitions < 8 workers");
    }

    #[test]
    fn balanced_frame_passes_through() {
        let schema = Schema::strings(&["c"]);
        let parts: Vec<Partition> = (0..8)
            .map(|_| {
                Partition::new(vec![Column::from_strs(
                    (0..100).map(|i| Some(format!("r{i}"))).collect(),
                )])
            })
            .collect();
        let f = Frame::from_partitions(schema, parts).unwrap();
        assert!(!needs_rebalance(&f, 4, 0.25));
        let nparts = f.num_partitions();
        assert_eq!(rebalance(f, 4).num_partitions(), nparts);
    }

    #[test]
    fn rebalance_preserves_rows() {
        let f = skewed_frame();
        let rows = f.num_rows();
        let r = rebalance(f, 2);
        assert_eq!(r.num_rows(), rows);
        assert_eq!(r.num_partitions(), 8);
    }
}
