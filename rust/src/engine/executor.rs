//! Work-queue executor over partitions.

use crate::frame::Partition;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A parallel partition mapper with a fixed worker count.
///
/// Scheduling is a shared atomic cursor over the input vector — the
/// cheapest possible dynamic load balancer. Partition sizes are skewed
/// (file-size skew survives ingestion), so dynamic pull beats static
/// striping by keeping all cores busy until the queue drains.
#[derive(Debug, Clone, Copy)]
pub struct Executor {
    workers: usize,
}

impl Executor {
    /// `workers = 0` means "all logical cores" (`local[*]`).
    pub fn new(workers: usize) -> Self {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2)
        } else {
            workers
        };
        Executor { workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Apply `f` to every partition in parallel; output order == input
    /// order. `f` must be `Sync` (shared by all workers by reference).
    pub fn map_partitions<F>(&self, partitions: Vec<Partition>, f: F) -> Vec<Partition>
    where
        F: Fn(Partition) -> Partition + Sync,
    {
        let n = partitions.len();
        if n == 0 {
            return Vec::new();
        }
        let workers = self.workers.min(n);
        if workers <= 1 {
            return partitions.into_iter().map(f).collect();
        }

        // Input slots (taken by workers) and output slots (filled in
        // input order).
        let input: Vec<Mutex<Option<Partition>>> =
            partitions.into_iter().map(|p| Mutex::new(Some(p))).collect();
        let output: Vec<Mutex<Option<Partition>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let cursor = AtomicUsize::new(0);
        let f = &f;

        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let part = input[i].lock().unwrap().take().expect("slot taken once");
                    let out = f(part);
                    *output[i].lock().unwrap() = Some(out);
                });
            }
        });

        output
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
            .collect()
    }

    /// Parallel map over arbitrary Send items (used by the benchmark
    /// harness and the vocabulary builder).
    pub fn map_items<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let workers = self.workers.min(n);
        if workers <= 1 {
            return items.into_iter().map(f).collect();
        }
        let input: Vec<Mutex<Option<T>>> = items.into_iter().map(|p| Mutex::new(Some(p))).collect();
        let output: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let cursor = AtomicUsize::new(0);
        let f = &f;
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let item = input[i].lock().unwrap().take().expect("slot taken once");
                    *output[i].lock().unwrap() = Some(f(item));
                });
            }
        });
        output
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("filled"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frame::Column;

    fn part(vals: &[&str]) -> Partition {
        Partition::new(vec![Column::from_strs(
            vals.iter().map(|v| Some(v.to_string())).collect(),
        )])
    }

    #[test]
    fn preserves_order() {
        let parts: Vec<Partition> = (0..50).map(|i| part(&[&format!("p{i}")])).collect();
        let out = Executor::new(4).map_partitions(parts, |p| p);
        for (i, p) in out.iter().enumerate() {
            assert_eq!(p.column(0).get_str(0), Some(format!("p{i}").as_str()));
        }
    }

    #[test]
    fn applies_transform() {
        let parts = vec![part(&["a", "b"]), part(&["c"])];
        let out = Executor::new(2).map_partitions(parts, |p| {
            let upper: Vec<Option<String>> = p
                .column(0)
                .strs()
                .iter()
                .map(|v| v.as_ref().map(|s| s.to_uppercase()))
                .collect();
            Partition::new(vec![Column::from_strs(upper)])
        });
        assert_eq!(out[0].column(0).get_str(1), Some("B"));
        assert_eq!(out[1].column(0).get_str(0), Some("C"));
    }

    #[test]
    fn zero_workers_means_all_cores() {
        assert!(Executor::new(0).workers() >= 1);
    }

    #[test]
    fn empty_input() {
        let out = Executor::new(4).map_partitions(Vec::new(), |p| p);
        assert!(out.is_empty());
    }

    #[test]
    fn map_items_parallel() {
        let out = Executor::new(3).map_items((0..100).collect::<Vec<i32>>(), |x| x * 2);
        assert_eq!(out[51], 102);
        assert_eq!(out.len(), 100);
    }

    #[test]
    fn single_worker_path() {
        let parts = vec![part(&["x"]), part(&["y"])];
        let out = Executor::new(1).map_partitions(parts, |p| p);
        assert_eq!(out.len(), 2);
    }
}
