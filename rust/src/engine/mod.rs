//! Multicore partition executor — the `local[*]` analog. A fixed pool of
//! scoped worker threads pulls partitions from a shared queue and applies
//! a per-partition closure; results are returned in input order.
//!
//! This is the `k` in the paper's O(n/k) preprocessing claim (§3, §6):
//! the same total row work, divided across `k` logical cores.

mod executor;
mod rebalance;

pub use executor::Executor;
pub use rebalance::{needs_rebalance, rebalance};
