//! Parallel (P3SAPP) ingestion: a worker pool reads and parses shard
//! files concurrently, emitting one partition per file through a bounded
//! channel. The bound provides backpressure — parse workers stall when
//! the collector lags, capping peak memory at `queue_cap` partitions
//! regardless of corpus size.
//!
//! This bounded producer/consumer shape is also the template for the
//! plan layer's streaming executor ([`crate::plan::StreamExecutor`]),
//! which puts the whole cleaning program behind the same kind of queue.
//!
//! ```
//! use p3sapp::ingest::spark::{ingest_files, IngestOptions};
//!
//! // Four reader threads, at most two parsed-but-uncollected shards in
//! // flight. An empty file list yields an empty frame immediately.
//! let opts = IngestOptions { workers: 4, queue_cap: 2 };
//! let frame = ingest_files(&[], &["title", "abstract"], &opts).unwrap();
//! assert_eq!(frame.num_rows(), 0);
//! ```

use super::scanner::list_shards;
use crate::frame::{Column, Frame, Partition, Schema};
use crate::Result;
use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Mutex};

/// Tuning knobs for parallel ingestion.
#[derive(Debug, Clone)]
pub struct IngestOptions {
    /// Worker threads (the `k` of the paper's O(n/k); `local[*]` uses
    /// all logical cores).
    pub workers: usize,
    /// Bounded-channel capacity in partitions (backpressure window).
    pub queue_cap: usize,
}

impl Default for IngestOptions {
    fn default() -> Self {
        IngestOptions {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2),
            queue_cap: 16,
        }
    }
}

impl IngestOptions {
    pub fn with_workers(workers: usize) -> Self {
        IngestOptions { workers: workers.max(1), ..Default::default() }
    }
}

/// Ingest every `.json` shard under `dir`, projecting `fields`, with
/// `workers` parallel reader/parser threads. Convenience wrapper over
/// [`ingest_files`].
pub fn ingest_dir(dir: &Path, fields: &[&str], workers: usize) -> Result<Frame> {
    ingest_files(&list_shards(dir)?, fields, &IngestOptions::with_workers(workers))
}

/// Parallel ingestion over an explicit file list.
///
/// Partitions are re-assembled in *file order* at the collector so the
/// resulting frame is deterministic and row-comparable with the
/// sequential baseline (required by the accuracy analysis, Tables 5–6).
pub fn ingest_files(files: &[PathBuf], fields: &[&str], opts: &IngestOptions) -> Result<Frame> {
    ingest_files_with(files, fields, opts, read_shard)
}

/// [`ingest_files`] over the pre-cursor owned parser
/// ([`read_shard_owned`]). Kept non-deprecated on purpose: the
/// `parallel_x*` arms of `benches/ingest_modes.rs` measure this path so
/// the `cursor_x*` arms have a stable same-topology baseline to beat.
pub fn ingest_files_owned(
    files: &[PathBuf],
    fields: &[&str],
    opts: &IngestOptions,
) -> Result<Frame> {
    ingest_files_with(files, fields, opts, read_shard_owned)
}

fn ingest_files_with(
    files: &[PathBuf],
    fields: &[&str],
    opts: &IngestOptions,
    read: fn(&Path, &[String]) -> Result<Partition>,
) -> Result<Frame> {
    let schema = Schema::strings(fields);
    if files.is_empty() {
        return Ok(Frame::empty(schema));
    }
    let workers = opts.workers.max(1).min(files.len());

    // Work queue: (file index, path). Indexed so the collector can
    // restore file order.
    let queue: Arc<Mutex<VecDeque<(usize, PathBuf)>>> = Arc::new(Mutex::new(
        files.iter().cloned().enumerate().collect(),
    ));
    let fields_owned: Arc<Vec<String>> =
        Arc::new(fields.iter().map(|s| s.to_string()).collect());

    let (tx, rx) = sync_channel::<(usize, Result<Partition>)>(opts.queue_cap.max(1));

    std::thread::scope(|scope| -> Result<Frame> {
        for _ in 0..workers {
            let queue = Arc::clone(&queue);
            let fields = Arc::clone(&fields_owned);
            let tx = tx.clone();
            scope.spawn(move || {
                loop {
                    let job = queue.lock().unwrap().pop_front();
                    let Some((idx, path)) = job else { break };
                    let part = read(&path, &fields);
                    // Receiver gone ⇒ collector bailed on an earlier
                    // error; just stop.
                    if tx.send((idx, part)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx); // collector sees EOF when all workers finish

        // Collect out-of-order arrivals, release in file order.
        let mut pending: Vec<Option<Partition>> = (0..files.len()).map(|_| None).collect();
        let mut frame = Frame::empty(schema.clone());
        let mut next = 0usize;
        for (idx, part) in rx {
            pending[idx] = Some(part?);
            while next < pending.len() {
                if let Some(p) = pending[next].take() {
                    frame.push_partition(p)?;
                    next += 1;
                } else {
                    break;
                }
            }
        }
        if next != files.len() {
            anyhow::bail!("ingestion incomplete: {next}/{} shards", files.len());
        }
        Ok(frame)
    })
}

/// Read + parse + project one shard into a partition — the production
/// path: raw bytes read once, then the zero-copy byte cursor
/// ([`crate::json::parse_shard_projected`]) scans the buffer in place
/// and only the surviving cells are copied into owned columns.
///
/// Projection pushdown is unchanged: only the selected fields are
/// materialized, everything else is skipped at lexer speed — what
/// Spark's JSON datasource does for a two-column select, and a
/// mechanism pandas `read_json` (the CA path) lacks. The plan executors
/// (`crate::plan`) go one step further and run their leading filter ops
/// over the *borrowed* cells before materializing (`run_raw`); this
/// function is the re-chunk path's and eager driver's materialize-all
/// variant.
pub(crate) fn read_shard(path: &Path, fields: &[String]) -> Result<Partition> {
    let bytes = read_shard_bytes(path)?;
    partition_from_bytes(&bytes, path, fields)
}

/// Read one shard's raw bytes into a fresh buffer (sized from file
/// metadata by `fs::read`, so the file is copied exactly once). The
/// streaming executor's reader stage sends these whole buffers to its
/// workers; the cursor parses them in place there.
pub(crate) fn read_shard_bytes(path: &Path) -> Result<Vec<u8>> {
    std::fs::read(path).map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))
}

/// Read one shard's raw bytes into `buf` (cleared first, allocation
/// reused). The multi-process worker loop passes one buffer across all
/// its assigned shards so steady-state reads allocate nothing.
pub(crate) fn read_shard_into(path: &Path, buf: &mut Vec<u8>) -> Result<()> {
    use std::io::Read;
    buf.clear();
    let mut f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    if let Ok(meta) = f.metadata() {
        buf.reserve(meta.len() as usize);
    }
    f.read_to_end(buf)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    Ok(())
}

/// Cursor-parse a shard buffer and materialize every projected cell
/// into an owned partition. `path` is for error context only.
pub(crate) fn partition_from_bytes(
    bytes: &[u8],
    path: &Path,
    fields: &[String],
) -> Result<Partition> {
    let field_refs: Vec<&str> = fields.iter().map(|s| s.as_str()).collect();
    let raw = crate::json::parse_shard_projected(bytes, &field_refs)
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
    Ok(Partition::new(
        raw.cols
            .into_iter()
            .map(|col| {
                Column::from_strs(col.into_iter().map(|c| c.map(std::borrow::Cow::into_owned)).collect())
            })
            .collect(),
    ))
}

/// The pre-cursor read path: whole-file `read_to_string` (full UTF-8
/// pass) + owned projected parse (one `String` per cell, kept or not).
/// No production caller — this is the measured baseline for the
/// `parallel_x*` bench arms and a second reference implementation for
/// the cursor parity tests.
pub fn read_shard_owned(path: &Path, fields: &[String]) -> Result<Partition> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    let field_refs: Vec<&str> = fields.iter().map(|s| s.as_str()).collect();
    let rows = crate::json::parse_document_projected(&text, &field_refs)
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
    let mut cols: Vec<Vec<Option<String>>> =
        field_refs.iter().map(|_| Vec::with_capacity(rows.len())).collect();
    for row in rows {
        for (ci, cell) in row.into_iter().enumerate() {
            cols[ci].push(cell);
        }
    }
    Ok(Partition::new(cols.into_iter().map(Column::from_strs).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate_corpus, CorpusSpec};

    fn corpus(name: &str, spec: &CorpusSpec) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("p3sapp-ing-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        generate_corpus(spec, &dir).unwrap();
        dir
    }

    #[test]
    fn parallel_matches_record_count() {
        let spec = CorpusSpec::tiny(42);
        let dir = corpus("count", &spec);
        let frame = ingest_dir(&dir, &["title", "abstract"], 4).unwrap();
        assert_eq!(frame.num_partitions(), spec.n_files);
        // Row count equals manifest records (incl. duplicates).
        let manifest = std::fs::read_to_string(dir.join("manifest.txt")).unwrap();
        let records: usize = manifest
            .lines()
            .find_map(|l| l.strip_prefix("records="))
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(frame.num_rows(), records);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn order_is_deterministic_across_worker_counts() {
        let spec = CorpusSpec::tiny(99);
        let dir = corpus("order", &spec);
        let f1 = ingest_dir(&dir, &["title", "abstract"], 1).unwrap().collect();
        let f4 = ingest_dir(&dir, &["title", "abstract"], 4).unwrap().collect();
        assert_eq!(f1, f4);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn backpressure_small_queue_still_completes() {
        let spec = CorpusSpec::tiny(5);
        let dir = corpus("bp", &spec);
        let files = list_shards(&dir).unwrap();
        let frame = ingest_files(
            &files,
            &["title", "abstract"],
            &IngestOptions { workers: 4, queue_cap: 1 },
        )
        .unwrap();
        assert_eq!(frame.num_partitions(), files.len());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn cursor_and_owned_ingest_agree() {
        // Same corpus through the production byte-cursor path and the
        // legacy owned-parser path: identical frames, bit for bit.
        let spec = CorpusSpec::tiny(7);
        let dir = corpus("agree", &spec);
        let files = list_shards(&dir).unwrap();
        let opts = IngestOptions { workers: 2, queue_cap: 4 };
        let cur = ingest_files(&files, &["title", "abstract"], &opts).unwrap().collect();
        let owned = ingest_files_owned(&files, &["title", "abstract"], &opts).unwrap().collect();
        assert_eq!(cur, owned);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bad_shard_reports_error() {
        let dir = std::env::temp_dir().join(format!("p3sapp-ing-bad-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("bad.json"), "{not json").unwrap();
        let err = ingest_dir(&dir, &["title"], 2).unwrap_err();
        assert!(err.to_string().contains("bad.json"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_file_list_yields_empty_frame() {
        let frame =
            ingest_files(&[], &["title"], &IngestOptions::default()).unwrap();
        assert_eq!(frame.num_rows(), 0);
    }
}
