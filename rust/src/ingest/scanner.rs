//! Shard discovery: deterministic (sorted) listing of `.json` files
//! under a corpus directory — both approaches must visit files in the
//! same order for their outputs to be row-comparable.

use crate::Result;
use std::path::{Path, PathBuf};

/// All `.json` files directly under `dir`, sorted by file name.
pub fn list_shards(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("cannot read corpus dir {}: {e}", dir.display()))?
    {
        let p = entry?.path();
        if p.is_file() && p.extension().map(|e| e == "json") == Some(true) {
            out.push(p);
        }
    }
    out.sort();
    if out.is_empty() {
        anyhow::bail!("no .json shards found in {}", dir.display());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    #[test]
    fn lists_sorted_json_only() {
        let dir = std::env::temp_dir().join(format!("p3sapp-scan-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("b.json"), "{}").unwrap();
        fs::write(dir.join("a.json"), "{}").unwrap();
        fs::write(dir.join("notes.txt"), "x").unwrap();
        let shards = list_shards(&dir).unwrap();
        assert_eq!(shards.len(), 2);
        assert!(shards[0].ends_with("a.json"));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_dir_errors() {
        let dir = std::env::temp_dir().join(format!("p3sapp-scan-empty-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        assert!(list_shards(&dir).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }
}
