//! Column projection: CORE record JSON → the selected nullable string
//! fields ("Select data to be extracted", Algorithms 1 & 2 step 5).

use crate::frame::{Column, Partition};
use crate::json::Json;

/// Extract the named fields from one record. Missing, null, or
/// non-string fields project to `None` — Spark's permissive reading of
/// heterogeneous JSON.
pub fn project_record(record: &Json, fields: &[&str]) -> Vec<Option<String>> {
    fields
        .iter()
        .map(|f| record.get_str(f).map(|s| s.to_string()))
        .collect()
}

/// Project a batch of records into one [`Partition`] with `fields.len()`
/// string columns.
pub fn project_batch(records: &[Json], fields: &[&str]) -> Partition {
    let mut cols: Vec<Vec<Option<String>>> =
        fields.iter().map(|_| Vec::with_capacity(records.len())).collect();
    for rec in records {
        for (i, f) in fields.iter().enumerate() {
            cols[i].push(rec.get_str(f).map(|s| s.to_string()));
        }
    }
    Partition::new(cols.into_iter().map(Column::from_strs).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    #[test]
    fn projects_present_null_and_missing() {
        let rec = parse(r#"{"title": "T", "abstract": null, "year": 2019}"#).unwrap();
        let row = project_record(&rec, &["title", "abstract", "doi"]);
        assert_eq!(row, vec![Some("T".to_string()), None, None]);
    }

    #[test]
    fn non_string_field_projects_to_null() {
        let rec = parse(r#"{"title": 42}"#).unwrap();
        assert_eq!(project_record(&rec, &["title"]), vec![None]);
    }

    #[test]
    fn batch_projection_shape() {
        let records = vec![
            parse(r#"{"title":"a","abstract":"x"}"#).unwrap(),
            parse(r#"{"title":"b"}"#).unwrap(),
        ];
        let p = project_batch(&records, &["title", "abstract"]);
        assert_eq!(p.num_rows(), 2);
        assert_eq!(p.num_columns(), 2);
        assert_eq!(p.column(0).get_str(1), Some("b"));
        assert!(p.column(1).is_null(1));
    }
}
