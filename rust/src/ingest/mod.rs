//! Data ingestion — both worlds:
//!
//! - [`spark`] — P3SAPP path (Algorithm 1, steps 2–8): shard files are
//!   read and parsed **in parallel** by a worker pool; each file becomes
//!   one [`crate::frame::Partition`] pushed through a bounded channel
//!   (backpressure) and unioned into a [`crate::frame::Frame`] — an O(1)
//!   pointer append per file.
//! - [`append`] — conventional path (Algorithm 2, steps 2–8): files are
//!   read **sequentially**; each file's rows are appended to a growing
//!   [`crate::frame::LocalFrame`] with pandas `DataFrame.append`
//!   copy-semantics, which is what makes CA's ingestion superlinear
//!   (Table 2).
//!
//! Both paths perform the same *projection* (select `title`, `abstract`
//! out of the full CORE record) so downstream row content is identical.

pub mod append;
pub mod projector;
pub mod scanner;
pub mod spark;

pub use append::ingest_dir_append;
pub use projector::project_record;
pub use scanner::list_shards;
pub use spark::{ingest_dir, IngestOptions};
