//! Conventional (CA) ingestion: sequential file loop with pandas
//! `DataFrame.append` copy-semantics (Algorithm 2, steps 2–8).
//!
//! Each file is read, parsed, projected to a small [`LocalFrame`], and
//! appended to the accumulator via [`LocalFrame::append_copy`] — which
//! reallocates and copies *all rows so far*, every file. Over f files of
//! n total rows that is Θ(n·f) row copies: the measured mechanism behind
//! the paper's 433 s → 32,699 s CA ingestion column (Table 2).

use super::projector::project_batch;
use super::scanner::list_shards;
use crate::frame::{LocalFrame, Schema};
use crate::json::parse_document;
use crate::Result;
use std::path::{Path, PathBuf};

/// Sequential append-based ingestion of every shard under `dir`.
pub fn ingest_dir_append(dir: &Path, fields: &[&str]) -> Result<LocalFrame> {
    ingest_files_append(&list_shards(dir)?, fields)
}

/// Sequential append-based ingestion over an explicit file list.
pub fn ingest_files_append(files: &[PathBuf], fields: &[&str]) -> Result<LocalFrame> {
    let schema = Schema::strings(fields);
    let mut data = LocalFrame::empty(schema.clone());
    for path in files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let records = parse_document(&text)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let part = project_batch(&records, fields);
        let incoming = LocalFrame::from_columns(schema.clone(), part.into_columns())?;
        // pandas: data = data.append(selected)  — full copy each file.
        data.append_copy(&incoming)?;
    }
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate_corpus, CorpusSpec};
    use crate::ingest::spark::ingest_dir;

    #[test]
    fn sequential_equals_parallel_content() {
        let dir =
            std::env::temp_dir().join(format!("p3sapp-ca-eq-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        generate_corpus(&CorpusSpec::tiny(17), &dir).unwrap();

        let ca = ingest_dir_append(&dir, &["title", "abstract"]).unwrap();
        let pa = ingest_dir(&dir, &["title", "abstract"], 4).unwrap().collect();
        assert_eq!(ca, pa, "CA and P3SAPP ingestion must agree row-for-row");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_list_gives_empty_frame() {
        let f = ingest_files_append(&[], &["title"]).unwrap();
        assert_eq!(f.num_rows(), 0);
    }
}
