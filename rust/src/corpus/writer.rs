//! Shard writer: distributes generated records over variable-size JSON
//! files (array and JSON-lines layouts) under a target directory.

use super::record::CoreRecord;
use super::rng::Rng;
use super::spec::CorpusSpec;
use crate::json::write_value;
use crate::Result;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// What a generation run produced (persisted alongside the shards as
/// `manifest.json` for experiment bookkeeping).
#[derive(Debug, Clone)]
pub struct CorpusManifest {
    pub dir: PathBuf,
    pub seed: u64,
    pub n_records: usize,
    pub n_duplicates: usize,
    pub n_files: usize,
    pub total_bytes: u64,
}

/// Generate a corpus per `spec` into `dir` (created if missing; existing
/// `.json` shards are removed first so re-runs are clean).
///
/// File-size skew: each shard draws a skewed weight, records are dealt
/// proportionally — reproducing CORE's "2085 files, KB to GB" spread at
/// our scale, which is what makes naive one-file-at-a-time ingestion
/// scheduling imbalanced.
pub fn generate_corpus(spec: &CorpusSpec, dir: &Path) -> Result<CorpusManifest> {
    fs::create_dir_all(dir)?;
    for entry in fs::read_dir(dir)? {
        let p = entry?.path();
        if p.extension().map(|e| e == "json") == Some(true) {
            fs::remove_file(p)?;
        }
    }

    let mut rng = Rng::new(spec.seed);

    // 1. Generate base records.
    let mut records: Vec<CoreRecord> = Vec::with_capacity(spec.n_records);
    for id in 0..spec.n_records {
        let null_title = rng.chance(spec.null_title_rate);
        let null_abstract = rng.chance(spec.null_abstract_rate);
        records.push(CoreRecord::generate(
            &mut rng,
            id as u64,
            spec.html_noise_rate,
            null_title,
            null_abstract,
        ));
    }

    // 2. Inject duplicates: copies of random records spliced at random
    //    positions (CORE hosts multiple versions of the same article).
    let n_dups = ((spec.n_records as f64) * spec.dup_rate) as usize;
    for _ in 0..n_dups {
        let src = rng.gen_range(records.len());
        let dup = records[src].clone();
        let pos = rng.gen_range(records.len() + 1);
        records.insert(pos, dup);
    }

    // 3. Deal records to files proportionally to skewed weights.
    let n_files = spec.n_files.max(1);
    let weights: Vec<usize> = (0..n_files).map(|_| rng.skewed_size(1000)).collect();
    let total_w: usize = weights.iter().sum();
    let mut counts: Vec<usize> = weights
        .iter()
        .map(|w| (records.len() * w + total_w / 2) / total_w)
        .collect();
    // Fix rounding drift.
    let mut assigned: usize = counts.iter().sum();
    let mut i = 0;
    while assigned < records.len() {
        counts[i % n_files] += 1;
        assigned += 1;
        i += 1;
    }
    while assigned > records.len() {
        let j = counts.iter().position(|&c| c > 0).unwrap();
        counts[j] -= 1;
        assigned -= 1;
    }

    // 4. Write shards.
    let mut total_bytes = 0u64;
    let mut offset = 0usize;
    let mut buf = String::new();
    for (fi, &count) in counts.iter().enumerate() {
        let slice = &records[offset..offset + count];
        offset += count;
        let as_array = rng.chance(spec.array_file_rate);
        buf.clear();
        if as_array {
            buf.push_str("[\n");
            for (ri, r) in slice.iter().enumerate() {
                if ri > 0 {
                    buf.push_str(",\n");
                }
                write_value(&r.to_json(), &mut buf);
            }
            buf.push_str("\n]\n");
        } else {
            for r in slice {
                write_value(&r.to_json(), &mut buf);
                buf.push('\n');
            }
        }
        let path = dir.join(format!("shard-{fi:04}.json"));
        let mut f = fs::File::create(&path)?;
        f.write_all(buf.as_bytes())?;
        total_bytes += buf.len() as u64;
    }

    let manifest = CorpusManifest {
        dir: dir.to_path_buf(),
        seed: spec.seed,
        n_records: records.len(),
        n_duplicates: n_dups,
        n_files,
        total_bytes,
    };
    fs::write(
        dir.join("manifest.txt"),
        format!(
            "seed={}\nrecords={}\nduplicates={}\nfiles={}\nbytes={}\n",
            manifest.seed,
            manifest.n_records,
            manifest.n_duplicates,
            manifest.n_files,
            manifest.total_bytes
        ),
    )?;
    Ok(manifest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse_document;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("p3sapp-test-{name}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn generates_expected_record_count() {
        let dir = tmpdir("count");
        let spec = CorpusSpec::tiny(42);
        let m = generate_corpus(&spec, &dir).unwrap();
        assert_eq!(m.n_files, spec.n_files);
        assert!(m.n_records >= spec.n_records);

        // Every shard parses; record total matches the manifest.
        let mut total = 0;
        for fi in 0..m.n_files {
            let text = fs::read_to_string(dir.join(format!("shard-{fi:04}.json"))).unwrap();
            total += parse_document(&text).unwrap().len();
        }
        assert_eq!(total, m.n_records);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn deterministic_bytes_for_seed() {
        let d1 = tmpdir("det1");
        let d2 = tmpdir("det2");
        let spec = CorpusSpec::tiny(7);
        generate_corpus(&spec, &d1).unwrap();
        generate_corpus(&spec, &d2).unwrap();
        let a = fs::read(d1.join("shard-0000.json")).unwrap();
        let b = fs::read(d2.join("shard-0000.json")).unwrap();
        assert_eq!(a, b);
        fs::remove_dir_all(&d1).unwrap();
        fs::remove_dir_all(&d2).unwrap();
    }

    #[test]
    fn contains_nulls_and_duplicates() {
        let dir = tmpdir("nulls");
        let spec = CorpusSpec::tiny(13);
        let m = generate_corpus(&spec, &dir).unwrap();
        assert!(m.n_duplicates > 0);
        let mut titles_null = 0usize;
        let mut seen = std::collections::HashSet::new();
        let mut dups = 0usize;
        for fi in 0..m.n_files {
            let text = fs::read_to_string(dir.join(format!("shard-{fi:04}.json"))).unwrap();
            for rec in parse_document(&text).unwrap() {
                match rec.get_str("title") {
                    None => titles_null += 1,
                    Some(t) => {
                        if !seen.insert(t.to_string()) {
                            dups += 1;
                        }
                    }
                }
            }
        }
        assert!(titles_null > 0, "no null titles generated");
        assert!(dups > 0, "no duplicate titles generated");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn file_sizes_are_skewed() {
        let dir = tmpdir("skew");
        let m = generate_corpus(&CorpusSpec::tiny(21), &dir).unwrap();
        let sizes: Vec<u64> = (0..m.n_files)
            .map(|fi| fs::metadata(dir.join(format!("shard-{fi:04}.json"))).unwrap().len())
            .collect();
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().min().unwrap();
        assert!(max > min * 2, "expected size skew, got min={min} max={max}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rerun_cleans_old_shards() {
        let dir = tmpdir("clean");
        generate_corpus(&CorpusSpec::tiny(1), &dir).unwrap();
        // Second run with fewer files must not leave stale shards behind.
        let mut small = CorpusSpec::tiny(1);
        small.n_files = 2;
        small.n_records = 50;
        let m = generate_corpus(&small, &dir).unwrap();
        let shards = fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().path().extension().map(|x| x == "json") == Some(true)
            })
            .count();
        assert_eq!(shards, m.n_files);
        fs::remove_dir_all(&dir).unwrap();
    }
}
