//! Word pools for synthetic scholarly text. Sampled Zipf-ishly so the
//! generated corpus has a natural head-heavy frequency profile (matters
//! for vocabulary building and stopword hit rates).

/// Domain/content words (titles and abstracts draw from here).
/// Ordered roughly frequent→rare; `Rng::zipfish` indexes into this.
pub const CONTENT: &[&str] = &[
    "data", "model", "learning", "analysis", "system", "network", "approach", "method",
    "algorithm", "performance", "research", "information", "results", "framework", "deep",
    "neural", "classification", "detection", "evaluation", "optimization", "clustering",
    "feature", "image", "text", "language", "processing", "recognition", "prediction",
    "knowledge", "semantic", "distributed", "parallel", "efficient", "scalable", "novel",
    "hybrid", "adaptive", "dynamic", "statistical", "bayesian", "probabilistic", "graph",
    "structure", "architecture", "training", "inference", "accuracy", "precision", "recall",
    "dataset", "corpus", "benchmark", "experiment", "simulation", "implementation",
    "computation", "memory", "storage", "cloud", "cluster", "stream", "pipeline", "query",
    "index", "retrieval", "recommendation", "ranking", "embedding", "representation",
    "attention", "transformer", "recurrent", "convolutional", "sequence", "temporal",
    "spatial", "hierarchical", "supervised", "unsupervised", "reinforcement", "transfer",
    "domain", "task", "application", "service", "platform", "protocol", "security",
    "privacy", "encryption", "authentication", "wireless", "sensor", "mobile", "energy",
    "latency", "throughput", "bandwidth", "scheduling", "allocation", "resource",
    "virtualization", "container", "microservice", "database", "transaction", "consistency",
    "replication", "partition", "consensus", "fault", "tolerance", "recovery", "monitoring",
    "visualization", "interface", "interaction", "usability", "cognitive", "behavioral",
    "social", "citation", "scholarly", "bibliographic", "metadata", "ontology", "taxonomy",
    "genomic", "protein", "molecular", "clinical", "diagnosis", "treatment", "epidemic",
    "biological", "chemical", "physical", "quantum", "photonic", "semiconductor",
    "robotics", "autonomous", "vehicle", "navigation", "localization", "mapping",
    "segmentation", "synthesis", "generation", "summarization", "translation", "parsing",
    "tagging", "annotation", "extraction", "mining", "warehouse", "federated", "edge",
    "fog", "blockchain", "ledger", "contract", "incentive", "auction", "game", "equilibrium",
    "topology", "spectral", "manifold", "kernel", "regression", "ensemble", "boosting",
    "pruning", "quantization", "compression", "distillation", "augmentation",
    "regularization", "convergence", "gradient", "stochastic", "variational", "generative",
    "adversarial", "encoder", "decoder", "latent", "posterior", "likelihood", "entropy",
    "divergence", "metric", "similarity", "distance", "alignment", "matching", "fusion",
    "multimodal", "crossmodal", "heterogeneous", "longitudinal", "cohort", "survey",
    "review", "taxonomy", "tutorial", "perspective", "empirical", "theoretical",
];

/// Function words / connectives (never removed by content sampling,
/// guarantee stopword-stage work).
pub const CONNECTIVES: &[&str] = &[
    "the", "of", "and", "for", "in", "on", "with", "a", "an", "to", "using", "based",
    "via", "from", "towards", "through", "between", "under", "over", "by", "at", "as",
];

/// Sentence-level templates for abstracts: `{c}` slots take content
/// words, `{C}` a content bigram. Chosen to exercise every cleaning
/// stage (contractions, parentheses, digits, punctuation).
pub const SENTENCE_TEMPLATES: &[&str] = &[
    "this paper presents a {c} {c} for {c} {c}.",
    "we propose a novel {c} approach to {c} {c}, improving {c} by 12.5% over baselines.",
    "it's shown that {c} {c} doesn't degrade under {c} constraints.",
    "experimental results (on 5 datasets) demonstrate the {c} of our {c} {c}.",
    "the proposed {C} outperforms state-of-the-art {c} methods.",
    "we evaluate {c} {c} on large-scale {c} workloads, reporting {c} and {c}.",
    "a comprehensive study of {c} {c} reveals significant {c} gains.",
    "our {c} framework integrates {c} and {c} for end-to-end {c}.",
    "furthermore, the {c} analysis confirms that {c} can't explain the observed {c}.",
    "these findings suggest {c} {c} as a promising direction for {c} research.",
];

/// Author surname pool.
pub const SURNAMES: &[&str] = &[
    "Smith", "Chen", "Kumar", "Müller", "Garcia", "Kim", "Tanaka", "Ivanov", "Silva",
    "Ahmed", "Olsen", "Novak", "Rossi", "Dubois", "Park", "Wang", "Singh", "Khan",
    "Larsen", "Costa", "Haddad", "Okafor", "Nakamura", "Petrov", "Andersen",
];

/// Journal name fragments.
pub const JOURNALS: &[&str] = &[
    "Journal of Data Science", "Transactions on Computing", "Information Systems Review",
    "Proceedings of Machine Intelligence", "Scholarly Analytics Quarterly",
    "International Review of Networks", "Computational Methods Letters",
];

/// Publishers.
pub const PUBLISHERS: &[&str] =
    &["Elsevier", "Springer", "IEEE", "ACM", "Wiley", "MDPI", "Taylor & Francis"];

/// Subjects / topics.
pub const SUBJECTS: &[&str] = &[
    "Computer Science", "Information Science", "Applied Mathematics", "Bioinformatics",
    "Physics", "Electrical Engineering", "Digital Libraries", "Statistics",
];

/// Languages (weighting toward null/en like CORE).
pub const LANGUAGES: &[&str] = &["en", "en", "en", "de", "fr", "es", "pt", "zh"];

/// HTML noise snippets injected into a fraction of titles/abstracts —
/// the tags/entities real publisher feeds leak into CORE metadata.
pub const HTML_NOISE_WRAP: &[(&str, &str)] = &[
    ("<p>", "</p>"),
    ("<i>", "</i>"),
    ("<b>", "</b>"),
    ("<sub>", "</sub>"),
    ("<span class=\"title\">", "</span>"),
    ("<jats:title>", "</jats:title>"),
];

/// Inline entity noise.
pub const HTML_NOISE_INLINE: &[&str] =
    &["&amp;", "&lt;i&gt;", "&nbsp;", "<br/>", "&#8212;", "<!-- note -->"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::textutil::stopwords::is_stopword;

    #[test]
    fn pools_nonempty_and_lowercase_content() {
        assert!(CONTENT.len() > 150);
        for w in CONTENT {
            assert_eq!(*w, w.to_lowercase(), "content words must be lowercase");
        }
    }

    #[test]
    fn connectives_overlap_stopword_list() {
        let hits = CONNECTIVES.iter().filter(|w| is_stopword(w)).count();
        assert!(hits >= CONNECTIVES.len() / 2, "stopword stage must get work: {hits}");
    }

    #[test]
    fn templates_have_slots() {
        for t in SENTENCE_TEMPLATES {
            assert!(t.contains("{c}") || t.contains("{C}"));
        }
    }
}
