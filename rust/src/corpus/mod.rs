//! Synthetic CORE-corpus generator — the stand-in for the paper's 330 GB
//! CORE dataset (see DESIGN.md substitution table). Deterministic in the
//! spec seed; emits sharded JSON files with CORE's schema, realistic
//! null/duplicate rates, HTML noise, and heavy file-size skew.

pub mod record;
pub mod rng;
pub mod spec;
pub mod words;
mod writer;

pub use record::CoreRecord;
pub use rng::Rng;
pub use spec::CorpusSpec;
pub use writer::{generate_corpus, CorpusManifest};
