//! CORE-schema record synthesis (the JSON structure of §5 of the paper,
//! reproduced field-for-field).

use super::rng::Rng;
use super::words;
use crate::json::Json;
use std::collections::BTreeMap;

/// One synthetic scholarly record, mirroring the CORE metadata schema the
/// paper lists in §5. Only `title` and `abstract` are projected by the
/// case-study ingestion; everything else exists to make the files
/// realistically heavy (parse a lot, keep a little).
#[derive(Debug, Clone)]
pub struct CoreRecord {
    pub doi: Option<String>,
    pub core_id: String,
    pub oai: Option<String>,
    pub identifiers: Vec<String>,
    pub title: Option<String>,
    pub authors: Vec<String>,
    pub contributors: Vec<String>,
    pub date_published: Option<String>,
    pub abstract_text: Option<String>,
    pub download_url: Option<String>,
    pub full_text_identifier: Option<String>,
    pub pdf_hash: Option<String>,
    pub publisher: Option<String>,
    pub raw_record_xml: Option<String>,
    pub journals: Vec<String>,
    pub language: Option<String>,
    pub relations: Vec<String>,
    pub year: Option<i64>,
    pub topics: Vec<String>,
    pub subjects: Vec<String>,
    pub full_text: Option<String>,
    pub references: Vec<String>,
    pub document_type: Option<String>,
}

/// Generate a content phrase of `n` words, Zipf-sampled with occasional
/// connectives, capitalised per `titlecase`.
pub fn phrase(rng: &mut Rng, n: usize, titlecase: bool) -> String {
    let mut out = String::with_capacity(n * 8);
    for i in 0..n {
        if i > 0 {
            out.push(' ');
        }
        let w = if i > 0 && rng.chance(0.25) {
            *rng.choice(words::CONNECTIVES)
        } else {
            words::CONTENT[rng.zipfish(words::CONTENT.len())]
        };
        if titlecase && (i == 0 || w.len() > 3) {
            let mut cs = w.chars();
            if let Some(c0) = cs.next() {
                out.extend(c0.to_uppercase());
                out.push_str(cs.as_str());
            }
        } else {
            out.push_str(w);
        }
    }
    out
}

/// Generate an abstract of `n_sentences` templated sentences.
pub fn abstract_text(rng: &mut Rng, n_sentences: usize) -> String {
    let mut out = String::with_capacity(n_sentences * 90);
    for i in 0..n_sentences {
        if i > 0 {
            out.push(' ');
        }
        let template = *rng.choice(words::SENTENCE_TEMPLATES);
        let mut rest = template;
        while let Some(pos) = rest.find('{') {
            out.push_str(&rest[..pos]);
            let kind = &rest[pos + 1..pos + 2];
            match kind {
                "C" => {
                    out.push_str(&phrase(rng, 2, false));
                }
                _ => {
                    out.push_str(words::CONTENT[rng.zipfish(words::CONTENT.len())]);
                }
            }
            rest = &rest[pos + 3..];
        }
        out.push_str(rest);
    }
    out
}

/// Build a title out of an abstract's salient content words (plus
/// occasional connectives), in order of appearance — the summarization
/// relationship the case-study model is supposed to learn.
pub fn title_from_abstract(rng: &mut Rng, abstract_body: &str) -> String {
    use crate::textutil::stopwords::is_stopword;
    let content: Vec<&str> = abstract_body
        .split_whitespace()
        .map(|w| w.trim_matches(|c: char| !c.is_ascii_alphabetic()))
        .filter(|w| w.len() > 3 && !is_stopword(w))
        .collect();
    if content.is_empty() {
        return phrase(rng, 4, true);
    }
    let n_words = (3 + rng.gen_range(6)).min(content.len());
    // Sample positions without replacement, keep appearance order.
    let mut picks: Vec<usize> = Vec::with_capacity(n_words);
    while picks.len() < n_words {
        let idx = rng.zipfish(content.len());
        if !picks.contains(&idx) {
            picks.push(idx);
        }
    }
    picks.sort_unstable();
    let mut out = String::with_capacity(n_words * 10);
    for (i, &idx) in picks.iter().enumerate() {
        if i > 0 {
            out.push(' ');
            if rng.chance(0.2) {
                out.push_str(*rng.choice(words::CONNECTIVES));
                out.push(' ');
            }
        }
        let w = content[idx];
        let mut cs = w.chars();
        if let Some(c0) = cs.next() {
            out.extend(c0.to_uppercase());
            out.push_str(cs.as_str());
        }
    }
    out
}

/// Wrap `text` in HTML noise with probability `p` (tag wrap) and inject
/// inline entities with probability `p/2`.
pub fn add_html_noise(rng: &mut Rng, text: String, p: f64) -> String {
    let mut t = text;
    if rng.chance(p) {
        let (open, close) = *rng.choice(words::HTML_NOISE_WRAP);
        t = format!("{open}{t}{close}");
    }
    if rng.chance(p / 2.0) {
        // Splice an inline entity at a word boundary.
        if let Some(pos) = t[..t.len() / 2].rfind(' ') {
            let noise = *rng.choice(words::HTML_NOISE_INLINE);
            t = format!("{} {} {}", &t[..pos], noise, &t[pos + 1..]);
        }
    }
    t
}

impl CoreRecord {
    /// Synthesize one record. `noise` controls HTML-noise probability;
    /// `null_title` / `null_abstract` force those fields to null
    /// (injected upstream at spec-configured rates).
    pub fn generate(
        rng: &mut Rng,
        id: u64,
        noise: f64,
        null_title: bool,
        null_abstract: bool,
    ) -> CoreRecord {
        let year = 1990 + rng.gen_range(34) as i64;
        let n_authors = 1 + rng.gen_range(5);
        let authors: Vec<String> = (0..n_authors)
            .map(|_| {
                format!(
                    "{}. {}",
                    (b'A' + rng.gen_range(26) as u8) as char,
                    rng.choice(words::SURNAMES)
                )
            })
            .collect();
        // Abstract first; the title is then *derived from it* (titles
        // summarize their abstract) so the case-study seq2seq task has a
        // learnable abstract→title mapping, like real scholarly data.
        let n = 3 + rng.gen_range(6);
        let abstract_body = abstract_text(rng, n);
        let title = if null_title {
            None
        } else {
            let t = title_from_abstract(rng, &abstract_body);
            Some(add_html_noise(rng, t, noise))
        };
        let abstract_txt = if null_abstract {
            None
        } else {
            Some(add_html_noise(rng, abstract_body.clone(), noise))
        };
        let doi = if rng.chance(0.8) {
            Some(format!("10.{}/synth.{}", 1000 + rng.gen_range(9000), id))
        } else {
            None
        };
        let n_refs = rng.gen_range(12);
        let references: Vec<String> = (0..n_refs)
            .map(|_| format!("{} ({}). {}.", rng.choice(words::SURNAMES), year, phrase(rng, 6, true)))
            .collect();
        let full_text = if rng.chance(0.15) {
            // A minority of records carry a body snippet — keeps average
            // record weight up without ballooning generation time.
            let n = 12 + rng.gen_range(12);
            Some(abstract_text(rng, n))
        } else {
            None
        };
        CoreRecord {
            doi,
            core_id: format!("core-{id}"),
            oai: rng.chance(0.7).then(|| format!("oai:synth.org:{id}")),
            identifiers: vec![format!("synth:{id}")],
            title,
            authors,
            contributors: Vec::new(),
            date_published: Some(format!("{year}-{:02}-01", 1 + rng.gen_range(12))),
            abstract_text: abstract_txt,
            download_url: rng.chance(0.6).then(|| format!("https://synth.org/pdf/{id}.pdf")),
            full_text_identifier: None,
            pdf_hash: rng.chance(0.5).then(|| format!("{:016x}", rng.next_u64())),
            publisher: Some(rng.choice(words::PUBLISHERS).to_string()),
            raw_record_xml: rng
                .chance(0.3)
                .then(|| format!("<record id=\"{id}\"><status>ok</status></record>")),
            journals: vec![rng.choice(words::JOURNALS).to_string()],
            language: rng.chance(0.85).then(|| rng.choice(words::LANGUAGES).to_string()),
            relations: Vec::new(),
            year: Some(year),
            topics: vec![rng.choice(words::SUBJECTS).to_string()],
            subjects: vec![rng.choice(words::SUBJECTS).to_string()],
            full_text,
            references,
            document_type: Some("research".into()),
        }
    }

    /// Serialize to the CORE JSON layout.
    pub fn to_json(&self) -> Json {
        fn s(v: &Option<String>) -> Json {
            v.as_ref().map(|x| Json::Str(x.clone())).unwrap_or(Json::Null)
        }
        fn arr(v: &[String]) -> Json {
            Json::Arr(v.iter().map(|x| Json::Str(x.clone())).collect())
        }
        let mut o = BTreeMap::new();
        o.insert("doi".into(), s(&self.doi));
        o.insert("coreId".into(), Json::Str(self.core_id.clone()));
        o.insert("oai".into(), s(&self.oai));
        o.insert("identifiers".into(), arr(&self.identifiers));
        o.insert("title".into(), s(&self.title));
        o.insert("authors".into(), arr(&self.authors));
        let mut enrich = BTreeMap::new();
        enrich.insert("references".into(), arr(&self.references));
        let mut dt = BTreeMap::new();
        dt.insert("type".into(), s(&self.document_type));
        dt.insert("confidence".into(), Json::Str("0.9".into()));
        enrich.insert("documentType".into(), Json::Obj(dt));
        o.insert("enrichments".into(), Json::Obj(enrich));
        o.insert("contributors".into(), arr(&self.contributors));
        o.insert("datePublished".into(), s(&self.date_published));
        o.insert("abstract".into(), s(&self.abstract_text));
        o.insert("downloadUrl".into(), s(&self.download_url));
        o.insert("fullTextIdentifier".into(), s(&self.full_text_identifier));
        o.insert("pdfHashValue".into(), s(&self.pdf_hash));
        o.insert("publisher".into(), s(&self.publisher));
        o.insert("rawRecordXml".into(), s(&self.raw_record_xml));
        o.insert("journals".into(), arr(&self.journals));
        o.insert("language".into(), s(&self.language));
        o.insert("relations".into(), arr(&self.relations));
        o.insert(
            "year".into(),
            self.year.map(|y| Json::Num(y as f64)).unwrap_or(Json::Null),
        );
        o.insert("topics".into(), arr(&self.topics));
        o.insert("subjects".into(), arr(&self.subjects));
        o.insert("fullText".into(), s(&self.full_text));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_json_has_core_fields() {
        let mut rng = Rng::new(1);
        let r = CoreRecord::generate(&mut rng, 7, 0.3, false, false);
        let j = r.to_json();
        for key in ["doi", "coreId", "title", "abstract", "authors", "year", "fullText"] {
            assert!(j.as_obj().unwrap().contains_key(key), "missing {key}");
        }
        assert_eq!(j.get_str("coreId"), Some("core-7"));
        assert!(j.get_str("title").is_some());
    }

    #[test]
    fn null_injection_respected() {
        let mut rng = Rng::new(2);
        let r = CoreRecord::generate(&mut rng, 1, 0.0, true, true);
        assert!(r.title.is_none());
        assert!(r.abstract_text.is_none());
        let j = r.to_json();
        assert_eq!(j.get_str("title"), None);
        assert_eq!(j.get_str("abstract"), None);
    }

    #[test]
    fn generation_is_deterministic() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let ra = CoreRecord::generate(&mut a, 1, 0.2, false, false);
        let rb = CoreRecord::generate(&mut b, 1, 0.2, false, false);
        assert_eq!(ra.to_json().to_string(), rb.to_json().to_string());
    }

    #[test]
    fn html_noise_appears_at_high_p() {
        let mut rng = Rng::new(4);
        let mut saw_tag = false;
        for i in 0..50 {
            let r = CoreRecord::generate(&mut rng, i, 1.0, false, false);
            if r.title.unwrap().contains('<') {
                saw_tag = true;
                break;
            }
        }
        assert!(saw_tag);
    }

    #[test]
    fn abstract_sentences_end_with_period() {
        let mut rng = Rng::new(5);
        let a = abstract_text(&mut rng, 4);
        assert!(a.ends_with('.'));
        assert!(a.split(". ").count() >= 3);
    }
    #[test]
    fn title_words_come_from_abstract() {
        let mut rng = Rng::new(8);
        for i in 0..20 {
            let r = CoreRecord::generate(&mut rng, i, 0.0, false, false);
            let (title, abs) = (r.title.unwrap(), r.abstract_text.unwrap());
            let abs_lower = abs.to_lowercase();
            let hits = title
                .split_whitespace()
                .filter(|w| abs_lower.contains(&w.to_lowercase()))
                .count();
            let total = title.split_whitespace().count();
            assert!(hits * 2 >= total, "title {title:?} not derived from abstract");
        }
    }
}
