//! Deterministic PRNG (xoshiro256**) — the corpus must be byte-identical
//! across runs for a given seed so CA and P3SAPP see the same input and
//! EXPERIMENTS.md numbers are reproducible. No external rand crate.

/// xoshiro256** by Blackman & Vigna (public domain reference
/// implementation, ported).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection-free
    /// approximation (bias < 2^-64 * n, irrelevant here).
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Uniform choice from a slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.gen_range(items.len())]
    }

    /// Zipf-ish rank sampling over `[0, n)`: heavy head, long tail —
    /// approximates natural word-frequency distribution by squaring a
    /// uniform variate (exact Zipf is needlessly expensive here).
    pub fn zipfish(&mut self, n: usize) -> usize {
        let u = self.gen_f64();
        let r = (u * u * n as f64) as usize;
        r.min(n - 1)
    }

    /// Log-normal-ish positive size with median `median` and heavy upper
    /// tail — models CORE's KB→GB file-size skew at our scale.
    pub fn skewed_size(&mut self, median: usize) -> usize {
        // exp of a centered triangular variate ≈ lognormal shape.
        let t = self.gen_f64() + self.gen_f64() - 1.0; // [-1, 1) triangular
        let factor = (t * 2.2f64).exp(); // median 1.0, tail ~9x
        ((median as f64) * factor) as usize + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..4).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.gen_range(13) < 13);
        }
    }

    #[test]
    fn gen_f64_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn zipfish_head_heavy() {
        let mut r = Rng::new(3);
        let n = 1000;
        let head = (0..10_000).filter(|_| r.zipfish(n) < n / 10).count();
        // Squared-uniform puts ~31.6% of mass in the first decile.
        assert!(head > 2500, "head draws: {head}");
    }

    #[test]
    fn skewed_size_positive_with_tail() {
        let mut r = Rng::new(5);
        let sizes: Vec<usize> = (0..10_000).map(|_| r.skewed_size(1000)).collect();
        assert!(sizes.iter().all(|&s| s > 0));
        assert!(sizes.iter().any(|&s| s > 3000), "has heavy tail");
        assert!(sizes.iter().any(|&s| s < 400), "has light tail");
    }
}
