//! Corpus specification: the knobs that define one synthetic dataset
//! tier (record count, shard layout, noise/null/duplicate rates).

/// Parameters of one generated corpus.
#[derive(Debug, Clone)]
pub struct CorpusSpec {
    /// PRNG seed — fixes the corpus bytes completely.
    pub seed: u64,
    /// Total records before duplicate injection.
    pub n_records: usize,
    /// Number of shard files (variable sizes, KB→MB skew).
    pub n_files: usize,
    /// Probability a record's title is null.
    pub null_title_rate: f64,
    /// Probability a record's abstract is null.
    pub null_abstract_rate: f64,
    /// Fraction of extra duplicated records appended (CORE carries
    /// multiple copies/versions of many articles).
    pub dup_rate: f64,
    /// Probability of HTML noise on title/abstract.
    pub html_noise_rate: f64,
    /// Fraction of files written as JSON arrays (rest are JSON-lines).
    pub array_file_rate: f64,
}

impl CorpusSpec {
    /// Tiny corpus for unit tests and the quickstart example.
    pub fn tiny(seed: u64) -> Self {
        CorpusSpec {
            seed,
            n_records: 300,
            n_files: 6,
            null_title_rate: 0.05,
            null_abstract_rate: 0.08,
            dup_rate: 0.04,
            html_noise_rate: 0.3,
            array_file_rate: 0.5,
        }
    }

    /// Experiment tier `id` in 1..=5, mirroring the paper's five CORE
    /// subsets (4.18→23.58 GB). Record counts are the paper's Table 5
    /// counts at 1/10 scale (88,709→480,712 becomes 8,871→48,071), so
    /// the growth curve — and CA's superlinear append blow-up, which
    /// needs both rows *and* file count — is preserved while a full
    /// 5-tier suite still finishes in minutes on a 2-core box. File
    /// counts scale toward the paper's 2085-file corpus the same way.
    pub fn tier(id: usize, seed: u64) -> Self {
        assert!((1..=5).contains(&id), "tier must be 1..=5");
        const ROWS: [usize; 5] = [8871, 13268, 25636, 34517, 48071];
        const FILES: [usize; 5] = [150, 250, 380, 520, 700];
        CorpusSpec {
            seed: seed.wrapping_add(id as u64),
            n_records: ROWS[id - 1],
            n_files: FILES[id - 1],
            null_title_rate: 0.05,
            null_abstract_rate: 0.10,
            dup_rate: 0.05,
            html_noise_rate: 0.3,
            array_file_rate: 0.5,
        }
    }

    /// Scale every tier by `factor` (perf runs use >1).
    pub fn scaled(mut self, factor: f64) -> Self {
        self.n_records = ((self.n_records as f64) * factor).max(1.0) as usize;
        self.n_files = ((self.n_files as f64) * factor.sqrt()).max(1.0) as usize;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiers_grow_monotonically() {
        let mut prev = 0;
        for id in 1..=5 {
            let s = CorpusSpec::tier(id, 42);
            assert!(s.n_records > prev);
            prev = s.n_records;
        }
    }

    #[test]
    fn tier_growth_matches_paper_ratio() {
        let t1 = CorpusSpec::tier(1, 0).n_records as f64;
        let t5 = CorpusSpec::tier(5, 0).n_records as f64;
        let ratio = t5 / t1;
        // Paper: 480712 / 88709 = 5.42
        assert!((ratio - 5.42).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    #[should_panic]
    fn tier_out_of_range_panics() {
        CorpusSpec::tier(6, 0);
    }

    #[test]
    fn scaled_changes_records() {
        let s = CorpusSpec::tiny(1).scaled(2.0);
        assert_eq!(s.n_records, 600);
    }
}
