//! `benchgate` — the CI bench-regression gate.
//!
//! Usage: `benchgate [--threshold 0.25] <record.json> <current.json> [...]`
//! (paths in pairs: the checked-in repo-root record, then the freshly
//! measured `target/BENCH_*.json`).
//!
//! For every pair, each tracked arm (every record arm past the first)
//! is compared as its **ratio to the record's first arm** — absolute
//! seconds differ per runner, ratios to a reference workload measured
//! in the same run do not. A ratio that grew more than the threshold
//! (default +25%) fails the gate; a record with an empty `arms` list
//! (the pre-baseline schema placeholder) only warns, so the gate can be
//! landed before a baseline exists.
//!
//!     cargo run --release --bin benchgate -- \
//!         BENCH_streaming.json target/BENCH_streaming.json \
//!         BENCH_cache.json     target/BENCH_cache.json

use p3sapp::benchkit::{gate, parse_bench_record, BenchRecord};

fn load(path: &str) -> p3sapp::Result<BenchRecord> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {path}: {e}"))?;
    parse_bench_record(&text).map_err(|e| anyhow::anyhow!("parse {path}: {e}"))
}

fn run(args: &[String]) -> p3sapp::Result<bool> {
    let mut threshold = 0.25f64;
    let mut paths: Vec<&str> = Vec::new();
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        if arg == "--threshold" {
            let v = it
                .next()
                .ok_or_else(|| anyhow::anyhow!("--threshold expects a value"))?;
            threshold = v
                .parse()
                .map_err(|_| anyhow::anyhow!("--threshold expects a number, got '{v}'"))?;
        } else {
            paths.push(arg);
        }
    }
    anyhow::ensure!(
        !paths.is_empty() && paths.len() % 2 == 0,
        "usage: benchgate [--threshold F] <record.json> <current.json> [more pairs...]"
    );

    let mut all_pass = true;
    for pair in paths.chunks(2) {
        let (record_path, current_path) = (pair[0], pair[1]);
        let record = load(record_path)?;
        let current = load(current_path)?;
        let report = gate(&record, &current, threshold);
        println!("== {record_path} vs {current_path} ==");
        if report.no_baseline {
            println!(
                "  warn: no baseline arms in {record_path} — gate skipped \
                 (populate the record to arm it)"
            );
            continue;
        }
        for line in &report.lines {
            println!("  {line}");
        }
        // A provisional baseline (ratios not yet measured on the gating
        // hardware) reports regressions without failing the build — the
        // record must be re-baselined from a measured run to arm it.
        for f in &report.failures {
            if record.provisional {
                println!("  WARN (provisional baseline): {f}");
            } else {
                println!("  FAIL: {f}");
                all_pass = false;
            }
        }
        if report.failures.is_empty() {
            println!("  pass (threshold {:.0}%)", threshold * 100.0);
        } else if record.provisional {
            println!(
                "  provisional pass — re-baseline {record_path} from a measured \
                 run and drop \"provisional\" to arm the gate"
            );
        }
    }
    Ok(all_pass)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(true) => {}
        Ok(false) => {
            eprintln!("benchgate: tracked arm regression detected");
            std::process::exit(1);
        }
        Err(e) => {
            eprintln!("benchgate: error: {e:#}");
            std::process::exit(2);
        }
    }
}
