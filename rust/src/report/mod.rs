//! Experiment harness + paper-table renderers. Every table and figure of
//! the paper's evaluation (§5–§6) is regenerated from here; the criterion
//! benches and the `repro report` CLI both delegate to this module.

mod experiments;
mod tables;
mod text_table;

pub use experiments::{run_tier, run_suite, SuiteOptions, SuiteResult, TierResult};
pub use tables::*;
pub use text_table::TextTable;
