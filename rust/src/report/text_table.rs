//! Aligned plain-text table rendering (the tables the paper prints,
//! reproduced as console output and CSV).

/// Column-aligned text table builder.
#[derive(Debug, Clone, Default)]
pub struct TextTable {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        TextTable {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        debug_assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with padded columns.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&format!("{:width$}", c, width = widths[i]));
            }
            out.push('\n');
        };
        line(&self.header, &mut out);
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }

    /// CSV form (for plotting the figures).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format seconds with 3 decimals (the paper's table precision).
pub fn secs(v: f64) -> String {
    format!("{v:.3}")
}

/// Format a percentage with 3 decimals.
pub fn pct(v: f64) -> String {
    format!("{v:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new("Demo", &["id", "value"]);
        t.row(vec!["1".into(), "short".into()]);
        t.row(vec!["22".into(), "much longer cell".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
        assert!(lines[1].starts_with("id"));
    }

    #[test]
    fn csv_escapes() {
        let mut t = TextTable::new("x", &["a", "b"]);
        t.row(vec!["with,comma".into(), "with\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"with,comma\""));
        assert!(csv.contains("\"with\"\"quote\""));
    }
}
