//! Renderers: one function per paper table/figure. All take the measured
//! [`SuiteResult`] (and, where the paper needs it, the measured training
//! time model) and print the same rows/series the paper reports.

use super::experiments::SuiteResult;
use super::text_table::{pct, secs, TextTable};
use crate::analysis::accuracy::match_column;
use crate::analysis::cost::{evaluate, saving_to_mtt_ratio, CostInputs, EPOCH_SETTINGS};
use crate::analysis::trend::fit;
use crate::Result;

/// Measured training-cost model: per-step wall time from the runtime
/// trainer, scaled to per-epoch by each tier's row count (the paper's
/// MTT/epoch grows with dataset size the same way, Table 7).
#[derive(Debug, Clone, Copy)]
pub struct TrainTimeModel {
    pub sec_per_step: f64,
    pub batch_size: usize,
    /// Fraction of rows used for training (paper splits ~90/10,
    /// Table 8's training/validation columns).
    pub train_frac: f64,
}

impl TrainTimeModel {
    pub fn mtt_per_epoch(&self, rows: usize) -> f64 {
        let steps = ((rows as f64 * self.train_frac) / self.batch_size as f64).floor();
        steps.max(1.0) * self.sec_per_step
    }
}

/// Table 2 + Fig. 7 — ingestion time, CA vs P3SAPP, % reduction.
pub fn table2(suite: &SuiteResult) -> TextTable {
    let mut t = TextTable::new(
        "Table 2: Ingestion Time (CA vs P3SAPP)",
        &["Dataset ID", "Size (MB)", "CA (s)", "P3SAPP (s)", "Reduction (%)"],
    );
    for tier in &suite.tiers {
        let ca = tier.ca.as_ref().map(|c| c.ingestion_secs());
        t.row(vec![
            tier.tier.to_string(),
            format!("{:.2}", tier.size_mb()),
            ca.map(secs).unwrap_or_else(|| "-".into()),
            secs(tier.p3sapp.ingestion_secs()),
            tier.reduction_pct(|r| r.ingestion_secs())
                .map(pct)
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    t
}

/// Table 3 + Fig. 8 — preprocessing breakdown (pre/clean/post/total).
pub fn table3(suite: &SuiteResult) -> TextTable {
    let mut t = TextTable::new(
        "Table 3: Preprocessing Time breakdown (CA vs P3SAPP)",
        &[
            "Dataset ID",
            "Size (MB)",
            "Pre CA",
            "Pre P3",
            "Clean CA",
            "Clean P3",
            "Post CA",
            "Post P3",
            "Total CA",
            "Total P3",
            "Reduction (%)",
        ],
    );
    use crate::driver::{CLEANING, POST_CLEANING, PRE_CLEANING};
    for tier in &suite.tiers {
        let ca = tier.ca.as_ref();
        let g = |r: &crate::driver::PreprocessResult, k: &str| secs(r.times.secs(k));
        t.row(vec![
            tier.tier.to_string(),
            format!("{:.2}", tier.size_mb()),
            ca.map(|c| g(c, PRE_CLEANING)).unwrap_or_else(|| "-".into()),
            g(&tier.p3sapp, PRE_CLEANING),
            ca.map(|c| g(c, CLEANING)).unwrap_or_else(|| "-".into()),
            g(&tier.p3sapp, CLEANING),
            ca.map(|c| g(c, POST_CLEANING)).unwrap_or_else(|| "-".into()),
            g(&tier.p3sapp, POST_CLEANING),
            ca.map(|c| secs(c.preprocessing_secs())).unwrap_or_else(|| "-".into()),
            secs(tier.p3sapp.preprocessing_secs()),
            tier.reduction_pct(|r| r.preprocessing_secs())
                .map(pct)
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    t
}

/// Table 4 + Fig. 9 — cumulative time t_c = t_i + t_pp.
pub fn table4(suite: &SuiteResult) -> TextTable {
    let mut t = TextTable::new(
        "Table 4: Cumulative Time (CA vs P3SAPP)",
        &["Dataset ID", "Size (MB)", "CA (s)", "P3SAPP (s)", "Reduction (%)"],
    );
    for tier in &suite.tiers {
        t.row(vec![
            tier.tier.to_string(),
            format!("{:.2}", tier.size_mb()),
            tier.ca
                .as_ref()
                .map(|c| secs(c.cumulative_secs()))
                .unwrap_or_else(|| "-".into()),
            secs(tier.p3sapp.cumulative_secs()),
            tier.reduction_pct(|r| r.cumulative_secs())
                .map(pct)
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    t
}

/// Tables 5 & 6 — matching records for `column` ("title" or "abstract").
pub fn table5_6(suite: &SuiteResult, column: &str) -> Result<TextTable> {
    let label = if column == "title" { "5" } else { "6" };
    let mut t = TextTable::new(
        format!("Table {label}: Matching Records for Extracted {column}s"),
        &["Dataset ID", "CA rows", "P3SAPP rows", "Matching", "Percentage"],
    );
    for tier in &suite.tiers {
        let Some(ca) = tier.ca.as_ref() else {
            anyhow::bail!("accuracy table requires the CA run (suite ran with skip_ca)")
        };
        let m = match_column(&ca.frame, &tier.p3sapp.frame, column)?;
        t.row(vec![
            tier.tier.to_string(),
            m.rows_ca.to_string(),
            m.rows_p3sapp.to_string(),
            m.matching.to_string(),
            format!("{:.3}%", m.percentage),
        ]);
    }
    Ok(t)
}

/// Table 7 + Fig. 11 — cost-benefit at the paper's three epoch settings.
pub fn table7(suite: &SuiteResult, model: &TrainTimeModel) -> Result<TextTable> {
    let mut t = TextTable::new(
        "Table 7: Cost-Benefit Analysis",
        &[
            "Dataset ID",
            "t_c CA (s)",
            "t_c P3SAPP (s)",
            "MTT/epoch (s)",
            "T(10) CA h",
            "T(10) P3 h",
            "CB(10) %",
            "T(25) CA h",
            "T(25) P3 h",
            "CB(25) %",
            "T(50) CA h",
            "T(50) P3 h",
            "CB(50) %",
        ],
    );
    for tier in &suite.tiers {
        let Some(ca) = tier.ca.as_ref() else {
            anyhow::bail!("cost table requires the CA run")
        };
        let mtt = model.mtt_per_epoch(tier.p3sapp.rows_out);
        let inputs = CostInputs {
            tc_ca_secs: ca.cumulative_secs(),
            tc_p3sapp_secs: tier.p3sapp.cumulative_secs(),
            mtt_per_epoch_secs: mtt,
        };
        let mut cells = vec![
            tier.tier.to_string(),
            secs(inputs.tc_ca_secs),
            secs(inputs.tc_p3sapp_secs),
            secs(mtt),
        ];
        for &e in &EPOCH_SETTINGS {
            let row = evaluate(&inputs, e);
            cells.push(format!("{:.3}", row.total_ca_hours));
            cells.push(format!("{:.3}", row.total_p3sapp_hours));
            cells.push(format!("{:.3}", row.cost_benefit_pct));
        }
        t.row(cells);
    }
    Ok(t)
}

/// Table 8 + Fig. 13 — time saving expressed in MTT-per-epoch units.
pub fn table8(suite: &SuiteResult, model: &TrainTimeModel) -> Result<TextTable> {
    let mut t = TextTable::new(
        "Table 8: Time Saving in units of MTT/epoch",
        &[
            "Dataset ID",
            "Rows (train)",
            "Rows (val)",
            "MTT/epoch (s)",
            "Time Saving (s)",
            "Saving / MTT per epoch",
        ],
    );
    for tier in &suite.tiers {
        let Some(ca) = tier.ca.as_ref() else {
            anyhow::bail!("table 8 requires the CA run")
        };
        let rows = tier.p3sapp.rows_out;
        let train_rows = (rows as f64 * model.train_frac) as usize;
        let mtt = model.mtt_per_epoch(rows);
        let inputs = CostInputs {
            tc_ca_secs: ca.cumulative_secs(),
            tc_p3sapp_secs: tier.p3sapp.cumulative_secs(),
            mtt_per_epoch_secs: mtt,
        };
        t.row(vec![
            tier.tier.to_string(),
            train_rows.to_string(),
            (rows - train_rows).to_string(),
            secs(mtt),
            secs(inputs.tc_ca_secs - inputs.tc_p3sapp_secs),
            format!("{:.3}", saving_to_mtt_ratio(&inputs)),
        ]);
    }
    Ok(t)
}

/// Fig. 10 — linear trend of preprocessing time vs dataset size for both
/// approaches (slope comparison, §6).
pub fn fig10(suite: &SuiteResult) -> Result<TextTable> {
    let pts = |f: &dyn Fn(&crate::report::TierResult) -> Option<f64>| -> Vec<(f64, f64)> {
        suite
            .tiers
            .iter()
            .filter_map(|t| f(t).map(|y| (t.size_mb(), y)))
            .collect()
    };
    let ca_pts = pts(&|t| t.ca.as_ref().map(|c| c.preprocessing_secs()));
    let pa_pts = pts(&|t| Some(t.p3sapp.preprocessing_secs()));
    let mut t = TextTable::new(
        "Fig 10: Preprocessing-time trend lines (y = a*x + b over MB)",
        &["Series", "slope (s/MB)", "intercept (s)", "R^2"],
    );
    if let Some(l) = fit(&ca_pts) {
        t.row(vec!["CA".into(), format!("{:.4}", l.slope), format!("{:.4}", l.intercept), format!("{:.4}", l.r_squared)]);
    }
    if let Some(l) = fit(&pa_pts) {
        t.row(vec![
            "P3SAPP".into(),
            format!("{:.4}", l.slope),
            format!("{:.4}", l.intercept),
            format!("{:.4}", l.r_squared),
        ]);
    }
    anyhow::ensure!(t.num_rows() > 0, "fig10 needs >= 2 tiers");
    Ok(t)
}

/// Fig. 12 — summary of % reductions (ingestion/preprocessing/cumulative).
pub fn fig12(suite: &SuiteResult) -> TextTable {
    let mut t = TextTable::new(
        "Fig 12: Development time - Summary of results (% reduction)",
        &["Dataset ID", "Size (MB)", "Ingestion", "Preprocessing", "Cumulative"],
    );
    for tier in &suite.tiers {
        let f = |v: Option<f64>| v.map(pct).unwrap_or_else(|| "-".into());
        t.row(vec![
            tier.tier.to_string(),
            format!("{:.2}", tier.size_mb()),
            f(tier.reduction_pct(|r| r.ingestion_secs())),
            f(tier.reduction_pct(|r| r.preprocessing_secs())),
            f(tier.reduction_pct(|r| r.cumulative_secs())),
        ]);
    }
    t
}

/// Fig. 13 series — saving/MTT ratio per tier (rendered by table8; this
/// emits the CSV series for plotting).
pub fn fig13_csv(suite: &SuiteResult, model: &TrainTimeModel) -> Result<String> {
    Ok(table8(suite, model)?.to_csv())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::{run_suite, SuiteOptions};

    fn tiny_suite() -> SuiteResult {
        let base = std::env::temp_dir().join(format!("p3sapp-tbl-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let mut opts = SuiteOptions::new(&base);
        opts.scale = 0.08;
        opts.workers = 2;
        opts.tiers = vec![1, 2];
        run_suite(&opts).unwrap()
    }

    #[test]
    fn all_tables_render_from_suite() {
        let suite = tiny_suite();
        let model = TrainTimeModel { sec_per_step: 0.5, batch_size: 32, train_frac: 0.9 };
        assert_eq!(table2(&suite).num_rows(), 2);
        assert_eq!(table3(&suite).num_rows(), 2);
        assert_eq!(table4(&suite).num_rows(), 2);
        assert_eq!(table5_6(&suite, "title").unwrap().num_rows(), 2);
        assert_eq!(table5_6(&suite, "abstract").unwrap().num_rows(), 2);
        assert_eq!(table7(&suite, &model).unwrap().num_rows(), 2);
        assert_eq!(table8(&suite, &model).unwrap().num_rows(), 2);
        assert_eq!(fig10(&suite).unwrap().num_rows(), 2);
        assert_eq!(fig12(&suite).num_rows(), 2);
        assert!(fig13_csv(&suite, &model).unwrap().lines().count() >= 3);
        // Accuracy in our unified-substrate reproduction is 100% — the
        // paper's 93-98% stems from its two different ingestion stacks
        // (see EXPERIMENTS.md discussion).
        let acc = table5_6(&suite, "title").unwrap().render();
        assert!(acc.contains("100.000%"), "{acc}");
    }

    #[test]
    fn train_time_model_scales_with_rows() {
        let m = TrainTimeModel { sec_per_step: 2.0, batch_size: 32, train_frac: 0.9 };
        assert!(m.mtt_per_epoch(3200) > m.mtt_per_epoch(320));
        assert_eq!(m.mtt_per_epoch(10), 2.0, "at least one step per epoch");
    }
}
