//! Tiered experiment runner: generates (or reuses) the five corpus
//! tiers, runs both preprocessing approaches on each, and carries the
//! measured stage times into the table renderers.

use crate::corpus::{generate_corpus, CorpusSpec};
use crate::driver::{run_ca, run_p3sapp, DriverOptions, PreprocessResult};
use crate::ingest::list_shards;
use crate::Result;
use std::path::{Path, PathBuf};

/// Options for a full suite run.
#[derive(Debug, Clone)]
pub struct SuiteOptions {
    pub base_dir: PathBuf,
    pub seed: u64,
    /// Multiplies every tier's record count (perf runs use > 1).
    pub scale: f64,
    /// 0 = local[*].
    pub workers: usize,
    /// Tier ids to run (default 1..=5).
    pub tiers: Vec<usize>,
    /// Skip the (slow, superlinear) conventional approach — used by
    /// P3SAPP-only benches.
    pub skip_ca: bool,
    /// Print the P3SAPP execution plan (logical → optimized → physical)
    /// once per suite, so perf numbers in a report can be read next to
    /// what was actually fused.
    pub explain: bool,
    /// Which executor each tier's P3SAPP run uses (fused single pass,
    /// streaming pipeline, worker processes, warm pool or remote TCP
    /// endpoints); the EXPLAIN output names the same topology. The CA
    /// control stays in-process — it is the paper's eager baseline.
    pub executor: crate::plan::ExecutorKind,
    /// When set, each tier's P3SAPP run consults the persistent plan
    /// cache ([`crate::cache::CacheManager`]): a repeated `report` run
    /// (same corpus, same plan) restores every tier's frame instead of
    /// re-executing, and the EXPLAIN output renders the cache-hit path.
    /// The CA control never uses the cache.
    pub cache: Option<std::sync::Arc<crate::cache::CacheManager>>,
    /// Deterministic input sample `(fraction, seed)` for the P3SAPP
    /// runs (`--sample`): skipped records are never cleaned, so a
    /// sampled suite repeats the accuracy tables at a fraction of the
    /// cost. The CA control never samples — combine with `skip_ca`.
    pub sample: Option<(f64, u64)>,
    /// Clean-row cap for the P3SAPP runs (`--limit`).
    pub limit: Option<usize>,
}

impl SuiteOptions {
    pub fn new(base_dir: impl Into<PathBuf>) -> Self {
        SuiteOptions {
            base_dir: base_dir.into(),
            seed: 42,
            scale: 1.0,
            workers: 0,
            tiers: vec![1, 2, 3, 4, 5],
            skip_ca: false,
            explain: false,
            executor: crate::plan::ExecutorKind::Fused,
            cache: None,
            sample: None,
            limit: None,
        }
    }
}

/// Measured outcome for one tier.
#[derive(Debug, Clone)]
pub struct TierResult {
    pub tier: usize,
    pub corpus_dir: PathBuf,
    pub size_bytes: u64,
    pub n_files: usize,
    pub ca: Option<PreprocessResult>,
    pub p3sapp: PreprocessResult,
}

impl TierResult {
    pub fn size_mb(&self) -> f64 {
        self.size_bytes as f64 / (1024.0 * 1024.0)
    }

    /// % reduction of a time metric, CA → P3SAPP (guard: None if CA was
    /// skipped).
    pub fn reduction_pct(&self, f: impl Fn(&PreprocessResult) -> f64) -> Option<f64> {
        let ca = self.ca.as_ref()?;
        let (a, b) = (f(ca), f(&self.p3sapp));
        if a <= 0.0 {
            return Some(0.0);
        }
        Some((a - b) / a * 100.0)
    }
}

/// A full suite outcome (one per `repro report` invocation).
#[derive(Debug, Clone)]
pub struct SuiteResult {
    pub tiers: Vec<TierResult>,
    pub workers: usize,
}

/// Generate tier `id`'s corpus under `base_dir/tier-<id>` (reusing it if
/// the manifest matches) and run both approaches.
pub fn run_tier(opts: &SuiteOptions, tier: usize) -> Result<TierResult> {
    let dir = opts.base_dir.join(format!("tier-{tier}"));
    let spec = CorpusSpec::tier(tier, opts.seed).scaled(opts.scale);
    let manifest = ensure_corpus(&spec, &dir)?;
    let files = list_shards(&dir)?;

    let driver_opts = DriverOptions {
        workers: opts.workers,
        executor: opts.executor.clone(),
        cache: opts.cache.clone(),
        sample: opts.sample,
        limit: opts.limit,
        ..Default::default()
    };
    if opts.explain {
        // Print exactly the plan run_p3sapp is about to execute, built
        // from the same files, column config, plan variant (sample/
        // limit), executor choice and cache state (a warm cache renders
        // the restore path).
        let text = crate::cache::explain_with_cache(
            &driver_opts.build_plan(&files),
            driver_opts.workers,
            &driver_opts.executor,
            driver_opts.cache.as_deref(),
        )?;
        eprintln!("{text}");
    }
    let p3sapp = run_p3sapp(&files, &driver_opts)?;
    let ca = if opts.skip_ca { None } else { Some(run_ca(&files, &driver_opts)?) };

    Ok(TierResult {
        tier,
        corpus_dir: dir,
        size_bytes: manifest.total_bytes,
        n_files: manifest.n_files,
        ca,
        p3sapp,
    })
}

/// Run every requested tier.
pub fn run_suite(opts: &SuiteOptions) -> Result<SuiteResult> {
    let mut tiers = Vec::with_capacity(opts.tiers.len());
    // The plan only differs between tiers in its partition count, so
    // one EXPLAIN (printed by the first tier) documents the suite.
    let mut tier_opts = opts.clone();
    for &tier in &opts.tiers {
        eprintln!("[suite] tier {tier}: running ...");
        let r = run_tier(&tier_opts, tier)?;
        tier_opts.explain = false;
        eprintln!(
            "[suite] tier {tier}: {:.1} MB, {} files, P3SAPP t_c {:.3}s{}",
            r.size_mb(),
            r.n_files,
            r.p3sapp.cumulative_secs(),
            r.ca
                .as_ref()
                .map(|c| format!(", CA t_c {:.3}s", c.cumulative_secs()))
                .unwrap_or_default()
        );
        tiers.push(r);
    }
    let workers = if opts.workers == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2)
    } else {
        opts.workers
    };
    Ok(SuiteResult { tiers, workers })
}

/// Generate the corpus unless an identical-spec run already exists
/// (checked via manifest.txt seed/record fields).
fn ensure_corpus(spec: &CorpusSpec, dir: &Path) -> Result<crate::corpus::CorpusManifest> {
    let manifest_path = dir.join("manifest.txt");
    if let Ok(text) = std::fs::read_to_string(&manifest_path) {
        let get = |k: &str| -> Option<u64> {
            text.lines()
                .find_map(|l| l.strip_prefix(&format!("{k}=")))
                .and_then(|v| v.parse().ok())
        };
        if get("seed") == Some(spec.seed) && get("files") == Some(spec.n_files as u64) {
            if let (Some(records), Some(bytes), Some(dups)) =
                (get("records"), get("bytes"), get("duplicates"))
            {
                // Reuse: the generator is deterministic in the spec.
                return Ok(crate::corpus::CorpusManifest {
                    dir: dir.to_path_buf(),
                    seed: spec.seed,
                    n_records: records as usize,
                    n_duplicates: dups as usize,
                    n_files: spec.n_files,
                    total_bytes: bytes,
                });
            }
        }
    }
    generate_corpus(spec, dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_run_end_to_end_smallest() {
        let base =
            std::env::temp_dir().join(format!("p3sapp-suite-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let mut opts = SuiteOptions::new(&base);
        opts.scale = 0.1; // ~150 records
        opts.workers = 2;
        opts.tiers = vec![1];
        let suite = run_suite(&opts).unwrap();
        assert_eq!(suite.tiers.len(), 1);
        let t = &suite.tiers[0];
        assert!(t.size_bytes > 0);
        assert!(t.p3sapp.rows_out > 0);
        assert!(t.ca.as_ref().unwrap().rows_out > 0);
        assert!(t.reduction_pct(|r| r.ingestion_secs()).is_some());

        // Second run reuses the corpus (manifest match).
        let again = run_tier(&opts, 1).unwrap();
        assert_eq!(again.size_bytes, t.size_bytes);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn sampled_suite_runs_cheaper_and_deterministically() {
        let base = std::env::temp_dir()
            .join(format!("p3sapp-suite-sample-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let mut opts = SuiteOptions::new(&base);
        opts.scale = 0.1;
        opts.workers = 2;
        opts.tiers = vec![1];
        opts.skip_ca = true; // the control has no sample path
        let full = run_suite(&opts).unwrap();
        opts.sample = Some((0.5, 7));
        let sampled = run_suite(&opts).unwrap();
        let again = run_suite(&opts).unwrap();
        assert!(
            sampled.tiers[0].p3sapp.rows_out < full.tiers[0].p3sapp.rows_out,
            "a 50% sample must shrink the clean row count"
        );
        assert_eq!(sampled.tiers[0].p3sapp.frame, again.tiers[0].p3sapp.frame);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn repeated_report_run_hits_the_plan_cache() {
        let base =
            std::env::temp_dir().join(format!("p3sapp-suite-cache-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let mut opts = SuiteOptions::new(&base);
        opts.scale = 0.1;
        opts.workers = 2;
        opts.tiers = vec![1];
        opts.skip_ca = true; // the control never caches anyway
        let cache =
            std::sync::Arc::new(crate::cache::CacheManager::open(base.join("cache")).unwrap());
        opts.cache = Some(std::sync::Arc::clone(&cache));

        let first = run_suite(&opts).unwrap();
        assert!(!first.tiers[0].p3sapp.from_cache());
        let second = run_suite(&opts).unwrap();
        assert!(second.tiers[0].p3sapp.from_cache(), "repeat must restore");
        assert_eq!(second.tiers[0].p3sapp.frame, first.tiers[0].p3sapp.frame);
        assert_eq!(cache.stats().stores, 1);
        assert!(cache.stats().hits() >= 1);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn suite_digests_each_shard_exactly_once_per_cold_run() {
        // The cold-start triple-cost regression: EXPLAIN's cache probe,
        // the driver's cache fingerprint and the executor each used to
        // read the corpus independently. With both fingerprint callers
        // routed through the shared manager's memo, a cold suite pays
        // exactly one digest pass per shard; everything after that is a
        // stat-revalidation.
        let base = std::env::temp_dir()
            .join(format!("p3sapp-suite-fpmemo-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let mut opts = SuiteOptions::new(&base);
        opts.scale = 0.1;
        opts.workers = 2;
        opts.tiers = vec![1];
        opts.skip_ca = true;
        opts.explain = true; // the EXPLAIN probe must not add a digest pass
        let cache =
            std::sync::Arc::new(crate::cache::CacheManager::open(base.join("cache")).unwrap());
        opts.cache = Some(std::sync::Arc::clone(&cache));

        let first = run_suite(&opts).unwrap();
        let n_files = first.tiers[0].n_files as u64;
        assert!(n_files > 1, "tier 1 must have several shards for this to mean anything");
        let cold = cache.stats();
        assert_eq!(
            cold.fp_digest_shards, n_files,
            "cold suite: exactly one digest per shard (EXPLAIN probe and driver \
             fingerprint share the memo)"
        );
        assert!(
            cold.fp_stat_revalidations >= 1,
            "the driver run after the EXPLAIN probe revalidates by stat, not re-digest"
        );

        let second = run_suite(&opts).unwrap();
        assert!(second.tiers[0].p3sapp.from_cache(), "repeat must restore");
        let warm = cache.stats();
        assert_eq!(
            warm.fp_digest_shards, n_files,
            "a warm repeat must not re-digest any shard"
        );
        assert!(warm.fp_stat_revalidations > cold.fp_stat_revalidations);
        std::fs::remove_dir_all(&base).unwrap();
    }
}
