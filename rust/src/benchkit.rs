//! Micro-benchmark support (no criterion in the vendored dependency
//! closure): warmup + N timed iterations, mean/median/stddev reporting,
//! and a tiny black_box. Used by the `benches/` harnesses.
//!
//! Also home of the **bench-regression comparator** behind the
//! `benchgate` binary: it diffs a freshly measured `BENCH_*.json`
//! against the checked-in repo-root record and fails CI when a tracked
//! arm regresses. Because absolute seconds are meaningless across
//! runner hardware, arms are compared as **ratios to the record's first
//! arm** (the reference workload measured in the same run — `staged`,
//! `cache_cold`, `staged_tfidf`): a real regression in, say, the
//! streaming executor moves `streaming/staged` no matter how fast the
//! machine is.

use crate::json::Json;
use std::hint;
use std::time::{Duration, Instant};

/// Measurement summary for one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Measurement {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// One-line report, criterion-style.
    pub fn report(&self) -> String {
        format!(
            "{:40} {:>12} (median {:>12}, σ {:>10}, n={})",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.median),
            fmt_dur(self.stddev),
            self.iters
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} µs", s * 1e6)
    }
}

/// Opaque value barrier (prevents the optimizer from deleting work).
pub fn black_box<T>(x: T) -> T {
    hint::black_box(x)
}

/// Run `f` `iters` times after `warmup` unmeasured runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Measurement {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let n = times.len();
    let mean_ns = times.iter().map(|d| d.as_nanos()).sum::<u128>() / n as u128;
    let mean = Duration::from_nanos(mean_ns as u64);
    let var = times
        .iter()
        .map(|d| {
            let diff = d.as_nanos() as i128 - mean_ns as i128;
            (diff * diff) as u128
        })
        .sum::<u128>()
        / n as u128;
    let stddev = Duration::from_nanos((var as f64).sqrt() as u64);
    Measurement {
        name: name.to_string(),
        iters: n,
        mean,
        median: times[n / 2],
        stddev,
        min: times[0],
        max: times[n - 1],
    }
}

/// One parsed `BENCH_*.json`: the per-arm mean times, in file order.
/// The first arm is the comparison reference (see [`gate`]).
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRecord {
    pub arms: Vec<(String, f64)>,
    /// `"provisional": true` marks a baseline whose ratios were not
    /// measured on the gating hardware (e.g. authored before a CI run
    /// existed). The gate still compares and reports, but regressions
    /// are demoted to warnings — re-baseline from a measured run and
    /// drop the flag to arm the gate for real.
    pub provisional: bool,
}

impl BenchRecord {
    fn mean_of(&self, name: &str) -> Option<f64> {
        self.arms.iter().find(|(n, _)| n == name).map(|(_, m)| *m)
    }
}

/// Parse the `arms` array of a `BENCH_*.json` document (the shape
/// `benches/fused.rs` writes and the repo-root schema records pin).
/// A record whose `arms` is empty parses fine — the gate treats it as
/// "no baseline yet" and only warns.
pub fn parse_bench_record(text: &str) -> crate::Result<BenchRecord> {
    let doc = crate::json::parse(text)?;
    let Json::Obj(obj) = &doc else {
        anyhow::bail!("bench record is not a JSON object");
    };
    let arms_json = match obj.get("arms") {
        Some(Json::Arr(a)) => a.as_slice(),
        Some(Json::Null) | None => &[],
        Some(other) => anyhow::bail!("bench record 'arms' is not an array: {other:?}"),
    };
    let mut arms = Vec::with_capacity(arms_json.len());
    for arm in arms_json {
        let Json::Obj(fields) = arm else {
            anyhow::bail!("bench arm is not a JSON object: {arm:?}");
        };
        let name = fields
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("bench arm without a 'name'"))?;
        let mean = fields
            .get("mean_secs")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("bench arm '{name}' without 'mean_secs'"))?;
        arms.push((name.to_string(), mean));
    }
    let provisional = matches!(obj.get("provisional"), Some(Json::Bool(true)));
    Ok(BenchRecord { arms, provisional })
}

/// Outcome of gating one `record` (the checked-in baseline) against one
/// `current` (the freshly measured run).
#[derive(Debug)]
pub struct GateReport {
    /// Human-readable per-arm lines (always populated when compared).
    pub lines: Vec<String>,
    /// Failures that should fail the CI job; empty = pass.
    pub failures: Vec<String>,
    /// True when the record carries no baseline arms (warn-only mode).
    pub no_baseline: bool,
}

/// Compare `current` against `record`. Every tracked arm (all record
/// arms past the first) is compared as its ratio to the record's first
/// arm; a ratio that grew by more than `threshold` (0.25 = +25%) is a
/// failure, as is a tracked arm or the reference arm missing from the
/// current run. An empty-`arms` record yields warn-only (no baseline).
pub fn gate(record: &BenchRecord, current: &BenchRecord, threshold: f64) -> GateReport {
    let mut report = GateReport { lines: Vec::new(), failures: Vec::new(), no_baseline: false };
    let Some((ref_name, ref_rec_mean)) = record.arms.first().cloned() else {
        report.no_baseline = true;
        return report;
    };
    let Some(ref_cur_mean) = current.mean_of(&ref_name) else {
        report
            .failures
            .push(format!("reference arm '{ref_name}' missing from the current run"));
        return report;
    };
    if ref_rec_mean <= 0.0 || ref_cur_mean <= 0.0 {
        report.failures.push(format!(
            "reference arm '{ref_name}' has a non-positive mean (record {ref_rec_mean}, \
             current {ref_cur_mean})"
        ));
        return report;
    }
    for (name, rec_mean) in record.arms.iter().skip(1) {
        let Some(cur_mean) = current.mean_of(name) else {
            report.failures.push(format!("tracked arm '{name}' missing from the current run"));
            continue;
        };
        let rel_rec = rec_mean / ref_rec_mean;
        let rel_cur = cur_mean / ref_cur_mean;
        let regression = rel_cur / rel_rec - 1.0;
        report.lines.push(format!(
            "{name:24} ratio-to-{ref_name}: record {rel_rec:.3}, current {rel_cur:.3} \
             ({:+.1}%)",
            regression * 100.0
        ));
        if regression > threshold {
            report.failures.push(format!(
                "arm '{name}' regressed {:.1}% vs '{ref_name}' (threshold {:.0}%)",
                regression * 100.0,
                threshold * 100.0
            ));
        }
    }
    report
}

/// Serialize measurements in the shared `BENCH_*.json` record schema:
/// a `bench` name, free-form extra fields (values are raw JSON — quote
/// strings yourself), then the `arms` array [`parse_bench_record`]
/// reads. Every bench harness emits through this one serializer so the
/// per-arm schema cannot drift between records.
pub fn bench_record_json(
    bench: &str,
    extra: &[(&str, String)],
    arms: &[(&str, &Measurement)],
) -> String {
    let mut out = String::new();
    out.push_str(&format!("{{\n  \"bench\": \"{bench}\""));
    for (key, value) in extra {
        out.push_str(&format!(",\n  \"{key}\": {value}"));
    }
    out.push_str(",\n  \"arms\": [\n");
    for (i, (name, m)) in arms.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "    {{\"name\": \"{name}\", \"mean_secs\": {:.6}, \"median_secs\": {:.6}, \"stddev_secs\": {:.6}, \"iters\": {}}}",
            m.mean.as_secs_f64(),
            m.median.as_secs_f64(),
            m.stddev.as_secs_f64(),
            m.iters
        ));
    }
    out.push_str("\n  ]\n}\n");
    out
}

/// Write a bench record to the path named by `$env_key` (default
/// `default_path`; the value `-` disables the write). Prints the
/// destination or the write error — bench harnesses never fail a run
/// over a record file.
pub fn write_bench_record(env_key: &str, default_path: &str, json: &str) {
    let path = std::env::var(env_key).unwrap_or_else(|_| default_path.to_string());
    if path == "-" {
        return;
    }
    match std::fs::write(&path, json) {
        Ok(()) => println!("  wrote {path}"),
        Err(e) => eprintln!("  could not write {path}: {e}"),
    }
}

/// Environment knob helper for benches (`BENCH_SCALE=2 cargo bench`).
pub fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let m = bench("sleep", 0, 3, || std::thread::sleep(Duration::from_millis(5)));
        assert!(m.mean >= Duration::from_millis(4));
        assert_eq!(m.iters, 3);
        assert!(m.min <= m.median && m.median <= m.max);
    }

    #[test]
    fn report_formats() {
        let m = bench("fast", 1, 5, || 1 + 1);
        let r = m.report();
        assert!(r.contains("fast"));
        assert!(r.contains("n=5"));
    }

    fn record(arms: &[(&str, f64)]) -> BenchRecord {
        BenchRecord {
            arms: arms.iter().map(|(n, m)| (n.to_string(), *m)).collect(),
            provisional: false,
        }
    }

    #[test]
    fn parse_bench_record_reads_the_fused_schema() {
        let text = r#"{
  "bench": "fused", "records": 100, "workers": 4,
  "arms": [
    {"name": "staged", "mean_secs": 0.9, "median_secs": 0.9, "stddev_secs": 0.01, "iters": 5},
    {"name": "streaming", "mean_secs": 0.4, "median_secs": 0.4, "stddev_secs": 0.02, "iters": 5}
  ]
}"#;
        let r = parse_bench_record(text).unwrap();
        assert_eq!(r, record(&[("staged", 0.9), ("streaming", 0.4)]));
        assert!(!r.provisional, "absent flag defaults to a real baseline");
        // Null schema record (repo-root placeholder): empty arms, no error.
        let null = parse_bench_record(r#"{"bench": "fused", "records": null, "arms": []}"#)
            .unwrap();
        assert!(null.arms.is_empty());
        // The provisional marker is read from the top level.
        let prov =
            parse_bench_record(r#"{"provisional": true, "arms": []}"#).unwrap();
        assert!(prov.provisional);
        // Malformed arm: an error, not a silent skip.
        assert!(parse_bench_record(r#"{"arms": [{"mean_secs": 1.0}]}"#).is_err());
        assert!(parse_bench_record("[1, 2]").is_err());
    }

    #[test]
    fn gate_passes_within_threshold_and_fails_past_it() {
        let rec = record(&[("staged", 1.0), ("fast", 0.5)]);
        // Machine 2x slower overall: ratios identical → pass.
        let pass = gate(&rec, &record(&[("staged", 2.0), ("fast", 1.0)]), 0.25);
        assert!(pass.failures.is_empty(), "{:?}", pass.failures);
        assert!(!pass.no_baseline);
        assert_eq!(pass.lines.len(), 1);
        // Tracked arm 30% worse relative to the reference → fail.
        let fail = gate(&rec, &record(&[("staged", 1.0), ("fast", 0.65)]), 0.25);
        assert_eq!(fail.failures.len(), 1, "{:?}", fail.failures);
        assert!(fail.failures[0].contains("'fast' regressed"), "{:?}", fail.failures);
        // Improvements never fail.
        let ok = gate(&rec, &record(&[("staged", 1.0), ("fast", 0.2)]), 0.25);
        assert!(ok.failures.is_empty());
    }

    #[test]
    fn bench_record_json_roundtrips_through_the_parser() {
        let a = bench("a", 0, 1, || 1);
        let b = bench("b", 0, 1, || 2);
        let json = bench_record_json(
            "demo",
            &[("records", "100".into()), ("note", "\"free text\"".into())],
            &[("ref_arm", &a), ("tracked", &b)],
        );
        let rec = parse_bench_record(&json).unwrap();
        assert_eq!(rec.arms.len(), 2);
        assert_eq!(rec.arms[0].0, "ref_arm");
        assert_eq!(rec.arms[1].0, "tracked");
        assert!(!rec.provisional);
    }

    #[test]
    fn gate_handles_missing_arms_and_empty_baselines() {
        let rec = record(&[("staged", 1.0), ("fast", 0.5)]);
        // Empty baseline: warn-only.
        let warn = gate(&record(&[]), &rec, 0.25);
        assert!(warn.no_baseline && warn.failures.is_empty());
        // Tracked arm vanished from the current run: fail.
        let gone = gate(&rec, &record(&[("staged", 1.0)]), 0.25);
        assert_eq!(gone.failures.len(), 1);
        assert!(gone.failures[0].contains("missing"), "{:?}", gone.failures);
        // Reference arm vanished: fail.
        let noref = gate(&rec, &record(&[("fast", 0.5)]), 0.25);
        assert!(noref.failures[0].contains("reference"), "{:?}", noref.failures);
    }
}
