//! Micro-benchmark support (no criterion in the vendored dependency
//! closure): warmup + N timed iterations, mean/median/stddev reporting,
//! and a tiny black_box. Used by the `benches/` harnesses.

use std::hint;
use std::time::{Duration, Instant};

/// Measurement summary for one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Measurement {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// One-line report, criterion-style.
    pub fn report(&self) -> String {
        format!(
            "{:40} {:>12} (median {:>12}, σ {:>10}, n={})",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.median),
            fmt_dur(self.stddev),
            self.iters
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} µs", s * 1e6)
    }
}

/// Opaque value barrier (prevents the optimizer from deleting work).
pub fn black_box<T>(x: T) -> T {
    hint::black_box(x)
}

/// Run `f` `iters` times after `warmup` unmeasured runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Measurement {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let n = times.len();
    let mean_ns = times.iter().map(|d| d.as_nanos()).sum::<u128>() / n as u128;
    let mean = Duration::from_nanos(mean_ns as u64);
    let var = times
        .iter()
        .map(|d| {
            let diff = d.as_nanos() as i128 - mean_ns as i128;
            (diff * diff) as u128
        })
        .sum::<u128>()
        / n as u128;
    let stddev = Duration::from_nanos((var as f64).sqrt() as u64);
    Measurement {
        name: name.to_string(),
        iters: n,
        mean,
        median: times[n / 2],
        stddev,
        min: times[0],
        max: times[n - 1],
    }
}

/// Environment knob helper for benches (`BENCH_SCALE=2 cargo bench`).
pub fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let m = bench("sleep", 0, 3, || std::thread::sleep(Duration::from_millis(5)));
        assert!(m.mean >= Duration::from_millis(4));
        assert_eq!(m.iters, 3);
        assert!(m.min <= m.median && m.median <= m.max);
    }

    #[test]
    fn report_formats() {
        let m = bench("fast", 1, 5, || 1 + 1);
        let r = m.report();
        assert!(r.contains("fast"));
        assert!(r.contains("n=5"));
    }
}
