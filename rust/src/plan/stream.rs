//! Streaming plan execution: overlap shard **reading** with shard
//! **parsing + cleaning**.
//!
//! The fused single pass ([`PhysicalPlan::execute`]) already removed the
//! barriers between the paper's stages, but it still runs read, parse
//! and clean for one shard inside the same worker task — I/O and compute
//! remain serialized *per shard*. This module splits them into a
//! producer/consumer pipeline, the overlap the paper (and Spark's own
//! ingestion) attributes its throughput to. Since the zero-copy cursor
//! ([`crate::json::cursor`]) parses a raw byte buffer in place, the
//! reader stage is pure I/O — it ships whole shard buffers and the
//! workers cursor-parse them next to the op program, so the CPU-heavy
//! parse scales with the (larger) worker pool:
//!
//! ```text
//! readers (I/O-bound)        bounded queue         workers (CPU-bound)
//! read shard i+1..i+k   -->  cap raw buffers -->   cursor parse + op
//!                                                  program on shard i
//!                                                       |
//!                                    driver: reorder buffer -> ordered
//!                                    dedup merge -> collect(LocalFrame)
//! ```
//!
//! The queue reuses the backpressure `sync_channel` pattern from
//! [`crate::ingest::spark`]: readers stall when they get more than
//! `queue_cap` shard buffers ahead of the workers, bounding how far
//! *reading* can run ahead of cleaning. Cleaned results, by contrast,
//! are not memory-bounded: the driver drains its channel eagerly into a
//! reorder buffer, so under extreme skew the cleaned shards waiting on
//! one slow predecessor accumulate there — the same O(corpus) driver
//! footprint the single pass has when it collects its result vector,
//! and `ingest::spark`'s collector has for parsed partitions.
//!
//! **Adaptive reader split.** With `readers: 0` (the default) the
//! pipeline does not guess the I/O-vs-CPU balance from core counts: the
//! driver runs shard 0 inline, timing its read separately from its
//! parse+clean, and sizes the reader pool from the observed read share
//! ([`adaptive_readers`] — ceil(cores x read-share), clamped to
//! [1, cores/2]). The probe's result is fed to the sink *first*, so
//! shard order — and therefore output bytes — are unchanged.
//!
//! **Ordering.** The ordered first-occurrence-wins dedup merge requires
//! results in shard order, but workers finish out of order. The driver
//! therefore holds a reorder buffer and only feeds the merger contiguous
//! prefixes — a slow first shard can never be overtaken in output order,
//! so the streaming path is byte-identical to the single-pass path (and
//! to the staged reference; see `rust/tests/plan_equivalence.rs`).
//!
//! ```
//! use p3sapp::pipeline::presets::case_study_plan;
//! use p3sapp::plan::StreamOptions;
//!
//! // Empty scan: executes instantly, but exercises the whole topology.
//! let plan = case_study_plan(&[], "title", "abstract").optimize();
//! let opts = StreamOptions { readers: 2, workers: 2, queue_cap: 4 };
//! let out = plan.execute_stream(&opts).unwrap();
//! assert_eq!(out.rows_out, 0);
//! ```

use super::physical::{Merger, PartResult, PhysicalPlan, PlanOutput};
use crate::obs;
use crate::Result;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Tuning knobs for the streaming executor: the reader/worker split and
/// the backpressure window between them.
#[derive(Debug, Clone)]
pub struct StreamOptions {
    /// Reader (I/O) threads. `0` = adaptive: the driver probes the
    /// first shard, measures its read-vs-parse+clean ratio, and sizes
    /// the pool as ceil(cores x read-share) clamped to [1, cores/2]
    /// (see [`adaptive_readers`]). Readers only read bytes — the
    /// cursor parse happens on the workers — so they need far fewer
    /// threads than the cleaning pool.
    pub readers: usize,
    /// Parse + cleaning worker threads (0 = remaining logical cores).
    pub workers: usize,
    /// Bounded-queue capacity in raw shard buffers, for both the read
    /// queue and the cleaned queue (backpressure window; minimum 1).
    pub queue_cap: usize,
}

impl Default for StreamOptions {
    fn default() -> Self {
        StreamOptions { readers: 0, workers: 0, queue_cap: 16 }
    }
}

impl StreamOptions {
    /// Default split with an explicit backpressure window.
    pub fn with_queue_cap(queue_cap: usize) -> Self {
        StreamOptions { queue_cap, ..Default::default() }
    }

    /// Resolve the knobs against a concrete shard count, returning
    /// `(readers, workers, queue_cap)`. Zero values auto-size from the
    /// logical core count — for `readers: 0` this static quarter-of-cores
    /// figure is only the *estimate* used by EXPLAIN and the fallback
    /// decision; the pipeline itself replaces it with the measured
    /// [`adaptive_readers`] split once the first shard's timings are in.
    /// Readers are clamped to the shard count so no reader thread is
    /// spawned with nothing to read.
    pub fn resolve(&self, n_files: usize) -> (usize, usize, usize) {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        let readers = if self.readers == 0 { (cores / 4).max(1) } else { self.readers };
        let readers = readers.min(n_files.max(1));
        let workers = if self.workers == 0 {
            cores.saturating_sub(readers).max(1)
        } else {
            self.workers
        };
        (readers, workers, self.queue_cap.max(1))
    }
}

/// Two-stage streaming executor over a lowered [`PhysicalPlan`]: a
/// bounded byte-reader stage feeding a consumer pool that cursor-parses
/// each raw shard buffer and runs the per-partition op program (null
/// mask → dedup keys → fused cleaning → empty sweep) while later shards
/// are still being read.
///
/// Construction is cheap — the executor is just its options; threads
/// live only for the duration of one [`StreamExecutor::execute`] call.
pub struct StreamExecutor {
    opts: StreamOptions,
}

impl StreamExecutor {
    pub fn new(opts: StreamOptions) -> Self {
        StreamExecutor { opts }
    }

    pub fn options(&self) -> &StreamOptions {
        &self.opts
    }

    /// Run `plan` through the streaming pipeline. Output (frame bytes,
    /// row order, drop accounting) is identical to
    /// [`PhysicalPlan::execute`]; only the schedule differs.
    pub fn execute(&self, plan: &PhysicalPlan) -> Result<PlanOutput> {
        // Estimator-bearing plans orchestrate their two passes in
        // `PhysicalPlan::execute_stream` (fit pass over the prefix, then
        // the fitted program back through here).
        if plan.is_two_pass() {
            return plan.execute_stream(&self.opts);
        }
        let t_pass = Instant::now();
        let n = plan.files().len();
        if n == 0 {
            return Ok(Merger::new(plan.output_schema().clone(), plan.n_distinct(), plan.limit_n())
                .finish_overlapped(t_pass.elapsed()));
        }
        let (readers, workers, _) = self.opts.resolve(n);

        // The shard file is this pipeline's unit of work, so with fewer
        // shards than cleaning workers most of the pool would sit idle.
        // The single-pass executor re-chunks parsed partitions to fill
        // its pool in exactly this case — delegate to it (same bytes
        // out, better schedule) with the full thread budget.
        if n < workers {
            return plan.execute(readers + workers);
        }

        let mut merger =
            Merger::new(plan.output_schema().clone(), plan.n_distinct(), plan.limit_n());
        self.run_pipeline(plan, &mut |r| {
            merger.push(r);
            Ok(())
        })?;
        Ok(merger.finish_overlapped(t_pass.elapsed()))
    }

    /// Sink-based variant of [`Self::execute`]: run `plan`'s per-shard
    /// programs through the reader/worker pipeline and hand each
    /// [`PartResult`] to `sink` **in shard order**, without merging.
    /// Used by the two-pass strategy's fit pass, which folds results
    /// into the estimator's accumulator instead of a frame. Delegates
    /// to the single-pass executor when shards are scarcer than the
    /// worker pool (same delegation rule as `execute`).
    pub(super) fn run(
        &self,
        plan: &PhysicalPlan,
        sink: &mut dyn FnMut(PartResult) -> Result<()>,
    ) -> Result<()> {
        let n = plan.files().len();
        if n == 0 {
            return Ok(());
        }
        let (readers, workers, _) = self.opts.resolve(n);
        if n < workers {
            let (results, _) = plan.collect_results(readers + workers)?;
            for r in results {
                sink(r)?;
            }
            return Ok(());
        }
        self.run_pipeline(plan, sink)
    }

    /// Like [`Self::run`], but the shard file is *always* the unit of
    /// work: the scarce-shard case drops to the single-pass executor's
    /// shard-aligned collect instead of its re-chunk path. The
    /// incremental cache needs every [`PartResult`] to map 1:1 onto a
    /// shard file so it can be stored as (or compared against) that
    /// shard's artifact.
    pub(super) fn run_shards(
        &self,
        plan: &PhysicalPlan,
        sink: &mut dyn FnMut(PartResult) -> Result<()>,
    ) -> Result<()> {
        let n = plan.files().len();
        if n == 0 {
            return Ok(());
        }
        let (readers, workers, _) = self.opts.resolve(n);
        if n < workers {
            for r in plan.collect_shard_results(readers + workers)? {
                sink(r)?;
            }
            return Ok(());
        }
        self.run_pipeline(plan, sink)
    }

    /// The two-stage pipeline itself: a bounded reader pool shipping raw
    /// shard buffers, a worker pool cursor-parsing them and running the
    /// op program, and the driver's reorder buffer releasing contiguous
    /// shard prefixes to `sink`. With `readers: 0` the driver first runs
    /// shard 0 inline as the adaptive-split probe.
    fn run_pipeline(
        &self,
        plan: &PhysicalPlan,
        sink: &mut dyn FnMut(PartResult) -> Result<()>,
    ) -> Result<()> {
        let files: Vec<PathBuf> = plan.files().to_vec();
        let n = files.len();
        let (mut readers, _, queue_cap) = self.opts.resolve(n);
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(2);

        // Adaptive reader split: run shard 0 on the driver, timing its
        // read separately from its parse+clean, and size the reader
        // pool from the observed ratio. Feeding the probe's result to
        // the sink before the pipeline starts preserves shard order, so
        // output bytes are identical to any fixed split.
        let mut start = 0usize;
        if self.opts.readers == 0 && n >= 2 {
            let _sp = obs::span("probe shard 0", "exec");
            let t_read = Instant::now();
            let bytes = crate::ingest::spark::read_shard_bytes(&files[0])?;
            let read_span = t_read.elapsed();
            let t_work = Instant::now();
            let probe = plan.run_shard_bytes(0, &files[0], &bytes, read_span)?;
            let work_span = t_work.elapsed();
            sink(probe)?;
            readers = adaptive_readers(cores, read_span, work_span).min(n - 1);
            start = 1;
        }
        let workers = if self.opts.workers == 0 {
            cores.saturating_sub(readers).max(1)
        } else {
            self.opts.workers
        };

        // Reader work queue, indexed so the driver can restore shard
        // order after out-of-order completion.
        let jobs: Mutex<VecDeque<(usize, PathBuf)>> =
            Mutex::new(files.iter().cloned().enumerate().skip(start).collect());
        let files = &files;
        // Set when the driver hits a terminal error: readers skip the
        // remaining shards instead of reading work nobody will merge.
        let abort = AtomicBool::new(false);

        // Stage 1 -> stage 2: raw shard buffers (with their read span),
        // bounded for backpressure — this is the knob that keeps reading
        // from racing arbitrarily far ahead of cleaning.
        let (parsed_tx, parsed_rx) =
            sync_channel::<(usize, Result<(Vec<u8>, Duration)>)>(queue_cap);
        let parsed_rx = Mutex::new(parsed_rx);
        // Stage 2 -> driver: cleaned shard results. Bounded only to keep
        // the handoff allocation small — the driver drains it eagerly
        // into the reorder buffer, so this cap is not a memory bound.
        let (done_tx, done_rx) = sync_channel::<(usize, Result<PartResult>)>(queue_cap);

        std::thread::scope(|scope| -> Result<()> {
            for r in 0..readers {
                let jobs = &jobs;
                let abort = &abort;
                let parsed_tx = parsed_tx.clone();
                scope.spawn(move || {
                    obs::set_lane(obs::lane_reader(r));
                    loop {
                        if abort.load(Ordering::Relaxed) {
                            break;
                        }
                        let job = jobs.lock().unwrap().pop_front();
                        let Some((idx, path)) = job else { break };
                        let mut sp = obs::span("read shard", "io");
                        let t0 = Instant::now();
                        let read = crate::ingest::spark::read_shard_bytes(&path)
                            .map(|bytes| (bytes, t0.elapsed()));
                        if sp.active() {
                            sp.arg("shard", idx as u64);
                            if let Ok((bytes, _)) = &read {
                                sp.arg("bytes", bytes.len() as u64);
                            }
                        }
                        drop(sp);
                        if parsed_tx.send((idx, read)).is_err() {
                            break;
                        }
                    }
                });
            }
            drop(parsed_tx); // workers see EOF once all readers finish

            for k in 0..workers {
                let parsed_rx = &parsed_rx;
                let abort = &abort;
                let done_tx = done_tx.clone();
                scope.spawn(move || {
                    obs::set_lane(obs::lane_worker_thread(k));
                    // After the driver bails, keep draining the read
                    // queue (without cleaning) so blocked readers can
                    // finish their in-flight send and exit.
                    let mut drain = false;
                    loop {
                        let msg = parsed_rx.lock().unwrap().recv();
                        let Ok((idx, read)) = msg else { break };
                        if drain {
                            continue;
                        }
                        // Contain panics from transformer bugs: a worker
                        // that unwound here would stop draining, leaving
                        // readers blocked mid-send and the scope join
                        // hung. Convert to an error the driver reports.
                        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            read.and_then(|(bytes, span)| {
                                plan.run_shard_bytes(idx, &files[idx], &bytes, span)
                            })
                        }))
                        .unwrap_or_else(|_| {
                            Err(anyhow::anyhow!("worker panicked while cleaning shard {idx}"))
                        });
                        if done_tx.send((idx, out)).is_err() {
                            drain = true;
                            abort.store(true, Ordering::Relaxed);
                        }
                    }
                });
            }
            drop(done_tx); // driver sees EOF once all workers finish

            // Driver: re-sequence out-of-order completions, release
            // contiguous prefixes only. Runs concurrently with both
            // pools — the sink's work on shard i overlaps the cleaning
            // of i+1 and the parsing of i+2.
            let mut pending: Vec<Option<PartResult>> = (0..n).map(|_| None).collect();
            let mut next = start;
            for (idx, res) in done_rx {
                pending[idx] = Some(res?);
                while next < n {
                    match pending[next].take() {
                        Some(r) => {
                            sink(r)?;
                            next += 1;
                        }
                        None => break,
                    }
                }
            }
            anyhow::ensure!(next == n, "streaming execution incomplete: {next}/{n} shards");
            Ok(())
        })
    }
}

/// Size the reader pool from one observed shard: readers get the share
/// of the core budget that matches the read share of the shard's total
/// (read + parse+clean) time, rounded up, clamped to [1, cores/2] so
/// neither stage is ever starved however skewed the probe was. A probe
/// too fast to measure (both spans zero) falls back to one reader.
pub(crate) fn adaptive_readers(cores: usize, read: Duration, work: Duration) -> usize {
    let hi = (cores / 2).max(1);
    let total = read.as_secs_f64() + work.as_secs_f64();
    if total <= 0.0 {
        return 1;
    }
    let share = read.as_secs_f64() / total;
    ((cores as f64 * share).ceil() as usize).clamp(1, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate_corpus, CorpusSpec};
    use crate::ingest::list_shards;
    use crate::pipeline::presets::case_study_plan;

    fn corpus(name: &str, seed: u64) -> (PathBuf, Vec<PathBuf>) {
        let dir =
            std::env::temp_dir().join(format!("p3sapp-stream-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        generate_corpus(&CorpusSpec::tiny(seed), &dir).unwrap();
        let files = list_shards(&dir).unwrap();
        (dir, files)
    }

    #[test]
    fn resolve_clamps_and_auto_sizes() {
        let auto = StreamOptions::default();
        let (r, w, cap) = auto.resolve(100);
        assert!(r >= 1 && w >= 1 && cap >= 1);
        // Readers never exceed the shard count.
        let (r, _, _) = StreamOptions { readers: 8, workers: 2, queue_cap: 4 }.resolve(3);
        assert_eq!(r, 3);
        // Explicit values pass through; a zero queue cap is bumped to 1.
        let (r, w, cap) = StreamOptions { readers: 2, workers: 5, queue_cap: 0 }.resolve(10);
        assert_eq!((r, w, cap), (2, 5, 1));
    }

    #[test]
    fn empty_file_list_yields_empty_output() {
        let plan = case_study_plan(&[], "title", "abstract").optimize();
        let out = plan.execute_stream(&StreamOptions::default()).unwrap();
        assert_eq!(out.rows_ingested, 0);
        assert_eq!(out.rows_out, 0);
        assert_eq!(out.frame.num_rows(), 0);
    }

    #[test]
    fn streaming_matches_single_pass_output() {
        let (dir, files) = corpus("match", 23);
        let plan = case_study_plan(&files, "title", "abstract").optimize();
        let single = plan.execute(2).unwrap();
        for opts in [
            StreamOptions::default(),
            StreamOptions { readers: 1, workers: 1, queue_cap: 1 },
            StreamOptions { readers: 3, workers: 2, queue_cap: 2 },
            // More workers than shards: exercises the single-pass
            // delegation, which must produce the same bytes too.
            StreamOptions { readers: 2, workers: 32, queue_cap: 4 },
        ] {
            let streamed = plan.execute_stream(&opts).unwrap();
            assert_eq!(streamed.frame, single.frame, "{opts:?}");
            assert_eq!(streamed.rows_ingested, single.rows_ingested, "{opts:?}");
            assert_eq!(streamed.rows_out, single.rows_out, "{opts:?}");
            assert_eq!(streamed.nulls_dropped, single.nulls_dropped, "{opts:?}");
            assert_eq!(streamed.dups_dropped, single.dups_dropped, "{opts:?}");
            assert_eq!(streamed.empties_dropped, single.empties_dropped, "{opts:?}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn slow_first_shard_is_not_overtaken_in_output_order() {
        // Shard 0 carries ~200x the rows of shards 1..5, so with
        // several readers the small shards finish parsing and cleaning
        // long before shard 0 — the reorder buffer must hold them back
        // until shard 0's rows have been merged. JSON-lines layout,
        // every row unique and non-null so nothing is dropped and row
        // order is fully observable.
        let dir =
            std::env::temp_dir().join(format!("p3sapp-stream-order-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // Letters-only payloads: the cleaning sweeps keep them verbatim
        // (digits/punctuation would be stripped), so every row survives
        // and the title column stays unique per (file, row).
        fn word(mut x: usize) -> String {
            let mut s = String::new();
            loop {
                s.push((b'a' + (x % 26) as u8) as char);
                x /= 26;
                if x == 0 {
                    break;
                }
            }
            s
        }
        let row = |f: usize, r: usize| {
            let fid = (b'a' + f as u8) as char;
            format!(
                "{{\"title\": \"title {fid} {w}\", \"abstract\": \"zebra {fid} {w} quartz\"}}\n",
                w = word(r)
            )
        };
        let mut big = String::new();
        for r in 0..2000 {
            big.push_str(&row(0, r));
        }
        std::fs::write(dir.join("shard-a.json"), big).unwrap();
        for f in 1..6 {
            let fid = (b'a' + f as u8) as char;
            let mut small = String::new();
            for r in 0..10 {
                small.push_str(&row(f, r));
            }
            std::fs::write(dir.join(format!("shard-{fid}.json")), small).unwrap();
        }
        let files = list_shards(&dir).unwrap();
        let plan = case_study_plan(&files, "title", "abstract").optimize();
        let reference = plan.execute(1).unwrap();
        assert_eq!(reference.rows_out, 2000 + 5 * 10);
        // The shard boundary is observable in the title column.
        assert_ne!(
            reference.frame.column(0).get_str(1999),
            reference.frame.column(0).get_str(2000)
        );
        let opts = StreamOptions { readers: 4, workers: 2, queue_cap: 2 };
        for _ in 0..3 {
            let streamed = plan.execute_stream(&opts).unwrap();
            assert_eq!(streamed.frame, reference.frame);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn streaming_two_pass_matches_fused_two_pass() {
        use crate::pipeline::features::{HashingTF, Idf};
        use crate::pipeline::stages::Tokenizer;
        use crate::plan::LogicalPlan;
        let (dir, files) = corpus("twopass", 31);
        let plan = LogicalPlan::scan(files, &["title", "abstract"])
            .drop_nulls(&["title", "abstract"])
            .distinct(&["title", "abstract"])
            .transform(Tokenizer::new("abstract", "tokens"))
            .transform(HashingTF::new("tokens", "tf", 32))
            .fit(Idf::new("tf", "tfidf"))
            .collect();
        let fused = plan.execute(2).unwrap();
        assert!(fused.rows_out > 0);
        for opts in [
            StreamOptions { readers: 2, workers: 2, queue_cap: 1 },
            // Scarce-shard delegation inside both passes.
            StreamOptions { readers: 1, workers: 32, queue_cap: 4 },
        ] {
            let streamed = plan.execute_stream(&opts).unwrap();
            assert_eq!(streamed.frame, fused.frame, "{opts:?}");
            assert_eq!(streamed.rows_out, fused.rows_out, "{opts:?}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bad_shard_reports_error_and_terminates() {
        let dir =
            std::env::temp_dir().join(format!("p3sapp-stream-bad-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("a.json"), "{\"title\": \"ok\", \"abstract\": \"fine\"}\n")
            .unwrap();
        std::fs::write(dir.join("b.json"), "{not json").unwrap();
        std::fs::write(dir.join("c.json"), "{\"title\": \"ok2\", \"abstract\": \"fine2\"}\n")
            .unwrap();
        let files = list_shards(&dir).unwrap();
        let plan = case_study_plan(&files, "title", "abstract").optimize();
        // queue_cap=1 with a mid-list failure exercises the drain path
        // that keeps blocked readers from deadlocking the scope join.
        let opts = StreamOptions { readers: 2, workers: 2, queue_cap: 1 };
        let err = plan.execute_stream(&opts).unwrap_err();
        assert!(err.to_string().contains("b.json"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn render_stream_shows_fallback_when_shards_are_scarce() {
        let (dir, files) = corpus("fallback", 9);
        let phys = case_study_plan(&files, "title", "abstract").optimize().lower().unwrap();
        // 6 shard files, 32 workers: the executor would delegate to the
        // single pass, and EXPLAIN must say so instead of rendering a
        // topology that never runs.
        let r = phys.render_stream(&StreamOptions { readers: 1, workers: 32, queue_cap: 4 });
        assert!(r.contains("fallback"), "{r}");
        assert!(r.contains("SinglePass"), "{r}");
        assert!(!r.contains("reorder buffer"), "{r}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn render_stream_shows_topology() {
        let (dir, files) = corpus("render", 5);
        let phys = case_study_plan(&files, "title", "abstract").optimize().lower().unwrap();
        let r = phys.render_stream(&StreamOptions { readers: 2, workers: 3, queue_cap: 8 });
        assert!(r.contains("StreamPipeline"), "{r}");
        assert!(r.contains("readers: 2 x read-bytes"), "{r}");
        assert!(!r.contains("adaptive split"), "{r}"); // explicit readers
        assert!(r.contains("bounded(8 raw shard buffers"), "{r}");
        assert!(r.contains("workers: 3 x parse+project [title, abstract] + op-program"), "{r}");
        assert!(r.contains("hash-keys #0 [title, abstract] (128-bit)"), "{r}");
        assert!(r.contains("reorder buffer"), "{r}");
        // readers: 0 renders the static estimate, flagged as adaptive.
        let r = phys.render_stream(&StreamOptions { readers: 0, workers: 3, queue_cap: 8 });
        assert!(r.contains("adaptive split") || r.contains("fallback"), "{r}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn adaptive_reader_split_clamps() {
        let ms = Duration::from_millis;
        // All-CPU probe: one reader is enough.
        assert_eq!(adaptive_readers(8, ms(0), ms(100)), 1);
        // All-I/O probe: capped at half the cores.
        assert_eq!(adaptive_readers(8, ms(100), ms(0)), 4);
        // Tiny machines still get one reader and one worker.
        assert_eq!(adaptive_readers(1, ms(100), ms(0)), 1);
        assert_eq!(adaptive_readers(2, ms(50), ms(50)), 1);
        // Proportional in between: 25% read share of 16 cores -> 4.
        assert_eq!(adaptive_readers(16, ms(25), ms(75)), 4);
        // Unmeasurably fast probe falls back to one reader.
        assert_eq!(adaptive_readers(8, ms(0), ms(0)), 1);
    }
}
