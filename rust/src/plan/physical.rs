//! Physical execution: the whole lowered plan runs inside **one**
//! parallel pass over the shard files. Each worker, per file:
//! parse+project → null mask → 128-bit dedup keys → (fused) cleaning
//! sweeps → empty-string sweep. The driver is left with the only
//! inherently ordered work: the first-occurrence-wins dedup merge and
//! the final extend into a contiguous [`LocalFrame`].
//!
//! This replaces the eager driver's four barrier-separated phases
//! (ingest ‖ → pre-clean → clean ‖ → post-clean) with a single
//! `map_items` over files — no thread pool ever drains while another
//! stage waits to start, which is where the fused plan's wall-clock win
//! comes from on top of the per-row fusion win.
//!
//! Stage-time accounting: the paper's tables want per-stage wall times,
//! but a fused pass has no per-stage walls. Workers therefore record
//! per-phase CPU spans, and the pass's wall time is attributed to the
//! four stage keys proportionally; the driver-side dedup merge and
//! collect are measured directly and added to pre-/post-cleaning.

use super::logical::{LogicalOp, LogicalPlan};
use super::stream::{StreamExecutor, StreamOptions};
use crate::driver::{CLEANING, INGESTION, POST_CLEANING, PRE_CLEANING};
use crate::engine::Executor;
use crate::frame::{hash_row_wide, Field, LocalFrame, Partition, Schema};
use crate::metrics::StageTimes;
use crate::pipeline::Transformer;
use crate::Result;
use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One step of the per-partition single-pass program.
#[derive(Clone)]
enum PartitionOp {
    /// Drop rows null in any of the columns (pre-cleaning).
    NullFilter { idxs: Vec<usize> },
    /// Compute 128-bit dedup keys over the columns *at this point* in
    /// the program — i.e. over raw values when `Distinct` precedes the
    /// cleaning stages, as in Algorithm 1.
    HashKeys { idxs: Vec<usize> },
    /// Apply one (possibly fused) transformer stage.
    Stage { stage: Arc<dyn Transformer>, in_idx: usize, out_idx: usize },
    /// Empty-string → null sweep + null filter (post-cleaning).
    EmptyFilter { idxs: Vec<usize> },
}

/// A lowered, executable plan: the ingestion spec plus the straight-line
/// per-partition program and the pre-computed output schema.
pub struct PhysicalPlan {
    files: Vec<PathBuf>,
    fields: Vec<String>,
    ops: Vec<PartitionOp>,
    output_schema: Schema,
}

/// Lower a logical plan. Fails on shapes the single-pass executor cannot
/// run: no leading `Ingest`, a `Project` that did not fold into the scan
/// (run [`LogicalPlan::optimize`]), more than one `Distinct`, or a
/// missing/misplaced `Collect`.
///
/// ```
/// use p3sapp::plan::{lower, LogicalPlan};
///
/// let plan = LogicalPlan::scan(vec![], &["title"]).collect();
/// let phys = lower(&plan).unwrap();
/// assert_eq!(phys.output_schema().field_names(), vec!["title"]);
/// ```
pub fn lower(plan: &LogicalPlan) -> Result<PhysicalPlan> {
    let mut it = plan.ops().iter();
    let (files, mut fields) = match it.next() {
        Some(LogicalOp::Ingest { files, fields }) => (files.clone(), fields.clone()),
        _ => anyhow::bail!("plan must start with an Ingest op"),
    };
    let mut schema = strings_schema(&fields);
    let mut ops: Vec<PartitionOp> = Vec::new();
    let mut has_distinct = false;
    let mut collected = false;
    for op in it {
        anyhow::ensure!(!collected, "Collect must be the final plan op");
        match op {
            LogicalOp::Ingest { .. } => anyhow::bail!("plan has more than one Ingest op"),
            LogicalOp::Project { cols } => {
                anyhow::ensure!(
                    ops.is_empty(),
                    "Project is only supported directly after Ingest (run optimize())"
                );
                for c in cols {
                    anyhow::ensure!(fields.contains(c), "Project: unknown column '{c}'");
                }
                fields = cols.clone();
                schema = strings_schema(&fields);
            }
            LogicalOp::DropNulls { cols } => {
                ops.push(PartitionOp::NullFilter { idxs: resolve(&schema, cols)? });
            }
            LogicalOp::Distinct { cols } => {
                anyhow::ensure!(!has_distinct, "at most one Distinct op is supported");
                has_distinct = true;
                ops.push(PartitionOp::HashKeys { idxs: resolve(&schema, cols)? });
            }
            LogicalOp::DropEmpty { cols } => {
                ops.push(PartitionOp::EmptyFilter { idxs: resolve(&schema, cols)? });
            }
            LogicalOp::Transform { stage } => {
                let in_idx = schema.index_of(stage.input_col()).ok_or_else(|| {
                    anyhow::anyhow!(
                        "stage {}: input column '{}' not found",
                        stage.name(),
                        stage.input_col()
                    )
                })?;
                let in_dtype = schema.fields()[in_idx].dtype;
                let out_dtype = stage.output_dtype(in_dtype);
                let out_idx = match schema.index_of(stage.output_col()) {
                    Some(i) => {
                        schema = schema.with_dtype(stage.output_col(), out_dtype).unwrap();
                        i
                    }
                    None => {
                        let mut f = schema.fields().to_vec();
                        f.push(Field::new(stage.output_col(), out_dtype));
                        schema = Schema::new(f);
                        schema.len() - 1
                    }
                };
                ops.push(PartitionOp::Stage { stage: Arc::clone(stage), in_idx, out_idx });
            }
            LogicalOp::Collect => collected = true,
        }
    }
    anyhow::ensure!(collected, "plan must end with a Collect op");
    Ok(PhysicalPlan { files, fields, ops, output_schema: schema })
}

fn strings_schema(fields: &[String]) -> Schema {
    Schema::strings(&fields.iter().map(|s| s.as_str()).collect::<Vec<_>>())
}

fn resolve(schema: &Schema, cols: &[String]) -> Result<Vec<usize>> {
    cols.iter()
        .map(|c| {
            schema
                .index_of(c)
                .ok_or_else(|| anyhow::anyhow!("no such column: {c}"))
        })
        .collect()
}

/// Per-worker time spent in each of the paper's stages during the pass.
#[derive(Debug, Default, Clone, Copy)]
struct Phases {
    ingest: Duration,
    pre: Duration,
    clean: Duration,
    post: Duration,
}

impl Phases {
    fn total(&self) -> Duration {
        self.ingest + self.pre + self.clean + self.post
    }
}

/// What one worker hands back for one shard file. Opaque outside the
/// plan layer; the streaming executor moves these from its worker pool
/// to the driver-side [`Merger`] without looking inside.
pub(super) struct PartResult {
    part: Partition,
    /// Dedup keys aligned with `part` rows (present iff the plan has a
    /// `Distinct`); masked along with the rows by later filters.
    keys: Option<Vec<u128>>,
    rows_ingested: usize,
    nulls_dropped: usize,
    empties_dropped: usize,
    phases: Phases,
}

/// Result of executing a plan: the collected frame plus the stage-time
/// and row accounting the drivers/reports consume.
#[derive(Debug, Clone)]
pub struct PlanOutput {
    pub frame: LocalFrame,
    pub times: StageTimes,
    pub rows_ingested: usize,
    pub rows_out: usize,
    pub nulls_dropped: usize,
    pub dups_dropped: usize,
    pub empties_dropped: usize,
}

/// Driver-side accumulator shared by the single-pass and streaming
/// executors: counters, the first-occurrence-wins dedup merge over the
/// pre-hashed keys, and the extend into one contiguous [`LocalFrame`].
///
/// Push order **is** output row order and decides which duplicate
/// survives, so callers must push results in input shard order — the
/// streaming executor re-sequences out-of-order arrivals before pushing.
pub(super) struct Merger {
    local: LocalFrame,
    seen: HashSet<u128>,
    phases: Phases,
    rows_ingested: usize,
    nulls_dropped: usize,
    empties_dropped: usize,
    dups_dropped: usize,
    dedup_wall: Duration,
    collect_wall: Duration,
}

impl Merger {
    pub(super) fn new(schema: Schema) -> Merger {
        Merger {
            local: LocalFrame::empty(schema),
            seen: HashSet::new(),
            phases: Phases::default(),
            rows_ingested: 0,
            nulls_dropped: 0,
            empties_dropped: 0,
            dups_dropped: 0,
            dedup_wall: Duration::ZERO,
            collect_wall: Duration::ZERO,
        }
    }

    /// Fold one shard's result in (must be called in shard order).
    pub(super) fn push(&mut self, r: PartResult) {
        let PartResult { part, keys, rows_ingested, nulls_dropped, empties_dropped, phases } = r;
        self.phases.ingest += phases.ingest;
        self.phases.pre += phases.pre;
        self.phases.clean += phases.clean;
        self.phases.post += phases.post;
        self.rows_ingested += rows_ingested;
        self.nulls_dropped += nulls_dropped;
        self.empties_dropped += empties_dropped;
        let part = match keys {
            Some(keys) => {
                let t = Instant::now();
                debug_assert_eq!(keys.len(), part.num_rows());
                let mut mask = vec![true; keys.len()];
                let mut local_drop = 0usize;
                for (i, k) in keys.iter().enumerate() {
                    if !self.seen.insert(*k) {
                        mask[i] = false;
                        local_drop += 1;
                    }
                }
                self.dups_dropped += local_drop;
                let part = if local_drop > 0 { part.filter_by_mask(&mask) } else { part };
                self.dedup_wall += t.elapsed();
                part
            }
            None => part,
        };
        let t = Instant::now();
        self.local.extend_from_partition(part);
        self.collect_wall += t.elapsed();
    }

    /// Close the accumulation: attribute `pass_wall` to the four stage
    /// keys in proportion to the summed per-worker phase spans, add the
    /// directly-measured dedup/collect spans, and assemble the output.
    /// `extra_ingest` carries parse time measured outside the op program
    /// (the re-chunk path parses before chunking).
    ///
    /// This variant is for the single-pass executor, where the driver
    /// merge runs *after* `pass_wall` was captured.
    pub(super) fn finish(self, pass_wall: Duration, extra_ingest: Duration) -> PlanOutput {
        self.finish_with(pass_wall, extra_ingest)
    }

    /// Streaming variant: the driver merge ran *inside* `pass_wall`
    /// (concurrently with parsing and cleaning), so its directly-measured
    /// spans are removed from the proportional base before attribution —
    /// otherwise `times.total()` would exceed the real wall time by the
    /// merge duration.
    pub(super) fn finish_overlapped(self, pass_wall: Duration) -> PlanOutput {
        let merge = self.dedup_wall + self.collect_wall;
        self.finish_with(pass_wall.saturating_sub(merge), Duration::ZERO)
    }

    fn finish_with(self, pass_wall: Duration, extra_ingest: Duration) -> PlanOutput {
        let mut phases = self.phases;
        phases.ingest += extra_ingest;

        let mut times = StageTimes::new();
        let worker_total = phases.total().as_secs_f64();
        let wall = pass_wall.as_secs_f64();
        let share = |d: Duration| {
            if worker_total > 0.0 {
                Duration::from_secs_f64(wall * d.as_secs_f64() / worker_total)
            } else {
                Duration::ZERO
            }
        };
        times.add(
            INGESTION,
            if worker_total > 0.0 { share(phases.ingest) } else { pass_wall },
        );
        times.add(PRE_CLEANING, share(phases.pre));
        times.add(CLEANING, share(phases.clean));
        times.add(POST_CLEANING, share(phases.post));
        times.add(PRE_CLEANING, self.dedup_wall);
        times.add(POST_CLEANING, self.collect_wall);

        let rows_out = self.local.num_rows();
        PlanOutput {
            frame: self.local,
            times,
            rows_ingested: self.rows_ingested,
            rows_out,
            nulls_dropped: self.nulls_dropped,
            dups_dropped: self.dups_dropped,
            empties_dropped: self.empties_dropped,
        }
    }
}

impl PhysicalPlan {
    pub fn output_schema(&self) -> &Schema {
        &self.output_schema
    }

    /// The shard files this plan will scan, in output (shard) order.
    pub(super) fn files(&self) -> &[PathBuf] {
        &self.files
    }

    /// The projected field list the scan parses.
    pub(super) fn fields(&self) -> &[String] {
        &self.fields
    }

    /// Execute with `workers` threads (0 = all cores).
    pub fn execute(&self, workers: usize) -> Result<PlanOutput> {
        let exec = Executor::new(workers);
        let t_pass = Instant::now();
        // The shard file is the unit of parallelism — unless files are
        // scarcer than threads or one oversized shard would serialize
        // the cleaning (the straggler problem `engine::rebalance` solved
        // for the eager path). In those cases parse first, re-chunk the
        // partitions to fill the pool, and run the op program over the
        // chunks; output order (and therefore dedup and row order) is
        // identical either way.
        let mut extra_ingest = Duration::ZERO;
        let results: Vec<PartResult> = if !self.needs_rechunk(exec.workers()) {
            exec.map_items(self.files.clone(), |path| self.run_partition(&path))
                .into_iter()
                .collect::<Result<Vec<_>>>()?
        } else {
            let parsed: Vec<Result<(Partition, Duration)>> =
                exec.map_items(self.files.clone(), |path| {
                    let t0 = Instant::now();
                    let part = crate::ingest::spark::read_shard(&path, &self.fields)?;
                    Ok((part, t0.elapsed()))
                });
            let mut parts: Vec<Partition> = Vec::with_capacity(parsed.len());
            for r in parsed {
                let (part, span) = r?;
                extra_ingest += span;
                parts.push(part);
            }
            // Same chunk budget as the eager path's rebalance: about
            // workers*4 chunks total, each file split by its own share.
            let total_rows: usize = parts.iter().map(Partition::num_rows).sum();
            let target_rows = (total_rows / (exec.workers() * 4)).max(1);
            let mut chunks: Vec<Partition> = Vec::new();
            for part in parts {
                let pieces = part.num_rows().div_ceil(target_rows).max(1);
                chunks.extend(part.split_rows(pieces));
            }
            exec.map_items(chunks, |part| self.run_ops(part, Duration::ZERO))
        };
        let pass_wall = t_pass.elapsed();

        let mut merger = Merger::new(self.output_schema.clone());
        for r in results {
            merger.push(r);
        }
        Ok(merger.finish(pass_wall, extra_ingest))
    }

    /// Execute through the two-stage streaming pipeline instead of the
    /// fused single pass: a bounded reader stage parses shards while a
    /// worker pool runs the op program on shards already parsed (see
    /// [`StreamExecutor`]). Output is byte-identical to [`Self::execute`].
    pub fn execute_stream(&self, opts: &StreamOptions) -> Result<PlanOutput> {
        StreamExecutor::new(opts.clone()).execute(self)
    }

    /// File-granularity parallelism serializes when files are scarcer
    /// than workers or when one shard dominates the byte count
    /// (mirrors `engine::needs_rebalance`'s `max_share = 0.25` rule,
    /// judged from file metadata so no parse is wasted). Unreadable
    /// metadata defers to the single-pass path, where `read_shard`
    /// reports the real error.
    fn needs_rechunk(&self, workers: usize) -> bool {
        if self.files.is_empty() || workers <= 1 {
            return false;
        }
        if self.files.len() < workers {
            return true;
        }
        let mut total = 0u64;
        let mut max = 0u64;
        for f in &self.files {
            let Ok(meta) = std::fs::metadata(f) else { return false };
            total += meta.len();
            max = max.max(meta.len());
        }
        total > 0 && (max as f64) / (total as f64) > 0.25
    }

    /// The whole per-shard program, run by one worker: parse + op chain.
    fn run_partition(&self, path: &Path) -> Result<PartResult> {
        let t0 = Instant::now();
        let part = crate::ingest::spark::read_shard(path, &self.fields)?;
        Ok(self.run_ops(part, t0.elapsed()))
    }

    /// The op chain over one already-parsed partition (or chunk of one).
    /// `ingest_span` is the parse time to attribute to the ingestion
    /// stage — measured by the caller when parsing happened elsewhere
    /// (the streaming executor's reader stage, the re-chunk path).
    pub(super) fn run_ops(&self, mut part: Partition, ingest_span: Duration) -> PartResult {
        let mut phases = Phases { ingest: ingest_span, ..Default::default() };
        let rows_ingested = part.num_rows();
        let mut keys: Option<Vec<u128>> = None;
        let mut nulls_dropped = 0usize;
        let mut empties_dropped = 0usize;

        for op in &self.ops {
            match op {
                PartitionOp::NullFilter { idxs } => {
                    let t = Instant::now();
                    let (mask, dropped) = crate::frame::null_mask(&part, idxs);
                    if dropped > 0 {
                        part = part.filter_by_mask(&mask);
                        if let Some(k) = &mut keys {
                            retain_by_mask(k, &mask);
                        }
                    }
                    nulls_dropped += dropped;
                    phases.pre += t.elapsed();
                }
                PartitionOp::HashKeys { idxs } => {
                    let t = Instant::now();
                    keys = Some(
                        (0..part.num_rows()).map(|i| hash_row_wide(&part, idxs, i)).collect(),
                    );
                    phases.pre += t.elapsed();
                }
                PartitionOp::Stage { stage, in_idx, out_idx } => {
                    let t = Instant::now();
                    if in_idx == out_idx {
                        let owned = part.take_column(*in_idx);
                        part.replace_column(*out_idx, stage.transform_column_owned(owned));
                    } else {
                        let col = stage.transform_column(part.column(*in_idx));
                        if *out_idx < part.num_columns() {
                            part.replace_column(*out_idx, col);
                        } else {
                            let mut cols = part.into_columns();
                            cols.push(col);
                            part = Partition::new(cols);
                        }
                    }
                    phases.clean += t.elapsed();
                }
                PartitionOp::EmptyFilter { idxs } => {
                    let t = Instant::now();
                    for &ci in idxs {
                        part.column_mut(ci).nullify_empty_strs();
                    }
                    let (mask, dropped) = crate::frame::null_mask(&part, idxs);
                    if dropped > 0 {
                        part = part.filter_by_mask(&mask);
                        if let Some(k) = &mut keys {
                            retain_by_mask(k, &mask);
                        }
                    }
                    empties_dropped += dropped;
                    phases.post += t.elapsed();
                }
            }
        }
        PartResult { part, keys, rows_ingested, nulls_dropped, empties_dropped, phases }
    }

    /// One rendered line per op of the per-partition program, shared by
    /// the single-pass and streaming EXPLAIN renderings.
    fn op_lines(&self) -> Vec<String> {
        let name = |i: usize| self.output_schema.fields()[i].name.as_str();
        let list =
            |idxs: &[usize]| idxs.iter().map(|&i| name(i)).collect::<Vec<_>>().join(", ");
        let mut lines = Vec::with_capacity(self.ops.len());
        for op in &self.ops {
            match op {
                PartitionOp::NullFilter { idxs } => {
                    lines.push(format!("null-filter [{}]", list(idxs)));
                }
                PartitionOp::HashKeys { idxs } => {
                    lines.push(format!("hash-keys [{}] (128-bit)", list(idxs)));
                }
                PartitionOp::Stage { stage, in_idx, out_idx } => {
                    let mode = if in_idx == out_idx { "in-place sweep" } else { "append" };
                    lines.push(format!("{} ({mode})", stage.describe()));
                }
                PartitionOp::EmptyFilter { idxs } => {
                    lines.push(format!("empty-filter [{}]", list(idxs)));
                }
            }
        }
        lines
    }

    fn has_dedup(&self) -> bool {
        self.ops.iter().any(|op| matches!(op, PartitionOp::HashKeys { .. }))
    }

    /// Render the physical program (EXPLAIN's third section).
    pub fn render(&self, workers: usize) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "SinglePass [{} file-partitions, {} workers]",
            self.files.len(),
            Executor::new(workers).workers()
        );
        let _ = writeln!(s, "  parse+project [{}]", self.fields.join(", "));
        for line in self.op_lines() {
            let _ = writeln!(s, "  {line}");
        }
        if self.has_dedup() {
            let _ = writeln!(s, "Driver: ordered dedup merge (HashSet) -> collect(LocalFrame)");
        } else {
            let _ = writeln!(s, "Driver: collect(LocalFrame)");
        }
        s
    }

    /// Render the streaming topology (EXPLAIN's third section when the
    /// streaming executor is selected): reader count, queue bound and
    /// worker count around the same per-partition op program. When the
    /// executor would delegate to the single pass (fewer shards than
    /// cleaning workers — see [`StreamExecutor`]), that is rendered
    /// instead, so EXPLAIN always shows the schedule that actually runs.
    pub fn render_stream(&self, opts: &StreamOptions) -> String {
        use std::fmt::Write;
        let (readers, workers, queue_cap) = opts.resolve(self.files.len());
        if !self.files.is_empty() && self.files.len() < workers {
            let mut s = String::new();
            let _ = writeln!(
                s,
                "StreamPipeline fallback ({} file-partitions < {workers} workers) -> single pass:",
                self.files.len()
            );
            s.push_str(&self.render(readers + workers));
            return s;
        }
        let mut s = String::new();
        let _ = writeln!(s, "StreamPipeline [{} file-partitions]", self.files.len());
        let _ = writeln!(s, "  readers: {readers} x parse+project [{}]", self.fields.join(", "));
        let _ = writeln!(s, "  queue:   bounded({queue_cap} partitions, backpressure)");
        let _ = writeln!(s, "  workers: {workers} x op-program");
        for line in self.op_lines() {
            let _ = writeln!(s, "    {line}");
        }
        if self.has_dedup() {
            let _ = writeln!(
                s,
                "Driver: streaming ordered dedup merge (reorder buffer) -> collect(LocalFrame)"
            );
        } else {
            let _ = writeln!(s, "Driver: streaming ordered collect(LocalFrame)");
        }
        s
    }
}

fn retain_by_mask(keys: &mut Vec<u128>, mask: &[bool]) {
    debug_assert_eq!(keys.len(), mask.len());
    let mut i = 0;
    keys.retain(|_| {
        let keep = mask[i];
        i += 1;
        keep
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate_corpus, CorpusSpec};
    use crate::ingest::list_shards;
    use crate::pipeline::presets::case_study_plan;
    use crate::pipeline::stages::Tokenizer;

    fn corpus(name: &str) -> (PathBuf, Vec<PathBuf>) {
        let dir = std::env::temp_dir().join(format!("p3sapp-plan-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        generate_corpus(&CorpusSpec::tiny(23), &dir).unwrap();
        let files = list_shards(&dir).unwrap();
        (dir, files)
    }

    #[test]
    fn lower_rejects_malformed_plans() {
        // No Ingest.
        let bare = LogicalPlan { ops: vec![LogicalOp::Collect] };
        assert!(lower(&bare).is_err());
        // No Collect.
        assert!(lower(&LogicalPlan::scan(vec![], &["c"])).is_err());
        // Two Distincts.
        let twice = LogicalPlan::scan(vec![], &["c"])
            .distinct(&["c"])
            .distinct(&["c"])
            .collect();
        assert!(lower(&twice).is_err());
        // Unknown column.
        let bad = LogicalPlan::scan(vec![], &["c"]).drop_nulls(&["nope"]).collect();
        assert!(lower(&bad).is_err());
    }

    #[test]
    fn lower_tracks_schema_through_transforms() {
        let plan = LogicalPlan::scan(vec![], &["abstract"])
            .transform(Tokenizer::new("abstract", "words"))
            .collect();
        let phys = lower(&plan).unwrap();
        assert_eq!(phys.output_schema().field_names(), vec!["abstract", "words"]);
    }

    #[test]
    fn execute_empty_file_list() {
        let plan = case_study_plan(&[], "title", "abstract").optimize();
        let out = plan.execute(2).unwrap();
        assert_eq!(out.rows_ingested, 0);
        assert_eq!(out.rows_out, 0);
        assert_eq!(out.frame.num_rows(), 0);
    }

    #[test]
    fn execute_records_all_four_stages_and_counts() {
        let (dir, files) = corpus("stages");
        let out = case_study_plan(&files, "title", "abstract")
            .optimize()
            .execute(2)
            .unwrap();
        assert!(out.rows_ingested > 0);
        assert!(out.rows_out > 0);
        assert_eq!(
            out.rows_out,
            out.rows_ingested - out.nulls_dropped - out.dups_dropped - out.empties_dropped
        );
        for key in [INGESTION, PRE_CLEANING, CLEANING, POST_CLEANING] {
            assert!(out.times.secs(key) >= 0.0);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unoptimized_and_optimized_plans_agree() {
        let (dir, files) = corpus("optagree");
        let plan = case_study_plan(&files, "title", "abstract");
        let staged = plan.execute(2).unwrap();
        let fused = plan.clone().optimize().execute(2).unwrap();
        assert_eq!(staged.frame, fused.frame);
        assert_eq!(staged.dups_dropped, fused.dups_dropped);
        assert_eq!(
            staged.nulls_dropped + staged.empties_dropped,
            fused.nulls_dropped + fused.empties_dropped
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn worker_count_does_not_change_plan_output() {
        let (dir, files) = corpus("workers");
        let plan = case_study_plan(&files, "title", "abstract").optimize();
        let r1 = plan.execute(1).unwrap();
        let r4 = plan.execute(4).unwrap();
        // More workers than shard files exercises the re-chunking path.
        let r16 = plan.execute(files.len() * 3).unwrap();
        assert_eq!(r1.frame, r4.frame);
        assert_eq!(r1.frame, r16.frame);
        assert_eq!(r1.rows_ingested, r16.rows_ingested);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rechunk_triggers_on_scarce_or_skewed_files() {
        let dir = std::env::temp_dir()
            .join(format!("p3sapp-plan-rechunk-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut files = Vec::new();
        for (name, bytes) in [("a", 10usize), ("b", 10), ("c", 10), ("d", 1000)] {
            let path = dir.join(format!("{name}.json"));
            std::fs::write(&path, "x".repeat(bytes)).unwrap();
            files.push(path);
        }
        let phys = case_study_plan(&files, "title", "abstract").lower().unwrap();
        assert!(phys.needs_rechunk(8), "fewer files than workers");
        assert!(phys.needs_rechunk(4), "one shard holds >25% of the bytes");
        assert!(!phys.needs_rechunk(1), "single worker has nothing to balance");
        // Balanced files at matching worker count pass through.
        let balanced: Vec<PathBuf> = files[..3].to_vec();
        let phys = case_study_plan(&balanced, "title", "abstract").lower().unwrap();
        assert!(!phys.needs_rechunk(3));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn render_mentions_single_pass_and_dedup() {
        let plan = case_study_plan(&[], "title", "abstract").optimize();
        let phys = plan.lower().unwrap();
        let r = phys.render(2);
        assert!(r.contains("SinglePass"), "{r}");
        assert!(r.contains("hash-keys [title, abstract]"), "{r}");
        assert!(r.contains("FusedStringStage"), "{r}");
        assert!(r.contains("dedup merge"), "{r}");
    }
}
