//! Physical execution: the whole lowered plan runs inside **one**
//! parallel pass over the shard files. Each worker, per file:
//! read bytes → zero-copy cursor parse ([`crate::json::cursor`]) →
//! null mask / positional sample / 128-bit dedup keys / limit cap over
//! *borrowed* cells still pointing into the shard buffer → materialize
//! survivors → (fused) cleaning sweeps → empty-string sweep. Rows the
//! leading filters drop are never copied out of the raw buffer. The
//! driver is left with the only inherently ordered work: the
//! first-occurrence-wins dedup merge, the global `Limit` budget, and
//! the final extend into a contiguous [`LocalFrame`].
//!
//! Plans carrying an `Estimator` stage ([`LogicalOp::Fit`]) lower to a
//! **two-pass strategy**: pass 1 runs the pre-estimator program over the
//! shards and folds each surviving partition's input column into the
//! estimator's [`FitAccumulator`](crate::pipeline::FitAccumulator)
//! (document frequencies for `IDF`) — no frame is materialized — then
//! pass 2 re-runs the program with the fitted model spliced in as an
//! ordinary stage, fused with the remaining ops. Both passes run on
//! whichever executor the caller picked (fused single pass or the
//! streaming pipeline), so estimator-bearing pipelines no longer bail
//! out to the staged `Pipeline::fit`/`transform` path.
//!
//! This replaces the eager driver's four barrier-separated phases
//! (ingest ‖ → pre-clean → clean ‖ → post-clean) with a single
//! `map_items` over files — no thread pool ever drains while another
//! stage waits to start, which is where the fused plan's wall-clock win
//! comes from on top of the per-row fusion win.
//!
//! Stage-time accounting: the paper's tables want per-stage wall times,
//! but a fused pass has no per-stage walls. Workers therefore record
//! per-phase CPU spans, and the pass's wall time is attributed to the
//! four stage keys proportionally; the driver-side dedup merge and
//! collect are measured directly and added to pre-/post-cleaning. A fit
//! pass's wall time is added to the cleaning stage (fitting is
//! preprocessing work the staged path pays inside `Pipeline::fit`).

use super::logical::{LogicalOp, LogicalPlan};
use super::stream::{StreamExecutor, StreamOptions};
use crate::cache::xxh64;
use crate::driver::{CLEANING, INGESTION, POST_CLEANING, PRE_CLEANING};
use crate::engine::Executor;
use crate::frame::{hash_cells_wide, hash_row_wide, Column, Field, LocalFrame, Partition, Schema};
use crate::json::cursor::ProjectedColumns;
use crate::metrics::StageTimes;
use crate::obs;
use crate::pipeline::{Estimator, Transformer};
use crate::Result;
use std::borrow::Cow;
use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One step of the per-partition single-pass program. `pub(super)` so
/// the multi-process executor (`super::process`) can serialize the
/// program into its wire format and a worker process can rebuild it.
#[derive(Clone)]
pub(super) enum PartitionOp {
    /// Drop rows null in any of the columns (pre-cleaning).
    NullFilter { idxs: Vec<usize> },
    /// Compute 128-bit dedup keys for distinct op `slot` over the
    /// columns *at this point* in the program — i.e. over raw values
    /// when `Distinct` precedes the cleaning stages, as in Algorithm 1.
    HashKeys { slot: usize, idxs: Vec<usize> },
    /// Positional Bernoulli sample: keep row `i` of shard `s` (at this
    /// point in the program) iff `hash(s, i, seed)` lands under
    /// `fraction`. Position-based — not content-based — so the optimizer
    /// may hoist it over row-preserving transforms without changing
    /// which rows are kept.
    SampleFilter { fraction: f64, seed: u64 },
    /// Per-partition prefix cap for a `Limit` — emitted only when the
    /// plan has no `Distinct` (a pending dedup could need rows past the
    /// local cap). The global budget is always enforced at the merge.
    LimitCap { n: usize },
    /// Apply one (possibly fused) transformer stage.
    Stage { stage: Arc<dyn Transformer>, in_idx: usize, out_idx: usize },
    /// Empty-string → null sweep + null filter (post-cleaning).
    EmptyFilter { idxs: Vec<usize> },
}

/// The lowered form of a [`LogicalOp::Fit`]: everything pass 1 and
/// pass 2 need to fit the estimator and splice the fitted model.
/// `pub(super)` so the incremental cache (`super::incremental`) can
/// orchestrate its own prefix-restore + re-fit + continuation.
pub(super) struct TwoPass {
    /// `ops[..prefix_len]` is the pass-1 (pre-estimator) program.
    pub(super) prefix_len: usize,
    /// Schema at the estimator's position (pass-1 output schema).
    prefix_schema: Schema,
    pub(super) est: Arc<dyn Estimator>,
    pub(super) in_idx: usize,
    out_idx: usize,
    /// Whether the plan's `Limit` precedes the estimator (then the fit
    /// pass must enforce it — the fit sees only the limited stream).
    limit_in_prefix: bool,
}

/// A lowered, executable plan: the ingestion spec plus the straight-line
/// per-partition program and the pre-computed output schema.
pub struct PhysicalPlan {
    files: Vec<PathBuf>,
    fields: Vec<String>,
    ops: Vec<PartitionOp>,
    output_schema: Schema,
    /// Number of `Distinct` ops lowered into the program.
    n_distinct: usize,
    /// Global row budget of the plan's `Limit` op, enforced at the
    /// driver-side merge (plus an optional per-partition `LimitCap`).
    limit: Option<usize>,
    two_pass: Option<TwoPass>,
}

/// Lower a logical plan. Fails on shapes the executors cannot run: no
/// leading `Ingest`, a `Project` that did not fold into the scan (run
/// [`LogicalPlan::optimize`]), a `Sample` after a `Distinct` or `Limit`
/// (merge-side dedup/budgeting makes downstream row positions unknowable
/// inside a worker), a `Limit` followed by filters, more than one
/// `Limit` or estimator, an estimator without incremental-fit support,
/// or a missing/misplaced `Collect`.
///
/// ```
/// use p3sapp::plan::{lower, LogicalPlan};
///
/// let plan = LogicalPlan::scan(vec![], &["title"]).collect();
/// let phys = lower(&plan).unwrap();
/// assert_eq!(phys.output_schema().field_names(), vec!["title"]);
/// ```
pub fn lower(plan: &LogicalPlan) -> Result<PhysicalPlan> {
    let mut it = plan.ops().iter();
    let (files, mut fields) = match it.next() {
        Some(LogicalOp::Ingest { files, fields }) => (files.clone(), fields.clone()),
        _ => anyhow::bail!("plan must start with an Ingest op"),
    };
    let mut schema = strings_schema(&fields);
    let mut ops: Vec<PartitionOp> = Vec::new();
    let mut n_distinct = 0usize;
    let mut limit: Option<usize> = None;
    let mut two_pass: Option<TwoPass> = None;
    let mut collected = false;
    for op in it {
        anyhow::ensure!(!collected, "Collect must be the final plan op");
        if limit.is_some() {
            // Past a Limit only row-preserving ops may follow: a filter
            // or dedup would need the merge to know each surviving
            // row's rank at the Limit point, which workers cannot know.
            anyhow::ensure!(
                matches!(
                    op,
                    LogicalOp::Transform { .. } | LogicalOp::Fit { .. } | LogicalOp::Collect
                ),
                "only transform stages may follow Limit (move Limit later in the plan)"
            );
        }
        match op {
            LogicalOp::Ingest { .. } => anyhow::bail!("plan has more than one Ingest op"),
            LogicalOp::Project { cols } => {
                anyhow::ensure!(
                    ops.is_empty(),
                    "Project is only supported directly after Ingest (run optimize())"
                );
                for c in cols {
                    anyhow::ensure!(fields.contains(c), "Project: unknown column '{c}'");
                }
                fields = cols.clone();
                schema = strings_schema(&fields);
            }
            LogicalOp::DropNulls { cols } => {
                ops.push(PartitionOp::NullFilter { idxs: resolve(&schema, cols)? });
            }
            LogicalOp::Distinct { cols } => {
                ops.push(PartitionOp::HashKeys {
                    slot: n_distinct,
                    idxs: resolve(&schema, cols)?,
                });
                n_distinct += 1;
            }
            LogicalOp::Sample { fraction, seed } => {
                anyhow::ensure!(
                    (0.0..=1.0).contains(fraction),
                    "Sample fraction must be in [0, 1], got {fraction}"
                );
                anyhow::ensure!(
                    n_distinct == 0,
                    "Sample after Distinct is not supported (the merge-side dedup makes \
                     downstream row positions worker-unknowable); sample before dedup"
                );
                ops.push(PartitionOp::SampleFilter { fraction: *fraction, seed: *seed });
            }
            LogicalOp::Limit { n } => {
                anyhow::ensure!(limit.is_none(), "at most one Limit op is supported");
                limit = Some(*n);
                if n_distinct == 0 {
                    // No pending dedup: the global first-n rows at this
                    // point are a prefix of each shard's local rows, so
                    // workers may cap early and skip transforming rows
                    // that can never be admitted.
                    ops.push(PartitionOp::LimitCap { n: *n });
                }
            }
            LogicalOp::DropEmpty { cols } => {
                ops.push(PartitionOp::EmptyFilter { idxs: resolve(&schema, cols)? });
            }
            LogicalOp::Transform { stage } => {
                let (in_idx, out_idx, new_schema) = resolve_stage(
                    &schema,
                    stage.name(),
                    stage.input_col(),
                    stage.output_col(),
                    |d| stage.output_dtype(d),
                )?;
                schema = new_schema;
                ops.push(PartitionOp::Stage { stage: Arc::clone(stage), in_idx, out_idx });
            }
            LogicalOp::Fit { est } => {
                anyhow::ensure!(
                    two_pass.is_none(),
                    "at most one estimator stage can be lowered (chain plans for more)"
                );
                anyhow::ensure!(
                    est.accumulator().is_some(),
                    "estimator {} does not support incremental fit (no accumulator); \
                     use the eager Pipeline::fit path",
                    est.name()
                );
                let prefix_schema = schema.clone();
                let (in_idx, out_idx, new_schema) =
                    resolve_stage(&schema, est.name(), est.input_col(), est.output_col(), |d| {
                        est.output_dtype(d)
                    })?;
                schema = new_schema;
                two_pass = Some(TwoPass {
                    prefix_len: ops.len(),
                    prefix_schema,
                    est: Arc::clone(est),
                    in_idx,
                    out_idx,
                    limit_in_prefix: limit.is_some(),
                });
            }
            LogicalOp::Collect => collected = true,
        }
    }
    anyhow::ensure!(collected, "plan must end with a Collect op");
    Ok(PhysicalPlan { files, fields, ops, output_schema: schema, n_distinct, limit, two_pass })
}

fn strings_schema(fields: &[String]) -> Schema {
    Schema::strings(&fields.iter().map(|s| s.as_str()).collect::<Vec<_>>())
}

fn resolve(schema: &Schema, cols: &[String]) -> Result<Vec<usize>> {
    cols.iter()
        .map(|c| {
            schema
                .index_of(c)
                .ok_or_else(|| anyhow::anyhow!("no such column: {c}"))
        })
        .collect()
}

/// Resolve one stage's input/output column indices against `schema`,
/// returning the updated schema (shared by `Transform` and `Fit`
/// lowering so the two can never diverge on column resolution).
fn resolve_stage(
    schema: &Schema,
    name: &str,
    input_col: &str,
    output_col: &str,
    output_dtype: impl Fn(crate::frame::DType) -> crate::frame::DType,
) -> Result<(usize, usize, Schema)> {
    let in_idx = schema.index_of(input_col).ok_or_else(|| {
        anyhow::anyhow!("stage {name}: input column '{input_col}' not found")
    })?;
    let in_dtype = schema.fields()[in_idx].dtype;
    let out_dtype = output_dtype(in_dtype);
    let (out_idx, schema) = match schema.index_of(output_col) {
        Some(i) => (i, schema.with_dtype(output_col, out_dtype).unwrap()),
        None => {
            let mut f = schema.fields().to_vec();
            f.push(Field::new(output_col, out_dtype));
            let schema = Schema::new(f);
            (schema.len() - 1, schema)
        }
    };
    Ok((in_idx, out_idx, schema))
}

/// The positional sample decision shared by every executor (and by the
/// staged reference paths in tests/benches): keep row `row` of shard
/// `shard` iff a seeded position hash lands under `fraction`. The
/// decision depends only on (seed, shard, row), so sequential, fused and
/// streaming execution — and any worker count — keep the same rows.
pub fn sample_keeps(seed: u64, shard: usize, row: usize, fraction: f64) -> bool {
    if fraction >= 1.0 {
        return true;
    }
    if fraction <= 0.0 {
        return false;
    }
    let mut buf = [0u8; 16];
    buf[..8].copy_from_slice(&(shard as u64).to_le_bytes());
    buf[8..].copy_from_slice(&(row as u64).to_le_bytes());
    // Top 53 bits → uniform f64 in [0, 1).
    let h = xxh64(&buf, seed);
    ((h >> 11) as f64) * (1.0 / (1u64 << 53) as f64) < fraction
}

/// Per-worker time spent in each of the paper's stages during the pass.
#[derive(Debug, Default, Clone, Copy)]
pub(super) struct Phases {
    pub(super) ingest: Duration,
    pub(super) pre: Duration,
    pub(super) clean: Duration,
    pub(super) post: Duration,
}

impl Phases {
    fn total(&self) -> Duration {
        self.ingest + self.pre + self.clean + self.post
    }
}

/// Keys for one `Distinct` op as hashed by a worker: the key values and
/// the provenance ids (into the partition's row domain) of the rows that
/// were alive when this slot's `HashKeys` ran. Keeping ids — rather than
/// masking keys away when later filters drop rows — lets the merge
/// register a first occurrence that a later filter removed, which is
/// what makes multi-`Distinct` plans byte-identical to the staged path.
#[derive(Clone)]
pub(super) struct KeySlot {
    pub(super) keys: Vec<u128>,
    pub(super) ids: Vec<u32>,
}

/// What one worker hands back for one shard file (or chunk). Opaque
/// outside the plan layer; the streaming executor moves these from its
/// worker pool to the driver-side [`Merger`] without looking inside.
/// `Clone` because the incremental cache's fit fold consumes a copy of
/// each pass-1 result while the original continues into pass 2.
#[derive(Clone)]
pub(super) struct PartResult {
    pub(super) part: Partition,
    /// One entry per `Distinct` op in the program, in slot order; empty
    /// when the plan does not dedup.
    pub(super) slots: Vec<KeySlot>,
    /// Final rows → provenance ids; `None` when the plan does not dedup.
    pub(super) final_ids: Option<Vec<u32>>,
    pub(super) rows_ingested: usize,
    pub(super) nulls_dropped: usize,
    pub(super) empties_dropped: usize,
    pub(super) sampled_out: usize,
    pub(super) limited_out: usize,
    pub(super) phases: Phases,
}

/// Mutable op-program state threaded from the raw (borrowed-cell)
/// prefix into the owned continuation (`run_ops_from`): counters,
/// provenance ids and hashed key slots accumulate across the handoff
/// so the two halves together report exactly what one owned pass would.
struct OpState {
    phases: Phases,
    /// Current rows → parsed-row provenance ids; `Some` only when the
    /// plan dedups (they let the merge register first occurrences that
    /// later filters removed).
    ids: Option<Vec<u32>>,
    slots: Vec<KeySlot>,
    rows_ingested: usize,
    nulls_dropped: usize,
    empties_dropped: usize,
    sampled_out: usize,
    limited_out: usize,
}

impl OpState {
    fn new(rows_ingested: usize, n_distinct: usize, ingest_span: Duration) -> Self {
        OpState {
            phases: Phases { ingest: ingest_span, ..Default::default() },
            ids: (n_distinct > 0).then(|| (0..rows_ingested as u32).collect()),
            slots: Vec::new(),
            rows_ingested,
            nulls_dropped: 0,
            empties_dropped: 0,
            sampled_out: 0,
            limited_out: 0,
        }
    }
}

/// A cursor-parsed shard still borrowing the raw byte buffer: projected
/// columns of `Cow` cells. The raw-capable prefix ops (null filter,
/// dedup-key hashing, positional sample, limit cap) run directly on
/// these borrowed cells, so rows they drop are never copied out of the
/// shard buffer; `materialize` builds the owned [`Partition`] from
/// whatever survived.
struct RawPart<'a> {
    cols: Vec<Vec<Option<Cow<'a, str>>>>,
    rows: usize,
}

impl<'a> RawPart<'a> {
    fn cell(&self, ci: usize, ri: usize) -> Option<&str> {
        self.cols[ci][ri].as_deref()
    }

    /// Drop rows with a null in any of the listed columns; returns how
    /// many were dropped. Mirrors `frame::null_mask` + `filter_by_mask`.
    fn null_filter(&mut self, idxs: &[usize], ids: Option<&mut Vec<u32>>) -> usize {
        let mask: Vec<bool> = (0..self.rows)
            .map(|i| !idxs.iter().any(|&ci| self.cols[ci][i].is_none()))
            .collect();
        let before = self.rows;
        self.filter(&mask, ids);
        before - self.rows
    }

    /// Wide hash of the listed key columns per row — cell-for-cell the
    /// same encoding as [`hash_row_wide`] on a materialized partition
    /// (pinned by a test in `frame::ops`).
    fn hash_keys(&self, idxs: &[usize]) -> Vec<u128> {
        (0..self.rows)
            .map(|i| hash_cells_wide(idxs.iter().map(|&ci| self.cell(ci, i))))
            .collect()
    }

    /// Positional Bernoulli sample, same keep function as the owned op.
    fn sample_filter(
        &mut self,
        fraction: f64,
        seed: u64,
        shard: usize,
        ids: Option<&mut Vec<u32>>,
    ) -> usize {
        let mask: Vec<bool> = (0..self.rows).map(|i| sample_keeps(seed, shard, i, fraction)).collect();
        let before = self.rows;
        self.filter(&mask, ids);
        before - self.rows
    }

    /// Per-shard limit cap; returns how many rows were cut.
    fn truncate(&mut self, n: usize, ids: Option<&mut Vec<u32>>) -> usize {
        if self.rows <= n {
            return 0;
        }
        let cut = self.rows - n;
        for col in &mut self.cols {
            col.truncate(n);
        }
        if let Some(ids) = ids {
            ids.truncate(n);
        }
        self.rows = n;
        cut
    }

    fn filter(&mut self, mask: &[bool], ids: Option<&mut Vec<u32>>) {
        let kept = mask.iter().filter(|&&k| k).count();
        if kept == self.rows {
            return;
        }
        for col in &mut self.cols {
            retain_by_mask(col, mask);
        }
        if let Some(ids) = ids {
            retain_by_mask(ids, mask);
        }
        self.rows = kept;
    }

    /// Build the owned partition — the first (and only) copy out of the
    /// shard buffer for every surviving cell.
    fn materialize(self) -> Partition {
        Partition::new(
            self.cols
                .into_iter()
                .map(|col| {
                    Column::from_strs(col.into_iter().map(|c| c.map(Cow::into_owned)).collect())
                })
                .collect(),
        )
    }
}

/// Result of executing a plan: the collected frame plus the stage-time
/// and row accounting the drivers/reports consume.
#[derive(Debug, Clone)]
pub struct PlanOutput {
    pub frame: LocalFrame,
    pub times: StageTimes,
    pub rows_ingested: usize,
    pub rows_out: usize,
    pub nulls_dropped: usize,
    pub dups_dropped: usize,
    pub empties_dropped: usize,
    /// Rows skipped by a `Sample` op.
    pub sampled_out: usize,
    /// Rows cut by a `Limit` op (per-partition cap + global budget).
    pub limited_out: usize,
}

/// The global, order-sensitive admission logic shared by the collect
/// merge and the fit pass: first-occurrence-wins dedup across all
/// `Distinct` slots, then the `Limit` budget. Must be fed partitions in
/// shard order — push order *is* stream order.
pub(super) struct Admitter {
    seen: Vec<HashSet<u128>>,
    remaining: Option<usize>,
}

impl Admitter {
    pub(super) fn new(n_slots: usize, limit: Option<usize>) -> Admitter {
        Admitter { seen: (0..n_slots).map(|_| HashSet::new()).collect(), remaining: limit }
    }

    /// Admit one partition's rows: apply every distinct op in slot
    /// (= program) order over the provenance domain, mask the final
    /// rows, then charge the limit budget. Returns the admitted
    /// partition plus (dups dropped, rows cut by the limit).
    fn admit(
        &mut self,
        part: Partition,
        domain: usize,
        slots: &[KeySlot],
        final_ids: Option<&[u32]>,
    ) -> (Partition, usize, usize) {
        let (part, dups) = if self.seen.is_empty() {
            (part, 0)
        } else {
            debug_assert_eq!(slots.len(), self.seen.len());
            let mut dup = vec![false; domain];
            for (slot, ks) in slots.iter().enumerate() {
                let seen = &mut self.seen[slot];
                for (i, &id) in ks.ids.iter().enumerate() {
                    // A row dropped by an earlier distinct never
                    // reaches this one, so it must not register here.
                    if dup[id as usize] {
                        continue;
                    }
                    if !seen.insert(ks.keys[i]) {
                        dup[id as usize] = true;
                    }
                }
            }
            let ids = final_ids.expect("dedup plans carry final row ids");
            debug_assert_eq!(ids.len(), part.num_rows());
            let mut mask = vec![true; ids.len()];
            let mut dropped = 0usize;
            for (i, &id) in ids.iter().enumerate() {
                if dup[id as usize] {
                    mask[i] = false;
                    dropped += 1;
                }
            }
            let part = if dropped > 0 { part.filter_by_mask(&mask) } else { part };
            (part, dropped)
        };
        let (part, cut) = match &mut self.remaining {
            Some(budget) => {
                let rows = part.num_rows();
                if rows > *budget {
                    let cut = rows - *budget;
                    let mut part = part;
                    part.truncate_rows(*budget);
                    *budget = 0;
                    (part, cut)
                } else {
                    *budget -= rows;
                    (part, 0)
                }
            }
            None => (part, 0),
        };
        (part, dups, cut)
    }
}

/// Driver-side accumulator shared by the single-pass and streaming
/// executors: counters, the ordered dedup/limit admission
/// ([`Admitter`]), and the extend into one contiguous [`LocalFrame`].
///
/// Push order **is** output row order and decides which duplicate
/// survives, so callers must push results in input shard order — the
/// streaming executor re-sequences out-of-order arrivals before pushing.
pub(super) struct Merger {
    local: LocalFrame,
    admitter: Admitter,
    phases: Phases,
    rows_ingested: usize,
    nulls_dropped: usize,
    empties_dropped: usize,
    dups_dropped: usize,
    sampled_out: usize,
    limited_out: usize,
    dedup_wall: Duration,
    collect_wall: Duration,
}

impl Merger {
    pub(super) fn new(schema: Schema, n_slots: usize, limit: Option<usize>) -> Merger {
        Merger {
            local: LocalFrame::empty(schema),
            admitter: Admitter::new(n_slots, limit),
            phases: Phases::default(),
            rows_ingested: 0,
            nulls_dropped: 0,
            empties_dropped: 0,
            dups_dropped: 0,
            sampled_out: 0,
            limited_out: 0,
            dedup_wall: Duration::ZERO,
            collect_wall: Duration::ZERO,
        }
    }

    /// Fold one shard's result in (must be called in shard order).
    pub(super) fn push(&mut self, r: PartResult) {
        let PartResult {
            part,
            slots,
            final_ids,
            rows_ingested,
            nulls_dropped,
            empties_dropped,
            sampled_out,
            limited_out,
            phases,
        } = r;
        self.phases.ingest += phases.ingest;
        self.phases.pre += phases.pre;
        self.phases.clean += phases.clean;
        self.phases.post += phases.post;
        self.rows_ingested += rows_ingested;
        self.nulls_dropped += nulls_dropped;
        self.empties_dropped += empties_dropped;
        self.sampled_out += sampled_out;
        self.limited_out += limited_out;
        let t = Instant::now();
        let (part, dups, cut) =
            self.admitter.admit(part, rows_ingested, &slots, final_ids.as_deref());
        self.dups_dropped += dups;
        self.limited_out += cut;
        self.dedup_wall += t.elapsed();
        let t = Instant::now();
        self.local.extend_from_partition(part);
        self.collect_wall += t.elapsed();
    }

    /// Close the accumulation: attribute `pass_wall` to the four stage
    /// keys in proportion to the summed per-worker phase spans, add the
    /// directly-measured dedup/collect spans, and assemble the output.
    /// `extra_ingest` carries parse time measured outside the op program
    /// (the re-chunk path parses before chunking).
    ///
    /// This variant is for the single-pass executor, where the driver
    /// merge runs *after* `pass_wall` was captured.
    pub(super) fn finish(self, pass_wall: Duration, extra_ingest: Duration) -> PlanOutput {
        self.finish_with(pass_wall, extra_ingest)
    }

    /// Streaming variant: the driver merge ran *inside* `pass_wall`
    /// (concurrently with parsing and cleaning), so its directly-measured
    /// spans are removed from the proportional base before attribution —
    /// otherwise `times.total()` would exceed the real wall time by the
    /// merge duration.
    pub(super) fn finish_overlapped(self, pass_wall: Duration) -> PlanOutput {
        let merge = self.dedup_wall + self.collect_wall;
        self.finish_with(pass_wall.saturating_sub(merge), Duration::ZERO)
    }

    fn finish_with(self, pass_wall: Duration, extra_ingest: Duration) -> PlanOutput {
        let mut phases = self.phases;
        phases.ingest += extra_ingest;

        let mut times = StageTimes::new();
        let worker_total = phases.total().as_secs_f64();
        let wall = pass_wall.as_secs_f64();
        let share = |d: Duration| {
            if worker_total > 0.0 {
                Duration::from_secs_f64(wall * d.as_secs_f64() / worker_total)
            } else {
                Duration::ZERO
            }
        };
        times.add(
            INGESTION,
            if worker_total > 0.0 { share(phases.ingest) } else { pass_wall },
        );
        times.add(PRE_CLEANING, share(phases.pre));
        times.add(CLEANING, share(phases.clean));
        times.add(POST_CLEANING, share(phases.post));
        times.add(PRE_CLEANING, self.dedup_wall);
        times.add(POST_CLEANING, self.collect_wall);

        let rows_out = self.local.num_rows();
        PlanOutput {
            frame: self.local,
            times,
            rows_ingested: self.rows_ingested,
            rows_out,
            nulls_dropped: self.nulls_dropped,
            dups_dropped: self.dups_dropped,
            empties_dropped: self.empties_dropped,
            sampled_out: self.sampled_out,
            limited_out: self.limited_out,
        }
    }
}

impl PhysicalPlan {
    pub fn output_schema(&self) -> &Schema {
        &self.output_schema
    }

    /// The shard files this plan will scan, in output (shard) order.
    pub(super) fn files(&self) -> &[PathBuf] {
        &self.files
    }

    /// The projected field list the scan parses.
    pub(super) fn fields(&self) -> &[String] {
        &self.fields
    }

    pub(super) fn n_distinct(&self) -> usize {
        self.n_distinct
    }

    pub(super) fn limit_n(&self) -> Option<usize> {
        self.limit
    }

    /// The per-partition op program (for the wire serializer).
    pub(super) fn program(&self) -> &[PartitionOp] {
        &self.ops
    }

    /// Assemble a worker-side plan from wire-decoded parts
    /// (`super::process`). The worker only runs [`Self::run_partition`],
    /// which consults `fields`, `ops` and the derived dedup-slot count —
    /// the schema slot is a placeholder the worker never reads (the
    /// driver keeps the real output schema for the merge).
    pub(super) fn from_wire(fields: Vec<String>, ops: Vec<PartitionOp>) -> PhysicalPlan {
        let n_distinct = ops
            .iter()
            .filter(|op| matches!(op, PartitionOp::HashKeys { .. }))
            .count();
        PhysicalPlan {
            files: Vec::new(),
            output_schema: strings_schema(&fields),
            fields,
            ops,
            n_distinct,
            limit: None,
            two_pass: None,
        }
    }

    pub(super) fn is_two_pass(&self) -> bool {
        self.two_pass.is_some()
    }

    pub(super) fn two_pass(&self) -> Option<&TwoPass> {
        self.two_pass.as_ref()
    }

    pub(super) fn has_sample(&self) -> bool {
        self.ops.iter().any(|op| matches!(op, PartitionOp::SampleFilter { .. }))
    }

    /// The same program over a subset of the shard files — the
    /// incremental cache's miss sub-plan. Only the scan target changes;
    /// op program, schema, dedup slots and the global limit budget are
    /// untouched (the budget is enforced at the caller's merge over the
    /// full restored+fresh sequence, not inside the sub-plan).
    pub(super) fn with_files(&self, files: Vec<PathBuf>) -> PhysicalPlan {
        PhysicalPlan {
            files,
            fields: self.fields.clone(),
            ops: self.ops.clone(),
            output_schema: self.output_schema.clone(),
            n_distinct: self.n_distinct,
            limit: self.limit,
            two_pass: None,
        }
    }

    /// Execute with `workers` threads (0 = all cores).
    pub fn execute(&self, workers: usize) -> Result<PlanOutput> {
        if let Some(tp) = &self.two_pass {
            // Pass 1: stream shards through the prefix program to fit
            // the estimator; pass 2: the fused single pass with the
            // fitted model spliced in.
            let t0 = Instant::now();
            let fitted = self.run_fit_fused(tp, workers)?;
            let fit_wall = t0.elapsed();
            let mut out = self.with_model(tp, fitted).execute(workers)?;
            out.times.add(CLEANING, fit_wall);
            return Ok(out);
        }
        let t_pass = Instant::now();
        let (results, extra_ingest) = self.collect_results(workers)?;
        let pass_wall = t_pass.elapsed();

        let mut merger =
            Merger::new(self.output_schema.clone(), self.n_distinct, self.limit_n());
        {
            let mut sp = obs::span("merge", "driver");
            if sp.active() {
                sp.arg("parts", results.len() as u64);
            }
            for r in results {
                merger.push(r);
            }
        }
        Ok(merger.finish(pass_wall, extra_ingest))
    }

    /// Run the per-shard programs and return their results in shard
    /// order, plus parse time measured outside the programs (re-chunk
    /// path). Shared by [`Self::execute`], the fit pass, and the
    /// streaming executor's scarce-shard fallback.
    pub(super) fn collect_results(&self, workers: usize) -> Result<(Vec<PartResult>, Duration)> {
        let exec = Executor::new(workers);
        // The shard file is the unit of parallelism — unless files are
        // scarcer than threads or one oversized shard would serialize
        // the cleaning (the straggler problem `engine::rebalance` solved
        // for the eager path). In those cases parse first, re-chunk the
        // partitions to fill the pool, and run the op program over the
        // chunks; output order (and therefore dedup and row order) is
        // identical either way.
        let mut extra_ingest = Duration::ZERO;
        let results: Vec<PartResult> = if !self.needs_rechunk(exec.workers()) {
            let jobs: Vec<(usize, PathBuf)> =
                self.files.iter().cloned().enumerate().collect();
            exec.map_items(jobs, |(idx, path)| {
                // Pool threads have no external index: each claims a
                // stable worker-thread lane on first use.
                let _lane = obs::lane_scope(obs::pool_lane());
                self.run_partition(idx, &path)
            })
            .into_iter()
            .collect::<Result<Vec<_>>>()?
        } else {
            let parsed: Vec<Result<(Partition, Duration)>> =
                exec.map_items(self.files.clone(), |path| {
                    let _lane = obs::lane_scope(obs::pool_lane());
                    let mut sp = obs::span("read+parse shard", "ingest");
                    let t0 = Instant::now();
                    let part = crate::ingest::spark::read_shard(&path, &self.fields)?;
                    if sp.active() {
                        sp.arg("rows", part.num_rows() as u64);
                    }
                    Ok((part, t0.elapsed()))
                });
            let mut parts: Vec<Partition> = Vec::with_capacity(parsed.len());
            for r in parsed {
                let (part, span) = r?;
                extra_ingest += span;
                parts.push(part);
            }
            // Same chunk budget as the eager path's rebalance: about
            // workers*4 chunks total, each file split by its own share.
            let total_rows: usize = parts.iter().map(Partition::num_rows).sum();
            let target_rows = (total_rows / (exec.workers() * 4)).max(1);
            let mut chunks: Vec<Partition> = Vec::new();
            for part in parts {
                let pieces = part.num_rows().div_ceil(target_rows).max(1);
                chunks.extend(part.split_rows(pieces));
            }
            // Chunks are order-contiguous, so dedup provenance and the
            // limit budget work per chunk exactly as per shard; shard
            // identity is only needed by SampleFilter, which disables
            // re-chunking (`needs_rechunk`), so the index is unused.
            exec.map_items(chunks, |part| {
                let _lane = obs::lane_scope(obs::pool_lane());
                self.run_ops(part, 0, Duration::ZERO)
            })
        };
        Ok((results, extra_ingest))
    }

    /// Like [`Self::collect_results`], but the shard file is *always*
    /// the unit of parallelism — never the re-chunk path, whatever the
    /// file/worker ratio or byte skew. The incremental cache requires
    /// shard-aligned results (each one becomes, or is compared against,
    /// a per-shard artifact), so chunk-level results are useless to it.
    pub(super) fn collect_shard_results(&self, workers: usize) -> Result<Vec<PartResult>> {
        let exec = Executor::new(workers);
        let jobs: Vec<(usize, PathBuf)> = self.files.iter().cloned().enumerate().collect();
        exec.map_items(jobs, |(idx, path)| {
            let _lane = obs::lane_scope(obs::pool_lane());
            self.run_partition(idx, &path)
        })
        .into_iter()
        .collect::<Result<Vec<_>>>()
    }

    /// Continue the op program at `self.ops[start..]` over a shard
    /// result whose first `start` ops already ran (in this process or a
    /// previous one — the incremental cache restores pass-1 prefix
    /// results and resumes them through the fitted stage + suffix).
    /// Counters, provenance ids and hashed key slots carry across, so
    /// the resumed result is identical to running the whole program.
    pub(super) fn resume_ops(&self, r: PartResult, shard: usize, start: usize) -> PartResult {
        let state = OpState {
            phases: r.phases,
            ids: r.final_ids,
            slots: r.slots,
            rows_ingested: r.rows_ingested,
            nulls_dropped: r.nulls_dropped,
            empties_dropped: r.empties_dropped,
            sampled_out: r.sampled_out,
            limited_out: r.limited_out,
        };
        self.run_ops_from(r.part, shard, start, state)
    }

    /// Execute by distributing the op program across worker OS
    /// processes (see [`super::process::ProcessExecutor`]): the
    /// optimized program plus per-worker shard assignments are
    /// serialized into the `P3PJ` wire format, each worker runs its
    /// shards through the same per-shard program the in-process
    /// executors run and streams `P3PW` result frames back, and the
    /// driver folds them through the same `Merger`. Output is
    /// byte-identical to [`Self::execute`].
    ///
    /// Estimator plans fit in a first process pass — workers either ship
    /// [`crate::pipeline::FitAccumulator`] partials (no dedup/limit
    /// pending: the driver merges accumulated state) or admitted
    /// partitions (the driver folds them through the shared
    /// `Admitter`) — then the fitted model is broadcast inside the
    /// pass-2 job.
    pub fn execute_process(&self, opts: &super::process::ProcessOptions) -> Result<PlanOutput> {
        if let Some(tp) = &self.two_pass {
            let t0 = Instant::now();
            let fitted = self.run_fit_process(tp, opts)?;
            let fit_wall = t0.elapsed();
            let mut out = self.with_model(tp, fitted).execute_process(opts)?;
            out.times.add(CLEANING, fit_wall);
            return Ok(out);
        }
        super::process::ProcessExecutor::new(opts.clone()).execute(self)
    }

    /// Pass 1 on the process executor. Without a pending dedup or
    /// `Limit` the driver-side admission is the identity, so each worker
    /// folds its shards into its own accumulator and ships only the
    /// accumulated state (document frequencies for `IDF`) — the
    /// Spark-style partial aggregate. With dedup/limit in the prefix (or
    /// an estimator that cannot cross the wire) workers ship their
    /// prefix partitions instead and the driver admits + accumulates in
    /// shard order, exactly like the streaming fit pass.
    fn run_fit_process(
        &self,
        tp: &TwoPass,
        opts: &super::process::ProcessOptions,
    ) -> Result<Arc<dyn Transformer>> {
        let prefix = self.prefix_plan(tp);
        if partial_fit_available(tp, &prefix) {
            let spec = tp.est.wire_spec().expect("checked by partial_fit_available");
            return super::process::ProcessExecutor::new(opts.clone()).run_fit_partial(
                &prefix,
                &*tp.est,
                spec,
                tp.in_idx,
            );
        }
        let mut sink = FitSink::new(tp, &prefix)?;
        super::process::ProcessExecutor::new(opts.clone()).run(&prefix, &mut |r| sink.push(r))?;
        sink.finish()
    }

    /// Execute by shipping the op program to remote `plan-worker
    /// --listen` endpoints over TCP (see [`super::remote::RemoteExecutor`]):
    /// the same `P3PJ` job frames the process executor pipes to local
    /// children travel over sockets, shard bytes ride inline or are
    /// fetched back by content digest, and each worker streams bounded
    /// per-shard `P3PW` chunk frames that the driver folds through the
    /// same `Merger` in shard order. Output is byte-identical to
    /// [`Self::execute`].
    pub fn execute_remote(&self, opts: &super::remote::RemoteOptions) -> Result<PlanOutput> {
        if let Some(tp) = &self.two_pass {
            let t0 = Instant::now();
            let fitted = self.run_fit_remote(tp, opts)?;
            let fit_wall = t0.elapsed();
            let mut out = self.with_model(tp, fitted).execute_remote(opts)?;
            out.times.add(CLEANING, fit_wall);
            return Ok(out);
        }
        super::remote::RemoteExecutor::new(opts.clone()).execute(self)
    }

    /// Pass 1 on the remote executor — the same split as
    /// [`Self::run_fit_process`]: accumulator partials when no
    /// dedup/limit is pending, admitted prefix partitions otherwise.
    fn run_fit_remote(
        &self,
        tp: &TwoPass,
        opts: &super::remote::RemoteOptions,
    ) -> Result<Arc<dyn Transformer>> {
        let prefix = self.prefix_plan(tp);
        if partial_fit_available(tp, &prefix) {
            let spec = tp.est.wire_spec().expect("checked by partial_fit_available");
            return super::remote::RemoteExecutor::new(opts.clone()).run_fit_partial(
                &prefix,
                &*tp.est,
                spec,
                tp.in_idx,
            );
        }
        let mut sink = FitSink::new(tp, &prefix)?;
        super::remote::RemoteExecutor::new(opts.clone()).run(&prefix, &mut |r| sink.push(r))?;
        sink.finish()
    }

    /// Execute through the two-stage streaming pipeline instead of the
    /// fused single pass: a bounded reader stage parses shards while a
    /// worker pool runs the op program on shards already parsed (see
    /// [`StreamExecutor`]). Output is byte-identical to [`Self::execute`].
    pub fn execute_stream(&self, opts: &StreamOptions) -> Result<PlanOutput> {
        if let Some(tp) = &self.two_pass {
            // Pass 1 reuses the streaming reader pool over the prefix
            // program; pass 2 streams the full fitted program.
            let t0 = Instant::now();
            let fitted = self.run_fit_stream(tp, opts)?;
            let fit_wall = t0.elapsed();
            let mut out = self.with_model(tp, fitted).execute_stream(opts)?;
            out.times.add(CLEANING, fit_wall);
            return Ok(out);
        }
        StreamExecutor::new(opts.clone()).execute(self)
    }

    /// The pass-1 plan: the pre-estimator program with the estimator's
    /// input schema (no fitted stage, no suffix ops).
    pub(super) fn prefix_plan(&self, tp: &TwoPass) -> PhysicalPlan {
        let ops: Vec<PartitionOp> = self.ops[..tp.prefix_len].to_vec();
        let n_distinct = ops
            .iter()
            .filter(|op| matches!(op, PartitionOp::HashKeys { .. }))
            .count();
        PhysicalPlan {
            files: self.files.clone(),
            fields: self.fields.clone(),
            ops,
            output_schema: tp.prefix_schema.clone(),
            n_distinct,
            limit: self.limit.filter(|_| tp.limit_in_prefix),
            two_pass: None,
        }
    }

    /// The pass-2 plan: the full program with the fitted model spliced
    /// in at the estimator's position as an ordinary stage.
    pub(super) fn with_model(&self, tp: &TwoPass, fitted: Arc<dyn Transformer>) -> PhysicalPlan {
        let mut ops = self.ops.clone();
        ops.insert(
            tp.prefix_len,
            PartitionOp::Stage { stage: fitted, in_idx: tp.in_idx, out_idx: tp.out_idx },
        );
        PhysicalPlan {
            files: self.files.clone(),
            fields: self.fields.clone(),
            ops,
            output_schema: self.output_schema.clone(),
            n_distinct: self.n_distinct,
            limit: self.limit,
            two_pass: None,
        }
    }

    /// Pass 1 when the caller picked the fused executor. The fit pass
    /// only produces accumulator state (document frequencies), so even
    /// here it folds incrementally through the bounded streaming
    /// pipeline — barriering every shard's cleaned+tokenized partitions
    /// into one `Vec` before folding would give pass 1 the peak memory
    /// of a full frame materialization for no benefit. (With fewer
    /// shards than workers, [`StreamExecutor::run`] itself falls back
    /// to the parallel collect, where the partition count is small.)
    fn run_fit_fused(&self, tp: &TwoPass, workers: usize) -> Result<Arc<dyn Transformer>> {
        self.run_fit_stream(tp, &StreamOptions { readers: 0, workers, queue_cap: 16 })
    }

    /// Pass 1 on the streaming executor: the reader pool parses shards
    /// while workers run the prefix program; the driver's reorder
    /// buffer feeds the accumulator in shard order.
    fn run_fit_stream(&self, tp: &TwoPass, opts: &StreamOptions) -> Result<Arc<dyn Transformer>> {
        let prefix = self.prefix_plan(tp);
        let mut sink = FitSink::new(tp, &prefix)?;
        StreamExecutor::new(opts.clone()).run(&prefix, &mut |r| sink.push(r))?;
        sink.finish()
    }

    /// File-granularity parallelism serializes when files are scarcer
    /// than workers or when one shard dominates the byte count
    /// (mirrors `engine::needs_rebalance`'s `max_share = 0.25` rule,
    /// judged from file metadata so no parse is wasted). Unreadable
    /// metadata defers to the single-pass path, where `read_shard`
    /// reports the real error. Plans with a `Sample` never re-chunk:
    /// the positional sample is keyed on (shard, row) and a chunk has
    /// no shard identity.
    fn needs_rechunk(&self, workers: usize) -> bool {
        if self.files.is_empty() || workers <= 1 || self.has_sample() {
            return false;
        }
        if self.files.len() < workers {
            return true;
        }
        let mut total = 0u64;
        let mut max = 0u64;
        for f in &self.files {
            let Ok(meta) = std::fs::metadata(f) else { return false };
            total += meta.len();
            max = max.max(meta.len());
        }
        total > 0 && (max as f64) / (total as f64) > 0.25
    }

    /// The whole per-shard program, run by one worker: read + cursor
    /// parse + op chain. Shared with the multi-process executor's worker
    /// entry point (`super::process::worker_main`), so an in-process
    /// worker thread and a worker OS process run the exact same code
    /// per shard.
    pub(super) fn run_partition(&self, shard: usize, path: &Path) -> Result<PartResult> {
        let mut buf = Vec::new();
        self.run_partition_buffered(shard, path, &mut buf)
    }

    /// Buffer-reusing variant of [`Self::run_partition`]: the shard's
    /// raw bytes land in `buf` (cleared first), the byte cursor parses
    /// them in place, and the leading filter ops run over borrowed
    /// cells before anything is materialized. Callers that loop shards
    /// on one thread (the process worker) pass one buffer so
    /// steady-state reads reuse its allocation.
    pub(super) fn run_partition_buffered(
        &self,
        shard: usize,
        path: &Path,
        buf: &mut Vec<u8>,
    ) -> Result<PartResult> {
        let t0 = Instant::now();
        {
            let mut sp = obs::span("read shard", "io");
            crate::ingest::spark::read_shard_into(path, buf)?;
            if sp.active() {
                sp.arg("shard", shard as u64);
                sp.arg("bytes", buf.len() as u64);
            }
        }
        self.run_shard_bytes(shard, path, buf, t0.elapsed())
    }

    /// Cursor-parse an already-read shard buffer and run the program.
    /// The streaming executor's workers call this with buffers its
    /// reader stage produced; `read_span` is the reader-side I/O time
    /// to attribute to ingestion, `path` is error context only.
    pub(super) fn run_shard_bytes(
        &self,
        shard: usize,
        path: &Path,
        bytes: &[u8],
        read_span: Duration,
    ) -> Result<PartResult> {
        let mut shard_sp = obs::span("shard", "shard");
        if shard_sp.active() {
            shard_sp.arg("shard", shard as u64);
        }
        let t0 = Instant::now();
        let raw = {
            let mut sp = obs::span("parse shard", "ingest");
            let field_refs: Vec<&str> = self.fields.iter().map(|s| s.as_str()).collect();
            let raw = crate::json::parse_shard_projected(bytes, &field_refs)
                .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
            if sp.active() {
                sp.arg("shard", shard as u64);
                sp.arg("rows", raw.rows as u64);
            }
            raw
        };
        Ok(self.run_raw(raw, shard, read_span + t0.elapsed()))
    }

    /// Run the program over a freshly cursor-parsed shard: the leading
    /// raw-capable ops (null filter, dedup keys, positional sample,
    /// limit cap) execute directly on the borrowed `Cow` cells, so rows
    /// they drop are never copied out of the shard buffer; the first
    /// transformer stage (or empty-filter) forces materialization and
    /// the rest of the program continues on the owned partition.
    pub(super) fn run_raw(
        &self,
        raw: ProjectedColumns<'_>,
        shard: usize,
        ingest_span: Duration,
    ) -> PartResult {
        let mut raw = RawPart { rows: raw.rows, cols: raw.cols };
        let mut state = OpState::new(raw.rows, self.n_distinct, ingest_span);
        let mut consumed = 0usize;
        let t_raw = Instant::now();
        for op in &self.ops {
            if matches!(op, PartitionOp::Stage { .. } | PartitionOp::EmptyFilter { .. }) {
                break;
            }
            let mut sp = obs::span(op_span_name(op), "op");
            let rows_before = raw.rows;
            match op {
                PartitionOp::NullFilter { idxs } => {
                    state.nulls_dropped += raw.null_filter(idxs, state.ids.as_mut());
                }
                PartitionOp::HashKeys { slot, idxs } => {
                    debug_assert_eq!(*slot, state.slots.len(), "HashKeys slots out of order");
                    state.slots.push(KeySlot {
                        keys: raw.hash_keys(idxs),
                        ids: state.ids.as_ref().expect("dedup plans track ids").clone(),
                    });
                }
                PartitionOp::SampleFilter { fraction, seed } => {
                    state.sampled_out +=
                        raw.sample_filter(*fraction, *seed, shard, state.ids.as_mut());
                }
                PartitionOp::LimitCap { n } => {
                    state.limited_out += raw.truncate(*n, state.ids.as_mut());
                }
                PartitionOp::Stage { .. } | PartitionOp::EmptyFilter { .. } => unreachable!(),
            }
            if sp.active() {
                sp.arg("op", consumed as u64);
                sp.arg("shard", shard as u64);
                sp.arg("rows_in", rows_before as u64);
                sp.arg("rows_out", raw.rows as u64);
            }
            consumed += 1;
        }
        state.phases.pre += t_raw.elapsed();
        // Materializing the surviving cells is the column-build work
        // `read_shard` used to do at parse time — ingestion's bill.
        let t_mat = Instant::now();
        let part = {
            let _sp = obs::span("materialize", "ingest");
            raw.materialize()
        };
        state.phases.ingest += t_mat.elapsed();
        self.run_ops_from(part, shard, consumed, state)
    }

    /// The op chain over one already-parsed partition (or chunk of one).
    /// `shard` is the shard index (used only by `SampleFilter`);
    /// `ingest_span` is the parse time to attribute to the ingestion
    /// stage — measured by the caller when parsing happened elsewhere
    /// (the re-chunk path, tests feeding synthetic partitions).
    pub(super) fn run_ops(
        &self,
        part: Partition,
        shard: usize,
        ingest_span: Duration,
    ) -> PartResult {
        let state = OpState::new(part.num_rows(), self.n_distinct, ingest_span);
        self.run_ops_from(part, shard, 0, state)
    }

    /// Continue the op program at `self.ops[start..]` over an owned
    /// partition, with `state` carrying whatever the raw prefix already
    /// did (counters, provenance ids, hashed key slots, phase spans).
    fn run_ops_from(
        &self,
        mut part: Partition,
        shard: usize,
        start: usize,
        state: OpState,
    ) -> PartResult {
        let OpState {
            mut phases,
            mut ids,
            mut slots,
            rows_ingested,
            mut nulls_dropped,
            mut empties_dropped,
            mut sampled_out,
            mut limited_out,
        } = state;

        let apply_mask = |part: &mut Partition, ids: &mut Option<Vec<u32>>, mask: &[bool]| {
            *part = part.filter_by_mask(mask);
            if let Some(ids) = ids {
                retain_by_mask(ids, mask);
            }
        };

        for (off, op) in self.ops[start..].iter().enumerate() {
            let mut sp = obs::span(op_span_name(op), "op");
            let rows_before = part.num_rows();
            match op {
                PartitionOp::NullFilter { idxs } => {
                    let t = Instant::now();
                    let (mask, dropped) = crate::frame::null_mask(&part, idxs);
                    if dropped > 0 {
                        apply_mask(&mut part, &mut ids, &mask);
                    }
                    nulls_dropped += dropped;
                    phases.pre += t.elapsed();
                }
                PartitionOp::HashKeys { slot, idxs } => {
                    let t = Instant::now();
                    debug_assert_eq!(*slot, slots.len(), "HashKeys slots out of order");
                    let keys: Vec<u128> =
                        (0..part.num_rows()).map(|i| hash_row_wide(&part, idxs, i)).collect();
                    slots.push(KeySlot {
                        keys,
                        ids: ids.as_ref().expect("dedup plans track ids").clone(),
                    });
                    phases.pre += t.elapsed();
                }
                PartitionOp::SampleFilter { fraction, seed } => {
                    let t = Instant::now();
                    let mut dropped = 0usize;
                    let mask: Vec<bool> = (0..part.num_rows())
                        .map(|i| {
                            let keep = sample_keeps(*seed, shard, i, *fraction);
                            if !keep {
                                dropped += 1;
                            }
                            keep
                        })
                        .collect();
                    if dropped > 0 {
                        apply_mask(&mut part, &mut ids, &mask);
                    }
                    sampled_out += dropped;
                    phases.pre += t.elapsed();
                }
                PartitionOp::LimitCap { n } => {
                    let t = Instant::now();
                    let rows = part.num_rows();
                    if rows > *n {
                        limited_out += rows - n;
                        part.truncate_rows(*n);
                        if let Some(ids) = &mut ids {
                            ids.truncate(*n);
                        }
                    }
                    phases.pre += t.elapsed();
                }
                PartitionOp::Stage { stage, in_idx, out_idx } => {
                    let t = Instant::now();
                    if in_idx == out_idx {
                        let owned = part.take_column(*in_idx);
                        part.replace_column(*out_idx, stage.transform_column_owned(owned));
                    } else {
                        let col = stage.transform_column(part.column(*in_idx));
                        if *out_idx < part.num_columns() {
                            part.replace_column(*out_idx, col);
                        } else {
                            let mut cols = part.into_columns();
                            cols.push(col);
                            part = Partition::new(cols);
                        }
                    }
                    phases.clean += t.elapsed();
                }
                PartitionOp::EmptyFilter { idxs } => {
                    let t = Instant::now();
                    for &ci in idxs {
                        part.column_mut(ci).nullify_empty_strs();
                    }
                    let (mask, dropped) = crate::frame::null_mask(&part, idxs);
                    if dropped > 0 {
                        apply_mask(&mut part, &mut ids, &mask);
                    }
                    empties_dropped += dropped;
                    phases.post += t.elapsed();
                }
            }
            if sp.active() {
                sp.arg("op", (start + off) as u64);
                sp.arg("shard", shard as u64);
                sp.arg("rows_in", rows_before as u64);
                sp.arg("rows_out", part.num_rows() as u64);
            }
        }
        PartResult {
            part,
            slots,
            final_ids: ids,
            rows_ingested,
            nulls_dropped,
            empties_dropped,
            sampled_out,
            limited_out,
            phases,
        }
    }

    /// One rendered line per op of the per-partition program, shared by
    /// the single-pass, streaming and two-pass EXPLAIN renderings.
    fn op_lines(&self) -> Vec<String> {
        op_lines_of(&self.ops, &self.output_schema)
    }

    fn has_dedup(&self) -> bool {
        self.n_distinct > 0
    }

    /// The driver line of an EXPLAIN rendering: dedup merge, limit
    /// budget and collect, in the order they apply.
    fn driver_line(&self, streaming: bool) -> String {
        let mut steps: Vec<String> = Vec::new();
        if self.has_dedup() {
            steps.push(if streaming {
                "streaming ordered dedup merge (reorder buffer)".into()
            } else {
                "ordered dedup merge (HashSet)".into()
            });
        }
        if let Some(n) = self.limit_n() {
            steps.push(format!("limit({n})"));
        }
        steps.push(if streaming && !self.has_dedup() {
            "streaming ordered collect(LocalFrame)".into()
        } else {
            "collect(LocalFrame)".into()
        });
        format!("Driver: {}", steps.join(" -> "))
    }

    /// Render the physical program (EXPLAIN's third section).
    pub fn render(&self, workers: usize) -> String {
        use std::fmt::Write;
        if let Some(tp) = &self.two_pass {
            let sched = format!("{} workers", Executor::new(workers).workers());
            return self.render_two_pass(tp, &sched, None);
        }
        let mut s = String::new();
        let _ = writeln!(
            s,
            "SinglePass [{} file-partitions, {} workers]",
            self.files.len(),
            Executor::new(workers).workers()
        );
        let _ = writeln!(s, "  parse+project [{}]", self.fields.join(", "));
        for line in self.op_lines() {
            let _ = writeln!(s, "  {line}");
        }
        let _ = writeln!(s, "{}", self.driver_line(false));
        s
    }

    /// Render the streaming topology (EXPLAIN's third section when the
    /// streaming executor is selected): reader count, queue bound and
    /// worker count around the same per-partition op program. When the
    /// executor would delegate to the single pass (fewer shards than
    /// cleaning workers — see [`StreamExecutor`]), that is rendered
    /// instead, so EXPLAIN always shows the schedule that actually runs.
    pub fn render_stream(&self, opts: &StreamOptions) -> String {
        use std::fmt::Write;
        let (readers, workers, queue_cap) = opts.resolve(self.files.len());
        if let Some(tp) = &self.two_pass {
            return self.render_two_pass(
                tp,
                &format!("streaming, {readers} readers + {workers} workers, queue {queue_cap}"),
                Some(opts),
            );
        }
        if !self.files.is_empty() && self.files.len() < workers {
            let mut s = String::new();
            let _ = writeln!(
                s,
                "StreamPipeline fallback ({} file-partitions < {workers} workers) -> single pass:",
                self.files.len()
            );
            s.push_str(&self.render(readers + workers));
            return s;
        }
        let mut s = String::new();
        let _ = writeln!(s, "StreamPipeline [{} file-partitions]", self.files.len());
        let adaptive = if opts.readers == 0 { " (adaptive split)" } else { "" };
        let _ = writeln!(s, "  readers: {readers} x read-bytes{adaptive}");
        let _ = writeln!(s, "  queue:   bounded({queue_cap} raw shard buffers, backpressure)");
        let _ = writeln!(
            s,
            "  workers: {workers} x parse+project [{}] + op-program",
            self.fields.join(", ")
        );
        for line in self.op_lines() {
            let _ = writeln!(s, "    {line}");
        }
        let _ = writeln!(s, "{}", self.driver_line(true));
        s
    }

    /// Render the multi-process topology (EXPLAIN's third section when
    /// `--processes` is selected): the worker-process count around the
    /// same per-partition op program, plus the spawn/fold driver steps.
    /// When the executor would delegate to the in-process single pass
    /// (fewer than two resolved worker processes — see
    /// [`super::process::ProcessExecutor`]), that is rendered instead,
    /// so EXPLAIN always shows the schedule that actually runs.
    pub fn render_process(&self, opts: &super::process::ProcessOptions) -> String {
        use std::fmt::Write;
        let procs = opts.resolve(self.files.len());
        if let Some(tp) = &self.two_pass {
            // Same predicate the executor uses, so EXPLAIN describes
            // the fold that actually runs.
            let mode = if partial_fit_available(tp, &self.prefix_plan(tp)) {
                "accumulator partials"
            } else {
                "admitted partitions"
            };
            return self.render_two_pass(
                tp,
                &format!("{procs} worker processes, pass-1 fold: {mode}"),
                None,
            );
        }
        if procs <= 1 {
            let mut s = String::new();
            let _ = writeln!(
                s,
                "ProcessPool fallback ({} file-partitions, {procs} resolved worker \
                 processes) -> single pass:",
                self.files.len()
            );
            s.push_str(&self.render(0));
            return s;
        }
        let mut s = String::new();
        let _ = writeln!(
            s,
            "ProcessPool [{} file-partitions, {procs} worker processes]",
            self.files.len()
        );
        let _ = writeln!(
            s,
            "  spawn:  {procs} x self-exec `plan-worker` (P3PJ job: op program + shard \
             assignment on stdin)"
        );
        let _ = writeln!(s, "  worker: parse+project [{}] + op-program", self.fields.join(", "));
        for line in self.op_lines() {
            let _ = writeln!(s, "    {line}");
        }
        let base = self.driver_line(false);
        let _ = writeln!(
            s,
            "Driver: fold P3PW result frames (shard order) -> {}",
            base.trim_start_matches("Driver: ")
        );
        s
    }

    /// Render the remote topology (EXPLAIN's third section when
    /// `--remote` is selected): the endpoint list and shard-shipping
    /// policy around the same per-partition op program, plus the
    /// streamed-chunk driver fold.
    pub fn render_remote(&self, opts: &super::remote::RemoteOptions) -> String {
        use std::fmt::Write;
        let n_eps = opts.endpoints.len();
        if let Some(tp) = &self.two_pass {
            let mode = if partial_fit_available(tp, &self.prefix_plan(tp)) {
                "accumulator partials"
            } else {
                "admitted partitions"
            };
            return self.render_two_pass(
                tp,
                &format!("{n_eps} remote endpoints, pass-1 fold: {mode}"),
                None,
            );
        }
        let mut s = String::new();
        let _ = writeln!(
            s,
            "RemotePool [{} file-partitions, {n_eps} remote endpoints]",
            self.files.len()
        );
        let _ = writeln!(s, "  connect: {}", opts.endpoints.join(", "));
        let _ = writeln!(
            s,
            "  ship:    P3PJ job over TCP (shards <= {} KiB inline, else fetch-by-digest)",
            opts.inline_max_bytes / 1024
        );
        let _ = writeln!(
            s,
            "  worker:  parse+project [{}] + op-program (scoped threads across cores)",
            self.fields.join(", ")
        );
        for line in self.op_lines() {
            let _ = writeln!(s, "    {line}");
        }
        let base = self.driver_line(false);
        let _ = writeln!(
            s,
            "Driver: fold streamed P3PW chunk frames (shard order) -> {}",
            base.trim_start_matches("Driver: ")
        );
        s
    }

    /// Render the two-pass topology: the fit pass over the prefix
    /// program, then the full program with the fitted model spliced in.
    fn render_two_pass(&self, tp: &TwoPass, sched: &str, stream: Option<&StreamOptions>) -> String {
        use std::fmt::Write;
        let prefix = self.prefix_plan(tp);
        let mut s = String::new();
        let _ = writeln!(s, "TwoPass [{} file-partitions, {sched}]", self.files.len());
        let _ = writeln!(s, "  Pass 1 — fit {}:", tp.est.describe());
        let _ = writeln!(s, "    parse+project [{}]", self.fields.join(", "));
        for line in prefix.op_lines() {
            let _ = writeln!(s, "    {line}");
        }
        let fit_driver = if prefix.has_dedup() {
            "ordered dedup merge"
        } else {
            "ordered fold"
        };
        let limit_note = if tp.limit_in_prefix {
            self.limit.map(|n| format!(" -> limit({n})")).unwrap_or_default()
        } else {
            String::new()
        };
        let _ = writeln!(
            s,
            "    Driver: {fit_driver}{limit_note} -> {}.accumulate -> fit",
            tp.est.name()
        );
        let _ = writeln!(s, "  Pass 2 — apply fitted model, fused with remaining ops:");
        let _ = writeln!(s, "    parse+project [{}]", self.fields.join(", "));
        let mode = if tp.in_idx == tp.out_idx { "in-place sweep" } else { "append" };
        for (i, line) in op_lines_of(&self.ops, &self.output_schema).iter().enumerate() {
            if i == tp.prefix_len {
                let _ = writeln!(s, "    fitted {} ({mode})", tp.est.describe());
            }
            let _ = writeln!(s, "    {line}");
        }
        if tp.prefix_len == self.ops.len() {
            let _ = writeln!(s, "    fitted {} ({mode})", tp.est.describe());
        }
        let _ = writeln!(s, "  {}", self.driver_line(stream.is_some()));
        s
    }

    /// Render the per-partition program annotated with the actuals an
    /// executed run recorded (`explain --analyze`): per op, total rows
    /// in → out, summed in-op time and the number of shard-level
    /// executions, folded from category-`"op"` spans by
    /// [`crate::obs::aggregate_ops`]. Stats are keyed by op index in
    /// the *executed* program; for estimator plans that program splices
    /// the fitted stage in at the estimator's position, so indices past
    /// it shift by one and any extra index renders as the spliced
    /// stage.
    pub fn render_analyze(
        &self,
        stats: &std::collections::BTreeMap<u64, obs::OpStats>,
    ) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(s, "AnalyzedProgram [{} file-partitions]", self.files.len());
        let _ = writeln!(s, "  parse+project [{}]", self.fields.join(", "));
        let fmt_stats = |st: &obs::OpStats| {
            format!(
                "[actual: {} -> {} rows, {:.3} ms, {} shard-runs]",
                st.rows_in,
                st.rows_out,
                st.time_ns as f64 / 1e6,
                st.shards
            )
        };
        for (i, line) in self.op_lines().iter().enumerate() {
            match stats.get(&(i as u64)) {
                Some(st) => {
                    let _ = writeln!(s, "  {line}  {}", fmt_stats(st));
                }
                None => {
                    let _ = writeln!(s, "  {line}  [actual: not executed]");
                }
            }
        }
        for (idx, st) in stats.iter().filter(|(i, _)| **i >= self.ops.len() as u64) {
            let _ = writeln!(s, "  op#{idx} (spliced fitted stage)  {}", fmt_stats(st));
        }
        s
    }
}

/// The `&'static str` span name for one op kind — static so opening a
/// span on the tracing-off path never allocates (the op *index* in the
/// span args is what EXPLAIN ANALYZE keys on).
fn op_span_name(op: &PartitionOp) -> &'static str {
    match op {
        PartitionOp::NullFilter { .. } => "null-filter",
        PartitionOp::HashKeys { .. } => "hash-keys",
        PartitionOp::SampleFilter { .. } => "sample",
        PartitionOp::LimitCap { .. } => "limit-cap",
        PartitionOp::Stage { .. } => "stage",
        PartitionOp::EmptyFilter { .. } => "empty-filter",
    }
}

/// Render one op per line against `schema` (column-name lookup).
fn op_lines_of(ops: &[PartitionOp], schema: &Schema) -> Vec<String> {
    let name = |i: usize| schema.fields()[i].name.as_str();
    let list = |idxs: &[usize]| idxs.iter().map(|&i| name(i)).collect::<Vec<_>>().join(", ");
    let mut lines = Vec::with_capacity(ops.len());
    for op in ops {
        match op {
            PartitionOp::NullFilter { idxs } => {
                lines.push(format!("null-filter [{}]", list(idxs)));
            }
            PartitionOp::HashKeys { slot, idxs } => {
                lines.push(format!("hash-keys #{slot} [{}] (128-bit)", list(idxs)));
            }
            PartitionOp::SampleFilter { fraction, seed } => {
                lines.push(format!("sample [fraction={fraction}, seed={seed}] (positional)"));
            }
            PartitionOp::LimitCap { n } => {
                lines.push(format!("limit-cap [{n}] (per-partition prefix)"));
            }
            PartitionOp::Stage { stage, in_idx, out_idx } => {
                let mode = if in_idx == out_idx { "in-place sweep" } else { "append" };
                lines.push(format!("{} ({mode})", stage.describe()));
            }
            PartitionOp::EmptyFilter { idxs } => {
                lines.push(format!("empty-filter [{}]", list(idxs)));
            }
        }
    }
    lines
}

/// Whether the multi-process fit pass can use the partial-aggregate
/// fold: the driver-side admission must be the identity (no pending
/// dedup or limit in the prefix) and the estimator must both cross the
/// wire and support accumulator partials. One predicate shared by
/// `run_fit_process` and `render_process`, so `--processes` never picks
/// a fold its EXPLAIN did not describe — and never errors on a plan the
/// partition-shipping fallback could run.
pub(super) fn partial_fit_available(tp: &TwoPass, prefix: &PhysicalPlan) -> bool {
    prefix.n_distinct() == 0
        && prefix.limit_n().is_none()
        && tp.est.wire_spec().is_some()
        && tp.est.accumulator().is_some_and(|acc| acc.partial().is_some())
}

/// Pass-1 sink: admit partitions in stream order (dedup + limit), feed
/// the estimator's accumulator, discard the rows.
pub(super) struct FitSink {
    admitter: Admitter,
    acc: Box<dyn crate::pipeline::FitAccumulator>,
    in_idx: usize,
}

impl FitSink {
    pub(super) fn new(tp: &TwoPass, prefix: &PhysicalPlan) -> Result<FitSink> {
        let acc = tp.est.accumulator().ok_or_else(|| {
            anyhow::anyhow!(
                "estimator {} lost its accumulator between lower and execute",
                tp.est.name()
            )
        })?;
        Ok(FitSink {
            admitter: Admitter::new(prefix.n_distinct, prefix.limit_n()),
            acc,
            in_idx: tp.in_idx,
        })
    }

    pub(super) fn push(&mut self, r: PartResult) -> Result<()> {
        let (part, _, _) =
            self.admitter.admit(r.part, r.rows_ingested, &r.slots, r.final_ids.as_deref());
        if part.num_rows() > 0 {
            self.acc.accumulate(part.column(self.in_idx))?;
        }
        Ok(())
    }

    pub(super) fn finish(self) -> Result<Arc<dyn Transformer>> {
        self.acc.finish()
    }
}

fn retain_by_mask<T>(items: &mut Vec<T>, mask: &[bool]) {
    debug_assert_eq!(items.len(), mask.len());
    let mut i = 0;
    items.retain(|_| {
        let keep = mask[i];
        i += 1;
        keep
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate_corpus, CorpusSpec};
    use crate::ingest::list_shards;
    use crate::pipeline::features::{HashingTF, Idf};
    use crate::pipeline::presets::case_study_plan;
    use crate::pipeline::stages::Tokenizer;

    fn corpus(name: &str) -> (PathBuf, Vec<PathBuf>) {
        let dir = std::env::temp_dir().join(format!("p3sapp-plan-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        generate_corpus(&CorpusSpec::tiny(23), &dir).unwrap();
        let files = list_shards(&dir).unwrap();
        (dir, files)
    }

    #[test]
    fn lower_rejects_malformed_plans() {
        // No Ingest.
        let bare = LogicalPlan { ops: vec![LogicalOp::Collect] };
        assert!(lower(&bare).is_err());
        // No Collect.
        assert!(lower(&LogicalPlan::scan(vec![], &["c"])).is_err());
        // Unknown column.
        let bad = LogicalPlan::scan(vec![], &["c"]).drop_nulls(&["nope"]).collect();
        assert!(lower(&bad).is_err());
        // Sample after Distinct.
        let sad = LogicalPlan::scan(vec![], &["c"]).distinct(&["c"]).sample(0.5, 1).collect();
        assert!(lower(&sad).is_err());
        // Sample fraction out of range.
        let oor = LogicalPlan::scan(vec![], &["c"]).sample(1.5, 1).collect();
        assert!(lower(&oor).is_err());
        // A filter after Limit.
        let laf = LogicalPlan::scan(vec![], &["c"]).limit(5).drop_nulls(&["c"]).collect();
        assert!(lower(&laf).is_err());
        // Two Limits.
        let ll = LogicalPlan::scan(vec![], &["c"]).limit(5).limit(3).collect();
        assert!(lower(&ll).is_err());
        // Two estimators.
        let ee = LogicalPlan::scan(vec![], &["c"])
            .transform(Tokenizer::new("c", "w"))
            .transform(HashingTF::new("w", "tf", 8))
            .fit(Idf::new("tf", "v1"))
            .fit(Idf::new("v1", "v2"))
            .collect();
        assert!(lower(&ee).is_err());
    }

    #[test]
    fn lower_accepts_multiple_distincts() {
        let plan = LogicalPlan::scan(vec![], &["a", "b"])
            .distinct(&["a"])
            .distinct(&["b"])
            .collect();
        let phys = lower(&plan).unwrap();
        assert_eq!(phys.n_distinct(), 2);
        let r = phys.render(2);
        assert!(r.contains("hash-keys #0 [a]"), "{r}");
        assert!(r.contains("hash-keys #1 [b]"), "{r}");
    }

    #[test]
    fn lower_tracks_schema_through_transforms() {
        let plan = LogicalPlan::scan(vec![], &["abstract"])
            .transform(Tokenizer::new("abstract", "words"))
            .collect();
        let phys = lower(&plan).unwrap();
        assert_eq!(phys.output_schema().field_names(), vec!["abstract", "words"]);
    }

    #[test]
    fn lower_tracks_schema_through_estimators() {
        let plan = LogicalPlan::scan(vec![], &["abstract"])
            .transform(Tokenizer::new("abstract", "words"))
            .transform(HashingTF::new("words", "tf", 16))
            .fit(Idf::new("tf", "tfidf"))
            .collect();
        let phys = lower(&plan).unwrap();
        assert!(phys.is_two_pass());
        assert_eq!(
            phys.output_schema().field_names(),
            vec!["abstract", "words", "tf", "tfidf"]
        );
        assert_eq!(
            phys.output_schema().dtype_of("tfidf"),
            Some(crate::frame::DType::Vector)
        );
    }

    #[test]
    fn sample_keeps_is_deterministic_and_roughly_proportional() {
        let kept: Vec<bool> = (0..1000).map(|i| sample_keeps(7, 3, i, 0.25)).collect();
        let again: Vec<bool> = (0..1000).map(|i| sample_keeps(7, 3, i, 0.25)).collect();
        assert_eq!(kept, again, "positional sampling must be deterministic");
        let n = kept.iter().filter(|&&k| k).count();
        assert!((150..350).contains(&n), "kept {n}/1000 at fraction 0.25");
        // Extremes are exact.
        assert!((0..100).all(|i| sample_keeps(1, 0, i, 1.0)));
        assert!((0..100).all(|i| !sample_keeps(1, 0, i, 0.0)));
        // Seed and shard matter.
        let other: Vec<bool> = (0..1000).map(|i| sample_keeps(8, 3, i, 0.25)).collect();
        assert_ne!(kept, other);
    }

    #[test]
    fn execute_empty_file_list() {
        let plan = case_study_plan(&[], "title", "abstract").optimize();
        let out = plan.execute(2).unwrap();
        assert_eq!(out.rows_ingested, 0);
        assert_eq!(out.rows_out, 0);
        assert_eq!(out.frame.num_rows(), 0);
    }

    #[test]
    fn execute_records_all_four_stages_and_counts() {
        let (dir, files) = corpus("stages");
        let out = case_study_plan(&files, "title", "abstract")
            .optimize()
            .execute(2)
            .unwrap();
        assert!(out.rows_ingested > 0);
        assert!(out.rows_out > 0);
        assert_eq!(
            out.rows_out,
            out.rows_ingested - out.nulls_dropped - out.dups_dropped - out.empties_dropped
        );
        assert_eq!(out.sampled_out, 0);
        assert_eq!(out.limited_out, 0);
        for key in [INGESTION, PRE_CLEANING, CLEANING, POST_CLEANING] {
            assert!(out.times.secs(key) >= 0.0);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unoptimized_and_optimized_plans_agree() {
        let (dir, files) = corpus("optagree");
        let plan = case_study_plan(&files, "title", "abstract");
        let staged = plan.execute(2).unwrap();
        let fused = plan.clone().optimize().execute(2).unwrap();
        assert_eq!(staged.frame, fused.frame);
        assert_eq!(staged.dups_dropped, fused.dups_dropped);
        assert_eq!(
            staged.nulls_dropped + staged.empties_dropped,
            fused.nulls_dropped + fused.empties_dropped
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn worker_count_does_not_change_plan_output() {
        let (dir, files) = corpus("workers");
        let plan = case_study_plan(&files, "title", "abstract").optimize();
        let r1 = plan.execute(1).unwrap();
        let r4 = plan.execute(4).unwrap();
        // More workers than shards exercises the re-chunking path.
        let r16 = plan.execute(files.len() * 3).unwrap();
        assert_eq!(r1.frame, r4.frame);
        assert_eq!(r1.frame, r16.frame);
        assert_eq!(r1.rows_ingested, r16.rows_ingested);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sampled_plan_is_worker_count_invariant() {
        let (dir, files) = corpus("sampleworkers");
        let plan = LogicalPlan::scan(files.clone(), &["title", "abstract"])
            .sample(0.5, 11)
            .drop_nulls(&["title", "abstract"])
            .collect();
        let r1 = plan.execute(1).unwrap();
        let r4 = plan.execute(4).unwrap();
        let r16 = plan.execute(files.len() * 3).unwrap();
        assert!(r1.sampled_out > 0, "a 50% sample must drop something");
        assert_eq!(r1.frame, r4.frame);
        assert_eq!(r1.frame, r16.frame);
        assert_eq!(r1.sampled_out, r16.sampled_out);
        assert_eq!(
            r1.rows_out,
            r1.rows_ingested - r1.nulls_dropped - r1.sampled_out
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn limited_plan_truncates_exactly_and_counts() {
        let (dir, files) = corpus("limit");
        let full = case_study_plan(&files, "title", "abstract").optimize().execute(2).unwrap();
        let n = full.rows_out / 2;
        let plan = crate::pipeline::presets::case_study_plan(&files, "title", "abstract");
        // Insert the limit before Collect (the CLI's --limit shape).
        let mut ops = plan.ops().to_vec();
        let collect = ops.pop().unwrap();
        ops.push(LogicalOp::Limit { n });
        ops.push(collect);
        let limited = LogicalPlan { ops }.optimize();
        for workers in [1, 2, 8] {
            let out = limited.execute(workers).unwrap();
            assert_eq!(out.rows_out, n, "workers {workers}");
            assert_eq!(out.limited_out, full.rows_out - n, "workers {workers}");
            // The limited frame is the full frame's prefix.
            for ci in 0..out.frame.num_columns() {
                for ri in 0..n {
                    assert_eq!(
                        out.frame.column(ci).get_str(ri),
                        full.frame.column(ci).get_str(ri)
                    );
                }
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rechunk_triggers_on_scarce_or_skewed_files() {
        let dir = std::env::temp_dir()
            .join(format!("p3sapp-plan-rechunk-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut files = Vec::new();
        for (name, bytes) in [("a", 10usize), ("b", 10), ("c", 10), ("d", 1000)] {
            let path = dir.join(format!("{name}.json"));
            std::fs::write(&path, "x".repeat(bytes)).unwrap();
            files.push(path);
        }
        let phys = case_study_plan(&files, "title", "abstract").lower().unwrap();
        assert!(phys.needs_rechunk(8), "fewer files than workers");
        assert!(phys.needs_rechunk(4), "one shard holds >25% of the bytes");
        assert!(!phys.needs_rechunk(1), "single worker has nothing to balance");
        // A sampled plan must never re-chunk (positional sampling needs
        // shard identity).
        let sampled = LogicalPlan::scan(files.clone(), &["title", "abstract"])
            .sample(0.5, 1)
            .collect()
            .lower()
            .unwrap();
        assert!(!sampled.needs_rechunk(8));
        // Balanced files at matching worker count pass through.
        let balanced: Vec<PathBuf> = files[..3].to_vec();
        let phys = case_study_plan(&balanced, "title", "abstract").lower().unwrap();
        assert!(!phys.needs_rechunk(3));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn render_mentions_single_pass_and_dedup() {
        let plan = case_study_plan(&[], "title", "abstract").optimize();
        let phys = plan.lower().unwrap();
        let r = phys.render(2);
        assert!(r.contains("SinglePass"), "{r}");
        assert!(r.contains("hash-keys #0 [title, abstract]"), "{r}");
        assert!(r.contains("FusedStringStage"), "{r}");
        assert!(r.contains("dedup merge"), "{r}");
    }

    #[test]
    fn render_shows_sample_and_limit() {
        let plan = LogicalPlan::scan(vec![], &["t"])
            .sample(0.25, 42)
            .limit(10)
            .collect();
        let phys = plan.lower().unwrap();
        let r = phys.render(2);
        assert!(r.contains("sample [fraction=0.25, seed=42] (positional)"), "{r}");
        assert!(r.contains("limit-cap [10] (per-partition prefix)"), "{r}");
        assert!(r.contains("limit(10)"), "{r}");
        // With a dedup in the plan the per-partition cap must vanish
        // (the merge could need rows past it) but the driver limit stays.
        let plan = LogicalPlan::scan(vec![], &["t"]).distinct(&["t"]).limit(10).collect();
        let r = plan.lower().unwrap().render(2);
        assert!(!r.contains("limit-cap"), "{r}");
        assert!(r.contains("limit(10)"), "{r}");
    }

    #[test]
    fn render_two_pass_topology() {
        let plan = LogicalPlan::scan(vec![], &["abstract"])
            .drop_nulls(&["abstract"])
            .transform(Tokenizer::new("abstract", "words"))
            .transform(HashingTF::new("words", "tf", 64))
            .fit(Idf::new("tf", "tfidf").with_min_doc_freq(2))
            .collect();
        let phys = plan.lower().unwrap();
        let r = phys.render(4);
        assert!(r.contains("TwoPass"), "{r}");
        assert!(r.contains("Pass 1 — fit IDF(tf -> tfidf, min_df=2)"), "{r}");
        assert!(r.contains("IDF.accumulate -> fit"), "{r}");
        assert!(r.contains("Pass 2 — apply fitted model"), "{r}");
        assert!(r.contains("fitted IDF(tf -> tfidf, min_df=2) (append)"), "{r}");
        let rs = phys.render_stream(&StreamOptions { readers: 2, workers: 3, queue_cap: 8 });
        assert!(rs.contains("TwoPass"), "{rs}");
        // readers clamped to 1: zero files.
        assert!(rs.contains("streaming, 1 readers + 3 workers"), "{rs}");
    }

    #[test]
    fn two_pass_plan_executes_and_matches_staged_fit() {
        use crate::frame::DType;
        let (dir, files) = corpus("twopass");
        let plan = LogicalPlan::scan(files.clone(), &["title", "abstract"])
            .drop_nulls(&["title", "abstract"])
            .distinct(&["title", "abstract"])
            .transform(Tokenizer::new("abstract", "tokens"))
            .transform(HashingTF::new("tokens", "tf", 64))
            .fit(Idf::new("tf", "tfidf"))
            .collect();
        let out = plan.execute(2).unwrap();
        assert!(out.rows_out > 0);
        assert_eq!(out.frame.schema().dtype_of("tfidf"), Some(DType::Vector));
        // Workers must not change the fit or the bytes.
        let seq = plan.execute(1).unwrap();
        assert_eq!(out.frame, seq.frame);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn tracing_is_byte_identical_and_feeds_explain_analyze() {
        let (dir, files) = corpus("traced");
        let plan = case_study_plan(&files, "title", "abstract").optimize();
        let phys = plan.lower().unwrap();
        let plain = phys.execute(2).unwrap();
        let _l = obs::trace::test_lock();
        let _sink = obs::install_new();
        let traced = phys.execute(2).unwrap();
        let spans = obs::uninstall().unwrap().drain();
        assert_eq!(plain.frame, traced.frame, "tracing must not change output");
        assert_eq!(plain.rows_ingested, traced.rows_ingested);
        // Every lowered op ran and reported real row flow.
        let stats = obs::aggregate_ops(&spans);
        assert_eq!(stats.len(), phys.ops.len(), "one stats entry per op");
        assert_eq!(stats[&0].rows_in as usize, traced.rows_ingested);
        for st in stats.values() {
            assert!(st.rows_out <= st.rows_in);
            assert!(st.shards as usize >= 1);
        }
        let rendered = phys.render_analyze(&stats);
        assert!(rendered.contains("[actual: "), "{rendered}");
        assert!(!rendered.contains("not executed"), "{rendered}");
        // Op spans landed on pool worker-thread lanes (both the
        // per-file and the re-chunk scheduling run on pool threads).
        assert!(spans
            .iter()
            .any(|s| s.cat == "op" && s.lane.tid >= obs::trace::WORKER_TID_BASE));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn admitter_registers_first_occurrences_that_filters_removed() {
        use crate::frame::Column;
        // Shard 1 row: key K, but the row itself was dropped by a later
        // filter. Shard 2 row: same key K, survives its filters. The
        // staged path would have dropped shard 2's row (dup of a row
        // that existed at the distinct point), so the admitter must too.
        let mut adm = Admitter::new(1, None);
        let empty = Partition::new(vec![Column::from_strs(vec![])]);
        let (p, dups, _) = adm.admit(
            empty,
            1,
            &[KeySlot { keys: vec![42], ids: vec![0] }],
            Some(&[]),
        );
        assert_eq!(p.num_rows(), 0);
        assert_eq!(dups, 0);
        let row = Partition::new(vec![Column::from_strs(vec![Some("x".into())])]);
        let (p, dups, _) = adm.admit(
            row,
            1,
            &[KeySlot { keys: vec![42], ids: vec![0] }],
            Some(&[0]),
        );
        assert_eq!(p.num_rows(), 0, "duplicate of a filtered first occurrence must drop");
        assert_eq!(dups, 1);
    }
}
