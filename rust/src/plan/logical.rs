//! The logical plan: a declarative description of a preprocessing job
//! (what to ingest, which rows to keep, which rewrites to apply) with no
//! commitment to *how* it runs. Built lazily with a fluent builder,
//! optimized by [`super::optimize`], lowered and executed by
//! [`super::physical`].

use super::physical::{self, PhysicalPlan, PlanOutput};
use super::stream::StreamOptions;
use crate::pipeline::{Estimator, Transformer};
use crate::Result;
use std::path::PathBuf;
use std::sync::Arc;

/// One node of the logical plan, in pipeline order.
#[derive(Clone)]
pub enum LogicalOp {
    /// Parallel scan of JSON shard files, parsing only `fields`
    /// (projection-pushdown ingestion, Algorithm 1 steps 2–8).
    Ingest { files: Vec<PathBuf>, fields: Vec<String> },
    /// Narrow the frame to `cols`. The optimizer folds this into
    /// [`LogicalOp::Ingest`] so dropped fields are never even parsed.
    Project { cols: Vec<String> },
    /// Apply one transformer stage (steps 11–14).
    Transform { stage: Arc<dyn Transformer> },
    /// Fit an estimator stage on the stream *at this point* in the plan
    /// (Spark `Pipeline.fit` semantics), then apply the fitted model.
    /// Lowered by [`super::lower`] into a two-pass physical strategy:
    /// pass 1 streams shards through the preceding ops to accumulate the
    /// fit state, pass 2 re-runs the program with the fitted model
    /// spliced in as an ordinary stage.
    Fit { est: Arc<dyn Estimator> },
    /// Deterministic Bernoulli row sample: keep each row of the stream
    /// at this point with probability `fraction`, decided by a
    /// position-seeded hash (shard index × row index × `seed`) so every
    /// executor — sequential, fused, streaming — keeps the same rows.
    Sample { fraction: f64, seed: u64 },
    /// Keep the first `n` rows of the stream at this point (in shard
    /// order). Enforced exactly by the driver-side merge.
    Limit { n: usize },
    /// Drop rows with a null in any of `cols` (step 9).
    DropNulls { cols: Vec<String> },
    /// Drop duplicate rows keyed on `cols`, first occurrence wins
    /// (step 10). Keys are hashed from the values *at this point* in the
    /// plan — before the cleaning stages in the paper's ordering.
    Distinct { cols: Vec<String> },
    /// Null out empty strings in `cols`, then drop rows null in any of
    /// them — the post-cleaning sweep (steps 15–16).
    DropEmpty { cols: Vec<String> },
    /// Gather every partition into a contiguous [`crate::frame::LocalFrame`]
    /// (the Spark→pandas conversion, step 15).
    Collect,
}

impl LogicalOp {
    /// One-line rendering for EXPLAIN output.
    pub fn label(&self) -> String {
        match self {
            LogicalOp::Ingest { files, fields } => {
                format!("Ingest [{} files] project=[{}]", files.len(), fields.join(", "))
            }
            LogicalOp::Project { cols } => format!("Project [{}]", cols.join(", ")),
            LogicalOp::Transform { stage } => format!("Transform {}", stage.describe()),
            LogicalOp::Fit { est } => format!("Fit {}", est.describe()),
            LogicalOp::Sample { fraction, seed } => {
                format!("Sample [fraction={fraction}, seed={seed}]")
            }
            LogicalOp::Limit { n } => format!("Limit [{n}]"),
            LogicalOp::DropNulls { cols } => format!("DropNulls [{}]", cols.join(", ")),
            LogicalOp::Distinct { cols } => format!("Distinct [{}]", cols.join(", ")),
            LogicalOp::DropEmpty { cols } => format!("DropEmpty [{}]", cols.join(", ")),
            LogicalOp::Collect => "Collect".into(),
        }
    }
}

/// An ordered list of [`LogicalOp`]s — the lazy counterpart of the eager
/// `ingest → transform → drop → collect` driver code it replaces.
///
/// ```
/// use p3sapp::plan::LogicalPlan;
/// use p3sapp::pipeline::stages::ConvertToLower;
///
/// // Describe the job lazily (no files touched), then optimize,
/// // lower and execute. An empty scan runs end to end instantly.
/// let plan = LogicalPlan::scan(vec![], &["title"])
///     .drop_nulls(&["title"])
///     .transform(ConvertToLower::new("title"))
///     .collect()
///     .optimize();
/// let out = plan.execute(2).unwrap();
/// assert_eq!(out.rows_out, 0);
/// ```
#[derive(Clone)]
pub struct LogicalPlan {
    pub(crate) ops: Vec<LogicalOp>,
}

impl LogicalPlan {
    /// Start a plan with a file scan projecting `fields`.
    pub fn scan(files: Vec<PathBuf>, fields: &[&str]) -> Self {
        LogicalPlan {
            ops: vec![LogicalOp::Ingest {
                files,
                fields: fields.iter().map(|s| s.to_string()).collect(),
            }],
        }
    }

    fn push(mut self, op: LogicalOp) -> Self {
        self.ops.push(op);
        self
    }

    /// Keep only `cols` (folded into the scan by the optimizer).
    pub fn project(self, cols: &[&str]) -> Self {
        self.push(LogicalOp::Project { cols: owned(cols) })
    }

    /// Append one transformer stage.
    pub fn transform(self, stage: impl Transformer + 'static) -> Self {
        self.transform_arc(Arc::new(stage))
    }

    /// Append an already-shared transformer stage.
    pub fn transform_arc(self, stage: Arc<dyn Transformer>) -> Self {
        self.push(LogicalOp::Transform { stage })
    }

    /// Append a whole stage list (preset reuse path).
    pub fn transforms(mut self, stages: impl IntoIterator<Item = Arc<dyn Transformer>>) -> Self {
        for stage in stages {
            self.ops.push(LogicalOp::Transform { stage });
        }
        self
    }

    /// Append an estimator stage, fit on the stream at this point and
    /// applied in place (lowers to the two-pass physical strategy).
    pub fn fit(self, est: impl Estimator + 'static) -> Self {
        self.fit_arc(Arc::new(est))
    }

    /// Append an already-shared estimator stage.
    pub fn fit_arc(self, est: Arc<dyn Estimator>) -> Self {
        self.push(LogicalOp::Fit { est })
    }

    /// Deterministic Bernoulli sample of the stream at this point: keep
    /// each row with probability `fraction` (position-hashed with
    /// `seed`, identical across executors). The optimizer hoists the
    /// sample ahead of row-preserving transforms so skipped rows are
    /// never cleaned.
    pub fn sample(self, fraction: f64, seed: u64) -> Self {
        self.push(LogicalOp::Sample { fraction, seed })
    }

    /// Keep the first `n` rows of the stream at this point.
    pub fn limit(self, n: usize) -> Self {
        self.push(LogicalOp::Limit { n })
    }

    /// Drop rows null in any of `cols`.
    pub fn drop_nulls(self, cols: &[&str]) -> Self {
        self.push(LogicalOp::DropNulls { cols: owned(cols) })
    }

    /// Drop duplicate rows keyed on `cols` (first occurrence wins).
    pub fn distinct(self, cols: &[&str]) -> Self {
        self.push(LogicalOp::Distinct { cols: owned(cols) })
    }

    /// Empty-string → null sweep over `cols`, then drop those rows.
    pub fn drop_empty(self, cols: &[&str]) -> Self {
        self.push(LogicalOp::DropEmpty { cols: owned(cols) })
    }

    /// Finish the plan with the collect-to-LocalFrame step.
    pub fn collect(self) -> Self {
        self.push(LogicalOp::Collect)
    }

    pub fn ops(&self) -> &[LogicalOp] {
        &self.ops
    }

    /// Run the optimizer: projection pushdown, null-drop pushdown, and
    /// string-stage fusion (the `plan::optimize` rule set).
    pub fn optimize(self) -> LogicalPlan {
        super::optimize::optimize(self)
    }

    /// Lower to an executable [`PhysicalPlan`] (no data touched yet).
    pub fn lower(&self) -> Result<PhysicalPlan> {
        physical::lower(self)
    }

    /// Lower and execute with `workers` threads (0 = all cores).
    pub fn execute(&self, workers: usize) -> Result<PlanOutput> {
        self.lower()?.execute(workers)
    }

    /// Lower and execute through the streaming pipeline
    /// ([`super::StreamExecutor`]): shard parsing overlaps cleaning.
    /// Byte-identical output to [`LogicalPlan::execute`].
    pub fn execute_stream(&self, opts: &StreamOptions) -> Result<PlanOutput> {
        self.lower()?.execute_stream(opts)
    }

    /// Lower and execute across worker OS processes
    /// ([`super::process::ProcessExecutor`]): the op program and shard
    /// assignments ship over a versioned wire format and the driver
    /// folds the result frames. Byte-identical output to
    /// [`LogicalPlan::execute`].
    pub fn execute_process(&self, opts: &super::process::ProcessOptions) -> Result<PlanOutput> {
        self.lower()?.execute_process(opts)
    }

    /// Lower and execute across remote `plan-worker --listen` endpoints
    /// ([`super::remote::RemoteExecutor`]): the same job frames travel
    /// over TCP, shard bytes ship inline or by content digest, and
    /// workers stream per-shard result chunks back. Byte-identical
    /// output to [`LogicalPlan::execute`].
    pub fn execute_remote(&self, opts: &super::remote::RemoteOptions) -> Result<PlanOutput> {
        self.lower()?.execute_remote(opts)
    }

    /// Render the op list, one op per line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for op in &self.ops {
            out.push_str(&op.label());
            out.push('\n');
        }
        out
    }
}

fn owned(cols: &[&str]) -> Vec<String> {
    cols.iter().map(|s| s.to_string()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::stages::{ConvertToLower, Tokenizer};

    #[test]
    fn builder_orders_ops() {
        let plan = LogicalPlan::scan(vec![], &["title", "abstract"])
            .drop_nulls(&["title"])
            .distinct(&["title", "abstract"])
            .transform(ConvertToLower::new("title"))
            .transform(Tokenizer::new("abstract", "words"))
            .drop_empty(&["title"])
            .collect();
        let labels: Vec<String> = plan.ops().iter().map(|o| o.label()).collect();
        assert_eq!(labels[0], "Ingest [0 files] project=[title, abstract]");
        assert_eq!(labels[1], "DropNulls [title]");
        assert_eq!(labels[2], "Distinct [title, abstract]");
        assert_eq!(labels[3], "Transform ConvertToLower(title)");
        assert_eq!(labels[4], "Transform Tokenizer(abstract -> words)");
        assert_eq!(labels[5], "DropEmpty [title]");
        assert_eq!(labels[6], "Collect");
    }

    #[test]
    fn render_is_one_op_per_line() {
        let plan = LogicalPlan::scan(vec![], &["c"]).collect();
        assert_eq!(plan.render(), "Ingest [0 files] project=[c]\nCollect\n");
    }

    #[test]
    fn sample_limit_and_fit_render_their_state() {
        use crate::pipeline::features::Idf;
        let plan = LogicalPlan::scan(vec![], &["c"])
            .sample(0.25, 7)
            .limit(100)
            .fit(Idf::new("c", "v").with_min_doc_freq(3))
            .collect();
        let labels: Vec<String> = plan.ops().iter().map(|o| o.label()).collect();
        assert_eq!(labels[1], "Sample [fraction=0.25, seed=7]");
        assert_eq!(labels[2], "Limit [100]");
        assert_eq!(labels[3], "Fit IDF(c -> v, min_df=3)");
    }
}
