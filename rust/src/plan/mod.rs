//! Fused execution plans — the Catalyst/Tungsten analog of this crate's
//! Spark-like engine, and the layer [`crate::driver::run_p3sapp`] now
//! executes through.
//!
//! A preprocessing job is described lazily as a [`LogicalPlan`]
//! (Ingest → Project → Sample/Limit → Transform*/Fit → DropNulls →
//! Distinct* → DropEmpty → Collect), rewritten by the
//! [`optimize`](LogicalPlan::optimize) rules
//!
//! 1. projection pushdown into ingestion,
//! 2. null-drop pushdown ahead of cleaning,
//! 3. sample/limit pushdown ahead of row-preserving transforms, and
//! 4. fusion of adjacent same-column string stages into one
//!    [`FusedStringStage`],
//!
//! then lowered to a [`PhysicalPlan`] that runs everything — parse,
//! null masks, positional sampling, pre-hashed dedup keys (any number
//! of `Distinct` ops), fused cleaning sweeps, the empty-string sweep —
//! inside **one** parallel pass per shard file. Only the ordered
//! first-occurrence dedup merge, the global `Limit` budget and the
//! final collect remain on the driver, eliminating the
//! ingest/clean/dedup barriers of the eager path.
//!
//! Plans with an `Estimator` stage (`IDF`) lower to a **two-pass**
//! strategy instead of bailing out to the staged `Pipeline::fit` path:
//! pass 1 streams shards through the pre-estimator program and folds
//! surviving rows into the estimator's accumulator (document
//! frequencies), pass 2 re-runs the program with the fitted model
//! spliced in as an ordinary fused stage. Output is byte-identical to
//! `Pipeline::fit` + `transform` (`rust/tests/plan_equivalence.rs`).
//!
//! Four executors share that lowered program, selected through one
//! [`ExecutorKind`] value:
//!
//! - [`PhysicalPlan::execute`] — the fused single pass: each worker
//!   parses *and* cleans one shard end to end;
//! - [`PhysicalPlan::execute_stream`] — the streaming pipeline
//!   ([`StreamExecutor`]): a bounded reader stage parses shards while a
//!   worker pool cleans shards already parsed, so I/O and compute
//!   overlap *within* the pass too;
//! - [`PhysicalPlan::execute_process`] — the multi-process sharded
//!   executor ([`process::ProcessExecutor`]): the optimized program plus
//!   per-worker shard assignments serialize into a versioned wire format
//!   and run in worker OS processes (self-exec `plan-worker`), the
//!   Spark-executor analogy;
//! - [`PhysicalPlan::execute_remote`] — the multi-machine tier
//!   ([`remote::RemoteExecutor`]): the same versioned `P3PJ`/`P3PW`
//!   frames travel over TCP to `plan-worker --listen` endpoints, shard
//!   bytes ship inline or are fetched back by content digest, and
//!   workers stream bounded per-shard result chunks.
//!
//! All produce byte-identical output; `docs/ARCHITECTURE.md` at the
//! repository root walks the whole layer with a rendered EXPLAIN sample.
//!
//! ```no_run
//! use p3sapp::pipeline::presets::case_study_plan;
//! use p3sapp::plan::StreamOptions;
//!
//! let files = p3sapp::ingest::list_shards(std::path::Path::new("/tmp/corpus")).unwrap();
//! let plan = case_study_plan(&files, "title", "abstract").optimize();
//! println!("{}", p3sapp::plan::explain(&plan, 4).unwrap());
//! let out = plan.execute(4).unwrap();
//! println!("{} clean rows in {:?}", out.rows_out, out.times.total());
//!
//! // Same job, streaming: parse shard i+1 while cleaning shard i.
//! let streamed = plan.execute_stream(&StreamOptions::default()).unwrap();
//! assert_eq!(streamed.rows_out, out.rows_out);
//! ```

mod explain;
mod fused;
mod incremental;
mod logical;
mod optimize;
mod physical;
pub mod process;
pub mod remote;
mod stream;

pub use explain::{
    explain, explain_process, explain_remote, explain_stream, explain_with,
};
pub use fused::FusedStringStage;
pub use incremental::{execute_incremental, incremental_eligible, incremental_shard_keys};
pub use logical::{LogicalOp, LogicalPlan};
pub use physical::{lower, sample_keeps, PhysicalPlan, PlanOutput};
pub use process::{ProcessExecutor, ProcessOptions, WorkerPool};
pub use remote::{RemoteExecutor, RemoteOptions};
pub use stream::{StreamExecutor, StreamOptions};

use std::sync::Arc;

/// Which executor a run uses — the *single* selection surface shared by
/// the driver, the CLI, the serve daemon and the report suite. Exactly
/// one variant can be held, so conflicting executor configurations
/// (`--stream` plus `--processes`, a warm pool plus a remote tier, …)
/// are unrepresentable rather than merely rejected.
#[derive(Debug, Clone, Default)]
pub enum ExecutorKind {
    /// The fused single pass ([`PhysicalPlan::execute`]) — the default.
    #[default]
    Fused,
    /// The streaming pipeline ([`PhysicalPlan::execute_stream`]).
    Stream(StreamOptions),
    /// Worker OS processes spawned per run
    /// ([`PhysicalPlan::execute_process`]).
    Process(ProcessOptions),
    /// A warm, long-lived worker-process pool (the serve daemon's
    /// executor). Jobs ship to these processes instead of spawning
    /// fresh ones.
    Pool(Arc<WorkerPool>),
    /// Remote `plan-worker --listen` endpoints over TCP
    /// ([`PhysicalPlan::execute_remote`]).
    Remote(RemoteOptions),
}

impl ExecutorKind {
    /// Short name for EXPLAIN output and error messages.
    pub fn name(&self) -> &'static str {
        match self {
            ExecutorKind::Fused => "fused",
            ExecutorKind::Stream(_) => "stream",
            ExecutorKind::Process(_) => "process",
            ExecutorKind::Pool(_) => "pool",
            ExecutorKind::Remote(_) => "remote",
        }
    }

    /// The `ProcessOptions` this kind executes through, when it is one
    /// of the two process-backed variants: `Pool` is a `Process` run
    /// whose jobs ship to the warm pool's processes.
    pub fn process_options(&self) -> Option<ProcessOptions> {
        match self {
            ExecutorKind::Process(opts) => Some(opts.clone()),
            ExecutorKind::Pool(pool) => Some(ProcessOptions {
                processes: pool.size(),
                worker_cmd: None,
                pool: Some(Arc::clone(pool)),
            }),
            _ => None,
        }
    }
}
