//! Fused execution plans — the Catalyst/Tungsten analog of this crate's
//! Spark-like engine, and the layer [`crate::driver::run_p3sapp`] now
//! executes through.
//!
//! A preprocessing job is described lazily as a [`LogicalPlan`]
//! (Ingest → Project → Transform* → DropNulls → Distinct → DropEmpty →
//! Collect), rewritten by the [`optimize`](LogicalPlan::optimize) rules
//!
//! 1. projection pushdown into ingestion,
//! 2. null-drop pushdown ahead of cleaning, and
//! 3. fusion of adjacent same-column string stages into one
//!    [`FusedStringStage`],
//!
//! then lowered to a [`PhysicalPlan`] that runs everything — parse,
//! null masks, pre-hashed dedup keys, fused cleaning sweeps, the
//! empty-string sweep — inside **one** parallel pass per shard file.
//! Only the ordered first-occurrence dedup merge and the final collect
//! remain on the driver, eliminating the ingest/clean/dedup barriers of
//! the eager path.
//!
//! ```no_run
//! use p3sapp::pipeline::presets::case_study_plan;
//!
//! let files = p3sapp::ingest::list_shards(std::path::Path::new("/tmp/corpus")).unwrap();
//! let plan = case_study_plan(&files, "title", "abstract").optimize();
//! println!("{}", p3sapp::plan::explain(&plan, 4).unwrap());
//! let out = plan.execute(4).unwrap();
//! println!("{} clean rows in {:?}", out.rows_out, out.times.total());
//! ```

mod explain;
mod fused;
mod logical;
mod optimize;
mod physical;

pub use explain::explain;
pub use fused::FusedStringStage;
pub use logical::{LogicalOp, LogicalPlan};
pub use physical::{lower, PhysicalPlan, PlanOutput};
