//! EXPLAIN rendering: the logical plan as written, the plan after
//! optimization (showing what folded, hoisted and fused), and the
//! physical single-pass program it lowers to. Consumed by the CLI
//! `explain` command, `preprocess --explain`, and the report suite.

use super::logical::LogicalPlan;
use super::process::ProcessOptions;
use super::remote::RemoteOptions;
use super::stream::StreamOptions;
use super::ExecutorKind;
use crate::Result;

/// Render all three EXPLAIN sections for `plan`.
///
/// ```
/// use p3sapp::pipeline::presets::case_study_plan;
///
/// let plan = case_study_plan(&[], "title", "abstract");
/// let text = p3sapp::plan::explain(&plan, 2).unwrap();
/// assert!(text.contains("== Optimized Logical Plan =="));
/// ```
pub fn explain(plan: &LogicalPlan, workers: usize) -> Result<String> {
    let optimized = plan.clone().optimize();
    let physical = optimized.lower()?;
    Ok(format!(
        "== Logical Plan ==\n{}\n== Optimized Logical Plan ==\n{}\n== Physical Plan ==\n{}",
        plan.render(),
        optimized.render(),
        physical.render(workers)
    ))
}

/// Dispatch on the run's [`ExecutorKind`] — the same value the driver
/// executes through, so EXPLAIN always names the executor that would
/// actually run. A `Pool` renders as the multi-process topology its
/// jobs ship to.
pub fn explain_with(plan: &LogicalPlan, workers: usize, executor: &ExecutorKind) -> Result<String> {
    match executor {
        ExecutorKind::Fused => explain(plan, workers),
        ExecutorKind::Stream(opts) => explain_stream(plan, opts),
        ExecutorKind::Process(opts) => explain_process(plan, opts),
        ExecutorKind::Pool(_) => {
            let opts = executor.process_options().expect("Pool maps to ProcessOptions");
            explain_process(plan, &opts)
        }
        ExecutorKind::Remote(opts) => explain_remote(plan, opts),
    }
}

/// Like [`explain`], but the physical section renders the multi-process
/// topology (worker-process count, spawn/fold driver steps) that
/// [`LogicalPlan::execute_process`] would run — including the
/// single-pass fallback when fewer than two workers resolve.
pub fn explain_process(plan: &LogicalPlan, opts: &ProcessOptions) -> Result<String> {
    let optimized = plan.clone().optimize();
    let physical = optimized.lower()?;
    Ok(format!(
        "== Logical Plan ==\n{}\n== Optimized Logical Plan ==\n{}\n== Physical Plan (multi-process) ==\n{}",
        plan.render(),
        optimized.render(),
        physical.render_process(opts)
    ))
}

/// Like [`explain`], but the physical section renders the remote
/// topology (endpoint list, shard shipping strategy, chunked reply
/// fold) that [`LogicalPlan::execute_remote`] would run.
pub fn explain_remote(plan: &LogicalPlan, opts: &RemoteOptions) -> Result<String> {
    let optimized = plan.clone().optimize();
    let physical = optimized.lower()?;
    Ok(format!(
        "== Logical Plan ==\n{}\n== Optimized Logical Plan ==\n{}\n== Physical Plan (remote) ==\n{}",
        plan.render(),
        optimized.render(),
        physical.render_remote(opts)
    ))
}

/// Like [`explain`], but the physical section renders the streaming
/// topology (reader count, queue bound, worker count) that
/// [`LogicalPlan::execute_stream`] would run.
pub fn explain_stream(plan: &LogicalPlan, opts: &StreamOptions) -> Result<String> {
    let optimized = plan.clone().optimize();
    let physical = optimized.lower()?;
    Ok(format!(
        "== Logical Plan ==\n{}\n== Optimized Logical Plan ==\n{}\n== Physical Plan (streaming) ==\n{}",
        plan.render(),
        optimized.render(),
        physical.render_stream(opts)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::presets::case_study_plan;

    #[test]
    fn explain_shows_fusion_happening() {
        let plan = case_study_plan(&[], "title", "abstract");
        let text = explain(&plan, 2).unwrap();
        assert!(text.contains("== Logical Plan =="), "{text}");
        assert!(text.contains("== Optimized Logical Plan =="), "{text}");
        assert!(text.contains("== Physical Plan =="), "{text}");
        // The raw plan lists the individual stages; the optimized one
        // replaces them with fused sweeps.
        assert!(text.contains("Transform ConvertToLower(title)"), "{text}");
        assert!(text.contains("FusedStringStage(abstract <- lower|html|chars|stopwords"), "{text}");
        assert!(text.contains("SinglePass"), "{text}");
    }

    #[test]
    fn explain_renders_two_pass_for_estimator_plans() {
        use crate::pipeline::presets::case_study_features_plan;
        let plan = case_study_features_plan(&[], "title", "abstract");
        for text in [
            explain(&plan, 2).unwrap(),
            explain_stream(&plan, &StreamOptions { readers: 2, workers: 3, queue_cap: 4 })
                .unwrap(),
        ] {
            assert!(text.contains("Fit IDF(tf -> tfidf"), "{text}");
            assert!(text.contains("TwoPass"), "{text}");
            assert!(text.contains("Pass 1 — fit IDF"), "{text}");
            assert!(text.contains("Pass 2 — apply fitted model"), "{text}");
            assert!(!text.contains("staged"), "no staged-path fallback: {text}");
        }
    }

    #[test]
    fn explain_fails_on_unexecutable_plans() {
        let plan = LogicalPlan::scan(vec![], &["c"]); // no Collect
        assert!(explain(&plan, 1).is_err());
        assert!(explain_stream(&plan, &StreamOptions::default()).is_err());
        assert!(explain_process(&plan, &ProcessOptions::default()).is_err());
        assert!(explain_remote(&plan, &RemoteOptions::default()).is_err());
    }

    #[test]
    fn explain_process_renders_topology_section() {
        let files: Vec<std::path::PathBuf> =
            (0..4).map(|i| std::path::PathBuf::from(format!("/tmp/{i}.json"))).collect();
        let plan = case_study_plan(&files, "title", "abstract");
        let opts = ProcessOptions { processes: 2, ..Default::default() };
        let text = explain_with(&plan, 2, &ExecutorKind::Process(opts)).unwrap();
        assert!(text.contains("== Physical Plan (multi-process) =="), "{text}");
        assert!(text.contains("ProcessPool [4 file-partitions, 2 worker processes]"), "{text}");
        assert!(text.contains("FusedStringStage"), "{text}");
        // The unified enum holds exactly one executor, so dispatch is
        // total — the default renders the single-pass topology.
        let fused = explain_with(&plan, 2, &ExecutorKind::Fused).unwrap();
        assert!(fused.contains("SinglePass"), "{fused}");
    }

    #[test]
    fn explain_remote_renders_topology_section() {
        let files: Vec<std::path::PathBuf> =
            (0..4).map(|i| std::path::PathBuf::from(format!("/tmp/{i}.json"))).collect();
        let plan = case_study_plan(&files, "title", "abstract");
        let opts = RemoteOptions {
            endpoints: vec!["10.0.0.1:7401".into(), "10.0.0.2:7401".into()],
            ..Default::default()
        };
        let text = explain_with(&plan, 2, &ExecutorKind::Remote(opts)).unwrap();
        assert!(text.contains("== Physical Plan (remote) =="), "{text}");
        assert!(text.contains("RemotePool [4 file-partitions, 2 remote endpoints]"), "{text}");
        assert!(text.contains("10.0.0.1:7401"), "{text}");
        assert!(text.contains("FusedStringStage"), "{text}");
    }

    #[test]
    fn explain_stream_renders_topology_section() {
        let plan = case_study_plan(&[], "title", "abstract");
        let opts = StreamOptions { readers: 2, workers: 3, queue_cap: 8 };
        let text = explain_stream(&plan, &opts).unwrap();
        assert!(text.contains("== Physical Plan (streaming) =="), "{text}");
        assert!(text.contains("StreamPipeline"), "{text}");
        assert!(text.contains("readers: 1 x read-bytes"), "{text}"); // clamped: 0 files
        assert!(text.contains("workers: 3 x parse+project [title, abstract] + op-program"), "{text}");
        assert!(text.contains("FusedStringStage"), "{text}");
    }
}
