//! EXPLAIN rendering: the logical plan as written, the plan after
//! optimization (showing what folded, hoisted and fused), and the
//! physical single-pass program it lowers to. Consumed by the CLI
//! `explain` command, `preprocess --explain`, and the report suite.

use super::logical::LogicalPlan;
use super::stream::StreamOptions;
use crate::Result;

/// Render all three EXPLAIN sections for `plan`.
///
/// ```
/// use p3sapp::pipeline::presets::case_study_plan;
///
/// let plan = case_study_plan(&[], "title", "abstract");
/// let text = p3sapp::plan::explain(&plan, 2).unwrap();
/// assert!(text.contains("== Optimized Logical Plan =="));
/// ```
pub fn explain(plan: &LogicalPlan, workers: usize) -> Result<String> {
    let optimized = plan.clone().optimize();
    let physical = optimized.lower()?;
    Ok(format!(
        "== Logical Plan ==\n{}\n== Optimized Logical Plan ==\n{}\n== Physical Plan ==\n{}",
        plan.render(),
        optimized.render(),
        physical.render(workers)
    ))
}

/// Dispatch for callers holding an optional streaming config (the CLI's
/// `--stream`, the report suite's `SuiteOptions::stream`):
/// [`explain_stream`] when one is set, [`explain`] otherwise.
pub fn explain_with(
    plan: &LogicalPlan,
    workers: usize,
    stream: Option<&StreamOptions>,
) -> Result<String> {
    match stream {
        Some(opts) => explain_stream(plan, opts),
        None => explain(plan, workers),
    }
}

/// Like [`explain`], but the physical section renders the streaming
/// topology (reader count, queue bound, worker count) that
/// [`LogicalPlan::execute_stream`] would run.
pub fn explain_stream(plan: &LogicalPlan, opts: &StreamOptions) -> Result<String> {
    let optimized = plan.clone().optimize();
    let physical = optimized.lower()?;
    Ok(format!(
        "== Logical Plan ==\n{}\n== Optimized Logical Plan ==\n{}\n== Physical Plan (streaming) ==\n{}",
        plan.render(),
        optimized.render(),
        physical.render_stream(opts)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::presets::case_study_plan;

    #[test]
    fn explain_shows_fusion_happening() {
        let plan = case_study_plan(&[], "title", "abstract");
        let text = explain(&plan, 2).unwrap();
        assert!(text.contains("== Logical Plan =="), "{text}");
        assert!(text.contains("== Optimized Logical Plan =="), "{text}");
        assert!(text.contains("== Physical Plan =="), "{text}");
        // The raw plan lists the individual stages; the optimized one
        // replaces them with fused sweeps.
        assert!(text.contains("Transform ConvertToLower(title)"), "{text}");
        assert!(text.contains("FusedStringStage(abstract <- lower|html|chars|stopwords"), "{text}");
        assert!(text.contains("SinglePass"), "{text}");
    }

    #[test]
    fn explain_renders_two_pass_for_estimator_plans() {
        use crate::pipeline::presets::case_study_features_plan;
        let plan = case_study_features_plan(&[], "title", "abstract");
        for text in [
            explain(&plan, 2).unwrap(),
            explain_stream(&plan, &StreamOptions { readers: 2, workers: 3, queue_cap: 4 })
                .unwrap(),
        ] {
            assert!(text.contains("Fit IDF(tf -> tfidf"), "{text}");
            assert!(text.contains("TwoPass"), "{text}");
            assert!(text.contains("Pass 1 — fit IDF"), "{text}");
            assert!(text.contains("Pass 2 — apply fitted model"), "{text}");
            assert!(!text.contains("staged"), "no staged-path fallback: {text}");
        }
    }

    #[test]
    fn explain_fails_on_unexecutable_plans() {
        let plan = LogicalPlan::scan(vec![], &["c"]); // no Collect
        assert!(explain(&plan, 1).is_err());
        assert!(explain_stream(&plan, &StreamOptions::default()).is_err());
    }

    #[test]
    fn explain_stream_renders_topology_section() {
        let plan = case_study_plan(&[], "title", "abstract");
        let opts = StreamOptions { readers: 2, workers: 3, queue_cap: 8 };
        let text = explain_stream(&plan, &opts).unwrap();
        assert!(text.contains("== Physical Plan (streaming) =="), "{text}");
        assert!(text.contains("StreamPipeline"), "{text}");
        assert!(text.contains("readers: 1 x parse+project"), "{text}"); // clamped: 0 files
        assert!(text.contains("workers: 3 x op-program"), "{text}");
        assert!(text.contains("FusedStringStage"), "{text}");
    }
}
