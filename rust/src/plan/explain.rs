//! EXPLAIN rendering: the logical plan as written, the plan after
//! optimization (showing what folded, hoisted and fused), and the
//! physical single-pass program it lowers to. Consumed by the CLI
//! `explain` command, `preprocess --explain`, and the report suite.

use super::logical::LogicalPlan;
use crate::Result;

/// Render all three EXPLAIN sections for `plan`.
pub fn explain(plan: &LogicalPlan, workers: usize) -> Result<String> {
    let optimized = plan.clone().optimize();
    let physical = optimized.lower()?;
    Ok(format!(
        "== Logical Plan ==\n{}\n== Optimized Logical Plan ==\n{}\n== Physical Plan ==\n{}",
        plan.render(),
        optimized.render(),
        physical.render(workers)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::presets::case_study_plan;

    #[test]
    fn explain_shows_fusion_happening() {
        let plan = case_study_plan(&[], "title", "abstract");
        let text = explain(&plan, 2).unwrap();
        assert!(text.contains("== Logical Plan =="), "{text}");
        assert!(text.contains("== Optimized Logical Plan =="), "{text}");
        assert!(text.contains("== Physical Plan =="), "{text}");
        // The raw plan lists the individual stages; the optimized one
        // replaces them with fused sweeps.
        assert!(text.contains("Transform ConvertToLower(title)"), "{text}");
        assert!(text.contains("FusedStringStage(abstract <- lower|html|chars|stopwords"), "{text}");
        assert!(text.contains("SinglePass"), "{text}");
    }

    #[test]
    fn explain_fails_on_unexecutable_plans() {
        let plan = LogicalPlan::scan(vec![], &["c"]); // no Collect
        assert!(explain(&plan, 1).is_err());
    }
}
