//! `FusedStringStage` — the plan optimizer's whole-stage "codegen"
//! analog. A run of N adjacent same-column string stages normally costs
//! N full column traversals and N intermediate `String` materializations
//! per row; fused, the kernel chain runs row-at-a-time through one
//! ping-pong pair of scratch buffers, sweeping the partition **once**.

use crate::frame::{Column, DType};
use crate::pipeline::stages::StringKernel;
use crate::pipeline::Transformer;

/// A chain of [`StringKernel`]s fused into one transformer. Built by the
/// optimizer (rule 4 of [`LogicalPlan::optimize`](super::LogicalPlan::optimize),
/// after sample/limit pushdown has moved row filters out of the way);
/// can also be constructed directly for ad-hoc pipelines and benches.
pub struct FusedStringStage {
    col: String,
    kernels: Vec<StringKernel>,
}

impl FusedStringStage {
    /// Fuse `kernels` (applied left to right) over column `col`.
    ///
    /// # Panics
    /// If `kernels` is empty.
    pub fn new(col: impl Into<String>, kernels: Vec<StringKernel>) -> Self {
        assert!(!kernels.is_empty(), "FusedStringStage needs at least one kernel");
        FusedStringStage { col: col.into(), kernels }
    }

    pub fn kernels(&self) -> &[StringKernel] {
        &self.kernels
    }

    /// Run the whole kernel chain on one row. The result is left in `a`;
    /// `b` and `scratch` are intermediates. All three buffers keep their
    /// capacity across calls, so steady-state cost is zero allocations
    /// per row beyond growth to the longest row seen.
    fn run_chain(&self, input: &str, scratch: &mut String, a: &mut String, b: &mut String) {
        self.kernels[0].apply(input, scratch, a);
        let mut in_a = true;
        for k in &self.kernels[1..] {
            if in_a {
                k.apply(a, scratch, b);
            } else {
                k.apply(b, scratch, a);
            }
            in_a = !in_a;
        }
        if !in_a {
            std::mem::swap(a, b);
        }
    }
}

impl Transformer for FusedStringStage {
    fn name(&self) -> &'static str {
        "FusedStringStage"
    }
    fn input_col(&self) -> &str {
        &self.col
    }
    fn output_col(&self) -> &str {
        &self.col
    }
    fn output_dtype(&self, input: DType) -> DType {
        input
    }

    fn transform_column(&self, input: &Column) -> Column {
        match input {
            Column::Str(src) => {
                let mut rows: Vec<Option<String>> = Vec::with_capacity(src.len());
                let (mut scratch, mut a, mut b) = (String::new(), String::new(), String::new());
                for v in src {
                    match v {
                        None => rows.push(None),
                        Some(s) => {
                            self.run_chain(s, &mut scratch, &mut a, &mut b);
                            rows.push(Some(std::mem::take(&mut a)));
                        }
                    }
                }
                Column::from_strs(rows)
            }
            other => other.clone(),
        }
    }

    fn transform_column_owned(&self, mut input: Column) -> Column {
        if let Column::Str(rows) = &mut input {
            let (mut scratch, mut a, mut b) = (String::new(), String::new(), String::new());
            for cell in rows.iter_mut() {
                if let Some(s) = cell {
                    self.run_chain(s, &mut scratch, &mut a, &mut b);
                    // The old cell string becomes the next row's output
                    // buffer — same zero-allocation swap trick the
                    // individual stages use, once per row instead of
                    // once per row *per stage*.
                    std::mem::swap(s, &mut a);
                }
            }
        }
        input
    }

    fn describe(&self) -> String {
        let chain: Vec<String> = self.kernels.iter().map(|k| k.label()).collect();
        format!("FusedStringStage({} <- {})", self.col, chain.join("|"))
    }

    fn wire_spec(&self) -> Option<super::process::WireStage> {
        Some(super::process::WireStage::Fused {
            col: self.col.clone(),
            kernels: self.kernels.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::stages::{
        ConvertToLower, RemoveHtmlTags, RemoveShortWords, RemoveUnwantedCharacters,
        StopWordsRemoverStr,
    };

    fn col(vals: &[Option<&str>]) -> Column {
        Column::from_strs(vals.iter().map(|v| v.map(String::from)).collect())
    }

    fn abstract_chain() -> FusedStringStage {
        FusedStringStage::new(
            "c",
            vec![
                StringKernel::Lower,
                StringKernel::StripHtml,
                StringKernel::RemoveUnwanted,
                StringKernel::RemoveStopwords,
                StringKernel::RemoveShortWords(1),
            ],
        )
    }

    fn staged_reference(input: &Column) -> Column {
        let c = ConvertToLower::new("c").transform_column(input);
        let c = RemoveHtmlTags::new("c").transform_column(&c);
        let c = RemoveUnwantedCharacters::new("c").transform_column(&c);
        let c = StopWordsRemoverStr::new("c").transform_column(&c);
        RemoveShortWords::new("c", 1).transform_column(&c)
    }

    #[test]
    fn fused_matches_staged_chain() {
        let input = col(&[
            Some("<b>The MODEL doesn't overfit (p < 0.05)</b> &amp; it's 12% better!"),
            Some(""),
            None,
            Some("a bb The CCC"),
        ]);
        let fused = abstract_chain();
        assert_eq!(fused.transform_column(&input), staged_reference(&input));
        // Owned path must agree with the borrowing path.
        assert_eq!(fused.transform_column_owned(input.clone()), staged_reference(&input));
    }

    #[test]
    fn single_kernel_chain_matches_stage() {
        let input = col(&[Some("AbC <i>X</i>")]);
        let fused = FusedStringStage::new("c", vec![StringKernel::Lower]);
        assert_eq!(
            fused.transform_column(&input),
            ConvertToLower::new("c").transform_column(&input)
        );
    }

    #[test]
    fn even_length_chain_lands_in_the_right_buffer() {
        // Two kernels: result ends in buffer b and must be swapped back.
        let input = col(&[Some("<i>The Answer</i>"), Some("X")]);
        let fused =
            FusedStringStage::new("c", vec![StringKernel::Lower, StringKernel::StripHtml]);
        let c = ConvertToLower::new("c").transform_column(&input);
        let expect = RemoveHtmlTags::new("c").transform_column(&c);
        assert_eq!(fused.transform_column(&input), expect);
    }

    #[test]
    fn nulls_propagate_and_describe_lists_kernels() {
        let fused = abstract_chain();
        assert!(fused.transform_column(&col(&[None])).is_null(0));
        let d = fused.describe();
        assert!(d.contains("FusedStringStage(c <- lower|html|chars|stopwords"), "{d}");
    }
}
