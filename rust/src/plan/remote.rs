//! Remote sharded execution: distribute a lowered [`PhysicalPlan`]
//! across `repro plan-worker --listen` endpoints on **other machines** —
//! the same versioned, digest-checked `P3PJ`/`P3PW` frames the process
//! executor pipes over stdio ([`super::process`]), carried over TCP.
//!
//! ```text
//! driver                                     remote workers (plan-worker --listen)
//! connect (retry + timeouts), ship    P3PJ   accept loop, one connection thread
//! op program + shard list per      ───────►  per driver; shards arrive inline or
//! endpoint                                   are fetched back by content digest
//!                                     P3PJ
//! answer fetch-artifact requests   ◄───────  resolve digest shards, then run the
//! with the shard bytes             ───────►  program on scoped threads across
//!                                     P3PW   cores
//! fold streamed chunk frames in    ◄───────  one bounded MODE_MAP_CHUNK frame
//! shard order through the shared             per completed shard, MODE_MAP_DONE
//! Merger as they arrive                      with the span section last
//! ```
//!
//! The job frame is the process executor's prefix
//! ([`super::process::encode_job_prefix`]: magic + version + trace flag
//! + op program + optional fit spec) followed by a *remote* shard
//! section: small shards ship **inline** (raw bytes in the frame, up to
//! [`RemoteOptions::inline_max_bytes`]), large shards ship as a content
//! digest (`xxh64` hex key + length) the worker resolves by sending a
//! [`Request::FetchArtifact`] back over the same connection before any
//! compute starts. Both directions verify the digest, so a shard that
//! changes on disk between encoding and fetching is a typed error,
//! never silent divergence.
//!
//! Failures are **driver errors naming the endpoint**: connection
//! refused (after [`RemoteOptions::connect_retries`] retries with
//! backoff), a read/write stuck past [`RemoteOptions::io_timeout`], a
//! garbled frame, or a connection that dies mid-stream (the error says
//! how many of the assigned shard results had arrived). The driver
//! checks that every assigned shard comes back exactly once and that
//! the worker's `MODE_MAP_DONE` chunk count matches.
//!
//! Output is **byte-identical** to every other executor: workers run
//! the exact same per-shard program ([`PhysicalPlan::run_shard_bytes`])
//! and the driver folds the streamed chunks through the exact same
//! ordered [`Merger`] (`rust/tests/plan_equivalence.rs`). Traced jobs
//! ship their spans home in the `MODE_MAP_DONE` / `MODE_FIT` frame and
//! [`obs::record_remote`] re-anchors them onto the driver timeline
//! inside the endpoint's `rpc` span, exactly like process workers.

use super::physical::{Merger, PartResult, PhysicalPlan, PlanOutput};
use super::process::{
    assign_shards, decode_fit_reply, decode_job_prefix, decode_part_result, decode_spans,
    encode_job_prefix, encode_part_result, encode_spans, JobPrefix, WireEstimator,
};
use crate::cache::xxh64;
use crate::obs;
use crate::pipeline::{Estimator, Transformer};
use crate::serve::proto::{
    begin_frame, check_frame, decode_reply, decode_request, encode_reply, encode_request,
    read_frame, read_path, seal_frame, write_frame, write_path, write_str, Reply, Request,
    JOB_MAGIC, MODE_FIT, MODE_MAP_CHUNK, MODE_MAP_DONE, REPLY_MAGIC,
};
use crate::Result;
use anyhow::Context as _;
use std::collections::{BTreeMap, HashMap};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Shard-shipping kinds in the remote job frame's shard section.
const SHARD_INLINE: u8 = 0;
const SHARD_DIGEST: u8 = 1;

/// Ceiling on any single worker-side socket read or write. A wedged or
/// dead driver must not pin a connection thread forever; this is a
/// generous backstop (a healthy driver answers fetches and drains
/// chunks promptly), not pacing.
const WORKER_IO_TIMEOUT: Duration = Duration::from_secs(600);

/// Knobs for the remote executor. `endpoints` is the only required
/// field; the rest default to LAN-friendly values.
#[derive(Debug, Clone)]
pub struct RemoteOptions {
    /// `HOST:PORT` of each `repro plan-worker --listen` endpoint. One
    /// connection per endpoint; shards stripe across them round-robin
    /// ([`assign_shards`]).
    pub endpoints: Vec<String>,
    /// Per-attempt TCP connect ceiling.
    pub connect_timeout: Duration,
    /// Ceiling on any single socket read or write once connected —
    /// a worker stuck past this is a typed driver error, not a hang.
    pub io_timeout: Duration,
    /// Connect retries after the first attempt (so `3` means up to 4
    /// attempts), with [`RemoteOptions::retry_backoff`] between them —
    /// covers a worker still binding its listener.
    pub connect_retries: u32,
    /// Sleep between connect attempts.
    pub retry_backoff: Duration,
    /// Shards at most this many bytes ship inline in the job frame;
    /// larger shards ship as a content digest the worker fetches back.
    pub inline_max_bytes: u64,
}

impl Default for RemoteOptions {
    fn default() -> Self {
        RemoteOptions {
            endpoints: Vec::new(),
            connect_timeout: Duration::from_secs(5),
            io_timeout: Duration::from_secs(60),
            connect_retries: 3,
            retry_backoff: Duration::from_millis(100),
            inline_max_bytes: 4 * 1024 * 1024,
        }
    }
}

/// Drives a plan across remote `plan-worker --listen` endpoints. See
/// the module docs for the wire protocol and failure semantics.
pub struct RemoteExecutor {
    opts: RemoteOptions,
}

impl RemoteExecutor {
    pub fn new(opts: RemoteOptions) -> Self {
        RemoteExecutor { opts }
    }

    fn check_endpoints(&self) -> Result<()> {
        anyhow::ensure!(
            !self.opts.endpoints.is_empty(),
            "remote executor has no endpoints (pass --remote HOST:PORT[,HOST:PORT...])"
        );
        Ok(())
    }

    /// Run `plan` across the remote endpoints. Output (frame bytes, row
    /// order, drop accounting) is identical to
    /// [`PhysicalPlan::execute`]; only the schedule differs.
    pub fn execute(&self, plan: &PhysicalPlan) -> Result<PlanOutput> {
        // Estimator-bearing plans orchestrate their two passes in
        // `PhysicalPlan::execute_remote`.
        if plan.is_two_pass() {
            return plan.execute_remote(&self.opts);
        }
        self.check_endpoints()?;
        let t_pass = Instant::now();
        if plan.files().is_empty() {
            // Nothing to ship: the in-process pass produces the same
            // (empty) bytes without a connection.
            return plan.execute(0);
        }
        let mut merger =
            Merger::new(plan.output_schema().clone(), plan.n_distinct(), plan.limit_n());
        self.run_map(plan, &mut |r| {
            merger.push(r);
            Ok(())
        })?;
        Ok(merger.finish(t_pass.elapsed(), Duration::ZERO))
    }

    /// Sink-based variant: hand each shard's [`PartResult`] to `sink`
    /// **in shard order** without merging — the partition-shipping fit
    /// pass of the two-pass strategy.
    pub(super) fn run(
        &self,
        plan: &PhysicalPlan,
        sink: &mut dyn FnMut(PartResult) -> Result<()>,
    ) -> Result<()> {
        if plan.files().is_empty() {
            return Ok(());
        }
        self.check_endpoints()?;
        self.run_map(plan, sink)
    }

    /// Partial-aggregate fit pass: each endpoint folds its shards into
    /// its own accumulator and ships the accumulated state; the driver
    /// merges partials (endpoint order) and fits the model. Only valid
    /// when the prefix program has no pending dedup/limit — the caller
    /// ([`PhysicalPlan::execute_remote`]) checks that.
    pub(super) fn run_fit_partial(
        &self,
        prefix: &PhysicalPlan,
        est: &dyn Estimator,
        spec: WireEstimator,
        in_idx: usize,
    ) -> Result<Arc<dyn Transformer>> {
        let mut acc = est.accumulator().ok_or_else(|| {
            anyhow::anyhow!(
                "estimator {} lost its accumulator between lower and execute",
                est.name()
            )
        })?;
        let n = prefix.files().len();
        if n == 0 {
            return acc.finish();
        }
        self.check_endpoints()?;
        anyhow::ensure!(
            acc.partial().is_some(),
            "estimator {} does not support cross-process partial folds",
            est.name()
        );
        let k = self.opts.endpoints.len().min(n);
        let assignments = assign_shards(prefix.files(), k);
        let replies: Vec<(u64, Vec<u8>)> = std::thread::scope(|scope| {
            let handles: Vec<_> = assignments
                .iter()
                .enumerate()
                .map(|(w, shards)| {
                    let opts = &self.opts;
                    let ep = self.opts.endpoints[w].as_str();
                    let spec = &spec;
                    scope.spawn(move || drive_endpoint_fit(opts, ep, w, prefix, spec, in_idx, shards))
                })
                .collect();
            join_first_err(handles)
        })?;
        for (w, (anchor, bytes)) in replies.iter().enumerate() {
            let ep = &self.opts.endpoints[w];
            let (partial, spans) = decode_fit_reply(bytes, w as u32)
                .with_context(|| format!("remote worker {ep}"))?;
            obs::record_remote(spans, w, *anchor);
            acc.merge_partial(&partial)
                .with_context(|| format!("remote worker {ep}: merging fit partial"))?;
        }
        acc.finish()
    }

    /// Scatter the plan's shards across the endpoints and fold the
    /// streamed chunk frames into `sink` **in shard order** (the
    /// `Merger`'s dedup and limit fold depend on it): out-of-order
    /// arrivals park in a reorder buffer until their predecessors land.
    fn run_map(
        &self,
        plan: &PhysicalPlan,
        sink: &mut dyn FnMut(PartResult) -> Result<()>,
    ) -> Result<()> {
        let n = plan.files().len();
        let k = self.opts.endpoints.len().min(n);
        let assignments = assign_shards(plan.files(), k);
        let (tx, rx) = mpsc::channel::<(u64, PartResult)>();
        let mut pending: BTreeMap<u64, PartResult> = BTreeMap::new();
        let mut next: u64 = 0;
        let mut sink_err: Option<anyhow::Error> = None;
        std::thread::scope(|scope| -> Result<()> {
            let handles: Vec<_> = assignments
                .iter()
                .enumerate()
                .map(|(w, shards)| {
                    let tx = tx.clone();
                    let opts = &self.opts;
                    let ep = self.opts.endpoints[w].as_str();
                    scope.spawn(move || drive_endpoint_map(opts, ep, w, plan, shards, &tx))
                })
                .collect();
            // The endpoint threads hold the remaining senders; dropping
            // ours lets the drain loop end when they all finish.
            drop(tx);
            while let Ok((idx, r)) = rx.recv() {
                anyhow::ensure!(idx < n as u64, "remote result for unknown shard index {idx}");
                anyhow::ensure!(
                    idx >= next && !pending.contains_key(&idx),
                    "shard {idx} returned twice"
                );
                pending.insert(idx, r);
                while let Some(r) = pending.remove(&next) {
                    if sink_err.is_none() {
                        if let Err(e) = sink(r) {
                            // Keep draining so endpoint threads can
                            // finish; their error (if any) wins below.
                            sink_err = Some(e);
                        }
                    }
                    next += 1;
                }
            }
            join_first_err(handles).map(|_| ())
        })?;
        if let Some(e) = sink_err {
            return Err(e);
        }
        anyhow::ensure!(next == n as u64, "remote pass folded {next} of {n} shards");
        Ok(())
    }
}

/// Join every endpoint thread and return their results in endpoint
/// order, first error winning — every thread is joined before this
/// returns, so no connection outlives a driver error unobserved.
fn join_first_err<T>(
    handles: Vec<std::thread::ScopedJoinHandle<'_, Result<T>>>,
) -> Result<Vec<T>> {
    let mut out = Vec::with_capacity(handles.len());
    let mut first_err: Option<anyhow::Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(v)) => out.push(v),
            Ok(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
            Err(_) => {
                if first_err.is_none() {
                    first_err = Some(anyhow::anyhow!("remote driver thread panicked"));
                }
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(out),
    }
}

/// Resolve and connect to `ep` with per-attempt timeouts and
/// retry-with-backoff, then arm both I/O timeouts. Every failure is a
/// typed error naming the endpoint.
fn connect(opts: &RemoteOptions, ep: &str) -> Result<TcpStream> {
    let attempts = opts.connect_retries.saturating_add(1);
    let mut last = String::from("no addresses resolved");
    for attempt in 0..attempts {
        if attempt > 0 {
            std::thread::sleep(opts.retry_backoff);
        }
        // Re-resolve each attempt: a worker host coming up may not be
        // in DNS yet on the first try.
        let addrs: Vec<SocketAddr> = match ep.to_socket_addrs() {
            Ok(addrs) => addrs.collect(),
            Err(e) => {
                last = format!("resolve: {e}");
                continue;
            }
        };
        for addr in addrs {
            match TcpStream::connect_timeout(&addr, opts.connect_timeout) {
                Ok(stream) => {
                    if !opts.io_timeout.is_zero() {
                        stream
                            .set_read_timeout(Some(opts.io_timeout))
                            .and_then(|()| stream.set_write_timeout(Some(opts.io_timeout)))
                            .map_err(|e| {
                                anyhow::anyhow!("remote worker {ep}: arming I/O timeouts: {e}")
                            })?;
                    }
                    // Chunk frames are small and latency-sensitive.
                    let _ = stream.set_nodelay(true);
                    return Ok(stream);
                }
                Err(e) => last = format!("{addr}: {e}"),
            }
        }
    }
    anyhow::bail!("remote worker {ep}: connect failed after {attempts} attempts: {last}")
}

/// Encode one endpoint's job frame: the shared prefix
/// ([`encode_job_prefix`]) plus the remote shard section — inline bytes
/// for small shards, a content-digest key (plus expected length) for
/// large ones. Returns the sealed frame and the `key → path` map for
/// answering that connection's fetch-artifact requests. Every shard is
/// read once here (large ones are re-read on fetch; the digest pins
/// content identity across the two reads).
fn encode_remote_job(
    plan: &PhysicalPlan,
    worker_id: u32,
    fit: Option<(&WireEstimator, usize)>,
    shards: &[(u64, &Path)],
    inline_max: u64,
) -> Result<(Vec<u8>, HashMap<String, PathBuf>)> {
    let mut buf = encode_job_prefix(plan, worker_id, fit)?;
    let mut by_key = HashMap::new();
    buf.extend_from_slice(&(shards.len() as u32).to_le_bytes());
    for (idx, path) in shards {
        buf.extend_from_slice(&idx.to_le_bytes());
        write_path(&mut buf, path);
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("read shard {}: {e}", path.display()))?;
        if bytes.len() as u64 <= inline_max {
            buf.push(SHARD_INLINE);
            buf.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
            buf.extend_from_slice(&bytes);
        } else {
            let key = format!("{:016x}", xxh64(&bytes, 0));
            buf.push(SHARD_DIGEST);
            write_str(&mut buf, &key);
            buf.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
            by_key.insert(key, path.to_path_buf());
        }
    }
    seal_frame(&mut buf);
    Ok((buf, by_key))
}

/// Answer one worker fetch-artifact request with the shard bytes,
/// re-verifying the digest before shipping.
fn answer_fetch(
    stream: &mut TcpStream,
    ep: &str,
    key: &str,
    by_key: &HashMap<String, PathBuf>,
) -> Result<()> {
    let path = by_key
        .get(key)
        .ok_or_else(|| anyhow::anyhow!("remote worker {ep}: requested unknown shard digest {key}"))?;
    let bytes = std::fs::read(path).map_err(|e| {
        anyhow::anyhow!("remote worker {ep}: re-reading shard {}: {e}", path.display())
    })?;
    anyhow::ensure!(
        format!("{:016x}", xxh64(&bytes, 0)) == key,
        "remote worker {ep}: shard {} changed on disk since the job was encoded",
        path.display()
    );
    write_frame(stream, &encode_reply(&Reply::Bytes(bytes)))
        .map_err(|e| anyhow::anyhow!("remote worker {ep}: shipping shard bytes: {e}"))?;
    Ok(())
}

/// Drive one endpoint through a map job: connect, ship the job, answer
/// its shard fetches, and forward every streamed chunk to `tx` until
/// the `MODE_MAP_DONE` frame closes the books.
fn drive_endpoint_map(
    opts: &RemoteOptions,
    ep: &str,
    w: usize,
    plan: &PhysicalPlan,
    shards: &[(u64, &Path)],
    tx: &mpsc::Sender<(u64, PartResult)>,
) -> Result<()> {
    let (job, by_key) = encode_remote_job(plan, w as u32, None, shards, opts.inline_max_bytes)?;
    let mut stream = connect(opts, ep)?;
    // Wrap the exchange in an `rpc` span on the worker-process lane;
    // the worker's shipped spans re-anchor against `anchor` so they
    // nest inside it on the same track ([`obs::record_remote`]).
    let _lane = obs::lane_scope(obs::lane_worker_process(w));
    let mut sp = obs::span("rpc", "rpc");
    if sp.active() {
        sp.arg("worker", w as u64);
    }
    let anchor = obs::now_ns();
    write_frame(&mut stream, &job)
        .map_err(|e| anyhow::anyhow!("remote worker {ep}: shipping job: {e}"))?;
    let mut chunks: u64 = 0;
    loop {
        let frame = read_frame(&mut stream)
            .with_context(|| format!("remote worker {ep}"))?
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "remote worker {ep}: connection closed mid-stream \
                     ({chunks} of {} shard results received)",
                    shards.len()
                )
            })?;
        // The worker interleaves two frame kinds on this socket: P3PJ
        // fetch-artifact requests (before compute) and P3PW results.
        if frame.starts_with(JOB_MAGIC) {
            match decode_request(&frame).with_context(|| format!("remote worker {ep}"))? {
                Request::FetchArtifact { key } => answer_fetch(&mut stream, ep, &key, &by_key)?,
                _ => anyhow::bail!("remote worker {ep}: unexpected request on a job connection"),
            }
            continue;
        }
        let mut cur = check_frame(&frame, REPLY_MAGIC, "result")
            .with_context(|| format!("remote worker {ep}"))?;
        let ctx = || format!("remote worker {ep}");
        let got = cur.u32().with_context(ctx)?;
        anyhow::ensure!(
            got == w as u32,
            "remote worker {ep}: result frame for worker {got}, expected {w}"
        );
        match cur.u8().with_context(ctx)? {
            MODE_MAP_CHUNK => {
                let (idx, r) = decode_part_result(&mut cur, plan.output_schema(), plan.n_distinct())
                    .with_context(ctx)?;
                anyhow::ensure!(
                    cur.remaining() == 0,
                    "remote worker {ep}: chunk frame has {} trailing bytes",
                    cur.remaining()
                );
                chunks += 1;
                if tx.send((idx, r)).is_err() {
                    // Receiver gone: another endpoint already failed
                    // and the drain loop ended. Stop quietly; that
                    // first error wins.
                    return Ok(());
                }
            }
            MODE_MAP_DONE => {
                let declared = cur.u64().with_context(ctx)?;
                let spans = decode_spans(&mut cur).with_context(ctx)?;
                anyhow::ensure!(
                    cur.remaining() == 0,
                    "remote worker {ep}: done frame has {} trailing bytes",
                    cur.remaining()
                );
                anyhow::ensure!(
                    declared == chunks && chunks as usize == shards.len(),
                    "remote worker {ep}: {chunks} shard results arrived for {} assigned \
                     shards ({declared} declared)",
                    shards.len()
                );
                obs::record_remote(spans, w, anchor);
                return Ok(());
            }
            mode => anyhow::bail!("remote worker {ep}: result frame has unexpected mode {mode}"),
        }
    }
}

/// Drive one endpoint through a fit job: connect, ship, answer
/// fetches, and return the raw `MODE_FIT` reply frame with the RPC
/// anchor (decoded on the driver thread, in endpoint order).
fn drive_endpoint_fit(
    opts: &RemoteOptions,
    ep: &str,
    w: usize,
    prefix: &PhysicalPlan,
    spec: &WireEstimator,
    in_idx: usize,
    shards: &[(u64, &Path)],
) -> Result<(u64, Vec<u8>)> {
    let (job, by_key) =
        encode_remote_job(prefix, w as u32, Some((spec, in_idx)), shards, opts.inline_max_bytes)?;
    let mut stream = connect(opts, ep)?;
    let _lane = obs::lane_scope(obs::lane_worker_process(w));
    let mut sp = obs::span("rpc", "rpc");
    if sp.active() {
        sp.arg("worker", w as u64);
    }
    let anchor = obs::now_ns();
    write_frame(&mut stream, &job)
        .map_err(|e| anyhow::anyhow!("remote worker {ep}: shipping job: {e}"))?;
    loop {
        let frame = read_frame(&mut stream)
            .with_context(|| format!("remote worker {ep}"))?
            .ok_or_else(|| {
                anyhow::anyhow!("remote worker {ep}: connection closed before the fit reply")
            })?;
        if frame.starts_with(JOB_MAGIC) {
            match decode_request(&frame).with_context(|| format!("remote worker {ep}"))? {
                Request::FetchArtifact { key } => answer_fetch(&mut stream, ep, &key, &by_key)?,
                _ => anyhow::bail!("remote worker {ep}: unexpected request on a job connection"),
            }
            continue;
        }
        return Ok((anchor, frame));
    }
}

// ---------------------------------------------------------------------------
// Worker side: `repro plan-worker --listen ADDR`
// ---------------------------------------------------------------------------

/// CLI entry for `repro plan-worker --listen [ADDR]` (default
/// `127.0.0.1:0`): bind, print the bound address, serve forever.
pub fn listen_main(addr: Option<&str>) -> i32 {
    match listen(addr.unwrap_or("127.0.0.1:0")) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("plan-worker: {e:#}");
            1
        }
    }
}

fn listen(addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr).map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
    // The bound address prints first and alone on stdout — harnesses
    // that bind port 0 parse this line to learn the real port.
    let local = listener.local_addr().map_err(|e| anyhow::anyhow!("local addr: {e}"))?;
    println!("listening on {local}");
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    serve_listener(listener)
}

/// Accept loop: one connection thread per driver, forever. Public so
/// tests and benches can serve an in-process loopback listener (bind
/// `127.0.0.1:0` themselves, spawn this on a thread) without spawning
/// the `repro` binary. A connection error is logged to stderr and does
/// not take the listener down.
pub fn serve_listener(listener: TcpListener) -> Result<()> {
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(conn) => conn,
            Err(e) => {
                eprintln!("plan-worker: accept: {e}");
                continue;
            }
        };
        std::thread::spawn(move || {
            if let Err(e) = serve_conn(stream, peer) {
                eprintln!("plan-worker: {peer}: {e:#}");
            }
        });
    }
}

/// One driver connection: run job frames until the driver hangs up
/// cleanly. A job failure propagates (closing the connection), which
/// the driver surfaces as a typed mid-stream error.
fn serve_conn(mut stream: TcpStream, peer: SocketAddr) -> Result<()> {
    stream
        .set_read_timeout(Some(WORKER_IO_TIMEOUT))
        .and_then(|()| stream.set_write_timeout(Some(WORKER_IO_TIMEOUT)))
        .map_err(|e| anyhow::anyhow!("arming I/O timeouts: {e}"))?;
    let _ = stream.set_nodelay(true);
    while let Some(job) = read_frame(&mut stream).with_context(|| format!("driver {peer}"))? {
        run_remote_job(&job, &mut stream).with_context(|| format!("driver {peer}"))?;
    }
    Ok(())
}

/// A shard as shipped in the remote job frame: index, path (error
/// context only), and its raw bytes (resolved before compute starts).
type RemoteShard = (u64, PathBuf, Vec<u8>);

enum RunDone {
    Map { chunks: u64 },
    Fit { partial: Vec<u8> },
}

/// Decode and execute one remote job frame, streaming chunk frames as
/// shards complete and closing with the `MODE_MAP_DONE` (or `MODE_FIT`)
/// frame that carries the span section.
fn run_remote_job(job: &[u8], stream: &mut TcpStream) -> Result<()> {
    let mut cur = check_frame(job, JOB_MAGIC, "job")?;
    let JobPrefix { worker_id, mode: _, traced, plan, fit } = decode_job_prefix(&mut cur)?;
    let n_shards = cur.u32()? as usize;
    anyhow::ensure!(n_shards <= cur.remaining(), "job declares {n_shards} shards");
    let mut shards: Vec<RemoteShard> = Vec::with_capacity(n_shards);
    let mut fetches: Vec<(usize, String, u64)> = Vec::new();
    for i in 0..n_shards {
        let idx = cur.u64()?;
        let path = read_path(&mut cur)?;
        match cur.u8()? {
            SHARD_INLINE => {
                let len = cur.u64()? as usize;
                let bytes = cur.take(len)?.to_vec();
                shards.push((idx, path, bytes));
            }
            SHARD_DIGEST => {
                let key = cur.str()?;
                let len = cur.u64()?;
                fetches.push((i, key, len));
                shards.push((idx, path, Vec::new()));
            }
            kind => anyhow::bail!("unknown shard-shipping kind {kind}"),
        }
    }
    anyhow::ensure!(cur.remaining() == 0, "job frame has {} trailing bytes", cur.remaining());

    // Resolve digest shards back over the same connection, one at a
    // time, before any compute starts — afterwards the socket is
    // write-only until the job's closing frame.
    for (i, key, len) in fetches {
        write_frame(stream, &encode_request(&Request::FetchArtifact { key: key.clone() }))
            .map_err(|e| anyhow::anyhow!("requesting shard {key}: {e}"))?;
        let frame = read_frame(stream)?
            .ok_or_else(|| anyhow::anyhow!("driver closed while serving shard {key}"))?;
        let bytes = match decode_reply(&frame)? {
            Reply::Bytes(bytes) => bytes,
            Reply::Err(e) => anyhow::bail!("driver refused shard {key}: {}", e.message),
            _ => anyhow::bail!("unexpected reply to a shard fetch"),
        };
        anyhow::ensure!(
            bytes.len() as u64 == len && format!("{:016x}", xxh64(&bytes, 0)) == key,
            "shard {key}: fetched bytes fail their digest"
        );
        shards[i].2 = bytes;
    }

    // A traced job gets a fresh sink, uninstalled on every exit path:
    // this connection thread would otherwise leak a stale sink into
    // the driver's next job on the same connection.
    let sink = if traced { Some(obs::trace::install_new()) } else { None };
    let result = run_assigned(worker_id, &plan, fit, &shards, stream);
    let spans = match &sink {
        Some(sink) => {
            obs::trace::uninstall();
            sink.drain()
        }
        None => Vec::new(),
    };
    match result? {
        RunDone::Map { chunks } => {
            let mut buf = begin_frame(REPLY_MAGIC);
            buf.extend_from_slice(&worker_id.to_le_bytes());
            buf.push(MODE_MAP_DONE);
            buf.extend_from_slice(&chunks.to_le_bytes());
            encode_spans(&mut buf, &spans);
            seal_frame(&mut buf);
            write_frame(stream, &buf).map_err(|e| anyhow::anyhow!("writing done frame: {e}"))
        }
        RunDone::Fit { partial } => {
            let mut buf = begin_frame(REPLY_MAGIC);
            buf.extend_from_slice(&worker_id.to_le_bytes());
            buf.push(MODE_FIT);
            buf.extend_from_slice(&(partial.len() as u64).to_le_bytes());
            buf.extend_from_slice(&partial);
            encode_spans(&mut buf, &spans);
            seal_frame(&mut buf);
            write_frame(stream, &buf).map_err(|e| anyhow::anyhow!("writing fit reply: {e}"))
        }
    }
}

/// Run the resolved shards. Map jobs fan out across scoped threads
/// (one per core, capped at the shard count), each claiming shards off
/// a shared counter and streaming one bounded chunk frame per
/// completed shard under the write lock — the reply never buffers more
/// than one shard's result. Fit jobs fold sequentially in shard order,
/// exactly like the process worker.
fn run_assigned(
    worker_id: u32,
    plan: &PhysicalPlan,
    fit: Option<(WireEstimator, usize)>,
    shards: &[RemoteShard],
    stream: &mut TcpStream,
) -> Result<RunDone> {
    match fit {
        Some((est_spec, in_idx)) => {
            let est = est_spec.build();
            let mut acc = est
                .accumulator()
                .ok_or_else(|| anyhow::anyhow!("estimator {} has no accumulator", est.name()))?;
            for (idx, path, bytes) in shards {
                let r = plan
                    .run_shard_bytes(*idx as usize, path, bytes, Duration::ZERO)
                    .with_context(|| format!("shard {idx}"))?;
                if r.part.num_rows() > 0 {
                    anyhow::ensure!(
                        in_idx < r.part.num_columns(),
                        "fit input column {in_idx} out of range ({} columns)",
                        r.part.num_columns()
                    );
                    acc.accumulate(r.part.column(in_idx))?;
                }
            }
            let partial = acc
                .partial()
                .ok_or_else(|| anyhow::anyhow!("estimator {} has no partial state", est.name()))?;
            Ok(RunDone::Fit { partial })
        }
        None => {
            let threads = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(2)
                .min(shards.len())
                .max(1);
            let next = AtomicUsize::new(0);
            let writer = Mutex::new(stream);
            std::thread::scope(|scope| -> Result<()> {
                let handles: Vec<_> = (0..threads)
                    .map(|t| {
                        let next = &next;
                        let writer = &writer;
                        scope.spawn(move || -> Result<()> {
                            // Each compute thread records on its own lane
                            // so shipped spans land on per-thread tracks.
                            let _lane = obs::lane_scope(obs::lane_worker_thread(t));
                            loop {
                                let i = next.fetch_add(1, Ordering::Relaxed);
                                let Some((idx, path, bytes)) = shards.get(i) else {
                                    return Ok(());
                                };
                                let r = plan
                                    .run_shard_bytes(*idx as usize, path, bytes, Duration::ZERO)
                                    .with_context(|| format!("shard {idx}"))?;
                                let mut buf = begin_frame(REPLY_MAGIC);
                                buf.extend_from_slice(&worker_id.to_le_bytes());
                                buf.push(MODE_MAP_CHUNK);
                                encode_part_result(&mut buf, *idx, &r);
                                seal_frame(&mut buf);
                                let mut w =
                                    writer.lock().unwrap_or_else(|poison| poison.into_inner());
                                write_frame(&mut **w, &buf).map_err(|e| {
                                    anyhow::anyhow!("shipping shard {idx} result: {e}")
                                })?;
                            }
                        })
                    })
                    .collect();
                join_first_err(handles).map(|_| ())
            })?;
            Ok(RunDone::Map { chunks: shards.len() as u64 })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::LogicalPlan;

    fn tmp_shard(name: &str, bytes: &[u8]) -> PathBuf {
        let path = std::env::temp_dir().join(format!("p3sapp-remote-{}-{name}", std::process::id()));
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn options_default_to_lan_friendly_knobs() {
        let opts = RemoteOptions::default();
        assert!(opts.endpoints.is_empty());
        assert_eq!(opts.connect_timeout, Duration::from_secs(5));
        assert_eq!(opts.io_timeout, Duration::from_secs(60));
        assert_eq!(opts.connect_retries, 3);
        assert_eq!(opts.retry_backoff, Duration::from_millis(100));
        assert_eq!(opts.inline_max_bytes, 4 * 1024 * 1024);
    }

    #[test]
    fn connect_failure_names_endpoint_and_attempts() {
        // Bind then drop to find a port that (very likely) refuses.
        let port = {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            listener.local_addr().unwrap().port()
        };
        let ep = format!("127.0.0.1:{port}");
        let opts = RemoteOptions {
            endpoints: vec![ep.clone()],
            connect_timeout: Duration::from_millis(250),
            connect_retries: 1,
            retry_backoff: Duration::from_millis(1),
            ..RemoteOptions::default()
        };
        let err = format!("{:#}", connect(&opts, &ep).unwrap_err());
        assert!(err.contains(&format!("remote worker {ep}")), "{err}");
        assert!(err.contains("connect failed after 2 attempts"), "{err}");
    }

    #[test]
    fn execute_without_endpoints_is_a_typed_error() {
        let plan = LogicalPlan::scan(vec![tmp_shard("no-eps", b"{}")], &["title"])
            .collect()
            .optimize()
            .lower()
            .unwrap();
        let err = format!(
            "{:#}",
            RemoteExecutor::new(RemoteOptions::default()).execute(&plan).unwrap_err()
        );
        assert!(err.contains("remote executor has no endpoints"), "{err}");
        assert!(err.contains("--remote"), "{err}");
    }

    #[test]
    fn job_shards_ship_inline_or_by_digest() {
        let small = tmp_shard("inline", b"{\"title\":\"a\"}\n");
        let big_bytes = vec![b'x'; 64];
        let big = tmp_shard("digest", &big_bytes);
        let plan = LogicalPlan::scan(vec![small.clone(), big.clone()], &["title"])
            .collect()
            .optimize()
            .lower()
            .unwrap();
        let shards = assign_shards(plan.files(), 1);
        let (job, by_key) = encode_remote_job(&plan, 0, None, &shards[0], 32).unwrap();

        let expect_key = format!("{:016x}", xxh64(&big_bytes, 0));
        assert_eq!(by_key.len(), 1);
        assert_eq!(by_key.get(&expect_key), Some(&big));

        let mut cur = check_frame(&job, JOB_MAGIC, "job").unwrap();
        let prefix = decode_job_prefix(&mut cur).unwrap();
        assert_eq!(prefix.worker_id, 0);
        assert!(prefix.fit.is_none());
        assert_eq!(cur.u32().unwrap(), 2);
        // Shard 0: small enough for the inline kind, raw bytes present.
        assert_eq!(cur.u64().unwrap(), 0);
        assert_eq!(read_path(&mut cur).unwrap(), small);
        assert_eq!(cur.u8().unwrap(), SHARD_INLINE);
        let len = cur.u64().unwrap() as usize;
        assert_eq!(cur.take(len).unwrap(), &std::fs::read(&small).unwrap()[..]);
        // Shard 1: over the inline ceiling, ships digest + length only.
        assert_eq!(cur.u64().unwrap(), 1);
        assert_eq!(read_path(&mut cur).unwrap(), big);
        assert_eq!(cur.u8().unwrap(), SHARD_DIGEST);
        assert_eq!(cur.str().unwrap(), expect_key);
        assert_eq!(cur.u64().unwrap(), big_bytes.len() as u64);
        assert_eq!(cur.remaining(), 0);
    }

    #[test]
    fn loopback_execute_matches_fused() {
        let rows = b"{\"title\":\"Alpha\",\"x\":1}\n{\"title\":\"beta\"}\n{\"title\":null}\n";
        let rows2 = b"{\"title\":\"Gamma\"}\n{\"title\":\"beta\"}\n";
        let files = vec![tmp_shard("lb-0", rows), tmp_shard("lb-1", rows2)];
        let plan = LogicalPlan::scan(files, &["title"])
            .drop_nulls(&["title"])
            .distinct(&["title"])
            .collect()
            .optimize()
            .lower()
            .unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let ep = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || serve_listener(listener));

        let opts = RemoteOptions {
            endpoints: vec![ep],
            // Force the digest path for one shard to cover the fetch
            // round-trip end to end.
            inline_max_bytes: rows2.len() as u64,
            ..RemoteOptions::default()
        };
        let remote = RemoteExecutor::new(opts).execute(&plan).unwrap();
        let fused = plan.execute(0).unwrap();
        assert_eq!(remote.rows_out, fused.rows_out);
        assert_eq!(remote.frame, fused.frame);
    }
}
