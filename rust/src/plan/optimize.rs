//! Rule-based logical-plan optimizer — the Catalyst analog, sized to our
//! three rules:
//!
//! 1. **Projection pushdown** — a `Project` directly after `Ingest`
//!    narrows the scan's field list, so dropped fields are skipped at
//!    JSON-lexer speed instead of parsed and thrown away.
//! 2. **Null-drop pushdown** — `DropNulls` hoists ahead of any
//!    null-preserving same-column string rewrite, so rows that are going
//!    to be dropped are never cleaned.
//! 3. **String-stage fusion** — adjacent same-column `string -> string`
//!    stages collapse into one [`FusedStringStage`] whose kernel chain
//!    sweeps the partition once (whole-stage codegen, scaled down).
//!
//! Rules run in that order; each is a pure `Vec<LogicalOp>` rewrite.

use super::fused::FusedStringStage;
use super::logical::{LogicalOp, LogicalPlan};
use crate::frame::DType;
use crate::pipeline::stages::StringKernel;
use crate::pipeline::Transformer;
use std::collections::HashMap;
use std::sync::Arc;

/// Apply all rules to `plan`.
pub fn optimize(plan: LogicalPlan) -> LogicalPlan {
    let ops = push_projection(plan.ops);
    let ops = push_null_drop(ops);
    let ops = fuse_string_stages(ops);
    LogicalPlan { ops }
}

/// Rule 1: fold `Project` into a directly preceding `Ingest` when it
/// only narrows the scan's field list.
fn push_projection(ops: Vec<LogicalOp>) -> Vec<LogicalOp> {
    let mut out: Vec<LogicalOp> = Vec::with_capacity(ops.len());
    for op in ops {
        if let LogicalOp::Project { cols } = &op {
            if let Some(LogicalOp::Ingest { fields, .. }) = out.last_mut() {
                if cols.iter().all(|c| fields.contains(c)) {
                    *fields = cols.clone();
                    continue;
                }
            }
        }
        out.push(op);
    }
    out
}

/// Rule 2: bubble every `DropNulls` leftwards over null-preserving
/// same-column string rewrites (a stage with a [`StringKernel`] maps
/// null -> null and never *creates* a null, so the filtered row set is
/// identical on either side — but dropped rows skip the rewrite).
fn push_null_drop(mut ops: Vec<LogicalOp>) -> Vec<LogicalOp> {
    for i in 1..ops.len() {
        if matches!(ops[i], LogicalOp::DropNulls { .. }) {
            let mut j = i;
            while j > 0 && hoistable(&ops[j - 1]) {
                ops.swap(j - 1, j);
                j -= 1;
            }
        }
    }
    ops
}

fn hoistable(op: &LogicalOp) -> bool {
    match op {
        LogicalOp::Transform { stage } => {
            stage.string_kernel().is_some() && stage.input_col() == stage.output_col()
        }
        _ => false,
    }
}

/// Rule 3: collapse runs of adjacent fusable stages on the same string
/// column into one [`FusedStringStage`]. Column dtypes are tracked
/// through the plan so a stage whose input has become `array<string>`
/// (e.g. `RemoveShortWords` after a `Tokenizer`) is never fused.
fn fuse_string_stages(ops: Vec<LogicalOp>) -> Vec<LogicalOp> {
    let mut dtypes: HashMap<String, DType> = HashMap::new();
    let mut out: Vec<LogicalOp> = Vec::with_capacity(ops.len());
    let mut run: Vec<(Arc<dyn Transformer>, StringKernel)> = Vec::new();
    let mut run_col: Option<String> = None;

    fn flush(
        out: &mut Vec<LogicalOp>,
        run: &mut Vec<(Arc<dyn Transformer>, StringKernel)>,
        run_col: &mut Option<String>,
    ) {
        let Some(col) = run_col.take() else { return };
        if run.len() == 1 {
            // A lone fusable stage gains nothing from fusion — emit the
            // original stage so EXPLAIN keeps its real name.
            let (stage, _) = run.pop().unwrap();
            out.push(LogicalOp::Transform { stage });
        } else if !run.is_empty() {
            let kernels: Vec<StringKernel> = run.drain(..).map(|(_, k)| k).collect();
            out.push(LogicalOp::Transform {
                stage: Arc::new(FusedStringStage::new(col, kernels)),
            });
        }
    }

    for op in ops {
        match op {
            LogicalOp::Ingest { files, fields } => {
                for f in &fields {
                    dtypes.insert(f.clone(), DType::Str);
                }
                flush(&mut out, &mut run, &mut run_col);
                out.push(LogicalOp::Ingest { files, fields });
            }
            LogicalOp::Transform { stage } => {
                let in_dtype =
                    dtypes.get(stage.input_col()).copied().unwrap_or(DType::Str);
                let kernel = stage.string_kernel();
                let fusable = kernel.is_some()
                    && stage.input_col() == stage.output_col()
                    && in_dtype == DType::Str;
                if fusable {
                    if run_col.as_deref() != Some(stage.input_col()) {
                        flush(&mut out, &mut run, &mut run_col);
                        run_col = Some(stage.input_col().to_string());
                    }
                    let k = kernel.unwrap();
                    run.push((stage, k));
                } else {
                    flush(&mut out, &mut run, &mut run_col);
                    dtypes.insert(
                        stage.output_col().to_string(),
                        stage.output_dtype(in_dtype),
                    );
                    out.push(LogicalOp::Transform { stage });
                }
            }
            other => {
                // Filters, dedup, project and collect are fusion
                // barriers: a filter between two rewrites changes which
                // rows the second rewrite sees.
                flush(&mut out, &mut run, &mut run_col);
                out.push(other);
            }
        }
    }
    flush(&mut out, &mut run, &mut run_col);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::presets::case_study_plan;
    use crate::pipeline::stages::{ConvertToLower, RemoveHtmlTags, RemoveShortWords, Tokenizer};

    fn transform_labels(plan: &LogicalPlan) -> Vec<String> {
        plan.ops()
            .iter()
            .filter(|o| matches!(o, LogicalOp::Transform { .. }))
            .map(|o| o.label())
            .collect()
    }

    #[test]
    fn projection_folds_into_ingest() {
        let plan = LogicalPlan::scan(vec![], &["title", "abstract", "doi"])
            .project(&["title", "abstract"])
            .collect()
            .optimize();
        assert_eq!(plan.ops().len(), 2);
        assert_eq!(plan.ops()[0].label(), "Ingest [0 files] project=[title, abstract]");
    }

    #[test]
    fn null_drop_hoists_ahead_of_string_rewrites() {
        let plan = LogicalPlan::scan(vec![], &["t"])
            .transform(ConvertToLower::new("t"))
            .transform(RemoveHtmlTags::new("t"))
            .drop_nulls(&["t"])
            .collect()
            .optimize();
        // DropNulls must now sit directly after Ingest, and the two
        // rewrites must have fused behind it.
        assert_eq!(plan.ops()[1].label(), "DropNulls [t]");
        assert!(plan.ops()[2].label().contains("FusedStringStage"), "{}", plan.render());
    }

    #[test]
    fn null_drop_does_not_cross_tokenizer() {
        let plan = LogicalPlan::scan(vec![], &["t"])
            .transform(Tokenizer::new("t", "w"))
            .drop_nulls(&["w"])
            .collect()
            .optimize();
        assert_eq!(plan.ops()[1].label(), "Transform Tokenizer(t -> w)");
        assert_eq!(plan.ops()[2].label(), "DropNulls [w]");
    }

    #[test]
    fn case_study_fuses_to_one_stage_per_column() {
        let plan = case_study_plan(&[], "title", "abstract").optimize();
        let transforms = transform_labels(&plan);
        assert_eq!(transforms.len(), 2, "{}", plan.render());
        assert!(transforms[0].contains("FusedStringStage(title <- lower|html|chars)"));
        assert!(transforms[1]
            .contains("FusedStringStage(abstract <- lower|html|chars|stopwords|short-words(<=1))"));
        // 13 logical ops collapse to 7: Ingest, DropNulls, Distinct,
        // 2 fused transforms, DropEmpty, Collect.
        assert_eq!(plan.ops().len(), 7);
    }

    #[test]
    fn short_words_after_tokenizer_is_not_fused() {
        // On a token column the RemoveShortWords token path must be kept
        // — dtype tracking forbids fusion even though a kernel exists.
        let plan = LogicalPlan::scan(vec![], &["t"])
            .transform(Tokenizer::new("t", "t"))
            .transform(RemoveShortWords::new("t", 1))
            .collect()
            .optimize();
        let transforms = transform_labels(&plan);
        assert_eq!(transforms.len(), 2, "{}", plan.render());
        assert!(transforms[1].contains("RemoveShortWords"));
    }

    #[test]
    fn lone_fusable_stage_keeps_its_name() {
        let plan = LogicalPlan::scan(vec![], &["t"])
            .transform(ConvertToLower::new("t"))
            .collect()
            .optimize();
        assert_eq!(plan.ops()[1].label(), "Transform ConvertToLower(t)");
    }

    #[test]
    fn optimize_is_idempotent_on_the_case_study() {
        let once = case_study_plan(&[], "title", "abstract").optimize();
        let twice = once.clone().optimize();
        assert_eq!(once.render(), twice.render());
    }
}
