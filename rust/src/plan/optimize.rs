//! Rule-based logical-plan optimizer — the Catalyst analog, sized to our
//! three rules:
//!
//! 1. **Projection pushdown** — a `Project` directly after `Ingest`
//!    narrows the scan's field list, so dropped fields are skipped at
//!    JSON-lexer speed instead of parsed and thrown away.
//! 2. **Null-drop pushdown** — `DropNulls` hoists ahead of any
//!    null-preserving same-column string rewrite, so rows that are going
//!    to be dropped are never cleaned.
//! 3. **Sample/Limit pushdown** — `Sample` and `Limit` hoist ahead of
//!    row-preserving `Transform` stages (a 1:1 map keeps the same rows
//!    on either side of a positional sample or a prefix limit), so rows
//!    the sample skips or the limit cuts are never cleaned. They never
//!    cross filters, `Distinct`, `Fit` (the fit input would change), or
//!    each other.
//! 4. **String-stage fusion** — adjacent same-column `string -> string`
//!    stages collapse into one [`FusedStringStage`] whose kernel chain
//!    sweeps the partition once (whole-stage codegen, scaled down).
//!
//! Rules run in that order; each is a pure `Vec<LogicalOp>` rewrite.

use super::fused::FusedStringStage;
use super::logical::{LogicalOp, LogicalPlan};
use crate::frame::DType;
use crate::pipeline::stages::StringKernel;
use crate::pipeline::Transformer;
use std::collections::HashMap;
use std::sync::Arc;

/// Apply all rules to `plan`.
pub fn optimize(plan: LogicalPlan) -> LogicalPlan {
    let ops = push_projection(plan.ops);
    let ops = push_null_drop(ops);
    let ops = push_sample_limit(ops);
    let ops = fuse_string_stages(ops);
    LogicalPlan { ops }
}

/// Rule 1: fold `Project` into a directly preceding `Ingest` when it
/// only narrows the scan's field list.
fn push_projection(ops: Vec<LogicalOp>) -> Vec<LogicalOp> {
    let mut out: Vec<LogicalOp> = Vec::with_capacity(ops.len());
    for op in ops {
        if let LogicalOp::Project { cols } = &op {
            if let Some(LogicalOp::Ingest { fields, .. }) = out.last_mut() {
                if cols.iter().all(|c| fields.contains(c)) {
                    *fields = cols.clone();
                    continue;
                }
            }
        }
        out.push(op);
    }
    out
}

/// Rule 2: bubble every `DropNulls` leftwards over null-preserving
/// same-column string rewrites (a stage with a [`StringKernel`] maps
/// null -> null and never *creates* a null, so the filtered row set is
/// identical on either side — but dropped rows skip the rewrite).
fn push_null_drop(mut ops: Vec<LogicalOp>) -> Vec<LogicalOp> {
    for i in 1..ops.len() {
        if matches!(ops[i], LogicalOp::DropNulls { .. }) {
            let mut j = i;
            while j > 0 && hoistable(&ops[j - 1]) {
                ops.swap(j - 1, j);
                j -= 1;
            }
        }
    }
    ops
}

fn hoistable(op: &LogicalOp) -> bool {
    match op {
        LogicalOp::Transform { stage } => {
            stage.string_kernel().is_some() && stage.input_col() == stage.output_col()
        }
        _ => false,
    }
}

/// Rule 3: bubble `Sample` and `Limit` leftwards over `Transform` ops.
/// A transform is a 1:1 row map, so the rows a positional sample keeps
/// (or a prefix limit admits) are identical on either side — but hoisted
/// ahead, the skipped rows are never transformed. Everything else is a
/// barrier: crossing a filter would change which rows the sample/limit
/// indexes, crossing a `Fit` would change the fit input, and crossing
/// each other would reorder their (non-commutative) composition.
fn push_sample_limit(mut ops: Vec<LogicalOp>) -> Vec<LogicalOp> {
    for i in 1..ops.len() {
        if matches!(ops[i], LogicalOp::Sample { .. } | LogicalOp::Limit { .. }) {
            let mut j = i;
            while j > 0 && matches!(ops[j - 1], LogicalOp::Transform { .. }) {
                ops.swap(j - 1, j);
                j -= 1;
            }
        }
    }
    ops
}

/// Rule 3: collapse runs of adjacent fusable stages on the same string
/// column into one [`FusedStringStage`]. Column dtypes are tracked
/// through the plan so a stage whose input has become `array<string>`
/// (e.g. `RemoveShortWords` after a `Tokenizer`) is never fused.
fn fuse_string_stages(ops: Vec<LogicalOp>) -> Vec<LogicalOp> {
    let mut dtypes: HashMap<String, DType> = HashMap::new();
    let mut out: Vec<LogicalOp> = Vec::with_capacity(ops.len());
    let mut run: Vec<(Arc<dyn Transformer>, StringKernel)> = Vec::new();
    let mut run_col: Option<String> = None;

    fn flush(
        out: &mut Vec<LogicalOp>,
        run: &mut Vec<(Arc<dyn Transformer>, StringKernel)>,
        run_col: &mut Option<String>,
    ) {
        let Some(col) = run_col.take() else { return };
        if run.len() == 1 {
            // A lone fusable stage gains nothing from fusion — emit the
            // original stage so EXPLAIN keeps its real name.
            let (stage, _) = run.pop().unwrap();
            out.push(LogicalOp::Transform { stage });
        } else if !run.is_empty() {
            let kernels: Vec<StringKernel> = run.drain(..).map(|(_, k)| k).collect();
            out.push(LogicalOp::Transform {
                stage: Arc::new(FusedStringStage::new(col, kernels)),
            });
        }
    }

    for op in ops {
        match op {
            LogicalOp::Ingest { files, fields } => {
                for f in &fields {
                    dtypes.insert(f.clone(), DType::Str);
                }
                flush(&mut out, &mut run, &mut run_col);
                out.push(LogicalOp::Ingest { files, fields });
            }
            LogicalOp::Transform { stage } => {
                let in_dtype =
                    dtypes.get(stage.input_col()).copied().unwrap_or(DType::Str);
                let kernel = stage.string_kernel();
                let fusable = kernel.is_some()
                    && stage.input_col() == stage.output_col()
                    && in_dtype == DType::Str;
                if fusable {
                    if run_col.as_deref() != Some(stage.input_col()) {
                        flush(&mut out, &mut run, &mut run_col);
                        run_col = Some(stage.input_col().to_string());
                    }
                    let k = kernel.unwrap();
                    run.push((stage, k));
                } else {
                    flush(&mut out, &mut run, &mut run_col);
                    dtypes.insert(
                        stage.output_col().to_string(),
                        stage.output_dtype(in_dtype),
                    );
                    out.push(LogicalOp::Transform { stage });
                }
            }
            LogicalOp::Fit { est } => {
                // An estimator is a fusion barrier and, like a
                // transform, may retype (or create) its output column.
                flush(&mut out, &mut run, &mut run_col);
                let in_dtype = dtypes.get(est.input_col()).copied().unwrap_or(DType::Str);
                dtypes.insert(est.output_col().to_string(), est.output_dtype(in_dtype));
                out.push(LogicalOp::Fit { est });
            }
            other => {
                // Filters, dedup, sample/limit, project and collect are
                // fusion barriers: a filter between two rewrites changes
                // which rows the second rewrite sees.
                flush(&mut out, &mut run, &mut run_col);
                out.push(other);
            }
        }
    }
    flush(&mut out, &mut run, &mut run_col);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::presets::case_study_plan;
    use crate::pipeline::stages::{ConvertToLower, RemoveHtmlTags, RemoveShortWords, Tokenizer};

    fn transform_labels(plan: &LogicalPlan) -> Vec<String> {
        plan.ops()
            .iter()
            .filter(|o| matches!(o, LogicalOp::Transform { .. }))
            .map(|o| o.label())
            .collect()
    }

    #[test]
    fn projection_folds_into_ingest() {
        let plan = LogicalPlan::scan(vec![], &["title", "abstract", "doi"])
            .project(&["title", "abstract"])
            .collect()
            .optimize();
        assert_eq!(plan.ops().len(), 2);
        assert_eq!(plan.ops()[0].label(), "Ingest [0 files] project=[title, abstract]");
    }

    #[test]
    fn null_drop_hoists_ahead_of_string_rewrites() {
        let plan = LogicalPlan::scan(vec![], &["t"])
            .transform(ConvertToLower::new("t"))
            .transform(RemoveHtmlTags::new("t"))
            .drop_nulls(&["t"])
            .collect()
            .optimize();
        // DropNulls must now sit directly after Ingest, and the two
        // rewrites must have fused behind it.
        assert_eq!(plan.ops()[1].label(), "DropNulls [t]");
        assert!(plan.ops()[2].label().contains("FusedStringStage"), "{}", plan.render());
    }

    #[test]
    fn null_drop_does_not_cross_tokenizer() {
        let plan = LogicalPlan::scan(vec![], &["t"])
            .transform(Tokenizer::new("t", "w"))
            .drop_nulls(&["w"])
            .collect()
            .optimize();
        assert_eq!(plan.ops()[1].label(), "Transform Tokenizer(t -> w)");
        assert_eq!(plan.ops()[2].label(), "DropNulls [w]");
    }

    #[test]
    fn case_study_fuses_to_one_stage_per_column() {
        let plan = case_study_plan(&[], "title", "abstract").optimize();
        let transforms = transform_labels(&plan);
        assert_eq!(transforms.len(), 2, "{}", plan.render());
        assert!(transforms[0].contains("FusedStringStage(title <- lower|html|chars)"));
        assert!(transforms[1]
            .contains("FusedStringStage(abstract <- lower|html|chars|stopwords|short-words(<=1))"));
        // 13 logical ops collapse to 7: Ingest, DropNulls, Distinct,
        // 2 fused transforms, DropEmpty, Collect.
        assert_eq!(plan.ops().len(), 7);
    }

    #[test]
    fn short_words_after_tokenizer_is_not_fused() {
        // On a token column the RemoveShortWords token path must be kept
        // — dtype tracking forbids fusion even though a kernel exists.
        let plan = LogicalPlan::scan(vec![], &["t"])
            .transform(Tokenizer::new("t", "t"))
            .transform(RemoveShortWords::new("t", 1))
            .collect()
            .optimize();
        let transforms = transform_labels(&plan);
        assert_eq!(transforms.len(), 2, "{}", plan.render());
        assert!(transforms[1].contains("RemoveShortWords"));
    }

    #[test]
    fn lone_fusable_stage_keeps_its_name() {
        let plan = LogicalPlan::scan(vec![], &["t"])
            .transform(ConvertToLower::new("t"))
            .collect()
            .optimize();
        assert_eq!(plan.ops()[1].label(), "Transform ConvertToLower(t)");
    }

    #[test]
    fn optimize_is_idempotent_on_the_case_study() {
        let once = case_study_plan(&[], "title", "abstract").optimize();
        let twice = once.clone().optimize();
        assert_eq!(once.render(), twice.render());
    }

    #[test]
    fn sample_and_limit_hoist_ahead_of_transforms_only() {
        let plan = LogicalPlan::scan(vec![], &["t"])
            .drop_nulls(&["t"])
            .transform(ConvertToLower::new("t"))
            .transform(RemoveHtmlTags::new("t"))
            .sample(0.5, 9)
            .limit(10)
            .collect()
            .optimize();
        let labels: Vec<String> = plan.ops().iter().map(|o| o.label()).collect();
        // Both hoisted past the (now fused) rewrites, stopping at the
        // filter; their relative order is preserved.
        assert_eq!(labels[1], "DropNulls [t]", "{}", plan.render());
        assert_eq!(labels[2], "Sample [fraction=0.5, seed=9]", "{}", plan.render());
        assert_eq!(labels[3], "Limit [10]", "{}", plan.render());
        assert!(labels[4].contains("FusedStringStage"), "{}", plan.render());
    }

    #[test]
    fn sample_does_not_cross_distinct_or_fit() {
        use crate::pipeline::features::{HashingTF, Idf};
        use crate::pipeline::stages::Tokenizer;
        let plan = LogicalPlan::scan(vec![], &["t"])
            .distinct(&["t"])
            .sample(0.5, 1)
            .transform(Tokenizer::new("t", "w"))
            .transform(HashingTF::new("w", "tf", 16))
            .fit(Idf::new("tf", "tfidf"))
            .limit(5)
            .collect()
            .optimize();
        let labels: Vec<String> = plan.ops().iter().map(|o| o.label()).collect();
        assert_eq!(labels[1], "Distinct [t]", "{}", plan.render());
        assert_eq!(labels[2], "Sample [fraction=0.5, seed=1]", "{}", plan.render());
        // Limit hoists over nothing here: Fit is a barrier.
        assert!(labels[5].starts_with("Fit IDF"), "{}", plan.render());
        assert_eq!(labels[6], "Limit [5]", "{}", plan.render());
    }

    #[test]
    fn fusion_resumes_after_a_fit_barrier() {
        use crate::pipeline::features::Idf;
        // A Fit between two fusable rewrites must keep them apart.
        let plan = LogicalPlan::scan(vec![], &["t"])
            .transform(ConvertToLower::new("t"))
            .fit(Idf::new("t", "v"))
            .transform(RemoveHtmlTags::new("t"))
            .collect()
            .optimize();
        let labels: Vec<String> = plan.ops().iter().map(|o| o.label()).collect();
        assert_eq!(labels[1], "Transform ConvertToLower(t)", "{}", plan.render());
        assert!(labels[2].starts_with("Fit IDF"), "{}", plan.render());
        assert_eq!(labels[3], "Transform RemoveHTMLTags(t)", "{}", plan.render());
    }
}
