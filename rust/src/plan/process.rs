//! Multi-process sharded execution: distribute a lowered
//! [`PhysicalPlan`] across worker **OS processes** — the Spark-executor
//! analog of this crate's plan layer, completing the progression
//! single-pass → streaming → multi-process.
//!
//! ```text
//! driver                                   worker processes (self-exec)
//! serialize op program + shard     P3PJ    `repro plan-worker` reads the
//! assignment, spawn N workers   ───────►   job from stdin, runs parse +
//!                                          op-program per assigned shard
//! fold result frames in shard      P3PW
//! order through the shared      ◄───────   writes partitions + dedup
//! Admitter/Merger                          KeySlot provenance to stdout
//! ```
//!
//! The wire format reuses the `P3PC` artifact conventions
//! ([`crate::cache::artifact`]): little-endian integers, a magic +
//! version header, and a trailing xxh64 digest, so truncation and
//! corruption are detected before any payload is trusted. The envelope
//! discipline itself (magic constants, digest check, path/string
//! helpers) lives in [`crate::serve::proto`] — one implementation
//! shared with the serve daemon, which speaks the same `P3PJ`/`P3PW`
//! frames over its Unix socket. A worker that
//! exits nonzero, dies on a signal, or returns a garbled frame becomes a
//! **driver error naming the worker** — never a hang (each worker's
//! stdout is drained to EOF and the child is always reaped) and never a
//! silent partial result (the driver checks that every assigned shard
//! came back exactly once).
//!
//! Since wire v2 every job frame carries a trace flag and every reply
//! frame ends with a span section (count 0 when untraced): a traced
//! worker installs a fresh [`crate::obs::TraceSink`] per job and ships
//! its spans home, where [`crate::obs::record_remote`] re-anchors them
//! onto the driver timeline inside that worker's `rpc` span.
//!
//! Workers are spawned by re-executing the current binary with the
//! hidden `plan-worker` CLI mode ([`worker_main`]); tests and benches
//! point [`ProcessOptions::worker_cmd`] (or `P3SAPP_WORKER_CMD`) at the
//! built `repro` binary, since their own harness executable has no
//! worker mode.
//!
//! Output is **byte-identical** to the fused single pass and the
//! streaming executor: workers run the exact same per-shard program
//! (`PhysicalPlan::run_partition`) and the driver folds their results
//! through the exact same ordered `Merger`
//! (`rust/tests/plan_equivalence.rs`, `rust/tests/process_executor.rs`).
//!
//! Estimator plans fit in a first process pass: when the pre-estimator
//! program carries no `Distinct`/`Limit` (driver-side admission is the
//! identity), each worker folds its shards into its own
//! [`FitAccumulator`](crate::pipeline::FitAccumulator) and ships only
//! the accumulated state (document frequencies for `IDF`) — a
//! Spark-style partial aggregate the driver merges before broadcasting
//! the fitted model inside the pass-2 job. With dedup/limit pending,
//! workers ship admitted partitions instead and the driver folds them
//! through the shared `Admitter`, exactly like the streaming fit pass.

use super::physical::{KeySlot, Merger, PartResult, PartitionOp, Phases, PhysicalPlan, PlanOutput};
use crate::cache::artifact::{decode_cells, dtype_code, dtype_from, encode_cells, Cursor};
use crate::frame::{Partition, Schema};
use crate::obs;
use crate::pipeline::features::{HashingTF, Idf, IdfModel, NGram};
use crate::pipeline::stages::{
    ConvertToLower, RemoveHtmlTags, RemoveShortWords, RemoveUnwantedCharacters, StopWordsRemover,
    StopWordsRemoverStr, StringKernel, Tokenizer,
};
use crate::pipeline::{Estimator, Transformer};
use crate::serve::proto::{
    begin_frame, check_frame, read_frame, read_path, seal_frame, write_frame, write_path,
    write_str, JOB_MAGIC, MODE_FIT, MODE_MAP, REPLY_MAGIC, WIRE_VERSION,
};
use crate::Result;
use anyhow::Context as _;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Tuning knobs for the multi-process executor.
#[derive(Debug, Clone, Default)]
pub struct ProcessOptions {
    /// Worker process count (0 = one per logical core). Always clamped
    /// to the shard count; fewer than two resolved workers delegate to
    /// the in-process single pass (same bytes, none of the spawn cost).
    pub processes: usize,
    /// Worker executable. `None` resolves `P3SAPP_WORKER_CMD` from the
    /// environment, then the current executable (the normal case: the
    /// `repro` binary self-execs its hidden `plan-worker` mode). Test
    /// and bench harnesses must point this at the built `repro` binary.
    pub worker_cmd: Option<PathBuf>,
    /// Warm worker pool to run jobs through instead of spawning fresh
    /// processes per pass (the serve daemon's amortization lever).
    /// `None` — the default everywhere except `serve` — keeps the
    /// spawn-per-pass behavior exactly. When set, `worker_cmd` is
    /// ignored: the pool's own command governs, and the resolved worker
    /// count is additionally clamped to the pool size.
    pub pool: Option<Arc<WorkerPool>>,
}

impl ProcessOptions {
    /// Resolve the worker-process count against a concrete shard count.
    pub fn resolve(&self, n_files: usize) -> usize {
        let procs = if self.processes == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2)
        } else {
            self.processes
        };
        let procs = match &self.pool {
            Some(pool) => procs.min(pool.size()),
            None => procs,
        };
        procs.min(n_files)
    }

    /// The executable to spawn as `<cmd> plan-worker`.
    fn worker_command(&self) -> Result<PathBuf> {
        if let Some(pool) = &self.pool {
            return Ok(pool.cmd().to_path_buf());
        }
        if let Some(cmd) = &self.worker_cmd {
            return Ok(cmd.clone());
        }
        if let Ok(env) = std::env::var("P3SAPP_WORKER_CMD") {
            if !env.is_empty() {
                return Ok(PathBuf::from(env));
            }
        }
        std::env::current_exe().map_err(|e| anyhow::anyhow!("cannot resolve worker binary: {e}"))
    }

    /// Ship each job to its worker — through the warm pool when one is
    /// configured, else spawn-per-job — returning, in job order, each
    /// worker's RPC anchor (driver-epoch nanos captured just before the
    /// job was sent; 0 when tracing is off) and its raw reply frame.
    /// The anchor is what clock-aligns the worker's shipped spans into
    /// the driver timeline ([`obs::record_remote`]).
    fn ship(&self, jobs: &[Vec<u8>]) -> Result<Vec<(u64, Vec<u8>)>> {
        match &self.pool {
            Some(pool) => run_workers_pooled(pool, jobs),
            None => {
                let cmd = self.worker_command()?;
                run_workers(&cmd, jobs)
            }
        }
    }
}

/// A pool of persistent `plan-worker --persist` processes, kept warm
/// across passes by the serve daemon. Each slot owns (at most) one
/// lazily spawned child; jobs ship as length-prefixed `P3PJ` frames on
/// the child's stdin and replies return as length-prefixed `P3PW`
/// frames on its stdout, one exchange at a time per slot.
///
/// Failure posture matches the spawn-per-job path: a worker that dies,
/// closes its pipe early, or returns a garbled frame becomes a driver
/// error naming the slot, and the dead child is reaped immediately —
/// the slot respawns lazily on its next job, so one failed job never
/// poisons the pool. A failed job also kills its persistent worker on
/// the worker side (it exits nonzero rather than trying to resync the
/// stream), which is what makes "error, then respawn" the whole
/// recovery story.
#[derive(Debug)]
pub struct WorkerPool {
    cmd: PathBuf,
    slots: Vec<Mutex<Option<PooledWorker>>>,
}

#[derive(Debug)]
struct PooledWorker {
    child: Child,
    stdin: ChildStdin,
    stdout: ChildStdout,
}

impl WorkerPool {
    /// A pool of `size` slots (clamped to ≥ 1) running `cmd plan-worker
    /// --persist`. No process is spawned until a slot gets its first
    /// job.
    pub fn new(cmd: impl Into<PathBuf>, size: usize) -> WorkerPool {
        WorkerPool {
            cmd: cmd.into(),
            slots: (0..size.max(1)).map(|_| Mutex::new(None)).collect(),
        }
    }

    pub fn size(&self) -> usize {
        self.slots.len()
    }

    pub fn cmd(&self) -> &Path {
        &self.cmd
    }

    /// PIDs of the currently live workers (lazily spawned slots that
    /// have not run a job yet are absent). The serve `stats` reply and
    /// the no-orphans shutdown test read this.
    pub fn pids(&self) -> Vec<u32> {
        self.slots
            .iter()
            .filter_map(|slot| {
                slot.lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .as_ref()
                    .map(|w| w.child.id())
            })
            .collect()
    }

    fn spawn_worker(&self, slot: usize) -> Result<PooledWorker> {
        let mut child = Command::new(&self.cmd)
            .args(["plan-worker", "--persist"])
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            // Stderr passes through to the daemon's own stderr: a
            // persistent worker's diagnostics belong in the daemon log,
            // and per-job capture would need a drain thread per slot
            // for the lifetime of the pool.
            .stderr(Stdio::inherit())
            .spawn()
            .map_err(|e| {
                anyhow::anyhow!("pooled plan worker {slot}: spawn {}: {e}", self.cmd.display())
            })?;
        let stdin = child.stdin.take().expect("piped stdin");
        let stdout = child.stdout.take().expect("piped stdout");
        Ok(PooledWorker { child, stdin, stdout })
    }

    /// Run one job frame through `slot`'s persistent worker: lazily
    /// spawn it, write the length-prefixed job, read the
    /// length-prefixed reply. On any error the slot's worker is killed
    /// and reaped before the error propagates, leaving the slot empty
    /// for a lazy respawn.
    fn exchange(&self, slot: usize, job: &[u8]) -> Result<Vec<u8>> {
        let mut guard = self.slots[slot].lock().unwrap_or_else(|p| p.into_inner());
        if guard.is_none() {
            *guard = Some(self.spawn_worker(slot)?);
        }
        let worker = guard.as_mut().expect("just spawned");
        let result = (|| -> Result<Vec<u8>> {
            write_frame(&mut worker.stdin, job)
                .map_err(|e| anyhow::anyhow!("shipping job: {e}"))?;
            match read_frame(&mut worker.stdout)? {
                Some(reply) => Ok(reply),
                None => anyhow::bail!("worker closed its pipe without a reply"),
            }
        })();
        if result.is_err() {
            // The stream is out of sync (or the worker is dead): reap
            // it now so the slot can respawn clean.
            if let Some(mut dead) = guard.take() {
                let _ = dead.child.kill();
                let _ = dead.child.wait();
            }
        }
        result.map_err(|e| {
            anyhow::anyhow!("pooled plan worker {slot} ({}): {e:#}", self.cmd.display())
        })
    }
}

impl Drop for WorkerPool {
    /// Reap every live worker: close its stdin (the persistent loop
    /// sees job EOF and exits cleanly), give it a short grace window,
    /// then kill. Always waits, so no zombie survives the pool.
    fn drop(&mut self) {
        for slot in &self.slots {
            let mut guard = slot.lock().unwrap_or_else(|p| p.into_inner());
            let Some(worker) = guard.take() else { continue };
            let PooledWorker { mut child, stdin, stdout } = worker;
            drop(stdin);
            drop(stdout);
            let mut exited = false;
            for _ in 0..200 {
                match child.try_wait() {
                    Ok(Some(_)) => {
                        exited = true;
                        break;
                    }
                    Ok(None) => std::thread::sleep(Duration::from_millis(10)),
                    Err(_) => break,
                }
            }
            if !exited {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
    }
}

/// Serializable description of one transformer stage — what crosses the
/// process boundary in place of an `Arc<dyn Transformer>`. Stages map to
/// specs via [`Transformer::wire_spec`]; a worker rebuilds the concrete
/// stage with [`WireStage::build`]. Crate-internal: the wire format is
/// an implementation detail of the process/remote executors, and
/// framing enters through [`crate::serve::proto`] only.
#[derive(Debug, Clone)]
pub(crate) enum WireStage {
    /// A fused chain of string kernels (`FusedStringStage`).
    Fused { col: String, kernels: Vec<StringKernel> },
    Lower { col: String },
    Html { col: String },
    Unwanted { col: String },
    ShortWords { col: String, threshold: usize },
    StopwordsStr { col: String },
    Tokenizer { input: String, output: String },
    StopwordsTokens { input: String, output: String },
    NGram { input: String, output: String, n: usize },
    HashingTF { input: String, output: String, num_features: usize },
    /// A fitted IDF model: the driver broadcasts the fitted weights
    /// inside the pass-2 job.
    IdfModel { input: String, output: String, idf: Vec<f32> },
}

impl WireStage {
    /// Rebuild the concrete transformer this spec describes.
    pub(crate) fn build(self) -> Arc<dyn Transformer> {
        match self {
            WireStage::Fused { col, kernels } => {
                Arc::new(super::fused::FusedStringStage::new(col, kernels))
            }
            WireStage::Lower { col } => Arc::new(ConvertToLower::new(col)),
            WireStage::Html { col } => Arc::new(RemoveHtmlTags::new(col)),
            WireStage::Unwanted { col } => Arc::new(RemoveUnwantedCharacters::new(col)),
            WireStage::ShortWords { col, threshold } => {
                Arc::new(RemoveShortWords::new(col, threshold))
            }
            WireStage::StopwordsStr { col } => Arc::new(StopWordsRemoverStr::new(col)),
            WireStage::Tokenizer { input, output } => Arc::new(Tokenizer::new(input, output)),
            WireStage::StopwordsTokens { input, output } => {
                Arc::new(StopWordsRemover::new(input, output))
            }
            WireStage::NGram { input, output, n } => Arc::new(NGram::new(input, output, n)),
            WireStage::HashingTF { input, output, num_features } => {
                Arc::new(HashingTF::new(input, output, num_features))
            }
            WireStage::IdfModel { input, output, idf } => {
                Arc::new(IdfModel::new(input, output, idf))
            }
        }
    }

    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            WireStage::Fused { col, kernels } => {
                buf.push(0);
                write_str(buf, col);
                buf.extend_from_slice(&(kernels.len() as u32).to_le_bytes());
                for k in kernels {
                    match *k {
                        StringKernel::Lower => buf.push(0),
                        StringKernel::StripHtml => buf.push(1),
                        StringKernel::RemoveUnwanted => buf.push(2),
                        StringKernel::RemoveStopwords => buf.push(3),
                        StringKernel::RemoveShortWords(th) => {
                            buf.push(4);
                            buf.extend_from_slice(&(th as u64).to_le_bytes());
                        }
                    }
                }
            }
            WireStage::Lower { col } => {
                buf.push(1);
                write_str(buf, col);
            }
            WireStage::Html { col } => {
                buf.push(2);
                write_str(buf, col);
            }
            WireStage::Unwanted { col } => {
                buf.push(3);
                write_str(buf, col);
            }
            WireStage::ShortWords { col, threshold } => {
                buf.push(4);
                write_str(buf, col);
                buf.extend_from_slice(&(*threshold as u64).to_le_bytes());
            }
            WireStage::StopwordsStr { col } => {
                buf.push(5);
                write_str(buf, col);
            }
            WireStage::Tokenizer { input, output } => {
                buf.push(6);
                write_str(buf, input);
                write_str(buf, output);
            }
            WireStage::StopwordsTokens { input, output } => {
                buf.push(7);
                write_str(buf, input);
                write_str(buf, output);
            }
            WireStage::NGram { input, output, n } => {
                buf.push(8);
                write_str(buf, input);
                write_str(buf, output);
                buf.extend_from_slice(&(*n as u64).to_le_bytes());
            }
            WireStage::HashingTF { input, output, num_features } => {
                buf.push(9);
                write_str(buf, input);
                write_str(buf, output);
                buf.extend_from_slice(&(*num_features as u64).to_le_bytes());
            }
            WireStage::IdfModel { input, output, idf } => {
                buf.push(10);
                write_str(buf, input);
                write_str(buf, output);
                buf.extend_from_slice(&(idf.len() as u32).to_le_bytes());
                for x in idf {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }

    fn decode(cur: &mut Cursor<'_>) -> Result<WireStage> {
        Ok(match cur.u8()? {
            0 => {
                let col = cur.str()?;
                let n = cur.u32()? as usize;
                anyhow::ensure!(n >= 1, "fused stage spec with no kernels");
                anyhow::ensure!(n <= cur.remaining(), "fused stage declares {n} kernels");
                let mut kernels = Vec::with_capacity(n);
                for _ in 0..n {
                    kernels.push(match cur.u8()? {
                        0 => StringKernel::Lower,
                        1 => StringKernel::StripHtml,
                        2 => StringKernel::RemoveUnwanted,
                        3 => StringKernel::RemoveStopwords,
                        4 => StringKernel::RemoveShortWords(cur.u64()? as usize),
                        other => anyhow::bail!("unknown string-kernel code {other}"),
                    });
                }
                WireStage::Fused { col, kernels }
            }
            1 => WireStage::Lower { col: cur.str()? },
            2 => WireStage::Html { col: cur.str()? },
            3 => WireStage::Unwanted { col: cur.str()? },
            4 => WireStage::ShortWords { col: cur.str()?, threshold: cur.u64()? as usize },
            5 => WireStage::StopwordsStr { col: cur.str()? },
            6 => WireStage::Tokenizer { input: cur.str()?, output: cur.str()? },
            7 => WireStage::StopwordsTokens { input: cur.str()?, output: cur.str()? },
            8 => {
                let (input, output, n) = (cur.str()?, cur.str()?, cur.u64()? as usize);
                anyhow::ensure!(n >= 1, "NGram spec with n=0");
                WireStage::NGram { input, output, n }
            }
            9 => {
                let (input, output, nf) = (cur.str()?, cur.str()?, cur.u64()? as usize);
                anyhow::ensure!(nf >= 1, "HashingTF spec with zero buckets");
                WireStage::HashingTF { input, output, num_features: nf }
            }
            10 => {
                let (input, output) = (cur.str()?, cur.str()?);
                let n = cur.u32()? as usize;
                anyhow::ensure!(
                    n.saturating_mul(4) <= cur.remaining(),
                    "IDF model spec declares {n} weights"
                );
                let mut idf = Vec::with_capacity(n);
                for _ in 0..n {
                    idf.push(f32::from_le_bytes(cur.take(4)?.try_into().unwrap()));
                }
                WireStage::IdfModel { input, output, idf }
            }
            other => anyhow::bail!("unknown stage spec code {other}"),
        })
    }
}

/// Serializable description of one estimator, for the partial-aggregate
/// fit pass. Maps via [`Estimator::wire_spec`].
#[derive(Debug, Clone)]
pub(crate) enum WireEstimator {
    Idf { input: String, output: String, min_doc_freq: usize },
}

impl WireEstimator {
    /// Rebuild the concrete estimator this spec describes.
    pub(crate) fn build(self) -> Box<dyn Estimator> {
        match self {
            WireEstimator::Idf { input, output, min_doc_freq } => {
                Box::new(Idf::new(input, output).with_min_doc_freq(min_doc_freq))
            }
        }
    }

    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            WireEstimator::Idf { input, output, min_doc_freq } => {
                buf.push(0);
                write_str(buf, input);
                write_str(buf, output);
                buf.extend_from_slice(&(*min_doc_freq as u64).to_le_bytes());
            }
        }
    }

    fn decode(cur: &mut Cursor<'_>) -> Result<WireEstimator> {
        Ok(match cur.u8()? {
            0 => WireEstimator::Idf {
                input: cur.str()?,
                output: cur.str()?,
                min_doc_freq: cur.u64()? as usize,
            },
            other => anyhow::bail!("unknown estimator spec code {other}"),
        })
    }
}

fn write_idxs(buf: &mut Vec<u8>, idxs: &[usize]) {
    buf.extend_from_slice(&(idxs.len() as u32).to_le_bytes());
    for &i in idxs {
        buf.extend_from_slice(&(i as u32).to_le_bytes());
    }
}

fn read_idxs(cur: &mut Cursor<'_>) -> Result<Vec<usize>> {
    let n = cur.u32()? as usize;
    anyhow::ensure!(n.saturating_mul(4) <= cur.remaining(), "index list declares {n} entries");
    (0..n).map(|_| Ok(cur.u32()? as usize)).collect()
}

/// Serialize the per-partition op program. Fails on stages without a
/// [`Transformer::wire_spec`] — those cannot cross a process boundary.
fn encode_ops(buf: &mut Vec<u8>, ops: &[PartitionOp]) -> Result<()> {
    buf.extend_from_slice(&(ops.len() as u32).to_le_bytes());
    for op in ops {
        match op {
            PartitionOp::NullFilter { idxs } => {
                buf.push(0);
                write_idxs(buf, idxs);
            }
            PartitionOp::HashKeys { slot, idxs } => {
                buf.push(1);
                buf.extend_from_slice(&(*slot as u32).to_le_bytes());
                write_idxs(buf, idxs);
            }
            PartitionOp::SampleFilter { fraction, seed } => {
                buf.push(2);
                buf.extend_from_slice(&fraction.to_le_bytes());
                buf.extend_from_slice(&seed.to_le_bytes());
            }
            PartitionOp::LimitCap { n } => {
                buf.push(3);
                buf.extend_from_slice(&(*n as u64).to_le_bytes());
            }
            PartitionOp::Stage { stage, in_idx, out_idx } => {
                buf.push(4);
                buf.extend_from_slice(&(*in_idx as u32).to_le_bytes());
                buf.extend_from_slice(&(*out_idx as u32).to_le_bytes());
                let spec = stage.wire_spec().ok_or_else(|| {
                    anyhow::anyhow!(
                        "stage {} cannot be serialized for multi-process execution \
                         (no wire spec); run this plan with the in-process executors",
                        stage.describe()
                    )
                })?;
                spec.encode(buf);
            }
            PartitionOp::EmptyFilter { idxs } => {
                buf.push(5);
                write_idxs(buf, idxs);
            }
        }
    }
    Ok(())
}

fn decode_ops(cur: &mut Cursor<'_>) -> Result<Vec<PartitionOp>> {
    let n = cur.u32()? as usize;
    anyhow::ensure!(n <= cur.remaining(), "op program declares {n} ops");
    let mut ops = Vec::with_capacity(n);
    for _ in 0..n {
        ops.push(match cur.u8()? {
            0 => PartitionOp::NullFilter { idxs: read_idxs(cur)? },
            1 => PartitionOp::HashKeys { slot: cur.u32()? as usize, idxs: read_idxs(cur)? },
            2 => PartitionOp::SampleFilter { fraction: cur.f64()?, seed: cur.u64()? },
            3 => PartitionOp::LimitCap { n: cur.u64()? as usize },
            4 => {
                let in_idx = cur.u32()? as usize;
                let out_idx = cur.u32()? as usize;
                let stage = WireStage::decode(cur)?.build();
                PartitionOp::Stage { stage, in_idx, out_idx }
            }
            5 => PartitionOp::EmptyFilter { idxs: read_idxs(cur)? },
            other => anyhow::bail!("unknown op code {other}"),
        });
    }
    Ok(ops)
}

/// Assemble the job-frame prefix shared by the local and remote
/// executors — everything up to (not including) the shard section:
/// worker id, mode, trace flag, field names, op program, and the fit
/// spec when fitting. Each executor appends its own shard section
/// (local paths here, inline-bytes-or-digest entries in
/// [`super::remote`]) and seals the frame.
pub(super) fn encode_job_prefix(
    plan: &PhysicalPlan,
    worker_id: u32,
    fit: Option<(&WireEstimator, usize)>,
) -> Result<Vec<u8>> {
    let mut buf = begin_frame(JOB_MAGIC);
    buf.extend_from_slice(&worker_id.to_le_bytes());
    buf.push(if fit.is_some() { MODE_FIT } else { MODE_MAP });
    // Trace flag: when the driver is tracing, the worker installs a
    // fresh local sink and ships its spans back in the reply's span
    // section. Observability only — the result payload is byte-for-byte
    // independent of this flag.
    buf.push(obs::enabled() as u8);
    buf.extend_from_slice(&(plan.fields().len() as u32).to_le_bytes());
    for f in plan.fields() {
        write_str(&mut buf, f);
    }
    encode_ops(&mut buf, plan.program())?;
    if let Some((est, in_idx)) = fit {
        est.encode(&mut buf);
        buf.extend_from_slice(&(in_idx as u32).to_le_bytes());
    }
    Ok(buf)
}

/// Assemble one worker's job frame.
fn encode_job(
    plan: &PhysicalPlan,
    worker_id: u32,
    fit: Option<(&WireEstimator, usize)>,
    shards: &[(u64, &Path)],
) -> Result<Vec<u8>> {
    let mut buf = encode_job_prefix(plan, worker_id, fit)?;
    buf.extend_from_slice(&(shards.len() as u32).to_le_bytes());
    for (idx, path) in shards {
        buf.extend_from_slice(&idx.to_le_bytes());
        write_path(&mut buf, path);
    }
    seal_frame(&mut buf);
    Ok(buf)
}

/// The decoded job-frame prefix, shared by the local and remote worker
/// entry points. The cursor is left at the start of the executor's own
/// shard section.
pub(super) struct JobPrefix {
    pub(super) worker_id: u32,
    pub(super) mode: u8,
    pub(super) traced: bool,
    pub(super) plan: PhysicalPlan,
    pub(super) fit: Option<(WireEstimator, usize)>,
}

/// Decode everything of a checked job frame up to the shard section.
pub(super) fn decode_job_prefix(cur: &mut Cursor<'_>) -> Result<JobPrefix> {
    let worker_id = cur.u32()?;
    let mode = cur.u8()?;
    anyhow::ensure!(mode == MODE_MAP || mode == MODE_FIT, "job frame has unknown mode {mode}");
    let traced = cur.u8()? != 0;
    let n_fields = cur.u32()? as usize;
    anyhow::ensure!(n_fields <= cur.remaining(), "job declares {n_fields} fields");
    let mut fields = Vec::with_capacity(n_fields);
    for _ in 0..n_fields {
        fields.push(cur.str()?);
    }
    let ops = decode_ops(cur)?;
    let fit = if mode == MODE_FIT {
        let est = WireEstimator::decode(cur)?;
        let in_idx = cur.u32()? as usize;
        Some((est, in_idx))
    } else {
        None
    };
    Ok(JobPrefix { worker_id, mode, traced, plan: PhysicalPlan::from_wire(fields, ops), fit })
}

/// Serialize one shard's [`PartResult`] into a reply frame body.
pub(super) fn encode_part_result(buf: &mut Vec<u8>, idx: u64, r: &PartResult) {
    buf.extend_from_slice(&idx.to_le_bytes());
    buf.extend_from_slice(&(r.part.num_rows() as u64).to_le_bytes());
    buf.extend_from_slice(&(r.part.num_columns() as u32).to_le_bytes());
    for col in r.part.columns() {
        buf.push(dtype_code(col.dtype()));
        encode_cells(buf, col);
    }
    buf.extend_from_slice(&(r.slots.len() as u32).to_le_bytes());
    for slot in &r.slots {
        buf.extend_from_slice(&(slot.keys.len() as u64).to_le_bytes());
        for k in &slot.keys {
            buf.extend_from_slice(&k.to_le_bytes());
        }
        for id in &slot.ids {
            buf.extend_from_slice(&id.to_le_bytes());
        }
    }
    match &r.final_ids {
        None => buf.push(0),
        Some(ids) => {
            buf.push(1);
            buf.extend_from_slice(&(ids.len() as u64).to_le_bytes());
            for id in ids {
                buf.extend_from_slice(&id.to_le_bytes());
            }
        }
    }
    for n in [r.rows_ingested, r.nulls_dropped, r.empties_dropped, r.sampled_out, r.limited_out] {
        buf.extend_from_slice(&(n as u64).to_le_bytes());
    }
    for d in [r.phases.ingest, r.phases.pre, r.phases.clean, r.phases.post] {
        buf.extend_from_slice(&(d.as_nanos() as u64).to_le_bytes());
    }
}

/// Decode one shard's result, validating every declared count against
/// the bytes present and the driver's expectations (schema dtypes, slot
/// count, provenance-id domain) so a corrupt frame can only ever error.
pub(super) fn decode_part_result(
    cur: &mut Cursor<'_>,
    schema: &Schema,
    expected_slots: usize,
) -> Result<(u64, PartResult)> {
    let idx = cur.u64()?;
    let n_rows = cur.u64()? as usize;
    let n_cols = cur.u32()? as usize;
    anyhow::ensure!(
        n_cols == schema.len(),
        "shard {idx}: result has {n_cols} columns, schema expects {}",
        schema.len()
    );
    anyhow::ensure!(
        n_cols.saturating_mul(n_rows.saturating_add(1)) <= cur.remaining(),
        "shard {idx}: declares more cells ({n_cols} x {n_rows}) than it contains"
    );
    let mut cols = Vec::with_capacity(n_cols);
    for field in schema.fields() {
        let dtype = dtype_from(cur.u8()?)?;
        anyhow::ensure!(
            dtype == field.dtype,
            "shard {idx}: column '{}' arrived as {dtype}, schema expects {}",
            field.name,
            field.dtype
        );
        cols.push(decode_cells(cur, dtype, n_rows)?);
    }
    let part = Partition::new(cols);

    let n_slots = cur.u32()? as usize;
    anyhow::ensure!(
        n_slots == expected_slots,
        "shard {idx}: {n_slots} dedup slots, plan has {expected_slots}"
    );
    let mut slots = Vec::with_capacity(n_slots);
    for _ in 0..n_slots {
        let n = cur.u64()? as usize;
        anyhow::ensure!(
            n.saturating_mul(20) <= cur.remaining(),
            "shard {idx}: dedup slot declares {n} keys"
        );
        let mut keys = Vec::with_capacity(n);
        for _ in 0..n {
            keys.push(u128::from_le_bytes(cur.take(16)?.try_into().unwrap()));
        }
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            ids.push(cur.u32()?);
        }
        slots.push(KeySlot { keys, ids });
    }
    let final_ids = match cur.u8()? {
        0 => None,
        _ => {
            let n = cur.u64()? as usize;
            anyhow::ensure!(
                n.saturating_mul(4) <= cur.remaining(),
                "shard {idx}: declares {n} final row ids"
            );
            let mut ids = Vec::with_capacity(n);
            for _ in 0..n {
                ids.push(cur.u32()?);
            }
            anyhow::ensure!(
                ids.len() == part.num_rows(),
                "shard {idx}: {} final ids for {} rows",
                ids.len(),
                part.num_rows()
            );
            Some(ids)
        }
    };
    anyhow::ensure!(
        (expected_slots > 0) == final_ids.is_some(),
        "shard {idx}: dedup provenance missing or unexpected"
    );
    let rows_ingested = cur.u64()? as usize;
    let nulls_dropped = cur.u64()? as usize;
    let empties_dropped = cur.u64()? as usize;
    let sampled_out = cur.u64()? as usize;
    let limited_out = cur.u64()? as usize;
    // Provenance ids index the parsed-row domain of this shard; the
    // Admitter sizes its duplicate mask from `rows_ingested`, so every
    // id must stay inside it (a corrupt frame must not panic the merge).
    for slot in &slots {
        anyhow::ensure!(
            slot.keys.len() == slot.ids.len()
                && slot.ids.iter().all(|&id| (id as usize) < rows_ingested),
            "shard {idx}: dedup provenance out of range"
        );
    }
    if let Some(ids) = &final_ids {
        anyhow::ensure!(
            ids.iter().all(|&id| (id as usize) < rows_ingested),
            "shard {idx}: final row ids out of range"
        );
    }
    let phases = Phases {
        ingest: Duration::from_nanos(cur.u64()?),
        pre: Duration::from_nanos(cur.u64()?),
        clean: Duration::from_nanos(cur.u64()?),
        post: Duration::from_nanos(cur.u64()?),
    };
    Ok((
        idx,
        PartResult {
            part,
            slots,
            final_ids,
            rows_ingested,
            nulls_dropped,
            empties_dropped,
            sampled_out,
            limited_out,
            phases,
        },
    ))
}

/// Hard caps on the reply span section — a corrupt frame must not be
/// able to provoke a huge allocation before validation fails.
const MAX_WIRE_SPANS: usize = 1_000_000;
const MAX_SPAN_ARGS: usize = 64;

/// Serialize a worker's recorded spans as the reply frame's trailing
/// span section (always present since wire v2; count 0 when the job was
/// not traced). Lanes ship as the tid only — the driver rewrites the
/// pid to the worker-process lane in [`obs::record_remote`].
pub(super) fn encode_spans(buf: &mut Vec<u8>, spans: &[obs::Span]) {
    buf.extend_from_slice(&(spans.len() as u32).to_le_bytes());
    for s in spans {
        write_str(buf, &s.name);
        write_str(buf, &s.cat);
        buf.extend_from_slice(&s.lane.tid.to_le_bytes());
        buf.extend_from_slice(&s.start_ns.to_le_bytes());
        buf.extend_from_slice(&s.dur_ns.to_le_bytes());
        buf.extend_from_slice(&(s.args.len().min(MAX_SPAN_ARGS) as u32).to_le_bytes());
        for (k, v) in s.args.iter().take(MAX_SPAN_ARGS) {
            write_str(buf, k);
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Decode the reply's span section. Spans arrive in worker-local
/// coordinates (pid 0, worker epoch); the caller re-anchors them.
pub(super) fn decode_spans(cur: &mut Cursor<'_>) -> Result<Vec<obs::Span>> {
    let n = cur.u32()? as usize;
    anyhow::ensure!(n <= MAX_WIRE_SPANS, "reply declares {n} spans");
    anyhow::ensure!(n <= cur.remaining(), "reply span section declares {n} spans");
    let mut spans = Vec::with_capacity(n);
    for _ in 0..n {
        let name = cur.str()?;
        let cat = cur.str()?;
        let tid = cur.u32()?;
        let start_ns = cur.u64()?;
        let dur_ns = cur.u64()?;
        let n_args = cur.u32()? as usize;
        anyhow::ensure!(n_args <= MAX_SPAN_ARGS, "span declares {n_args} args");
        let mut args = Vec::with_capacity(n_args);
        for _ in 0..n_args {
            let key = cur.str()?;
            args.push((key, cur.u64()?));
        }
        spans.push(obs::Span {
            name,
            cat,
            lane: obs::Lane { pid: 0, tid },
            start_ns,
            dur_ns,
            args,
        });
    }
    Ok(spans)
}

/// Decode a whole map-mode reply frame into shard results plus the
/// worker's shipped spans (empty when the job was not traced).
fn decode_map_reply(
    bytes: &[u8],
    worker_id: u32,
    schema: &Schema,
    expected_slots: usize,
) -> Result<(Vec<(u64, PartResult)>, Vec<obs::Span>)> {
    let mut cur = check_frame(bytes, REPLY_MAGIC, "result")?;
    let got_worker = cur.u32()?;
    anyhow::ensure!(
        got_worker == worker_id,
        "result frame from worker {got_worker}, expected {worker_id}"
    );
    anyhow::ensure!(cur.u8()? == MODE_MAP, "result frame has the wrong mode");
    let n_shards = cur.u32()? as usize;
    anyhow::ensure!(n_shards <= cur.remaining(), "result declares {n_shards} shards");
    let mut out = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        out.push(decode_part_result(&mut cur, schema, expected_slots)?);
    }
    let spans = decode_spans(&mut cur)?;
    anyhow::ensure!(
        cur.remaining() == 0,
        "result frame has {} trailing bytes",
        cur.remaining()
    );
    Ok((out, spans))
}

/// Decode a fit-mode reply frame into the accumulator partial plus the
/// worker's shipped spans (empty when the job was not traced).
pub(super) fn decode_fit_reply(bytes: &[u8], worker_id: u32) -> Result<(Vec<u8>, Vec<obs::Span>)> {
    let mut cur = check_frame(bytes, REPLY_MAGIC, "result")?;
    let got_worker = cur.u32()?;
    anyhow::ensure!(
        got_worker == worker_id,
        "result frame from worker {got_worker}, expected {worker_id}"
    );
    anyhow::ensure!(cur.u8()? == MODE_FIT, "result frame has the wrong mode");
    let n = cur.u64()? as usize;
    anyhow::ensure!(n <= cur.remaining(), "fit partial length mismatch");
    let partial = cur.take(n)?.to_vec();
    let spans = decode_spans(&mut cur)?;
    anyhow::ensure!(
        cur.remaining() == 0,
        "result frame has {} trailing bytes",
        cur.remaining()
    );
    Ok((partial, spans))
}

/// The multi-process executor: scatter the op program + shard
/// assignments to worker processes, gather their result frames, fold
/// through the shared driver-side `Merger`.
pub struct ProcessExecutor {
    opts: ProcessOptions,
}

impl ProcessExecutor {
    pub fn new(opts: ProcessOptions) -> Self {
        ProcessExecutor { opts }
    }

    /// Run `plan` across worker processes. Output (frame bytes, row
    /// order, drop accounting) is identical to [`PhysicalPlan::execute`];
    /// only the schedule differs.
    pub fn execute(&self, plan: &PhysicalPlan) -> Result<PlanOutput> {
        // Estimator-bearing plans orchestrate their two passes in
        // `PhysicalPlan::execute_process`.
        if plan.is_two_pass() {
            return plan.execute_process(&self.opts);
        }
        let t_pass = Instant::now();
        let n = plan.files().len();
        let procs = self.opts.resolve(n);
        if procs <= 1 {
            // Scarce shards or a single worker: one process would redo
            // the in-process single pass with spawn + serialization cost
            // on top — delegate (same bytes out, better schedule).
            return plan.execute(0);
        }
        let results = self.scatter_gather(plan, procs)?;
        let pass_wall = t_pass.elapsed();
        let mut merger =
            Merger::new(plan.output_schema().clone(), plan.n_distinct(), plan.limit_n());
        for r in results {
            merger.push(r);
        }
        Ok(merger.finish(pass_wall, Duration::ZERO))
    }

    /// Sink-based variant: hand each shard's [`PartResult`] to `sink`
    /// **in shard order** without merging — the partition-shipping fit
    /// pass of the two-pass strategy. Delegates to the in-process
    /// collect when fewer than two workers resolve.
    pub(super) fn run(
        &self,
        plan: &PhysicalPlan,
        sink: &mut dyn FnMut(PartResult) -> Result<()>,
    ) -> Result<()> {
        let n = plan.files().len();
        if n == 0 {
            return Ok(());
        }
        let procs = self.opts.resolve(n);
        if procs <= 1 {
            let (results, _) = plan.collect_results(0)?;
            for r in results {
                sink(r)?;
            }
            return Ok(());
        }
        for r in self.scatter_gather(plan, procs)? {
            sink(r)?;
        }
        Ok(())
    }

    /// Like [`Self::run`], but the shard file is *always* the unit of
    /// work: the single-worker fallback uses the shard-aligned collect
    /// rather than the re-chunk path, because the incremental cache
    /// needs each [`PartResult`] to map 1:1 onto a shard file.
    pub(super) fn run_shards(
        &self,
        plan: &PhysicalPlan,
        sink: &mut dyn FnMut(PartResult) -> Result<()>,
    ) -> Result<()> {
        let n = plan.files().len();
        if n == 0 {
            return Ok(());
        }
        let procs = self.opts.resolve(n);
        if procs <= 1 {
            for r in plan.collect_shard_results(0)? {
                sink(r)?;
            }
            return Ok(());
        }
        for r in self.scatter_gather(plan, procs)? {
            sink(r)?;
        }
        Ok(())
    }

    /// Partial-aggregate fit pass: each worker folds its shards into its
    /// own accumulator and ships the accumulated state; the driver
    /// merges partials (worker order) and fits the model. Only valid
    /// when the prefix program has no pending dedup/limit — the caller
    /// ([`PhysicalPlan::execute_process`]) checks that.
    pub(super) fn run_fit_partial(
        &self,
        prefix: &PhysicalPlan,
        est: &dyn Estimator,
        spec: WireEstimator,
        in_idx: usize,
    ) -> Result<Arc<dyn Transformer>> {
        let mut acc = est.accumulator().ok_or_else(|| {
            anyhow::anyhow!(
                "estimator {} lost its accumulator between lower and execute",
                est.name()
            )
        })?;
        let n = prefix.files().len();
        let procs = self.opts.resolve(n);
        if procs <= 1 {
            // In-process fallback: no dedup/limit pending, so admission
            // is the identity and shard results fold directly.
            let (results, _) = prefix.collect_results(0)?;
            for r in results {
                if r.part.num_rows() > 0 {
                    acc.accumulate(r.part.column(in_idx))?;
                }
            }
            return acc.finish();
        }
        anyhow::ensure!(
            acc.partial().is_some(),
            "estimator {} does not support cross-process partial folds",
            est.name()
        );
        let cmd = self.opts.worker_command()?;
        let assignments = assign_shards(prefix.files(), procs);
        let jobs: Vec<Vec<u8>> = assignments
            .iter()
            .enumerate()
            .map(|(w, shards)| encode_job(prefix, w as u32, Some((&spec, in_idx)), shards))
            .collect::<Result<_>>()?;
        let replies = self.opts.ship(&jobs)?;
        for (w, (anchor, bytes)) in replies.iter().enumerate() {
            let (partial, spans) = decode_fit_reply(bytes, w as u32)
                .with_context(|| format!("plan worker {w} ({})", cmd.display()))?;
            obs::record_remote(spans, w, *anchor);
            acc.merge_partial(&partial)
                .with_context(|| format!("plan worker {w}: merging fit partial"))?;
        }
        acc.finish()
    }

    /// Spawn `procs` workers over the plan's shards and return every
    /// shard's result in shard order. Any worker failure — spawn error,
    /// nonzero exit, death by signal, or a garbled/short result frame —
    /// is a driver error naming the worker; all children are reaped
    /// before this returns.
    fn scatter_gather(&self, plan: &PhysicalPlan, procs: usize) -> Result<Vec<PartResult>> {
        let n = plan.files().len();
        let cmd = self.opts.worker_command()?;
        let assignments = assign_shards(plan.files(), procs);
        let jobs: Vec<Vec<u8>> = assignments
            .iter()
            .enumerate()
            .map(|(w, shards)| encode_job(plan, w as u32, None, shards))
            .collect::<Result<_>>()?;
        let replies = self.opts.ship(&jobs)?;

        let mut pending: Vec<Option<PartResult>> = (0..n).map(|_| None).collect();
        for (w, (anchor, bytes)) in replies.iter().enumerate() {
            let (shard_results, spans) =
                decode_map_reply(bytes, w as u32, plan.output_schema(), plan.n_distinct())
                    .with_context(|| format!("plan worker {w} ({})", cmd.display()))?;
            obs::record_remote(spans, w, *anchor);
            anyhow::ensure!(
                shard_results.len() == assignments[w].len(),
                "plan worker {w}: returned {} shards, {} were assigned",
                shard_results.len(),
                assignments[w].len()
            );
            for (idx, r) in shard_results {
                let slot = pending
                    .get_mut(idx as usize)
                    .ok_or_else(|| anyhow::anyhow!("plan worker {w}: unknown shard index {idx}"))?;
                anyhow::ensure!(slot.is_none(), "plan worker {w}: shard {idx} returned twice");
                *slot = Some(r);
            }
        }
        let mut out = Vec::with_capacity(n);
        for (i, slot) in pending.into_iter().enumerate() {
            out.push(slot.ok_or_else(|| anyhow::anyhow!("shard {i} never came back"))?);
        }
        Ok(out)
    }
}

/// Stripe shards across workers round-robin (shard `i` → worker
/// `i % procs`), so early shards land on distinct workers and the
/// in-order driver fold is never starved by one worker holding the
/// whole prefix.
pub(super) fn assign_shards(files: &[PathBuf], procs: usize) -> Vec<Vec<(u64, &Path)>> {
    let mut assignments: Vec<Vec<(u64, &Path)>> = (0..procs).map(|_| Vec::new()).collect();
    for (i, path) in files.iter().enumerate() {
        assignments[i % procs].push((i as u64, path.as_path()));
    }
    assignments
}

/// Drive every job concurrently through `run_one`, returning results in
/// job order (the first failure wins; every job still runs to
/// completion so children are always reaped). Shared by the
/// spawn-per-job and pooled paths — the failure-collection semantics
/// must not drift between them.
fn gather<T: Send>(
    jobs: &[Vec<u8>],
    run_one: impl Fn(usize, &[u8]) -> Result<T> + Sync,
) -> Result<Vec<T>> {
    std::thread::scope(|scope| {
        let run_one = &run_one;
        let handles: Vec<_> = jobs
            .iter()
            .enumerate()
            .map(|(w, job)| scope.spawn(move || run_one(w, job)))
            .collect();
        let mut out = Vec::with_capacity(handles.len());
        let mut first_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(bytes)) => out.push(bytes),
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow::anyhow!("worker driver thread panicked"));
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    })
}

/// Wrap one job exchange in an `rpc` span on the worker-process lane,
/// capturing the driver-epoch anchor just before the job ships. Worker
/// spans shipped back in the reply are re-anchored by this value
/// ([`obs::record_remote`]), so they nest inside this span on the same
/// Perfetto track. `anchor` is 0 when tracing is off.
fn traced_exchange(
    w: usize,
    job: &[u8],
    send: impl FnOnce(&[u8]) -> Result<Vec<u8>>,
) -> Result<(u64, Vec<u8>)> {
    let _lane = obs::lane_scope(obs::lane_worker_process(w));
    let mut sp = obs::span("rpc", "rpc");
    if sp.active() {
        sp.arg("worker", w as u64);
    }
    let anchor = obs::now_ns();
    send(job).map(|reply| (anchor, reply))
}

/// Spawn-per-job execution: every worker process is spawned, driven to
/// completion, and waited on before this returns — success or failure —
/// so no orphan survives a driver error.
fn run_workers(cmd: &Path, jobs: &[Vec<u8>]) -> Result<Vec<(u64, Vec<u8>)>> {
    gather(jobs, |w, job| traced_exchange(w, job, |job| run_worker(w, cmd, job)))
}

/// Pooled execution: job `w` exchanges with pool slot `w`. Callers
/// never build more jobs than `ProcessOptions::resolve` allows, which
/// is clamped to the pool size, so the slot index is always in range.
fn run_workers_pooled(pool: &WorkerPool, jobs: &[Vec<u8>]) -> Result<Vec<(u64, Vec<u8>)>> {
    anyhow::ensure!(
        jobs.len() <= pool.size(),
        "{} jobs for a {}-slot worker pool",
        jobs.len(),
        pool.size()
    );
    gather(jobs, |w, job| traced_exchange(w, job, |job| pool.exchange(w, job)))
}

/// Run one worker process end to end: spawn, ship the job on stdin,
/// drain stdout/stderr, reap, and validate the exit status.
fn run_worker(worker: usize, cmd: &Path, job: &[u8]) -> Result<Vec<u8>> {
    let mut child = Command::new(cmd)
        .arg("plan-worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .map_err(|e| anyhow::anyhow!("plan worker {worker}: spawn {}: {e}", cmd.display()))?;
    let mut stdin = child.stdin.take().expect("piped stdin");
    let mut stdout = child.stdout.take().expect("piped stdout");
    let mut stderr = child.stderr.take().expect("piped stderr");
    let (reply, err_text) = std::thread::scope(|scope| {
        // Stderr drains on its own thread so a chatty worker can never
        // fill that pipe while the driver blocks on stdout (and vice
        // versa). The job ships on its own thread too: a worker that
        // dies early closes its stdin mid-write, and the stdout read
        // below must keep draining so the child can be reaped.
        let err = scope.spawn(move || {
            let mut t = String::new();
            let _ = stderr.read_to_string(&mut t);
            t
        });
        let input = scope.spawn(move || {
            // A write error (worker died before reading its whole job)
            // is diagnosed by the exit-status check below.
            let _ = stdin.write_all(job);
            // stdin drops here -> the worker sees job EOF.
        });
        let mut out = Vec::new();
        let _ = stdout.read_to_end(&mut out);
        let _ = input.join();
        (out, err.join().unwrap_or_default())
    });
    // stdout hit EOF, so the worker exited (or is exiting): wait() can
    // no longer block on a full pipe, and always reaps the child.
    let status = child
        .wait()
        .map_err(|e| anyhow::anyhow!("plan worker {worker}: wait: {e}"))?;
    if !status.success() {
        let err = err_text.trim();
        anyhow::bail!(
            "plan worker {worker} ({}) failed with {status}{}",
            cmd.display(),
            if err.is_empty() { String::new() } else { format!(": {err}") }
        );
    }
    Ok(reply)
}

/// Entry point of the hidden `plan-worker` CLI mode (`repro
/// plan-worker`): read one `P3PJ` job frame from stdin, run the
/// assigned shards, write one `P3PW` result frame to stdout. Returns
/// the process exit code; all diagnostics go to stderr, where the
/// driver folds them into its error message.
pub fn worker_main() -> i32 {
    match worker_run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("plan-worker: {e:#}");
            1
        }
    }
}

fn worker_run() -> Result<()> {
    let mut job = Vec::new();
    std::io::stdin()
        .lock()
        .read_to_end(&mut job)
        .map_err(|e| anyhow::anyhow!("reading job from stdin: {e}"))?;
    let reply = run_job(&job)?;
    let mut out = std::io::stdout().lock();
    out.write_all(&reply)
        .and_then(|()| out.flush())
        .map_err(|e| anyhow::anyhow!("writing result to stdout: {e}"))?;
    Ok(())
}

/// Entry point of the persistent worker mode (`repro plan-worker
/// --persist`), which a [`WorkerPool`] keeps warm across passes:
/// length-prefixed `P3PJ` job frames arrive on stdin in a loop, each
/// answered with a length-prefixed `P3PW` reply on stdout; clean EOF at
/// a frame boundary is the shutdown signal (exit 0).
///
/// A failed job exits the worker nonzero instead of attempting to
/// resync the stream — the driver-side pool reaps it, surfaces the
/// typed error, and lazily respawns the slot for the next job.
pub fn worker_main_persist() -> i32 {
    match worker_persist_loop() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("plan-worker: {e:#}");
            1
        }
    }
}

fn worker_persist_loop() -> Result<()> {
    let mut stdin = std::io::stdin().lock();
    let mut stdout = std::io::stdout().lock();
    while let Some(job) = read_frame(&mut stdin)? {
        let reply = run_job(&job)?;
        write_frame(&mut stdout, &reply)
            .map_err(|e| anyhow::anyhow!("writing result to stdout: {e}"))?;
    }
    Ok(())
}

/// Decode and execute one job frame, producing the reply frame.
fn run_job(job: &[u8]) -> Result<Vec<u8>> {
    let mut cur = check_frame(job, JOB_MAGIC, "job")?;
    let JobPrefix { worker_id, mode, traced, plan, fit } = decode_job_prefix(&mut cur)?;
    let n_shards = cur.u32()? as usize;
    anyhow::ensure!(n_shards <= cur.remaining(), "job declares {n_shards} shards");
    let mut shards = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        let idx = cur.u64()?;
        let path = read_path(&mut cur)?;
        shards.push((idx, path));
    }
    anyhow::ensure!(cur.remaining() == 0, "job frame has {} trailing bytes", cur.remaining());

    // A traced job gets a fresh sink (epoch = now, i.e. at/after the
    // driver's RPC anchor). It is uninstalled on every exit path: the
    // persistent worker would otherwise leak a stale sink into its next
    // job's spans.
    let sink = if traced { Some(obs::trace::install_new()) } else { None };
    let result = (|| -> Result<Vec<u8>> {
        let mut buf = begin_frame(REPLY_MAGIC);
        buf.extend_from_slice(&worker_id.to_le_bytes());
        buf.push(mode);
        // One shard-byte buffer per worker process: each read reuses the
        // high-water allocation instead of growing a fresh Vec per shard.
        let mut shard_buf: Vec<u8> = Vec::new();
        match fit {
            None => {
                buf.extend_from_slice(&(shards.len() as u32).to_le_bytes());
                for (idx, path) in &shards {
                    let r = plan
                        .run_partition_buffered(*idx as usize, path, &mut shard_buf)
                        .with_context(|| format!("shard {idx}"))?;
                    encode_part_result(&mut buf, *idx, &r);
                }
            }
            Some((est_spec, in_idx)) => {
                let est = est_spec.build();
                let mut acc = est.accumulator().ok_or_else(|| {
                    anyhow::anyhow!("estimator {} has no accumulator", est.name())
                })?;
                for (idx, path) in &shards {
                    let r = plan
                        .run_partition_buffered(*idx as usize, path, &mut shard_buf)
                        .with_context(|| format!("shard {idx}"))?;
                    if r.part.num_rows() > 0 {
                        anyhow::ensure!(
                            in_idx < r.part.num_columns(),
                            "fit input column {in_idx} out of range ({} columns)",
                            r.part.num_columns()
                        );
                        acc.accumulate(r.part.column(in_idx))?;
                    }
                }
                let partial = acc.partial().ok_or_else(|| {
                    anyhow::anyhow!("estimator {} has no partial state", est.name())
                })?;
                buf.extend_from_slice(&(partial.len() as u64).to_le_bytes());
                buf.extend_from_slice(&partial);
            }
        }
        Ok(buf)
    })();
    let spans = match &sink {
        Some(sink) => {
            obs::trace::uninstall();
            sink.drain()
        }
        None => Vec::new(),
    };
    let mut buf = result?;
    encode_spans(&mut buf, &spans);
    seal_frame(&mut buf);
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::xxh64;
    use crate::frame::Column;
    use crate::pipeline::presets::case_study_plan;
    use crate::plan::LogicalPlan;

    fn sample_partition() -> Partition {
        let titles = vec![
            Some("<b>The FIRST Title</b>".to_string()),
            Some("plain title".to_string()),
            None,
            Some("plain title".to_string()), // duplicate of row 1
            Some("12345 (all digits)".to_string()),
        ];
        let abstracts = vec![
            Some("Deep LEARNING &amp; networks (see Fig. 1)".to_string()),
            Some("the model is the best".to_string()),
            Some("orphaned abstract".to_string()),
            Some("the model is the best".to_string()),
            Some("numbers 42 everywhere".to_string()),
        ];
        Partition::new(vec![Column::from_strs(titles), Column::from_strs(abstracts)])
    }

    /// Encode a plan's program, decode it, and check the rebuilt program
    /// transforms a partition exactly like the original.
    fn assert_program_roundtrip(plan: &LogicalPlan) {
        let phys = plan.lower().unwrap();
        let mut buf = Vec::new();
        encode_ops(&mut buf, phys.program()).unwrap();
        let mut cur = Cursor::new(&buf, 0);
        let ops = decode_ops(&mut cur).unwrap();
        assert_eq!(cur.remaining(), 0);
        let rebuilt = PhysicalPlan::from_wire(phys.fields().to_vec(), ops);
        let a = phys.run_ops(sample_partition(), 3, Duration::ZERO);
        let b = rebuilt.run_ops(sample_partition(), 3, Duration::ZERO);
        assert_eq!(a.part, b.part, "rebuilt program diverges");
        assert_eq!(a.rows_ingested, b.rows_ingested);
        assert_eq!(a.nulls_dropped, b.nulls_dropped);
        assert_eq!(a.empties_dropped, b.empties_dropped);
        assert_eq!(a.sampled_out, b.sampled_out);
        assert_eq!(a.limited_out, b.limited_out);
        assert_eq!(a.slots.len(), b.slots.len());
        for (sa, sb) in a.slots.iter().zip(&b.slots) {
            assert_eq!(sa.keys, sb.keys);
            assert_eq!(sa.ids, sb.ids);
        }
        assert_eq!(a.final_ids, b.final_ids);
    }

    #[test]
    fn program_roundtrips_for_the_case_study_plans() {
        // Unoptimized (individual stages) and optimized (fused sweeps).
        let plan = case_study_plan(&[], "title", "abstract");
        assert_program_roundtrip(&plan);
        assert_program_roundtrip(&plan.clone().optimize());
        // Sample + limit ops.
        let sampled = LogicalPlan::scan(vec![], &["title", "abstract"])
            .sample(0.5, 7)
            .drop_nulls(&["title", "abstract"])
            .limit(3)
            .collect();
        assert_program_roundtrip(&sampled);
    }

    #[test]
    fn program_roundtrips_for_feature_stages_and_fitted_models() {
        use crate::pipeline::features::{HashingTF, IdfModel, NGram};
        use crate::pipeline::stages::{StopWordsRemover, Tokenizer};
        let plan = LogicalPlan::scan(vec![], &["title", "abstract"])
            .transform(Tokenizer::new("abstract", "tokens"))
            .transform(StopWordsRemover::new("tokens", "tokens"))
            .transform(NGram::new("tokens", "tokens", 1))
            .transform(HashingTF::new("tokens", "tf", 32))
            .transform(IdfModel::new("tf", "tfidf", vec![0.5; 32]))
            .collect();
        assert_program_roundtrip(&plan);
    }

    #[test]
    fn unserializable_stage_fails_encoding_with_a_clear_error() {
        struct Opaque;
        impl Transformer for Opaque {
            fn name(&self) -> &'static str {
                "Opaque"
            }
            fn input_col(&self) -> &str {
                "title"
            }
            fn output_col(&self) -> &str {
                "title"
            }
            fn output_dtype(&self, input: crate::frame::DType) -> crate::frame::DType {
                input
            }
            fn transform_column(&self, input: &Column) -> Column {
                input.clone()
            }
        }
        let plan = LogicalPlan::scan(vec![], &["title"]).transform(Opaque).collect();
        let phys = plan.lower().unwrap();
        let err = encode_ops(&mut Vec::new(), phys.program()).unwrap_err();
        assert!(err.to_string().contains("wire spec"), "{err}");
    }

    #[test]
    fn part_result_roundtrips_through_the_reply_frame() {
        let plan = case_study_plan(&[], "title", "abstract").optimize();
        let phys = plan.lower().unwrap();
        let r = phys.run_ops(sample_partition(), 0, Duration::from_millis(3));
        let mut buf = Vec::new();
        buf.extend_from_slice(REPLY_MAGIC);
        buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        buf.extend_from_slice(&7u32.to_le_bytes());
        buf.push(MODE_MAP);
        buf.extend_from_slice(&1u32.to_le_bytes());
        encode_part_result(&mut buf, 0, &r);
        // Empty span section (wire v2: always present, count 0 when the
        // job was not traced).
        buf.extend_from_slice(&0u32.to_le_bytes());
        let digest = xxh64(&buf[4..], 0);
        buf.extend_from_slice(&digest.to_le_bytes());

        let (decoded, spans) =
            decode_map_reply(&buf, 7, phys.output_schema(), phys.n_distinct()).unwrap();
        assert!(spans.is_empty());
        assert_eq!(decoded.len(), 1);
        let (idx, d) = &decoded[0];
        assert_eq!(*idx, 0);
        assert_eq!(d.part, r.part);
        assert_eq!(d.rows_ingested, r.rows_ingested);
        assert_eq!(d.nulls_dropped, r.nulls_dropped);
        assert_eq!(d.final_ids, r.final_ids);
        assert_eq!(d.slots.len(), r.slots.len());
        for (sa, sb) in d.slots.iter().zip(&r.slots) {
            assert_eq!(sa.keys, sb.keys);
            assert_eq!(sa.ids, sb.ids);
        }

        // Wrong worker id, flipped payload byte, and truncation all
        // error — never panic, never a silent partial.
        assert!(decode_map_reply(&buf, 8, phys.output_schema(), phys.n_distinct()).is_err());
        let mut flipped = buf.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x20;
        assert!(
            decode_map_reply(&flipped, 7, phys.output_schema(), phys.n_distinct()).is_err(),
            "bit flip must fail the digest"
        );
        for cut in [0, 10, buf.len() / 2, buf.len() - 1] {
            assert!(
                decode_map_reply(&buf[..cut], 7, phys.output_schema(), phys.n_distinct())
                    .is_err(),
                "cut at {cut}"
            );
        }
    }

    #[test]
    fn span_section_roundtrips_and_caps_are_enforced() {
        let spans = vec![obs::Span {
            name: "op".into(),
            cat: "op".into(),
            lane: obs::Lane { pid: 0, tid: 0 },
            start_ns: 5,
            dur_ns: 10,
            args: vec![("rows_in".into(), 9), ("rows_out".into(), 7)],
        }];
        let mut buf = Vec::new();
        encode_spans(&mut buf, &spans);
        let mut cur = Cursor::new(&buf, 0);
        let decoded = decode_spans(&mut cur).unwrap();
        assert_eq!(cur.remaining(), 0);
        assert_eq!(decoded, spans);
        // A declared count past the cap errors before any allocation.
        let mut bad = Vec::new();
        bad.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_spans(&mut Cursor::new(&bad, 0)).is_err());
    }

    #[test]
    fn job_frame_roundtrips_and_rejects_corruption() {
        // `encode_job` reads the global tracing flag; the lock keeps a
        // concurrent sink-installing test from flipping it mid-encode.
        let _lock = crate::obs::trace::test_lock();
        let files = vec![PathBuf::from("/tmp/a.json"), PathBuf::from("/tmp/b.json")];
        let plan = case_study_plan(&files, "title", "abstract").optimize();
        let phys = plan.lower().unwrap();
        let shards: Vec<(u64, &Path)> =
            files.iter().enumerate().map(|(i, p)| (i as u64, p.as_path())).collect();
        let job = encode_job(&phys, 3, None, &shards).unwrap();
        // A valid frame parses (the worker would then fail on the
        // nonexistent shard paths, not on the frame).
        let mut cur = check_frame(&job, JOB_MAGIC, "job").unwrap();
        assert_eq!(cur.u32().unwrap(), 3, "worker id");
        assert_eq!(cur.u8().unwrap(), MODE_MAP);
        assert_eq!(cur.u8().unwrap(), 0, "trace flag off outside a sink install");
        // Corruption is detected by the digest.
        let mut bad = job.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x01;
        assert!(check_frame(&bad, JOB_MAGIC, "job").is_err());
        // A job is not a reply.
        assert!(check_frame(&job, REPLY_MAGIC, "result").is_err());
    }

    #[test]
    fn estimator_spec_roundtrips() {
        let spec = WireEstimator::Idf {
            input: "tf".into(),
            output: "tfidf".into(),
            min_doc_freq: 2,
        };
        let mut buf = Vec::new();
        spec.encode(&mut buf);
        let mut cur = Cursor::new(&buf, 0);
        let WireEstimator::Idf { input, output, min_doc_freq } =
            WireEstimator::decode(&mut cur).unwrap();
        assert_eq!((input.as_str(), output.as_str(), min_doc_freq), ("tf", "tfidf", 2));
        assert_eq!(cur.remaining(), 0);
        let est = spec.build();
        assert_eq!(est.describe(), "IDF(tf -> tfidf, min_df=2)");
        assert!(est.accumulator().is_some());
    }

    #[test]
    fn resolve_clamps_to_shards_and_auto_sizes() {
        let auto = ProcessOptions::default();
        assert!(auto.resolve(100) >= 1);
        assert_eq!(auto.resolve(0), 0);
        let four = ProcessOptions { processes: 4, ..Default::default() };
        assert_eq!(four.resolve(100), 4);
        assert_eq!(four.resolve(3), 3, "never more workers than shards");
        assert_eq!(four.resolve(1), 1);
        // A pool additionally clamps the resolved count to its size.
        let pooled = ProcessOptions {
            processes: 4,
            pool: Some(Arc::new(WorkerPool::new("/bin/false", 2))),
            ..Default::default()
        };
        assert_eq!(pooled.resolve(100), 2);
        assert_eq!(pooled.resolve(1), 1);
    }

    #[test]
    fn assign_shards_stripes_round_robin() {
        let files: Vec<PathBuf> = (0..5).map(|i| PathBuf::from(format!("/tmp/{i}"))).collect();
        let a = assign_shards(&files, 2);
        assert_eq!(a.len(), 2);
        let idxs = |w: usize| a[w].iter().map(|(i, _)| *i).collect::<Vec<_>>();
        assert_eq!(idxs(0), vec![0, 2, 4]);
        assert_eq!(idxs(1), vec![1, 3]);
    }

    #[test]
    fn render_process_shows_topology_and_fallback() {
        let files: Vec<PathBuf> = (0..6).map(|i| PathBuf::from(format!("/tmp/{i}.json"))).collect();
        let phys = case_study_plan(&files, "title", "abstract").optimize().lower().unwrap();
        let r = phys.render_process(&ProcessOptions { processes: 3, ..Default::default() });
        assert!(r.contains("ProcessPool [6 file-partitions, 3 worker processes]"), "{r}");
        assert!(r.contains("plan-worker"), "{r}");
        assert!(r.contains("fold P3PW result frames"), "{r}");
        assert!(r.contains("hash-keys #0 [title, abstract]"), "{r}");
        // One shard: the executor delegates, and EXPLAIN says so.
        let one = case_study_plan(&files[..1], "title", "abstract").optimize().lower().unwrap();
        let r = one.render_process(&ProcessOptions { processes: 8, ..Default::default() });
        assert!(r.contains("fallback"), "{r}");
        assert!(r.contains("SinglePass"), "{r}");
    }

    #[test]
    fn worker_rejects_bad_jobs() {
        // `encode_job`/`run_job` consult the global tracing flag; hold
        // the obs test lock so no concurrent test's sink leaks in.
        let _lock = crate::obs::trace::test_lock();
        assert!(run_job(b"garbage").is_err());
        assert!(run_job(&[]).is_err());
        // Valid envelope, truncated body.
        let missing = std::env::temp_dir()
            .join(format!("p3sapp-proc-missing-{}", std::process::id()))
            .join("a.json");
        let files = vec![missing.clone()];
        let phys = case_study_plan(&files, "title", "abstract").lower().unwrap();
        let job = encode_job(&phys, 0, None, &[(0, missing.as_path())]).unwrap();
        assert!(run_job(&job[..job.len() - 9]).is_err(), "lost digest must fail");
        // Valid job over a missing shard file errors with the path.
        let err = run_job(&job).unwrap_err();
        assert!(format!("{err:#}").contains("a.json"), "{err:#}");
    }
}
