//! Per-shard incremental plan cache — warm re-runs over a *grown*
//! corpus execute only the shards that changed.
//!
//! The whole-plan cache ([`crate::cache`]) collapses a byte-identical
//! re-run to one deserialization, but the paper's workload is a corpus
//! that *grows*: each arXiv ingest appends shard files while the
//! existing ones stay untouched. A single appended shard changes the
//! plan fingerprint, and the whole-plan tier re-preprocesses everything.
//! This module re-keys cached work at shard granularity — one `P3PC`
//! payload per (plan fingerprint × shard content digest), see
//! [`crate::cache::fingerprint::shard_key`] — so a warm run restores
//! the per-shard results it has, executes only the miss shards through
//! the selected [`ExecutorKind`], and re-runs the driver-side merge
//! over the mixed restored + fresh partitions.
//!
//! Correctness hinges on what a cached payload carries: not the shard's
//! *final* rows but its full [`PartResult`] — the partition plus every
//! `Distinct` slot's hashed keys and row-provenance ids, and the
//! stage counters. Dedup provenance crossing serialization is what
//! keeps cross-shard `Distinct` exact: the merge can still register a
//! first occurrence inside a restored shard and drop its duplicate in
//! a fresh one (or vice versa), byte-identical to a cold full run.
//!
//! Estimator-bearing (two-pass) plans cache their **pass-1 prefix**
//! results instead — pass-2 rows depend on the fitted model, which
//! depends on every shard, so they can never be reused across corpus
//! states. Each shard's payload carries the prefix `PartResult` plus,
//! when the estimator supports it, its order-insensitive
//! [`FitAccumulator`](crate::pipeline::FitAccumulator) partial. A warm
//! run merges partials (restored + fresh) to re-fit the model — `Idf`
//! document frequencies fold per shard — then *resumes* each prefix
//! result through the fitted stage and suffix ops
//! ([`PhysicalPlan::resume_ops`]) rather than re-parsing raw bytes.
//!
//! Not eligible (the driver falls back to a normal execute): plans with
//! a `Sample` op (the positional keep-decision depends on the shard
//! *index*, while shard keys are content-addressed and index-free) and
//! empty file lists. Restores are reported honestly: the run gains a
//! `cache_restore(k of n shards)` stage and the manager's
//! `shard_hits`/`shard_misses` counters move, so `p3sapp cache stats`
//! and EXPLAIN pin exactly how much work was skipped.

use super::logical::LogicalPlan;
use super::physical::{
    lower, partial_fit_available, FitSink, KeySlot, Merger, PartResult, Phases, PhysicalPlan,
};
use super::process::ProcessExecutor;
use super::remote::RemoteExecutor;
use super::stream::StreamExecutor;
use super::{ExecutorKind, PlanOutput};
use crate::cache::artifact::{decode_cells, dtype_code, dtype_from, encode_cells, Cursor};
use crate::cache::{shard_key, CacheManager, PlanFingerprint};
use crate::driver::{CACHE_RESTORE, CLEANING};
use crate::engine::Executor;
use crate::frame::Partition;
use crate::obs;
use crate::Result;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Whether `plan` (already optimized) can run through the incremental
/// path at all. Shared with cache-aware EXPLAIN so the topology it
/// renders is the one the driver will pick.
pub fn incremental_eligible(plan: &LogicalPlan) -> bool {
    match lower(plan) {
        Ok(phys) => !phys.files().is_empty() && !phys.has_sample(),
        Err(_) => false,
    }
}

/// The per-shard cache keys of `plan` over the fingerprinted shard set,
/// in shard order. Public for EXPLAIN and the CLI's probe paths.
pub fn incremental_shard_keys(plan: &LogicalPlan, fp: &PlanFingerprint) -> Vec<String> {
    let render = plan.render();
    fp.shards().iter().map(|s| shard_key(&render, s)).collect()
}

/// Execute `plan` (already optimized) through the per-shard cache:
/// restore hit shards, execute only miss shards on `executor`, merge.
/// Returns `Ok(None)` when the plan is not eligible — the caller falls
/// back to a normal execute. Fresh shard results are stored as they
/// complete, so even an all-miss (cold) pass warms the shard tier.
///
/// `fp` must be the fingerprint of exactly `plan` over its own files —
/// the driver computes it for the whole-plan probe and hands it down so
/// the corpus is digested once per run.
pub fn execute_incremental(
    plan: &LogicalPlan,
    workers: usize,
    executor: &ExecutorKind,
    cache: &CacheManager,
    fp: &PlanFingerprint,
) -> Result<Option<PlanOutput>> {
    let phys = lower(plan)?;
    if phys.files().is_empty() || phys.has_sample() {
        return Ok(None);
    }
    anyhow::ensure!(
        fp.shards().len() == phys.files().len(),
        "fingerprint covers {} shards but the plan ingests {}",
        fp.shards().len(),
        phys.files().len()
    );
    let keys = incremental_shard_keys(plan, fp);
    let out = if phys.two_pass().is_some() {
        run_two_pass(&phys, &keys, workers, executor, cache)?
    } else {
        run_single_pass(&phys, &keys, workers, executor, cache)?
    };
    Ok(Some(out))
}

/// Single-pass plans: one cached payload per shard is the shard's final
/// `PartResult`; a warm run merges restored and fresh results exactly
/// as the cold merge would.
fn run_single_pass(
    phys: &PhysicalPlan,
    keys: &[String],
    workers: usize,
    executor: &ExecutorKind,
    cache: &CacheManager,
) -> Result<PlanOutput> {
    let n = keys.len();
    let t_restore = Instant::now();
    let mut slots: Vec<Option<PartResult>> = keys
        .iter()
        .enumerate()
        .map(|(i, key)| restore_shard(cache, key, i, false).map(|(r, _)| r))
        .collect();
    let restore_wall = t_restore.elapsed();
    let miss_idx: Vec<usize> =
        slots.iter().enumerate().filter(|(_, s)| s.is_none()).map(|(i, _)| i).collect();
    let hits = n - miss_idx.len();

    let t_pass = Instant::now();
    if !miss_idx.is_empty() {
        let sub = phys.with_files(miss_idx.iter().map(|&i| phys.files()[i].clone()).collect());
        let mut j = 0usize;
        run_miss_shards(&sub, workers, executor, &mut |r| {
            let gi = miss_idx[j];
            j += 1;
            cache.put_shard(&keys[gi], &encode_payload(&r, None))?;
            slots[gi] = Some(r);
            Ok(())
        })?;
        anyhow::ensure!(
            j == miss_idx.len(),
            "executor delivered {j} of {} miss shards",
            miss_idx.len()
        );
    }
    let pass_wall = t_pass.elapsed();

    let mut merger = Merger::new(phys.output_schema().clone(), phys.n_distinct(), phys.limit_n());
    for s in slots {
        merger.push(s.expect("every shard was restored or executed"));
    }
    let mut out = merger.finish(pass_wall, Duration::ZERO);
    finish_restore(&mut out, cache, hits, n, restore_wall);
    Ok(out)
}

/// Two-pass plans: the cached payload per shard is its pass-1 prefix
/// `PartResult` plus (when available) the estimator's partial; a warm
/// run re-fits from merged partials and resumes every prefix result
/// through the fitted stage + suffix.
fn run_two_pass(
    phys: &PhysicalPlan,
    keys: &[String],
    workers: usize,
    executor: &ExecutorKind,
    cache: &CacheManager,
) -> Result<PlanOutput> {
    let tp = phys.two_pass().expect("caller checked is_two_pass");
    let prefix = phys.prefix_plan(tp);
    let partials_ok = partial_fit_available(tp, &prefix);
    let n = keys.len();

    let t_restore = Instant::now();
    let mut slots: Vec<Option<(PartResult, Option<Vec<u8>>)>> = keys
        .iter()
        .enumerate()
        .map(|(i, key)| restore_shard(cache, key, i, partials_ok))
        .collect();
    let restore_wall = t_restore.elapsed();
    let miss_idx: Vec<usize> =
        slots.iter().enumerate().filter(|(_, s)| s.is_none()).map(|(i, _)| i).collect();
    let hits = n - miss_idx.len();

    let t_pass = Instant::now();
    if !miss_idx.is_empty() {
        let sub = prefix.with_files(miss_idx.iter().map(|&i| phys.files()[i].clone()).collect());
        let mut j = 0usize;
        run_miss_shards(&sub, workers, executor, &mut |r| {
            let gi = miss_idx[j];
            j += 1;
            let partial = if partials_ok {
                let mut acc = tp.est.accumulator().ok_or_else(|| {
                    anyhow::anyhow!(
                        "estimator {} lost its accumulator between lower and execute",
                        tp.est.name()
                    )
                })?;
                if r.part.num_rows() > 0 {
                    acc.accumulate(r.part.column(tp.in_idx))?;
                }
                acc.partial()
            } else {
                None
            };
            cache.put_shard(&keys[gi], &encode_payload(&r, partial.as_deref()))?;
            slots[gi] = Some((r, partial));
            Ok(())
        })?;
        anyhow::ensure!(
            j == miss_idx.len(),
            "executor delivered {j} of {} miss shards",
            miss_idx.len()
        );
    }

    // Re-fit over all shards. The partial fold never applies when the
    // prefix carries a pending dedup or limit, so when it does not
    // apply the `FitSink` fold re-runs the exact stream-order admission
    // the cold fit pass used — over clones, because the originals
    // continue into pass 2.
    let t_fit = Instant::now();
    let fitted = if partials_ok {
        let mut acc = tp.est.accumulator().ok_or_else(|| {
            anyhow::anyhow!(
                "estimator {} lost its accumulator between lower and execute",
                tp.est.name()
            )
        })?;
        for s in &slots {
            let (_, partial) = s.as_ref().expect("every shard was restored or executed");
            let bytes = partial.as_ref().expect("partial availability is plan-determined");
            acc.merge_partial(bytes)?;
        }
        acc.finish()?
    } else {
        let mut sink = FitSink::new(tp, &prefix)?;
        for s in &slots {
            let (r, _) = s.as_ref().expect("every shard was restored or executed");
            sink.push(r.clone())?;
        }
        sink.finish()?
    };
    let fit_wall = t_fit.elapsed();

    // Pass 2: resume every prefix result through the fitted stage and
    // the suffix ops — no shard is re-parsed from raw bytes.
    let full = phys.with_model(tp, fitted);
    let start = tp.prefix_len;
    let jobs: Vec<(usize, PartResult)> = slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| (i, s.expect("every shard was restored or executed").0))
        .collect();
    let exec = Executor::new(workers);
    let resumed = exec.map_items(jobs, |(i, r)| {
        let _lane = obs::lane_scope(obs::pool_lane());
        full.resume_ops(r, i, start)
    });
    let pass_wall = t_pass.elapsed();

    let mut merger = Merger::new(phys.output_schema().clone(), phys.n_distinct(), phys.limit_n());
    for r in resumed {
        merger.push(r);
    }
    let mut out = merger.finish(pass_wall, Duration::ZERO);
    // Same attribution as the cold two-pass: fitting is cleaning work.
    out.times.add(CLEANING, fit_wall);
    finish_restore(&mut out, cache, hits, n, restore_wall);
    Ok(out)
}

/// Probe + restore one shard payload. `None` on a miss, a corrupt or
/// undecodable payload (removed — next run is a clean miss), or a
/// payload missing a fit partial the plan requires.
fn restore_shard(
    cache: &CacheManager,
    key: &str,
    shard: usize,
    want_partial: bool,
) -> Option<(PartResult, Option<Vec<u8>>)> {
    let bytes = cache.get_shard(key)?;
    let mut sp = obs::span("restore shard", "cache");
    if sp.active() {
        sp.arg("shard", shard as u64);
        sp.arg("bytes", bytes.len() as u64);
    }
    match decode_payload(&bytes) {
        Ok((r, partial)) => {
            if want_partial && partial.is_none() {
                // A payload for this exact plan without the partial its
                // estimator supports can only be damage — drop it.
                cache.remove_shard(key);
                return None;
            }
            Some((r, partial))
        }
        Err(_) => {
            cache.remove_shard(key);
            None
        }
    }
}

/// Run the miss sub-plan's shards through the selected executor,
/// delivering each shard's `PartResult` to `sink` in (sub-plan) shard
/// order. Every route keeps the shard file as the unit of work — the
/// re-chunk fallbacks would break the 1:1 shard↔artifact mapping.
fn run_miss_shards(
    sub: &PhysicalPlan,
    workers: usize,
    executor: &ExecutorKind,
    sink: &mut dyn FnMut(PartResult) -> Result<()>,
) -> Result<()> {
    match executor {
        ExecutorKind::Fused => {
            for r in sub.collect_shard_results(workers)? {
                sink(r)?;
            }
            Ok(())
        }
        ExecutorKind::Stream(opts) => StreamExecutor::new(opts.clone()).run_shards(sub, sink),
        ExecutorKind::Process(_) | ExecutorKind::Pool(_) => {
            let opts = executor.process_options().expect("process-backed kind");
            ProcessExecutor::new(opts).run_shards(sub, sink)
        }
        ExecutorKind::Remote(opts) => RemoteExecutor::new(opts.clone()).run(sub, sink),
    }
}

/// Book-keeping shared by both strategies: report the restore as its
/// own stage (only when something was restored) and move the manager's
/// shard counters so `cache stats` pins the split.
fn finish_restore(
    out: &mut PlanOutput,
    cache: &CacheManager,
    hits: usize,
    n: usize,
    restore_wall: Duration,
) {
    if hits > 0 {
        out.times.add(&format!("{CACHE_RESTORE}({hits} of {n} shards)"), restore_wall);
    }
    cache.count_shard_probe(hits as u64, (n - hits) as u64);
}

// --- per-shard payload codec -------------------------------------------
//
// The bytes inside a kind-1 `P3PC` artifact (see [`crate::cache::artifact`]
// for the envelope). Little-endian throughout:
//
// | field        | encoding                                            |
// |--------------|-----------------------------------------------------|
// | n_rows       | u64                                                 |
// | n_cols       | u32                                                 |
// | columns      | per column: dtype u8 + cells (artifact cell codec)  |
// | counters     | 5 × u64 (ingested, nulls, empties, sampled, limited)|
// | n_slots      | u32                                                 |
// | slots        | per slot: n u64, n × u128 keys, n × u32 ids         |
// | final_ids    | u8 tag (0/1); if 1: n u64 + n × u32                 |
// | fit partial  | u8 tag (0/1); if 1: len u64 + bytes                 |
//
// Worker phase spans are deliberately not persisted: a restored shard
// did no work this run, so its phases are zero and the proportional
// stage attribution only covers shards that actually executed.

fn encode_payload(r: &PartResult, partial: Option<&[u8]>) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(&(r.part.num_rows() as u64).to_le_bytes());
    buf.extend_from_slice(&(r.part.num_columns() as u32).to_le_bytes());
    for col in r.part.columns() {
        buf.push(dtype_code(col.dtype()));
        encode_cells(&mut buf, col);
    }
    for v in [r.rows_ingested, r.nulls_dropped, r.empties_dropped, r.sampled_out, r.limited_out] {
        buf.extend_from_slice(&(v as u64).to_le_bytes());
    }
    buf.extend_from_slice(&(r.slots.len() as u32).to_le_bytes());
    for slot in &r.slots {
        buf.extend_from_slice(&(slot.keys.len() as u64).to_le_bytes());
        for k in &slot.keys {
            buf.extend_from_slice(&k.to_le_bytes());
        }
        for id in &slot.ids {
            buf.extend_from_slice(&id.to_le_bytes());
        }
    }
    match &r.final_ids {
        None => buf.push(0),
        Some(ids) => {
            buf.push(1);
            buf.extend_from_slice(&(ids.len() as u64).to_le_bytes());
            for id in ids {
                buf.extend_from_slice(&id.to_le_bytes());
            }
        }
    }
    match partial {
        None => buf.push(0),
        Some(bytes) => {
            buf.push(1);
            buf.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
            buf.extend_from_slice(bytes);
        }
    }
    buf
}

fn decode_payload(bytes: &[u8]) -> Result<(PartResult, Option<Vec<u8>>)> {
    let mut cur = Cursor::new(bytes, 0);
    let n_rows = cur.u64()? as usize;
    let n_cols = cur.u32()? as usize;
    let mut cols = Vec::with_capacity(n_cols);
    for _ in 0..n_cols {
        let dtype = dtype_from(cur.u8()?)?;
        cols.push(decode_cells(&mut cur, dtype, n_rows)?);
    }
    let rows_ingested = cur.u64()? as usize;
    let nulls_dropped = cur.u64()? as usize;
    let empties_dropped = cur.u64()? as usize;
    let sampled_out = cur.u64()? as usize;
    let limited_out = cur.u64()? as usize;
    let n_slots = cur.u32()? as usize;
    let mut slots = Vec::with_capacity(n_slots);
    for _ in 0..n_slots {
        let n = cur.u64()? as usize;
        let mut keys = Vec::with_capacity(n);
        for _ in 0..n {
            let b: [u8; 16] = cur.take(16)?.try_into().expect("take(16) is 16 bytes");
            keys.push(u128::from_le_bytes(b));
        }
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            ids.push(cur.u32()?);
        }
        slots.push(KeySlot { keys, ids });
    }
    let final_ids = match cur.u8()? {
        0 => None,
        1 => {
            let n = cur.u64()? as usize;
            let mut ids = Vec::with_capacity(n);
            for _ in 0..n {
                ids.push(cur.u32()?);
            }
            Some(ids)
        }
        t => anyhow::bail!("bad final_ids tag {t} in shard payload"),
    };
    let partial = match cur.u8()? {
        0 => None,
        1 => {
            let len = cur.u64()? as usize;
            Some(cur.take(len)?.to_vec())
        }
        t => anyhow::bail!("bad fit-partial tag {t} in shard payload"),
    };
    anyhow::ensure!(cur.remaining() == 0, "trailing bytes in shard payload");
    let r = PartResult {
        part: Partition::new(cols),
        slots,
        final_ids,
        rows_ingested,
        nulls_dropped,
        empties_dropped,
        sampled_out,
        limited_out,
        phases: Phases::default(),
    };
    Ok((r, partial))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::fingerprint;
    use crate::corpus::{generate_corpus, CorpusSpec};
    use crate::ingest::list_shards;
    use crate::pipeline::presets::case_study_plan;

    fn corpus(name: &str) -> (PathBuf, Vec<PathBuf>) {
        let dir =
            std::env::temp_dir().join(format!("p3sapp-incr-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        generate_corpus(&CorpusSpec::tiny(31), &dir).unwrap();
        let files = list_shards(&dir).unwrap();
        assert!(files.len() >= 3, "need multiple shards, got {}", files.len());
        (dir, files)
    }

    #[test]
    fn payload_roundtrips_real_part_results() {
        let (dir, files) = corpus("codec");
        let plan = case_study_plan(&files, "title", "abstract").optimize();
        let phys = lower(&plan).unwrap();
        for r in phys.collect_shard_results(2).unwrap() {
            let bytes = encode_payload(&r, Some(b"partial-state"));
            let (back, partial) = decode_payload(&bytes).unwrap();
            assert_eq!(back.part, r.part);
            assert_eq!(back.rows_ingested, r.rows_ingested);
            assert_eq!(back.nulls_dropped, r.nulls_dropped);
            assert_eq!(back.empties_dropped, r.empties_dropped);
            assert_eq!(back.sampled_out, r.sampled_out);
            assert_eq!(back.limited_out, r.limited_out);
            assert_eq!(back.final_ids, r.final_ids);
            assert_eq!(back.slots.len(), r.slots.len());
            for (a, b) in back.slots.iter().zip(&r.slots) {
                assert_eq!(a.keys, b.keys);
                assert_eq!(a.ids, b.ids);
            }
            assert_eq!(partial.as_deref(), Some(&b"partial-state"[..]));

            let (_, none) = decode_payload(&encode_payload(&r, None)).unwrap();
            assert!(none.is_none());
            // Truncation anywhere must error, never panic or misread.
            assert!(decode_payload(&bytes[..bytes.len() - 1]).is_err());
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn warm_append_executes_only_the_new_shard_and_matches_cold() {
        let (dir, files) = corpus("append");
        let cache = CacheManager::open(dir.join("cache")).unwrap();
        let grown = files.clone();
        let initial = files[..files.len() - 1].to_vec();

        // Cold pass over the initial corpus: all shards miss, all store.
        let plan1 = case_study_plan(&initial, "title", "abstract").optimize();
        let fp1 = fingerprint(&plan1.render(), &initial).unwrap();
        let out1 = execute_incremental(&plan1, 2, &ExecutorKind::Fused, &cache, &fp1)
            .unwrap()
            .expect("eligible plan");
        let s = cache.stats();
        assert_eq!((s.shard_hits, s.shard_misses), (0, initial.len() as u64));
        assert_eq!(out1.frame, plan1.execute(2).unwrap().frame);
        assert!(!out1.times.stages().any(|(st, _)| st.starts_with(CACHE_RESTORE)));

        // Warm pass over the grown corpus: only the appended shard runs.
        let plan2 = case_study_plan(&grown, "title", "abstract").optimize();
        let fp2 = fingerprint(&plan2.render(), &grown).unwrap();
        let out2 = execute_incremental(&plan2, 2, &ExecutorKind::Fused, &cache, &fp2)
            .unwrap()
            .expect("eligible plan");
        let s = cache.stats();
        assert_eq!(s.shard_hits, initial.len() as u64);
        assert_eq!(s.shard_misses, initial.len() as u64 + 1);
        let restore = format!("{CACHE_RESTORE}({} of {} shards)", initial.len(), grown.len());
        assert!(out2.times.stages().any(|(st, _)| st == restore), "{:?}",
            out2.times.stages().map(|(st, _)| st.to_string()).collect::<Vec<_>>());
        // Byte-identical to a cold full run, counters included.
        let cold = plan2.execute(2).unwrap();
        assert_eq!(out2.frame, cold.frame);
        assert_eq!(out2.rows_ingested, cold.rows_ingested);
        assert_eq!(out2.rows_out, cold.rows_out);
        assert_eq!(out2.dups_dropped, cold.dups_dropped);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sample_plans_are_not_eligible() {
        let (dir, files) = corpus("sample");
        let plan = crate::plan::LogicalPlan::scan(files.clone(), &["title", "abstract"])
            .sample(0.5, 7)
            .collect()
            .optimize();
        assert!(!incremental_eligible(&plan));
        let cache = CacheManager::open(dir.join("cache")).unwrap();
        let fp = fingerprint(&plan.render(), &files).unwrap();
        let out =
            execute_incremental(&plan, 2, &ExecutorKind::Fused, &cache, &fp).unwrap();
        assert!(out.is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
