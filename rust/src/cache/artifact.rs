//! On-disk artifact format for cached [`PlanOutput`] frames — the
//! same little-endian binary discipline as the trainer's `P3CK`
//! checkpoints (`runtime/checkpoint.rs`), applied to a columnar frame.
//!
//! Layout (all integers little-endian):
//! ```text
//! magic    b"P3PC"        4 bytes
//! version  u32            (3)
//! key_len  u32, key bytes (fingerprint hex — verified on load)
//! kind     u8             (0 = whole-plan frame, 1 = per-shard payload)
//! rows_ingested  u64      \
//! nulls_dropped  u64       |
//! dups_dropped   u64       | the drop accounting the reports consume
//! empties_dropped u64      | (sampled/limited: rows a Sample/Limit
//! sampled_out    u64       |  op excluded — v2 addition)
//! limited_out    u64      /
//! n_rows   u64
//! n_cols   u32
//! per column:
//!   name_len u32, name bytes (utf-8)
//!   dtype    u8   (0 = string, 1 = array<string>, 2 = vector)
//!   per cell (n_rows of them):
//!     tag u8 (0 = null, 1 = present), then if present:
//!       string:        len u32, utf-8 bytes
//!       array<string>: count u32, then per token len u32 + bytes
//!       vector:        count u32, then count × f32
//! digest   u64            xxh64 over bytes[4 .. len-8], seed 0
//! ```
//!
//! Kind-1 artifacts (the incremental cache's per-shard entries, see
//! `crate::plan` and [`save_raw`]/[`load_raw`]) replace everything
//! between `kind` and `digest` with an opaque payload the plan layer
//! encodes — the envelope discipline (magic, version, key, trailing
//! digest, atomic save) is identical.
//!
//! The trailing digest makes truncation and bit-rot detectable without
//! parsing; [`load`] additionally bounds-checks every read, so a corrupt
//! artifact can only ever produce an `Err` — which the
//! [`super::CacheManager`] maps to a cache **miss**, never a user-facing
//! error.

use super::fingerprint::xxh64;
use crate::frame::{Column, DType, Field, LocalFrame, Schema};
use crate::plan::PlanOutput;
use crate::Result;
use std::path::Path;

pub(super) const MAGIC: &[u8; 4] = b"P3PC";
/// v3: a `kind` byte after the key distinguishes whole-plan frame
/// artifacts from the incremental cache's per-shard payloads (v2 grew
/// the accounting block with `sampled_out` / `limited_out`). Artifacts
/// from any earlier version fail the version check and are treated as
/// clean misses — the pass re-executes and re-stores; never an error.
pub(super) const VERSION: u32 = 3;
/// Whole-plan frame artifact (the original `P3PC` payload).
const KIND_FRAME: u8 = 0;
/// Per-shard payload artifact (opaque bytes the plan layer encodes).
const KIND_SHARD: u8 = 1;
/// Magic + version + key_len + kind is the minimum readable prefix; the
/// digest trails the file.
const MIN_LEN: usize = 4 + 4 + 4 + 1 + 8;

/// What an artifact restores: the cleaned frame plus the row accounting.
/// Stage times are *not* stored — a restored run reports its own
/// `cache_restore` wall time instead (the honest Tables 2–4 number).
#[derive(Debug, Clone)]
pub struct CachedFrame {
    pub frame: LocalFrame,
    pub rows_ingested: usize,
    pub nulls_dropped: usize,
    pub dups_dropped: usize,
    pub empties_dropped: usize,
    pub sampled_out: usize,
    pub limited_out: usize,
}

pub(crate) fn dtype_code(d: DType) -> u8 {
    match d {
        DType::Str => 0,
        DType::Tokens => 1,
        DType::Vector => 2,
    }
}

pub(crate) fn dtype_from(code: u8) -> Result<DType> {
    match code {
        0 => Ok(DType::Str),
        1 => Ok(DType::Tokens),
        2 => Ok(DType::Vector),
        other => anyhow::bail!("artifact: unknown dtype code {other}"),
    }
}

/// Serialize `out` under cache key `key` into the `P3PC` byte layout.
pub fn encode(key: &str, out: &PlanOutput) -> Vec<u8> {
    let mut buf = Vec::with_capacity(1024);
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(key.len() as u32).to_le_bytes());
    buf.extend_from_slice(key.as_bytes());
    buf.push(KIND_FRAME);
    for n in [
        out.rows_ingested,
        out.nulls_dropped,
        out.dups_dropped,
        out.empties_dropped,
        out.sampled_out,
        out.limited_out,
    ] {
        buf.extend_from_slice(&(n as u64).to_le_bytes());
    }
    let frame = &out.frame;
    buf.extend_from_slice(&(frame.num_rows() as u64).to_le_bytes());
    buf.extend_from_slice(&(frame.num_columns() as u32).to_le_bytes());
    for (field, col) in frame.schema().fields().iter().zip(frame.columns()) {
        buf.extend_from_slice(&(field.name.len() as u32).to_le_bytes());
        buf.extend_from_slice(field.name.as_bytes());
        buf.push(dtype_code(field.dtype));
        encode_cells(&mut buf, col);
    }
    let digest = xxh64(&buf[4..], 0);
    buf.extend_from_slice(&digest.to_le_bytes());
    buf
}

/// Append one column's cells (a tag byte per row, then the payload) in
/// the `P3PC` cell layout. Shared with the multi-process executor's wire
/// format (`crate::plan::process`), which frames whole partitions with
/// the same discipline.
pub(crate) fn encode_cells(buf: &mut Vec<u8>, col: &Column) {
    match col {
        Column::Str(cells) => {
            for cell in cells {
                match cell {
                    None => buf.push(0),
                    Some(s) => {
                        buf.push(1);
                        buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
                        buf.extend_from_slice(s.as_bytes());
                    }
                }
            }
        }
        Column::Tokens(cells) => {
            for cell in cells {
                match cell {
                    None => buf.push(0),
                    Some(tokens) => {
                        buf.push(1);
                        buf.extend_from_slice(&(tokens.len() as u32).to_le_bytes());
                        for t in tokens {
                            buf.extend_from_slice(&(t.len() as u32).to_le_bytes());
                            buf.extend_from_slice(t.as_bytes());
                        }
                    }
                }
            }
        }
        Column::Vecs(cells) => {
            for cell in cells {
                match cell {
                    None => buf.push(0),
                    Some(xs) => {
                        buf.push(1);
                        buf.extend_from_slice(&(xs.len() as u32).to_le_bytes());
                        for x in xs {
                            buf.extend_from_slice(&x.to_le_bytes());
                        }
                    }
                }
            }
        }
    }
}

/// Decode `n_rows` cells of `dtype` written by [`encode_cells`]. Every
/// read is bounds-checked and declared token/vector counts are validated
/// against the bytes actually present before any allocation sized from
/// them.
pub(crate) fn decode_cells(cur: &mut Cursor<'_>, dtype: DType, n_rows: usize) -> Result<Column> {
    let col = match dtype {
        DType::Str => {
            let mut cells = Vec::with_capacity(n_rows);
            for _ in 0..n_rows {
                cells.push(match cur.u8()? {
                    0 => None,
                    _ => Some(cur.str()?),
                });
            }
            Column::Str(cells)
        }
        DType::Tokens => {
            let mut cells = Vec::with_capacity(n_rows);
            for _ in 0..n_rows {
                cells.push(match cur.u8()? {
                    0 => None,
                    _ => {
                        let count = cur.u32()? as usize;
                        // Each token costs at least its 4-byte length.
                        anyhow::ensure!(
                            count.saturating_mul(4) <= cur.remaining(),
                            "artifact token count {count} exceeds remaining bytes"
                        );
                        let mut tokens = Vec::with_capacity(count);
                        for _ in 0..count {
                            tokens.push(cur.str()?);
                        }
                        Some(tokens)
                    }
                });
            }
            Column::Tokens(cells)
        }
        DType::Vector => {
            let mut cells = Vec::with_capacity(n_rows);
            for _ in 0..n_rows {
                cells.push(match cur.u8()? {
                    0 => None,
                    _ => {
                        let count = cur.u32()? as usize;
                        anyhow::ensure!(
                            count.saturating_mul(4) <= cur.remaining(),
                            "artifact vector count {count} exceeds remaining bytes"
                        );
                        let mut xs = Vec::with_capacity(count);
                        for _ in 0..count {
                            xs.push(f32::from_le_bytes(cur.take(4)?.try_into().unwrap()));
                        }
                        Some(xs)
                    }
                });
            }
            Column::Vecs(cells)
        }
    };
    Ok(col)
}

/// Bounds-checked cursor over an artifact's (or wire frame's) bytes.
/// Shared with `crate::plan::process`, whose job/result frames follow
/// the same little-endian + trailing-digest conventions.
pub(crate) struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    /// Cursor over `buf` starting at byte offset `pos`.
    pub(crate) fn new(buf: &'a [u8], pos: usize) -> Cursor<'a> {
        Cursor { buf, pos }
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| anyhow::anyhow!("artifact truncated at offset {}", self.pos))?;
        let slice = &self.buf[self.pos..end];
        self.pos = end;
        Ok(slice)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        Ok(String::from_utf8(self.take(len)?.to_vec())?)
    }

    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Verify an artifact's full envelope (magic, version, key, trailing
/// digest) without deserializing the frame. Reads — and digests — the
/// whole file; `false` for any unreadable, foreign, stale-versioned or
/// corrupt file.
pub fn verify(path: &Path, key: &str) -> bool {
    let Ok(bytes) = std::fs::read(path) else { return false };
    check_envelope(&bytes, key, KIND_FRAME).is_ok()
}

/// O(header) probe: check magic, version and key from the first few
/// dozen bytes only, never touching the payload or digest. Suitable for
/// EXPLAIN's hit rendering, where reading a multi-hundred-MB artifact
/// just to print one line would double the warm run's I/O. A file that
/// passes this but is truncated mid-payload still loads as a miss —
/// [`load`] revalidates everything.
pub fn verify_header(path: &Path, key: &str) -> bool {
    use std::io::Read;
    let Ok(mut f) = std::fs::File::open(path) else { return false };
    let mut head = [0u8; 12];
    if f.read_exact(&mut head).is_err()
        || &head[..4] != MAGIC
        || u32::from_le_bytes(head[4..8].try_into().unwrap()) != VERSION
    {
        return false;
    }
    let key_len = u32::from_le_bytes(head[8..12].try_into().unwrap()) as usize;
    if key_len != key.len() {
        return false;
    }
    let mut got = vec![0u8; key_len + 1];
    f.read_exact(&mut got).is_ok()
        && &got[..key_len] == key.as_bytes()
        && got[key_len] == KIND_FRAME
}

fn check_envelope<'a>(bytes: &'a [u8], key: &str, kind: u8) -> Result<Cursor<'a>> {
    anyhow::ensure!(bytes.len() >= MIN_LEN, "artifact too short ({} bytes)", bytes.len());
    anyhow::ensure!(&bytes[..4] == MAGIC, "not a p3sapp plan-cache artifact (bad magic)");
    let body = &bytes[..bytes.len() - 8];
    let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    anyhow::ensure!(xxh64(&body[4..], 0) == stored, "artifact digest mismatch");
    let mut cur = Cursor { buf: body, pos: 4 };
    let version = cur.u32()?;
    anyhow::ensure!(version == VERSION, "unsupported artifact version {version}");
    let got_key = cur.str()?;
    anyhow::ensure!(
        got_key == key,
        "artifact key mismatch: stored {got_key}, expected {key}"
    );
    let got_kind = cur.u8()?;
    anyhow::ensure!(
        got_kind == kind,
        "artifact kind mismatch: stored {got_kind}, expected {kind}"
    );
    Ok(cur)
}

/// Load and fully validate an artifact. Errors on *any* defect —
/// truncation, digest mismatch, key mismatch, malformed payload; the
/// cache manager treats every error as a miss.
pub fn load(path: &Path, key: &str) -> Result<CachedFrame> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("read artifact {}: {e}", path.display()))?;
    let mut cur = check_envelope(&bytes, key, KIND_FRAME)?;
    let rows_ingested = cur.u64()? as usize;
    let nulls_dropped = cur.u64()? as usize;
    let dups_dropped = cur.u64()? as usize;
    let empties_dropped = cur.u64()? as usize;
    let sampled_out = cur.u64()? as usize;
    let limited_out = cur.u64()? as usize;
    let n_rows = cur.u64()? as usize;
    let n_cols = cur.u32()? as usize;
    // Never trust declared counts with allocations before checking them
    // against the bytes actually present (a digest-valid but foreign or
    // hand-crafted artifact must error, not abort): every column costs
    // at least name_len(4) + dtype(1) + one tag byte per row.
    anyhow::ensure!(
        n_cols.saturating_mul(n_rows.saturating_add(5)) <= cur.remaining(),
        "artifact declares more cells ({n_cols} cols x {n_rows} rows) than it contains"
    );
    let mut fields = Vec::with_capacity(n_cols);
    let mut columns = Vec::with_capacity(n_cols);
    for _ in 0..n_cols {
        let name = cur.str()?;
        let dtype = dtype_from(cur.u8()?)?;
        let col = decode_cells(&mut cur, dtype, n_rows)?;
        fields.push(Field::new(name, dtype));
        columns.push(col);
    }
    anyhow::ensure!(
        cur.pos == cur.buf.len(),
        "artifact has {} trailing payload bytes",
        cur.buf.len() - cur.pos
    );
    let frame = LocalFrame::from_columns(Schema::new(fields), columns)?;
    anyhow::ensure!(
        frame.num_rows() == n_rows,
        "artifact row count mismatch: {} != {n_rows}",
        frame.num_rows()
    );
    Ok(CachedFrame {
        frame,
        rows_ingested,
        nulls_dropped,
        dups_dropped,
        empties_dropped,
        sampled_out,
        limited_out,
    })
}

/// Atomically persist `out` to `path` (write to a sibling temp file,
/// then rename). The temp name is unique per process *and* per call, so
/// two processes sharing a cache dir that store the same key cannot
/// interleave writes into one temp file — each renames its own complete
/// artifact, last one wins, and readers only ever observe whole files.
pub fn save(path: &Path, key: &str, out: &PlanOutput) -> Result<()> {
    write_atomic(path, &encode(key, out))
}

/// Persist an opaque per-shard payload under the same `P3PC` envelope
/// (kind 1): the plan layer's incremental cache stores one serialized
/// shard result per artifact, keyed by
/// [`super::fingerprint::shard_key`]. Same atomic temp+rename
/// discipline as [`save`].
pub fn save_raw(path: &Path, key: &str, payload: &[u8]) -> Result<()> {
    let mut buf = Vec::with_capacity(MIN_LEN + key.len() + payload.len());
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(key.len() as u32).to_le_bytes());
    buf.extend_from_slice(key.as_bytes());
    buf.push(KIND_SHARD);
    buf.extend_from_slice(payload);
    let digest = xxh64(&buf[4..], 0);
    buf.extend_from_slice(&digest.to_le_bytes());
    write_atomic(path, &buf)
}

/// Load a per-shard payload saved by [`save_raw`], validating the full
/// envelope (magic, version, key, kind, trailing digest). Errors on any
/// defect; the cache manager treats every error as a miss.
pub fn load_raw(path: &Path, key: &str) -> Result<Vec<u8>> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("read artifact {}: {e}", path.display()))?;
    let mut cur = check_envelope(&bytes, key, KIND_SHARD)?;
    let n = cur.remaining();
    Ok(cur.take(n)?.to_vec())
}

fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let tmp = path.with_extension(format!(
        "{}-{}.tmp",
        std::process::id(),
        SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    if let Err(e) = std::fs::write(&tmp, bytes) {
        let _ = std::fs::remove_file(&tmp);
        anyhow::bail!("write artifact {}: {e}", tmp.display());
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        anyhow::bail!("rename artifact into {}: {e}", path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::StageTimes;
    use std::path::PathBuf;

    fn sample_output() -> PlanOutput {
        let frame = LocalFrame::from_columns(
            Schema::new(vec![
                Field::new("title", DType::Str),
                Field::new("words", DType::Tokens),
                Field::new("tfidf", DType::Vector),
            ]),
            vec![
                Column::Str(vec![Some("deep nets".into()), None, Some(String::new())]),
                Column::Tokens(vec![Some(vec!["deep".into(), "nets".into()]), Some(vec![]), None]),
                Column::Vecs(vec![None, Some(vec![0.5, -1.25]), Some(vec![])]),
            ],
        )
        .unwrap();
        PlanOutput {
            frame,
            times: StageTimes::new(),
            rows_ingested: 9,
            rows_out: 3,
            nulls_dropped: 2,
            dups_dropped: 1,
            empties_dropped: 1,
            sampled_out: 1,
            limited_out: 1,
        }
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("p3pc-art-{name}-{}.p3pc", std::process::id()))
    }

    #[test]
    fn roundtrip_is_byte_identical() {
        let out = sample_output();
        let path = tmp("rt");
        save(&path, "deadbeef", &out).unwrap();
        assert!(verify(&path, "deadbeef"));
        let restored = load(&path, "deadbeef").unwrap();
        assert_eq!(restored.frame, out.frame);
        assert_eq!(restored.rows_ingested, 9);
        assert_eq!(restored.nulls_dropped, 2);
        assert_eq!(restored.dups_dropped, 1);
        assert_eq!(restored.empties_dropped, 1);
        assert_eq!(restored.sampled_out, 1);
        assert_eq!(restored.limited_out, 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_wrong_key_and_version() {
        let out = sample_output();
        let path = tmp("key");
        save(&path, "key-a", &out).unwrap();
        assert!(!verify(&path, "key-b"));
        assert!(load(&path, "key-b").is_err());
        // Version bump (with a re-stamped digest) must be rejected too.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4] = 99;
        let n = bytes.len();
        let digest = xxh64(&bytes[4..n - 8], 0);
        bytes[n - 8..].copy_from_slice(&digest.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(!verify(&path, "key-a"));
        assert!(load(&path, "key-a").is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn detects_truncation_and_bitflips() {
        let out = sample_output();
        let path = tmp("corrupt");
        save(&path, "k", &out).unwrap();
        let good = std::fs::read(&path).unwrap();
        // Truncate at every structurally interesting point.
        for cut in [0, 3, MIN_LEN - 1, good.len() / 2, good.len() - 1] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(load(&path, "k").is_err(), "cut at {cut}");
            assert!(!verify(&path, "k"), "cut at {cut}");
        }
        // Single bit flip in the payload flips the digest.
        let mut flipped = good.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x10;
        std::fs::write(&path, &flipped).unwrap();
        assert!(load(&path, "k").is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn digest_valid_but_absurd_counts_error_instead_of_allocating() {
        // A foreign artifact can carry a correct (unkeyed) digest, so
        // declared counts must be validated against the bytes actually
        // present before any allocation sized from them.
        let path = tmp("absurd");
        save(&path, "k", &sample_output()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // n_rows sits after magic(4) + version(4) + key_len(4) + key(1)
        // + kind(1) + six u64 counters(48).
        let n_rows_at = 14 + 48;
        bytes[n_rows_at..n_rows_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let n = bytes.len();
        let digest = xxh64(&bytes[4..n - 8], 0);
        bytes[n - 8..].copy_from_slice(&digest.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(verify(&path, "k"), "digest is deliberately valid");
        assert!(load(&path, "k").is_err(), "counts exceed payload -> error, not abort");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn raw_payload_roundtrips_and_kinds_do_not_cross() {
        let path = tmp("raw");
        save_raw(&path, "shard-key", b"opaque shard payload").unwrap();
        assert_eq!(load_raw(&path, "shard-key").unwrap(), b"opaque shard payload");
        assert!(load_raw(&path, "other-key").is_err());
        // A shard artifact is not a frame artifact and vice versa.
        assert!(load(&path, "shard-key").is_err());
        assert!(!verify(&path, "shard-key"));
        assert!(!verify_header(&path, "shard-key"));
        save(&path, "shard-key", &sample_output()).unwrap();
        assert!(load_raw(&path, "shard-key").is_err());
        assert!(load(&path, "shard-key").is_ok());
        // Truncation and bit rot are caught by the trailing digest.
        save_raw(&path, "shard-key", b"opaque shard payload").unwrap();
        let good = std::fs::read(&path).unwrap();
        std::fs::write(&path, &good[..good.len() - 3]).unwrap();
        assert!(load_raw(&path, "shard-key").is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn stale_v2_layout_artifact_is_rejected_not_misread() {
        // A pre-incremental (v2) artifact has no kind byte: its counter
        // block starts where v3 expects the kind. The version check must
        // reject it before any payload interpretation.
        let path = tmp("v2");
        let key = "stale-key";
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&(key.len() as u32).to_le_bytes());
        bytes.extend_from_slice(key.as_bytes());
        for n in [9u64, 2, 1, 1, 0, 0] {
            bytes.extend_from_slice(&n.to_le_bytes());
        }
        bytes.extend_from_slice(&0u64.to_le_bytes()); // n_rows
        bytes.extend_from_slice(&0u32.to_le_bytes()); // n_cols
        let digest = xxh64(&bytes[4..], 0);
        bytes.extend_from_slice(&digest.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(!verify(&path, key));
        assert!(!verify_header(&path, key));
        let err = load(&path, key).unwrap_err().to_string();
        assert!(err.contains("unsupported artifact version 2"), "{err}");
        assert!(load_raw(&path, key).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn garbage_file_is_an_error_not_a_panic() {
        let path = tmp("junk");
        std::fs::write(&path, b"not an artifact at all").unwrap();
        assert!(load(&path, "k").is_err());
        assert!(!verify(&path, "k"));
        assert!(!verify_header(&path, "k"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn verify_header_checks_only_the_envelope_prefix() {
        let path = tmp("hdr");
        save(&path, "hdr-key", &sample_output()).unwrap();
        assert!(verify_header(&path, "hdr-key"));
        assert!(!verify_header(&path, "other-key"));
        assert!(!verify_header(&path.with_extension("missing"), "hdr-key"));
        // Payload truncation is invisible to the header probe by design
        // (load() still rejects it) — but losing the header itself is not.
        let good = std::fs::read(&path).unwrap();
        std::fs::write(&path, &good[..good.len() - 10]).unwrap();
        assert!(verify_header(&path, "hdr-key"));
        assert!(load(&path, "hdr-key").is_err());
        std::fs::write(&path, &good[..10]).unwrap();
        assert!(!verify_header(&path, "hdr-key"));
        std::fs::remove_file(&path).unwrap();
    }
}
