//! Cache-key derivation: what makes two preprocessing runs "the same
//! work".
//!
//! A [`PlanFingerprint`] hashes two things together:
//!
//! 1. the **optimized logical plan render** — any change to the op list
//!    (different columns, an extra stage, a different fusion outcome)
//!    changes the key, so a plan-shape change can never restore a stale
//!    frame; and
//! 2. the **per-shard identity** of every input file: path, byte
//!    length and an xxhash-style content digest.
//!
//! The shard mtime is captured in [`ShardIdentity`] for diagnostics
//! (`repro cache stats` age reporting) but deliberately **excluded from
//! the key bits**: a shard that was touched (or re-downloaded) with
//! byte-identical content still hits, because the digest — not the
//! timestamp — is what names the bytes. Conversely an edit that
//! carefully preserves length and mtime still misses, because the
//! digest changes. `rust/tests/cache_roundtrip.rs` pins both
//! behaviours.

use crate::Result;
use std::path::{Path, PathBuf};

const PRIME64_1: u64 = 0x9E37_79B1_85EB_CA87;
const PRIME64_2: u64 = 0xC2B2_AE3D_27D4_EB4F;
const PRIME64_3: u64 = 0x1656_67B1_9E37_79F9;
const PRIME64_4: u64 = 0x85EB_CA77_C2B2_AE63;
const PRIME64_5: u64 = 0x27D4_EB2F_1656_67C5;

#[inline]
fn round(acc: u64, input: u64) -> u64 {
    acc.wrapping_add(input.wrapping_mul(PRIME64_2))
        .rotate_left(31)
        .wrapping_mul(PRIME64_1)
}

#[inline]
fn merge_round(acc: u64, val: u64) -> u64 {
    (acc ^ round(0, val)).wrapping_mul(PRIME64_1).wrapping_add(PRIME64_4)
}

#[inline]
fn read_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().unwrap())
}

#[inline]
fn read_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes(b[..4].try_into().unwrap())
}

/// XXH64 — the dependency-free 64-bit content digest used for shard
/// identities and artifact integrity (same role xxhash plays in Spark's
/// shuffle checksums). One pass, 8 bytes/step on the wide loop.
///
/// ```
/// use p3sapp::cache::xxh64;
///
/// assert_eq!(xxh64(b"abc", 0), xxh64(b"abc", 0)); // deterministic
/// assert_ne!(xxh64(b"abc", 0), xxh64(b"abd", 0)); // content-sensitive
/// assert_ne!(xxh64(b"abc", 0), xxh64(b"abc", 1)); // seed-sensitive
/// ```
pub fn xxh64(data: &[u8], seed: u64) -> u64 {
    let mut p = data;
    let mut h = if data.len() >= 32 {
        let mut v1 = seed.wrapping_add(PRIME64_1).wrapping_add(PRIME64_2);
        let mut v2 = seed.wrapping_add(PRIME64_2);
        let mut v3 = seed;
        let mut v4 = seed.wrapping_sub(PRIME64_1);
        while p.len() >= 32 {
            v1 = round(v1, read_u64(&p[0..8]));
            v2 = round(v2, read_u64(&p[8..16]));
            v3 = round(v3, read_u64(&p[16..24]));
            v4 = round(v4, read_u64(&p[24..32]));
            p = &p[32..];
        }
        let mut h = v1
            .rotate_left(1)
            .wrapping_add(v2.rotate_left(7))
            .wrapping_add(v3.rotate_left(12))
            .wrapping_add(v4.rotate_left(18));
        h = merge_round(h, v1);
        h = merge_round(h, v2);
        h = merge_round(h, v3);
        merge_round(h, v4)
    } else {
        seed.wrapping_add(PRIME64_5)
    };
    h = h.wrapping_add(data.len() as u64);
    while p.len() >= 8 {
        h ^= round(0, read_u64(p));
        h = h.rotate_left(27).wrapping_mul(PRIME64_1).wrapping_add(PRIME64_4);
        p = &p[8..];
    }
    if p.len() >= 4 {
        h ^= (read_u32(p) as u64).wrapping_mul(PRIME64_1);
        h = h.rotate_left(23).wrapping_mul(PRIME64_2).wrapping_add(PRIME64_3);
        p = &p[4..];
    }
    for &b in p {
        h ^= (b as u64).wrapping_mul(PRIME64_5);
        h = h.rotate_left(11).wrapping_mul(PRIME64_1);
    }
    h ^= h >> 33;
    h = h.wrapping_mul(PRIME64_2);
    h ^= h >> 29;
    h = h.wrapping_mul(PRIME64_3);
    h ^= h >> 32;
    h
}

/// Identity of one input shard at fingerprint time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardIdentity {
    pub path: PathBuf,
    /// Byte length (part of the key).
    pub len: u64,
    /// Modification time in nanos since the epoch, as observed when the
    /// fingerprint was taken — **not part of the key** (see module
    /// docs); carried so callers inspecting a [`PlanFingerprint`] can
    /// see the stat-level identity next to the digest. Zero when the
    /// filesystem reports no mtime.
    pub mtime_nanos: u128,
    /// xxhash-style digest of the full file contents (part of the key).
    pub digest: u64,
}

/// Fingerprint one shard: stat + full-content digest.
pub fn shard_identity(path: &Path) -> Result<ShardIdentity> {
    let meta = std::fs::metadata(path)
        .map_err(|e| anyhow::anyhow!("fingerprint stat {}: {e}", path.display()))?;
    let mtime_nanos = meta
        .modified()
        .ok()
        .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("fingerprint read {}: {e}", path.display()))?;
    Ok(ShardIdentity {
        path: path.to_path_buf(),
        len: bytes.len() as u64,
        mtime_nanos,
        digest: xxh64(&bytes, 0),
    })
}

/// A complete cache key: the 128-bit hex key plus the shard identities
/// it was derived from (kept for `cache stats` style diagnostics).
#[derive(Debug, Clone)]
pub struct PlanFingerprint {
    key: String,
    shards: Vec<ShardIdentity>,
}

impl PlanFingerprint {
    /// The 32-hex-char content-addressed key (artifact file stem).
    pub fn key(&self) -> &str {
        &self.key
    }

    pub fn shards(&self) -> &[ShardIdentity] {
        &self.shards
    }
}

/// Derive the cache key for running `plan_render` (the **optimized**
/// logical plan's [`crate::plan::LogicalPlan::render`] output) over
/// `files`. Reads every shard once to digest it — a sequential pass that
/// is orders of magnitude cheaper than parsing and cleaning the same
/// bytes. Because stage and estimator `describe()` output carries every
/// fit-relevant parameter (`IDF` min_df, `HashingTF` bucket count), the
/// key covers the fitted-model state too: same key ⟹ same fitted model.
///
/// Callers that hold a [`super::CacheManager`] should go through
/// [`super::CacheManager::fingerprint_for`], which memoizes the digest
/// pass in-process (a stat per shard revalidates it) so EXPLAIN and the
/// driver run that follows read the corpus once, not three times.
///
/// ```
/// use p3sapp::cache::fingerprint;
///
/// // No shard files: the key still covers the plan shape.
/// let a = fingerprint("Ingest\nCollect\n", &[]).unwrap();
/// let b = fingerprint("Ingest\nDropNulls\nCollect\n", &[]).unwrap();
/// assert_ne!(a.key(), b.key());
/// assert_eq!(a.key().len(), 32);
/// ```
/// Derive the per-shard cache key for running `plan_render` over one
/// shard: the **plan shape** × the **shard content** — nothing else.
///
/// Two deliberate exclusions make appended corpora O(delta):
///
/// - The `Ingest [N files]` line of the render is normalized to drop the
///   file count, so adding a shard to the corpus leaves every other
///   shard's key unchanged (the whole-plan [`fingerprint`] still covers
///   the full file list — these keys name *per-shard* work).
/// - The shard's path and mtime are excluded: like the whole-plan key,
///   the digest names the bytes, so a renamed or re-downloaded
///   byte-identical shard still hits, and the key is independent of the
///   shard's position in the file list.
///
/// A `1u8` domain marker separates this material from the whole-plan
/// key's per-file `0u8` records, so a one-shard corpus never collides
/// with its own whole-plan artifact key.
pub fn shard_key(plan_render: &str, shard: &ShardIdentity) -> String {
    let mut material = Vec::with_capacity(plan_render.len() + 32);
    for line in plan_render.lines() {
        let normalized = line
            .strip_prefix("Ingest [")
            .and_then(|rest| rest.find("] ").map(|end| &rest[end + 2..]));
        match normalized {
            Some(rest) => {
                material.extend_from_slice(b"Ingest ");
                material.extend_from_slice(rest.as_bytes());
            }
            None => material.extend_from_slice(line.as_bytes()),
        }
        material.push(b'\n');
    }
    material.push(1);
    material.extend_from_slice(&shard.len.to_le_bytes());
    material.extend_from_slice(&shard.digest.to_le_bytes());
    let lo = xxh64(&material, 0);
    let hi = xxh64(&material, PRIME64_5);
    format!("{hi:016x}{lo:016x}")
}

pub fn fingerprint(plan_render: &str, files: &[std::path::PathBuf]) -> Result<PlanFingerprint> {
    let mut shards = Vec::with_capacity(files.len());
    let mut material = Vec::with_capacity(plan_render.len() + files.len() * 64);
    material.extend_from_slice(plan_render.as_bytes());
    for path in files {
        let id = shard_identity(path)?;
        // Key bits: path, length, content digest. NOT mtime (module docs).
        material.push(0);
        material.extend_from_slice(id.path.to_string_lossy().as_bytes());
        material.extend_from_slice(&id.len.to_le_bytes());
        material.extend_from_slice(&id.digest.to_le_bytes());
        shards.push(id);
    }
    let lo = xxh64(&material, 0);
    let hi = xxh64(&material, PRIME64_5);
    Ok(PlanFingerprint { key: format!("{hi:016x}{lo:016x}"), shards })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xxh64_is_deterministic_and_sensitive() {
        let data = b"the quick brown fox jumps over the lazy dog, twice over";
        assert!(data.len() > 32, "exercise the wide loop");
        assert_eq!(xxh64(data, 7), xxh64(data, 7));
        assert_ne!(xxh64(data, 7), xxh64(data, 8));
        let mut edited = data.to_vec();
        edited[40] ^= 1;
        assert_ne!(xxh64(data, 7), xxh64(&edited, 7));
        // Every tail length hashes (and differs from its neighbours).
        let mut seen = std::collections::HashSet::new();
        for n in 0..data.len() {
            assert!(seen.insert(xxh64(&data[..n], 0)), "collision at len {n}");
        }
    }

    #[test]
    fn fingerprint_covers_plan_and_content_but_not_mtime() {
        let dir = std::env::temp_dir().join(format!("p3pc-fp-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let shard = dir.join("s.json");
        std::fs::write(&shard, b"{\"title\": \"a\"}\n").unwrap();
        let files = vec![shard.clone()];

        let base = fingerprint("plan-a", &files).unwrap();
        assert_eq!(base.key().len(), 32);
        assert_eq!(base.shards().len(), 1);
        let identity = &base.shards()[0];
        assert_eq!(identity.len, 15);
        assert!(identity.mtime_nanos > 0, "stat identity captured for inspection");
        // Plan shape changes the key.
        assert_ne!(base.key(), fingerprint("plan-b", &files).unwrap().key());
        // Rewriting identical bytes (mtime moves) does not.
        std::fs::write(&shard, b"{\"title\": \"a\"}\n").unwrap();
        assert_eq!(base.key(), fingerprint("plan-a", &files).unwrap().key());
        // A same-length content edit does.
        std::fs::write(&shard, b"{\"title\": \"b\"}\n").unwrap();
        assert_ne!(base.key(), fingerprint("plan-a", &files).unwrap().key());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shard_key_ignores_file_count_path_and_mtime_but_not_content_or_plan() {
        let id = |path: &str, len: u64, digest: u64| ShardIdentity {
            path: PathBuf::from(path),
            len,
            mtime_nanos: 7,
            digest,
        };
        let base = shard_key("Ingest [3 files] project=[title]\nCollect\n", &id("/a/s0.json", 10, 99));
        assert_eq!(base.len(), 32);
        // Appending a shard only changes the Ingest line's file count —
        // the per-shard key must not move.
        assert_eq!(
            base,
            shard_key("Ingest [4 files] project=[title]\nCollect\n", &id("/a/s0.json", 10, 99))
        );
        // Path and mtime are not key bits (content-addressed identity).
        assert_eq!(
            base,
            shard_key("Ingest [3 files] project=[title]\nCollect\n", &{
                let mut other = id("/elsewhere/renamed.json", 10, 99);
                other.mtime_nanos = 123_456;
                other
            })
        );
        // Content, length, projection and plan shape all are.
        assert_ne!(base, shard_key("Ingest [3 files] project=[title]\nCollect\n", &id("/a/s0.json", 10, 98)));
        assert_ne!(base, shard_key("Ingest [3 files] project=[title]\nCollect\n", &id("/a/s0.json", 11, 99)));
        assert_ne!(base, shard_key("Ingest [3 files] project=[abstract]\nCollect\n", &id("/a/s0.json", 10, 99)));
        assert_ne!(
            base,
            shard_key("Ingest [3 files] project=[title]\nDropNulls [title]\nCollect\n", &id("/a/s0.json", 10, 99))
        );
        // Distinct domain from the whole-plan key of a one-shard corpus.
        let dir = std::env::temp_dir().join(format!("p3pc-shardkey-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let shard = dir.join("s.json");
        std::fs::write(&shard, b"{\"title\": \"a\"}\n").unwrap();
        let render = "Ingest [1 files] project=[title]\nCollect\n";
        let fp = fingerprint(render, &[shard]).unwrap();
        assert_ne!(fp.key(), shard_key(render, &fp.shards()[0]));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fingerprint_fails_on_missing_shard() {
        let missing = vec![PathBuf::from("/nonexistent/p3pc-shard.json")];
        assert!(fingerprint("plan", &missing).is_err());
    }
}
