//! Persistent plan cache — a fingerprinted artifact store for
//! preprocessed frames.
//!
//! The paper's whole argument is that preprocessing time dominates
//! cumulative cost, yet a pipeline re-runs that cost for every repeated
//! job: each `report` invocation re-preprocesses every tier, and the
//! `train`/`infer` pair preprocesses the same corpus twice. Production
//! Spark NLP deployments get their throughput from reusing fitted
//! pipeline artifacts across runs; this module is that lever for the
//! plan layer — when nothing about the job changed, cumulative time
//! collapses to a single deserialization, reported honestly as a
//! distinct `cache_restore` stage.
//!
//! Three parts:
//!
//! - [`mod@fingerprint`] (entry point [`fingerprint()`]) — the cache
//!   key: xxhash over the optimized-plan render plus every input
//!   shard's (path, length, content-digest) identity. Touched-but-
//!   identical shards still hit (the digest names the bytes, not the
//!   mtime); any content or plan-shape change misses.
//! - [`artifact`] — the `P3PC` columnar on-disk format (versioned,
//!   little-endian, digest-trailed — the same discipline as the
//!   trainer's `P3CK` checkpoints). Corrupt or stale artifacts are
//!   detected and treated as misses, never as errors.
//! - [`CacheManager`] — the two-tier store (in-memory memo + disk),
//!   with hit/miss/store/evict stats and size-capped LRU eviction,
//!   threaded through [`crate::driver::DriverOptions`], the CLI
//!   (`--cache-dir`, `--no-cache`, the `cache` subcommand) and
//!   [`crate::report::SuiteOptions`].
//!
//! `docs/ARCHITECTURE.md` has the full walk (key derivation, format
//! table, rendered EXPLAIN and `cache stats` samples);
//! `rust/tests/cache_roundtrip.rs` pins the correctness contract.

pub mod artifact;
pub mod fingerprint;
mod manager;

pub use artifact::CachedFrame;
pub use fingerprint::{fingerprint, shard_identity, xxh64, PlanFingerprint, ShardIdentity};
pub use manager::{
    CacheConfig, CacheEntry, CacheManager, CacheStats, LifetimeCounters, ARTIFACT_EXT,
    COUNTERS_FILE, DEFAULT_MAX_BYTES, DEFAULT_MEMO_MAX_BYTES,
};

use crate::plan::{ExecutorKind, LogicalOp, LogicalPlan};
use crate::Result;
use std::path::PathBuf;

/// The shard files a plan would scan (its leading `Ingest` op), used to
/// fingerprint a plan without re-plumbing the file list.
pub fn plan_files(plan: &LogicalPlan) -> &[PathBuf] {
    match plan.ops().first() {
        Some(LogicalOp::Ingest { files, .. }) => files,
        _ => &[],
    }
}

/// Cache-aware EXPLAIN: like [`crate::plan::explain_with`], but when a
/// cache manager is present and holds a valid artifact for this exact
/// plan + input state, the physical section renders the restore path —
/// `[cache hit <key>]` — instead of a topology that will not run. On a
/// miss (or with no cache) the full topology renders as before.
///
/// The fingerprint is derived through the manager's in-process memo
/// ([`CacheManager::fingerprint_for`]), so the driver run that follows
/// (`preprocess --explain --cache-dir`) revalidates it with a stat per
/// shard instead of re-digesting every byte — EXPLAIN probing, cache
/// fingerprinting and execution share one read of the corpus cold.
pub fn explain_with_cache(
    plan: &LogicalPlan,
    workers: usize,
    executor: &ExecutorKind,
    cache: Option<&CacheManager>,
) -> Result<String> {
    if let Some(mgr) = cache {
        let optimized = plan.clone().optimize();
        // An unreadable shard fails the fingerprint; fall through to the
        // normal EXPLAIN, whose executor will report the real error.
        if let Ok(fp) = mgr.fingerprint_for(&optimized.render(), plan_files(plan)) {
            if mgr.probe(&fp) {
                // Lowering still validates the plan shape, so EXPLAIN
                // rejects unexecutable plans with or without a cache.
                optimized.lower()?;
                return Ok(format!(
                    "== Logical Plan ==\n{}\n== Optimized Logical Plan ==\n{}\
                     \n== Physical Plan ==\n\
                     CacheRestore [cache hit {}]\n  \
                     artifact: {}\n\
                     Driver: deserialize(P3PC) -> LocalFrame\n",
                    plan.render(),
                    optimized.render(),
                    fp.key(),
                    mgr.dir().join(format!("{}.{ARTIFACT_EXT}", fp.key())).display(),
                ));
            }
        }
    }
    crate::plan::explain_with(plan, workers, executor)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate_corpus, CorpusSpec};
    use crate::ingest::list_shards;
    use crate::pipeline::presets::case_study_plan;

    #[test]
    fn explain_renders_cache_hit_only_when_an_artifact_exists() {
        let dir = std::env::temp_dir().join(format!("p3pc-explain-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        generate_corpus(&CorpusSpec::tiny(13), &dir).unwrap();
        let files = list_shards(&dir).unwrap();
        let plan = case_study_plan(&files, "title", "abstract");
        let cache = CacheManager::open(dir.join("cache")).unwrap();

        // Cold: the normal topology renders.
        let cold = explain_with_cache(&plan, 2, &ExecutorKind::Fused, Some(&cache)).unwrap();
        assert!(cold.contains("SinglePass"), "{cold}");
        assert!(!cold.contains("cache hit"), "{cold}");

        // Warm: store the real output, then EXPLAIN must switch.
        let optimized = plan.clone().optimize();
        let fp = fingerprint(&optimized.render(), &files).unwrap();
        let out = optimized.execute(2).unwrap();
        cache.put(&fp, &out).unwrap();
        let warm = explain_with_cache(&plan, 2, &ExecutorKind::Fused, Some(&cache)).unwrap();
        assert!(warm.contains(&format!("[cache hit {}]", fp.key())), "{warm}");
        assert!(warm.contains("== Optimized Logical Plan =="), "{warm}");
        assert!(!warm.contains("SinglePass"), "{warm}");

        // No cache manager: identical to the plain EXPLAIN.
        let plain = explain_with_cache(&plan, 2, &ExecutorKind::Fused, None).unwrap();
        assert_eq!(plain, crate::plan::explain(&plan, 2).unwrap());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn plan_files_reads_the_ingest_op() {
        let files = vec![PathBuf::from("/tmp/a.json"), PathBuf::from("/tmp/b.json")];
        let plan = case_study_plan(&files, "title", "abstract");
        assert_eq!(plan_files(&plan), &files[..]);
        assert!(plan_files(&LogicalPlan { ops: vec![] }).is_empty());
    }
}
