//! Persistent plan cache — a fingerprinted artifact store for
//! preprocessed frames.
//!
//! The paper's whole argument is that preprocessing time dominates
//! cumulative cost, yet a pipeline re-runs that cost for every repeated
//! job: each `report` invocation re-preprocesses every tier, and the
//! `train`/`infer` pair preprocesses the same corpus twice. Production
//! Spark NLP deployments get their throughput from reusing fitted
//! pipeline artifacts across runs; this module is that lever for the
//! plan layer — when nothing about the job changed, cumulative time
//! collapses to a single deserialization, reported honestly as a
//! distinct `cache_restore` stage.
//!
//! Three parts:
//!
//! - [`mod@fingerprint`] (entry point [`fingerprint()`]) — the cache
//!   key: xxhash over the optimized-plan render plus every input
//!   shard's (path, length, content-digest) identity. Touched-but-
//!   identical shards still hit (the digest names the bytes, not the
//!   mtime); any content or plan-shape change misses.
//! - [`artifact`] — the `P3PC` columnar on-disk format (versioned,
//!   little-endian, digest-trailed — the same discipline as the
//!   trainer's `P3CK` checkpoints). Corrupt or stale artifacts are
//!   detected and treated as misses, never as errors.
//! - [`CacheManager`] — the two-tier store (in-memory memo + disk),
//!   with hit/miss/store/evict stats and size-capped LRU eviction,
//!   threaded through [`crate::driver::DriverOptions`], the CLI
//!   (`--cache-dir`, `--no-cache`, the `cache` subcommand) and
//!   [`crate::report::SuiteOptions`].
//!
//! `docs/ARCHITECTURE.md` has the full walk (key derivation, format
//! table, rendered EXPLAIN and `cache stats` samples);
//! `rust/tests/cache_roundtrip.rs` pins the correctness contract.

pub mod artifact;
pub mod fingerprint;
mod manager;

pub use artifact::CachedFrame;
pub use fingerprint::{
    fingerprint, shard_identity, shard_key, xxh64, PlanFingerprint, ShardIdentity,
};
pub use manager::{
    CacheConfig, CacheEntry, CacheManager, CacheStats, LifetimeCounters, ARTIFACT_EXT,
    COUNTERS_FILE, DEFAULT_MAX_BYTES, DEFAULT_MEMO_MAX_BYTES,
};

use crate::plan::{ExecutorKind, LogicalOp, LogicalPlan};
use crate::Result;
use std::path::PathBuf;

/// The shard files a plan would scan (its leading `Ingest` op), used to
/// fingerprint a plan without re-plumbing the file list.
pub fn plan_files(plan: &LogicalPlan) -> &[PathBuf] {
    match plan.ops().first() {
        Some(LogicalOp::Ingest { files, .. }) => files,
        _ => &[],
    }
}

/// Cache-aware EXPLAIN: like [`crate::plan::explain_with`], but when a
/// cache manager is present and holds a valid artifact for this exact
/// plan + input state, the physical section renders the restore path —
/// `[cache hit <key>]` — instead of a topology that will not run. On a
/// whole-plan miss with per-shard artifacts available (a grown corpus),
/// a `CacheRestore [k of n shards hit]` block renders the hit/miss
/// split ahead of the topology that will execute the misses. On a full
/// miss (or with no cache) the plain topology renders as before.
///
/// The fingerprint is derived through the manager's in-process memo
/// ([`CacheManager::fingerprint_for`]), so the driver run that follows
/// (`preprocess --explain --cache-dir`) revalidates it with a stat per
/// shard instead of re-digesting every byte — EXPLAIN probing, cache
/// fingerprinting and execution share one read of the corpus cold.
pub fn explain_with_cache(
    plan: &LogicalPlan,
    workers: usize,
    executor: &ExecutorKind,
    cache: Option<&CacheManager>,
) -> Result<String> {
    if let Some(mgr) = cache {
        let optimized = plan.clone().optimize();
        // An unreadable shard fails the fingerprint; fall through to the
        // normal EXPLAIN, whose executor will report the real error.
        if let Ok(fp) = mgr.fingerprint_for(&optimized.render(), plan_files(plan)) {
            if mgr.probe(&fp) {
                // Lowering still validates the plan shape, so EXPLAIN
                // rejects unexecutable plans with or without a cache.
                optimized.lower()?;
                return Ok(format!(
                    "== Logical Plan ==\n{}\n== Optimized Logical Plan ==\n{}\
                     \n== Physical Plan ==\n\
                     CacheRestore [cache hit {}]\n  \
                     artifact: {}\n\
                     Driver: deserialize(P3PC) -> LocalFrame\n",
                    plan.render(),
                    optimized.render(),
                    fp.key(),
                    mgr.dir().join(format!("{}.{ARTIFACT_EXT}", fp.key())).display(),
                ));
            }
            // Whole-plan miss: the per-shard tier may still cover part
            // of the run (see `plan::incremental`). Render the split
            // only when at least one shard would restore — a fully cold
            // probe explains exactly like the cache-less path.
            if crate::plan::incremental_eligible(&optimized) {
                let keys = crate::plan::incremental_shard_keys(&optimized, &fp);
                let probed: Vec<bool> = keys.iter().map(|k| mgr.probe_shard(k)).collect();
                let hits = probed.iter().filter(|&&h| h).count();
                if hits > 0 {
                    let full = crate::plan::explain_with(plan, workers, executor)?;
                    let marker = "== Physical Plan ==\n";
                    if let Some(pos) = full.find(marker) {
                        let at = pos + marker.len();
                        let mut block =
                            format!("CacheRestore [{hits} of {} shards hit]\n", keys.len());
                        for (i, (key, hit)) in keys.iter().zip(&probed).enumerate() {
                            let state = if *hit { "hit " } else { "miss" };
                            block.push_str(&format!("  shard {i}: {state} {key}\n"));
                        }
                        return Ok(format!("{}{}{}", &full[..at], block, &full[at..]));
                    }
                }
            }
        }
    }
    crate::plan::explain_with(plan, workers, executor)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate_corpus, CorpusSpec};
    use crate::ingest::list_shards;
    use crate::pipeline::presets::case_study_plan;

    #[test]
    fn explain_renders_cache_hit_only_when_an_artifact_exists() {
        let dir = std::env::temp_dir().join(format!("p3pc-explain-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        generate_corpus(&CorpusSpec::tiny(13), &dir).unwrap();
        let files = list_shards(&dir).unwrap();
        let plan = case_study_plan(&files, "title", "abstract");
        let cache = CacheManager::open(dir.join("cache")).unwrap();

        // Cold: the normal topology renders.
        let cold = explain_with_cache(&plan, 2, &ExecutorKind::Fused, Some(&cache)).unwrap();
        assert!(cold.contains("SinglePass"), "{cold}");
        assert!(!cold.contains("cache hit"), "{cold}");

        // Warm: store the real output, then EXPLAIN must switch.
        let optimized = plan.clone().optimize();
        let fp = fingerprint(&optimized.render(), &files).unwrap();
        let out = optimized.execute(2).unwrap();
        cache.put(&fp, &out).unwrap();
        let warm = explain_with_cache(&plan, 2, &ExecutorKind::Fused, Some(&cache)).unwrap();
        assert!(warm.contains(&format!("[cache hit {}]", fp.key())), "{warm}");
        assert!(warm.contains("== Optimized Logical Plan =="), "{warm}");
        assert!(!warm.contains("SinglePass"), "{warm}");

        // No cache manager: identical to the plain EXPLAIN.
        let plain = explain_with_cache(&plan, 2, &ExecutorKind::Fused, None).unwrap();
        assert_eq!(plain, crate::plan::explain(&plan, 2).unwrap());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn explain_renders_the_shard_split_after_a_corpus_grows() {
        let dir = std::env::temp_dir().join(format!("p3pc-explain-incr-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        generate_corpus(&CorpusSpec::tiny(17), &dir).unwrap();
        let files = list_shards(&dir).unwrap();
        assert!(files.len() >= 2);
        let cache = CacheManager::open(dir.join("cache")).unwrap();

        // Warm the shard tier over the initial corpus (cold incremental
        // run stores per-shard artifacts, whole-plan artifact withheld).
        let initial = files[..files.len() - 1].to_vec();
        let plan1 = case_study_plan(&initial, "title", "abstract").optimize();
        let fp1 = cache.fingerprint_for(&plan1.render(), &initial).unwrap();
        crate::plan::execute_incremental(&plan1, 2, &ExecutorKind::Fused, &cache, &fp1)
            .unwrap()
            .expect("eligible");

        // Grown corpus: whole-plan miss, but the untouched shards hit.
        let plan2 = case_study_plan(&files, "title", "abstract");
        let grown = explain_with_cache(&plan2, 2, &ExecutorKind::Fused, Some(&cache)).unwrap();
        let split = format!("CacheRestore [{} of {} shards hit]", initial.len(), files.len());
        assert!(grown.contains(&split), "{grown}");
        assert!(grown.contains(&format!("shard {}: miss", files.len() - 1)), "{grown}");
        assert!(grown.contains("shard 0: hit"), "{grown}");
        // The topology that will execute the misses still renders.
        assert!(grown.contains("SinglePass"), "{grown}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn plan_files_reads_the_ingest_op() {
        let files = vec![PathBuf::from("/tmp/a.json"), PathBuf::from("/tmp/b.json")];
        let plan = case_study_plan(&files, "title", "abstract");
        assert_eq!(plan_files(&plan), &files[..]);
        assert!(plan_files(&LogicalPlan { ops: vec![] }).is_empty());
    }
}
