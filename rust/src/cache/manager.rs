//! The cache manager: a two-tier (in-memory memo + content-addressed
//! disk store) cache of preprocessed frames, with hit/miss/evict
//! accounting and size-capped LRU eviction of the disk tier.
//!
//! Tiering. The memo tier serves repeats **within** one process (a
//! `report` suite re-running a tier, the train side of `train`/`infer`)
//! from a clone — no I/O at all. The disk tier serves repeats **across**
//! processes (a second `repro report`, `train` after `infer`) from a
//! `P3PC` artifact. A disk hit re-populates the memo and touches the
//! artifact's mtime, which is what the LRU eviction orders by.
//!
//! Failure posture: the cache must never turn a working run into a
//! failing one. Corrupt, truncated, foreign or stale-versioned artifacts
//! are counted (`CacheStats::corrupt`) and treated as misses; a failed
//! store is reported by the caller but does not fail the run.

use super::artifact::{self, CachedFrame};
use super::fingerprint::{fingerprint, xxh64, PlanFingerprint};
use crate::driver::CACHE_RESTORE;
use crate::metrics::StageTimes;
use crate::plan::PlanOutput;
use crate::Result;
use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Instant, SystemTime};

/// Artifact file extension (content-addressed stem = fingerprint key).
pub const ARTIFACT_EXT: &str = "p3pc";

/// Sidecar file holding the cache *directory's* lifetime eviction and
/// corruption counts, accumulated across processes — the in-process
/// [`CacheStats`] restart at zero, so without it `repro cache stats`
/// (always a fresh process) could never report either. Named without
/// the artifact extension so [`CacheManager::entries`], the size cap
/// and [`CacheManager::clear`] never treat it as cache content.
pub const COUNTERS_FILE: &str = "counters.v1";

/// Default disk-tier size cap: 1 GiB.
pub const DEFAULT_MAX_BYTES: u64 = 1 << 30;

/// Default memo-tier (in-memory) size cap: 256 MiB of frame payload.
pub const DEFAULT_MEMO_MAX_BYTES: u64 = 256 << 20;

/// Cache construction knobs.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Directory holding the `<key>.p3pc` artifacts (created on open).
    pub dir: PathBuf,
    /// Disk-tier size cap in bytes; least-recently-used artifacts are
    /// evicted past it. `0` disables eviction.
    pub max_bytes: u64,
    /// Enable the in-memory memo tier (disable to measure true disk
    /// restores, as `benches/fused.rs` does for its warm arm).
    pub memory: bool,
    /// Memo-tier size cap in approximate frame-payload bytes — without
    /// it a multi-tier suite would keep every tier's frame resident for
    /// the process lifetime. Oldest-inserted entries are dropped past
    /// the cap (they remain on disk); `0` disables the cap.
    pub memory_max_bytes: u64,
}

/// In-process counters, surfaced via [`CacheManager::stats`] (and the
/// driver's bench/test assertions). They live in memory only — a fresh
/// process starts from zero; `repro cache stats` reports the *disk*
/// tier (artifact list, sizes, ages), not these.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Hits served from the in-memory memo.
    pub mem_hits: u64,
    /// Hits served by deserializing a disk artifact.
    pub disk_hits: u64,
    /// Lookups that found nothing usable.
    pub misses: u64,
    /// Artifacts written.
    pub stores: u64,
    /// Artifacts removed by the LRU size cap.
    pub evictions: u64,
    /// Misses caused by a corrupt/unreadable artifact (subset of
    /// `misses`).
    pub corrupt: u64,
    /// Shards fully digested by [`CacheManager::fingerprint_for`] — each
    /// memo miss adds `files.len()`. The suite regression test pins this
    /// to exactly one digest per shard per suite.
    pub fp_digest_shards: u64,
    /// Fingerprint memo hits revalidated by a cheap stat-identity check
    /// instead of a re-digest.
    pub fp_stat_revalidations: u64,
    /// Per-shard artifacts restored by an incremental run (reported by
    /// the plan layer after a restored payload decoded cleanly).
    pub shard_hits: u64,
    /// Shards an incremental run had to execute (no usable per-shard
    /// artifact). `shard_hits + shard_misses` sums to the shard count of
    /// every incremental pass.
    pub shard_misses: u64,
    /// Per-shard artifacts written. Deliberately separate from `stores`,
    /// which stays whole-plan-only (bench and test assertions pin it).
    pub shard_stores: u64,
}

impl CacheStats {
    pub fn hits(&self) -> u64 {
        self.mem_hits + self.disk_hits
    }
}

/// Lifetime counters read from the [`COUNTERS_FILE`] sidecar: per cache
/// directory, across processes. Advisory observability — a missing or
/// unparseable sidecar reads as zeros, never an error.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LifetimeCounters {
    /// Artifacts ever removed by the LRU size cap.
    pub evictions: u64,
    /// Artifacts ever dropped as corrupt/unreadable.
    pub corrupt: u64,
    /// Incremental-tier shards ever restored instead of executed.
    /// Persisted (unlike the whole-plan hit counters) because the
    /// incremental CI smoke asserts the hit/miss split from a *fresh*
    /// `repro cache stats` process after the warm run exited.
    pub shard_hits: u64,
    /// Incremental-tier shards that had to execute.
    pub shard_misses: u64,
    /// Per-shard artifacts ever written.
    pub shard_stores: u64,
}

/// One disk-tier entry, as listed by [`CacheManager::entries`].
#[derive(Debug, Clone)]
pub struct CacheEntry {
    pub key: String,
    pub path: PathBuf,
    pub bytes: u64,
    pub modified: Option<SystemTime>,
}

/// The byte-capped, insertion-ordered memo tier. Insertion order is the
/// eviction order — close enough to LRU for the repeat patterns this
/// tier serves (suite reruns, train/infer), and O(1) on the hot path.
#[derive(Debug, Default)]
struct Memo {
    map: HashMap<String, CachedFrame>,
    /// Keys oldest-inserted first.
    order: VecDeque<String>,
    /// Approximate frame-payload bytes currently held.
    bytes: u64,
}

fn frame_bytes(hit: &CachedFrame) -> u64 {
    hit.frame.columns().iter().map(|c| c.approx_bytes() as u64).sum()
}

impl Memo {
    /// Insert under the byte cap (`0` = uncapped): entries larger than
    /// the whole cap are not memoized at all (the disk tier serves
    /// them); otherwise oldest entries are dropped until this one fits.
    fn insert(&mut self, key: String, hit: CachedFrame, max_bytes: u64) {
        self.remove(&key);
        let size = frame_bytes(&hit);
        if max_bytes > 0 && size > max_bytes {
            return;
        }
        self.bytes += size;
        self.order.push_back(key.clone());
        self.map.insert(key, hit);
        // size <= max_bytes, so anything over the cap is an older entry.
        while max_bytes > 0 && self.bytes > max_bytes {
            let Some(oldest) = self.order.front().cloned() else { break };
            self.remove(&oldest);
        }
    }

    fn remove(&mut self, key: &str) {
        if let Some(old) = self.map.remove(key) {
            self.bytes = self.bytes.saturating_sub(frame_bytes(&old));
            self.order.retain(|k| k != key);
        }
    }

    fn clear(&mut self) {
        self.map.clear();
        self.order.clear();
        self.bytes = 0;
    }
}

/// The plan cache: memoizes a [`PlanOutput`] under its
/// [`PlanFingerprint`] so a byte-identical preprocessing job restores
/// its frame instead of re-executing the pass.
///
/// ```no_run
/// use p3sapp::cache::{fingerprint, CacheManager};
/// use p3sapp::pipeline::presets::case_study_plan;
///
/// let files = p3sapp::ingest::list_shards(std::path::Path::new("/tmp/corpus")).unwrap();
/// let plan = case_study_plan(&files, "title", "abstract").optimize();
/// let cache = CacheManager::open("/tmp/p3sapp-cache").unwrap();
/// let fp = fingerprint(&plan.render(), &files).unwrap();
/// let out = match cache.get(&fp) {
///     Some(hit) => hit, // times = one `cache_restore` stage
///     None => {
///         let out = plan.execute(4).unwrap();
///         cache.put(&fp, &out).unwrap();
///         out
///     }
/// };
/// println!("{} rows ({:?})", out.rows_out, cache.stats());
/// ```
#[derive(Debug)]
pub struct CacheManager {
    cfg: CacheConfig,
    memo: Mutex<Memo>,
    /// In-process fingerprint memo: (plan render, file list) → the last
    /// computed [`PlanFingerprint`], reused while every shard's
    /// stat-level identity (length + mtime) is unchanged. This is what
    /// lets `--explain --cache-dir` and the driver run that follows
    /// share one digest pass instead of reading every shard twice
    /// before execution even starts. See [`CacheManager::fingerprint_for`].
    fingerprints: Mutex<HashMap<u64, PlanFingerprint>>,
    stats: Mutex<CacheStats>,
}

impl CacheManager {
    /// Open (creating if needed) a cache rooted at `dir` with the
    /// default size caps and the memo tier enabled.
    pub fn open(dir: impl Into<PathBuf>) -> Result<CacheManager> {
        CacheManager::with_config(CacheConfig {
            dir: dir.into(),
            max_bytes: DEFAULT_MAX_BYTES,
            memory: true,
            memory_max_bytes: DEFAULT_MEMO_MAX_BYTES,
        })
    }

    pub fn with_config(cfg: CacheConfig) -> Result<CacheManager> {
        std::fs::create_dir_all(&cfg.dir)
            .map_err(|e| anyhow::anyhow!("create cache dir {}: {e}", cfg.dir.display()))?;
        Ok(CacheManager {
            cfg,
            memo: Mutex::new(Memo::default()),
            fingerprints: Mutex::new(HashMap::new()),
            stats: Mutex::new(CacheStats::default()),
        })
    }

    /// Memoized [`fingerprint`]: returns the cached
    /// [`PlanFingerprint`] for this exact (plan render, file list) pair
    /// when every shard's stat identity (path order, byte length,
    /// mtime) is unchanged since it was computed, re-digesting
    /// otherwise. A cold `--explain --cache-dir` run used to read every
    /// shard three times (EXPLAIN probe digest, driver fingerprint
    /// digest, executor parse); with both callers routed through here
    /// the second digest pass collapses to a stat per shard.
    ///
    /// Scope: the memo lives in this process only, so the cross-run
    /// guarantee is untouched — a fresh process always digests. Within
    /// a process, an edit that preserves a shard's length *and* mtime
    /// is served the memoized digest (the pure [`fingerprint`] function
    /// still sees through it); files with no readable mtime are never
    /// memo-served.
    pub fn fingerprint_for(
        &self,
        plan_render: &str,
        files: &[PathBuf],
    ) -> crate::Result<PlanFingerprint> {
        let mut material = Vec::with_capacity(plan_render.len() + files.len() * 32);
        material.extend_from_slice(plan_render.as_bytes());
        for f in files {
            material.push(0);
            material.extend_from_slice(f.to_string_lossy().as_bytes());
        }
        let memo_key = xxh64(&material, 0x5eed);
        if let Some(fp) = self.fingerprints.lock().unwrap().get(&memo_key) {
            if stat_identity_unchanged(fp, files) {
                self.stats.lock().unwrap().fp_stat_revalidations += 1;
                return Ok(fp.clone());
            }
        }
        let fp = fingerprint(plan_render, files)?;
        self.stats.lock().unwrap().fp_digest_shards += files.len() as u64;
        self.fingerprints.lock().unwrap().insert(memo_key, fp.clone());
        Ok(fp)
    }

    pub fn dir(&self) -> &Path {
        &self.cfg.dir
    }

    fn artifact_path(&self, key: &str) -> PathBuf {
        self.cfg.dir.join(format!("{key}.{ARTIFACT_EXT}"))
    }

    /// Cheap hit probe for EXPLAIN rendering: validates only the
    /// artifact's header (magic, version, key — O(header) I/O, not a
    /// full read+digest of a potentially huge file), and does not skew
    /// the hit/miss counters. A header-valid but payload-corrupt
    /// artifact renders as a hit here and then misses in [`Self::get`],
    /// which revalidates everything.
    pub fn probe(&self, fp: &PlanFingerprint) -> bool {
        if self.cfg.memory && self.memo.lock().unwrap().map.contains_key(fp.key()) {
            return true;
        }
        artifact::verify_header(&self.artifact_path(fp.key()), fp.key())
    }

    /// Look up `fp`. On a hit, returns a [`PlanOutput`] whose stage
    /// times hold exactly one entry — [`CACHE_RESTORE`], the measured
    /// memo-clone or deserialization wall time — so the paper's
    /// cumulative-time accounting reports the restore honestly instead
    /// of pretending the stages re-ran.
    pub fn get(&self, fp: &PlanFingerprint) -> Option<PlanOutput> {
        let t0 = Instant::now();
        if self.cfg.memory {
            if let Some(hit) = self.memo.lock().unwrap().map.get(fp.key()).cloned() {
                self.stats.lock().unwrap().mem_hits += 1;
                return Some(restored(hit, t0));
            }
        }
        let path = self.artifact_path(fp.key());
        if !path.exists() {
            self.stats.lock().unwrap().misses += 1;
            return None;
        }
        match artifact::load(&path, fp.key()) {
            Ok(hit) => {
                // Touch for LRU, refill the memo for in-process repeats.
                let _ = std::fs::File::options()
                    .write(true)
                    .open(&path)
                    .and_then(|f| f.set_modified(SystemTime::now()));
                if self.cfg.memory {
                    self.memo.lock().unwrap().insert(
                        fp.key().to_string(),
                        hit.clone(),
                        self.cfg.memory_max_bytes,
                    );
                }
                self.stats.lock().unwrap().disk_hits += 1;
                Some(restored(hit, t0))
            }
            Err(_) => {
                // Corrupt or stale: a miss, never an error. Drop the
                // defective artifact so the re-executed pass can store a
                // fresh one over it.
                let _ = std::fs::remove_file(&path);
                {
                    let mut stats = self.stats.lock().unwrap();
                    stats.misses += 1;
                    stats.corrupt += 1;
                }
                self.bump_lifetime(corrupt_delta());
                None
            }
        }
    }

    /// Store `out` under `fp`, then enforce the size cap. The write is
    /// atomic (temp file + rename), so concurrent readers only ever see
    /// whole artifacts.
    pub fn put(&self, fp: &PlanFingerprint, out: &PlanOutput) -> Result<()> {
        artifact::save(&self.artifact_path(fp.key()), fp.key(), out)?;
        if self.cfg.memory {
            self.memo.lock().unwrap().insert(
                fp.key().to_string(),
                CachedFrame {
                    frame: out.frame.clone(),
                    rows_ingested: out.rows_ingested,
                    nulls_dropped: out.nulls_dropped,
                    dups_dropped: out.dups_dropped,
                    empties_dropped: out.empties_dropped,
                    sampled_out: out.sampled_out,
                    limited_out: out.limited_out,
                },
                self.cfg.memory_max_bytes,
            );
        }
        self.stats.lock().unwrap().stores += 1;
        self.evict(fp.key())?;
        Ok(())
    }

    /// LRU eviction: drop oldest-touched artifacts until the disk tier
    /// fits `max_bytes`. `protect` (the key just stored) is exempt —
    /// mtime ordering alone cannot guarantee it survives on filesystems
    /// with coarse timestamp granularity, where a same-second tie would
    /// otherwise fall back to key order — unless it alone exceeds the
    /// cap, in which case it is the last thing removed.
    fn evict(&self, protect: &str) -> Result<()> {
        if self.cfg.max_bytes == 0 {
            return Ok(());
        }
        let mut entries = self.entries()?;
        let mut total: u64 = entries.iter().map(|e| e.bytes).sum();
        if total <= self.cfg.max_bytes {
            return Ok(());
        }
        // Oldest first; entries without an mtime evict first, and the
        // just-stored entry is considered newest regardless of mtime.
        entries.sort_by_key(|e| (e.key == protect, e.modified));
        let mut evicted = 0u64;
        for e in entries {
            if total <= self.cfg.max_bytes {
                break;
            }
            std::fs::remove_file(&e.path)
                .map_err(|err| anyhow::anyhow!("evict {}: {err}", e.path.display()))?;
            self.memo.lock().unwrap().remove(&e.key);
            total = total.saturating_sub(e.bytes);
            self.stats.lock().unwrap().evictions += 1;
            evicted += 1;
        }
        self.bump_lifetime(LifetimeCounters { evictions: evicted, ..Default::default() });
        Ok(())
    }

    /// List the disk tier (every `*.p3pc` under the cache dir).
    pub fn entries(&self) -> Result<Vec<CacheEntry>> {
        let mut out = Vec::new();
        let rd = std::fs::read_dir(&self.cfg.dir)
            .map_err(|e| anyhow::anyhow!("read cache dir {}: {e}", self.cfg.dir.display()))?;
        for entry in rd {
            let entry = entry?;
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some(ARTIFACT_EXT) {
                continue;
            }
            let key = match path.file_stem().and_then(|s| s.to_str()) {
                Some(s) => s.to_string(),
                None => continue,
            };
            let meta = entry.metadata()?;
            out.push(CacheEntry {
                key,
                path,
                bytes: meta.len(),
                modified: meta.modified().ok(),
            });
        }
        out.sort_by(|a, b| a.key.cmp(&b.key));
        Ok(out)
    }

    /// Remove every artifact (and the memo); returns how many artifacts
    /// were removed. Also sweeps orphaned `*.tmp` files — a crash
    /// between [`artifact::save`]'s write and rename can strand one,
    /// and those are invisible to [`Self::entries`] and the size cap.
    /// `repro cache clear`.
    pub fn clear(&self) -> Result<usize> {
        let entries = self.entries()?;
        for e in &entries {
            std::fs::remove_file(&e.path)
                .map_err(|err| anyhow::anyhow!("remove {}: {err}", e.path.display()))?;
        }
        for entry in std::fs::read_dir(&self.cfg.dir)? {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) == Some("tmp") {
                let _ = std::fs::remove_file(&path);
            }
        }
        self.memo.lock().unwrap().clear();
        Ok(entries.len())
    }

    pub fn stats(&self) -> CacheStats {
        *self.stats.lock().unwrap()
    }

    /// Load one per-shard payload (incremental tier, kind-1 artifacts;
    /// see [`super::fingerprint::shard_key`]). Envelope-validated bytes,
    /// or `None` when absent; a corrupt or stale-versioned artifact is
    /// removed and counted (`CacheStats::corrupt`), never an error.
    /// Disk-only — shard payloads are plan-layer bytes, not frames, so
    /// the memo tier does not apply. Hit/miss accounting is reported by
    /// the caller via [`Self::count_shard_probe`] once it knows whether
    /// the payload also *decoded*, so the counters mean "shard restored"
    /// / "shard executed", not "file existed".
    pub fn get_shard(&self, key: &str) -> Option<Vec<u8>> {
        let path = self.artifact_path(key);
        if !path.exists() {
            return None;
        }
        match artifact::load_raw(&path, key) {
            Ok(bytes) => {
                // Touch for LRU, same as the whole-plan tier.
                let _ = std::fs::File::options()
                    .write(true)
                    .open(&path)
                    .and_then(|f| f.set_modified(SystemTime::now()));
                Some(bytes)
            }
            Err(_) => {
                self.stats.lock().unwrap().corrupt += 1;
                let _ = std::fs::remove_file(&path);
                self.bump_lifetime(corrupt_delta());
                None
            }
        }
    }

    /// Store one per-shard payload under `key` (atomic write, then the
    /// shared LRU size cap — shard artifacts live in the same `.p3pc`
    /// namespace as whole-plan ones, so eviction and `clear` cover both).
    pub fn put_shard(&self, key: &str, payload: &[u8]) -> Result<()> {
        artifact::save_raw(&self.artifact_path(key), key, payload)?;
        self.stats.lock().unwrap().shard_stores += 1;
        self.bump_lifetime(LifetimeCounters { shard_stores: 1, ..Default::default() });
        self.evict(key)?;
        Ok(())
    }

    /// Drop one shard artifact whose envelope verified but whose payload
    /// failed to decode in the plan layer — counted corrupt, so the next
    /// run re-executes and re-stores that shard.
    pub fn remove_shard(&self, key: &str) {
        let _ = std::fs::remove_file(self.artifact_path(key));
        self.stats.lock().unwrap().corrupt += 1;
        self.bump_lifetime(corrupt_delta());
    }

    /// Cheap existence probe for EXPLAIN's hit/miss shard split: does a
    /// `.p3pc` file exist under this shard key? (The warm run revalidates
    /// the full envelope; a stale or corrupt file renders as a hit here
    /// and misses there.)
    pub fn probe_shard(&self, key: &str) -> bool {
        self.artifact_path(key).exists()
    }

    /// Record one incremental pass's restored/executed shard split
    /// (reported by the plan layer — see [`Self::get_shard`]).
    pub fn count_shard_probe(&self, hits: u64, misses: u64) {
        {
            let mut stats = self.stats.lock().unwrap();
            stats.shard_hits += hits;
            stats.shard_misses += misses;
        }
        self.bump_lifetime(LifetimeCounters {
            shard_hits: hits,
            shard_misses: misses,
            ..Default::default()
        });
    }

    fn counters_path(&self) -> PathBuf {
        self.cfg.dir.join(COUNTERS_FILE)
    }

    /// Lifetime eviction/corruption/shard counts for this cache *directory*,
    /// accumulated in the [`COUNTERS_FILE`] sidecar across processes —
    /// unlike [`Self::stats`], which restarts at zero with the process.
    pub fn lifetime_counters(&self) -> LifetimeCounters {
        read_lifetime(&self.counters_path())
    }

    /// Best-effort read-modify-write of the lifetime sidecar. The stats
    /// lock serializes writers within this process; a concurrent
    /// *process* can lose an increment, which is acceptable for
    /// advisory counters — and a write failure never fails the run.
    fn bump_lifetime(&self, delta: LifetimeCounters) {
        if delta == LifetimeCounters::default() {
            return;
        }
        let _guard = self.stats.lock().unwrap();
        let path = self.counters_path();
        let mut c = read_lifetime(&path);
        c.evictions += delta.evictions;
        c.corrupt += delta.corrupt;
        c.shard_hits += delta.shard_hits;
        c.shard_misses += delta.shard_misses;
        c.shard_stores += delta.shard_stores;
        let _ = std::fs::write(
            &path,
            format!(
                "evictions={}\ncorrupt={}\nshard_hits={}\nshard_misses={}\nshard_stores={}\n",
                c.evictions, c.corrupt, c.shard_hits, c.shard_misses, c.shard_stores
            ),
        );
    }
}

/// A lifetime delta with only `corrupt` set — the most common bump.
fn corrupt_delta() -> LifetimeCounters {
    LifetimeCounters { corrupt: 1, ..Default::default() }
}

/// Parse the lifetime sidecar (`key=value` lines); anything missing or
/// malformed reads as zero.
fn read_lifetime(path: &Path) -> LifetimeCounters {
    let mut c = LifetimeCounters::default();
    let Ok(text) = std::fs::read_to_string(path) else { return c };
    for line in text.lines() {
        let Some((k, v)) = line.split_once('=') else { continue };
        let Ok(v) = v.trim().parse::<u64>() else { continue };
        match k.trim() {
            "evictions" => c.evictions = v,
            "corrupt" => c.corrupt = v,
            "shard_hits" => c.shard_hits = v,
            "shard_misses" => c.shard_misses = v,
            "shard_stores" => c.shard_stores = v,
            _ => {}
        }
    }
    c
}

/// True when every shard's stat identity (path order, length, mtime)
/// matches what `fp` recorded — the revalidation gate of
/// [`CacheManager::fingerprint_for`]. Any anomaly (missing file, zero
/// mtime, reordered list) forces a fresh digest.
fn stat_identity_unchanged(fp: &PlanFingerprint, files: &[PathBuf]) -> bool {
    let shards = fp.shards();
    if shards.len() != files.len() {
        return false;
    }
    for (id, path) in shards.iter().zip(files) {
        if &id.path != path || id.mtime_nanos == 0 {
            return false;
        }
        let Ok(meta) = std::fs::metadata(path) else { return false };
        if meta.len() != id.len {
            return false;
        }
        let mtime = meta
            .modified()
            .ok()
            .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
            .map(|d| d.as_nanos())
            .unwrap_or(0);
        if mtime == 0 || mtime != id.mtime_nanos {
            return false;
        }
    }
    true
}

/// Wrap a restored frame as a [`PlanOutput`] whose only stage time is
/// the restore itself.
fn restored(hit: CachedFrame, t0: Instant) -> PlanOutput {
    let rows_out = hit.frame.num_rows();
    let mut times = StageTimes::new();
    times.add(CACHE_RESTORE, t0.elapsed());
    PlanOutput {
        frame: hit.frame,
        times,
        rows_ingested: hit.rows_ingested,
        rows_out,
        nulls_dropped: hit.nulls_dropped,
        dups_dropped: hit.dups_dropped,
        empties_dropped: hit.empties_dropped,
        sampled_out: hit.sampled_out,
        limited_out: hit.limited_out,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frame::{Column, DType, Field, LocalFrame, Schema};
    use crate::plan::PlanOutput;

    fn output(rows: usize, payload: &str) -> PlanOutput {
        let cells: Vec<Option<String>> =
            (0..rows).map(|i| Some(format!("{payload}-{i}"))).collect();
        let frame = LocalFrame::from_columns(
            Schema::new(vec![Field::new("title", DType::Str)]),
            vec![Column::Str(cells)],
        )
        .unwrap();
        PlanOutput {
            frame,
            times: StageTimes::new(),
            rows_ingested: rows + 2,
            rows_out: rows,
            nulls_dropped: 1,
            dups_dropped: 1,
            empties_dropped: 0,
            sampled_out: 0,
            limited_out: 0,
        }
    }

    fn fp(plan: &str) -> PlanFingerprint {
        super::super::fingerprint::fingerprint(plan, &[]).unwrap()
    }

    fn mgr(name: &str, max_bytes: u64, memory: bool) -> CacheManager {
        let dir = std::env::temp_dir().join(format!("p3pc-mgr-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        CacheManager::with_config(CacheConfig {
            dir,
            max_bytes,
            memory,
            memory_max_bytes: 0,
        })
        .unwrap()
    }

    #[test]
    fn miss_store_hit_lifecycle() {
        let m = mgr("life", 0, true);
        let fp = fp("plan-life");
        assert!(m.get(&fp).is_none());
        assert!(!m.probe(&fp));
        let out = output(5, "row");
        m.put(&fp, &out).unwrap();
        assert!(m.probe(&fp));
        // Memo tier serves the repeat.
        let hit = m.get(&fp).expect("memo hit");
        assert_eq!(hit.frame, out.frame);
        assert_eq!(hit.rows_out, 5);
        assert_eq!(hit.rows_ingested, 7);
        assert!(hit.times.secs(CACHE_RESTORE) >= 0.0);
        assert_eq!(hit.times.stages().count(), 1, "restore is the only stage");
        let s = m.stats();
        assert_eq!((s.mem_hits, s.disk_hits, s.misses, s.stores), (1, 0, 1, 1));
        std::fs::remove_dir_all(m.dir()).unwrap();
    }

    #[test]
    fn disk_tier_survives_a_fresh_manager() {
        let m = mgr("disk", 0, true);
        let fp = fp("plan-disk");
        m.put(&fp, &output(3, "d")).unwrap();
        // A new manager over the same dir (a "second process").
        let m2 = CacheManager::with_config(CacheConfig {
            dir: m.dir().to_path_buf(),
            max_bytes: 0,
            memory: true,
            memory_max_bytes: 0,
        })
        .unwrap();
        let hit = m2.get(&fp).expect("disk hit");
        assert_eq!(hit.frame, output(3, "d").frame);
        assert_eq!(m2.stats().disk_hits, 1);
        // The disk hit refilled the memo.
        let again = m2.get(&fp).unwrap();
        assert_eq!(again.frame, hit.frame);
        assert_eq!(m2.stats().mem_hits, 1);
        std::fs::remove_dir_all(m.dir()).unwrap();
    }

    #[test]
    fn corrupt_artifact_is_a_counted_miss_and_is_removed() {
        let m = mgr("corrupt", 0, false);
        let fp = fp("plan-corrupt");
        m.put(&fp, &output(4, "c")).unwrap();
        let path = m.artifact_path(fp.key());
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(m.get(&fp).is_none());
        let s = m.stats();
        assert_eq!((s.misses, s.corrupt), (1, 1));
        assert!(!path.exists(), "defective artifact dropped");
        // Re-store over it works.
        m.put(&fp, &output(4, "c")).unwrap();
        assert!(m.get(&fp).is_some());
        std::fs::remove_dir_all(m.dir()).unwrap();
    }

    #[test]
    fn lru_eviction_caps_the_disk_tier() {
        let m = mgr("evict", 1, true); // 1-byte cap: every artifact alone exceeds it
        let fp_a = fp("plan-a");
        let fp_b = fp("plan-b");
        m.put(&fp_a, &output(2, "a")).unwrap();
        m.put(&fp_b, &output(2, "b")).unwrap();
        assert!(m.stats().evictions >= 1);
        // Evicted entries are gone from the memo too (memo mirrors disk).
        let remaining = m.entries().unwrap();
        assert!(remaining.len() <= 1, "{remaining:?}");
        std::fs::remove_dir_all(m.dir()).unwrap();
    }

    #[test]
    fn memo_tier_is_byte_capped_but_disk_still_serves() {
        let dir = std::env::temp_dir()
            .join(format!("p3pc-mgr-memocap-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // Cap the memo far below one frame's payload: nothing memoizes,
        // every repeat is served (correctly) by the disk tier.
        let m = CacheManager::with_config(CacheConfig {
            dir,
            max_bytes: 0,
            memory: true,
            memory_max_bytes: 8,
        })
        .unwrap();
        let fp = fp("plan-memocap");
        let out = output(50, "payload-row");
        m.put(&fp, &out).unwrap();
        assert_eq!(m.memo.lock().unwrap().map.len(), 0, "over-cap frame not memoized");
        let hit = m.get(&fp).expect("disk hit");
        assert_eq!(hit.frame, out.frame);
        assert_eq!(m.stats().disk_hits, 1);
        assert_eq!(m.memo.lock().unwrap().bytes, 0);
        std::fs::remove_dir_all(m.dir()).unwrap();
    }

    #[test]
    fn memo_evicts_oldest_insertion_past_the_cap() {
        let mut memo = Memo::default();
        let entry = |o: &PlanOutput| CachedFrame {
            frame: o.frame.clone(),
            rows_ingested: o.rows_ingested,
            nulls_dropped: o.nulls_dropped,
            dups_dropped: o.dups_dropped,
            empties_dropped: o.empties_dropped,
            sampled_out: o.sampled_out,
            limited_out: o.limited_out,
        };
        let frame_a = output(10, "aaaa");
        let size = frame_bytes(&entry(&frame_a));
        // Cap fits two same-sized entries but not three.
        let cap = size * 2;
        memo.insert("a".into(), entry(&frame_a), cap);
        memo.insert("b".into(), entry(&output(10, "bbbb")), cap);
        memo.insert("c".into(), entry(&output(10, "cccc")), cap);
        assert!(!memo.map.contains_key("a"), "oldest evicted");
        assert!(memo.map.contains_key("b") && memo.map.contains_key("c"));
        assert!(memo.bytes <= cap);
        // Re-inserting an existing key replaces, not duplicates.
        memo.insert("c".into(), entry(&output(10, "cccc")), cap);
        assert_eq!(memo.order.len(), 2);
        memo.clear();
        assert_eq!((memo.map.len(), memo.order.len(), memo.bytes), (0, 0, 0));
    }

    #[test]
    fn fingerprint_memo_reuses_digests_while_stat_identity_holds() {
        let m = mgr("fpmemo", 0, false);
        let shard = m.dir().join("s.json");
        std::fs::write(&shard, b"{\"title\": \"a\"}\n").unwrap();
        let files = vec![shard.clone()];

        let first = m.fingerprint_for("plan", &files).unwrap();
        assert_eq!(
            first.key(),
            super::super::fingerprint::fingerprint("plan", &files).unwrap().key(),
            "memoized derivation must match the pure function"
        );
        let s = m.stats();
        assert_eq!((s.fp_digest_shards, s.fp_stat_revalidations), (1, 0));
        // Unchanged file: the memo serves the same key (stat-only path).
        assert_eq!(m.fingerprint_for("plan", &files).unwrap().key(), first.key());
        let s = m.stats();
        assert_eq!((s.fp_digest_shards, s.fp_stat_revalidations), (1, 1));
        // A different plan render over the same files is a different
        // memo entry, not a stale reuse.
        assert_ne!(m.fingerprint_for("plan-b", &files).unwrap().key(), first.key());
        assert_eq!(m.stats().fp_digest_shards, 2, "new memo entry re-digests");

        // Content edit that moves the mtime: re-digested, key changes.
        // The mtime bump is explicit so coarse-granularity filesystems
        // cannot leave the stat identity accidentally unchanged.
        std::fs::write(&shard, b"{\"title\": \"b\"}\n").unwrap();
        let bumped = std::fs::metadata(&shard).unwrap().modified().unwrap()
            + std::time::Duration::from_secs(2);
        std::fs::File::options().write(true).open(&shard).unwrap().set_modified(bumped).unwrap();
        let edited = m.fingerprint_for("plan", &files).unwrap();
        assert_ne!(edited.key(), first.key());
        assert_eq!(m.stats().fp_digest_shards, 3, "stat drift forces a re-digest");

        // The documented in-process trade-off: an edit that restores
        // length *and* mtime is served the memoized digest (a fresh
        // process — or the pure fingerprint() — still sees through it).
        let mtime = std::fs::metadata(&shard).unwrap().modified().unwrap();
        std::fs::write(&shard, b"{\"title\": \"c\"}\n").unwrap();
        std::fs::File::options().write(true).open(&shard).unwrap().set_modified(mtime).unwrap();
        assert_eq!(m.fingerprint_for("plan", &files).unwrap().key(), edited.key());
        assert_ne!(
            super::super::fingerprint::fingerprint("plan", &files).unwrap().key(),
            edited.key()
        );
        std::fs::remove_dir_all(m.dir()).unwrap();
    }

    #[test]
    fn lifetime_counters_accumulate_in_the_sidecar_across_managers() {
        let m = mgr("lifetime", 1, false); // every artifact alone exceeds the cap
        assert_eq!(m.lifetime_counters(), LifetimeCounters::default());
        m.put(&fp("plan-la"), &output(2, "a")).unwrap();
        m.put(&fp("plan-lb"), &output(2, "b")).unwrap();
        let evicted = m.lifetime_counters().evictions;
        assert!(evicted >= 1);
        assert_eq!(m.lifetime_counters().evictions, m.stats().evictions);

        // A fresh manager over the same dir (a "second process") starts
        // its in-process stats at zero but reads the sidecar — and
        // keeps accumulating into it.
        let m2 = CacheManager::with_config(CacheConfig {
            dir: m.dir().to_path_buf(),
            max_bytes: 0,
            memory: false,
            memory_max_bytes: 0,
        })
        .unwrap();
        assert_eq!(m2.stats().evictions, 0);
        assert_eq!(m2.lifetime_counters().evictions, evicted);
        let fpc = fp("plan-lc");
        m2.put(&fpc, &output(4, "c")).unwrap();
        let path = m2.artifact_path(fpc.key());
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(m2.get(&fpc).is_none());
        let c = m2.lifetime_counters();
        assert_eq!((c.evictions, c.corrupt), (evicted, 1));
        // The sidecar is not cache content: entries() skips it and
        // clear() leaves it standing.
        assert!(m2.dir().join(COUNTERS_FILE).exists());
        assert!(m2.entries().unwrap().iter().all(|e| e.path.extension().unwrap() == "p3pc"));
        m2.clear().unwrap();
        assert_eq!(m2.lifetime_counters(), c);
        std::fs::remove_dir_all(m.dir()).unwrap();
    }

    #[test]
    fn shard_tier_stores_restores_and_drops_corrupt_payloads() {
        let m = mgr("shard", 0, false);
        let key = "00000000000000000000000000000abc";
        assert!(m.get_shard(key).is_none());
        assert!(!m.probe_shard(key));
        m.put_shard(key, b"per-shard payload").unwrap();
        assert!(m.probe_shard(key));
        assert_eq!(m.get_shard(key).unwrap(), b"per-shard payload");
        m.count_shard_probe(1, 0);
        let s = m.stats();
        assert_eq!((s.shard_hits, s.shard_misses, s.shard_stores), (1, 0, 1));
        assert_eq!(s.stores, 0, "shard stores never count as whole-plan stores");
        // Shard artifacts are ordinary cache content: listed and cleared.
        assert_eq!(m.entries().unwrap().len(), 1);
        // Corrupt payloads are dropped and counted, never an error.
        let path = m.dir().join(format!("{key}.{ARTIFACT_EXT}"));
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 2]).unwrap();
        assert!(m.get_shard(key).is_none());
        assert!(!path.exists());
        assert_eq!(m.stats().corrupt, 1);
        // remove_shard covers the decoded-but-unusable path.
        m.put_shard(key, b"payload 2").unwrap();
        m.remove_shard(key);
        assert!(!m.probe_shard(key));
        assert_eq!(m.stats().corrupt, 2);
        // Shard counters persist in the lifetime sidecar, so a fresh
        // process (`repro cache stats` after a warm run) can report the
        // restored/executed split. Pre-shard sidecars read back zeros.
        let c = m.lifetime_counters();
        assert_eq!((c.shard_hits, c.shard_misses, c.shard_stores), (1, 0, 2));
        std::fs::write(m.dir().join(COUNTERS_FILE), "evictions=3\ncorrupt=1\n").unwrap();
        let old = m.lifetime_counters();
        assert_eq!((old.evictions, old.corrupt), (3, 1));
        assert_eq!((old.shard_hits, old.shard_misses, old.shard_stores), (0, 0, 0));
        std::fs::remove_dir_all(m.dir()).unwrap();
    }

    #[test]
    fn clear_empties_the_cache_and_sweeps_orphaned_temps() {
        let m = mgr("clear", 0, true);
        m.put(&fp("p1"), &output(1, "x")).unwrap();
        m.put(&fp("p2"), &output(1, "y")).unwrap();
        assert_eq!(m.entries().unwrap().len(), 2);
        // A crash between write and rename strands a temp file.
        let orphan = m.dir().join("deadbeef.1234-0.tmp");
        std::fs::write(&orphan, b"half-written").unwrap();
        assert_eq!(m.clear().unwrap(), 2, "temps are swept but not counted");
        assert_eq!(m.entries().unwrap().len(), 0);
        assert!(!orphan.exists(), "orphaned temp swept");
        assert!(m.get(&fp("p1")).is_none());
        std::fs::remove_dir_all(m.dir()).unwrap();
    }
}
