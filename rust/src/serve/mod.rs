//! Preprocessing-as-a-service: a persistent driver daemon.
//!
//! The paper's cost story (Tables 2–4) is that repeated preprocessing
//! dominates cumulative cloud cost — and a one-shot CLI invocation
//! re-pays the cold-start share of that cost on *every* job: the plan
//! cache memo starts empty, every fingerprint is re-digested, and the
//! `--processes` worker pool is spawned and torn down per run. Spark
//! NLP's production answer is to serve pipelines as long-lived services
//! rather than one-shot jobs; this module is that shape for the plan
//! layer.
//!
//! `repro serve start --socket S` runs a daemon on a local Unix socket.
//! Clients (`repro serve preprocess|explain|train|stats|shutdown`)
//! exchange the same versioned, digest-trailed `P3PJ`/`P3PW` envelopes
//! the multi-process executor ships to its workers — factored into
//! [`proto`] so the framing, digest checks and failure semantics are
//! one implementation — length-prefixed over the stream
//! ([`proto::read_frame`]/[`proto::write_frame`]).
//!
//! What stays warm across requests:
//!
//! - the [`CacheManager`] memo tier (a repeat job restores its frame
//!   from memory and honestly reports a `cache_restore` stage),
//! - the plan-fingerprint memo (a warm repeat revalidates shards with a
//!   stat instead of re-digesting every byte),
//! - a [`WorkerPool`](crate::plan::process::WorkerPool) of persistent
//!   `plan-worker --persist` processes (with `--processes N`), so
//!   `--processes` jobs skip the per-run spawn cost.
//!
//! Concurrency is governed by [`admission::Admission`]: `--max-active`
//! execution permits, a `--max-queue`-bounded wait queue, and a
//! `--job-budget-bytes` per-job memory screen (estimated from the job's
//! total shard bytes — the same quantity the byte-capped memo tiers
//! account in). Over-budget and queue-full submissions get a typed
//! [`proto::ServeError`] reply immediately; they never hang.

pub mod admission;
pub mod proto;

pub use admission::{Admission, Decision};
pub use proto::{
    CacheCounters, ErrKind, JobSpec, PreprocessReply, Reply, Request, ServeError, StatsReply,
};

use crate::cache::CacheManager;
use crate::driver::{run_p3sapp, DriverOptions};
use crate::ingest::list_shards;
use crate::obs;
use crate::plan::process::WorkerPool;
use crate::Result;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Daemon construction knobs (`repro serve start` flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Unix socket path to listen on (created on start, removed on
    /// shutdown; a stale file from a crashed daemon is replaced).
    pub socket: PathBuf,
    /// Plan cache directory (`None` = serve without a cache — every job
    /// executes; the warm-repeat story needs this set).
    pub cache_dir: Option<PathBuf>,
    /// Worker binary for the pool (`None` = this executable, like the
    /// one-shot `--processes` path).
    pub worker_cmd: Option<PathBuf>,
    /// Worker threads inside each in-process executor (0 = one per
    /// core); a job spec's own non-zero `workers` overrides it.
    pub workers: usize,
    /// Keep a pool of N persistent worker processes and run jobs
    /// through the multi-process executor (0 = in-process execution, no
    /// pool).
    pub processes: usize,
    /// Admission: concurrent execution permits.
    pub max_active: usize,
    /// Admission: bounded wait-queue depth (0 = reject when busy).
    pub max_queue: usize,
    /// Admission: per-job memory budget in bytes, screened against the
    /// job's total shard bytes (0 = unlimited).
    pub job_budget_bytes: u64,
    /// Write a Chrome-trace-event JSON covering the daemon's whole
    /// lifetime here on shutdown (`serve start --trace`). Spans from
    /// every served job — driver work, reader/worker threads, pooled
    /// worker processes — land in one timeline.
    pub trace: Option<PathBuf>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            socket: PathBuf::from("/tmp/p3sapp-serve.sock"),
            cache_dir: None,
            worker_cmd: None,
            workers: 0,
            processes: 0,
            max_active: 2,
            max_queue: 8,
            job_budget_bytes: 0,
            trace: None,
        }
    }
}

/// Shared daemon state: everything that stays warm across requests.
#[derive(Debug)]
struct Daemon {
    opts: ServeOptions,
    cache: Option<Arc<CacheManager>>,
    pool: Option<Arc<WorkerPool>>,
    admission: Admission,
    shutdown: AtomicBool,
}

/// Run the daemon until a shutdown request. Blocks the calling thread;
/// client connections are handled on scoped threads, so a panic in one
/// handler cannot orphan the pool.
pub fn run_serve(opts: ServeOptions) -> Result<()> {
    if opts.socket.exists() {
        // A live daemon would still be accepting here; the common case
        // for a pre-existing file is a crashed predecessor's stale
        // socket. Probe before clobbering.
        if UnixStream::connect(&opts.socket).is_ok() {
            anyhow::bail!("a daemon is already listening on {}", opts.socket.display());
        }
        std::fs::remove_file(&opts.socket)
            .map_err(|e| anyhow::anyhow!("remove stale socket {}: {e}", opts.socket.display()))?;
    }
    let listener = UnixListener::bind(&opts.socket)
        .map_err(|e| anyhow::anyhow!("bind {}: {e}", opts.socket.display()))?;

    let cache = match &opts.cache_dir {
        Some(dir) => Some(Arc::new(CacheManager::open(dir.clone())?)),
        None => None,
    };
    let pool = if opts.processes > 0 {
        let cmd = match &opts.worker_cmd {
            Some(cmd) => cmd.clone(),
            None => std::env::current_exe()
                .map_err(|e| anyhow::anyhow!("resolve worker binary: {e}"))?,
        };
        Some(Arc::new(WorkerPool::new(cmd, opts.processes)))
    } else {
        None
    };
    // With --trace, one sink spans the daemon's whole lifetime: every
    // served job's spans (including re-anchored pooled-worker spans)
    // accumulate into a single timeline written at shutdown.
    let trace_sink = opts.trace.as_ref().map(|_| obs::install_new());
    let daemon = Daemon {
        admission: Admission::new(opts.max_active, opts.max_queue, opts.job_budget_bytes),
        opts,
        cache,
        pool,
        shutdown: AtomicBool::new(false),
    };
    eprintln!(
        "[serve] listening on {} (max-active {}, max-queue {}, processes {})",
        daemon.opts.socket.display(),
        daemon.opts.max_active,
        daemon.opts.max_queue,
        daemon.opts.processes
    );

    std::thread::scope(|scope| {
        for conn in listener.incoming() {
            if daemon.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    scope.spawn(|| handle_client(stream, &daemon));
                }
                Err(e) => eprintln!("[serve] accept failed: {e}"),
            }
        }
    });
    // Scope joined: every in-flight job has replied, so the trace is
    // complete — write it before teardown. A write failure costs the
    // trace, never the shutdown.
    if let (Some(path), Some(sink)) = (&daemon.opts.trace, &trace_sink) {
        obs::uninstall();
        match std::fs::write(path, obs::chrome_trace_json(&sink.drain())) {
            Ok(()) => eprintln!("[serve] trace written to {}", path.display()),
            Err(e) => eprintln!("[serve] writing trace {}: {e}", path.display()),
        }
    }
    // Every handler's pool clone is gone and dropping the daemon drops
    // the last Arc — `WorkerPool`'s Drop reaps the persistent workers
    // (clean EOF first, kill as fallback) before run_serve returns.
    let socket = daemon.opts.socket.clone();
    drop(daemon);
    let _ = std::fs::remove_file(&socket);
    eprintln!("[serve] shut down");
    Ok(())
}

/// One-shot client call: connect to a daemon at `socket`, send `req`,
/// return its reply. This is what the `repro serve <job>` subcommands
/// and the black-box tests drive.
pub fn request(socket: &Path, req: &Request) -> Result<Reply> {
    let mut stream = UnixStream::connect(socket)
        .map_err(|e| anyhow::anyhow!("connect {}: {e}", socket.display()))?;
    proto::write_frame(&mut stream, &proto::encode_request(req))
        .map_err(|e| anyhow::anyhow!("send request: {e}"))?;
    match proto::read_frame(&mut stream)? {
        Some(frame) => proto::decode_reply(&frame),
        None => anyhow::bail!("daemon closed the connection without a reply"),
    }
}

/// Serve one connection: one request, one reply. A malformed frame gets
/// a typed `bad_request` reply; a client that hangs up early costs the
/// daemon nothing but a log line.
fn handle_client(mut stream: UnixStream, daemon: &Daemon) {
    // A stalled or vanished client must not pin a handler thread (and
    // with it, scope join at shutdown) forever.
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let reply = match proto::read_frame(&mut stream) {
        // Connected and left without sending a frame: nothing to do.
        Ok(None) => return,
        Ok(Some(frame)) => match proto::decode_request(&frame) {
            Ok(req) => dispatch(req, daemon),
            Err(e) => Reply::Err(ServeError {
                kind: ErrKind::BadRequest,
                message: format!("{e:#}"),
            }),
        },
        Err(e) => Reply::Err(ServeError {
            kind: ErrKind::BadRequest,
            message: format!("{e:#}"),
        }),
    };
    if let Err(e) = proto::write_frame(&mut stream, &proto::encode_reply(&reply)) {
        // The client disconnected mid-job (or stalled past the write
        // timeout). Its admitted work is already done and cached; the
        // daemon itself keeps serving.
        eprintln!("[serve] client went away before the reply: {e}");
    }
}

fn err(kind: ErrKind, message: String) -> Reply {
    Reply::Err(ServeError { kind, message })
}

fn dispatch(req: Request, daemon: &Daemon) -> Reply {
    if daemon.shutdown.load(Ordering::SeqCst) {
        return err(ErrKind::Shutdown, "daemon is shutting down".into());
    }
    match req {
        // Stats is deliberately *not* admission-gated: it is the
        // observability channel clients (and tests) use to watch the
        // admission state itself.
        Request::Stats => {
            let (active, queued) = daemon.admission.load();
            let cache = daemon.cache.as_ref().map(|c| {
                let s = c.stats();
                CacheCounters {
                    mem_hits: s.mem_hits,
                    disk_hits: s.disk_hits,
                    misses: s.misses,
                    stores: s.stores,
                    fp_digest_shards: s.fp_digest_shards,
                    fp_stat_revalidations: s.fp_stat_revalidations,
                    shard_hits: s.shard_hits,
                    shard_misses: s.shard_misses,
                    shard_stores: s.shard_stores,
                }
            });
            Reply::Stats(StatsReply {
                active: active as u64,
                queued: queued as u64,
                worker_pids: daemon.pool.as_deref().map(WorkerPool::pids).unwrap_or_default(),
                cache,
            })
        }
        // Metrics bypasses admission like stats: scraping must work
        // precisely when the daemon is saturated. Gauge-like state and
        // externally-owned counters are mirrored at scrape time; the
        // latency histograms accumulate in `run_admitted`.
        Request::Metrics => {
            let reg = crate::metrics::registry();
            let (active, queued) = daemon.admission.load();
            reg.gauge_set("p3sapp_admission_active", active as u64);
            reg.gauge_set("p3sapp_admission_queued", queued as u64);
            reg.gauge_set(
                "p3sapp_pool_workers_live",
                daemon.pool.as_deref().map(|p| p.pids().len()).unwrap_or(0) as u64,
            );
            if let Some(c) = &daemon.cache {
                let s = c.stats();
                for (name, v) in [
                    ("p3sapp_cache_mem_hits_total", s.mem_hits),
                    ("p3sapp_cache_disk_hits_total", s.disk_hits),
                    ("p3sapp_cache_misses_total", s.misses),
                    ("p3sapp_cache_stores_total", s.stores),
                    ("p3sapp_cache_evictions_total", s.evictions),
                    ("p3sapp_cache_corrupt_total", s.corrupt),
                    ("p3sapp_cache_fp_digest_shards_total", s.fp_digest_shards),
                    ("p3sapp_cache_fp_stat_revalidations_total", s.fp_stat_revalidations),
                    ("p3sapp_cache_shard_hits_total", s.shard_hits),
                    ("p3sapp_cache_shard_misses_total", s.shard_misses),
                    ("p3sapp_cache_shard_stores_total", s.shard_stores),
                ] {
                    reg.counter_store(name, v);
                }
            }
            Reply::Text(reg.exposition())
        }
        Request::Shutdown => {
            daemon.shutdown.store(true, Ordering::SeqCst);
            // The accept loop is blocked in `incoming()`; poke it so it
            // observes the flag. The nudge connection is served the
            // shutting_down reply path or dropped — either is fine.
            let _ = UnixStream::connect(&daemon.opts.socket);
            Reply::Ok
        }
        // Explain is metadata-only (a cheap plan render plus at most a
        // stat-revalidated fingerprint probe), so it also bypasses
        // admission — a full queue must not block introspection.
        Request::Explain(spec) => match explain_job(&spec, daemon) {
            Ok(text) => Reply::Text(text),
            Err(e) => err(ErrKind::Exec, format!("{e:#}")),
        },
        Request::Preprocess(spec) => run_admitted(&spec, daemon, |files, dopts| {
            let res = run_p3sapp(files, dopts)?;
            Ok(Reply::Preprocess(PreprocessReply::from_result(&res)))
        }),
        Request::Train { spec, artifacts, steps } => {
            run_admitted(&spec, daemon, |files, dopts| train_job(files, dopts, &artifacts, steps))
        }
        // The cross-machine artifact exchange: hand out a stored P3PC
        // artifact by key. Not admission-gated — the requester is
        // another machine's already-admitted job, and the cost is one
        // sequential file read.
        Request::FetchArtifact { key } => match fetch_artifact(&key, daemon) {
            Ok(bytes) => Reply::Bytes(bytes),
            Err(e) => err(ErrKind::BadRequest, format!("{e:#}")),
        },
    }
}

/// Resolve one `fetch-artifact` request against the daemon's artifact
/// store. The key is hex (it names an xxh64 fingerprint), so reject
/// anything else outright — a key is never allowed to become a path
/// traversal.
fn fetch_artifact(key: &str, daemon: &Daemon) -> Result<Vec<u8>> {
    let cache = daemon
        .cache
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("this daemon runs cache-less; no artifacts to fetch"))?;
    anyhow::ensure!(
        !key.is_empty()
            && key.len() <= 64
            && key.bytes().all(|b| b.is_ascii_hexdigit() && !b.is_ascii_uppercase()),
        "artifact key must be lowercase hex, got '{key}'"
    );
    let path = cache.dir().join(format!("{key}.{}", crate::cache::ARTIFACT_EXT));
    anyhow::ensure!(
        crate::cache::artifact::verify_header(&path, key),
        "no artifact stored under key {key}"
    );
    std::fs::read(&path).map_err(|e| anyhow::anyhow!("read artifact {key}: {e}"))
}

/// Admission-gated execution shared by preprocess and train: estimate
/// the job's footprint from its shard bytes, take (or be refused) a
/// permit, then run.
fn run_admitted(
    spec: &JobSpec,
    daemon: &Daemon,
    job: impl FnOnce(&[PathBuf], &DriverOptions) -> Result<Reply>,
) -> Reply {
    let files = match list_shards(&spec.dir) {
        Ok(files) => files,
        Err(e) => return err(ErrKind::BadRequest, format!("{e:#}")),
    };
    let job_bytes: u64 =
        files.iter().map(|f| std::fs::metadata(f).map(|m| m.len()).unwrap_or(0)).sum();
    let t_admit = Instant::now();
    let _permit = match daemon.admission.admit(job_bytes) {
        Decision::Admitted(permit) => permit,
        Decision::QueueFull { active, queued } => {
            return err(
                ErrKind::QueueFull,
                format!(
                    "admission queue full ({active} active, {queued} queued, \
                     --max-queue {}); retry later",
                    daemon.opts.max_queue
                ),
            );
        }
        Decision::OverBudget { need_bytes, budget_bytes } => {
            return err(
                ErrKind::OverBudget,
                format!(
                    "job needs ~{need_bytes} bytes of shard input, per-job memory \
                     budget is {budget_bytes} bytes (--job-budget-bytes)"
                ),
            );
        }
    };
    let queue_wait = t_admit.elapsed();
    if spec.linger_millis > 0 {
        std::thread::sleep(Duration::from_millis(spec.linger_millis));
    }
    let dopts = daemon.driver_opts(spec);
    let mut sp = obs::span("serve job", "serve");
    if sp.active() {
        sp.arg("shards", files.len() as u64);
        sp.arg("bytes", job_bytes);
    }
    let t_exec = Instant::now();
    let reply = match job(&files, &dopts) {
        Ok(reply) => reply,
        Err(e) => err(ErrKind::Exec, format!("{e:#}")),
    };
    drop(sp);
    let reg = crate::metrics::registry();
    reg.counter_add("p3sapp_serve_jobs_total", 1);
    reg.observe_us("p3sapp_serve_job_queue_wait_us", queue_wait.as_micros() as u64);
    reg.observe_us("p3sapp_serve_job_execute_us", t_exec.elapsed().as_micros() as u64);
    if let Reply::Preprocess(p) = &reply {
        // Whole-plan restores report the bare stage; incremental runs
        // report `cache_restore(k of n shards)` — both are restore time.
        if let Some((_, nanos)) = p.stages.iter().find(|(name, _)| {
            name == crate::driver::CACHE_RESTORE
                || name.starts_with(&format!("{}(", crate::driver::CACHE_RESTORE))
        }) {
            reg.observe_us("p3sapp_serve_job_cache_restore_us", *nanos / 1_000);
        }
    }
    reply
}

impl Daemon {
    /// Driver options for one served job: the spec's plan-variant knobs
    /// over the daemon's warm cache and pool.
    fn driver_opts(&self, spec: &JobSpec) -> DriverOptions {
        DriverOptions {
            workers: if spec.workers > 0 { spec.workers } else { self.opts.workers },
            executor: match &self.pool {
                Some(pool) => crate::plan::ExecutorKind::Pool(Arc::clone(pool)),
                None => crate::plan::ExecutorKind::Fused,
            },
            cache: self.cache.clone(),
            sample: spec.sample,
            limit: spec.limit,
            features: spec.features,
            ..Default::default()
        }
    }
}

fn explain_job(spec: &JobSpec, daemon: &Daemon) -> Result<String> {
    let files = list_shards(&spec.dir)?;
    let dopts = daemon.driver_opts(spec);
    crate::cache::explain_with_cache(
        &dopts.build_plan(&files),
        dopts.workers,
        &dopts.executor,
        dopts.cache.as_deref(),
    )
}

/// The served `train` job: preprocess through the warm cache, then run
/// the real training loop against the AOT artifacts. Mirrors the CLI
/// `train` pipeline; the reply is a text summary (the model lives in
/// the daemon's artifacts dir, not on the wire).
fn train_job(
    files: &[PathBuf],
    dopts: &DriverOptions,
    artifacts: &str,
    steps: usize,
) -> Result<Reply> {
    use crate::runtime::{Session, Trainer};
    use crate::vocab::{Batcher, Vocabulary};
    let pre = run_p3sapp(files, dopts)?;
    let from_cache = pre.from_cache();
    let session = Session::cpu(artifacts)?;
    let mut trainer = Trainer::new(session)?;
    let mcfg = trainer.manifest.config.clone();
    let frame = pre.frame;
    let texts: Vec<&str> = (0..frame.num_rows())
        .flat_map(|i| {
            [frame.column(0).get_str(i).unwrap_or(""), frame.column(1).get_str(i).unwrap_or("")]
        })
        .collect();
    let vocab = Vocabulary::build(texts.into_iter(), mcfg.vocab);
    let mut batcher = Batcher::new(
        &frame,
        &vocab,
        "title",
        "abstract",
        mcfg.batch,
        mcfg.src_len,
        mcfg.tgt_len,
        42,
    )?;
    let stats = trainer.train_loop(steps, || batcher.next_batch())?;
    let last_loss = stats.last().map(|s| s.loss).unwrap_or(f32::NAN);
    Ok(Reply::Text(format!(
        "preprocessed {} rows (cache restore: {from_cache}), trained {} steps, \
         final loss {last_loss:.4}",
        frame.num_rows(),
        stats.len(),
    )))
}
