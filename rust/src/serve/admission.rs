//! Admission control for the serve daemon: a bounded wait queue in
//! front of a fixed number of execution permits, plus a per-job memory
//! budget screened before a job ever queues.
//!
//! The contract the black-box tests pin: a submission is either
//! **admitted** (possibly after waiting in the bounded queue), or
//! rejected **immediately** with a typed cause — queue at capacity or
//! job over the memory budget. Nothing ever blocks indefinitely behind
//! an unbounded backlog, and rejection is a reply, not a dropped
//! connection.

use std::sync::{Condvar, Mutex};

/// Shared admission state: `active` jobs hold a permit, `queued` jobs
/// wait for one.
#[derive(Debug, Default)]
struct State {
    active: usize,
    queued: usize,
}

/// The daemon-wide admission controller.
#[derive(Debug)]
pub struct Admission {
    max_active: usize,
    max_queue: usize,
    job_budget_bytes: u64,
    state: Mutex<State>,
    cv: Condvar,
}

/// An execution permit; dropping it releases the slot and wakes one
/// queued waiter.
#[derive(Debug)]
pub struct Permit<'a> {
    adm: &'a Admission,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        let mut st = self.adm.state.lock().unwrap();
        st.active -= 1;
        drop(st);
        self.adm.cv.notify_one();
    }
}

/// The outcome of [`Admission::admit`].
#[derive(Debug)]
pub enum Decision<'a> {
    Admitted(Permit<'a>),
    /// The wait queue is at capacity; the load snapshot goes into the
    /// typed reply so a client sees *why* it was turned away.
    QueueFull { active: usize, queued: usize },
    /// The job's estimated footprint exceeds the per-job budget; it
    /// would be rejected no matter how idle the daemon is, so it is
    /// screened before taking a queue slot.
    OverBudget { need_bytes: u64, budget_bytes: u64 },
}

impl Admission {
    /// `max_active` is clamped to ≥ 1 (an admission controller that can
    /// admit nothing is a deadlock generator); `max_queue` 0 means
    /// reject whenever all permits are busy; `job_budget_bytes` 0 means
    /// no per-job memory screening.
    pub fn new(max_active: usize, max_queue: usize, job_budget_bytes: u64) -> Admission {
        Admission {
            max_active: max_active.max(1),
            max_queue,
            job_budget_bytes,
            state: Mutex::new(State::default()),
            cv: Condvar::new(),
        }
    }

    /// Try to admit a job with an estimated footprint of `job_bytes`.
    /// May block while queued (bounded by `max_queue` peers), never
    /// blocks when rejecting.
    pub fn admit(&self, job_bytes: u64) -> Decision<'_> {
        if self.job_budget_bytes > 0 && job_bytes > self.job_budget_bytes {
            return Decision::OverBudget {
                need_bytes: job_bytes,
                budget_bytes: self.job_budget_bytes,
            };
        }
        let mut st = self.state.lock().unwrap();
        if st.active < self.max_active {
            st.active += 1;
            return Decision::Admitted(Permit { adm: self });
        }
        if st.queued >= self.max_queue {
            return Decision::QueueFull { active: st.active, queued: st.queued };
        }
        st.queued += 1;
        while st.active >= self.max_active {
            st = self.cv.wait(st).unwrap();
        }
        st.queued -= 1;
        st.active += 1;
        Decision::Admitted(Permit { adm: self })
    }

    /// `(active, queued)` snapshot for the stats reply.
    pub fn load(&self) -> (usize, usize) {
        let st = self.state.lock().unwrap();
        (st.active, st.queued)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn over_budget_rejects_immediately() {
        let adm = Admission::new(2, 8, 100);
        match adm.admit(101) {
            Decision::OverBudget { need_bytes, budget_bytes } => {
                assert_eq!((need_bytes, budget_bytes), (101, 100));
            }
            other => panic!("expected OverBudget, got {other:?}"),
        }
        // Exactly at budget is fine; budget 0 disables the screen.
        assert!(matches!(adm.admit(100), Decision::Admitted(_)));
        let unlimited = Admission::new(1, 0, 0);
        assert!(matches!(unlimited.admit(u64::MAX), Decision::Admitted(_)));
    }

    #[test]
    fn queue_full_rejects_with_load_snapshot() {
        let adm = Admission::new(1, 0, 0);
        let permit = match adm.admit(1) {
            Decision::Admitted(p) => p,
            other => panic!("expected Admitted, got {other:?}"),
        };
        match adm.admit(1) {
            Decision::QueueFull { active, queued } => assert_eq!((active, queued), (1, 0)),
            other => panic!("expected QueueFull, got {other:?}"),
        }
        drop(permit);
        assert_eq!(adm.load(), (0, 0));
        assert!(matches!(adm.admit(1), Decision::Admitted(_)));
    }

    #[test]
    fn queued_job_runs_after_permit_release() {
        let adm = Arc::new(Admission::new(1, 4, 0));
        let first = match adm.admit(1) {
            Decision::Admitted(p) => p,
            other => panic!("expected Admitted, got {other:?}"),
        };
        let adm2 = Arc::clone(&adm);
        let waiter = std::thread::spawn(move || match adm2.admit(1) {
            Decision::Admitted(_) => true,
            _ => false,
        });
        // Wait until the second submission is visibly queued, then
        // release the permit and let it through.
        loop {
            if adm.load().1 == 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        drop(first);
        assert!(waiter.join().unwrap(), "queued job was admitted after release");
        // The waiter's permit dropped when its thread finished.
        assert_eq!(adm.load(), (0, 0));
    }
}
