//! The serve wire protocol: **one implementation** of the versioned
//! `P3PJ`/`P3PW` envelope discipline shared by the multi-process plan
//! executor ([`crate::plan::process`]) and the serve daemon
//! ([`crate::serve`]).
//!
//! Three layers, smallest first:
//!
//! 1. **Envelope** — every message is `magic(4) + version(u32 LE) +
//!    body + xxh64(body[4..])` ([`begin_frame`]/[`seal_frame`] build
//!    it, [`check_frame`] validates it). Truncation, corruption and
//!    version skew are detected before any payload is trusted; this is
//!    the exact code the process executor has pinned since PR 5, now
//!    factored here so the daemon cannot drift from it.
//! 2. **Stream framing** — a `u64 LE` length prefix per envelope
//!    ([`read_frame`]/[`write_frame`]), so the same envelopes cross a
//!    long-lived byte stream (the daemon's Unix socket, a pooled
//!    worker's pipes) instead of a one-shot stdin/stdout pair. Clean
//!    EOF at a frame boundary is `None`, not an error — that is how a
//!    pooled worker and the daemon's accept loop distinguish an orderly
//!    hang-up from a truncated message.
//! 3. **Serve job codec** — [`Request`]/[`Reply`] for the daemon's
//!    preprocess/explain/train/stats/shutdown jobs, including the typed
//!    backpressure errors ([`ServeError`]) admission control returns
//!    instead of hanging.

use crate::cache::artifact::{decode_cells, dtype_code, dtype_from, encode_cells, Cursor};
use crate::cache::xxh64;
use crate::frame::{Column, DType, Field, LocalFrame, Schema};
use crate::Result;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

/// Job frame magic (driver → worker, daemon client → daemon).
pub(crate) const JOB_MAGIC: &[u8; 4] = b"P3PJ";
/// Result frame magic (worker → driver, daemon → client).
pub(crate) const REPLY_MAGIC: &[u8; 4] = b"P3PW";
/// Wire-format version shared by both frames; a mismatch is a hard
/// error (driver, workers and daemon are the same binary, so it only
/// trips when a foreign peer is pointed at an incompatible build).
/// v2: plan-worker job frames carry a trace flag, plan-worker replies
/// end with a span section, stats replies carry typed cache counters,
/// and the metrics request exists.
/// v3: stats-reply cache counters grew the per-shard incremental tier
/// (`shard_hits`, `shard_misses`, `shard_stores`).
pub(crate) const WIRE_VERSION: u32 = 3;
/// Plan-worker job modes: run the op program and return per-shard
/// results, or fold the shards into a fit accumulator and return its
/// partial state.
pub(crate) const MODE_MAP: u8 = 0;
pub(crate) const MODE_FIT: u8 = 1;
/// Remote-worker streaming reply modes: one bounded `MODE_MAP_CHUNK`
/// frame per completed shard (so the worker never buffers its whole
/// stripe), then a single `MODE_MAP_DONE` frame carrying the chunk
/// count and the worker's span section. Only the TCP transport
/// ([`crate::plan::remote`]) emits these; pipe workers keep the
/// buffered single-frame `MODE_MAP` reply.
pub(crate) const MODE_MAP_CHUNK: u8 = 2;
pub(crate) const MODE_MAP_DONE: u8 = 3;

/// Upper bound on one length-prefixed frame: a declared length past
/// this is treated as a garbled prefix rather than honored with a
/// multi-gigabyte allocation.
const MAX_FRAME_BYTES: u64 = 1 << 30;

/// Start an envelope: magic + version, body appended by the caller.
pub(crate) fn begin_frame(magic: &[u8; 4]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(256);
    buf.extend_from_slice(magic);
    buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    buf
}

/// Finish an envelope: append the xxh64 digest over everything past the
/// magic (version included, like the `P3PC` artifact convention).
pub(crate) fn seal_frame(buf: &mut Vec<u8>) {
    let digest = xxh64(&buf[4..], 0);
    buf.extend_from_slice(&digest.to_le_bytes());
}

/// Validate a frame's envelope (magic, digest, version) and return a
/// cursor over its body.
pub(crate) fn check_frame<'a>(bytes: &'a [u8], magic: &[u8; 4], what: &str) -> Result<Cursor<'a>> {
    anyhow::ensure!(bytes.len() >= 16, "{what} frame too short ({} bytes)", bytes.len());
    anyhow::ensure!(&bytes[..4] == magic, "{what} frame has bad magic");
    let body = &bytes[..bytes.len() - 8];
    let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    anyhow::ensure!(xxh64(&body[4..], 0) == stored, "{what} frame digest mismatch");
    let mut cur = Cursor::new(body, 4);
    let version = cur.u32()?;
    anyhow::ensure!(version == WIRE_VERSION, "unsupported {what} frame version {version}");
    Ok(cur)
}

pub(crate) fn write_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

/// Shard paths cross the wire as raw OS bytes on unix — a POSIX
/// filename need not be UTF-8, and a lossy round trip would make the
/// worker fail on a subtly mangled path. Elsewhere (no byte-level path
/// API) the lossy conversion is the best available.
pub(crate) fn write_path(buf: &mut Vec<u8>, path: &Path) {
    #[cfg(unix)]
    {
        use std::os::unix::ffi::OsStrExt;
        let bytes = path.as_os_str().as_bytes();
        buf.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
        buf.extend_from_slice(bytes);
    }
    #[cfg(not(unix))]
    {
        write_str(buf, &path.to_string_lossy());
    }
}

pub(crate) fn read_path(cur: &mut Cursor<'_>) -> Result<PathBuf> {
    let len = cur.u32()? as usize;
    let bytes = cur.take(len)?;
    #[cfg(unix)]
    {
        use std::os::unix::ffi::OsStrExt;
        Ok(PathBuf::from(std::ffi::OsStr::from_bytes(bytes)))
    }
    #[cfg(not(unix))]
    {
        Ok(PathBuf::from(String::from_utf8(bytes.to_vec())?))
    }
}

/// Write one envelope onto a byte stream with a `u64 LE` length prefix.
pub fn write_frame(w: &mut impl Write, frame: &[u8]) -> std::io::Result<()> {
    w.write_all(&(frame.len() as u64).to_le_bytes())?;
    w.write_all(frame)?;
    w.flush()
}

/// Read one length-prefixed envelope off a byte stream. Clean EOF at a
/// frame boundary returns `None` (orderly hang-up); EOF inside a prefix
/// or body, an unreasonable declared length, or any other I/O error is
/// an `Err` — truncation can never be mistaken for completion.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 8];
    let mut got = 0;
    while got < 8 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => anyhow::bail!("frame length prefix truncated ({got} of 8 bytes)"),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(anyhow::anyhow!("reading frame length: {e}")),
        }
    }
    let len = u64::from_le_bytes(len_buf);
    anyhow::ensure!(
        (16..=MAX_FRAME_BYTES).contains(&len),
        "unreasonable frame length {len}"
    );
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)
        .map_err(|e| anyhow::anyhow!("frame body truncated ({len} bytes declared): {e}"))?;
    Ok(Some(buf))
}

// ---------------------------------------------------------------------------
// Serve job codec
// ---------------------------------------------------------------------------

const REQ_PREPROCESS: u8 = 0;
const REQ_EXPLAIN: u8 = 1;
const REQ_TRAIN: u8 = 2;
const REQ_STATS: u8 = 3;
const REQ_SHUTDOWN: u8 = 4;
const REQ_METRICS: u8 = 5;
const REQ_FETCH_ARTIFACT: u8 = 6;

const STATUS_OK: u8 = 0;
const STATUS_ERR: u8 = 1;

const PAYLOAD_EMPTY: u8 = 0;
const PAYLOAD_TEXT: u8 = 1;
const PAYLOAD_PREPROCESS: u8 = 2;
const PAYLOAD_STATS: u8 = 3;
const PAYLOAD_BYTES: u8 = 4;

/// One preprocessing job, as a client describes it: the corpus dir plus
/// the plan-variant knobs the one-shot CLI takes.
#[derive(Debug, Clone, Default)]
pub struct JobSpec {
    pub dir: PathBuf,
    /// Worker threads for the in-process executors (0 = one per core).
    pub workers: usize,
    pub sample: Option<(f64, u64)>,
    pub limit: Option<usize>,
    pub features: bool,
    /// Test/ops knob: hold the admission permit for this many
    /// milliseconds before executing. Makes the admission-control
    /// black-box tests (queue-full, client-disconnect-mid-job)
    /// deterministic without a sleep-and-hope race; 0 in normal use.
    pub linger_millis: u64,
}

/// A client request to the serve daemon.
#[derive(Debug, Clone)]
pub enum Request {
    Preprocess(JobSpec),
    Explain(JobSpec),
    Train { spec: JobSpec, artifacts: String, steps: usize },
    Stats,
    Shutdown,
    /// Prometheus-style text exposition of the daemon's metrics
    /// registry (counters, gauges, latency histograms). Answered with
    /// [`Reply::Text`]; never queued behind admission control.
    Metrics,
    /// Fetch content-addressed bytes by their hex xxh64 `key` — the
    /// cross-machine artifact exchange. The serve daemon answers from
    /// its `P3PC` artifact store; a remote plan worker sends this back
    /// up its job connection to pull a shard the driver declared by
    /// digest instead of shipping inline. Answered with
    /// [`Reply::Bytes`]; never queued behind admission control (it
    /// gates another machine's already-admitted job).
    FetchArtifact { key: String },
}

/// Typed failure causes: admission backpressure ([`ErrKind::QueueFull`],
/// [`ErrKind::OverBudget`]) and the request/execution failures. A
/// client always gets one of these as a reply — never a hang, never a
/// dropped connection with no diagnosis (unless the client itself left).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrKind {
    /// Admission queue at capacity: retry later.
    QueueFull,
    /// The job's estimated bytes exceed the per-job memory budget.
    OverBudget,
    /// The request frame or its contents could not be understood.
    BadRequest,
    /// The job was admitted but failed while executing.
    Exec,
    /// The daemon is shutting down and takes no new jobs.
    Shutdown,
}

impl ErrKind {
    pub fn name(&self) -> &'static str {
        match self {
            ErrKind::QueueFull => "queue_full",
            ErrKind::OverBudget => "over_budget",
            ErrKind::BadRequest => "bad_request",
            ErrKind::Exec => "exec",
            ErrKind::Shutdown => "shutting_down",
        }
    }

    fn code(&self) -> u8 {
        match self {
            ErrKind::QueueFull => 0,
            ErrKind::OverBudget => 1,
            ErrKind::BadRequest => 2,
            ErrKind::Exec => 3,
            ErrKind::Shutdown => 4,
        }
    }

    fn from_code(code: u8) -> Result<ErrKind> {
        Ok(match code {
            0 => ErrKind::QueueFull,
            1 => ErrKind::OverBudget,
            2 => ErrKind::BadRequest,
            3 => ErrKind::Exec,
            4 => ErrKind::Shutdown,
            other => anyhow::bail!("unknown serve error kind {other}"),
        })
    }
}

/// A typed error reply naming its cause.
#[derive(Debug, Clone)]
pub struct ServeError {
    pub kind: ErrKind,
    pub message: String,
}

/// A completed preprocess job: the row accounting, the honest stage
/// times (a warm job reports exactly one `cache_restore` stage), and
/// the cleaned frame itself, cell-encoded with the same `P3PC` codec
/// the artifact store and the worker reply frames use.
#[derive(Debug, Clone)]
pub struct PreprocessReply {
    pub rows_ingested: u64,
    pub rows_out: u64,
    /// `(stage name, nanos)` in recorded order.
    pub stages: Vec<(String, u64)>,
    /// `(column name, dtype)` in schema order.
    pub schema: Vec<(String, DType)>,
    pub columns: Vec<Column>,
}

impl PreprocessReply {
    pub fn from_result(res: &crate::driver::PreprocessResult) -> PreprocessReply {
        PreprocessReply {
            rows_ingested: res.rows_ingested as u64,
            rows_out: res.rows_out as u64,
            stages: res
                .times
                .stages()
                .map(|(name, d)| (name.to_string(), d.as_nanos() as u64))
                .collect(),
            schema: res
                .frame
                .schema()
                .fields()
                .iter()
                .map(|f| (f.name.clone(), f.dtype))
                .collect(),
            columns: res.frame.columns().to_vec(),
        }
    }

    /// Whether this job was served from the live cache (keyed on the
    /// presence of the `cache_restore` stage, like
    /// [`crate::driver::PreprocessResult::from_cache`]).
    pub fn from_cache(&self) -> bool {
        self.stages.iter().any(|(name, _)| name == crate::driver::CACHE_RESTORE)
    }

    /// Reassemble the cleaned frame — what byte-identity tests compare
    /// against a one-shot in-process run.
    pub fn frame(&self) -> Result<LocalFrame> {
        let fields =
            self.schema.iter().map(|(name, dtype)| Field::new(name.clone(), *dtype)).collect();
        LocalFrame::from_columns(Schema::new(fields), self.columns.clone())
    }
}

/// Typed cache counters as they cross the wire — numbers, not a
/// pre-formatted line. The CLI renders them at the edge; tests and
/// monitoring read the fields directly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheCounters {
    pub mem_hits: u64,
    pub disk_hits: u64,
    pub misses: u64,
    pub stores: u64,
    /// Shards whose content digest was recomputed while fingerprinting.
    pub fp_digest_shards: u64,
    /// Fingerprint memo hits revalidated by a stat scan alone.
    pub fp_stat_revalidations: u64,
    /// Per-shard incremental tier: shards restored instead of executed.
    pub shard_hits: u64,
    /// Per-shard incremental tier: shards that had to execute.
    pub shard_misses: u64,
    /// Per-shard artifacts written.
    pub shard_stores: u64,
}

/// Daemon liveness/occupancy snapshot.
#[derive(Debug, Clone)]
pub struct StatsReply {
    /// Jobs currently holding an admission permit.
    pub active: u64,
    /// Jobs waiting in the admission queue.
    pub queued: u64,
    /// PIDs of the live pooled plan workers (lazily spawned — empty
    /// until the first `--processes` job warms the pool).
    pub worker_pids: Vec<u32>,
    /// Live cache counters; `None` when the daemon runs cache-less.
    pub cache: Option<CacheCounters>,
}

/// A daemon reply.
#[derive(Debug, Clone)]
pub enum Reply {
    Preprocess(PreprocessReply),
    /// Rendered EXPLAIN text or a train summary.
    Text(String),
    Stats(StatsReply),
    /// Bare acknowledgement (shutdown).
    Ok,
    /// Raw content-addressed bytes (a [`Request::FetchArtifact`]
    /// answer). The requester verifies the digest against the key it
    /// asked for — the transport digest only covers the frame.
    Bytes(Vec<u8>),
    Err(ServeError),
}

fn encode_spec(buf: &mut Vec<u8>, spec: &JobSpec) {
    write_path(buf, &spec.dir);
    buf.extend_from_slice(&(spec.workers as u32).to_le_bytes());
    match spec.sample {
        None => buf.push(0),
        Some((fraction, seed)) => {
            buf.push(1);
            buf.extend_from_slice(&fraction.to_le_bytes());
            buf.extend_from_slice(&seed.to_le_bytes());
        }
    }
    match spec.limit {
        None => buf.push(0),
        Some(n) => {
            buf.push(1);
            buf.extend_from_slice(&(n as u64).to_le_bytes());
        }
    }
    buf.push(spec.features as u8);
    buf.extend_from_slice(&spec.linger_millis.to_le_bytes());
}

fn decode_spec(cur: &mut Cursor<'_>) -> Result<JobSpec> {
    let dir = read_path(cur)?;
    let workers = cur.u32()? as usize;
    let sample = match cur.u8()? {
        0 => None,
        _ => Some((cur.f64()?, cur.u64()?)),
    };
    let limit = match cur.u8()? {
        0 => None,
        _ => Some(cur.u64()? as usize),
    };
    let features = cur.u8()? != 0;
    let linger_millis = cur.u64()?;
    Ok(JobSpec { dir, workers, sample, limit, features, linger_millis })
}

/// Serialize a request into a sealed `P3PJ` envelope.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut buf = begin_frame(JOB_MAGIC);
    match req {
        Request::Preprocess(spec) => {
            buf.push(REQ_PREPROCESS);
            encode_spec(&mut buf, spec);
        }
        Request::Explain(spec) => {
            buf.push(REQ_EXPLAIN);
            encode_spec(&mut buf, spec);
        }
        Request::Train { spec, artifacts, steps } => {
            buf.push(REQ_TRAIN);
            encode_spec(&mut buf, spec);
            write_str(&mut buf, artifacts);
            buf.extend_from_slice(&(*steps as u64).to_le_bytes());
        }
        Request::Stats => buf.push(REQ_STATS),
        Request::Shutdown => buf.push(REQ_SHUTDOWN),
        Request::Metrics => buf.push(REQ_METRICS),
        Request::FetchArtifact { key } => {
            buf.push(REQ_FETCH_ARTIFACT);
            write_str(&mut buf, key);
        }
    }
    seal_frame(&mut buf);
    buf
}

/// Validate and decode a request envelope.
pub fn decode_request(frame: &[u8]) -> Result<Request> {
    let mut cur = check_frame(frame, JOB_MAGIC, "serve request")?;
    let req = match cur.u8()? {
        REQ_PREPROCESS => Request::Preprocess(decode_spec(&mut cur)?),
        REQ_EXPLAIN => Request::Explain(decode_spec(&mut cur)?),
        REQ_TRAIN => {
            let spec = decode_spec(&mut cur)?;
            let artifacts = cur.str()?;
            let steps = cur.u64()? as usize;
            Request::Train { spec, artifacts, steps }
        }
        REQ_STATS => Request::Stats,
        REQ_SHUTDOWN => Request::Shutdown,
        REQ_METRICS => Request::Metrics,
        REQ_FETCH_ARTIFACT => Request::FetchArtifact { key: cur.str()? },
        other => anyhow::bail!("unknown serve request kind {other}"),
    };
    anyhow::ensure!(
        cur.remaining() == 0,
        "serve request has {} trailing bytes",
        cur.remaining()
    );
    Ok(req)
}

/// Serialize a reply into a sealed `P3PW` envelope.
pub fn encode_reply(reply: &Reply) -> Vec<u8> {
    let mut buf = begin_frame(REPLY_MAGIC);
    match reply {
        Reply::Err(e) => {
            buf.push(STATUS_ERR);
            buf.push(e.kind.code());
            write_str(&mut buf, &e.message);
        }
        Reply::Ok => {
            buf.push(STATUS_OK);
            buf.push(PAYLOAD_EMPTY);
        }
        Reply::Bytes(bytes) => {
            buf.push(STATUS_OK);
            buf.push(PAYLOAD_BYTES);
            buf.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
            buf.extend_from_slice(bytes);
        }
        Reply::Text(text) => {
            buf.push(STATUS_OK);
            buf.push(PAYLOAD_TEXT);
            write_str(&mut buf, text);
        }
        Reply::Stats(s) => {
            buf.push(STATUS_OK);
            buf.push(PAYLOAD_STATS);
            buf.extend_from_slice(&s.active.to_le_bytes());
            buf.extend_from_slice(&s.queued.to_le_bytes());
            buf.extend_from_slice(&(s.worker_pids.len() as u32).to_le_bytes());
            for pid in &s.worker_pids {
                buf.extend_from_slice(&pid.to_le_bytes());
            }
            match &s.cache {
                None => buf.push(0),
                Some(c) => {
                    buf.push(1);
                    for n in [
                        c.mem_hits,
                        c.disk_hits,
                        c.misses,
                        c.stores,
                        c.fp_digest_shards,
                        c.fp_stat_revalidations,
                        c.shard_hits,
                        c.shard_misses,
                        c.shard_stores,
                    ] {
                        buf.extend_from_slice(&n.to_le_bytes());
                    }
                }
            }
        }
        Reply::Preprocess(p) => {
            buf.push(STATUS_OK);
            buf.push(PAYLOAD_PREPROCESS);
            buf.extend_from_slice(&p.rows_ingested.to_le_bytes());
            buf.extend_from_slice(&p.rows_out.to_le_bytes());
            buf.extend_from_slice(&(p.stages.len() as u32).to_le_bytes());
            for (name, nanos) in &p.stages {
                write_str(&mut buf, name);
                buf.extend_from_slice(&nanos.to_le_bytes());
            }
            buf.extend_from_slice(&(p.schema.len() as u32).to_le_bytes());
            for ((name, dtype), col) in p.schema.iter().zip(&p.columns) {
                write_str(&mut buf, name);
                buf.push(dtype_code(*dtype));
                encode_cells(&mut buf, col);
            }
        }
    }
    seal_frame(&mut buf);
    buf
}

/// Validate and decode a reply envelope. Every declared count is
/// checked against the bytes present (via the shared `P3PC` cell
/// decoder) so a corrupt reply can only ever error.
pub fn decode_reply(frame: &[u8]) -> Result<Reply> {
    let mut cur = check_frame(frame, REPLY_MAGIC, "serve reply")?;
    let reply = match cur.u8()? {
        STATUS_ERR => {
            let kind = ErrKind::from_code(cur.u8()?)?;
            let message = cur.str()?;
            Reply::Err(ServeError { kind, message })
        }
        STATUS_OK => match cur.u8()? {
            PAYLOAD_EMPTY => Reply::Ok,
            PAYLOAD_TEXT => Reply::Text(cur.str()?),
            PAYLOAD_BYTES => {
                let len = cur.u64()? as usize;
                Reply::Bytes(cur.take(len)?.to_vec())
            }
            PAYLOAD_STATS => {
                let active = cur.u64()?;
                let queued = cur.u64()?;
                let n = cur.u32()? as usize;
                anyhow::ensure!(
                    n.saturating_mul(4) <= cur.remaining(),
                    "stats reply declares {n} worker pids"
                );
                let worker_pids = (0..n).map(|_| cur.u32()).collect::<Result<Vec<_>>>()?;
                let cache = match cur.u8()? {
                    0 => None,
                    _ => Some(CacheCounters {
                        mem_hits: cur.u64()?,
                        disk_hits: cur.u64()?,
                        misses: cur.u64()?,
                        stores: cur.u64()?,
                        fp_digest_shards: cur.u64()?,
                        fp_stat_revalidations: cur.u64()?,
                        shard_hits: cur.u64()?,
                        shard_misses: cur.u64()?,
                        shard_stores: cur.u64()?,
                    }),
                };
                Reply::Stats(StatsReply { active, queued, worker_pids, cache })
            }
            PAYLOAD_PREPROCESS => {
                let rows_ingested = cur.u64()?;
                let rows_out = cur.u64()?;
                let n_stages = cur.u32()? as usize;
                anyhow::ensure!(
                    n_stages <= cur.remaining(),
                    "preprocess reply declares {n_stages} stages"
                );
                let mut stages = Vec::with_capacity(n_stages);
                for _ in 0..n_stages {
                    let name = cur.str()?;
                    stages.push((name, cur.u64()?));
                }
                let n_cols = cur.u32()? as usize;
                anyhow::ensure!(
                    n_cols <= cur.remaining(),
                    "preprocess reply declares {n_cols} columns"
                );
                let mut schema = Vec::with_capacity(n_cols);
                let mut columns = Vec::with_capacity(n_cols);
                for _ in 0..n_cols {
                    let name = cur.str()?;
                    let dtype = dtype_from(cur.u8()?)?;
                    columns.push(decode_cells(&mut cur, dtype, rows_out as usize)?);
                    schema.push((name, dtype));
                }
                Reply::Preprocess(PreprocessReply {
                    rows_ingested,
                    rows_out,
                    stages,
                    schema,
                    columns,
                })
            }
            other => anyhow::bail!("unknown serve reply payload {other}"),
        },
        other => anyhow::bail!("unknown serve reply status {other}"),
    };
    anyhow::ensure!(
        cur.remaining() == 0,
        "serve reply has {} trailing bytes",
        cur.remaining()
    );
    Ok(reply)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_framing_roundtrips_and_detects_truncation() {
        let frame = vec![7u8; 64];
        let mut wire = Vec::new();
        write_frame(&mut wire, &frame).unwrap();
        let mut r = wire.as_slice();
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), frame);
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF is None");
        // Truncated prefix and truncated body are errors, not EOF.
        assert!(read_frame(&mut &wire[..4]).is_err());
        assert!(read_frame(&mut &wire[..wire.len() - 1]).is_err());
        // A garbage length prefix never drives a giant allocation.
        let mut huge = Vec::new();
        huge.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(read_frame(&mut huge.as_slice()).is_err());
    }

    #[test]
    fn requests_roundtrip_and_reject_corruption() {
        let spec = JobSpec {
            dir: PathBuf::from("/tmp/corpus"),
            workers: 4,
            sample: Some((0.5, 42)),
            limit: Some(100),
            features: true,
            linger_millis: 250,
        };
        for req in [
            Request::Preprocess(spec.clone()),
            Request::Explain(spec.clone()),
            Request::Train { spec: spec.clone(), artifacts: "artifacts".into(), steps: 12 },
            Request::Stats,
            Request::Shutdown,
            Request::Metrics,
            Request::FetchArtifact { key: "00deadbeefc0ffee".into() },
        ] {
            let frame = encode_request(&req);
            let back = decode_request(&frame).unwrap();
            match (&req, &back) {
                (Request::Preprocess(a), Request::Preprocess(b))
                | (Request::Explain(a), Request::Explain(b)) => {
                    assert_eq!(a.dir, b.dir);
                    assert_eq!(a.workers, b.workers);
                    assert_eq!(a.sample, b.sample);
                    assert_eq!(a.limit, b.limit);
                    assert_eq!(a.features, b.features);
                    assert_eq!(a.linger_millis, b.linger_millis);
                }
                (
                    Request::Train { spec: a, artifacts: aa, steps: sa },
                    Request::Train { spec: b, artifacts: ab, steps: sb },
                ) => {
                    assert_eq!(a.dir, b.dir);
                    assert_eq!((aa, sa), (ab, sb));
                }
                (Request::Stats, Request::Stats)
                | (Request::Shutdown, Request::Shutdown)
                | (Request::Metrics, Request::Metrics) => {}
                (
                    Request::FetchArtifact { key: a },
                    Request::FetchArtifact { key: b },
                ) => assert_eq!(a, b),
                other => panic!("request changed shape over the wire: {other:?}"),
            }
            // Corruption fails the digest; truncation fails the length
            // checks — never a panic, never a silently different job.
            let mut bad = frame.clone();
            let mid = bad.len() / 2;
            bad[mid] ^= 0x10;
            assert!(decode_request(&bad).is_err());
            assert!(decode_request(&frame[..frame.len() - 3]).is_err());
            // A request is not a reply.
            assert!(decode_reply(&frame).is_err());
        }
    }

    #[test]
    fn replies_roundtrip_including_frames_and_typed_errors() {
        let frame = LocalFrame::from_columns(
            Schema::new(vec![
                Field::new("title", DType::Str),
                Field::new("tfidf", DType::Vector),
            ]),
            vec![
                Column::Str(vec![Some("a".into()), Some("b".into())]),
                Column::Vecs(vec![Some(vec![1.0, 2.0]), None]),
            ],
        )
        .unwrap();
        let res = crate::driver::PreprocessResult {
            frame: frame.clone(),
            times: {
                let mut t = crate::metrics::StageTimes::new();
                t.add(crate::driver::CACHE_RESTORE, std::time::Duration::from_millis(3));
                t
            },
            rows_ingested: 5,
            rows_out: 2,
        };
        let p = PreprocessReply::from_result(&res);
        assert!(p.from_cache());
        let wire = encode_reply(&Reply::Preprocess(p));
        match decode_reply(&wire).unwrap() {
            Reply::Preprocess(back) => {
                assert_eq!(back.rows_ingested, 5);
                assert_eq!(back.rows_out, 2);
                assert_eq!(back.stages, vec![("cache_restore".to_string(), 3_000_000)]);
                assert!(back.from_cache());
                assert_eq!(back.frame().unwrap(), frame, "frame survives the socket byte-for-byte");
            }
            other => panic!("wrong reply: {other:?}"),
        }
        let mut bad = wire.clone();
        bad[wire.len() / 2] ^= 0x01;
        assert!(decode_reply(&bad).is_err());

        let err_wire = encode_reply(&Reply::Err(ServeError {
            kind: ErrKind::QueueFull,
            message: "admission queue full (2 active, 8 queued)".into(),
        }));
        match decode_reply(&err_wire).unwrap() {
            Reply::Err(e) => {
                assert_eq!(e.kind, ErrKind::QueueFull);
                assert_eq!(e.kind.name(), "queue_full");
                assert!(e.message.contains("queue full"));
            }
            other => panic!("wrong reply: {other:?}"),
        }

        let counters = CacheCounters {
            mem_hits: 3,
            disk_hits: 1,
            misses: 4,
            stores: 5,
            fp_digest_shards: 12,
            fp_stat_revalidations: 6,
            shard_hits: 9,
            shard_misses: 2,
            shard_stores: 7,
        };
        let stats_wire = encode_reply(&Reply::Stats(StatsReply {
            active: 1,
            queued: 2,
            worker_pids: vec![101, 202],
            cache: Some(counters),
        }));
        match decode_reply(&stats_wire).unwrap() {
            Reply::Stats(s) => {
                assert_eq!((s.active, s.queued), (1, 2));
                assert_eq!(s.worker_pids, vec![101, 202]);
                assert_eq!(s.cache, Some(counters), "counters cross as numbers, not text");
            }
            other => panic!("wrong reply: {other:?}"),
        }

        // Content-addressed bytes cross verbatim (the fetch-artifact
        // exchange); truncating the declared length is caught by the
        // cell-level bound, corruption by the envelope digest.
        let blob: Vec<u8> = (0..=255u8).collect();
        let bytes_wire = encode_reply(&Reply::Bytes(blob.clone()));
        match decode_reply(&bytes_wire).unwrap() {
            Reply::Bytes(b) => assert_eq!(b, blob),
            other => panic!("wrong reply: {other:?}"),
        }
        let mut bad_bytes = bytes_wire.clone();
        bad_bytes[bytes_wire.len() / 2] ^= 0x04;
        assert!(decode_reply(&bad_bytes).is_err());

        // Cache-less daemon: the counters are absent, not zeroed.
        let bare_wire = encode_reply(&Reply::Stats(StatsReply {
            active: 0,
            queued: 0,
            worker_pids: vec![],
            cache: None,
        }));
        match decode_reply(&bare_wire).unwrap() {
            Reply::Stats(s) => assert_eq!(s.cache, None),
            other => panic!("wrong reply: {other:?}"),
        }
    }
}
