//! # p3sapp — Preprocessing Pipeline for Scholarly Applications
//!
//! A reproduction of *"A Spark ML-driven preprocessing approach for deep
//! learning-based scholarly data applications"* (Khan, Liu & Alam, 2019)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the coordination layer: a from-scratch
//!   Spark-like engine (`frame`, `pipeline`, `engine`, `ingest`) topped
//!   by a Catalyst/Tungsten-style plan layer (`plan`: lazy logical
//!   plans with sample/limit/multi-distinct ops, an optimizer that
//!   fuses adjacent string stages, a single-pass physical executor, a
//!   streaming executor that overlaps shard parsing with cleaning, a
//!   multi-process sharded executor that ships the op program to
//!   worker OS processes over a versioned wire format, and
//!   a two-pass strategy that lowers estimator stages like `IDF` into
//!   the plan), a persistent plan cache
//!   (`cache`: fingerprinted, content-addressed artifacts so repeated
//!   jobs restore their frame instead of re-executing), the
//!   conventional sequential baseline (`baseline`), the PJRT runtime
//!   that drives the
//!   AOT-compiled seq2seq model (`runtime`), and the analysis/reporting
//!   layer regenerating every table and figure of the paper
//!   (`analysis`, `report`).
//! - **L2** — `python/compile/model.py`: the JAX seq2seq model (3-layer
//!   stacked LSTM encoder, Bahdanau-attention decoder), AOT-lowered to
//!   HLO text artifacts at build time.
//! - **L1** — `python/compile/kernels/`: Pallas kernels for the fused
//!   LSTM cell and Bahdanau attention.
//!
//! Python never runs at request time: `make artifacts` produces
//! `artifacts/*.hlo.txt` once; the `repro` binary is self-contained.
//!
//! A guided tour of the plan layer — logical → optimized → physical →
//! streaming, with a rendered EXPLAIN sample — lives in
//! `docs/ARCHITECTURE.md` at the repository root; `README.md` covers
//! the CLI, benches and report suite.
//!
//! ## Quickstart
//!
//! The preferred path is the plan API: describe the whole job lazily,
//! let the optimizer fuse it, execute it in one parallel pass.
//!
//! ```no_run
//! use p3sapp::corpus::{CorpusSpec, generate_corpus};
//! use p3sapp::ingest::list_shards;
//! use p3sapp::pipeline::presets;
//!
//! let spec = CorpusSpec::tiny(42);
//! let dir = std::path::Path::new("/tmp/corpus");
//! generate_corpus(&spec, dir).unwrap();
//! let files = list_shards(dir).unwrap();
//!
//! let plan = presets::case_study_plan(&files, "title", "abstract").optimize();
//! println!("{}", p3sapp::plan::explain(&plan, 4).unwrap()); // what fused
//! let out = plan.execute(4).unwrap();
//! println!("{} clean rows ({} dups dropped)", out.rows_out, out.dups_dropped);
//!
//! // Or stream it: shard parsing overlaps cleaning, same output bytes.
//! let streamed = plan
//!     .execute_stream(&p3sapp::plan::StreamOptions::default())
//!     .unwrap();
//! assert_eq!(streamed.rows_out, out.rows_out);
//! ```
//!
//! The eager pipeline API remains for frames you already hold:
//!
//! ```no_run
//! use p3sapp::ingest::spark::ingest_dir;
//! use p3sapp::pipeline::presets;
//!
//! let dir = std::path::Path::new("/tmp/corpus");
//! let frame = ingest_dir(dir, &["title", "abstract"], 4).unwrap();
//! let model = presets::abstract_pipeline("abstract").fit(&frame).unwrap();
//! let clean = model.transform(frame, 4).unwrap();
//! println!("{} clean rows", clean.num_rows());
//! ```

pub mod analysis;
pub mod baseline;
pub mod benchkit;
pub mod cache;
pub mod cli;
pub mod config;
pub mod corpus;
pub mod driver;
pub mod engine;
pub mod frame;
pub mod ingest;
pub mod json;
pub mod metrics;
pub mod obs;
pub mod pipeline;
pub mod plan;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod textutil;
pub mod vocab;

/// Crate-wide result alias (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
