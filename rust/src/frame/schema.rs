//! Frame schema: ordered, named, typed fields.

use super::value::DType;

/// One named, typed column slot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Field {
    pub name: String,
    pub dtype: DType,
}

impl Field {
    pub fn new(name: impl Into<String>, dtype: DType) -> Self {
        Field { name: name.into(), dtype }
    }
}

/// Ordered collection of fields shared by every partition of a frame.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Schema {
    fields: Vec<Field>,
}

impl Schema {
    pub fn new(fields: Vec<Field>) -> Self {
        Schema { fields }
    }

    /// Convenience: all-string schema from column names (the shape every
    /// ingestion projection produces).
    pub fn strings(names: &[&str]) -> Self {
        Schema { fields: names.iter().map(|n| Field::new(*n, DType::Str)).collect() }
    }

    pub fn fields(&self) -> &[Field] {
        &self.fields
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    pub fn field_names(&self) -> Vec<&str> {
        self.fields.iter().map(|f| f.name.as_str()).collect()
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.fields.iter().position(|f| f.name == name)
    }

    pub fn dtype_of(&self, name: &str) -> Option<DType> {
        self.index_of(name).map(|i| self.fields[i].dtype)
    }

    /// New schema with one field's dtype replaced (stages like Tokenizer
    /// change `string` → `array<string>`).
    pub fn with_dtype(&self, name: &str, dtype: DType) -> Option<Schema> {
        let idx = self.index_of(name)?;
        let mut fields = self.fields.clone();
        fields[idx].dtype = dtype;
        Some(Schema { fields })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_builder_and_lookup() {
        let s = Schema::strings(&["title", "abstract"]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.index_of("abstract"), Some(1));
        assert_eq!(s.index_of("doi"), None);
        assert_eq!(s.dtype_of("title"), Some(DType::Str));
    }

    #[test]
    fn with_dtype_replaces_one_field() {
        let s = Schema::strings(&["title", "abstract"]);
        let s2 = s.with_dtype("abstract", DType::Tokens).unwrap();
        assert_eq!(s2.dtype_of("abstract"), Some(DType::Tokens));
        assert_eq!(s2.dtype_of("title"), Some(DType::Str));
        assert!(s.with_dtype("nope", DType::Tokens).is_none());
    }
}
