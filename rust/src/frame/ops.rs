//! Relational ops over distributed [`Frame`]s: null-drop and distinct
//! (Algorithm 1 steps 9–10). Both preserve row order (first occurrence
//! wins for distinct) so CA and P3SAPP outputs stay row-comparable for
//! the accuracy analysis (Tables 5–6).
//!
//! Both ops exist in a sequential form and a `_par` form that runs the
//! per-partition phase (null masks / key hashing) on the
//! [`Executor`] worker pool; the ordered merge that decides which
//! duplicate survives is inherently sequential and stays on the caller's
//! thread in both forms.

use super::{Column, Frame, Partition, Value};
use crate::engine::Executor;
use crate::Result;
use std::collections::HashMap;

/// Compute the keep-mask for rows with no null in any of the `idxs`
/// columns. Returns (mask, dropped count). Shared by the sequential and
/// parallel null-drops here and by the plan executor's fused pass.
pub(crate) fn null_mask(p: &Partition, idxs: &[usize]) -> (Vec<bool>, usize) {
    let n = p.num_rows();
    let mut mask = vec![true; n];
    let mut dropped = 0usize;
    for (i, m) in mask.iter_mut().enumerate() {
        if idxs.iter().any(|&ci| p.column(ci).is_null(i)) {
            *m = false;
            dropped += 1;
        }
    }
    (mask, dropped)
}

fn null_filter_partition(p: Partition, idxs: &[usize]) -> (Partition, usize) {
    let (mask, dropped) = null_mask(&p, idxs);
    if dropped > 0 {
        (p.filter_by_mask(&mask), dropped)
    } else {
        (p, 0)
    }
}

/// Drop rows with a null in any of the named columns.
/// Returns (filtered frame, rows dropped).
pub fn drop_nulls(frame: Frame, cols: &[&str]) -> Result<(Frame, usize)> {
    let idxs: Vec<usize> = cols.iter().map(|c| frame.column_index(c)).collect::<Result<_>>()?;
    let (schema, partitions) = frame.into_partitions();
    let mut dropped = 0usize;
    let mut out = Vec::with_capacity(partitions.len());
    for p in partitions {
        let (p, local_drop) = null_filter_partition(p, &idxs);
        dropped += local_drop;
        out.push(p);
    }
    Ok((Frame::from_partitions(schema, out)?, dropped))
}

/// [`drop_nulls`] with the per-partition masks computed on `workers`
/// threads (0 = all cores). Output and drop count are identical to the
/// sequential form — partitions are independent and order is preserved.
pub fn drop_nulls_par(frame: Frame, cols: &[&str], workers: usize) -> Result<(Frame, usize)> {
    let idxs: Vec<usize> = cols.iter().map(|c| frame.column_index(c)).collect::<Result<_>>()?;
    let (schema, partitions) = frame.into_partitions();
    let exec = Executor::new(workers);
    let results = exec.map_items(partitions, |p| null_filter_partition(p, &idxs));
    let mut dropped = 0usize;
    let mut out = Vec::with_capacity(results.len());
    for (p, local_drop) in results {
        dropped += local_drop;
        out.push(p);
    }
    Ok((Frame::from_partitions(schema, out)?, dropped))
}

/// Drop duplicate rows keyed on the named columns, keeping the first
/// occurrence in partition order. Two-phase: per-partition key hashing
/// (parallelizable — see [`distinct_par`]), then a global ordered merge —
/// the same shuffle-free shortcut Spark takes for `dropDuplicates` on a
/// single stage when the data is already collected to the driver's
/// partition list.
///
/// Hash equality alone never drops a row: on a 64-bit collision the
/// actual key values are compared, so two distinct rows that happen to
/// share a hash are both retained.
pub fn distinct(frame: Frame, cols: &[&str]) -> Result<(Frame, usize)> {
    distinct_impl(frame, cols, None, &hash_row)
}

/// [`distinct`] with the key-hashing phase run on `workers` threads
/// (0 = all cores). Output and drop count are identical to the
/// sequential form — the ordered merge is the same.
pub fn distinct_par(frame: Frame, cols: &[&str], workers: usize) -> Result<(Frame, usize)> {
    let exec = Executor::new(workers);
    distinct_impl(frame, cols, Some(&exec), &hash_row)
}

fn distinct_impl(
    frame: Frame,
    cols: &[&str],
    exec: Option<&Executor>,
    hash: &(dyn Fn(&Partition, &[usize], usize) -> u64 + Sync),
) -> Result<(Frame, usize)> {
    let idxs: Vec<usize> = cols.iter().map(|c| frame.column_index(c)).collect::<Result<_>>()?;
    let (schema, partitions) = frame.into_partitions();

    // Phase 1: per-partition key hashing (embarrassingly parallel).
    let hash_partition =
        |p: &Partition| -> Vec<u64> { (0..p.num_rows()).map(|i| hash(p, &idxs, i)).collect() };
    let hashes: Vec<Vec<u64>> = match exec {
        Some(e) => e.map_items(partitions.iter().collect(), |p: &Partition| hash_partition(p)),
        None => partitions.iter().map(hash_partition).collect(),
    };

    // Phase 2: ordered merge. `seen` maps each hash to the rows that
    // claimed it; a row is a duplicate only if it *equals* one of them,
    // so hash collisions between unequal rows keep both. The first
    // occupant is stored inline — the overflow `Vec` (empty `Vec`s
    // don't allocate) is touched only on a genuine 64-bit collision, so
    // the per-ingested-row cost stays one hash-map probe, as before the
    // collision fix.
    type RowRef = (usize, usize);
    let mut seen: HashMap<u64, (RowRef, Vec<RowRef>)> = HashMap::new();
    let mut masks: Vec<(Vec<bool>, usize)> = Vec::with_capacity(partitions.len());
    let mut dropped = 0usize;
    for pi in 0..partitions.len() {
        let n = partitions[pi].num_rows();
        let mut mask = vec![true; n];
        let mut local_drop = 0usize;
        for i in 0..n {
            match seen.entry(hashes[pi][i]) {
                std::collections::hash_map::Entry::Vacant(v) => {
                    v.insert(((pi, i), Vec::new()));
                }
                std::collections::hash_map::Entry::Occupied(mut e) => {
                    let (first, overflow) = e.get_mut();
                    let equals = |&(qp, qr): &RowRef| {
                        rows_equal(&partitions[qp], qr, &partitions[pi], i, &idxs)
                    };
                    if equals(first) || overflow.iter().any(equals) {
                        mask[i] = false;
                        local_drop += 1;
                    } else {
                        overflow.push((pi, i));
                    }
                }
            }
        }
        dropped += local_drop;
        masks.push((mask, local_drop));
    }

    let out: Vec<Partition> = partitions
        .into_iter()
        .zip(masks)
        .map(|(p, (mask, local_drop))| if local_drop > 0 { p.filter_by_mask(&mask) } else { p })
        .collect();
    Ok((Frame::from_partitions(schema, out)?, dropped))
}

/// Key equality over the selected columns, straight off the column
/// storage. Float cells compare by bit pattern — consistent with the
/// hash encoding (NaN == NaN, 0.0 != -0.0).
fn rows_equal(a: &Partition, ra: usize, b: &Partition, rb: usize, idxs: &[usize]) -> bool {
    idxs.iter().all(|&ci| match (a.column(ci), b.column(ci)) {
        (Column::Str(x), Column::Str(y)) => x[ra] == y[rb],
        (Column::Tokens(x), Column::Tokens(y)) => x[ra] == y[rb],
        (Column::Vecs(x), Column::Vecs(y)) => match (&x[ra], &y[rb]) {
            (None, None) => true,
            (Some(p), Some(q)) => {
                p.len() == q.len()
                    && p.iter().zip(q.iter()).all(|(u, v)| u.to_bits() == v.to_bits())
            }
            _ => false,
        },
        _ => false,
    })
}

/// Zero-copy row hash over selected columns (same encoding as
/// [`hash_key`], asserted equal by a unit test).
fn hash_row(p: &super::Partition, idxs: &[usize], row: usize) -> u64 {
    hash_row_from(p, idxs, row, FNV_BASIS)
}

/// 128-bit row key: two independently-seeded FNV-1a streams over the
/// same encoding. Used by the plan executor's single-pass dedup, where
/// the raw values are gone (rewritten in place by the fused cleaning
/// sweep) by the time the driver merges keys — so collisions cannot be
/// verified against the rows and the key width carries the correctness
/// burden instead (collision odds ~2⁻¹²⁸ · n²).
pub fn hash_row_wide(p: &super::Partition, idxs: &[usize], row: usize) -> u128 {
    let h1 = hash_row_from(p, idxs, row, FNV_BASIS);
    let h2 = hash_row_from(p, idxs, row, FNV_BASIS ^ 0x9e37_79b9_7f4a_7c15);
    ((h1 as u128) << 64) | (h2 as u128)
}

/// 128-bit key over nullable string cells, byte-identical to
/// [`hash_row_wide`] on `Str` columns (pinned by a test below). The
/// plan executor's raw ingest path hashes borrowed `Cow` cells with
/// this *before* materializing owned columns, and the driver-side merge
/// mixes keys from both sources — so the encodings must never diverge.
pub fn hash_cells_wide<'a, I>(cells: I) -> u128
where
    I: IntoIterator<Item = Option<&'a str>>,
{
    let mut h1 = Fnv(FNV_BASIS);
    let mut h2 = Fnv(FNV_BASIS ^ 0x9e37_79b9_7f4a_7c15);
    for cell in cells {
        match cell {
            None => {
                h1.feed(&[0xFF, 0x00]);
                h2.feed(&[0xFF, 0x00]);
            }
            Some(s) => {
                h1.feed(&[0x01]);
                h1.feed(s.as_bytes());
                h1.feed(&[0x00]);
                h2.feed(&[0x01]);
                h2.feed(s.as_bytes());
                h2.feed(&[0x00]);
            }
        }
    }
    ((h1.0 as u128) << 64) | (h2.0 as u128)
}

const FNV_BASIS: u64 = 0xcbf29ce484222325;

fn hash_row_from(p: &super::Partition, idxs: &[usize], row: usize, basis: u64) -> u64 {
    let mut h = Fnv(basis);
    for &ci in idxs {
        match p.column(ci) {
            super::Column::Str(v) => match &v[row] {
                None => h.feed(&[0xFF, 0x00]),
                Some(s) => {
                    h.feed(&[0x01]);
                    h.feed(s.as_bytes());
                    h.feed(&[0x00]);
                }
            },
            super::Column::Tokens(v) => match &v[row] {
                None => h.feed(&[0xFF, 0x00]),
                Some(ts) => {
                    h.feed(&[0x02]);
                    for t in ts {
                        h.feed(t.as_bytes());
                        h.feed(&[0x1F]);
                    }
                    h.feed(&[0x00]);
                }
            },
            super::Column::Vecs(v) => match &v[row] {
                None => h.feed(&[0xFF, 0x00]),
                Some(fs) => {
                    h.feed(&[0x03]);
                    for f in fs {
                        h.feed(&f.to_bits().to_le_bytes());
                    }
                    h.feed(&[0x00]);
                }
            },
        }
    }
    h.0
}

/// FNV-1a accumulator (seedable basis) shared by the row and key hashers.
struct Fnv(u64);

impl Fnv {
    #[inline]
    fn feed(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
}

/// Stable 64-bit key hash (FNV-1a over a canonical encoding), matching
/// the internal `hash_row`'s encoding byte for byte. Callers that dedup on this
/// hash alone must tolerate collisions; [`distinct`] verifies colliding
/// rows against the real key values instead.
pub fn hash_key(key: &[Value]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut feed = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    for v in key {
        match v {
            Value::Null => feed(&[0xFF, 0x00]),
            Value::Str(s) => {
                feed(&[0x01]);
                feed(s.as_bytes());
                feed(&[0x00]);
            }
            Value::Tokens(ts) => {
                feed(&[0x02]);
                for t in ts {
                    feed(t.as_bytes());
                    feed(&[0x1F]);
                }
                feed(&[0x00]);
            }
            Value::Vector(fs) => {
                feed(&[0x03]);
                for f in fs {
                    feed(&f.to_bits().to_le_bytes());
                }
                feed(&[0x00]);
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frame::{Column, Partition, Schema};

    fn frame(parts: Vec<Vec<(Option<&str>, Option<&str>)>>) -> Frame {
        let schema = Schema::strings(&["title", "abstract"]);
        let partitions = parts
            .into_iter()
            .map(|rows| {
                Partition::new(vec![
                    Column::from_strs(rows.iter().map(|r| r.0.map(String::from)).collect()),
                    Column::from_strs(rows.iter().map(|r| r.1.map(String::from)).collect()),
                ])
            })
            .collect();
        Frame::from_partitions(schema, partitions).unwrap()
    }

    #[test]
    fn drop_nulls_across_partitions() {
        let f = frame(vec![
            vec![(Some("t1"), None), (Some("t2"), Some("a2"))],
            vec![(None, Some("a3"))],
        ]);
        let (f, dropped) = drop_nulls(f, &["title", "abstract"]).unwrap();
        assert_eq!(dropped, 2);
        assert_eq!(f.num_rows(), 1);
    }

    #[test]
    fn distinct_across_partition_boundary() {
        let f = frame(vec![
            vec![(Some("t1"), Some("a1")), (Some("t2"), Some("a2"))],
            vec![(Some("t1"), Some("a1")), (Some("t3"), Some("a3"))],
        ]);
        let (f, dropped) = distinct(f, &["title", "abstract"]).unwrap();
        assert_eq!(dropped, 1);
        assert_eq!(f.num_rows(), 3);
        let local = f.collect();
        assert_eq!(local.column(0).get_str(0), Some("t1")); // first kept
    }

    #[test]
    fn distinct_on_key_subset() {
        let f = frame(vec![vec![(Some("t1"), Some("a1")), (Some("t1"), Some("different"))]]);
        let (f, dropped) = distinct(f, &["title"]).unwrap();
        assert_eq!(dropped, 1);
        assert_eq!(f.num_rows(), 1);
    }

    #[test]
    fn hash_key_distinguishes_null_from_empty() {
        assert_ne!(
            hash_key(&[Value::Null]),
            hash_key(&[Value::Str(String::new())])
        );
        assert_ne!(
            hash_key(&[Value::Str("ab".into()), Value::Str("c".into())]),
            hash_key(&[Value::Str("a".into()), Value::Str("bc".into())])
        );
    }

    #[test]
    fn nulls_are_equal_for_dedup() {
        let f = frame(vec![vec![(None, Some("a1")), (None, Some("a1"))]]);
        let (f, dropped) = distinct(f, &["title", "abstract"]).unwrap();
        assert_eq!(dropped, 1);
        assert_eq!(f.num_rows(), 1);
    }
    #[test]
    fn hash_cells_wide_matches_hash_row_wide() {
        // The raw (borrowed-cell) ingest path and the materialized path
        // must emit identical dedup keys, or the merge would treat the
        // same row as two distinct ones depending on the executor.
        let f = frame(vec![vec![
            (Some("t1"), Some("a1")),
            (None, Some("a2")),
            (Some(""), None),
            (None, None),
        ]]);
        let p = &f.partitions()[0];
        for i in 0..4 {
            let cells = [0usize, 1].map(|ci| match p.column(ci) {
                Column::Str(v) => v[i].as_deref(),
                _ => unreachable!(),
            });
            assert_eq!(hash_cells_wide(cells), hash_row_wide(p, &[0, 1], i), "row {i}");
            // Column order is part of the key.
            let rev = [1usize, 0].map(|ci| match p.column(ci) {
                Column::Str(v) => v[i].as_deref(),
                _ => unreachable!(),
            });
            assert_eq!(hash_cells_wide(rev), hash_row_wide(p, &[1, 0], i), "row {i} rev");
        }
    }

    #[test]
    fn hash_row_matches_hash_key() {
        let f = frame(vec![vec![(Some("t1"), None), (None, Some("a2"))]]);
        let p = &f.partitions()[0];
        for i in 0..2 {
            let key: Vec<Value> = vec![p.column(0).get(i), p.column(1).get(i)];
            assert_eq!(hash_row(p, &[0, 1], i), hash_key(&key));
        }
    }

    #[test]
    fn hash_collision_does_not_drop_distinct_rows() {
        // Regression for the hash-only dedup bug: force every row into
        // one hash bucket with a constant hasher — distinct rows must
        // all survive, true duplicates must still be dropped, first
        // occurrence must still win.
        let f = frame(vec![
            vec![(Some("t1"), Some("a1")), (Some("t2"), Some("a2"))],
            vec![(Some("t1"), Some("a1")), (Some("t3"), Some("a3"))],
        ]);
        let constant = |_: &Partition, _: &[usize], _: usize| 42u64;
        let (f, dropped) = distinct_impl(f, &["title", "abstract"], None, &constant).unwrap();
        assert_eq!(dropped, 1, "only the true duplicate is dropped");
        assert_eq!(f.num_rows(), 3);
        let local = f.collect();
        let titles: Vec<_> = (0..3).map(|i| local.column(0).get_str(i).unwrap()).collect();
        assert_eq!(titles, vec!["t1", "t2", "t3"]);
    }

    #[test]
    fn wide_hash_distinguishes_rows_sharing_one_half() {
        let f = frame(vec![vec![(Some("ab"), Some("c")), (Some("a"), Some("bc"))]]);
        let p = &f.partitions()[0];
        assert_ne!(hash_row_wide(p, &[0, 1], 0), hash_row_wide(p, &[0, 1], 1));
        // Equal rows hash equal.
        let g = frame(vec![vec![(Some("x"), Some("y")), (Some("x"), Some("y"))]]);
        let q = &g.partitions()[0];
        assert_eq!(hash_row_wide(q, &[0, 1], 0), hash_row_wide(q, &[0, 1], 1));
    }

    fn skewed_frame(seed: u64) -> Frame {
        // Multi-partition frame with nulls and duplicates sprinkled in.
        let mut rows: Vec<(Option<String>, Option<String>)> = Vec::new();
        let mut x = seed;
        for i in 0..400u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let t = match x % 7 {
                0 => None,
                1 => Some("dup-title".to_string()),
                _ => Some(format!("t{}", i % 90)),
            };
            let a = match (x >> 8) % 5 {
                0 => None,
                1 => Some("dup-abstract".to_string()),
                _ => Some(format!("a{}", i % 70)),
            };
            rows.push((t, a));
        }
        let schema = Schema::strings(&["title", "abstract"]);
        let partitions: Vec<Partition> = rows
            .chunks(37)
            .map(|c| {
                Partition::new(vec![
                    Column::from_strs(c.iter().map(|r| r.0.clone()).collect()),
                    Column::from_strs(c.iter().map(|r| r.1.clone()).collect()),
                ])
            })
            .collect();
        Frame::from_partitions(schema, partitions).unwrap()
    }

    #[test]
    fn parallel_drop_nulls_matches_sequential() {
        for workers in [1, 2, 4] {
            let seq = drop_nulls(skewed_frame(11), &["title", "abstract"]).unwrap();
            let par = drop_nulls_par(skewed_frame(11), &["title", "abstract"], workers).unwrap();
            assert_eq!(seq.1, par.1, "drop counts at workers={workers}");
            assert_eq!(seq.0.collect(), par.0.collect(), "rows at workers={workers}");
        }
    }

    #[test]
    fn parallel_distinct_matches_sequential() {
        for workers in [1, 2, 4] {
            let seq = distinct(skewed_frame(29), &["title", "abstract"]).unwrap();
            let par = distinct_par(skewed_frame(29), &["title", "abstract"], workers).unwrap();
            assert_eq!(seq.1, par.1, "drop counts at workers={workers}");
            assert_eq!(seq.0.collect(), par.0.collect(), "rows at workers={workers}");
        }
    }
}
