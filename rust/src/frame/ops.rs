//! Relational ops over distributed [`Frame`]s: null-drop and distinct
//! (Algorithm 1 steps 9–10). Both preserve row order (first occurrence
//! wins for distinct) so CA and P3SAPP outputs stay row-comparable for
//! the accuracy analysis (Tables 5–6).

use super::{Frame, Value};
use crate::Result;
use std::collections::HashSet;

/// Drop rows with a null in any of the named columns.
/// Returns (filtered frame, rows dropped).
pub fn drop_nulls(frame: Frame, cols: &[&str]) -> Result<(Frame, usize)> {
    let idxs: Vec<usize> = cols.iter().map(|c| frame.column_index(c)).collect::<Result<_>>()?;
    let (schema, partitions) = frame.into_partitions();
    let mut dropped = 0usize;
    let mut out = Vec::with_capacity(partitions.len());
    for p in partitions {
        let n = p.num_rows();
        let mut mask = vec![true; n];
        let mut local_drop = 0usize;
        for i in 0..n {
            if idxs.iter().any(|&ci| p.column(ci).is_null(i)) {
                mask[i] = false;
                local_drop += 1;
            }
        }
        dropped += local_drop;
        out.push(if local_drop > 0 { p.filter_by_mask(&mask) } else { p });
    }
    Ok((Frame::from_partitions(schema, out)?, dropped))
}

/// Drop duplicate rows keyed on the named columns, keeping the first
/// occurrence in partition order. Two-phase: per-partition key hashing
/// (parallelizable), then a global ordered merge — the same shuffle-free
/// shortcut Spark takes for `dropDuplicates` on a single stage when the
/// data is already collected to the driver's partition list.
pub fn distinct(frame: Frame, cols: &[&str]) -> Result<(Frame, usize)> {
    let idxs: Vec<usize> = cols.iter().map(|c| frame.column_index(c)).collect::<Result<_>>()?;
    let (schema, partitions) = frame.into_partitions();
    let mut seen: HashSet<u64> = HashSet::new();
    let mut dropped = 0usize;
    let mut out = Vec::with_capacity(partitions.len());
    for p in partitions {
        let n = p.num_rows();
        let mut mask = vec![true; n];
        let mut local_drop = 0usize;
        for i in 0..n {
            // Hash straight off the column storage — no per-row Value
            // boxing/cloning (this loop runs once per ingested row).
            let h = hash_row(&p, &idxs, i);
            if !seen.insert(h) {
                mask[i] = false;
                local_drop += 1;
            }
        }
        dropped += local_drop;
        out.push(if local_drop > 0 { p.filter_by_mask(&mask) } else { p });
    }
    Ok((Frame::from_partitions(schema, out)?, dropped))
}

/// Zero-copy row hash over selected columns (same encoding as
/// [`hash_key`], asserted equal by a unit test).
fn hash_row(p: &super::Partition, idxs: &[usize], row: usize) -> u64 {
    let mut h = Fnv::new();
    for &ci in idxs {
        match p.column(ci) {
            super::Column::Str(v) => match &v[row] {
                None => h.feed(&[0xFF, 0x00]),
                Some(s) => {
                    h.feed(&[0x01]);
                    h.feed(s.as_bytes());
                    h.feed(&[0x00]);
                }
            },
            super::Column::Tokens(v) => match &v[row] {
                None => h.feed(&[0xFF, 0x00]),
                Some(ts) => {
                    h.feed(&[0x02]);
                    for t in ts {
                        h.feed(t.as_bytes());
                        h.feed(&[0x1F]);
                    }
                    h.feed(&[0x00]);
                }
            },
            super::Column::Vecs(v) => match &v[row] {
                None => h.feed(&[0xFF, 0x00]),
                Some(fs) => {
                    h.feed(&[0x03]);
                    for f in fs {
                        h.feed(&f.to_bits().to_le_bytes());
                    }
                    h.feed(&[0x00]);
                }
            },
        }
    }
    h.0
}

/// FNV-1a accumulator shared by the row and key hashers.
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }
    #[inline]
    fn feed(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
}

/// Stable 64-bit key hash (FNV-1a over a canonical encoding). A u64 set
/// is ~10x lighter than storing owned key tuples; collision probability
/// at our scale (<10^7 rows) is negligible and only affects dedup counts,
/// never correctness of the schema.
pub fn hash_key(key: &[Value]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut feed = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    for v in key {
        match v {
            Value::Null => feed(&[0xFF, 0x00]),
            Value::Str(s) => {
                feed(&[0x01]);
                feed(s.as_bytes());
                feed(&[0x00]);
            }
            Value::Tokens(ts) => {
                feed(&[0x02]);
                for t in ts {
                    feed(t.as_bytes());
                    feed(&[0x1F]);
                }
                feed(&[0x00]);
            }
            Value::Vector(fs) => {
                feed(&[0x03]);
                for f in fs {
                    feed(&f.to_bits().to_le_bytes());
                }
                feed(&[0x00]);
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frame::{Column, Partition, Schema};

    fn frame(parts: Vec<Vec<(Option<&str>, Option<&str>)>>) -> Frame {
        let schema = Schema::strings(&["title", "abstract"]);
        let partitions = parts
            .into_iter()
            .map(|rows| {
                Partition::new(vec![
                    Column::from_strs(rows.iter().map(|r| r.0.map(String::from)).collect()),
                    Column::from_strs(rows.iter().map(|r| r.1.map(String::from)).collect()),
                ])
            })
            .collect();
        Frame::from_partitions(schema, partitions).unwrap()
    }

    #[test]
    fn drop_nulls_across_partitions() {
        let f = frame(vec![
            vec![(Some("t1"), None), (Some("t2"), Some("a2"))],
            vec![(None, Some("a3"))],
        ]);
        let (f, dropped) = drop_nulls(f, &["title", "abstract"]).unwrap();
        assert_eq!(dropped, 2);
        assert_eq!(f.num_rows(), 1);
    }

    #[test]
    fn distinct_across_partition_boundary() {
        let f = frame(vec![
            vec![(Some("t1"), Some("a1")), (Some("t2"), Some("a2"))],
            vec![(Some("t1"), Some("a1")), (Some("t3"), Some("a3"))],
        ]);
        let (f, dropped) = distinct(f, &["title", "abstract"]).unwrap();
        assert_eq!(dropped, 1);
        assert_eq!(f.num_rows(), 3);
        let local = f.collect();
        assert_eq!(local.column(0).get_str(0), Some("t1")); // first kept
    }

    #[test]
    fn distinct_on_key_subset() {
        let f = frame(vec![vec![(Some("t1"), Some("a1")), (Some("t1"), Some("different"))]]);
        let (f, dropped) = distinct(f, &["title"]).unwrap();
        assert_eq!(dropped, 1);
        assert_eq!(f.num_rows(), 1);
    }

    #[test]
    fn hash_key_distinguishes_null_from_empty() {
        assert_ne!(
            hash_key(&[Value::Null]),
            hash_key(&[Value::Str(String::new())])
        );
        assert_ne!(
            hash_key(&[Value::Str("ab".into()), Value::Str("c".into())]),
            hash_key(&[Value::Str("a".into()), Value::Str("bc".into())])
        );
    }

    #[test]
    fn nulls_are_equal_for_dedup() {
        let f = frame(vec![vec![(None, Some("a1")), (None, Some("a1"))]]);
        let (f, dropped) = distinct(f, &["title", "abstract"]).unwrap();
        assert_eq!(dropped, 1);
        assert_eq!(f.num_rows(), 1);
    }
    #[test]
    fn hash_row_matches_hash_key() {
        let f = frame(vec![vec![(Some("t1"), None), (None, Some("a2"))]]);
        let p = &f.partitions()[0];
        for i in 0..2 {
            let key: Vec<Value> = vec![p.column(0).get(i), p.column(1).get(i)];
            assert_eq!(hash_row(p, &[0, 1], i), hash_key(&key));
        }
    }
}
