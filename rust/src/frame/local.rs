//! `LocalFrame` — the contiguous, single-buffer frame standing in for a
//! pandas DataFrame.
//!
//! Two growth modes matter for the reproduction:
//!
//! - [`LocalFrame::extend_from_partition`] — amortized `Vec` growth, used
//!   when collecting a distributed [`super::Frame`] (the P3SAPP exit path).
//! - [`LocalFrame::append_copy`] — **full reallocation + copy of the
//!   existing rows plus the new rows**, faithfully reproducing pandas
//!   `DataFrame.append` (never in-place before pandas 2.0, which is what
//!   the paper's CA, Algorithm 2 step 6, calls per file). Summed over
//!   f files this is O(total²/f) — the measured cause of CA's ingestion
//!   curve in Table 2.

use super::column::Column;
use super::partition::Partition;
use super::schema::Schema;
use super::value::{DType, Value};
use crate::Result;

/// Contiguous columnar frame (the "pandas DataFrame" of both algorithms'
/// output contract).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LocalFrame {
    schema: Schema,
    columns: Vec<Column>,
}

impl LocalFrame {
    pub fn empty(schema: Schema) -> Self {
        let columns = schema
            .fields()
            .iter()
            .map(|f| Column::with_capacity(f.dtype, 0))
            .collect();
        LocalFrame { schema, columns }
    }

    pub fn from_columns(schema: Schema, columns: Vec<Column>) -> Result<Self> {
        let p = Partition::new(columns);
        p.check_schema(&schema)?;
        Ok(LocalFrame { schema, columns: p.into_columns() })
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn num_rows(&self) -> usize {
        self.columns.first().map(|c| c.len()).unwrap_or(0)
    }

    pub fn num_columns(&self) -> usize {
        self.columns.len()
    }

    pub fn column(&self, i: usize) -> &Column {
        &self.columns[i]
    }

    pub fn column_by_name(&self, name: &str) -> Option<&Column> {
        self.schema.index_of(name).map(|i| &self.columns[i])
    }

    pub fn column_mut(&mut self, i: usize) -> &mut Column {
        &mut self.columns[i]
    }

    pub fn columns(&self) -> &[Column] {
        &self.columns
    }

    pub fn column_index(&self, name: &str) -> Result<usize> {
        self.schema
            .index_of(name)
            .ok_or_else(|| anyhow::anyhow!("no such column: {name}"))
    }

    /// Amortized append used by `Frame::collect` — plain `Vec::extend`.
    pub fn extend_from_partition(&mut self, partition: Partition) {
        debug_assert_eq!(partition.num_columns(), self.columns.len());
        for (dst, src) in self.columns.iter_mut().zip(partition.into_columns()) {
            match (dst, src) {
                (Column::Str(d), Column::Str(s)) => d.extend(s),
                (Column::Tokens(d), Column::Tokens(s)) => d.extend(s),
                (Column::Vecs(d), Column::Vecs(s)) => d.extend(s),
                _ => panic!("dtype mismatch in extend_from_partition"),
            }
        }
    }

    /// pandas-`append` semantics: allocate a **new** frame sized
    /// rows(self)+rows(other), copy both, replace self. Deliberately not
    /// amortized — this is the conventional approach's per-file ingestion
    /// cost (see module docs).
    pub fn append_copy(&mut self, other: &LocalFrame) -> Result<()> {
        if self.schema != other.schema {
            anyhow::bail!("append_copy: schema mismatch");
        }
        let total = self.num_rows() + other.num_rows();
        let mut new_columns = Vec::with_capacity(self.columns.len());
        for (a, b) in self.columns.iter().zip(&other.columns) {
            // Exact-capacity allocation + element-wise clone of both
            // halves = the realloc-and-copy pandas does on every append.
            let col = match (a, b) {
                (Column::Str(x), Column::Str(y)) => {
                    let mut v = Vec::with_capacity(total);
                    v.extend(x.iter().cloned());
                    v.extend(y.iter().cloned());
                    Column::Str(v)
                }
                (Column::Tokens(x), Column::Tokens(y)) => {
                    let mut v = Vec::with_capacity(total);
                    v.extend(x.iter().cloned());
                    v.extend(y.iter().cloned());
                    Column::Tokens(v)
                }
                _ => anyhow::bail!("append_copy: dtype mismatch"),
            };
            new_columns.push(col);
        }
        self.columns = new_columns;
        Ok(())
    }

    /// Row as generic values (test/debug helper).
    pub fn row(&self, i: usize) -> Vec<Value> {
        self.columns.iter().map(|c| c.get(i)).collect()
    }

    /// Drop rows with a null in any of the named columns (Algorithm 1/2
    /// step 9 and the post-cleaning null sweep).
    pub fn drop_nulls(&mut self, cols: &[&str]) -> Result<usize> {
        let idxs: Vec<usize> = cols
            .iter()
            .map(|c| self.column_index(c))
            .collect::<Result<_>>()?;
        let n = self.num_rows();
        let mut mask = vec![true; n];
        let mut dropped = 0usize;
        for i in 0..n {
            if idxs.iter().any(|&ci| self.columns[ci].is_null(i)) {
                mask[i] = false;
                dropped += 1;
            }
        }
        if dropped > 0 {
            for c in &mut self.columns {
                *c = c.filter_by_mask(&mask);
            }
        }
        Ok(dropped)
    }

    /// Drop duplicate rows, keyed on the named columns, keeping the first
    /// occurrence (Algorithm 1/2 step 10).
    pub fn drop_duplicates(&mut self, cols: &[&str]) -> Result<usize> {
        let idxs: Vec<usize> = cols
            .iter()
            .map(|c| self.column_index(c))
            .collect::<Result<_>>()?;
        let n = self.num_rows();
        let mut seen = std::collections::HashSet::with_capacity(n);
        let mut mask = vec![true; n];
        let mut dropped = 0usize;
        for i in 0..n {
            let key: Vec<Value> = idxs.iter().map(|&ci| self.columns[ci].get(i)).collect();
            if !seen.insert(key) {
                mask[i] = false;
                dropped += 1;
            }
        }
        if dropped > 0 {
            for c in &mut self.columns {
                *c = c.filter_by_mask(&mask);
            }
        }
        Ok(dropped)
    }

    /// Convert into a single-partition distributed frame.
    pub fn into_frame(self) -> super::Frame {
        let schema = self.schema.clone();
        super::Frame::from_partition(schema, Partition::new(self.columns))
            .expect("LocalFrame is schema-consistent by construction")
    }

    /// Make a `DType::Str` pair extractor for (title, abstract)-style
    /// record matching in the accuracy analysis.
    pub fn str_rows(&self, col: &str) -> Result<Vec<Option<&str>>> {
        let i = self.column_index(col)?;
        let c = &self.columns[i];
        if c.dtype() != DType::Str {
            anyhow::bail!("str_rows: column {col} is not a string column");
        }
        Ok((0..c.len()).map(|r| c.get_str(r)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frame::Field;

    fn lf(rows: &[(Option<&str>, Option<&str>)]) -> LocalFrame {
        LocalFrame::from_columns(
            Schema::strings(&["title", "abstract"]),
            vec![
                Column::from_strs(rows.iter().map(|r| r.0.map(String::from)).collect()),
                Column::from_strs(rows.iter().map(|r| r.1.map(String::from)).collect()),
            ],
        )
        .unwrap()
    }

    #[test]
    fn append_copy_concatenates() {
        let mut a = lf(&[(Some("t1"), Some("a1"))]);
        let b = lf(&[(Some("t2"), Some("a2"))]);
        a.append_copy(&b).unwrap();
        assert_eq!(a.num_rows(), 2);
        assert_eq!(a.column(0).get_str(1), Some("t2"));
    }

    #[test]
    fn append_copy_schema_mismatch() {
        let mut a = lf(&[(Some("t"), Some("a"))]);
        let b = LocalFrame::empty(Schema::new(vec![Field::new("doi", DType::Str)]));
        assert!(a.append_copy(&b).is_err());
    }

    #[test]
    fn drop_nulls_any_column() {
        let mut f = lf(&[
            (Some("t1"), Some("a1")),
            (None, Some("a2")),
            (Some("t3"), None),
            (Some("t4"), Some("a4")),
        ]);
        let dropped = f.drop_nulls(&["title", "abstract"]).unwrap();
        assert_eq!(dropped, 2);
        assert_eq!(f.num_rows(), 2);
        assert_eq!(f.column(0).get_str(1), Some("t4"));
    }

    #[test]
    fn drop_duplicates_keeps_first() {
        let mut f = lf(&[
            (Some("t1"), Some("a1")),
            (Some("t1"), Some("a1")),
            (Some("t1"), Some("a2")),
        ]);
        let dropped = f.drop_duplicates(&["title", "abstract"]).unwrap();
        assert_eq!(dropped, 1);
        assert_eq!(f.num_rows(), 2);
    }

    #[test]
    fn drop_duplicates_on_subset_of_columns() {
        let mut f = lf(&[(Some("t1"), Some("a1")), (Some("t1"), Some("a2"))]);
        let dropped = f.drop_duplicates(&["title"]).unwrap();
        assert_eq!(dropped, 1);
        assert_eq!(f.num_rows(), 1);
        assert_eq!(f.column(1).get_str(0), Some("a1"));
    }

    #[test]
    fn into_frame_roundtrip() {
        let f = lf(&[(Some("t1"), Some("a1")), (Some("t2"), Some("a2"))]);
        let frame = f.clone().into_frame();
        assert_eq!(frame.num_partitions(), 1);
        assert_eq!(frame.collect(), f);
    }
}
