//! A partition: the unit of parallel work.

use super::column::Column;
use super::schema::Schema;
use crate::Result;

/// One horizontal slice of a [`super::Frame`]: a set of equal-length
/// columns. Partitions are moved whole between the ingestion workers,
/// the transform executor, and the final collect.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    columns: Vec<Column>,
}

impl Partition {
    pub fn new(columns: Vec<Column>) -> Self {
        if let Some(first) = columns.first() {
            debug_assert!(
                columns.iter().all(|c| c.len() == first.len()),
                "partition columns must have equal length"
            );
        }
        Partition { columns }
    }

    pub fn num_rows(&self) -> usize {
        self.columns.first().map(|c| c.len()).unwrap_or(0)
    }

    pub fn num_columns(&self) -> usize {
        self.columns.len()
    }

    pub fn column(&self, i: usize) -> &Column {
        &self.columns[i]
    }

    pub fn column_mut(&mut self, i: usize) -> &mut Column {
        &mut self.columns[i]
    }

    pub fn columns(&self) -> &[Column] {
        &self.columns
    }

    pub fn into_columns(self) -> Vec<Column> {
        self.columns
    }

    /// Replace column `i` (dtype may change — Tokenizer does this).
    pub fn replace_column(&mut self, i: usize, col: Column) {
        self.columns[i] = col;
    }

    /// Take column `i` out for an owned in-place transform, leaving an
    /// empty placeholder. The partition is row-inconsistent until the
    /// matching [`Partition::replace_column`] call — callers must pair
    /// the two without touching other accessors in between.
    pub fn take_column(&mut self, i: usize) -> Column {
        let dtype = self.columns[i].dtype();
        std::mem::replace(&mut self.columns[i], Column::with_capacity(dtype, 0))
    }

    /// Verify this partition's column count and dtypes match `schema`.
    pub fn check_schema(&self, schema: &Schema) -> Result<()> {
        if self.columns.len() != schema.len() {
            anyhow::bail!(
                "partition has {} columns, schema expects {}",
                self.columns.len(),
                schema.len()
            );
        }
        for (col, field) in self.columns.iter().zip(schema.fields()) {
            if col.dtype() != field.dtype {
                anyhow::bail!(
                    "column '{}' has dtype {}, schema expects {}",
                    field.name,
                    col.dtype(),
                    field.dtype
                );
            }
        }
        Ok(())
    }

    /// Split into up to `pieces` row-contiguous partitions of roughly
    /// equal size, preserving row order. Used by the plan executor to
    /// keep every worker busy when there are fewer shard files than
    /// threads. Returns fewer pieces when there aren't enough rows.
    pub fn split_rows(mut self, pieces: usize) -> Vec<Partition> {
        let pieces = pieces.max(1);
        let total = self.num_rows();
        let per = total.div_ceil(pieces).max(1);
        let mut out = Vec::with_capacity(pieces);
        while self.num_rows() > per {
            let tail = Partition {
                columns: self.columns.iter_mut().map(|c| c.split_off(per)).collect(),
            };
            out.push(std::mem::replace(&mut self, tail));
        }
        out.push(self);
        out
    }

    /// Truncate to the first `n` rows in place (no-op when `n` is not
    /// smaller than the row count). Used by the plan executor's `Limit`
    /// enforcement — per-partition prefix caps and the driver-side
    /// global budget.
    pub fn truncate_rows(&mut self, n: usize) {
        if n < self.num_rows() {
            for c in &mut self.columns {
                let _ = c.split_off(n);
            }
        }
    }

    /// Keep only rows where `mask[i]` is true.
    pub fn filter_by_mask(&self, mask: &[bool]) -> Partition {
        Partition { columns: self.columns.iter().map(|c| c.filter_by_mask(mask)).collect() }
    }

    /// Approximate payload bytes (for rebalancing decisions).
    pub fn approx_bytes(&self) -> usize {
        self.columns.iter().map(|c| c.approx_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frame::{DType, Field};

    fn p() -> Partition {
        Partition::new(vec![
            Column::from_strs(vec![Some("t1".into()), None]),
            Column::from_strs(vec![Some("a1".into()), Some("a2".into())]),
        ])
    }

    #[test]
    fn row_and_column_counts() {
        let p = p();
        assert_eq!(p.num_rows(), 2);
        assert_eq!(p.num_columns(), 2);
    }

    #[test]
    fn schema_check_rejects_wrong_dtype() {
        let p = p();
        let ok = Schema::strings(&["title", "abstract"]);
        assert!(p.check_schema(&ok).is_ok());
        let bad = Schema::new(vec![
            Field::new("title", DType::Tokens),
            Field::new("abstract", DType::Str),
        ]);
        assert!(p.check_schema(&bad).is_err());
    }

    #[test]
    fn split_rows_preserves_order_and_balance() {
        let big = Partition::new(vec![
            Column::from_strs((0..10).map(|i| Some(format!("t{i}"))).collect()),
            Column::from_strs((0..10).map(|i| Some(format!("a{i}"))).collect()),
        ]);
        let parts = big.split_rows(4);
        assert_eq!(parts.len(), 4);
        assert_eq!(parts.iter().map(Partition::num_rows).sum::<usize>(), 10);
        let mut seen = Vec::new();
        for part in &parts {
            for i in 0..part.num_rows() {
                seen.push(part.column(0).get_str(i).unwrap().to_string());
            }
        }
        let expect: Vec<String> = (0..10).map(|i| format!("t{i}")).collect();
        assert_eq!(seen, expect);
        // Degenerate cases.
        assert_eq!(p().split_rows(1).len(), 1);
        assert_eq!(p().split_rows(100).len(), 2, "capped by row count");
    }

    #[test]
    fn truncate_rows_keeps_the_prefix() {
        let mut part = Partition::new(vec![
            Column::from_strs((0..5).map(|i| Some(format!("t{i}"))).collect()),
            Column::from_strs((0..5).map(|i| Some(format!("a{i}"))).collect()),
        ]);
        part.truncate_rows(2);
        assert_eq!(part.num_rows(), 2);
        assert_eq!(part.column(0).get_str(1), Some("t1"));
        // Not smaller than the row count: no-op.
        part.truncate_rows(10);
        assert_eq!(part.num_rows(), 2);
    }

    #[test]
    fn filter_by_mask_filters_all_columns() {
        let p = p().filter_by_mask(&[false, true]);
        assert_eq!(p.num_rows(), 1);
        assert_eq!(p.column(1).get_str(0), Some("a2"));
        assert!(p.column(0).is_null(0));
    }
}
