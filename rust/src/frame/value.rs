//! Cell values and column dtypes.

use std::fmt;

/// Column data type. The paper's preprocessing stages work on Spark
/// nullable `string` columns and `array<string>` columns (Tokenizer
/// output / StopWordsRemover input), so those are the two dtypes we model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// Nullable UTF-8 string.
    Str,
    /// Nullable list of tokens (Spark `array<string>`).
    Tokens,
    /// Nullable dense feature vector (Spark `Vector`, used by the
    /// TF-IDF feature-extraction stages).
    Vector,
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DType::Str => write!(f, "string"),
            DType::Tokens => write!(f, "array<string>"),
            DType::Vector => write!(f, "vector"),
        }
    }
}

/// A single cell value.
#[derive(Debug, Clone)]
pub enum Value {
    Null,
    Str(String),
    Tokens(Vec<String>),
    Vector(Vec<f32>),
}

// Eq/Hash by f32 bit pattern (NaN == NaN for dedup purposes; -0.0 and
// 0.0 differ — acceptable for key semantics, consistent between the two
// impls as the Hash/Eq contract requires).
impl PartialEq for Value {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Value::Null, Value::Null) => true,
            (Value::Str(a), Value::Str(b)) => a == b,
            (Value::Tokens(a), Value::Tokens(b)) => a == b,
            (Value::Vector(a), Value::Vector(b)) => {
                a.len() == b.len()
                    && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
            }
            _ => false,
        }
    }
}

impl Eq for Value {}

impl std::hash::Hash for Value {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        match self {
            Value::Null => 0u8.hash(state),
            Value::Str(s) => {
                1u8.hash(state);
                s.hash(state);
            }
            Value::Tokens(t) => {
                2u8.hash(state);
                t.hash(state);
            }
            Value::Vector(v) => {
                3u8.hash(state);
                for x in v {
                    x.to_bits().hash(state);
                }
            }
        }
    }
}

impl Value {
    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// dtype of a non-null value; nulls fit any dtype.
    pub fn fits(&self, dtype: DType) -> bool {
        match (self, dtype) {
            (Value::Null, _) => true,
            (Value::Str(_), DType::Str) => true,
            (Value::Tokens(_), DType::Tokens) => true,
            (Value::Vector(_), DType::Vector) => true,
            _ => false,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_tokens(&self) -> Option<&[String]> {
        match self {
            Value::Tokens(t) => Some(t),
            _ => None,
        }
    }

    pub fn as_vector(&self) -> Option<&[f32]> {
        match self {
            Value::Vector(v) => Some(v),
            _ => None,
        }
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}

impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}

impl From<Option<String>> for Value {
    fn from(s: Option<String>) -> Self {
        match s {
            Some(s) => Value::Str(s),
            None => Value::Null,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_fits_any_dtype() {
        assert!(Value::Null.fits(DType::Str));
        assert!(Value::Null.fits(DType::Tokens));
    }

    #[test]
    fn str_only_fits_str() {
        let v = Value::from("x");
        assert!(v.fits(DType::Str));
        assert!(!v.fits(DType::Tokens));
    }

    #[test]
    fn accessors() {
        assert_eq!(Value::from("a").as_str(), Some("a"));
        assert_eq!(Value::Null.as_str(), None);
        let t = Value::Tokens(vec!["a".into(), "b".into()]);
        assert_eq!(t.as_tokens().unwrap().len(), 2);
        assert!(t.as_str().is_none());
    }
}
