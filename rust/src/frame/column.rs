//! Typed, nullable columns.

use super::value::{DType, Value};

/// A single column of one partition. Stored as a dense `Vec` of optional
/// values — the natural layout for string-heavy scholarly data where
/// almost every transformation rewrites the payload anyway.
#[derive(Debug, Clone, PartialEq)]
pub enum Column {
    Str(Vec<Option<String>>),
    Tokens(Vec<Option<Vec<String>>>),
    Vecs(Vec<Option<Vec<f32>>>),
}

impl Column {
    pub fn from_strs(values: Vec<Option<String>>) -> Self {
        Column::Str(values)
    }

    pub fn from_token_lists(values: Vec<Option<Vec<String>>>) -> Self {
        Column::Tokens(values)
    }

    pub fn from_vectors(values: Vec<Option<Vec<f32>>>) -> Self {
        Column::Vecs(values)
    }

    /// Build a column of the given dtype from generic [`Value`]s.
    /// Values that don't fit the dtype become nulls — mirroring Spark's
    /// permissive cast-to-null on malformed records.
    pub fn from_values(values: Vec<Value>, dtype: DType) -> Self {
        match dtype {
            DType::Str => Column::Str(
                values
                    .into_iter()
                    .map(|v| match v {
                        Value::Str(s) => Some(s),
                        _ => None,
                    })
                    .collect(),
            ),
            DType::Tokens => Column::Tokens(
                values
                    .into_iter()
                    .map(|v| match v {
                        Value::Tokens(t) => Some(t),
                        _ => None,
                    })
                    .collect(),
            ),
            DType::Vector => Column::Vecs(
                values
                    .into_iter()
                    .map(|v| match v {
                        Value::Vector(x) => Some(x),
                        _ => None,
                    })
                    .collect(),
            ),
        }
    }

    /// Pre-sized empty column.
    pub fn with_capacity(dtype: DType, cap: usize) -> Self {
        match dtype {
            DType::Str => Column::Str(Vec::with_capacity(cap)),
            DType::Tokens => Column::Tokens(Vec::with_capacity(cap)),
            DType::Vector => Column::Vecs(Vec::with_capacity(cap)),
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Column::Str(_) => DType::Str,
            Column::Tokens(_) => DType::Tokens,
            Column::Vecs(_) => DType::Vector,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Column::Str(v) => v.len(),
            Column::Tokens(v) => v.len(),
            Column::Vecs(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_null(&self, i: usize) -> bool {
        match self {
            Column::Str(v) => v[i].is_none(),
            Column::Tokens(v) => v[i].is_none(),
            Column::Vecs(v) => v[i].is_none(),
        }
    }

    pub fn null_count(&self) -> usize {
        match self {
            Column::Str(v) => v.iter().filter(|x| x.is_none()).count(),
            Column::Tokens(v) => v.iter().filter(|x| x.is_none()).count(),
            Column::Vecs(v) => v.iter().filter(|x| x.is_none()).count(),
        }
    }

    pub fn get_str(&self, i: usize) -> Option<&str> {
        match self {
            Column::Str(v) => v[i].as_deref(),
            _ => None,
        }
    }

    pub fn get_tokens(&self, i: usize) -> Option<&[String]> {
        match self {
            Column::Tokens(v) => v[i].as_deref(),
            _ => None,
        }
    }

    pub fn get_vector(&self, i: usize) -> Option<&[f32]> {
        match self {
            Column::Vecs(v) => v[i].as_deref(),
            _ => None,
        }
    }

    pub fn get(&self, i: usize) -> Value {
        match self {
            Column::Str(v) => v[i].clone().map(Value::Str).unwrap_or(Value::Null),
            Column::Tokens(v) => v[i].clone().map(Value::Tokens).unwrap_or(Value::Null),
            Column::Vecs(v) => v[i].clone().map(Value::Vector).unwrap_or(Value::Null),
        }
    }

    /// Consume into generic values (used by repartitioning).
    pub fn into_values(self) -> Box<dyn Iterator<Item = Value>> {
        match self {
            Column::Str(v) => Box::new(
                v.into_iter().map(|x| x.map(Value::Str).unwrap_or(Value::Null)),
            ),
            Column::Tokens(v) => Box::new(
                v.into_iter().map(|x| x.map(Value::Tokens).unwrap_or(Value::Null)),
            ),
            Column::Vecs(v) => Box::new(
                v.into_iter().map(|x| x.map(Value::Vector).unwrap_or(Value::Null)),
            ),
        }
    }

    /// Borrow the raw string vector (panics on dtype mismatch) — the
    /// zero-copy path the transform stages use.
    pub fn strs(&self) -> &[Option<String>] {
        match self {
            Column::Str(v) => v,
            _ => panic!("column is not a string column"),
        }
    }

    pub fn strs_mut(&mut self) -> &mut Vec<Option<String>> {
        match self {
            Column::Str(v) => v,
            _ => panic!("column is not a string column"),
        }
    }

    pub fn token_lists(&self) -> &[Option<Vec<String>>] {
        match self {
            Column::Tokens(v) => v,
            _ => panic!("column is not a token column"),
        }
    }

    pub fn vectors(&self) -> &[Option<Vec<f32>>] {
        match self {
            Column::Vecs(v) => v,
            _ => panic!("column is not a vector column"),
        }
    }

    /// Empty-string cells become nulls — the pandas `.replace('', NaN)`
    /// analog shared by the CA driver, the plan executor's post-cleaning
    /// sweep, and their reference implementations in tests/benches.
    /// No-op on non-string columns.
    pub fn nullify_empty_strs(&mut self) {
        if let Column::Str(v) = self {
            for cell in v.iter_mut() {
                if cell.as_deref() == Some("") {
                    *cell = None;
                }
            }
        }
    }

    /// Split off and return the rows at `at..`, leaving `..at` in place
    /// (per-column counterpart of `Vec::split_off`; used to re-chunk a
    /// partition for the executor when shard files are scarce).
    pub fn split_off(&mut self, at: usize) -> Column {
        match self {
            Column::Str(v) => Column::Str(v.split_off(at)),
            Column::Tokens(v) => Column::Tokens(v.split_off(at)),
            Column::Vecs(v) => Column::Vecs(v.split_off(at)),
        }
    }

    /// Retain rows whose index passes `keep`. Used by null-drop and
    /// distinct; preserves order.
    pub fn filter_by_mask(&self, mask: &[bool]) -> Column {
        debug_assert_eq!(mask.len(), self.len());
        match self {
            Column::Str(v) => Column::Str(
                v.iter()
                    .zip(mask)
                    .filter(|(_, &m)| m)
                    .map(|(x, _)| x.clone())
                    .collect(),
            ),
            Column::Tokens(v) => Column::Tokens(
                v.iter()
                    .zip(mask)
                    .filter(|(_, &m)| m)
                    .map(|(x, _)| x.clone())
                    .collect(),
            ),
            Column::Vecs(v) => Column::Vecs(
                v.iter()
                    .zip(mask)
                    .filter(|(_, &m)| m)
                    .map(|(x, _)| x.clone())
                    .collect(),
            ),
        }
    }

    /// Approximate payload size in bytes (used for partition rebalancing
    /// and the copy-on-append cost model).
    pub fn approx_bytes(&self) -> usize {
        match self {
            Column::Str(v) => v
                .iter()
                .map(|x| x.as_ref().map(|s| s.len()).unwrap_or(0) + std::mem::size_of::<Option<String>>())
                .sum(),
            Column::Tokens(v) => v
                .iter()
                .map(|x| {
                    x.as_ref()
                        .map(|t| t.iter().map(|s| s.len() + std::mem::size_of::<String>()).sum())
                        .unwrap_or(0)
                        + std::mem::size_of::<Option<Vec<String>>>()
                })
                .sum(),
            Column::Vecs(v) => v
                .iter()
                .map(|x| {
                    x.as_ref().map(|f| f.len() * 4).unwrap_or(0)
                        + std::mem::size_of::<Option<Vec<f32>>>()
                })
                .sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn str_column_basics() {
        let c = Column::from_strs(vec![Some("a".into()), None, Some("b".into())]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.dtype(), DType::Str);
        assert_eq!(c.null_count(), 1);
        assert!(c.is_null(1));
        assert_eq!(c.get_str(0), Some("a"));
        assert_eq!(c.get_str(1), None);
    }

    #[test]
    fn from_values_casts_mismatch_to_null() {
        let vals = vec![Value::from("x"), Value::Tokens(vec!["t".into()]), Value::Null];
        let c = Column::from_values(vals, DType::Str);
        assert_eq!(c.get_str(0), Some("x"));
        assert!(c.is_null(1)); // tokens don't fit a string column
        assert!(c.is_null(2));
    }

    #[test]
    fn filter_by_mask_preserves_order() {
        let c = Column::from_strs(vec![Some("a".into()), Some("b".into()), Some("c".into())]);
        let f = c.filter_by_mask(&[true, false, true]);
        assert_eq!(f.len(), 2);
        assert_eq!(f.get_str(0), Some("a"));
        assert_eq!(f.get_str(1), Some("c"));
    }

    #[test]
    fn token_column_roundtrip() {
        let c = Column::from_token_lists(vec![Some(vec!["a".into(), "b".into()]), None]);
        assert_eq!(c.dtype(), DType::Tokens);
        assert_eq!(c.get_tokens(0).unwrap(), &["a".to_string(), "b".to_string()][..]);
        assert!(c.get_tokens(1).is_none());
        let vals: Vec<Value> = c.clone().into_values().collect();
        let c2 = Column::from_values(vals, DType::Tokens);
        assert_eq!(c, c2);
    }

    #[test]
    fn nullify_empty_strs_nulls_only_empties() {
        let mut c = Column::from_strs(vec![Some("a".into()), Some(String::new()), None]);
        c.nullify_empty_strs();
        assert_eq!(c.get_str(0), Some("a"));
        assert!(c.is_null(1));
        assert!(c.is_null(2));
    }

    #[test]
    fn split_off_keeps_head_returns_tail() {
        let mut c = Column::from_strs(vec![Some("a".into()), Some("b".into()), Some("c".into())]);
        let tail = c.split_off(1);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get_str(0), Some("a"));
        assert_eq!(tail.len(), 2);
        assert_eq!(tail.get_str(0), Some("b"));
    }

    #[test]
    fn approx_bytes_counts_payload() {
        let small = Column::from_strs(vec![Some("a".into())]);
        let big = Column::from_strs(vec![Some("a".repeat(1000))]);
        assert!(big.approx_bytes() > small.approx_bytes() + 900);
    }
}
