//! Columnar, partitioned DataFrame — the `sparklet` analog of a Spark SQL
//! DataFrame, plus a contiguous [`LocalFrame`] standing in for pandas.
//!
//! Two frame flavours model the paper's two worlds:
//!
//! - [`Frame`] — *distributed* flavour: rows live in independent
//!   [`Partition`]s, transformations run per-partition on the worker pool
//!   (`engine`), and `union` is O(1) partition-list concatenation. This is
//!   what gives P3SAPP its near-linear ingestion curve (Table 2).
//! - [`LocalFrame`] — *pandas* flavour: one contiguous buffer per column.
//!   The conventional approach (CA) appends each file's rows with a full
//!   copy (`append_copy`), reproducing pandas `DataFrame.append`
//!   semantics and therefore CA's superlinear ingestion blow-up.
//!
//! Columns are typed ([`DType::Str`] or [`DType::Tokens`]) with explicit
//! nulls, mirroring Spark's nullable string / array<string> columns used
//! by the paper's preprocessing stages.

mod column;
mod local;
mod ops;
mod partition;
mod schema;
mod value;

pub use column::Column;
pub use local::LocalFrame;
pub(crate) use ops::null_mask;
pub use ops::{
    distinct, distinct_par, drop_nulls, drop_nulls_par, hash_cells_wide, hash_key, hash_row_wide,
};
pub use partition::Partition;
pub use schema::{Field, Schema};
pub use value::{DType, Value};

use crate::Result;

/// A partitioned, columnar frame. The unit of parallelism is the
/// [`Partition`]; all partitions share one [`Schema`].
#[derive(Debug, Clone, Default)]
pub struct Frame {
    schema: Schema,
    partitions: Vec<Partition>,
}

impl Frame {
    /// Empty frame with the given schema and no partitions.
    pub fn empty(schema: Schema) -> Self {
        Frame { schema, partitions: Vec::new() }
    }

    /// Build a frame from one pre-assembled partition.
    pub fn from_partition(schema: Schema, partition: Partition) -> Result<Self> {
        partition.check_schema(&schema)?;
        Ok(Frame { schema, partitions: vec![partition] })
    }

    /// Build a frame from many partitions (all must match the schema).
    pub fn from_partitions(schema: Schema, partitions: Vec<Partition>) -> Result<Self> {
        for p in &partitions {
            p.check_schema(&schema)?;
        }
        Ok(Frame { schema, partitions })
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn partitions(&self) -> &[Partition] {
        &self.partitions
    }

    pub fn partitions_mut(&mut self) -> &mut Vec<Partition> {
        &mut self.partitions
    }

    pub fn into_partitions(self) -> (Schema, Vec<Partition>) {
        (self.schema, self.partitions)
    }

    pub fn num_partitions(&self) -> usize {
        self.partitions.len()
    }

    /// Total row count across partitions.
    pub fn num_rows(&self) -> usize {
        self.partitions.iter().map(|p| p.num_rows()).sum()
    }

    /// Union with another frame: O(1) in data — partition lists are
    /// concatenated, nothing is copied. This is the Spark-side ingestion
    /// primitive (Algorithm 1, step 6).
    pub fn union(mut self, mut other: Frame) -> Result<Frame> {
        if self.schema != other.schema {
            anyhow::bail!(
                "union: schema mismatch ({:?} vs {:?})",
                self.schema.field_names(),
                other.schema.field_names()
            );
        }
        self.partitions.append(&mut other.partitions);
        Ok(self)
    }

    /// Append a single partition in place (streaming ingestion path).
    pub fn push_partition(&mut self, partition: Partition) -> Result<()> {
        partition.check_schema(&self.schema)?;
        self.partitions.push(partition);
        Ok(())
    }

    /// Re-split rows into `n` roughly equal partitions. Used by the
    /// engine to rebalance skewed ingestion output (files vary KB→MB)
    /// before the transform stages.
    pub fn repartition(self, n: usize) -> Frame {
        let n = n.max(1);
        let total = self.num_rows();
        let schema = self.schema.clone();
        if total == 0 {
            return Frame::empty(schema);
        }
        let per = total.div_ceil(n);
        let ncols = schema.len();
        let mut builders: Vec<Vec<Value>> = (0..ncols).map(|_| Vec::with_capacity(per)).collect();
        let mut out: Vec<Partition> = Vec::with_capacity(n);
        let mut rows_in_builder = 0usize;
        for part in self.partitions {
            let nrows = part.num_rows();
            let cols = part.into_columns();
            let mut col_iters: Vec<_> = cols.into_iter().map(|c| c.into_values()).collect();
            for _ in 0..nrows {
                for (ci, it) in col_iters.iter_mut().enumerate() {
                    builders[ci].push(it.next().expect("column length mismatch"));
                }
                rows_in_builder += 1;
                if rows_in_builder == per {
                    let cols: Vec<Column> = builders
                        .iter_mut()
                        .zip(schema.fields())
                        .map(|(b, f)| Column::from_values(std::mem::take(b), f.dtype))
                        .collect();
                    out.push(Partition::new(cols));
                    rows_in_builder = 0;
                }
            }
        }
        if rows_in_builder > 0 {
            let cols: Vec<Column> = builders
                .iter_mut()
                .zip(schema.fields())
                .map(|(b, f)| Column::from_values(std::mem::take(b), f.dtype))
                .collect();
            out.push(Partition::new(cols));
        }
        Frame { schema, partitions: out }
    }

    /// Collect all partitions into a single contiguous [`LocalFrame`]
    /// (the Spark→pandas conversion of Algorithm 1, step 15 — the cost
    /// that dominates P3SAPP's post-cleaning time in Table 3).
    pub fn collect(self) -> LocalFrame {
        let mut local = LocalFrame::empty(self.schema.clone());
        for p in self.partitions {
            local.extend_from_partition(p);
        }
        local
    }

    /// Index of a column by name.
    pub fn column_index(&self, name: &str) -> Result<usize> {
        self.schema
            .index_of(name)
            .ok_or_else(|| anyhow::anyhow!("no such column: {name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_col_schema() -> Schema {
        Schema::new(vec![
            Field::new("title", DType::Str),
            Field::new("abstract", DType::Str),
        ])
    }

    fn part(rows: &[(&str, &str)]) -> Partition {
        Partition::new(vec![
            Column::from_strs(rows.iter().map(|r| Some(r.0.to_string())).collect()),
            Column::from_strs(rows.iter().map(|r| Some(r.1.to_string())).collect()),
        ])
    }

    #[test]
    fn union_is_partition_concat() {
        let s = two_col_schema();
        let a = Frame::from_partition(s.clone(), part(&[("t1", "a1")])).unwrap();
        let b = Frame::from_partition(s, part(&[("t2", "a2"), ("t3", "a3")])).unwrap();
        let u = a.union(b).unwrap();
        assert_eq!(u.num_partitions(), 2);
        assert_eq!(u.num_rows(), 3);
    }

    #[test]
    fn union_schema_mismatch_fails() {
        let a = Frame::empty(two_col_schema());
        let b = Frame::empty(Schema::new(vec![Field::new("doi", DType::Str)]));
        assert!(a.union(b).is_err());
    }

    #[test]
    fn collect_concatenates_rows_in_partition_order() {
        let s = two_col_schema();
        let mut f = Frame::empty(s);
        f.push_partition(part(&[("t1", "a1")])).unwrap();
        f.push_partition(part(&[("t2", "a2")])).unwrap();
        let local = f.collect();
        assert_eq!(local.num_rows(), 2);
        assert_eq!(local.column(0).get_str(0), Some("t1"));
        assert_eq!(local.column(0).get_str(1), Some("t2"));
    }

    #[test]
    fn push_partition_checks_schema() {
        let mut f = Frame::empty(two_col_schema());
        let bad = Partition::new(vec![Column::from_strs(vec![Some("x".into())])]);
        assert!(f.push_partition(bad).is_err());
    }

    #[test]
    fn repartition_preserves_rows_and_order() {
        let s = two_col_schema();
        let mut f = Frame::empty(s);
        f.push_partition(part(&[("a", "1"), ("b", "2"), ("c", "3")])).unwrap();
        f.push_partition(part(&[("d", "4"), ("e", "5")])).unwrap();
        let r = f.repartition(2);
        assert_eq!(r.num_partitions(), 2);
        assert_eq!(r.num_rows(), 5);
        let local = r.collect();
        let titles: Vec<_> = (0..5).map(|i| local.column(0).get_str(i).unwrap().to_string()).collect();
        assert_eq!(titles, vec!["a", "b", "c", "d", "e"]);
    }

    #[test]
    fn repartition_empty_frame() {
        let f = Frame::empty(two_col_schema());
        let r = f.repartition(4);
        assert_eq!(r.num_partitions(), 0);
        assert_eq!(r.num_rows(), 0);
    }
}
