//! Vocabulary building and sequence encoding: the bridge between the
//! cleaned text frame (pipeline output) and the model's fixed-shape
//! int32 tensors.
//!
//! Special ids mirror `python/compile/model.py`: PAD=0, BOS=1, EOS=2,
//! UNK=3 (pinned by the artifact manifest and checked at load time).

mod batcher;

pub use batcher::{Batcher, EncodedBatch};

use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const UNK: i32 = 3;
const N_SPECIAL: usize = 4;
const SPECIAL_NAMES: [&str; N_SPECIAL] = ["<pad>", "<start>", "<end>", "<unk>"];

/// Frequency-ranked word↔id mapping.
#[derive(Debug, Clone)]
pub struct Vocabulary {
    word_to_id: HashMap<String, i32>,
    id_to_word: Vec<String>,
}

impl Vocabulary {
    /// Build from whitespace-tokenized texts, keeping the `max_size -
    /// N_SPECIAL` most frequent words (ties broken lexicographically for
    /// determinism).
    pub fn build<'a>(texts: impl Iterator<Item = &'a str>, max_size: usize) -> Self {
        let mut freq: HashMap<&'a str, u64> = HashMap::new();
        for text in texts {
            for w in text.split_whitespace() {
                *freq.entry(w).or_default() += 1;
            }
        }
        let mut ranked: Vec<(&str, u64)> = freq.into_iter().collect();
        ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        let keep = max_size.saturating_sub(N_SPECIAL);

        let mut id_to_word: Vec<String> =
            SPECIAL_NAMES.iter().map(|s| s.to_string()).collect();
        id_to_word.extend(ranked.iter().take(keep).map(|(w, _)| w.to_string()));
        let word_to_id = id_to_word
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as i32))
            .collect();
        Vocabulary { word_to_id, id_to_word }
    }

    pub fn len(&self) -> usize {
        self.id_to_word.len()
    }

    pub fn is_empty(&self) -> bool {
        self.id_to_word.len() <= N_SPECIAL
    }

    pub fn id(&self, word: &str) -> i32 {
        self.word_to_id.get(word).copied().unwrap_or(UNK)
    }

    pub fn word(&self, id: i32) -> &str {
        self.id_to_word
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("<unk>")
    }

    /// Out-of-vocabulary rate over a text (diagnostics).
    pub fn oov_rate(&self, text: &str) -> f64 {
        let mut total = 0usize;
        let mut oov = 0usize;
        for w in text.split_whitespace() {
            total += 1;
            if !self.word_to_id.contains_key(w) {
                oov += 1;
            }
        }
        if total == 0 {
            0.0
        } else {
            oov as f64 / total as f64
        }
    }

    /// Encode a source text: right-pad/truncate to `len`.
    /// Returns (ids, mask).
    pub fn encode_src(&self, text: &str, len: usize) -> (Vec<i32>, Vec<f32>) {
        let mut ids = Vec::with_capacity(len);
        for w in text.split_whitespace().take(len) {
            ids.push(self.id(w));
        }
        let real = ids.len();
        ids.resize(len, PAD);
        let mut mask = vec![0.0f32; len];
        mask[..real].fill(1.0);
        (ids, mask)
    }

    /// Encode a target title for teacher forcing: returns
    /// (tgt_in = [BOS, w1..], tgt_out = [w1.., EOS], mask), all length
    /// `len`.
    pub fn encode_tgt(&self, text: &str, len: usize) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let words: Vec<i32> = text
            .split_whitespace()
            .take(len - 1) // room for EOS
            .map(|w| self.id(w))
            .collect();
        let mut tgt_out = words.clone();
        tgt_out.push(EOS);
        let real = tgt_out.len();
        tgt_out.resize(len, PAD);

        let mut tgt_in = Vec::with_capacity(len);
        tgt_in.push(BOS);
        tgt_in.extend(&words);
        tgt_in.resize(len, PAD);

        let mut mask = vec![0.0f32; len];
        mask[..real].fill(1.0);
        (tgt_in, tgt_out, mask)
    }

    /// Decode generated ids back to words, stopping at EOS/PAD.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            if id == EOS || id == PAD {
                break;
            }
            if id == BOS {
                continue;
            }
            if !out.is_empty() {
                out.push(' ');
            }
            out.push_str(self.word(id));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab() -> Vocabulary {
        Vocabulary::build(
            ["deep learning model", "model training data", "model data"]
                .into_iter(),
            16,
        )
    }

    #[test]
    fn specials_reserved() {
        let v = vocab();
        assert_eq!(v.word(PAD), "<pad>");
        assert_eq!(v.word(BOS), "<start>");
        assert_eq!(v.word(EOS), "<end>");
        assert_eq!(v.word(UNK), "<unk>");
    }

    #[test]
    fn frequency_ranked() {
        let v = vocab();
        // "model" (3) ranks before "data" (2) before the rest (1 each).
        assert_eq!(v.id("model"), 4);
        assert_eq!(v.id("data"), 5);
        assert_eq!(v.id("never-seen"), UNK);
    }

    #[test]
    fn max_size_enforced() {
        let v = Vocabulary::build(["a b c d e f g h"].into_iter(), 6);
        assert_eq!(v.len(), 6);
        assert_eq!(v.id("a"), 4);
        assert_eq!(v.id("b"), 5);
        assert_eq!(v.id("c"), UNK); // truncated
    }

    #[test]
    fn encode_src_pads_and_masks() {
        let v = vocab();
        let (ids, mask) = v.encode_src("model data", 4);
        assert_eq!(ids, vec![v.id("model"), v.id("data"), PAD, PAD]);
        assert_eq!(mask, vec![1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn encode_src_truncates() {
        let v = vocab();
        let (ids, mask) = v.encode_src("model data model data model", 3);
        assert_eq!(ids.len(), 3);
        assert_eq!(mask, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn encode_tgt_teacher_forcing_layout() {
        let v = vocab();
        let (tin, tout, mask) = v.encode_tgt("model data", 5);
        assert_eq!(tin, vec![BOS, v.id("model"), v.id("data"), PAD, PAD]);
        assert_eq!(tout, vec![v.id("model"), v.id("data"), EOS, PAD, PAD]);
        assert_eq!(mask, vec![1.0, 1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn encode_tgt_long_title_reserves_eos() {
        let v = vocab();
        let (_, tout, _) = v.encode_tgt("model data model data model data", 4);
        assert_eq!(tout[3], EOS);
    }

    #[test]
    fn decode_roundtrip_stops_at_eos() {
        let v = vocab();
        let (_, tout, _) = v.encode_tgt("model data", 5);
        assert_eq!(v.decode(&tout), "model data");
    }

    #[test]
    fn oov_rate() {
        let v = vocab();
        assert_eq!(v.oov_rate("model xyzzy"), 0.5);
        assert_eq!(v.oov_rate(""), 0.0);
    }
}
