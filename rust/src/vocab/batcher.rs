//! Batch assembly: cleaned (title, abstract) rows → fixed-shape int32/f32
//! host tensors matching the train_step artifact signature.

use super::Vocabulary;
use crate::corpus::Rng;
use crate::frame::LocalFrame;
use crate::Result;

/// One training batch, flattened row-major host buffers.
#[derive(Debug, Clone)]
pub struct EncodedBatch {
    pub src: Vec<i32>,      // [B * S]
    pub src_mask: Vec<f32>, // [B * S]
    pub tgt_in: Vec<i32>,   // [B * T]
    pub tgt_out: Vec<i32>,  // [B * T]
    pub tgt_mask: Vec<f32>, // [B * T]
    pub batch: usize,
    pub src_len: usize,
    pub tgt_len: usize,
}

/// Deterministic batch iterator over a cleaned frame: encodes all pairs
/// once, shuffles per epoch with a seeded PRNG, yields full batches
/// (remainder rows are dropped, as Keras `fit` does with
/// `drop_remainder`).
#[derive(Debug)]
pub struct Batcher {
    pairs: Vec<(Vec<i32>, Vec<f32>, Vec<i32>, Vec<i32>, Vec<f32>)>,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    batch: usize,
    src_len: usize,
    tgt_len: usize,
}

impl Batcher {
    /// Build from a cleaned frame. `abstract_col` feeds `src`,
    /// `title_col` feeds the target side.
    pub fn new(
        frame: &LocalFrame,
        vocab: &Vocabulary,
        title_col: &str,
        abstract_col: &str,
        batch: usize,
        src_len: usize,
        tgt_len: usize,
        seed: u64,
    ) -> Result<Self> {
        let t_idx = frame.column_index(title_col)?;
        let a_idx = frame.column_index(abstract_col)?;
        let mut pairs = Vec::with_capacity(frame.num_rows());
        for i in 0..frame.num_rows() {
            let (Some(title), Some(abs)) =
                (frame.column(t_idx).get_str(i), frame.column(a_idx).get_str(i))
            else {
                continue; // post-cleaning should have removed these
            };
            let (src, src_mask) = vocab.encode_src(abs, src_len);
            let (tgt_in, tgt_out, tgt_mask) = vocab.encode_tgt(title, tgt_len);
            pairs.push((src, src_mask, tgt_in, tgt_out, tgt_mask));
        }
        if pairs.is_empty() {
            anyhow::bail!("no usable (title, abstract) pairs for batching");
        }
        let order: Vec<usize> = (0..pairs.len()).collect();
        Ok(Batcher {
            pairs,
            order,
            cursor: 0,
            rng: Rng::new(seed),
            batch,
            src_len,
            tgt_len,
        })
    }

    pub fn num_pairs(&self) -> usize {
        self.pairs.len()
    }

    /// Full batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.pairs.len() / self.batch
    }

    fn reshuffle(&mut self) {
        // Fisher-Yates with the seeded PRNG.
        for i in (1..self.order.len()).rev() {
            let j = self.rng.gen_range(i + 1);
            self.order.swap(i, j);
        }
        self.cursor = 0;
    }

    /// Next full batch, reshuffling at epoch boundaries.
    pub fn next_batch(&mut self) -> EncodedBatch {
        if self.cursor + self.batch > self.order.len() {
            self.reshuffle();
        }
        let b = self.batch;
        let (s, t) = (self.src_len, self.tgt_len);
        let mut out = EncodedBatch {
            src: Vec::with_capacity(b * s),
            src_mask: Vec::with_capacity(b * s),
            tgt_in: Vec::with_capacity(b * t),
            tgt_out: Vec::with_capacity(b * t),
            tgt_mask: Vec::with_capacity(b * t),
            batch: b,
            src_len: s,
            tgt_len: t,
        };
        for k in 0..b {
            let idx = self.order[self.cursor + k];
            let (src, sm, tin, tout, tm) = &self.pairs[idx];
            out.src.extend(src);
            out.src_mask.extend(sm);
            out.tgt_in.extend(tin);
            out.tgt_out.extend(tout);
            out.tgt_mask.extend(tm);
        }
        self.cursor += b;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frame::{Column, Schema};

    fn frame(n: usize) -> LocalFrame {
        LocalFrame::from_columns(
            Schema::strings(&["title", "abstract"]),
            vec![
                Column::from_strs((0..n).map(|i| Some(format!("title {i}"))).collect()),
                Column::from_strs(
                    (0..n).map(|i| Some(format!("abstract text number {i}"))).collect(),
                ),
            ],
        )
        .unwrap()
    }

    fn vocab(f: &LocalFrame) -> Vocabulary {
        let texts: Vec<String> = (0..f.num_rows())
            .flat_map(|i| {
                [
                    f.column(0).get_str(i).unwrap().to_string(),
                    f.column(1).get_str(i).unwrap().to_string(),
                ]
            })
            .collect();
        Vocabulary::build(texts.iter().map(|s| s.as_str()), 64)
    }

    #[test]
    fn batch_shapes() {
        let f = frame(10);
        let v = vocab(&f);
        let mut b = Batcher::new(&f, &v, "title", "abstract", 4, 6, 3, 1).unwrap();
        assert_eq!(b.num_pairs(), 10);
        assert_eq!(b.batches_per_epoch(), 2);
        let batch = b.next_batch();
        assert_eq!(batch.src.len(), 4 * 6);
        assert_eq!(batch.tgt_in.len(), 4 * 3);
        assert_eq!(batch.tgt_mask.len(), 4 * 3);
    }

    #[test]
    fn deterministic_for_seed() {
        let f = frame(12);
        let v = vocab(&f);
        let mut b1 = Batcher::new(&f, &v, "title", "abstract", 4, 6, 3, 7).unwrap();
        let mut b2 = Batcher::new(&f, &v, "title", "abstract", 4, 6, 3, 7).unwrap();
        for _ in 0..6 {
            assert_eq!(b1.next_batch().src, b2.next_batch().src);
        }
    }

    #[test]
    fn epochs_cycle_without_panic() {
        let f = frame(5);
        let v = vocab(&f);
        let mut b = Batcher::new(&f, &v, "title", "abstract", 2, 6, 3, 1).unwrap();
        for _ in 0..20 {
            let batch = b.next_batch();
            assert_eq!(batch.batch, 2);
        }
    }

    #[test]
    fn empty_frame_errors() {
        let f = frame(0);
        let v = Vocabulary::build([].into_iter(), 8);
        assert!(Batcher::new(&f, &v, "title", "abstract", 2, 6, 3, 1).is_err());
    }
}
