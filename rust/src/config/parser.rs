//! TOML-subset parser: `[section]`, `key = value` with string / int /
//! float / bool / flat-array values, `#` comments. Enough for launcher
//! configs; deliberately not a full TOML implementation.

use crate::Result;
use std::collections::BTreeMap;

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

/// Parse into section → (key → value). Keys before any `[section]`
/// header land in the "" section.
pub fn parse_toml(text: &str) -> Result<BTreeMap<String, BTreeMap<String, TomlValue>>> {
    let mut out: BTreeMap<String, BTreeMap<String, TomlValue>> = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            anyhow::ensure!(!section.is_empty(), "line {}: empty section name", lineno + 1);
            out.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        anyhow::ensure!(!key.is_empty(), "line {}: empty key", lineno + 1);
        let value = parse_value(value.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let dup = out
            .entry(section.clone())
            .or_default()
            .insert(key.to_string(), value);
        anyhow::ensure!(dup.is_none(), "line {}: duplicate key '{key}'", lineno + 1);
    }
    Ok(out)
}

/// Strip a `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    anyhow::ensure!(!s.is_empty(), "empty value");
    if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|item| parse_value(item.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    anyhow::bail!("cannot parse value '{s}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = r#"
            top = 1
            [a]
            s = "hello # not a comment"
            i = -3          # trailing comment
            f = 2.5
            b = true
            arr = [1, 2, 3]
            [b]
            empty_arr = []
        "#;
        let t = parse_toml(doc).unwrap();
        assert_eq!(t[""]["top"], TomlValue::Int(1));
        assert_eq!(t["a"]["s"], TomlValue::Str("hello # not a comment".into()));
        assert_eq!(t["a"]["i"], TomlValue::Int(-3));
        assert_eq!(t["a"]["f"], TomlValue::Float(2.5));
        assert_eq!(t["a"]["b"], TomlValue::Bool(true));
        assert_eq!(
            t["a"]["arr"],
            TomlValue::Array(vec![TomlValue::Int(1), TomlValue::Int(2), TomlValue::Int(3)])
        );
        assert_eq!(t["b"]["empty_arr"], TomlValue::Array(vec![]));
    }

    #[test]
    fn errors() {
        assert!(parse_toml("noequals").is_err());
        assert!(parse_toml("[]\n").is_err());
        assert!(parse_toml("k = \n").is_err());
        assert!(parse_toml("k = what\n").is_err());
        assert!(parse_toml("k = 1\nk = 2\n").is_err(), "duplicate key");
    }

    #[test]
    fn escaped_quotes_in_strings() {
        let t = parse_toml(r#"k = "a \"b\" c""#).unwrap();
        assert_eq!(t[""]["k"], TomlValue::Str(r#"a "b" c"#.into()));
    }
}
