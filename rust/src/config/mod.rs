//! Configuration system: a TOML-subset parser (sections, string /
//! integer / float / boolean values, comments) plus the typed
//! [`AppConfig`] the launcher consumes. No external TOML crate exists in
//! the vendored closure, so the subset parser is part of the substrate.

mod parser;

pub use parser::{parse_toml, TomlValue};

use crate::Result;
use std::collections::BTreeMap;
use std::path::Path;

/// Corpus-generation settings ([corpus] section).
#[derive(Debug, Clone, PartialEq)]
pub struct CorpusConfig {
    pub seed: u64,
    pub scale: f64,
    pub html_noise_rate: f64,
    pub dup_rate: f64,
}

/// Engine settings ([engine]).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    /// 0 = local[*] (all logical cores).
    pub workers: usize,
    pub queue_cap: usize,
    pub short_word_threshold: usize,
}

/// Model/training settings ([model]).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub artifacts_dir: String,
    pub train_steps: usize,
    pub batch_seed: u64,
}

/// Cost-model settings ([cost]).
#[derive(Debug, Clone, PartialEq)]
pub struct CostConfig {
    /// Hourly price of the GPU instance (the paper's FloydHub analog).
    pub hourly_price: f64,
    pub epochs: Vec<u32>,
}

/// The full launcher configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct AppConfig {
    pub corpus: CorpusConfig,
    pub engine: EngineConfig,
    pub model: ModelConfig,
    pub cost: CostConfig,
}

impl Default for AppConfig {
    fn default() -> Self {
        AppConfig {
            corpus: CorpusConfig { seed: 42, scale: 1.0, html_noise_rate: 0.3, dup_rate: 0.05 },
            engine: EngineConfig { workers: 0, queue_cap: 16, short_word_threshold: 1 },
            model: ModelConfig {
                artifacts_dir: "artifacts".into(),
                train_steps: 200,
                batch_seed: 7,
            },
            cost: CostConfig { hourly_price: 0.9, epochs: vec![10, 25, 50] },
        }
    }
}

impl AppConfig {
    /// Load from a TOML file, overlaying defaults; unknown keys are
    /// rejected (typos must not silently fall back to defaults).
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read config {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let sections = parse_toml(text)?;
        let mut cfg = AppConfig::default();
        for (section, values) in &sections {
            match section.as_str() {
                "corpus" => apply(values, |k, v| match k {
                    "seed" => set_u64(v, &mut cfg.corpus.seed),
                    "scale" => set_f64(v, &mut cfg.corpus.scale),
                    "html_noise_rate" => set_f64(v, &mut cfg.corpus.html_noise_rate),
                    "dup_rate" => set_f64(v, &mut cfg.corpus.dup_rate),
                    _ => unknown(section, k),
                })?,
                "engine" => apply(values, |k, v| match k {
                    "workers" => set_usize(v, &mut cfg.engine.workers),
                    "queue_cap" => set_usize(v, &mut cfg.engine.queue_cap),
                    "short_word_threshold" => {
                        set_usize(v, &mut cfg.engine.short_word_threshold)
                    }
                    _ => unknown(section, k),
                })?,
                "model" => apply(values, |k, v| match k {
                    "artifacts_dir" => set_string(v, &mut cfg.model.artifacts_dir),
                    "train_steps" => set_usize(v, &mut cfg.model.train_steps),
                    "batch_seed" => set_u64(v, &mut cfg.model.batch_seed),
                    _ => unknown(section, k),
                })?,
                "cost" => apply(values, |k, v| match k {
                    "hourly_price" => set_f64(v, &mut cfg.cost.hourly_price),
                    "epochs" => {
                        if let TomlValue::Array(items) = v {
                            cfg.cost.epochs = items
                                .iter()
                                .filter_map(|x| match x {
                                    TomlValue::Int(i) => Some(*i as u32),
                                    _ => None,
                                })
                                .collect();
                            Ok(())
                        } else {
                            anyhow::bail!("cost.epochs must be an integer array")
                        }
                    }
                    _ => unknown(section, k),
                })?,
                other => anyhow::bail!("unknown config section [{other}]"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity bounds.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.corpus.html_noise_rate),
            "corpus.html_noise_rate must be in [0, 1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.corpus.dup_rate),
            "corpus.dup_rate must be in [0, 1]"
        );
        anyhow::ensure!(self.corpus.scale > 0.0, "corpus.scale must be positive");
        anyhow::ensure!(self.engine.queue_cap >= 1, "engine.queue_cap must be >= 1");
        anyhow::ensure!(self.cost.hourly_price >= 0.0, "cost.hourly_price must be >= 0");
        anyhow::ensure!(!self.cost.epochs.is_empty(), "cost.epochs must be non-empty");
        Ok(())
    }
}

fn apply(
    values: &BTreeMap<String, TomlValue>,
    mut f: impl FnMut(&str, &TomlValue) -> Result<()>,
) -> Result<()> {
    for (k, v) in values {
        f(k, v)?;
    }
    Ok(())
}

fn unknown(section: &str, key: &str) -> Result<()> {
    anyhow::bail!("unknown config key {section}.{key}")
}

fn set_u64(v: &TomlValue, dst: &mut u64) -> Result<()> {
    match v {
        TomlValue::Int(i) if *i >= 0 => {
            *dst = *i as u64;
            Ok(())
        }
        _ => anyhow::bail!("expected non-negative integer"),
    }
}

fn set_usize(v: &TomlValue, dst: &mut usize) -> Result<()> {
    match v {
        TomlValue::Int(i) if *i >= 0 => {
            *dst = *i as usize;
            Ok(())
        }
        _ => anyhow::bail!("expected non-negative integer"),
    }
}

fn set_f64(v: &TomlValue, dst: &mut f64) -> Result<()> {
    match v {
        TomlValue::Float(f) => {
            *dst = *f;
            Ok(())
        }
        TomlValue::Int(i) => {
            *dst = *i as f64;
            Ok(())
        }
        _ => anyhow::bail!("expected number"),
    }
}

fn set_string(v: &TomlValue, dst: &mut String) -> Result<()> {
    match v {
        TomlValue::Str(s) => {
            *dst = s.clone();
            Ok(())
        }
        _ => anyhow::bail!("expected string"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        AppConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_overlays_defaults() {
        let cfg = AppConfig::parse(
            r#"
            # experiment config
            [corpus]
            seed = 7
            scale = 2.5

            [engine]
            workers = 4

            [cost]
            hourly_price = 1.5
            epochs = [5, 10]
            "#,
        )
        .unwrap();
        assert_eq!(cfg.corpus.seed, 7);
        assert_eq!(cfg.corpus.scale, 2.5);
        assert_eq!(cfg.engine.workers, 4);
        assert_eq!(cfg.engine.queue_cap, 16, "default preserved");
        assert_eq!(cfg.cost.epochs, vec![5, 10]);
        assert_eq!(cfg.cost.hourly_price, 1.5);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(AppConfig::parse("[engine]\nworkerz = 4\n").is_err());
        assert!(AppConfig::parse("[nope]\nx = 1\n").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(AppConfig::parse("[corpus]\nhtml_noise_rate = 1.5\n").is_err());
        assert!(AppConfig::parse("[corpus]\nscale = 0.0\n").is_err());
        assert!(AppConfig::parse("[cost]\nepochs = []\n").is_err());
    }
}
