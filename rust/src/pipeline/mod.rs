//! Spark-ML-style transformer pipeline — the P3SAPP contribution.
//!
//! Mirrors the Spark ML `feature` API shape the paper extends:
//! a [`Transformer`] consumes an `inputCol` and produces an `outputCol`
//! (possibly the same column, possibly a new dtype), and a [`Pipeline`]
//! chains transformers into a single workflow that is `fit` to data
//! (producing a [`PipelineModel`]) and then `transform`ed — with the
//! transform executed **per-partition in parallel** by the
//! [`crate::engine`] worker pool.
//!
//! The four APIs the paper implements (§4.1) plus the two Spark built-ins
//! it reuses (§3.2) all live in [`stages`]:
//!
//! | Paper API | Stage |
//! |---|---|
//! | ConvertToLower (§4.1.1) | [`stages::ConvertToLower`] |
//! | RemoveHTMLTags (§4.1.2) | [`stages::RemoveHtmlTags`] |
//! | RemoveUnwantedCharacters (§4.1.3) | [`stages::RemoveUnwantedCharacters`] |
//! | RemoveShortWords (§4.1.4) | [`stages::RemoveShortWords`] |
//! | Tokenizer (Spark built-in) | [`stages::Tokenizer`] |
//! | StopWordsRemover (built-in + case-study string variant) | [`stages::StopWordsRemover`], [`stages::StopWordsRemoverStr`] |

pub mod features;
pub mod presets;
pub mod stages;

use crate::engine::Executor;
use crate::frame::{Column, DType, Frame, Schema};
use crate::Result;
use std::sync::Arc;

/// A feature transformer: one stage of the preprocessing pipeline.
///
/// `transform_column` maps the whole input column of one partition —
/// column-at-a-time (not row-at-a-time) so per-stage scratch buffers are
/// amortized across the partition, which is where P3SAPP's cleaning-time
/// win over the row-loop conventional approach comes from.
pub trait Transformer: Send + Sync {
    /// Stage name (diagnostics / ablation bench labels).
    fn name(&self) -> &'static str;
    /// Column read by this stage.
    fn input_col(&self) -> &str;
    /// Column written by this stage (may equal `input_col`).
    fn output_col(&self) -> &str;
    /// Output dtype given the input dtype.
    fn output_dtype(&self, input: DType) -> DType;
    /// Transform one partition's input column.
    fn transform_column(&self, input: &Column) -> Column;

    /// Owned variant used when the stage rewrites its own input column
    /// (`input_col == output_col`). Stages that can transform in place
    /// override this to avoid re-allocating the column; the default
    /// falls back to the borrowing path.
    fn transform_column_owned(&self, input: Column) -> Column {
        self.transform_column(&input)
    }

    /// The fusable per-row string kernel of this stage, if it is a pure
    /// same-column `string -> string` rewrite. Drives plan-level stage
    /// fusion ([`crate::plan`]); stages that tokenize, change dtype, or
    /// write a different column return `None` (the default) and act as
    /// fusion barriers.
    fn string_kernel(&self) -> Option<stages::StringKernel> {
        None
    }

    /// Human-readable stage label for plan EXPLAIN output.
    fn describe(&self) -> String {
        if self.input_col() == self.output_col() {
            format!("{}({})", self.name(), self.input_col())
        } else {
            format!("{}({} -> {})", self.name(), self.input_col(), self.output_col())
        }
    }

    /// Serializable description of this stage for the multi-process
    /// executor's wire format ([`crate::plan::process`]): a worker
    /// process rebuilds an equivalent transformer from the spec.
    /// Stages returning `None` (the default) cannot cross a process
    /// boundary, and a plan containing one fails `--processes` lowering
    /// with a clear error instead of silently running in-process.
    ///
    /// The spec type is crate-internal on purpose (the wire format is an
    /// implementation detail of [`crate::serve::proto`]'s framing):
    /// downstream crates cannot name it, so their stages inherit the
    /// `None` default and stay in-process.
    #[allow(private_interfaces)]
    fn wire_spec(&self) -> Option<crate::plan::process::WireStage> {
        None
    }
}

/// An estimator: a stage that must scan the data before it can
/// transform (Spark's `Estimator` — e.g. [`features::Idf`]). `fit`
/// receives the frame *as transformed by all previous pipeline stages*
/// plus its resolved input column index, and yields the fitted
/// transformer.
pub trait Estimator: Send + Sync {
    fn name(&self) -> &'static str;
    fn input_col(&self) -> &str;
    fn output_col(&self) -> &str;
    fn output_dtype(&self, input: DType) -> DType;
    fn fit_transformer(&self, frame: &Frame, in_idx: usize) -> Result<Box<dyn Transformer>>;

    /// Incremental fitting hook for the plan layer's two-pass physical
    /// strategy ([`crate::plan`]): pass 1 streams shards through the
    /// pre-estimator program and feeds each surviving partition's input
    /// column to this accumulator instead of materializing a frame.
    /// Estimators returning `None` (the default) cannot be lowered into
    /// a plan and must go through the eager [`Pipeline::fit`] path.
    fn accumulator(&self) -> Option<Box<dyn FitAccumulator>> {
        None
    }

    /// Stage label for plan EXPLAIN output **and** cache fingerprints.
    /// Implementations must include every fit-relevant parameter (e.g.
    /// `IDF`'s `min_doc_freq`): the rendered plan is hashed into the
    /// plan-cache key, so two estimators that would fit different models
    /// must describe themselves differently.
    fn describe(&self) -> String {
        format!("{}({} -> {})", self.name(), self.input_col(), self.output_col())
    }

    /// Serializable description of this estimator for the multi-process
    /// executor's partial-aggregate fit pass ([`crate::plan::process`]):
    /// each worker rebuilds the estimator, folds its shards into a local
    /// [`FitAccumulator`], and ships the accumulated state back for the
    /// driver to merge. `None` (the default) keeps the fit fold on the
    /// driver (workers ship admitted partitions instead).
    ///
    /// Crate-internal spec type, same rationale as
    /// [`Transformer::wire_spec`]: estimators outside this crate inherit
    /// the `None` default.
    #[allow(private_interfaces)]
    fn wire_spec(&self) -> Option<crate::plan::process::WireEstimator> {
        None
    }
}

/// Streaming fit state for one [`Estimator`]: the plan executor's pass 1
/// calls [`FitAccumulator::accumulate`] once per surviving partition (in
/// shard order, after dedup and any `Limit`), then
/// [`FitAccumulator::finish`] to obtain the fitted transformer that
/// pass 2 splices into the program.
pub trait FitAccumulator: Send {
    /// Fold one partition's input column into the fit state.
    fn accumulate(&mut self, col: &Column) -> Result<()>;
    /// Close the accumulation and build the fitted transformer.
    fn finish(self: Box<Self>) -> Result<Arc<dyn Transformer>>;

    /// Serialize the accumulated state for a cross-process fold (the
    /// multi-process executor's fit pass, [`crate::plan::process`]).
    /// `None` (the default) disables the partial-aggregate path; the
    /// executor then ships admitted partitions to the driver instead.
    fn partial(&self) -> Option<Vec<u8>> {
        None
    }

    /// Fold a state produced by [`FitAccumulator::partial`] in another
    /// process into this accumulator. Implementations must be
    /// order-insensitive across partials (worker completion order is
    /// nondeterministic) and reject malformed bytes with an error.
    fn merge_partial(&mut self, _bytes: &[u8]) -> Result<()> {
        anyhow::bail!("this accumulator does not support cross-process partial folds")
    }
}

/// One pipeline entry: transformer or estimator (Spark `PipelineStage`).
#[derive(Clone)]
enum StageKind {
    Transformer(Arc<dyn Transformer>),
    Estimator(Arc<dyn Estimator>),
}

impl StageKind {
    fn names(&self) -> (&'static str, &str, &str) {
        match self {
            StageKind::Transformer(t) => (t.name(), t.input_col(), t.output_col()),
            StageKind::Estimator(e) => (e.name(), e.input_col(), e.output_col()),
        }
    }
    fn output_dtype(&self, input: DType) -> DType {
        match self {
            StageKind::Transformer(t) => t.output_dtype(input),
            StageKind::Estimator(e) => e.output_dtype(input),
        }
    }
}

/// An unfitted pipeline: an ordered stage list (Spark `Pipeline`).
#[derive(Clone, Default)]
pub struct Pipeline {
    stages: Vec<StageKind>,
}

impl Pipeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a transformer stage (builder style).
    pub fn stage(mut self, t: impl Transformer + 'static) -> Self {
        self.stages.push(StageKind::Transformer(Arc::new(t)));
        self
    }

    /// Append an already-shared transformer stage — lets presets build
    /// the same stage list into a [`Pipeline`] or a
    /// [`crate::plan::LogicalPlan`] without duplicating it.
    pub fn stage_arc(mut self, t: Arc<dyn Transformer>) -> Self {
        self.stages.push(StageKind::Transformer(t));
        self
    }

    /// Append an estimator stage.
    pub fn estimator(mut self, e: impl Estimator + 'static) -> Self {
        self.stages.push(StageKind::Estimator(Arc::new(e)));
        self
    }

    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// Back-compat alias used by tests/docs: stage count.
    pub fn stages(&self) -> &[impl Sized] {
        &self.stages
    }

    /// Fit the pipeline to data: resolves every stage's input column
    /// against the evolving schema, pre-computes the output schema, and
    /// fits estimator stages on the frame *as transformed by the stages
    /// before them* (Spark `Pipeline.fit` semantics). Transformer-only
    /// pipelines never materialize intermediate data.
    pub fn fit(&self, frame: &Frame) -> Result<PipelineModel> {
        let mut schema = frame.schema().clone();
        let mut plan: Vec<StagePlan> = Vec::with_capacity(self.stages.len());
        // Materialized working copy — only if an estimator needs it.
        let has_estimator =
            self.stages.iter().any(|s| matches!(s, StageKind::Estimator(_)));
        let mut current: Option<Frame> = if has_estimator { Some(frame.clone()) } else { None };

        for st in &self.stages {
            let (name, input_col, output_col) = st.names();
            let in_idx = schema.index_of(input_col).ok_or_else(|| {
                anyhow::anyhow!("stage {name}: input column '{input_col}' not found")
            })?;
            let in_dtype = schema.fields()[in_idx].dtype;
            let out_dtype = st.output_dtype(in_dtype);
            let out_idx = match schema.index_of(output_col) {
                Some(i) => {
                    schema = schema.with_dtype(output_col, out_dtype).unwrap();
                    i
                }
                None => {
                    let mut fields = schema.fields().to_vec();
                    fields.push(crate::frame::Field::new(output_col, out_dtype));
                    schema = Schema::new(fields);
                    schema.len() - 1
                }
            };
            let fitted: Arc<dyn Transformer> = match st {
                StageKind::Transformer(t) => Arc::clone(t),
                StageKind::Estimator(e) => {
                    let data = current.as_ref().expect("materialized when estimators exist");
                    Arc::from(e.fit_transformer(data, in_idx)?)
                }
            };
            let sp = StagePlan { stage: fitted, in_idx, out_idx };
            if let Some(cur) = current.take() {
                current = Some(apply_stage(cur, &sp, &schema)?);
            }
            plan.push(sp);
        }
        Ok(PipelineModel { plan, output_schema: schema })
    }
}

/// Apply one fitted stage to a whole frame (single-threaded; used only
/// during estimator fitting).
fn apply_stage(frame: Frame, sp: &StagePlan, schema_after: &Schema) -> Result<Frame> {
    let (_, partitions) = frame.into_partitions();
    let out: Vec<crate::frame::Partition> = partitions
        .into_iter()
        .map(|mut part| {
            let col = sp.stage.transform_column(part.column(sp.in_idx));
            if sp.out_idx < part.num_columns() {
                part.replace_column(sp.out_idx, col);
                part
            } else {
                let mut cols = part.into_columns();
                cols.push(col);
                crate::frame::Partition::new(cols)
            }
        })
        .collect();
    Frame::from_partitions(schema_after.clone(), out)
}

/// One resolved stage: which column indices it reads/writes.
#[derive(Clone)]
struct StagePlan {
    stage: Arc<dyn Transformer>,
    in_idx: usize,
    out_idx: usize,
}

/// A fitted pipeline (Spark `PipelineModel`): ready to transform frames
/// with pre-resolved column indices.
#[derive(Clone)]
pub struct PipelineModel {
    plan: Vec<StagePlan>,
    output_schema: Schema,
}

impl PipelineModel {
    pub fn output_schema(&self) -> &Schema {
        &self.output_schema
    }

    /// Transform a distributed frame with `workers` parallel workers.
    /// Within a partition, stages run back-to-back (no barrier between
    /// stages — Spark's narrow-dependency chaining).
    pub fn transform(&self, frame: Frame, workers: usize) -> Result<Frame> {
        let (_, partitions) = frame.into_partitions();
        let plan = self.plan.clone();
        let exec = Executor::new(workers);
        let transformed = exec.map_partitions(partitions, move |mut part| {
            for sp in &plan {
                if sp.in_idx == sp.out_idx {
                    // In-place rewrite: hand the stage the owned column
                    // (zero-allocation sweep for the string stages).
                    let owned = part.take_column(sp.in_idx);
                    let out = sp.stage.transform_column_owned(owned);
                    part.replace_column(sp.out_idx, out);
                } else {
                    let out = sp.stage.transform_column(part.column(sp.in_idx));
                    if sp.out_idx < part.num_columns() {
                        part.replace_column(sp.out_idx, out);
                    } else {
                        let mut cols = part.into_columns();
                        cols.push(out);
                        part = crate::frame::Partition::new(cols);
                    }
                }
            }
            part
        });
        Frame::from_partitions(self.output_schema.clone(), transformed)
    }

    /// Single-threaded transform of one partition-worth of columns —
    /// used by tests and the sequential ablation bench.
    pub fn transform_local(&self, frame: Frame) -> Result<Frame> {
        self.transform(frame, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::stages::{ConvertToLower, RemoveHtmlTags, Tokenizer};
    use super::*;
    use crate::frame::{Column, Partition};

    fn frame(vals: &[Option<&str>]) -> Frame {
        Frame::from_partition(
            Schema::strings(&["abstract"]),
            Partition::new(vec![Column::from_strs(
                vals.iter().map(|v| v.map(String::from)).collect(),
            )]),
        )
        .unwrap()
    }

    #[test]
    fn fit_resolves_columns_and_schema() {
        let p = Pipeline::new()
            .stage(ConvertToLower::new("abstract"))
            .stage(Tokenizer::new("abstract", "words"));
        let m = p.fit(&frame(&[Some("X")])).unwrap();
        assert_eq!(m.output_schema().field_names(), vec!["abstract", "words"]);
        assert_eq!(m.output_schema().dtype_of("words"), Some(DType::Tokens));
    }

    #[test]
    fn fit_unknown_column_fails() {
        let p = Pipeline::new().stage(ConvertToLower::new("nope"));
        assert!(p.fit(&frame(&[Some("X")])).is_err());
    }

    #[test]
    fn chained_transform_applies_in_order() {
        let p = Pipeline::new()
            .stage(RemoveHtmlTags::new("abstract"))
            .stage(ConvertToLower::new("abstract"));
        let f = frame(&[Some("<b>Deep</b> LEARNING"), None]);
        let m = p.fit(&f).unwrap();
        let out = m.transform(f, 2).unwrap().collect();
        assert_eq!(out.column(0).get_str(0), Some(" deep  learning"));
        assert!(out.column(0).is_null(1), "nulls propagate");
    }

    #[test]
    fn new_output_column_appended() {
        let p = Pipeline::new().stage(Tokenizer::new("abstract", "words"));
        let f = frame(&[Some("a b")]);
        let m = p.fit(&f).unwrap();
        let out = m.transform(f, 1).unwrap().collect();
        assert_eq!(out.num_columns(), 2);
        assert_eq!(
            out.column(1).get_tokens(0).unwrap(),
            &["a".to_string(), "b".to_string()][..]
        );
    }

    #[test]
    fn parallel_and_serial_agree() {
        let vals: Vec<Option<String>> = (0..500)
            .map(|i| Some(format!("<p>Sample {i} TEXT</p>")))
            .collect();
        let parts: Vec<Partition> = vals
            .chunks(37)
            .map(|c| Partition::new(vec![Column::from_strs(c.to_vec())]))
            .collect();
        let schema = Schema::strings(&["abstract"]);
        let f1 = Frame::from_partitions(schema.clone(), parts.clone()).unwrap();
        let f2 = Frame::from_partitions(schema, parts).unwrap();
        let p = Pipeline::new()
            .stage(RemoveHtmlTags::new("abstract"))
            .stage(ConvertToLower::new("abstract"));
        let m = p.fit(&f1).unwrap();
        assert_eq!(
            m.transform(f1, 4).unwrap().collect(),
            m.transform_local(f2).unwrap().collect()
        );
    }
}
