//! Feature-extraction stages beyond the paper's four cleaning APIs —
//! the §7 future-work direction ("More APIs can be identified and
//! implemented"): `NGram` and `HashingTF` (Spark ML transformers) plus
//! `IDF`, the first **estimator** (a stage that must be `fit` to data
//! before it can transform), exercising the estimator half of the Spark
//! `Pipeline` contract. Together they give the TF-IDF feature pipeline
//! the paper's §2 cites as the classic scholarly-analytics workload.

use super::{Estimator, FitAccumulator, Transformer};
use crate::frame::{Column, DType, Frame};
use crate::plan::process::{WireEstimator, WireStage};
use crate::Result;
use std::sync::Arc;

/// Spark ML `NGram`: token sequence → sequence of space-joined n-grams.
pub struct NGram {
    input: String,
    output: String,
    n: usize,
}

impl NGram {
    pub fn new(input: impl Into<String>, output: impl Into<String>, n: usize) -> Self {
        assert!(n >= 1, "n must be >= 1");
        NGram { input: input.into(), output: output.into(), n }
    }
}

impl Transformer for NGram {
    fn name(&self) -> &'static str {
        "NGram"
    }
    fn input_col(&self) -> &str {
        &self.input
    }
    fn output_col(&self) -> &str {
        &self.output
    }
    fn output_dtype(&self, _input: DType) -> DType {
        DType::Tokens
    }
    fn transform_column(&self, input: &Column) -> Column {
        Column::from_token_lists(
            input
                .token_lists()
                .iter()
                .map(|row| {
                    row.as_ref().map(|toks| {
                        if toks.len() < self.n {
                            Vec::new()
                        } else {
                            toks.windows(self.n).map(|w| w.join(" ")).collect()
                        }
                    })
                })
                .collect(),
        )
    }
    fn describe(&self) -> String {
        // `n` must reach EXPLAIN output: the rendered plan is hashed
        // into the cache fingerprint, and bigram vs trigram plans must
        // not share a key.
        format!("NGram({} -> {}, n={})", self.input, self.output, self.n)
    }
    fn wire_spec(&self) -> Option<WireStage> {
        Some(WireStage::NGram {
            input: self.input.clone(),
            output: self.output.clone(),
            n: self.n,
        })
    }
}

/// Spark ML `HashingTF`: token sequence → fixed-size term-frequency
/// vector via feature hashing (no vocabulary pass needed).
pub struct HashingTF {
    input: String,
    output: String,
    num_features: usize,
}

impl HashingTF {
    pub fn new(input: impl Into<String>, output: impl Into<String>, num_features: usize) -> Self {
        assert!(num_features >= 1);
        HashingTF { input: input.into(), output: output.into(), num_features }
    }

    /// Term → bucket (FNV-1a mod buckets; murmur in real Spark — any
    /// stable hash preserves the semantics).
    pub fn bucket(&self, term: &str) -> usize {
        let mut h: u64 = 0xcbf29ce484222325;
        for &b in term.as_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        (h % self.num_features as u64) as usize
    }
}

impl Transformer for HashingTF {
    fn name(&self) -> &'static str {
        "HashingTF"
    }
    fn input_col(&self) -> &str {
        &self.input
    }
    fn output_col(&self) -> &str {
        &self.output
    }
    fn output_dtype(&self, _input: DType) -> DType {
        DType::Vector
    }
    fn transform_column(&self, input: &Column) -> Column {
        Column::from_vectors(
            input
                .token_lists()
                .iter()
                .map(|row| {
                    row.as_ref().map(|toks| {
                        let mut tf = vec![0.0f32; self.num_features];
                        for t in toks {
                            tf[self.bucket(t)] += 1.0;
                        }
                        tf
                    })
                })
                .collect(),
        )
    }
    fn describe(&self) -> String {
        // The bucket count shapes every downstream vector, so it must
        // be part of the rendered plan (and thus the cache key).
        format!("HashingTF({} -> {}, features={})", self.input, self.output, self.num_features)
    }
    fn wire_spec(&self) -> Option<WireStage> {
        Some(WireStage::HashingTF {
            input: self.input.clone(),
            output: self.output.clone(),
            num_features: self.num_features,
        })
    }
}

/// Spark ML `IDF` — an **estimator**: `fit` scans the corpus for
/// document frequencies and produces an [`IdfModel`] transformer with
/// idf(t) = ln((N + 1) / (df_t + 1)) (Spark's smoothed formula).
pub struct Idf {
    input: String,
    output: String,
    min_doc_freq: usize,
}

impl Idf {
    pub fn new(input: impl Into<String>, output: impl Into<String>) -> Self {
        Idf { input: input.into(), output: output.into(), min_doc_freq: 0 }
    }

    pub fn with_min_doc_freq(mut self, min_doc_freq: usize) -> Self {
        self.min_doc_freq = min_doc_freq;
        self
    }
}

impl Estimator for Idf {
    fn name(&self) -> &'static str {
        "IDF"
    }
    fn input_col(&self) -> &str {
        &self.input
    }
    fn output_col(&self) -> &str {
        &self.output
    }
    fn output_dtype(&self, _input: DType) -> DType {
        DType::Vector
    }

    fn fit_transformer(&self, frame: &Frame, in_idx: usize) -> Result<Box<dyn Transformer>> {
        // One fit code path: the eager Pipeline fit folds partitions
        // through the same accumulator the plan executor's pass 1 uses,
        // so the two can never diverge on the smoothing formula.
        let mut acc = self.make_accumulator();
        for part in frame.partitions() {
            acc.accumulate(part.column(in_idx))?;
        }
        Ok(Box::new(acc.finish_model()))
    }

    fn accumulator(&self) -> Option<Box<dyn FitAccumulator>> {
        Some(Box::new(self.make_accumulator()))
    }

    fn describe(&self) -> String {
        format!("IDF({} -> {}, min_df={})", self.input, self.output, self.min_doc_freq)
    }

    fn wire_spec(&self) -> Option<WireEstimator> {
        Some(WireEstimator::Idf {
            input: self.input.clone(),
            output: self.output.clone(),
            min_doc_freq: self.min_doc_freq,
        })
    }
}

impl Idf {
    fn make_accumulator(&self) -> IdfAccumulator {
        IdfAccumulator {
            input: self.input.clone(),
            output: self.output.clone(),
            min_doc_freq: self.min_doc_freq,
            df: Vec::new(),
            n_docs: 0,
        }
    }
}

/// Streaming document-frequency accumulation for [`Idf`] — the fit state
/// the plan executor's pass 1 folds shard partitions into.
struct IdfAccumulator {
    input: String,
    output: String,
    min_doc_freq: usize,
    df: Vec<u64>,
    n_docs: u64,
}

impl FitAccumulator for IdfAccumulator {
    fn accumulate(&mut self, col: &Column) -> Result<()> {
        if col.dtype() != DType::Vector {
            anyhow::bail!("IDF input column must be vector (got {})", col.dtype());
        }
        for row in col.vectors().iter().flatten() {
            if self.df.is_empty() {
                self.df = vec![0; row.len()];
            } else if self.df.len() != row.len() {
                anyhow::bail!(
                    "IDF: inconsistent vector widths ({} vs {})",
                    self.df.len(),
                    row.len()
                );
            }
            self.n_docs += 1;
            for (slot, &v) in self.df.iter_mut().zip(row) {
                if v > 0.0 {
                    *slot += 1;
                }
            }
        }
        Ok(())
    }

    fn finish(self: Box<Self>) -> Result<Arc<dyn Transformer>> {
        Ok(Arc::new(self.finish_model()))
    }

    /// Cross-process partial: `[n_docs u64][width u64][df u64 × width]`,
    /// little-endian. Document-frequency accumulation is a sum, so the
    /// fold is order-insensitive — any worker merge order fits the same
    /// model the single-process pass fits.
    fn partial(&self) -> Option<Vec<u8>> {
        let mut buf = Vec::with_capacity(16 + self.df.len() * 8);
        buf.extend_from_slice(&self.n_docs.to_le_bytes());
        buf.extend_from_slice(&(self.df.len() as u64).to_le_bytes());
        for &d in &self.df {
            buf.extend_from_slice(&d.to_le_bytes());
        }
        Some(buf)
    }

    fn merge_partial(&mut self, bytes: &[u8]) -> Result<()> {
        anyhow::ensure!(bytes.len() >= 16, "IDF partial too short ({} bytes)", bytes.len());
        let n_docs = u64::from_le_bytes(bytes[..8].try_into().unwrap());
        let width = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        // Checked math: an absurd declared width must error, not
        // overflow (which would panic in debug builds).
        let expect = width.checked_mul(8).and_then(|b| b.checked_add(16));
        anyhow::ensure!(
            expect == Some(bytes.len()),
            "IDF partial declares width {width} but carries {} bytes",
            bytes.len()
        );
        if width == 0 {
            // A worker whose shards held no non-null rows contributes
            // nothing (its accumulator never learned the vector width).
            anyhow::ensure!(n_docs == 0, "IDF partial counts docs without a width");
            return Ok(());
        }
        if self.df.is_empty() {
            self.df = vec![0; width];
        }
        anyhow::ensure!(
            self.df.len() == width,
            "IDF: inconsistent vector widths ({} vs {width})",
            self.df.len()
        );
        self.n_docs += n_docs;
        for (slot, chunk) in self.df.iter_mut().zip(bytes[16..].chunks_exact(8)) {
            *slot += u64::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(())
    }
}

impl IdfAccumulator {
    /// Spark's smoothed formula: idf(t) = ln((N + 1) / (df_t + 1)),
    /// zeroed below `min_doc_freq`.
    fn finish_model(self) -> IdfModel {
        let min_df = self.min_doc_freq as u64;
        let n_docs = self.n_docs;
        let idf: Vec<f32> = self
            .df
            .iter()
            .map(|&d| {
                if d < min_df {
                    0.0
                } else {
                    (((n_docs + 1) as f64) / ((d + 1) as f64)).ln() as f32
                }
            })
            .collect();
        IdfModel { input: self.input, output: self.output, idf }
    }
}

/// Fitted IDF: scales term-frequency vectors element-wise.
pub struct IdfModel {
    input: String,
    output: String,
    pub idf: Vec<f32>,
}

impl IdfModel {
    /// Assemble a fitted model from its weights — the multi-process
    /// executor uses this to rebuild the pass-2 model a driver fit and
    /// broadcast over the wire.
    pub fn new(input: impl Into<String>, output: impl Into<String>, idf: Vec<f32>) -> Self {
        IdfModel { input: input.into(), output: output.into(), idf }
    }
}

impl Transformer for IdfModel {
    fn name(&self) -> &'static str {
        "IDFModel"
    }
    fn input_col(&self) -> &str {
        &self.input
    }
    fn output_col(&self) -> &str {
        &self.output
    }
    fn output_dtype(&self, _input: DType) -> DType {
        DType::Vector
    }
    fn wire_spec(&self) -> Option<WireStage> {
        Some(WireStage::IdfModel {
            input: self.input.clone(),
            output: self.output.clone(),
            idf: self.idf.clone(),
        })
    }
    fn transform_column(&self, input: &Column) -> Column {
        Column::from_vectors(
            input
                .vectors()
                .iter()
                .map(|row| {
                    row.as_ref().map(|tf| {
                        tf.iter().zip(&self.idf).map(|(a, b)| a * b).collect()
                    })
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frame::{Partition, Schema, Field};
    use crate::pipeline::stages::Tokenizer;
    use crate::pipeline::Pipeline;

    fn token_frame(texts: &[&str]) -> Frame {
        let f = Frame::from_partition(
            Schema::strings(&["text"]),
            Partition::new(vec![Column::from_strs(
                texts.iter().map(|t| Some(t.to_string())).collect(),
            )]),
        )
        .unwrap();
        let p = Pipeline::new().stage(Tokenizer::new("text", "tokens"));
        p.fit(&f).unwrap().transform(f, 1).unwrap()
    }

    #[test]
    fn ngram_windows() {
        let f = token_frame(&["a b c d", "x"]);
        let idx = f.column_index("tokens").unwrap();
        let ng = NGram::new("tokens", "bigrams", 2);
        let col = ng.transform_column(f.partitions()[0].column(idx));
        assert_eq!(
            col.get_tokens(0).unwrap(),
            &["a b".to_string(), "b c".to_string(), "c d".to_string()][..]
        );
        assert!(col.get_tokens(1).unwrap().is_empty(), "short rows give empty");
    }

    #[test]
    fn hashing_tf_counts_terms() {
        let f = token_frame(&["cat dog cat"]);
        let idx = f.column_index("tokens").unwrap();
        let tf = HashingTF::new("tokens", "tf", 16);
        let col = tf.transform_column(f.partitions()[0].column(idx));
        let v = col.get_vector(0).unwrap();
        assert_eq!(v.iter().sum::<f32>(), 3.0);
        assert_eq!(v[tf.bucket("cat")], 2.0);
        assert_eq!(v[tf.bucket("dog")], 1.0);
    }

    #[test]
    fn idf_downweights_ubiquitous_terms() {
        // "the" in every doc, "quantum" in one.
        let f = token_frame(&["the quantum", "the cat", "the dog"]);
        let pipe = Pipeline::new()
            .stage(HashingTF::new("tokens", "tf", 64))
            .estimator(Idf::new("tf", "tfidf"));
        let model = pipe.fit(&f).unwrap();
        let out = model.transform(f, 1).unwrap().collect();
        let idx = out.column_index("tfidf").unwrap();
        let tfhash = HashingTF::new("tokens", "tf", 64);
        let v0 = out.column(idx).get_vector(0).unwrap();
        let the_w = v0[tfhash.bucket("the")];
        let quantum_w = v0[tfhash.bucket("quantum")];
        assert!(quantum_w > the_w, "idf must favor rare terms: {quantum_w} vs {the_w}");
        // "the" appears in all docs: idf = ln(4/4) = 0.
        assert_eq!(the_w, 0.0);
    }

    #[test]
    fn idf_respects_min_doc_freq() {
        let f = token_frame(&["rare common", "common x", "common y"]);
        let pipe = Pipeline::new()
            .stage(HashingTF::new("tokens", "tf", 64))
            .estimator(Idf::new("tf", "tfidf").with_min_doc_freq(2));
        let model = pipe.fit(&f).unwrap();
        let out = model.transform(f, 1).unwrap().collect();
        let idx = out.column_index("tfidf").unwrap();
        let tfhash = HashingTF::new("tokens", "tf", 64);
        let v0 = out.column(idx).get_vector(0).unwrap();
        assert_eq!(v0[tfhash.bucket("rare")], 0.0, "df=1 < min_doc_freq=2 → zeroed");
    }

    #[test]
    fn idf_rejects_wrong_dtype() {
        let f = token_frame(&["a"]);
        let pipe = Pipeline::new().estimator(Idf::new("tokens", "tfidf"));
        assert!(pipe.fit(&f).is_err());
    }

    #[test]
    fn describes_carry_fit_relevant_parameters() {
        assert_eq!(NGram::new("t", "b", 3).describe(), "NGram(t -> b, n=3)");
        assert_eq!(
            HashingTF::new("t", "tf", 128).describe(),
            "HashingTF(t -> tf, features=128)"
        );
        assert_eq!(
            Idf::new("tf", "tfidf").with_min_doc_freq(2).describe(),
            "IDF(tf -> tfidf, min_df=2)"
        );
    }

    #[test]
    fn incremental_accumulator_matches_whole_frame_fit() {
        let f = token_frame(&["the quantum", "the cat", "the dog"]);
        let idx = f.column_index("tokens").unwrap();
        let tf = HashingTF::new("tokens", "tf", 32);
        let tf_cols: Vec<Column> =
            f.partitions().iter().map(|p| tf.transform_column(p.column(idx))).collect();

        let est = Idf::new("tf", "tfidf").with_min_doc_freq(1);
        // Whole-frame fit on a single assembled column ...
        let whole = {
            let frame = Frame::from_partition(
                Schema::new(vec![Field::new("tf", DType::Vector)]),
                Partition::new(vec![tf_cols[0].clone()]),
            )
            .unwrap();
            est.fit_transformer(&frame, 0).unwrap()
        };
        // ... and the same rows split cell-by-cell through the
        // incremental accumulator must fit identical weights.
        let mut acc = est.accumulator().expect("IDF supports incremental fit");
        let rows = tf_cols[0].vectors().to_vec();
        for cell in rows {
            acc.accumulate(&Column::from_vectors(vec![cell])).unwrap();
        }
        let streamed = acc.finish().unwrap();
        let probe = Column::from_vectors(vec![Some(vec![1.0; 32])]);
        assert_eq!(
            whole.transform_column(&probe),
            streamed.transform_column(&probe),
            "incremental and whole-frame fits diverge"
        );
    }

    #[test]
    fn merged_partials_fit_the_same_model_as_one_accumulator() {
        let est = Idf::new("tf", "tfidf").with_min_doc_freq(1);
        let rows: Vec<Option<Vec<f32>>> = vec![
            Some(vec![1.0, 0.0, 2.0]),
            Some(vec![0.0, 1.0, 1.0]),
            None,
            Some(vec![3.0, 0.0, 0.0]),
        ];
        // One accumulator over everything vs two worker-local
        // accumulators merged as partials (in either order — the fold
        // must be order-insensitive).
        for order in [[0usize, 1], [1, 0]] {
            let mut a = est.accumulator().unwrap();
            a.accumulate(&Column::from_vectors(rows[..2].to_vec())).unwrap();
            let mut b = est.accumulator().unwrap();
            b.accumulate(&Column::from_vectors(rows[2..].to_vec())).unwrap();
            let partials = [a.partial().unwrap(), b.partial().unwrap()];
            let mut merged = est.accumulator().unwrap();
            for &i in &order {
                merged.merge_partial(&partials[i]).unwrap();
            }
            let probe = Column::from_vectors(vec![Some(vec![1.0; 3])]);
            let whole_model = {
                let mut w = est.accumulator().unwrap();
                w.accumulate(&Column::from_vectors(rows.clone())).unwrap();
                w.finish().unwrap()
            };
            let merged_model = merged.finish().unwrap();
            assert_eq!(
                whole_model.transform_column(&probe),
                merged_model.transform_column(&probe),
                "merged partials diverge from the single accumulator"
            );
        }
        // An empty worker contributes a width-0 partial that merges as
        // a no-op; malformed partials error.
        let empty = est.accumulator().unwrap();
        let mut acc = est.accumulator().unwrap();
        acc.merge_partial(&empty.partial().unwrap()).unwrap();
        assert!(acc.merge_partial(b"junk").is_err());
        // Width mismatch across partials is an error, not a silent skew.
        let mut narrow = est.accumulator().unwrap();
        narrow.accumulate(&Column::from_vectors(vec![Some(vec![1.0])])).unwrap();
        let mut wide = est.accumulator().unwrap();
        wide.accumulate(&Column::from_vectors(vec![Some(vec![1.0, 2.0])])).unwrap();
        let mut merged = est.accumulator().unwrap();
        merged.merge_partial(&narrow.partial().unwrap()).unwrap();
        assert!(merged.merge_partial(&wide.partial().unwrap()).is_err());
    }

    #[test]
    fn accumulator_rejects_wrong_dtype_and_width() {
        let est = Idf::new("tf", "tfidf");
        let mut acc = est.accumulator().unwrap();
        assert!(acc.accumulate(&Column::from_strs(vec![Some("x".into())])).is_err());
        let mut acc = est.accumulator().unwrap();
        acc.accumulate(&Column::from_vectors(vec![Some(vec![1.0, 0.0])])).unwrap();
        assert!(acc.accumulate(&Column::from_vectors(vec![Some(vec![1.0])])).is_err());
    }

    #[test]
    fn full_tfidf_pipeline_schema() {
        let f = token_frame(&["deep learning models", "deep nets"]);
        let pipe = Pipeline::new()
            .stage(NGram::new("tokens", "bigrams", 2))
            .stage(HashingTF::new("bigrams", "tf", 32))
            .estimator(Idf::new("tf", "tfidf"));
        let model = pipe.fit(&f).unwrap();
        let schema = model.output_schema();
        assert_eq!(schema.dtype_of("bigrams"), Some(DType::Tokens));
        assert_eq!(schema.dtype_of("tf"), Some(DType::Vector));
        assert_eq!(schema.dtype_of("tfidf"), Some(DType::Vector));
        let _ = Field::new("x", DType::Vector); // dtype is public API
        let out = model.transform(f, 2).unwrap();
        assert_eq!(out.num_rows(), 2);
    }
}
